"""Hypothesis property sweeps over the Pallas kernels' shape/value space.

Per the repro contract: hypothesis sweeps the kernels' shapes/dtypes and
asserts allclose against the pure-jnp oracles in ref.py.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import mriq as mriq_kernel
from compile.kernels import ref
from compile.kernels import tdfir as tdfir_kernel

SETTINGS = settings(max_examples=25, deadline=None)


def _arr(rng, shape, lo=-4.0, hi=4.0):
    return jnp.asarray(
        rng.uniform(lo, hi, size=shape).astype(np.float32)
    )


@SETTINGS
@given(
    m=st.integers(1, 6),
    n=st.integers(1, 96),
    k=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_tdfir_matches_ref_any_shape(m, n, k, seed):
    rng = np.random.default_rng(seed)
    xr, xi = _arr(rng, (m, n)), _arr(rng, (m, n))
    hr, hi = _arr(rng, (m, k)), _arr(rng, (m, k))
    yr, yi = tdfir_kernel.tdfir(xr, xi, hr, hi)
    er, ei = ref.tdfir_ref(xr, xi, hr, hi)
    np.testing.assert_allclose(yr, er, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(yi, ei, rtol=1e-4, atol=1e-4)


@SETTINGS
@given(
    m=st.integers(1, 4),
    n=st.integers(4, 64),
    k=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_tdfir_time_shift_equivariance(m, n, k, seed):
    """Shifting the input by one sample shifts the output by one sample
    (for the region with full history)."""
    rng = np.random.default_rng(seed)
    xr, xi = _arr(rng, (m, n)), _arr(rng, (m, n))
    hr, hi = _arr(rng, (m, k)), _arr(rng, (m, k))
    # Shifted input: prepend a zero column, drop the last.
    zs = jnp.zeros((m, 1), jnp.float32)
    xr_s = jnp.concatenate([zs, xr[:, :-1]], axis=1)
    xi_s = jnp.concatenate([zs, xi[:, :-1]], axis=1)
    yr, yi = tdfir_kernel.tdfir(xr, xi, hr, hi)
    yr_s, yi_s = tdfir_kernel.tdfir(xr_s, xi_s, hr, hi)
    np.testing.assert_allclose(yr_s[:, 1:], yr[:, :-1], rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(yi_s[:, 1:], yi[:, :-1], rtol=1e-4,
                               atol=1e-4)


@SETTINGS
@given(
    kblocks=st.integers(1, 4),
    xblocks=st.integers(1, 4),
    bk=st.sampled_from([8, 16, 32]),
    bx=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mriq_matches_ref_any_blocking(kblocks, xblocks, bk, bx, seed):
    rng = np.random.default_rng(seed)
    kd, xd = kblocks * bk, xblocks * bx
    kx, ky, kz = (_arr(rng, (kd,), -1, 1) for _ in range(3))
    phir, phii = _arr(rng, (kd,)), _arr(rng, (kd,))
    x, y, z = (_arr(rng, (xd,), -1, 1) for _ in range(3))
    qr, qi = mriq_kernel.mriq(kx, ky, kz, x, y, z, phir, phii,
                              block_x=bx, block_k=bk)
    er, ei = ref.mriq_ref(kx, ky, kz, x, y, z, phir, phii)
    np.testing.assert_allclose(qr, er, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(qi, ei, rtol=1e-3, atol=1e-3)


@SETTINGS
@given(
    kd=st.sampled_from([16, 32, 64]),
    xd=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_mriq_phimag_additivity(kd, xd, seed):
    """Q is additive in |phi|^2: splitting the K-space samples into two
    halves and summing the two Qs equals the full Q. (Requires even kd.)"""
    rng = np.random.default_rng(seed)
    kx, ky, kz = (_arr(rng, (kd,), -1, 1) for _ in range(3))
    phir, phii = _arr(rng, (kd,)), _arr(rng, (kd,))
    x, y, z = (_arr(rng, (xd,), -1, 1) for _ in range(3))
    h = kd // 2
    full = mriq_kernel.mriq(kx, ky, kz, x, y, z, phir, phii,
                            block_x=xd, block_k=h)
    a = mriq_kernel.mriq(kx[:h], ky[:h], kz[:h], x, y, z,
                         phir[:h], phii[:h], block_x=xd, block_k=h)
    b = mriq_kernel.mriq(kx[h:], ky[h:], kz[h:], x, y, z,
                         phir[h:], phii[h:], block_x=xd, block_k=h)
    np.testing.assert_allclose(full[0], a[0] + b[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(full[1], a[1] + b[1], rtol=1e-4, atol=1e-4)
