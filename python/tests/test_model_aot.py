"""L2 model + AOT lowering checks: shapes, determinism, HLO text validity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref


@pytest.fixture
def rng():
    return np.random.default_rng(7)


def _randn(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


class TestModels:
    def test_tdfir_model_matches_ref(self, rng):
        s = model.SHAPES["tdfir"]
        m, n, k = s["m"], s["n"], s["k"]
        xr, xi = _randn(rng, m, n), _randn(rng, m, n)
        hr, hi = _randn(rng, m, k), _randn(rng, m, k)
        yr, yi = model.tdfir_model(xr, xi, hr, hi)
        er, ei = ref.tdfir_ref(xr, xi, hr, hi)
        np.testing.assert_allclose(yr, er, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(yi, ei, rtol=1e-4, atol=1e-4)

    def test_mriq_model_matches_ref(self, rng):
        s = model.SHAPES["mriq"]
        kd, xd = s["k"], s["x"]
        kx, ky, kz = (_randn(rng, kd) for _ in range(3))
        phir, phii = _randn(rng, kd), _randn(rng, kd)
        x, y, z = (_randn(rng, xd) for _ in range(3))
        qr, qi = model.mriq_model(kx, ky, kz, x, y, z, phir, phii)
        er, ei = ref.mriq_ref(kx, ky, kz, x, y, z, phir, phii)
        np.testing.assert_allclose(qr, er, rtol=1e-3, atol=1e-2)
        np.testing.assert_allclose(qi, ei, rtol=1e-3, atol=1e-2)

    def test_shapes_consistent_with_blocking(self):
        s = model.SHAPES["mriq"]
        assert s["x"] % s["block_x"] == 0
        assert s["k"] % s["block_k"] == 0


class TestAot:
    def test_tdfir_hlo_text_structure(self):
        text = aot.to_hlo_text(aot.lower_tdfir())
        assert text.startswith("HloModule")
        s = model.SHAPES["tdfir"]
        # Entry layout mentions the expected parameter shapes.
        assert f"f32[{s['m']},{s['n']}]" in text
        assert f"f32[{s['m']},{s['k']}]" in text

    def test_mriq_hlo_text_structure(self):
        text = aot.to_hlo_text(aot.lower_mriq())
        assert text.startswith("HloModule")
        s = model.SHAPES["mriq"]
        assert f"f32[{s['k']}]" in text
        assert f"f32[{s['x']}]" in text
        # Trig from the kernel must survive lowering.
        assert "cosine" in text and "sine" in text

    def test_lowering_is_deterministic(self):
        a = aot.to_hlo_text(aot.lower_tdfir())
        b = aot.to_hlo_text(aot.lower_tdfir())
        assert a == b

    def test_no_custom_calls(self):
        """interpret=True must lower to plain HLO — a Mosaic custom-call
        would be unloadable by the CPU PJRT client in Rust."""
        for lower in (aot.lower_tdfir, aot.lower_mriq):
            assert "custom-call" not in aot.to_hlo_text(lower())
