"""Kernel-vs-reference correctness: the core L1 signal.

Fixed-shape allclose checks for both Pallas kernels against the pure-jnp
oracles in kernels/ref.py. Property-based shape/value sweeps live in
test_kernel_properties.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import mriq as mriq_kernel
from compile.kernels import ref
from compile.kernels import tdfir as tdfir_kernel


def _randn(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


class TestTdfir:
    @pytest.mark.parametrize(
        "m,n,k",
        [(1, 8, 1), (1, 16, 4), (2, 32, 8), (4, 64, 16), (8, 1024, 32)],
    )
    def test_matches_ref(self, rng, m, n, k):
        xr, xi = _randn(rng, m, n), _randn(rng, m, n)
        hr, hi = _randn(rng, m, k), _randn(rng, m, k)
        yr, yi = tdfir_kernel.tdfir(xr, xi, hr, hi)
        er, ei = ref.tdfir_ref(xr, xi, hr, hi)
        np.testing.assert_allclose(yr, er, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(yi, ei, rtol=1e-5, atol=1e-5)

    def test_impulse_recovers_taps(self, rng):
        """FIR of a unit impulse reproduces the tap sequence."""
        m, n, k = 2, 64, 8
        xr = jnp.zeros((m, n)).at[:, 0].set(1.0)
        xi = jnp.zeros((m, n))
        hr, hi = _randn(rng, m, k), _randn(rng, m, k)
        yr, yi = tdfir_kernel.tdfir(xr, xi, hr, hi)
        np.testing.assert_allclose(yr[:, :k], hr, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(yi[:, :k], hi, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(yr[:, k:], 0.0, atol=1e-6)

    def test_single_tap_is_complex_scale(self, rng):
        """K=1 degenerates to complex pointwise scaling."""
        m, n = 3, 32
        xr, xi = _randn(rng, m, n), _randn(rng, m, n)
        hr, hi = _randn(rng, m, 1), _randn(rng, m, 1)
        yr, yi = tdfir_kernel.tdfir(xr, xi, hr, hi)
        np.testing.assert_allclose(yr, hr * xr - hi * xi, rtol=1e-5,
                                   atol=1e-6)
        np.testing.assert_allclose(yi, hr * xi + hi * xr, rtol=1e-5,
                                   atol=1e-6)

    def test_linearity(self, rng):
        """FIR is linear: f(a*x) == a*f(x)."""
        m, n, k = 2, 48, 6
        xr, xi = _randn(rng, m, n), _randn(rng, m, n)
        hr, hi = _randn(rng, m, k), _randn(rng, m, k)
        y1r, y1i = tdfir_kernel.tdfir(2.5 * xr, 2.5 * xi, hr, hi)
        y2r, y2i = tdfir_kernel.tdfir(xr, xi, hr, hi)
        np.testing.assert_allclose(y1r, 2.5 * y2r, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(y1i, 2.5 * y2i, rtol=1e-4, atol=1e-5)

    def test_rows_independent(self, rng):
        """Each filter-bank row only depends on its own stream/taps."""
        m, n, k = 4, 32, 4
        xr, xi = _randn(rng, m, n), _randn(rng, m, n)
        hr, hi = _randn(rng, m, k), _randn(rng, m, k)
        full_r, full_i = tdfir_kernel.tdfir(xr, xi, hr, hi)
        row_r, row_i = tdfir_kernel.tdfir(
            xr[1:2], xi[1:2], hr[1:2], hi[1:2]
        )
        np.testing.assert_allclose(full_r[1:2], row_r, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(full_i[1:2], row_i, rtol=1e-6, atol=1e-6)


class TestMriq:
    @pytest.mark.parametrize(
        "kd,xd,bk,bx",
        [(64, 64, 64, 64), (128, 64, 64, 32), (256, 256, 64, 64),
         (512, 1024, 128, 128)],
    )
    def test_matches_ref(self, rng, kd, xd, bk, bx):
        kx, ky, kz = _randn(rng, kd), _randn(rng, kd), _randn(rng, kd)
        phir, phii = _randn(rng, kd), _randn(rng, kd)
        x, y, z = _randn(rng, xd), _randn(rng, xd), _randn(rng, xd)
        qr, qi = mriq_kernel.mriq(kx, ky, kz, x, y, z, phir, phii,
                                  block_x=bx, block_k=bk)
        er, ei = ref.mriq_ref(kx, ky, kz, x, y, z, phir, phii)
        np.testing.assert_allclose(qr, er, rtol=1e-4, atol=1e-3)
        np.testing.assert_allclose(qi, ei, rtol=1e-4, atol=1e-3)

    def test_zero_phase_gives_zero(self, rng):
        kd, xd = 64, 64
        z1 = jnp.zeros((kd,))
        kx, ky, kz = _randn(rng, kd), _randn(rng, kd), _randn(rng, kd)
        x, y, z = _randn(rng, xd), _randn(rng, xd), _randn(rng, xd)
        qr, qi = mriq_kernel.mriq(kx, ky, kz, x, y, z, z1, z1,
                                  block_x=64, block_k=64)
        np.testing.assert_allclose(qr, 0.0, atol=1e-6)
        np.testing.assert_allclose(qi, 0.0, atol=1e-6)

    def test_origin_voxel_sums_phimag(self, rng):
        """At x=y=z=0 the exponential is 1, so qr = sum(|phi|^2), qi = 0."""
        kd, xd = 128, 64
        kx, ky, kz = _randn(rng, kd), _randn(rng, kd), _randn(rng, kd)
        phir, phii = _randn(rng, kd), _randn(rng, kd)
        zeros = jnp.zeros((xd,))
        qr, qi = mriq_kernel.mriq(kx, ky, kz, zeros, zeros, zeros,
                                  phir, phii, block_x=64, block_k=64)
        expect = float(jnp.sum(phir**2 + phii**2))
        np.testing.assert_allclose(qr, expect, rtol=1e-5)
        np.testing.assert_allclose(qi, 0.0, atol=1e-4)

    def test_blocking_invariance(self, rng):
        """Different VMEM tilings must give identical results."""
        kd, xd = 256, 128
        kx, ky, kz = _randn(rng, kd), _randn(rng, kd), _randn(rng, kd)
        phir, phii = _randn(rng, kd), _randn(rng, kd)
        x, y, z = _randn(rng, xd), _randn(rng, xd), _randn(rng, xd)
        a = mriq_kernel.mriq(kx, ky, kz, x, y, z, phir, phii,
                             block_x=128, block_k=256)
        b = mriq_kernel.mriq(kx, ky, kz, x, y, z, phir, phii,
                             block_x=32, block_k=64)
        np.testing.assert_allclose(a[0], b[0], rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(a[1], b[1], rtol=1e-5, atol=1e-4)

    def test_bad_blocking_raises(self, rng):
        kd, xd = 96, 64
        arrs = [_randn(rng, kd)] * 3 + [_randn(rng, xd)] * 3 \
            + [_randn(rng, kd)] * 2
        with pytest.raises(ValueError, match="block sizes must divide"):
            mriq_kernel.mriq(*arrs, block_x=64, block_k=64)
