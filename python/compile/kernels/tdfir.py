"""L1 Pallas kernel: time-domain FIR filter bank (paper §5.1.1, app 1).

This is the loop the paper's method offloads to the FPGA — the hot loop of
the HPEC-challenge ``tdfir`` benchmark: M independent K-tap complex FIR
filters over M length-N streams.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's FPGA
pipeline processes one output sample per clock with the K-tap MAC fully
unrolled in space. On TPU the same insight — keep the filter taps and a
window of the stream resident in fast memory, stream the outer dimension —
becomes a Pallas kernel with one grid step per filter row: taps + the
padded row live in VMEM, the K-tap MAC is a ``fori_loop`` of vectorized
length-N FMAs on the VPU (the FPGA's unroll factor B corresponds to the
vector width here, so B=1 in the paper's terms maps to "one full-row vector
op per tap").

``interpret=True`` everywhere: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute; interpret mode lowers to
plain HLO so the Rust runtime can run the artifact (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tdfir_kernel(xr_ref, xi_ref, hr_ref, hi_ref, yr_ref, yi_ref, *, n, k):
    """One grid step = one filter row.

    Block shapes: ``x*_ref: (1, N+K-1)`` (left-padded row), ``h*_ref:
    (1, K)``, ``y*_ref: (1, N)``.
    """
    xr = xr_ref[0, :]
    xi = xi_ref[0, :]
    hr = hr_ref[0, :]
    hi = hi_ref[0, :]

    def tap(j, acc):
        yr, yi = acc
        # x[n - j] lives at padded index (K-1) + n - j.
        slr = jax.lax.dynamic_slice(xr, (k - 1 - j,), (n,))
        sli = jax.lax.dynamic_slice(xi, (k - 1 - j,), (n,))
        hrj = hr[j]
        hij = hi[j]
        # Complex MAC: y += h[j] * x[n-j].
        return (yr + hrj * slr - hij * sli, yi + hrj * sli + hij * slr)

    zero = jnp.zeros((n,), xr.dtype)
    yr, yi = jax.lax.fori_loop(0, k, tap, (zero, zero))
    yr_ref[0, :] = yr
    yi_ref[0, :] = yi


@functools.partial(jax.jit, static_argnames=())
def tdfir(xr, xi, hr, hi):
    """Complex FIR filter bank via the Pallas kernel.

    Args:
      xr, xi: ``f32[M, N]`` input streams.
      hr, hi: ``f32[M, K]`` filter taps.

    Returns:
      ``(yr, yi)``: ``f32[M, N]``, matching ``ref.tdfir_ref``.
    """
    m, n = xr.shape
    k = hr.shape[1]
    # Left-pad K-1 history samples so the kernel sees full windows; the pad
    # is the host-side half of the paper's host/kernel split (the host
    # program stages the stream into the FPGA's local memory).
    xr_p = jnp.pad(xr, ((0, 0), (k - 1, 0)))
    xi_p = jnp.pad(xi, ((0, 0), (k - 1, 0)))

    kern = functools.partial(_tdfir_kernel, n=n, k=k)
    row_in = pl.BlockSpec((1, n + k - 1), lambda i: (i, 0))
    row_h = pl.BlockSpec((1, k), lambda i: (i, 0))
    row_out = pl.BlockSpec((1, n), lambda i: (i, 0))
    out_shape = [
        jax.ShapeDtypeStruct((m, n), xr.dtype),
        jax.ShapeDtypeStruct((m, n), xr.dtype),
    ]
    yr, yi = pl.pallas_call(
        kern,
        grid=(m,),
        in_specs=[row_in, row_in, row_h, row_h],
        out_specs=[row_out, row_out],
        out_shape=out_shape,
        interpret=True,
    )(xr_p, xi_p, hr, hi)
    return yr, yi
