"""L1 Pallas kernel: MRI-Q Q-matrix computation (paper §5.1.1, app 2).

The Parboil MRI-Q hot loop: for every voxel, accumulate
``|phi[k]|^2 * exp(i * 2*pi * k . x)`` over all K-space samples. This is the
loop the paper's method offloads (7.1x in Fig. 4) — trig-dense, tiny
transfer footprint, the archetypal high-arithmetic-intensity loop.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's FPGA
build pipelines the K-loop with the voxel loop outer; the blocked TPU
equivalent tiles *both* dimensions so a (BX, BK) phase tile lives in VMEM
per grid step — BlockSpec plays the role of the FPGA unroll factor. The
K dimension is the reduction: grid = (X/BX, K/BK) with the output block
revisited across the K axis and accumulated in place (init at k-block 0).

``interpret=True`` for CPU-PJRT executability — see tdfir.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TWO_PI = 6.2831853071795864769

# Default VMEM tile: (BX, BK) f32 phase tile = 128*128*4 B = 64 KiB, plus
# the 1-D operand blocks — comfortably inside a TPU core's ~16 MiB VMEM
# with double-buffering headroom.
BLOCK_X = 128
BLOCK_K = 128


def _mriq_kernel(kx_ref, ky_ref, kz_ref, x_ref, y_ref, z_ref,
                 phir_ref, phii_ref, qr_ref, qi_ref):
    """One grid step = one (voxel-block, k-block) tile of the reduction."""
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        qr_ref[...] = jnp.zeros_like(qr_ref)
        qi_ref[...] = jnp.zeros_like(qi_ref)

    phir = phir_ref[...]
    phii = phii_ref[...]
    phimag = phir * phir + phii * phii  # |phi|^2, recomputed per tile —
    # mirrors the FPGA kernel, which computes it inside the pipeline rather
    # than staging a third input stream.
    arg = TWO_PI * (
        x_ref[...][:, None] * kx_ref[...][None, :]
        + y_ref[...][:, None] * ky_ref[...][None, :]
        + z_ref[...][:, None] * kz_ref[...][None, :]
    )
    qr_ref[...] += jnp.sum(phimag[None, :] * jnp.cos(arg), axis=1)
    qi_ref[...] += jnp.sum(phimag[None, :] * jnp.sin(arg), axis=1)


@functools.partial(jax.jit, static_argnames=("block_x", "block_k"))
def mriq(kx, ky, kz, x, y, z, phir, phii, *, block_x=BLOCK_X,
         block_k=BLOCK_K):
    """MRI-Q via the Pallas kernel.

    Args:
      kx, ky, kz, phir, phii: ``f32[K]`` K-space trajectory and phase.
      x, y, z: ``f32[X]`` voxel coordinates.
      block_x, block_k: VMEM tile sizes; must divide X and K.

    Returns:
      ``(qr, qi)``: ``f32[X]``, matching ``ref.mriq_ref``.
    """
    (kdim,) = kx.shape
    (xdim,) = x.shape
    if xdim % block_x or kdim % block_k:
        raise ValueError(
            f"block sizes must divide dims: X={xdim}%{block_x}, "
            f"K={kdim}%{block_k}"
        )
    grid = (xdim // block_x, kdim // block_k)
    kspec = pl.BlockSpec((block_k,), lambda i, kb: (kb,))
    xspec = pl.BlockSpec((block_x,), lambda i, kb: (i,))
    out_shape = [
        jax.ShapeDtypeStruct((xdim,), x.dtype),
        jax.ShapeDtypeStruct((xdim,), x.dtype),
    ]
    qr, qi = pl.pallas_call(
        _mriq_kernel,
        grid=grid,
        in_specs=[kspec, kspec, kspec, xspec, xspec, xspec, kspec, kspec],
        out_specs=[xspec, xspec],
        out_shape=out_shape,
        interpret=True,
    )(kx, ky, kz, x, y, z, phir, phii)
    return qr, qi
