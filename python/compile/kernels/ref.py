"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the ground truth the kernels (and, transitively, the Rust
runtime's sample tests) are validated against. They intentionally use only
plain jax.numpy — no pallas — so a bug in the kernels cannot hide in shared
code.

The two workloads mirror the paper's §5.1.1 evaluation applications:

* ``tdfir_ref`` — HPEC-challenge style *time-domain finite impulse response
  filter bank*: M independent complex FIR filters of K taps applied to
  M length-N complex input streams.
* ``mriq_ref``  — Parboil *MRI-Q*: Q-matrix computation for non-Cartesian
  MRI reconstruction; for every voxel, a sum over K-space samples of
  |phi|^2 * exp(i * 2*pi * k . x).
"""

from __future__ import annotations

import jax.numpy as jnp

TWO_PI = 6.2831853071795864769


def tdfir_ref(xr, xi, hr, hi):
    """Complex FIR filter bank, causal, zero-padded history.

    Args:
      xr, xi: ``f32[M, N]`` input stream (real / imaginary parts).
      hr, hi: ``f32[M, K]`` filter taps per stream.

    Returns:
      ``(yr, yi)``: ``f32[M, N]`` where
      ``y[m, n] = sum_k h[m, k] * x[m, n - k]`` (terms with ``n - k < 0``
      dropped), using complex multiplication.
    """
    m, n = xr.shape
    k = hr.shape[1]
    # Zero-pad K-1 samples of history on the left so every output index has
    # a full window.
    pad = ((0, 0), (k - 1, 0))
    xr_p = jnp.pad(xr, pad)
    xi_p = jnp.pad(xi, pad)
    yr = jnp.zeros((m, n), xr.dtype)
    yi = jnp.zeros((m, n), xr.dtype)
    for j in range(k):
        # x[m, n - j] == xpad[m, (K-1) + n - j]
        sl_r = xr_p[:, k - 1 - j : k - 1 - j + n]
        sl_i = xi_p[:, k - 1 - j : k - 1 - j + n]
        hr_j = hr[:, j : j + 1]
        hi_j = hi[:, j : j + 1]
        yr = yr + hr_j * sl_r - hi_j * sl_i
        yi = yi + hr_j * sl_i + hi_j * sl_r
    return yr, yi


def mriq_phimag_ref(phir, phii):
    """``|phi|^2`` per K-space sample: ``f32[K] -> f32[K]``."""
    return phir * phir + phii * phii


def mriq_ref(kx, ky, kz, x, y, z, phir, phii):
    """MRI-Q Q-matrix computation.

    Args:
      kx, ky, kz: ``f32[K]`` K-space trajectory.
      x, y, z:    ``f32[X]`` voxel coordinates.
      phir, phii: ``f32[K]`` per-sample phase.

    Returns:
      ``(qr, qi)``: ``f32[X]`` with
      ``q[i] = sum_k |phi[k]|^2 * exp(1j * 2*pi * (kx[k]*x[i] + ky[k]*y[i]
      + kz[k]*z[i]))``.
    """
    phimag = mriq_phimag_ref(phir, phii)
    # [X, K] phase matrix.
    arg = TWO_PI * (
        jnp.outer(x, kx) + jnp.outer(y, ky) + jnp.outer(z, kz)
    )
    qr = jnp.sum(phimag[None, :] * jnp.cos(arg), axis=1)
    qi = jnp.sum(phimag[None, :] * jnp.sin(arg), axis=1)
    return qr, qi
