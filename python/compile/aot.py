"""AOT bridge: lower the L2 models to HLO *text* artifacts for Rust.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits:
  artifacts/tdfir.hlo.txt   — TDFIR sample test (Pallas FIR kernel inside)
  artifacts/mriq.hlo.txt    — MRI-Q sample test (Pallas kernel inside)
  artifacts/meta.json       — shapes + argument order for the Rust loader

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids and round-trips
cleanly. Lowered with ``return_tuple=True``; the Rust side unwraps with
``to_tuple()``. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_tdfir():
    s = model.SHAPES["tdfir"]
    m, n, k = s["m"], s["n"], s["k"]
    args = [_spec(m, n), _spec(m, n), _spec(m, k), _spec(m, k)]
    return jax.jit(model.tdfir_model).lower(*args)


def lower_mriq():
    s = model.SHAPES["mriq"]
    kd, xd = s["k"], s["x"]
    args = [
        _spec(kd), _spec(kd), _spec(kd),          # kx, ky, kz
        _spec(xd), _spec(xd), _spec(xd),          # x, y, z
        _spec(kd), _spec(kd),                     # phir, phii
    ]
    return jax.jit(model.mriq_model).lower(*args)


META_ARG_ORDER = {
    "tdfir": ["xr[m,n]", "xi[m,n]", "hr[m,k]", "hi[m,k]"],
    "mriq": ["kx[k]", "ky[k]", "kz[k]", "x[x]", "y[x]", "z[x]",
             "phir[k]", "phii[k]"],
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    # Back-compat with the scaffold Makefile's `--out path/model.hlo.txt`.
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)
    ns = ap.parse_args()
    out_dir = os.path.dirname(ns.out) if ns.out else ns.out_dir
    os.makedirs(out_dir, exist_ok=True)

    for name, lower in (("tdfir", lower_tdfir), ("mriq", lower_mriq)):
        text = to_hlo_text(lower())
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta = {
        "format": "hlo-text/return-tuple",
        "shapes": model.SHAPES,
        "arg_order": META_ARG_ORDER,
        "outputs": {"tdfir": ["yr[m,n]", "yi[m,n]"],
                    "mriq": ["qr[x]", "qi[x]"]},
    }
    meta_path = os.path.join(out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {meta_path}")


if __name__ == "__main__":
    main()
