"""L2: JAX compute graphs for the paper's sample-test applications.

These are the "sample processing specified by the application to be
accelerated" (paper §4): the computations the verification environment runs
to measure each offload pattern. Each model wraps an L1 Pallas kernel
(kernels/tdfir.py, kernels/mriq.py) plus the host-side staging around it,
and is AOT-lowered once by aot.py to HLO text that the Rust runtime
(rust/src/runtime/) loads and executes via PJRT. Python never runs on the
request path.

Default shapes (SHAPES) are the sample-test sizes compiled into the
artifacts; the Rust side reads them from artifacts/meta.json.
"""

from __future__ import annotations

from .kernels import mriq as mriq_kernel
from .kernels import tdfir as tdfir_kernel

# Sample-test sizes. tdfir mirrors the HPEC-challenge "set 1" shape scaled
# to a CI-friendly footprint (bank of 8 filters, 32 complex taps, 1024
# samples); mriq mirrors Parboil's small dataset scaled likewise.
SHAPES = {
    "tdfir": {"m": 8, "n": 1024, "k": 32},
    "mriq": {"k": 512, "x": 1024, "block_x": 128, "block_k": 128},
}


def tdfir_model(xr, xi, hr, hi):
    """Sample test for the TDFIR application.

    Runs the filter bank via the Pallas kernel. Returns a flat tuple
    ``(yr, yi)`` — the Rust loader unwraps the 1-level output tuple that
    ``return_tuple=True`` lowering produces.
    """
    yr, yi = tdfir_kernel.tdfir(xr, xi, hr, hi)
    return yr, yi


def mriq_model(kx, ky, kz, x, y, z, phir, phii):
    """Sample test for the MRI-Q application (default VMEM blocking)."""
    shp = SHAPES["mriq"]
    qr, qi = mriq_kernel.mriq(
        kx, ky, kz, x, y, z, phir, phii,
        block_x=shp["block_x"], block_k=shp["block_k"],
    )
    return qr, qi
