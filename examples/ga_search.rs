//! GA baseline vs the paper's narrowing funnel (the §3.2 argument).
//!
//! The previous GPU work [32] searched offload patterns with a genetic
//! algorithm and many measurements — fine when compiles take minutes,
//! ruinous at FPGA compile times (~3 h). This example runs both
//! strategies on tdfir and prints the measurement/wall-clock gap the
//! paper's funnel exists to close. Both share ONE profiling run: the
//! staged pipeline's artifacts keep program + analysis in hand, so the
//! GA reuses them instead of re-profiling.
//!
//! Run with: `cargo run --release --example ga_search`

use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::envadapt::{OffloadRequest, Pipeline};
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::search::{ga, FpgaBackend, GaConfig, SearchConfig};
use fpga_offload::workloads;

fn main() -> anyhow::Result<()> {
    println!("== GA baseline [32] vs narrowing funnel (tdfir) ==\n");

    let backend = FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let pipeline = Pipeline::new(SearchConfig::default(), &backend)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let req = OffloadRequest::builder("tdfir")
        .source(workloads::TDFIR_C)
        .build()
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    // Stages 1–3 once; the GA reuses the profiled artifacts.
    let parsed = pipeline.parse(req).map_err(|e| anyhow::anyhow!("{e}"))?;
    let analyzed =
        pipeline.analyze(parsed).map_err(|e| anyhow::anyhow!("{e}"))?;
    let candidates =
        pipeline.extract(analyzed).map_err(|e| anyhow::anyhow!("{e}"))?;

    let ga_res = ga::run(
        &candidates.prog,
        &candidates.analysis,
        &GaConfig::default(),
        &XEON_BRONZE_3104,
        &ARRIA10_GX,
    );

    // Stages 4–5: the funnel's answer from the same artifacts.
    let measured =
        pipeline.measure(candidates).map_err(|e| anyhow::anyhow!("{e}"))?;
    let planned =
        pipeline.select(measured).map_err(|e| anyhow::anyhow!("{e}"))?;
    let funnel = planned.plan.solution().expect("fresh search");

    println!("funnel : best {:<10} {:>6.2}x  {} measurements  ~{:>6.1} h",
        funnel.best_measurement().label(),
        funnel.speedup(),
        funnel.measurements.len(),
        funnel.automation_s / 3600.0);
    println!("GA [32]: best {:<10} {:>6.2}x  {} measurements  ~{:>6.1} h",
        ga_res
            .best_loops
            .iter()
            .map(|l| l.to_string())
            .collect::<Vec<_>>()
            .join("+"),
        ga_res.best_speedup,
        ga_res.measurements,
        ga_res.modeled_wall_clock_s / 3600.0);
    println!("\nGA convergence (best speedup per generation): {:?}",
        ga_res.history);
    println!(
        "\nmeasurement economy: funnel used {:.0}% of the GA's compiles",
        100.0 * funnel.measurements.len() as f64
            / ga_res.measurements.max(1) as f64
    );
    Ok(())
}
