//! GA baseline vs the paper's narrowing funnel (the §3.2 argument).
//!
//! The previous GPU work [32] searched offload patterns with a genetic
//! algorithm and many measurements — fine when compiles take minutes,
//! ruinous at FPGA compile times (~3 h). This example runs both
//! strategies on tdfir and prints the measurement/wall-clock gap the
//! paper's funnel exists to close.
//!
//! Run with: `cargo run --release --example ga_search`

use fpga_offload::analysis::analyze;
use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::minic::parse;
use fpga_offload::search::{ga, search, GaConfig, SearchConfig};
use fpga_offload::workloads;

fn main() -> anyhow::Result<()> {
    println!("== GA baseline [32] vs narrowing funnel (tdfir) ==\n");
    let prog =
        parse(workloads::TDFIR_C).map_err(|e| anyhow::anyhow!("{e}"))?;
    let an = analyze(&prog, "main").map_err(|e| anyhow::anyhow!("{e}"))?;

    let funnel = search(
        "tdfir",
        &prog,
        &an,
        &SearchConfig::default(),
        &XEON_BRONZE_3104,
        &ARRIA10_GX,
    )
    .map_err(|e| anyhow::anyhow!("{e}"))?;

    let ga_res = ga::run(
        &prog,
        &an,
        &GaConfig::default(),
        &XEON_BRONZE_3104,
        &ARRIA10_GX,
    );

    println!("funnel : best {:<10} {:>6.2}x  {} measurements  ~{:>6.1} h",
        funnel.best_measurement().label(),
        funnel.speedup(),
        funnel.measurements.len(),
        funnel.automation_s / 3600.0);
    println!("GA [32]: best {:<10} {:>6.2}x  {} measurements  ~{:>6.1} h",
        ga_res
            .best_loops
            .iter()
            .map(|l| l.to_string())
            .collect::<Vec<_>>()
            .join("+"),
        ga_res.best_speedup,
        ga_res.measurements,
        ga_res.modeled_wall_clock_s / 3600.0);
    println!("\nGA convergence (best speedup per generation): {:?}",
        ga_res.history);
    println!(
        "\nmeasurement economy: funnel used {:.0}% of the GA's compiles",
        100.0 * funnel.measurements.len() as f64
            / ga_res.measurements.max(1) as f64
    );
    Ok(())
}
