//! Fault-tolerant automation cycle: what `repro batch --mixed
//! --inject-faults <seed>` does, as a library walk-through.
//!
//! Wraps every destination backend in a deterministic
//! [`fpga_offload::search::FaultyBackend`] (seeded transient bursts,
//! hung builds, verify flips, panics), gives each pipeline a bounded
//! [`fpga_offload::search::RetryPolicy`] on a shared simulated clock,
//! and runs one mixed cycle. Transient faults are retried away;
//! destinations that fail permanently drop out and their apps reroute;
//! if everything fails an app still leaves the cycle served (stale
//! cached plan, or the all-CPU baseline at worst). The printout shows
//! each app's service level and the cycle's fault telemetry.
//!
//! Run with: `cargo run --release --example faulty_cycle`

use fpga_offload::cpu::{XEON_BRONZE_3104, XEON_GOLD_6130};
use fpga_offload::envadapt::{Batch, OffloadRequest, Pipeline, TestDb};
use fpga_offload::gpu::TESLA_T4;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::search::{
    Backend, CpuBaseline, FaultPlan, FaultyBackend, FpgaBackend,
    GpuBackend, OmpBackend, RetryPolicy, SearchConfig, SimClock,
};
use fpga_offload::workloads;

fn main() -> anyhow::Result<()> {
    let seed = 7u64;
    println!(
        "== fault-injected automation cycle: fpga + gpu + omp + cpu, \
         seed {seed} ==\n"
    );

    let fpga = FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let gpu = GpuBackend {
        cpu: &XEON_BRONZE_3104,
        gpu: &TESLA_T4,
        device: &ARRIA10_GX,
    };
    let omp = OmpBackend {
        cpu: &XEON_BRONZE_3104,
        omp: &XEON_GOLD_6130,
        device: &ARRIA10_GX,
    };
    let cpu = CpuBaseline {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let inner: [&dyn Backend; 4] = [&fpga, &gpu, &omp, &cpu];

    // One clock shared by the fault injector (hangs burn virtual hours)
    // and the retry loops (backoff burns virtual seconds).
    let clock = SimClock::new();
    let faulty: Vec<FaultyBackend> = inner
        .iter()
        .map(|&b| {
            FaultyBackend::new(b, FaultPlan::from_seed(seed), clock.clone())
        })
        .collect();

    let cfg = SearchConfig::default();
    let policy = RetryPolicy {
        stage_deadline_s: Some(4.0 * 3600.0),
        ..RetryPolicy::default()
    };
    let mut pipelines = Vec::new();
    for b in &faulty {
        let p = Pipeline::new(cfg.clone(), b)
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .with_retry(policy.clone())
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .with_clock(clock.clone());
        pipelines.push(p);
    }

    let testdb = TestDb::builtin();
    let mut batch = Batch::mixed(pipelines.iter().collect());
    for app in workloads::APPS {
        let case = testdb.get(app).expect("bundled apps are registered");
        let src = workloads::source(app).expect("bundled source");
        let mut req = OffloadRequest::from_case(case, src);
        req.pjrt_sample = None;
        batch.push(req);
    }
    let report = batch.run();

    for e in &report.entries {
        let plan = e.plan.as_ref().expect("the ladder always serves");
        println!(
            "  {:<8} → {:<5} {:>6.2}x  [{}]",
            e.app,
            e.destination.unwrap_or("-"),
            plan.speedup(),
            e.service,
        );
        if let Some(why) = &e.degradation {
            println!("           {why}");
        }
    }

    let t = &report.fault_telemetry;
    println!(
        "\n{}/{} solved, {} served, {} degraded",
        report.solved(),
        report.entries.len(),
        report.served(),
        report.degraded()
    );
    println!(
        "faults: {} retries, {} exhausted budgets, {} panics caught; \
         {:.1} virtual h spent on backoff and hung builds",
        t.total_retries(),
        t.total_exhausted(),
        t.total_panics(),
        clock.now_s() / 3600.0
    );
    println!(
        "stage detail: measure {}r/{}t, verify {}r/{}t, deploy {}r/{}t \
         (r = retries, t = timeouts)",
        t.measure.retries,
        t.measure.timeouts,
        t.verify.retries,
        t.verify.timeouts,
        t.deploy.retries,
        t.deploy.timeouts
    );
    Ok(())
}
