//! Mixed-destination offloading: one automation cycle, four
//! destinations (the arXiv:2011.12431 environment — every app lands on
//! the best of FPGA / GPU / many-core OpenMP / CPU).
//!
//! Builds one [`fpga_offload::Pipeline`] per destination backend over the
//! same `SearchConfig`, registers every bundled application in a
//! [`fpga_offload::Batch::mixed`] cycle, and prints where each app was
//! routed and why — exactly what `repro batch --mixed` does.
//!
//! Run with: `cargo run --release --example mixed_destinations`

use fpga_offload::cpu::{XEON_BRONZE_3104, XEON_GOLD_6130};
use fpga_offload::envadapt::{Batch, OffloadRequest, Pipeline, TestDb};
use fpga_offload::gpu::TESLA_T4;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::search::{
    CpuBaseline, FpgaBackend, GpuBackend, OmpBackend, SearchConfig,
};
use fpga_offload::workloads;

fn main() -> anyhow::Result<()> {
    println!(
        "== mixed-destination automation cycle: fpga + gpu + omp + cpu ==\n"
    );

    let fpga = FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let gpu = GpuBackend {
        cpu: &XEON_BRONZE_3104,
        gpu: &TESLA_T4,
        device: &ARRIA10_GX,
    };
    let omp = OmpBackend {
        cpu: &XEON_BRONZE_3104,
        omp: &XEON_GOLD_6130,
        device: &ARRIA10_GX,
    };
    let cpu = CpuBaseline {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let cfg = SearchConfig::default();
    let pf = Pipeline::new(cfg.clone(), &fpga)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let pg = Pipeline::new(cfg.clone(), &gpu)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let po = Pipeline::new(cfg.clone(), &omp)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let pc =
        Pipeline::new(cfg, &cpu).map_err(|e| anyhow::anyhow!("{e}"))?;

    let testdb = TestDb::builtin();
    let mut batch = Batch::mixed(vec![&pf, &pg, &po, &pc]);
    for app in workloads::APPS {
        let case = testdb.get(app).expect("bundled apps are registered");
        let src = workloads::source(app).expect("bundled source");
        let mut req = OffloadRequest::from_case(case, src);
        req.pjrt_sample = None;
        batch.push(req);
    }

    println!(
        "{} applications × {} destinations, funnels in parallel\n",
        batch.len(),
        batch.backend_names().len()
    );
    let report = batch.run();

    for e in &report.entries {
        let Some(plan) = &e.plan else {
            println!(
                "  {:<8} FAILED: {}",
                e.app,
                e.error.as_deref().unwrap_or("?")
            );
            continue;
        };
        println!(
            "  {:<8} → {:<5} best {:<10} {:>6.2}x",
            e.app,
            e.destination.unwrap_or("?"),
            plan.label(),
            plan.speedup()
        );
        for o in &e.outcomes {
            match &o.plan {
                Some(p) => println!(
                    "             {:<5} {:>6.2}x  automation {:>5.1} h{}",
                    o.backend,
                    p.speedup(),
                    p.automation_s() / 3600.0,
                    if Some(o.backend) == e.destination {
                        "  ← selected"
                    } else {
                        ""
                    }
                ),
                None => println!(
                    "             {:<5} failed: {}",
                    o.backend,
                    o.error
                        .as_ref()
                        .map(|e| e.to_string())
                        .unwrap_or_else(|| "?".into())
                ),
            }
        }
    }

    let split: Vec<String> = report
        .destination_counts()
        .iter()
        .map(|(b, n)| format!("{b} {n}"))
        .collect();
    println!("\ndestination split: {}", split.join(" / "));
    println!(
        "cycle automation: {:.1} h serial, {:.1} h concurrent \
         (the GPU destination compiles in minutes and the OpenMP one in \
         seconds — their patterns barely register next to the FPGA's \
         ~3 h place-and-route jobs)",
        report.serial_automation_s / 3600.0,
        report.concurrent_automation_s / 3600.0
    );
    Ok(())
}
