//! Function-block offloading vs the loop-only funnel.
//!
//! Runs every bundled application twice through the staged pipeline on
//! the FPGA destination — once loop-only (the source paper's path) and
//! once with `func_blocks` enabled (the arXiv:2004.09883 follow-on):
//! whole algorithmic blocks (the tdfir FIR bank, the sobel gradient
//! stencil, the mriq magnitude pass) are detected, behaviorally
//! confirmed by VM sample tests, and replaced with catalogued IP cores;
//! the loop funnel then searches only the remaining loops.
//!
//! ```text
//! cargo run --release --example funcblock_offload
//! ```

use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::envadapt::{OffloadRequest, Pipeline, TestDb};
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::search::{FpgaBackend, SearchConfig};
use fpga_offload::workloads;

fn main() {
    let backend = FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let pipe = Pipeline::new(SearchConfig::default(), &backend)
        .expect("valid default config");
    let testdb = TestDb::builtin();

    println!("function-block offloading on {}\n", ARRIA10_GX.name);
    for app in workloads::APPS {
        let case = testdb.get(app).expect("bundled app");
        let src = workloads::source(app).unwrap();
        let mut loop_req = OffloadRequest::from_case(case, src);
        loop_req.pjrt_sample = None;
        let block_req = loop_req.clone().with_func_blocks(true);

        let loop_only = pipe.solve(loop_req).expect("loop-only solve");
        let blocked = pipe.solve(block_req).expect("func-block solve");

        println!(
            "{app}: loop-only {:.2}x ({}), with blocks {:.2}x",
            loop_only.plan.speedup(),
            loop_only.plan.label(),
            blocked.plan.speedup(),
        );
        let sol = blocked.plan.solution().expect("fresh plan");
        if sol.blocks.is_empty() {
            println!("    no profitable catalog block on this destination");
        }
        for b in &sol.blocks {
            println!(
                "    {} -> {} ({}): {:.1}x over the naive nest, \
                 sample-test confirmed",
                b.func,
                b.kind,
                b.ip_name,
                b.speedup()
            );
        }
        println!(
            "    remaining loop pattern: {} at {:.2}x\n",
            sol.best_measurement().label(),
            sol.loop_speedup()
        );
    }
}
