//! Batch orchestration: one automation cycle over every bundled
//! application (the arXiv:2002.09541 many-apps evaluation shape).
//!
//! Registers tdfir, MRI-Q and sobel in one [`fpga_offload::Batch`]
//! sharing a single `SearchConfig` and FPGA backend, runs their funnels
//! concurrently, prints the per-app solutions, and writes the aggregate
//! `BatchReport` JSON — exactly what `repro batch` does.
//!
//! Run with: `cargo run --release --example batch_offload`

use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::envadapt::{Batch, OffloadRequest, Pipeline, TestDb};
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::search::{FpgaBackend, SearchConfig};
use fpga_offload::util::tempdir::TempDir;
use fpga_offload::workloads;

fn main() -> anyhow::Result<()> {
    println!("== automatic FPGA offloading: batch automation cycle ==\n");

    let backend = FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let db_dir = TempDir::new("fpga-offload-batch-db")?;
    let pipeline = Pipeline::new(SearchConfig::default(), &backend)
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .with_pattern_db(db_dir.path())
        .with_cache_reuse(true);

    let testdb = TestDb::builtin();
    let mut batch = Batch::new(&pipeline);
    for app in workloads::APPS {
        let case = testdb.get(app).expect("bundled apps are registered");
        let src = workloads::source(app).expect("bundled source");
        batch.push(OffloadRequest::from_case(case, src));
    }

    println!("cycle 1: {} applications, funnels in parallel", batch.len());
    let first = batch.run();
    for e in &first.entries {
        match &e.plan {
            Some(plan) => println!(
                "  {:<8} best {:<10} {:>6.2}x  automation {:>5.1} h",
                e.app,
                plan.label(),
                plan.speedup(),
                plan.automation_s() / 3600.0
            ),
            None => println!(
                "  {:<8} FAILED: {}",
                e.app,
                e.error.as_deref().unwrap_or("?")
            ),
        }
    }
    println!(
        "cycle 1 automation: {:.1} h serial, {:.1} h with concurrent funnels",
        first.serial_automation_s / 3600.0,
        first.concurrent_automation_s / 3600.0
    );

    // Second cycle over unchanged sources: every plan comes from the
    // code-pattern DB — zero re-search, the environment-adaptive payoff.
    let second = batch.run();
    println!(
        "\ncycle 2 (sources unchanged): {} cache hits of {} apps, \
         automation {:.1} h",
        second.cache_hits(),
        second.entries.len(),
        second.serial_automation_s / 3600.0
    );

    let out = db_dir.join("batch_report.json");
    first.write_json(&out)?;
    println!("\nbatch report JSON:\n{}", first.to_json().pretty());
    Ok(())
}
