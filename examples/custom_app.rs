//! Bring-your-own-code: offload a user-supplied C application.
//!
//! The environment-adaptive premise (paper §1) is that developers write
//! plain code once and the platform adapts it. This example builds an
//! [`OffloadRequest`] for a small Black-Scholes-style option pricer and
//! runs it through the staged pipeline — exactly what
//! `repro offload path/to/app.c` does.
//!
//! Run with: `cargo run --release --example custom_app`

use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::envadapt::{OffloadRequest, Pipeline};
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::search::{FpgaBackend, SearchConfig};

const PRICER_C: &str = r#"
/* Vectorized option pricer: trig/exp-dense loop over contracts, plus
 * setup and reporting stages the method must leave on the CPU. */
#define N 4096
float spot[N]; float strike[N]; float vol[N]; float price[N];
float total;
void gen_book() {
    for (int i = 0; i < N; i++) {
        spot[i] = ((i * 37 + 11) % 97) * 0.8 + 40.0;
        strike[i] = ((i * 53 + 29) % 89) * 0.9 + 42.0;
        vol[i] = ((i * 17 + 3) % 31) * 0.01 + 0.1;
    }
}
void price_book() {
    for (int i = 0; i < N; i++) {
        float m = log(spot[i] / strike[i]);
        float d = m / (vol[i] * 0.5) + vol[i] * 0.25;
        float phi = 1.0 / (1.0 + exp(0.0 - d * 1.702));
        price[i] = spot[i] * phi - strike[i] * phi * exp(0.0 - 0.05);
    }
}
void sum_book() {
    total = 0.0;
    for (int i = 0; i < N; i++) { total += price[i]; }
}
int main() {
    gen_book();
    price_book();
    sum_book();
    return (int) total;
}
"#;

fn main() -> anyhow::Result<()> {
    println!("== automatic FPGA offloading: custom application ==\n");

    let backend = FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let pipeline = Pipeline::new(SearchConfig::default(), &backend)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    let req = OffloadRequest::builder("pricer")
        .source(PRICER_C)
        .entry("main")
        .seed(7)
        .build()
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    let planned =
        pipeline.solve(req).map_err(|e| anyhow::anyhow!("{e}"))?;
    let sol = planned.plan.solution().expect("fresh search");

    println!("loops: {} total, {} offloadable",
        sol.funnel.total_loops, sol.funnel.offloadable.len());
    for m in &sol.measurements {
        println!("  round {}  {:<8} {:>6.2}x  verified {:?}",
            m.round, m.label(), m.speedup(), m.verified);
    }
    println!("\nsolution: {} at {:.2}x vs all-CPU",
        planned.plan.label(), planned.plan.speedup());

    // The exp/log-dense pricing loop must be the winner.
    assert!(
        planned.plan.speedup() > 2.0,
        "pricer loop should clearly win on FPGA"
    );
    Ok(())
}
