//! Follow one plan request end to end through the observability layer:
//! serve a cold solve on a traced [`Service`], then render the span
//! tree — admission, queue wait, every pipeline stage, the backend
//! calls, and the final pattern-store append — all under one trace id,
//! plus the Prometheus families the `metrics` op exposes.
//!
//! ```text
//! cargo run --example trace_a_request
//! ```
//!
//! Against a live daemon the same views come from `repro trace`
//! (summary / `--id` tree / `--chrome` export) and `repro client
//! --metrics`.

use fpga_offload::obs::export::{render_tree, sort_spans};
use fpga_offload::obs::SpanRow;
use fpga_offload::service::{PlanRequest, Service, ServiceConfig};
use fpga_offload::util::tempdir::TempDir;
use fpga_offload::workloads;

fn main() -> anyhow::Result<()> {
    let dir = TempDir::new("trace-example")?;
    let cfg = ServiceConfig {
        pattern_db: Some(dir.path().to_path_buf()),
        workers: 1,
        ..ServiceConfig::default() // tracing is on by default
    };
    let svc = Service::start(cfg)?;

    let src = workloads::source("sobel").expect("bundled app");
    let resp = svc.request(PlanRequest::new("sobel", src));
    let plan = resp.result.as_ref().expect("sobel plan");
    println!(
        "served sobel: {} {:.2}x in {:.1} ms\n",
        plan.label,
        plan.speedup,
        resp.latency_us as f64 / 1e3
    );

    // The collector holds every span the request minted; one trace id
    // links the caller thread, the worker, and the batch's destination
    // thread.
    let mut rows: Vec<SpanRow> =
        svc.spans().iter().map(SpanRow::from).collect();
    sort_spans(&mut rows);
    println!("== span tree (repro trace --id N shows this live) ==");
    print!("{}", render_tree(&rows));

    println!("\n== metrics excerpt (the TCP `metrics` op) ==");
    for line in svc.stats().to_prometheus().lines() {
        if line.starts_with("offload_requests")
            || line.starts_with("offload_store_appends")
            || line.contains("hit_latency_us_bucket")
        {
            println!("{line}");
        }
    }

    svc.shutdown();
    Ok(())
}
