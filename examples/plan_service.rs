//! Offload-as-a-service in one process: start a [`Service`], take a
//! cold solve, watch the identical request come back as a
//! microsecond-class cache hit, and read the stats endpoint.
//!
//! ```text
//! cargo run --example plan_service
//! ```
//!
//! The same service speaks newline-delimited JSON over TCP via
//! `repro serve` / `repro client`; this example uses the in-process API
//! the daemon wraps.

use fpga_offload::service::{PlanRequest, Service, ServiceConfig};
use fpga_offload::util::tempdir::TempDir;
use fpga_offload::workloads;

fn main() -> anyhow::Result<()> {
    let dir = TempDir::new("plan-service-example")?;
    let cfg = ServiceConfig {
        pattern_db: Some(dir.path().to_path_buf()),
        workers: 2,
        ..ServiceConfig::default()
    };
    let svc = Service::start(cfg)?;

    println!("== cold solves (full funnel per app) ==");
    for app in workloads::APPS {
        let src = workloads::source(app).expect("bundled app");
        let resp = svc.request(PlanRequest::new(*app, src));
        match &resp.result {
            Ok(plan) => println!(
                "{app}: {} {:.2}x [{}] in {:.1} ms",
                plan.label,
                plan.speedup,
                resp.class.as_str(),
                resp.latency_us as f64 / 1e3,
            ),
            Err(e) => println!("{app}: failed — {e}"),
        }
    }

    println!("\n== warm hits (served from the in-memory index) ==");
    for app in workloads::APPS {
        let src = workloads::source(app).expect("bundled app");
        let resp = svc.request(PlanRequest::new(*app, src));
        let plan = resp.result.as_ref().expect("warm plan");
        println!(
            "{app}: {} {:.2}x [{}] in {} us{}",
            plan.label,
            plan.speedup,
            resp.class.as_str(),
            resp.latency_us,
            if plan.cached { " (cached)" } else { "" },
        );
        assert!(resp.is_hit(), "{app} should be a hit on repeat");
    }

    let snap = svc.stats();
    println!(
        "\nstats: {} requests — {} hits (p50 {} us) / {} misses \
         (p50 {} us), {} solves, queue {} deep",
        snap.requests,
        snap.hits,
        snap.hit_p50_us,
        snap.misses,
        snap.miss_p50_us,
        snap.solves,
        snap.queue_depth,
    );
    svc.shutdown();
    Ok(())
}
