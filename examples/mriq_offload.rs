//! MRI-Q offloading (Fig. 4, second row: 7.1x in the paper).
//!
//! Same staged pipeline as quickstart but for the Parboil MRI-Q
//! application, plus a side-by-side of the funnel's choice against
//! exhaustively simulating every single-loop pattern — showing the
//! narrowing found the true optimum with 4 measurements instead of 16.
//!
//! Run with: `cargo run --release --example mriq_offload`

use fpga_offload::codegen::split;
use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::envadapt::{OffloadRequest, Pipeline};
use fpga_offload::fpga::simulate;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::search::{FpgaBackend, SearchConfig};
use fpga_offload::workloads;

fn main() -> anyhow::Result<()> {
    println!("== automatic FPGA offloading: MRI-Q ==\n");

    let backend = FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    let pipeline = Pipeline::new(SearchConfig::default(), &backend)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let req = OffloadRequest::builder("mriq")
        .source(workloads::MRIQ_C)
        .build()
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    // Stages 1–3: the funnel survivors, with program + analysis in hand
    // for the exhaustive comparison below.
    let parsed = pipeline.parse(req).map_err(|e| anyhow::anyhow!("{e}"))?;
    let analyzed =
        pipeline.analyze(parsed).map_err(|e| anyhow::anyhow!("{e}"))?;
    let candidates =
        pipeline.extract(analyzed).map_err(|e| anyhow::anyhow!("{e}"))?;

    // Exhaustive single-loop sweep (what skipping the narrowing costs:
    // every simulate() here would be a ~3 h compile on real hardware).
    println!("exhaustive single-loop sweep (16 would-be compiles):");
    let mut best = ("none".to_string(), 1.0f64);
    let mut compiles = 0;
    for al in &candidates.analysis.loops {
        if !al.candidate() {
            continue;
        }
        let Ok(sp) = split(&candidates.prog, al) else { continue };
        let Ok(t) = simulate(
            &candidates.analysis,
            &[sp.kernel],
            &XEON_BRONZE_3104,
            &ARRIA10_GX,
        ) else {
            continue;
        };
        compiles += 1;
        println!("  {}  {:>6.2}x", al.id(), t.speedup);
        if t.speedup > best.1 {
            best = (al.id().to_string(), t.speedup);
        }
    }

    // Stages 4–5: the paper's method.
    let measured =
        pipeline.measure(candidates).map_err(|e| anyhow::anyhow!("{e}"))?;
    let planned =
        pipeline.select(measured).map_err(|e| anyhow::anyhow!("{e}"))?;
    let sol = planned.plan.solution().expect("fresh search");
    println!(
        "\nfunnel solution: {} at {:.2}x (paper: 7.1x) with {} measurements",
        planned.plan.label(),
        planned.plan.speedup(),
        sol.measurements.len()
    );

    println!(
        "\nexhaustive best: {} at {:.2}x after {} compiles (~{:.0} h of \
         place-and-route)\nfunnel matched it with {} measurements (~{:.0} h)",
        best.0,
        best.1,
        compiles,
        compiles as f64 * 2.5,
        sol.measurements.len(),
        sol.automation_s / 3600.0
    );
    assert!(
        planned.plan.speedup() >= best.1 * 0.99,
        "funnel must find the exhaustive optimum on MRI-Q"
    );
    Ok(())
}
