//! MRI-Q offloading (Fig. 4, second row: 7.1x in the paper).
//!
//! Same flow as quickstart but for the Parboil MRI-Q application, plus a
//! side-by-side of the funnel's choice against exhaustively simulating
//! every single-loop pattern — showing the narrowing found the true
//! optimum with 4 measurements instead of 16.
//!
//! Run with: `cargo run --release --example mriq_offload`

use fpga_offload::analysis::analyze;
use fpga_offload::codegen::split;
use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::fpga::simulate;
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::minic::parse;
use fpga_offload::search::{search, SearchConfig};
use fpga_offload::workloads;

fn main() -> anyhow::Result<()> {
    println!("== automatic FPGA offloading: MRI-Q ==\n");
    let prog = parse(workloads::MRIQ_C).map_err(|e| anyhow::anyhow!("{e}"))?;
    let an = analyze(&prog, "main").map_err(|e| anyhow::anyhow!("{e}"))?;

    // The paper's method.
    let sol = search(
        "mriq",
        &prog,
        &an,
        &SearchConfig::default(),
        &XEON_BRONZE_3104,
        &ARRIA10_GX,
    )
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("funnel solution: {} at {:.2}x (paper: 7.1x) with {} measurements",
        sol.best_measurement().label(),
        sol.speedup(),
        sol.measurements.len());

    // Exhaustive single-loop sweep (what skipping the narrowing costs:
    // every simulate() here would be a ~3 h compile on real hardware).
    println!("\nexhaustive single-loop sweep (16 would-be compiles):");
    let mut best = ("none".to_string(), 1.0f64);
    let mut compiles = 0;
    for al in &an.loops {
        if !al.candidate() {
            continue;
        }
        let Ok(sp) = split(&prog, al) else { continue };
        let Ok(t) = simulate(&an, &[sp.kernel], &XEON_BRONZE_3104, &ARRIA10_GX)
        else {
            continue;
        };
        compiles += 1;
        println!("  {}  {:>6.2}x", al.id(), t.speedup);
        if t.speedup > best.1 {
            best = (al.id().to_string(), t.speedup);
        }
    }
    println!(
        "\nexhaustive best: {} at {:.2}x after {} compiles (~{:.0} h of \
         place-and-route)\nfunnel matched it with {} measurements (~{:.0} h)",
        best.0,
        best.1,
        compiles,
        compiles as f64 * 2.5,
        sol.measurements.len(),
        sol.automation_s / 3600.0
    );
    assert!(
        sol.speedup() >= best.1 * 0.99,
        "funnel must find the exhaustive optimum on MRI-Q"
    );
    Ok(())
}
