//! Quickstart: the END-TO-END staged pipeline (Fig. 4 headline, tdfir).
//!
//! Exercises every layer of the reproduction on a real workload, one
//! pipeline stage at a time so each Fig.-1 artifact is visible:
//! 1. `parse` + `analyze` — the bundled HPEC tdfir C source (36 loops),
//!    profiled under the slot-resolved VM (all-CPU baseline),
//! 2. `extract` — the paper's funnel (top-A intensity → pre-compile →
//!    top-C resource efficiency),
//! 3. `measure` — two measurement rounds on the Arria10 FPGA backend,
//! 4. `select` — best pattern into the code-pattern DB, and
//! 5. `deploy` — the REAL tdfir kernels (the Pallas kernel lowered to
//!    HLO at build time) through the PJRT runtime, numerics checked
//!    against the in-crate reference (proving L1→L2→L3 compose).
//!
//! Run with: `make artifacts && cargo run --release --example quickstart`

use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::envadapt::{OffloadRequest, Pipeline, TestDb};
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::runtime::{Artifacts, Runtime};
use fpga_offload::search::{FpgaBackend, SearchConfig};
use fpga_offload::workloads;

fn main() -> anyhow::Result<()> {
    println!("== automatic FPGA offloading: tdfir quickstart ==\n");

    // The PJRT runtime is optional: without artifacts we still search,
    // we just skip the step-6 deploy check.
    let cwd = std::env::current_dir()?;
    let artifacts = Artifacts::discover(&cwd).ok();
    let runtime = match &artifacts {
        Some(_) => Some(Runtime::cpu()?),
        None => {
            eprintln!("note: no artifacts/ found — run `make artifacts` to \
                       enable the PJRT deploy check");
            None
        }
    };
    let runtime_pair = match (&runtime, &artifacts) {
        (Some(rt), Some(art)) => Some((rt, art)),
        _ => None,
    };

    // Paper §5.1.2 conditions: A=5 B=1 C=3 D=4, FPGA destination.
    let backend = FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    };
    // Stable dir (not a self-deleting temp dir): the stored pattern must
    // survive the run so a second invocation can inspect or reuse it.
    let db_dir = std::env::temp_dir().join("fpga-offload-quickstart-db");
    let pipeline = Pipeline::new(SearchConfig::default(), &backend)
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .with_pattern_db(&db_dir);

    let testdb = TestDb::builtin();
    let case = testdb.get("tdfir").expect("tdfir is builtin");
    let req = OffloadRequest::from_case(case, workloads::TDFIR_C);

    // Stages 1–5 one by one, artifacts in hand throughout.
    let parsed = pipeline.parse(req).map_err(|e| anyhow::anyhow!("{e}"))?;
    let analyzed =
        pipeline.analyze(parsed).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "analysis: {} loop statements profiled",
        analyzed.analysis.loops.len()
    );

    let candidates =
        pipeline.extract(analyzed).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "funnel: {} loops → {} offloadable → top-A {} → top-C {}",
        candidates.trace.total_loops,
        candidates.trace.offloadable.len(),
        candidates.trace.top_a.len(),
        candidates.trace.top_c.len()
    );

    let measured =
        pipeline.measure(candidates).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("\nmeasured patterns:");
    for m in &measured.set.measurements {
        println!(
            "  round {}  {:<10} {:>6.2}x  (compile {:.1} h, verified {:?})",
            m.round,
            m.label(),
            m.speedup(),
            m.compile_s / 3600.0,
            m.verified
        );
    }

    let planned =
        pipeline.select(measured).map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "\nsolution: {} at {:.2}x vs all-CPU (paper Fig. 4: 4.0x)",
        planned.plan.label(),
        planned.plan.speedup()
    );
    println!(
        "automation: {:.1} h modeled (paper §5.2: ~half a day)",
        planned.plan.automation_s() / 3600.0
    );
    if let Some(p) = &planned.stored_at {
        println!("pattern DB: {}", p.display());
    }

    // Step 6: production deploy check on the real (Pallas→HLO) kernels.
    let deployed = pipeline
        .deploy(planned, runtime_pair)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    match &deployed.sample_run {
        Some(sr) => println!(
            "\nPJRT deploy check (Pallas→HLO→Rust): exec {:?}, \
             max|err| {:.2e} over {} outputs — stack verified",
            sr.exec_time, sr.max_abs_err, sr.checked
        ),
        None => {
            println!("\nPJRT deploy check skipped (no artifacts/runtime)")
        }
    }
    Ok(())
}
