//! Quickstart: the END-TO-END driver (Fig. 4 headline, tdfir).
//!
//! Exercises every layer of the reproduction on a real workload:
//! 1. parses the bundled HPEC tdfir C source (36 loops),
//! 2. profiles it under the instrumented interpreter (all-CPU baseline),
//! 3. runs the paper's funnel (top-A intensity → pre-compile → top-C
//!    resource efficiency) and the two measurement rounds on the Arria10
//!    model,
//! 4. persists the winning pattern to the code-pattern DB, and
//! 5. executes the REAL tdfir kernels — the Pallas kernel lowered to HLO
//!    at build time — through the PJRT runtime and checks the numerics
//!    against the in-crate reference (proving L1→L2→L3 compose).
//!
//! Run with: `make artifacts && cargo run --release --example quickstart`

use fpga_offload::cpu::XEON_BRONZE_3104;
use fpga_offload::envadapt::{run_flow, FlowOptions, TestDb};
use fpga_offload::hls::ARRIA10_GX;
use fpga_offload::runtime::{Artifacts, Runtime};
use fpga_offload::search::SearchConfig;
use fpga_offload::workloads;

fn main() -> anyhow::Result<()> {
    println!("== automatic FPGA offloading: tdfir quickstart ==\n");

    // The PJRT runtime is optional: without artifacts we still search,
    // we just skip the step-6 sample test.
    let cwd = std::env::current_dir()?;
    let artifacts = Artifacts::discover(&cwd).ok();
    let runtime = match &artifacts {
        Some(_) => Some(Runtime::cpu()?),
        None => {
            eprintln!("note: no artifacts/ found — run `make artifacts` to \
                       enable the PJRT sample test");
            None
        }
    };
    let runtime_pair = match (&runtime, &artifacts) {
        (Some(rt), Some(art)) => Some((rt, art)),
        _ => None,
    };

    let db_dir = std::env::temp_dir().join("fpga-offload-quickstart-db");
    let opts = FlowOptions {
        config: SearchConfig::default(), // paper §5.1.2: A=5 B=1 C=3 D=4
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
        pattern_db: Some(&db_dir),
        runtime: runtime_pair,
        seed: 42,
    };

    let testdb = TestDb::builtin();
    let report = run_flow("tdfir", workloads::TDFIR_C, &testdb, &opts)?;
    let sol = &report.solution;

    println!("funnel: {} loops → {} offloadable → top-A {} → top-C {}",
        sol.funnel.total_loops,
        sol.funnel.offloadable.len(),
        sol.funnel.top_a.len(),
        sol.funnel.top_c.len());
    println!("\nmeasured patterns:");
    for m in &sol.measurements {
        println!(
            "  round {}  {:<10} {:>6.2}x  (compile {:.1} h, verified {:?})",
            m.round,
            m.label(),
            m.speedup(),
            m.compile_s / 3600.0,
            m.verified
        );
    }
    println!("\nsolution: {} at {:.2}x vs all-CPU (paper Fig. 4: 4.0x)",
        sol.best_measurement().label(), sol.speedup());
    println!("automation: {:.1} h modeled (paper §5.2: ~half a day)",
        sol.automation_s / 3600.0);
    if let Some(p) = &report.stored_at {
        println!("pattern DB: {}", p.display());
    }
    if let Some(sr) = &report.sample_run {
        println!(
            "\nPJRT sample test (Pallas→HLO→Rust): exec {:?}, \
             max|err| {:.2e} over {} outputs — stack verified",
            sr.exec_time, sr.max_abs_err, sr.checked
        );
    }
    Ok(())
}
