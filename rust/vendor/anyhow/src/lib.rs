//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build environment for this repository has no crates.io access, so
//! the subset of the `anyhow` API this codebase uses is reimplemented
//! here: [`Error`], [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Error values carry a context chain: `{}` prints the outermost context,
//! `{:#}` prints the whole chain colon-separated (matching anyhow's
//! alternate formatting), and `{:?}` prints a "Caused by" list.

use std::fmt;

/// A dynamic error with a chain of context strings (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an error built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::Error::msg(
            ::std::format!($($arg)*),
        ))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(
                ::std::concat!("condition failed: ", ::std::stringify!($cond)),
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(
                ::std::format!($($arg)*),
            ));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
    }

    #[test]
    fn option_context() {
        let v: Result<i32> = None.context("nothing there");
        assert_eq!(format!("{}", v.unwrap_err()), "nothing there");
    }

    #[test]
    fn macros_compile_and_fire() {
        fn inner(flag: bool) -> Result<i32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(inner(true).unwrap(), 7);
        assert_eq!(
            format!("{}", inner(false).unwrap_err()),
            "flag was false"
        );
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
    }

    #[test]
    fn debug_lists_causes() {
        let e: Error = Err::<(), _>(io_err()).context("outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.contains("outer"));
        assert!(dbg.contains("Caused by"));
        assert!(dbg.contains("missing file"));
    }
}
