//! Offline stub of the `xla` crate (xla-rs PJRT binding).
//!
//! The offline build environment has neither crates.io access nor an XLA
//! toolchain, so this stub provides just the type/API surface that
//! `fpga_offload::runtime::pjrt` compiles against. Every operation that
//! would touch a real PJRT client returns an error at runtime, which the
//! runtime layer surfaces as "PJRT unavailable". To run the real sample
//! tests, replace this path crate with the actual `xla` binding and build
//! with the `pjrt-live` feature enabled on `fpga_offload`.

use std::fmt;

/// Stub error: every live operation produces one.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT/XLA unavailable (stub `xla` crate; swap in the real \
         xla-rs binding under rust/vendor/xla to enable it)"
    )))
}

/// Host-side literal (stub).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle (stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_operations_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(Literal::vec1(&[1.0]).reshape(&[1]).is_err());
    }
}
