//! Trace contexts, RAII span guards, and cross-thread handoff.
//!
//! A [`Tracer`] mints one `trace_id` per root operation (a service
//! request, a batch app). The active context lives in a thread-local;
//! [`span`] reads it and returns a guard that records a [`SpanRecord`]
//! into the tracer's [`Collector`](super::Collector) on drop, so
//! instrumentation points deep in the store or the retry loop need no
//! signature changes — they pick the context up from the thread.
//! Crossing a thread boundary (queue → worker, batch → destination
//! thread) is explicit: capture a [`TraceHandoff`] on the source
//! thread, [`enter`](TraceHandoff::enter) it on the target.
//!
//! Everything degrades to a no-op: a disabled tracer, a sampled-out
//! trace, or a thread with no context all cost one thread-local read
//! per span. Recording never blocks (see
//! [`Collector`](super::Collector)), and guards hold their own `Arc` to
//! the collector, so dropping the `Tracer` (or the whole service) while
//! spans are in flight is safe.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::search::SimClock;

use super::collector::Collector;
use super::TraceConfig;

/// The root span's id within every trace (parent id 0 marks the root).
pub const ROOT_SPAN_ID: u64 = 1;

/// One finished span. `detail` is free-form ("tdfir" on a root,
/// "attempt 2" on a retry); empty when there is nothing to say.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    pub trace_id: u64,
    pub span_id: u64,
    /// 0 for the trace root.
    pub parent_id: u64,
    pub name: &'static str,
    pub detail: String,
    pub start_us: u64,
    pub end_us: u64,
}

impl SpanRecord {
    pub fn duration_us(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }
}

/// Where span timestamps come from: wall time anchored at tracer
/// creation (production), or the shared [`SimClock`] (deterministic
/// tests — backoff waits are the only thing that advances it).
#[derive(Debug)]
enum TraceClock {
    Wall(Instant),
    Sim(SimClock),
}

impl TraceClock {
    fn now_us(&self) -> u64 {
        match self {
            TraceClock::Wall(epoch) => {
                epoch.elapsed().as_micros().min(u64::MAX as u128) as u64
            }
            TraceClock::Sim(clock) => {
                (clock.now_s() * 1e6).round() as u64
            }
        }
    }
}

#[derive(Debug)]
struct TracerInner {
    collector: Collector,
    clock: TraceClock,
    /// Traces minted so far; also drives head sampling.
    next_trace: AtomicU64,
    /// Keep 1 trace in `sample`; 1 = keep everything.
    sample: u64,
}

/// Handle to one collector + clock. Cheap to clone; a disabled tracer
/// (the default) is a single `None` and every operation on it is a
/// no-op.
#[derive(Debug, Clone, Default)]
pub struct Tracer {
    inner: Option<Arc<TracerInner>>,
}

impl Tracer {
    /// The no-op tracer: no collector, no overhead beyond an `Option`
    /// check.
    pub fn disabled() -> Tracer {
        Tracer::default()
    }

    /// A wall-clock tracer (production: `repro serve`, `repro batch`).
    pub fn new(cfg: &TraceConfig) -> Tracer {
        Self::build(cfg, TraceClock::Wall(Instant::now()))
    }

    /// A tracer stamping spans from the shared virtual clock —
    /// deterministic timestamps for tests and seeded fault runs.
    pub fn with_sim_clock(cfg: &TraceConfig, clock: SimClock) -> Tracer {
        Self::build(cfg, TraceClock::Sim(clock))
    }

    fn build(cfg: &TraceConfig, clock: TraceClock) -> Tracer {
        if !cfg.enabled {
            return Tracer::disabled();
        }
        Tracer {
            inner: Some(Arc::new(TracerInner {
                collector: Collector::new(cfg.capacity),
                clock,
                next_trace: AtomicU64::new(0),
                sample: cfg.sample.max(1),
            })),
        }
    }

    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Current trace-clock reading (0 when disabled). Pair with
    /// [`closed_span`] to record an interval that started before its
    /// recording thread existed (queue wait).
    pub fn now_us(&self) -> u64 {
        self.inner.as_ref().map_or(0, |i| i.clock.now_us())
    }

    /// Mint a trace and install its context on this thread. The guard
    /// records the root span and restores the previous context on drop.
    /// Sampled-out traces return an inert guard.
    pub fn trace(&self, name: &'static str, detail: &str) -> TraceGuard {
        let Some(inner) = &self.inner else {
            return TraceGuard(None);
        };
        let seq = inner.next_trace.fetch_add(1, Ordering::Relaxed);
        if seq % inner.sample != 0 {
            return TraceGuard(None);
        }
        let trace_id = seq + 1;
        let ctx = ActiveCtx {
            inner: Arc::clone(inner),
            trace_id,
            parent: ROOT_SPAN_ID,
            counter: Arc::new(AtomicU64::new(ROOT_SPAN_ID + 1)),
        };
        let start_us = inner.clock.now_us();
        let prev = CURRENT.with(|c| c.borrow_mut().replace(ctx));
        TraceGuard(Some(RootSpan {
            inner: Arc::clone(inner),
            trace_id,
            name,
            detail: detail.to_string(),
            start_us,
            prev,
        }))
    }

    /// Every span currently retained, oldest claim first.
    pub fn spans(&self) -> Vec<SpanRecord> {
        self.inner
            .as_ref()
            .map_or_else(Vec::new, |i| i.collector.snapshot())
    }

    /// Spans recorded over the tracer's lifetime.
    pub fn recorded(&self) -> u64 {
        self.inner.as_ref().map_or(0, |i| i.collector.recorded())
    }

    /// Spans lost to slot contention.
    pub fn dropped(&self) -> u64 {
        self.inner.as_ref().map_or(0, |i| i.collector.dropped())
    }
}

/// The per-thread trace context.
#[derive(Debug, Clone)]
struct ActiveCtx {
    inner: Arc<TracerInner>,
    trace_id: u64,
    /// Parent for the next child span opened on this thread.
    parent: u64,
    /// Shared per-trace span-id allocator, so ids stay unique (and,
    /// under a single worker, deterministic) across handoffs.
    counter: Arc<AtomicU64>,
}

thread_local! {
    static CURRENT: RefCell<Option<ActiveCtx>> =
        const { RefCell::new(None) };
}

/// Root-span guard returned by [`Tracer::trace`].
pub struct TraceGuard(Option<RootSpan>);

struct RootSpan {
    inner: Arc<TracerInner>,
    trace_id: u64,
    name: &'static str,
    detail: String,
    start_us: u64,
    prev: Option<ActiveCtx>,
}

impl TraceGuard {
    /// Whether this guard is live (enabled tracer, sampled in).
    pub fn active(&self) -> bool {
        self.0.is_some()
    }

    /// The minted trace id (0 when inert).
    pub fn trace_id(&self) -> u64 {
        self.0.as_ref().map_or(0, |r| r.trace_id)
    }
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        let Some(root) = self.0.take() else {
            return;
        };
        let end_us = root.inner.clock.now_us();
        root.inner.collector.record(SpanRecord {
            trace_id: root.trace_id,
            span_id: ROOT_SPAN_ID,
            parent_id: 0,
            name: root.name,
            detail: root.detail,
            start_us: root.start_us,
            end_us,
        });
        CURRENT.with(|c| *c.borrow_mut() = root.prev);
    }
}

/// Child-span guard returned by [`span`]. Records on drop; inert when
/// the thread has no trace context.
pub struct SpanGuard(Option<LiveSpan>);

struct LiveSpan {
    inner: Arc<TracerInner>,
    trace_id: u64,
    span_id: u64,
    parent_id: u64,
    name: &'static str,
    detail: String,
    start_us: u64,
}

impl SpanGuard {
    pub fn active(&self) -> bool {
        self.0.is_some()
    }

    /// Attach detail, paying for the `String` only when the span is
    /// live.
    pub fn note(&mut self, detail: impl FnOnce() -> String) {
        if let Some(s) = &mut self.0 {
            s.detail = detail();
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(s) = self.0.take() else {
            return;
        };
        let end_us = s.inner.clock.now_us();
        // Restore the parent pointer if this thread is still inside the
        // same trace (it always is when guards nest lexically).
        CURRENT.with(|c| {
            if let Some(ctx) = c.borrow_mut().as_mut() {
                if ctx.trace_id == s.trace_id
                    && ctx.parent == s.span_id
                {
                    ctx.parent = s.parent_id;
                }
            }
        });
        s.inner.collector.record(SpanRecord {
            trace_id: s.trace_id,
            span_id: s.span_id,
            parent_id: s.parent_id,
            name: s.name,
            detail: s.detail,
            start_us: s.start_us,
            end_us,
        });
    }
}

/// Open a child span under the current thread's context. A no-op
/// costing one thread-local read when there is none.
pub fn span(name: &'static str) -> SpanGuard {
    CURRENT.with(|c| {
        let mut cur = c.borrow_mut();
        let Some(ctx) = cur.as_mut() else {
            return SpanGuard(None);
        };
        let span_id = ctx.counter.fetch_add(1, Ordering::Relaxed);
        let parent_id = std::mem::replace(&mut ctx.parent, span_id);
        SpanGuard(Some(LiveSpan {
            inner: Arc::clone(&ctx.inner),
            trace_id: ctx.trace_id,
            span_id,
            parent_id,
            name,
            detail: String::new(),
            start_us: ctx.inner.clock.now_us(),
        }))
    })
}

/// Record an already-elapsed interval ending now — the queue-wait span,
/// whose start predates the worker thread picking the job up.
/// `start_us` is in trace-clock units ([`Tracer::now_us`] at enqueue).
pub fn closed_span(name: &'static str, start_us: u64) {
    CURRENT.with(|c| {
        let mut cur = c.borrow_mut();
        let Some(ctx) = cur.as_mut() else {
            return;
        };
        let span_id = ctx.counter.fetch_add(1, Ordering::Relaxed);
        let end_us = ctx.inner.clock.now_us();
        ctx.inner.collector.record(SpanRecord {
            trace_id: ctx.trace_id,
            span_id,
            parent_id: ctx.parent,
            name,
            detail: String::new(),
            start_us: start_us.min(end_us),
            end_us,
        });
    })
}

/// A capture of the current trace context, safe to move to another
/// thread. `Job` structs carry one across the admission queue; batch
/// orchestration captures one per spawned destination thread.
#[derive(Debug, Clone)]
pub struct TraceHandoff {
    ctx: ActiveCtx,
}

/// Capture the current thread's context (None when untraced).
pub fn handoff() -> Option<TraceHandoff> {
    CURRENT.with(|c| {
        c.borrow().clone().map(|ctx| TraceHandoff { ctx })
    })
}

/// Enter each of `h` on this thread, when present. Sugar for the
/// `Option` every handoff naturally travels as.
pub fn enter(h: &Option<TraceHandoff>) -> Option<EnterGuard> {
    h.as_ref().map(|h| h.enter())
}

impl TraceHandoff {
    /// Install this context on the current thread until the guard
    /// drops (the previous context, if any, is restored).
    pub fn enter(&self) -> EnterGuard {
        let prev = CURRENT
            .with(|c| c.borrow_mut().replace(self.ctx.clone()));
        EnterGuard { prev: Some(prev) }
    }

    /// Trace-clock reading through the captured context.
    pub fn now_us(&self) -> u64 {
        self.ctx.inner.clock.now_us()
    }
}

/// Restores the pre-[`enter`](TraceHandoff::enter) context on drop.
pub struct EnterGuard {
    prev: Option<Option<ActiveCtx>>,
}

impl Drop for EnterGuard {
    fn drop(&mut self) {
        if let Some(prev) = self.prev.take() {
            CURRENT.with(|c| *c.borrow_mut() = prev);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim_tracer() -> (Tracer, SimClock) {
        let clock = SimClock::new();
        let cfg = TraceConfig::default();
        (Tracer::with_sim_clock(&cfg, clock.clone()), clock)
    }

    #[test]
    fn disabled_tracer_and_bare_threads_are_no_ops() {
        let t = Tracer::disabled();
        assert!(!t.enabled());
        {
            let _root = t.trace("request", "app");
            let mut s = span("anything");
            assert!(!s.active());
            s.note(|| unreachable!("detail must not be computed"));
        }
        assert!(t.spans().is_empty());
        assert!(handoff().is_none());
        closed_span("queue.wait", 0);
    }

    #[test]
    fn spans_nest_and_record_parentage() {
        let (t, clock) = sim_tracer();
        {
            let _root = t.trace("request", "tdfir");
            clock.advance_s(1.0);
            {
                let _a = span("stage.parse");
                clock.advance_s(1.0);
                let _b = span("store.read");
                clock.advance_s(1.0);
            }
            let _c = span("stage.measure");
            clock.advance_s(1.0);
        }
        let mut spans = t.spans();
        spans.sort_by_key(|s| s.span_id);
        let names: Vec<&str> = spans.iter().map(|s| s.name).collect();
        assert_eq!(
            names,
            vec!["request", "stage.parse", "store.read", "stage.measure"]
        );
        let by_name = |n: &str| {
            spans.iter().find(|s| s.name == n).unwrap().clone()
        };
        let root = by_name("request");
        assert_eq!(root.parent_id, 0);
        assert_eq!(root.span_id, ROOT_SPAN_ID);
        assert_eq!(root.detail, "tdfir");
        assert_eq!((root.start_us, root.end_us), (0, 4_000_000));
        let parse = by_name("stage.parse");
        assert_eq!(parse.parent_id, root.span_id);
        let read = by_name("store.read");
        // store.read nests under stage.parse, not the root.
        assert_eq!(read.parent_id, parse.span_id);
        let measure = by_name("stage.measure");
        // ...while stage.measure is back at the root after parse ends.
        assert_eq!(measure.parent_id, root.span_id);
        assert!(spans.iter().all(|s| s.trace_id == 1));
    }

    #[test]
    fn handoff_carries_the_trace_across_threads() {
        let (t, clock) = sim_tracer();
        {
            let _root = t.trace("request", "app");
            let h = handoff().expect("context must be capturable");
            let enqueued = t.now_us();
            clock.advance_s(2.0);
            std::thread::scope(|s| {
                s.spawn(move || {
                    let _e = h.enter();
                    closed_span("queue.wait", enqueued);
                    let _solve = span("solve");
                    clock.advance_s(1.0);
                })
                .join()
                .unwrap();
            });
            // Back on the origin thread the context still works.
            let _tail = span("admission");
        }
        let spans = t.spans();
        let wait = spans.iter().find(|s| s.name == "queue.wait").unwrap();
        assert_eq!(wait.parent_id, ROOT_SPAN_ID);
        assert_eq!(wait.duration_us(), 2_000_000);
        let solve = spans.iter().find(|s| s.name == "solve").unwrap();
        assert_eq!(solve.parent_id, ROOT_SPAN_ID);
        assert_eq!(solve.duration_us(), 1_000_000);
        let tail = spans.iter().find(|s| s.name == "admission").unwrap();
        assert_eq!(tail.parent_id, ROOT_SPAN_ID);
        assert_eq!(spans.len(), 4);
    }

    #[test]
    fn sampling_keeps_one_in_n_traces() {
        let cfg = TraceConfig {
            sample: 4,
            ..TraceConfig::default()
        };
        let t = Tracer::new(&cfg);
        let mut live = 0;
        for _ in 0..16 {
            let root = t.trace("request", "");
            if root.active() {
                live += 1;
            }
        }
        assert_eq!(live, 4);
        assert_eq!(t.spans().len(), 4);
    }

    #[test]
    fn guards_survive_the_tracer_being_dropped() {
        let (t, _clock) = sim_tracer();
        let root = t.trace("request", "app");
        let child = span("stage.parse");
        let spans_handle = t.clone();
        drop(t);
        // The service owning the tracer is gone; in-flight guards must
        // still complete (they hold their own Arc) without blocking.
        drop(child);
        drop(root);
        assert_eq!(spans_handle.spans().len(), 2);
    }

    #[test]
    fn wall_clock_timestamps_are_monotonic() {
        let t = Tracer::new(&TraceConfig::default());
        {
            let _root = t.trace("request", "");
            let _child = span("stage.parse");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        let spans = t.spans();
        assert_eq!(spans.len(), 2);
        for s in &spans {
            assert!(s.end_us >= s.start_us);
        }
        let root =
            spans.iter().find(|s| s.name == "request").unwrap();
        assert!(root.duration_us() >= 2_000);
    }
}
