//! Prometheus-style text exposition, built from counters and
//! [`HistogramSnapshot`]s.
//!
//! No client library, just the stable text format: `# HELP` / `# TYPE`
//! headers, `name value` samples, and the `_bucket{le="..."}` /
//! `_sum` / `_count` triple for histograms. The `metrics` protocol op
//! wraps the finished text in its JSON response line; anything that
//! scrapes Prometheus text can parse the body.

use super::hist::HistogramSnapshot;

/// Incremental builder for one exposition document.
#[derive(Debug, Default)]
pub struct PromText {
    out: String,
}

/// Format a sample value the way Prometheus expects (integers bare,
/// floats with their natural shortest form).
fn num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

impl PromText {
    pub fn new() -> Self {
        Self::default()
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str(&format!(
            "# HELP {name} {help}\n# TYPE {name} {kind}\n"
        ));
    }

    pub fn counter(&mut self, name: &str, help: &str, v: f64) {
        self.header(name, help, "counter");
        self.out.push_str(&format!("{name} {}\n", num(v)));
    }

    pub fn gauge(&mut self, name: &str, help: &str, v: f64) {
        self.header(name, help, "gauge");
        self.out.push_str(&format!("{name} {}\n", num(v)));
    }

    /// A counter family with one label dimension, e.g.
    /// `offload_retries_total{stage="measure"} 3`.
    pub fn counter_vec(
        &mut self,
        name: &str,
        help: &str,
        label: &str,
        samples: &[(&str, f64)],
    ) {
        self.header(name, help, "counter");
        for (value, v) in samples {
            self.out.push_str(&format!(
                "{name}{{{label}=\"{value}\"}} {}\n",
                num(*v)
            ));
        }
    }

    /// The cumulative `_bucket`/`_sum`/`_count` triple from a
    /// log-bucketed snapshot. Bucket bounds are the histogram's own
    /// non-empty bucket uppers — variable per scrape, which the text
    /// format is fine with.
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        snap: &HistogramSnapshot,
    ) {
        self.header(name, help, "histogram");
        let mut cumulative = 0u64;
        for &(upper, count) in &snap.counts {
            cumulative += count;
            self.out.push_str(&format!(
                "{name}_bucket{{le=\"{upper}\"}} {cumulative}\n"
            ));
        }
        self.out.push_str(&format!(
            "{name}_bucket{{le=\"+Inf\"}} {}\n",
            snap.count
        ));
        self.out
            .push_str(&format!("{name}_sum {}\n", snap.sum));
        self.out
            .push_str(&format!("{name}_count {}\n", snap.count));
    }

    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::LogHistogram;

    #[test]
    fn counters_and_gauges_have_help_and_type() {
        let mut p = PromText::new();
        p.counter("offload_requests_total", "Requests admitted.", 7.0);
        p.gauge("offload_queue_depth", "Jobs queued.", 3.0);
        let text = p.finish();
        assert!(text.contains("# HELP offload_requests_total"));
        assert!(text.contains("# TYPE offload_requests_total counter"));
        assert!(text.contains("offload_requests_total 7\n"));
        assert!(text.contains("# TYPE offload_queue_depth gauge"));
        assert!(text.contains("offload_queue_depth 3\n"));
    }

    #[test]
    fn labeled_counters_quote_their_label() {
        let mut p = PromText::new();
        p.counter_vec(
            "offload_retries_total",
            "Retries by stage.",
            "stage",
            &[("measure", 2.0), ("verify", 0.0)],
        );
        let text = p.finish();
        assert!(text
            .contains("offload_retries_total{stage=\"measure\"} 2\n"));
        assert!(text
            .contains("offload_retries_total{stage=\"verify\"} 0\n"));
    }

    #[test]
    fn histogram_triple_is_cumulative_and_ends_at_inf() {
        let h = LogHistogram::new();
        h.record(5);
        h.record(5);
        h.record(100);
        let mut p = PromText::new();
        p.histogram(
            "offload_hit_latency_us",
            "Hit latency.",
            &h.snapshot(),
        );
        let text = p.finish();
        assert!(text
            .contains("offload_hit_latency_us_bucket{le=\"5\"} 2\n"));
        assert!(text
            .contains("offload_hit_latency_us_bucket{le=\"100\"} 3\n"));
        assert!(text
            .contains("offload_hit_latency_us_bucket{le=\"+Inf\"} 3\n"));
        assert!(text.contains("offload_hit_latency_us_sum 110\n"));
        assert!(text.contains("offload_hit_latency_us_count 3\n"));
    }

    #[test]
    fn fractional_values_keep_their_precision() {
        let mut p = PromText::new();
        p.gauge("offload_avg_solve_ms", "Mean solve.", 4.9);
        assert!(p.finish().contains("offload_avg_solve_ms 4.9\n"));
    }
}
