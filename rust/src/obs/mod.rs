//! Unified observability: request-scoped tracing, lock-free latency
//! histograms, and a Prometheus-style exposition.
//!
//! Before this module, telemetry lived in silos — `ServiceStats`
//! atomics, the retry seam's `FaultReport`, the store's counters — and
//! none of it was *request-scoped*: when one request in a thousand
//! degraded to `served_stale`, nothing could say which stage ate the
//! time. This module follows a single `trace_id` from TCP accept to
//! shard append:
//!
//! ```text
//! request (root, per service request / batch app)
//! ├── admission            reuse-key + index probe + queue decision
//! │   └── store.read       sharded-store lookup (the hit path)
//! ├── queue.wait           enqueue → worker pickup
//! └── solve                the worker's ladder run
//!     └── destination      one per destination pipeline
//!         ├── stage.parse … stage.analyze … stage.funcblock
//!         ├── stage.extract
//!         ├── stage.measure
//!         │   └── backend.measure / backend.verify
//!         │       ├── retry.attempt (detail: "attempt N")
//!         │       └── retry.backoff (detail: wait seconds)
//!         ├── stage.select
//!         │   └── store.append → store.evict → store.compact
//!         └── stage.deploy
//! ```
//!
//! The context rides a thread-local; crossing the admission queue or a
//! batch's scoped threads is an explicit [`TraceHandoff`]. Timestamps
//! come from wall clock in production or the shared
//! [`SimClock`](crate::search::SimClock) in tests, so seeded fault runs
//! produce byte-identical span trees. Recording is bounded and
//! non-blocking by construction (see [`Collector`]): the request path
//! can never be stalled or poisoned by its own telemetry.
//!
//! Exporters: NDJSON span dumps and Chrome trace-event JSON
//! ([`export`]), plus the Prometheus text exposition ([`metrics`])
//! built from the log-bucketed [`LogHistogram`]s that also back the
//! service's latency quantiles. Surfaced over the wire as the `metrics`
//! and `trace` protocol ops and the `repro trace` subcommand.
//!
//! ```
//! use fpga_offload::obs::{self, TraceConfig, Tracer};
//!
//! let tracer = Tracer::new(&TraceConfig::default());
//! {
//!     let _root = tracer.trace("request", "demo-app");
//!     let _stage = obs::span("stage.parse");
//!     // ... work ...
//! }
//! let spans = tracer.spans();
//! assert_eq!(spans.len(), 2);
//! assert!(spans.iter().any(|s| s.name == "stage.parse"));
//! ```

pub mod collector;
pub mod export;
pub mod hist;
pub mod metrics;
pub mod span;

pub use collector::Collector;
pub use export::SpanRow;
pub use hist::{HistogramSnapshot, LogHistogram};
pub use metrics::PromText;
pub use span::{
    closed_span, enter, handoff, span, SpanGuard, SpanRecord,
    TraceGuard, TraceHandoff, Tracer, ROOT_SPAN_ID,
};

/// Tracing knobs, carried by
/// [`ServiceConfig`](crate::service::ServiceConfig) and the CLI flags.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceConfig {
    /// Master switch; off means [`Tracer::disabled`] everywhere.
    pub enabled: bool,
    /// Span-ring capacity (spans retained, oldest overwritten).
    pub capacity: usize,
    /// Head sampling: keep 1 trace in `sample` (1 = trace everything).
    pub sample: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            enabled: true,
            capacity: 4096,
            sample: 1,
        }
    }
}

impl TraceConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.enabled && self.capacity == 0 {
            return Err("trace capacity must be >= 1".into());
        }
        if self.enabled && self.sample == 0 {
            return Err("trace sample must be >= 1".into());
        }
        Ok(())
    }
}
