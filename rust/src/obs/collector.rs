//! The bounded in-process span sink.
//!
//! Writers never block and never propagate poison: a slot is claimed
//! with one wait-free `fetch_add`, and the slot write uses `try_lock` —
//! if an exporter (or a wedged thread) holds that slot, the span is
//! *dropped* and counted, because losing one span is always better than
//! stalling the request path. Readers take the slot locks properly and
//! recover from poison, so a panicking writer can never wedge future
//! recording or snapshots.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::span::SpanRecord;

/// Fixed-capacity span ring. Oldest spans are overwritten once the ring
/// wraps; memory is bounded at `capacity * sizeof(slot)` forever.
#[derive(Debug)]
pub struct Collector {
    slots: Box<[Mutex<Option<SpanRecord>>]>,
    /// Total spans ever claimed (slot = claimed % capacity).
    claimed: AtomicU64,
    /// Spans lost to slot contention (see module docs).
    dropped: AtomicU64,
}

impl Collector {
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        let slots = (0..capacity)
            .map(|_| Mutex::new(None))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Collector {
            slots,
            claimed: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Spans recorded over the collector's lifetime (including ones the
    /// ring has since overwritten).
    pub fn recorded(&self) -> u64 {
        self.claimed.load(Ordering::Relaxed)
    }

    /// Spans dropped because their slot was contended at write time.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Store one span. Never blocks: contended slots drop the span.
    pub fn record(&self, rec: SpanRecord) {
        let idx = self.claimed.fetch_add(1, Ordering::Relaxed) as usize
            % self.slots.len();
        match self.slots[idx].try_lock() {
            Ok(mut slot) => *slot = Some(rec),
            Err(std::sync::TryLockError::Poisoned(p)) => {
                *p.into_inner() = Some(rec)
            }
            Err(std::sync::TryLockError::WouldBlock) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Copy out every retained span, oldest claim first. Poisoned slots
    /// are read through, not propagated.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let claimed = self.claimed.load(Ordering::Relaxed) as usize;
        let cap = self.slots.len();
        let read = |i: usize| -> Option<SpanRecord> {
            self.slots[i]
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .clone()
        };
        let mut out = Vec::with_capacity(claimed.min(cap));
        if claimed <= cap {
            out.extend((0..claimed).filter_map(read));
        } else {
            let head = claimed % cap;
            out.extend((head..cap).filter_map(read));
            out.extend((0..head).filter_map(read));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn rec(span_id: u64) -> SpanRecord {
        SpanRecord {
            trace_id: 1,
            span_id,
            parent_id: 0,
            name: "test",
            detail: String::new(),
            start_us: span_id,
            end_us: span_id + 1,
        }
    }

    #[test]
    fn ring_keeps_the_newest_spans() {
        let c = Collector::new(4);
        for i in 0..10 {
            c.record(rec(i));
        }
        let spans = c.snapshot();
        let ids: Vec<u64> = spans.iter().map(|s| s.span_id).collect();
        assert_eq!(ids, vec![8, 9, 6, 7]);
        assert_eq!(c.recorded(), 10);
        assert_eq!(c.dropped(), 0);
    }

    #[test]
    fn contended_slot_drops_instead_of_blocking() {
        let c = Collector::new(1);
        // Hold the only slot's lock and record from another thread: the
        // writer must return immediately with the span dropped.
        let guard = c.slots[0].lock().unwrap();
        std::thread::scope(|s| {
            s.spawn(|| c.record(rec(1))).join().unwrap();
        });
        drop(guard);
        assert_eq!(c.dropped(), 1);
        assert!(c.snapshot().is_empty());
    }

    #[test]
    fn poisoned_slot_never_wedges_recording_or_snapshots() {
        let c = Arc::new(Collector::new(2));
        // Poison slot 0 by panicking while holding its lock.
        let c2 = Arc::clone(&c);
        let _ = std::thread::spawn(move || {
            let _guard = c2.slots[0].lock().unwrap();
            panic!("poison the slot");
        })
        .join();
        // Both recording into the poisoned slot and snapshotting recover.
        c.record(rec(1));
        c.record(rec(2));
        let ids: Vec<u64> =
            c.snapshot().iter().map(|s| s.span_id).collect();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(c.dropped(), 0);
    }

    #[test]
    fn concurrent_writers_never_lose_more_than_contention() {
        let c = Arc::new(Collector::new(64));
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for i in 0..1000 {
                        c.record(rec(t * 1000 + i));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.recorded(), 8000);
        // Everything still present was stored intact (never torn), and
        // the ring never grew past its capacity.
        let spans = c.snapshot();
        assert!(spans.len() <= 64);
        assert!(spans.iter().all(|s| s.end_us == s.start_us + 1));
    }
}
