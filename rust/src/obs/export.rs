//! Span exporters: JSON wire shape, NDJSON dumps, Chrome trace-event
//! JSON, and terminal rendering for `repro trace`.
//!
//! Two shapes exist on purpose. [`SpanRecord`] is the in-process record
//! (static name, cheap to produce on the hot path); [`SpanRow`] is the
//! owned equivalent that survives a trip through the wire protocol —
//! `repro trace` parses responses into rows and renders or re-exports
//! from there, so a dump taken from a live daemon and one written
//! locally are byte-identical in format.

use crate::util::json::Json;

use super::span::SpanRecord;

impl SpanRecord {
    /// The wire/NDJSON shape of one span.
    pub fn to_json(&self) -> Json {
        span_json(
            self.trace_id,
            self.span_id,
            self.parent_id,
            self.name,
            &self.detail,
            self.start_us,
            self.end_us,
        )
    }
}

fn span_json(
    trace_id: u64,
    span_id: u64,
    parent_id: u64,
    name: &str,
    detail: &str,
    start_us: u64,
    end_us: u64,
) -> Json {
    Json::obj(vec![
        ("trace_id", Json::Num(trace_id as f64)),
        ("span_id", Json::Num(span_id as f64)),
        ("parent_id", Json::Num(parent_id as f64)),
        ("name", Json::Str(name.to_string())),
        ("detail", Json::Str(detail.to_string())),
        ("start_us", Json::Num(start_us as f64)),
        ("end_us", Json::Num(end_us as f64)),
    ])
}

/// An owned span — what the CLI works with after parsing a `trace` op
/// response or an NDJSON dump.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRow {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent_id: u64,
    pub name: String,
    pub detail: String,
    pub start_us: u64,
    pub end_us: u64,
}

impl SpanRow {
    pub fn duration_us(&self) -> u64 {
        self.end_us.saturating_sub(self.start_us)
    }

    pub fn from_json(j: &Json) -> Option<SpanRow> {
        let num = |k: &str| j.get(&[k])?.as_f64().map(|v| v as u64);
        Some(SpanRow {
            trace_id: num("trace_id")?,
            span_id: num("span_id")?,
            parent_id: num("parent_id")?,
            name: j.get(&["name"])?.as_str()?.to_string(),
            detail: j
                .get(&["detail"])
                .and_then(|d| d.as_str())
                .unwrap_or_default()
                .to_string(),
            start_us: num("start_us")?,
            end_us: num("end_us")?,
        })
    }

    pub fn to_json(&self) -> Json {
        span_json(
            self.trace_id,
            self.span_id,
            self.parent_id,
            &self.name,
            &self.detail,
            self.start_us,
            self.end_us,
        )
    }
}

impl From<&SpanRecord> for SpanRow {
    fn from(rec: &SpanRecord) -> SpanRow {
        SpanRow {
            trace_id: rec.trace_id,
            span_id: rec.span_id,
            parent_id: rec.parent_id,
            name: rec.name.to_string(),
            detail: rec.detail.clone(),
            start_us: rec.start_us,
            end_us: rec.end_us,
        }
    }
}

/// Stable export order: by trace, then start time, then id.
pub fn sort_spans(spans: &mut [SpanRow]) {
    spans.sort_by(|a, b| {
        (a.trace_id, a.start_us, a.span_id).cmp(&(
            b.trace_id,
            b.start_us,
            b.span_id,
        ))
    });
}

/// One span object per line — the dump format `repro trace --out`
/// writes and `--in` reads back.
pub fn to_ndjson(spans: &[SpanRow]) -> String {
    let mut out = String::new();
    for s in spans {
        out.push_str(&s.to_json().to_string());
        out.push('\n');
    }
    out
}

/// Parse an NDJSON dump (blank lines skipped; unparseable lines are an
/// error naming the line number).
pub fn from_ndjson(text: &str) -> Result<Vec<SpanRow>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .map_err(|e| format!("line {}: {e}", i + 1))?;
        let row = SpanRow::from_json(&j)
            .ok_or_else(|| format!("line {}: not a span object", i + 1))?;
        out.push(row);
    }
    Ok(out)
}

/// Chrome trace-event JSON (the `chrome://tracing` / Perfetto "JSON
/// Array Format"): complete (`ph:"X"`) events, one virtual thread per
/// trace so concurrent requests stack side by side on the timeline.
pub fn to_chrome(spans: &[SpanRow]) -> Json {
    let events: Vec<Json> = spans
        .iter()
        .map(|s| {
            let label = if s.detail.is_empty() {
                s.name.clone()
            } else {
                format!("{} ({})", s.name, s.detail)
            };
            Json::obj(vec![
                ("name", Json::Str(label)),
                ("cat", Json::Str("offload".to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(s.start_us as f64)),
                ("dur", Json::Num(s.duration_us() as f64)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(s.trace_id as f64)),
                (
                    "args",
                    Json::obj(vec![
                        ("span_id", Json::Num(s.span_id as f64)),
                        (
                            "parent_id",
                            Json::Num(s.parent_id as f64),
                        ),
                    ]),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

fn fmt_us(us: u64) -> String {
    if us >= 10_000_000 {
        format!("{:.1}s", us as f64 / 1e6)
    } else if us >= 10_000 {
        format!("{:.1}ms", us as f64 / 1e3)
    } else {
        format!("{us}us")
    }
}

/// Indented tree of one trace's spans (children under parents, siblings
/// in start order) — what `repro trace --id N` prints.
pub fn render_tree(spans: &[SpanRow]) -> String {
    let mut sorted: Vec<&SpanRow> = spans.iter().collect();
    sorted.sort_by_key(|s| (s.start_us, s.span_id));
    let mut out = String::new();
    fn walk(
        out: &mut String,
        all: &[&SpanRow],
        parent: u64,
        depth: usize,
    ) {
        for s in all.iter().filter(|s| s.parent_id == parent) {
            let detail = if s.detail.is_empty() {
                String::new()
            } else {
                format!("  [{}]", s.detail)
            };
            out.push_str(&format!(
                "{:indent$}{} {} +{}{}\n",
                "",
                fmt_us(s.duration_us()),
                s.name,
                fmt_us(s.start_us),
                detail,
                indent = depth * 2,
            ));
            walk(out, all, s.span_id, depth + 1);
        }
    }
    walk(&mut out, &sorted, 0, 0);
    // Orphans (parent overwritten out of the ring) still show up, flat.
    let known: std::collections::BTreeSet<u64> =
        sorted.iter().map(|s| s.span_id).collect();
    for s in &sorted {
        if s.parent_id != 0 && !known.contains(&s.parent_id) {
            out.push_str(&format!(
                "{} {} +{}  [orphan of span {}]\n",
                fmt_us(s.duration_us()),
                s.name,
                fmt_us(s.start_us),
                s.parent_id,
            ));
        }
    }
    out
}

/// One summary line per trace (id, root name/detail, span count, root
/// duration) — what a bare `repro trace` prints.
pub fn render_summary(spans: &[SpanRow]) -> String {
    use std::collections::BTreeMap;
    let mut per: BTreeMap<u64, (Option<&SpanRow>, usize)> =
        BTreeMap::new();
    for s in spans {
        let e = per.entry(s.trace_id).or_insert((None, 0));
        e.1 += 1;
        if s.parent_id == 0 {
            e.0 = Some(s);
        }
    }
    let mut out = format!(
        "{:>8}  {:>10}  {:>6}  {:<14}  {}\n",
        "trace", "duration", "spans", "root", "detail"
    );
    for (id, (root, n)) in per {
        let (dur, name, detail) = match root {
            Some(r) => (
                fmt_us(r.duration_us()),
                r.name.as_str(),
                r.detail.as_str(),
            ),
            None => ("?".to_string(), "(root evicted)", ""),
        };
        out.push_str(&format!(
            "{id:>8}  {dur:>10}  {n:>6}  {name:<14}  {detail}\n"
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(
        trace: u64,
        span: u64,
        parent: u64,
        name: &str,
        start: u64,
        end: u64,
    ) -> SpanRow {
        SpanRow {
            trace_id: trace,
            span_id: span,
            parent_id: parent,
            name: name.to_string(),
            detail: String::new(),
            start_us: start,
            end_us: end,
        }
    }

    #[test]
    fn ndjson_round_trips() {
        let spans = vec![
            row(1, 1, 0, "request", 0, 100),
            row(1, 2, 1, "stage.parse", 5, 50),
        ];
        let text = to_ndjson(&spans);
        assert_eq!(text.lines().count(), 2);
        let back = from_ndjson(&text).unwrap();
        assert_eq!(back, spans);
    }

    #[test]
    fn ndjson_parse_errors_name_the_line() {
        let err = from_ndjson("{\"trace_id\":1}\nnot json\n")
            .unwrap_err();
        assert!(err.contains("line 1") || err.contains("line 2"), "{err}");
    }

    #[test]
    fn chrome_export_is_loadable_shape() {
        let spans = vec![
            row(1, 1, 0, "request", 0, 100),
            row(2, 1, 0, "request", 10, 60),
        ];
        let j = to_chrome(&spans);
        let events = j.get(&["traceEvents"]).unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        let e0 = &events[0];
        assert_eq!(e0.get(&["ph"]).unwrap().as_str(), Some("X"));
        assert_eq!(e0.get(&["ts"]).unwrap().as_f64(), Some(0.0));
        assert_eq!(e0.get(&["dur"]).unwrap().as_f64(), Some(100.0));
        // One virtual tid per trace.
        assert_eq!(e0.get(&["tid"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            events[1].get(&["tid"]).unwrap().as_f64(),
            Some(2.0)
        );
        // The whole document parses back (it is what --chrome writes).
        let text = j.to_string();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn tree_rendering_indents_children() {
        let spans = vec![
            row(1, 1, 0, "request", 0, 100),
            row(1, 2, 1, "admission", 1, 10),
            row(1, 3, 2, "store.read", 2, 8),
        ];
        let tree = render_tree(&spans);
        let lines: Vec<&str> = tree.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].contains("request"));
        assert!(lines[1].starts_with("  ") && lines[1].contains("admission"));
        assert!(
            lines[2].starts_with("    ")
                && lines[2].contains("store.read")
        );
    }

    #[test]
    fn summary_lists_each_trace_once() {
        let spans = vec![
            row(1, 1, 0, "request", 0, 100),
            row(1, 2, 1, "admission", 1, 10),
            row(2, 1, 0, "request", 0, 50),
        ];
        let s = render_summary(&spans);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3); // header + 2 traces
        assert!(lines[1].contains("100us"));
        assert!(lines[2].contains("50us"));
    }
}
