//! Lock-free log-linear histograms for latency recording.
//!
//! The layout is the HdrHistogram idea cut down to one tuning knob:
//! 64 linear sub-buckets per power-of-two octave. Values below 64 are
//! recorded exactly (one bucket per value); above that, a bucket spans
//! `2^(octave-6)` consecutive values, so the reported bound is never
//! more than 1/64 (~1.6%) above the true value. Recording is a single
//! relaxed `fetch_add` on a pre-sized atomic array — no locks, no
//! allocation, safe to hammer from every service thread at once — which
//! is what lets the hit path record latencies without the `Mutex<Ring>`
//! it used to take on every cached lookup.

use std::sync::atomic::{AtomicU64, Ordering};

/// log2 of the linear sub-buckets per octave.
const SUB_BITS: u32 = 6;
/// Linear sub-buckets per octave (and the exact-value range).
const SUB: usize = 1 << SUB_BITS;
/// Octaves above the exact range: values with their MSB at bit
/// `SUB_BITS..=63`.
const OCTAVES: usize = (64 - SUB_BITS) as usize;
/// Total buckets: the exact range plus `OCTAVES` octaves of `SUB`.
const NUM_BUCKETS: usize = SUB + OCTAVES * SUB;

/// Bucket index for a value (exact below [`SUB`], log-linear above).
fn bucket_of(v: u64) -> usize {
    if v < SUB as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros(); // >= SUB_BITS
    let octave = (msb - SUB_BITS) as usize;
    let sub = ((v >> (msb - SUB_BITS)) as usize) & (SUB - 1);
    SUB + octave * SUB + sub
}

/// Largest value bucket `i` covers — what quantiles report.
fn upper_of(i: usize) -> u64 {
    if i < SUB {
        return i as u64;
    }
    let octave = ((i - SUB) / SUB) as u32;
    let sub = ((i - SUB) % SUB) as u64;
    let width = 1u64 << octave;
    (SUB as u64 + sub)
        .checked_shl(octave)
        .map_or(u64::MAX, |lo| lo.saturating_add(width - 1))
}

/// A concurrent log-linear histogram of `u64` samples (typically
/// microseconds). All methods are lock-free; `record` is wait-free.
#[derive(Debug)]
pub struct LogHistogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        let buckets = (0..NUM_BUCKETS)
            .map(|_| AtomicU64::new(0))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        LogHistogram {
            buckets,
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample. Wait-free: three relaxed adds and a
    /// `fetch_max`.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn is_empty(&self) -> bool {
        self.count() == 0
    }

    /// Point-in-time copy of the non-empty buckets plus the scalar
    /// aggregates. Quantiles and the Prometheus exposition both work
    /// from this.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut counts = Vec::new();
        for (i, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                counts.push((upper_of(i), n));
            }
        }
        HistogramSnapshot {
            counts,
            count: self.count(),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }

    /// `(p50, p99, max)` — the shape [`crate::service::StatsSnapshot`]
    /// reports.
    pub fn quantiles(&self) -> (u64, u64, u64) {
        let snap = self.snapshot();
        (snap.quantile(0.50), snap.quantile(0.99), snap.max)
    }
}

/// A plain, comparable copy of a [`LogHistogram`] at one instant.
/// `counts` holds `(bucket_upper_bound, samples)` pairs for the
/// non-empty buckets, in ascending bound order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HistogramSnapshot {
    pub counts: Vec<(u64, u64)>,
    pub count: u64,
    pub sum: u64,
    pub max: u64,
}

impl HistogramSnapshot {
    /// Nearest-rank quantile (`q` in `[0, 1]`), reported as the bucket's
    /// upper bound and clamped to the exact observed max. Zero when
    /// empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank =
            ((self.count as f64 * q).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for &(upper, n) in &self.counts {
            seen += n;
            if seen >= rank {
                return upper.min(self.max);
            }
        }
        self.max
    }

    /// Mean of the recorded samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        let h = LogHistogram::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        let (p50, p99, max) = h.quantiles();
        // Everything below 128 sits in an exact (width-1) bucket.
        assert_eq!(p50, 50);
        assert_eq!(p99, 99);
        assert_eq!(max, 100);
        assert_eq!(h.count(), 100);
    }

    #[test]
    fn single_sample_round_trips() {
        let h = LogHistogram::new();
        h.record(5);
        assert_eq!(h.quantiles(), (5, 5, 5));
    }

    #[test]
    fn empty_reports_zero() {
        let h = LogHistogram::new();
        assert_eq!(h.quantiles(), (0, 0, 0));
        assert!(h.is_empty());
    }

    #[test]
    fn large_values_stay_within_relative_error() {
        let h = LogHistogram::new();
        for v in [1_000u64, 10_000, 100_000, 1_000_000, 123_456_789] {
            h.record(v);
        }
        let snap = h.snapshot();
        for &v in &[1_000u64, 10_000, 100_000, 1_000_000, 123_456_789] {
            let upper = upper_of(bucket_of(v));
            assert!(upper >= v, "bucket bound below sample: {upper} < {v}");
            let err = (upper - v) as f64 / v as f64;
            assert!(err <= 1.0 / 64.0, "relative error {err} for {v}");
        }
        assert_eq!(snap.max, 123_456_789);
        // p100 is clamped to the true max, not the bucket bound.
        assert_eq!(snap.quantile(1.0), 123_456_789);
    }

    #[test]
    fn bucket_bounds_are_monotonic_and_cover_u64() {
        let mut prev = 0u64;
        for i in 1..NUM_BUCKETS {
            let u = upper_of(i);
            assert!(u > prev, "bound not increasing at {i}");
            prev = u;
        }
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(63), 63);
        assert_eq!(bucket_of(64), 64);
        assert_eq!(upper_of(bucket_of(u64::MAX)), u64::MAX);
        assert!(bucket_of(u64::MAX) < NUM_BUCKETS);
    }

    #[test]
    fn concurrent_recording_loses_nothing() {
        let h = std::sync::Arc::new(LogHistogram::new());
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let h = std::sync::Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(t * 1_000 + (i % 97));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 80_000);
        let snap = h.snapshot();
        let total: u64 = snap.counts.iter().map(|&(_, n)| n).sum();
        assert_eq!(total, 80_000);
    }
}
