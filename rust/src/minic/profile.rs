//! Instruction-level profiling for the bytecode VM (§PGO).
//!
//! The PGO loop (ARCHITECTURE.md "VM + PGO loop") starts here: run the
//! bundled workloads under an [`OpProfiler`], read off the per-opcode
//! ranking and the per-adjacent-pair frequencies, and let the *measured*
//! numbers — not intuition — pick the dispatch layout of `vm.rs` and the
//! superinstruction peepholes of `resolve.rs`. `repro vmprofile` dumps
//! the same report from the CLI.
//!
//! Like [`crate::obs::Tracer`], the profiler is a handle the VM may or
//! may not carry: a non-profiled VM holds `None` and the hot loop pays
//! one predictable branch, nothing else — the differential and property
//! tests pin profiled and unprofiled runs to byte-identical results.
//!
//! Determinism rule: the profiler never reads a clock. Cycle figures in
//! the report come from a static per-opcode cost model ([`Op::weight`]),
//! so a report is a pure function of the executed instruction stream and
//! two runs (on any thread schedule) produce byte-identical reports.

use crate::util::json::Json;

use super::bytecode::Instr;

/// Payload-free mirror of [`Instr`] — the profiler's counter index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Op {
    ConstInt,
    ConstFloat,
    LoadLocal,
    StoreLocal,
    StoreLocalCoerce,
    LoadGlobal,
    StoreGlobal,
    CompoundLocal,
    CompoundGlobal,
    MacLocal,
    ZeroLocal,
    AllocLocalArray,
    LoadIndex,
    StoreIndex,
    Bin,
    Neg,
    Not,
    CastInt,
    CastFloat,
    BumpCmp,
    Jump,
    JumpIfFalse,
    AndCheck,
    OrCheck,
    ToBool,
    Pop,
    LoopEnter,
    LoopTrip,
    LoopExit,
    Call,
    Builtin1,
    Builtin2,
    Return,
    Trap,
    LoadIndexLocal,
    StoreIndexLocal,
    LoadIndexBin,
    BinConstInt,
    CompoundLocalConst,
    CmpConstJump,
    BinLocal,
}

/// Number of distinct opcodes (size of the counter vectors).
pub const N_OPS: usize = 41;

impl Op {
    /// Every opcode, in discriminant order.
    pub const ALL: [Op; N_OPS] = [
        Op::ConstInt,
        Op::ConstFloat,
        Op::LoadLocal,
        Op::StoreLocal,
        Op::StoreLocalCoerce,
        Op::LoadGlobal,
        Op::StoreGlobal,
        Op::CompoundLocal,
        Op::CompoundGlobal,
        Op::MacLocal,
        Op::ZeroLocal,
        Op::AllocLocalArray,
        Op::LoadIndex,
        Op::StoreIndex,
        Op::Bin,
        Op::Neg,
        Op::Not,
        Op::CastInt,
        Op::CastFloat,
        Op::BumpCmp,
        Op::Jump,
        Op::JumpIfFalse,
        Op::AndCheck,
        Op::OrCheck,
        Op::ToBool,
        Op::Pop,
        Op::LoopEnter,
        Op::LoopTrip,
        Op::LoopExit,
        Op::Call,
        Op::Builtin1,
        Op::Builtin2,
        Op::Return,
        Op::Trap,
        Op::LoadIndexLocal,
        Op::StoreIndexLocal,
        Op::LoadIndexBin,
        Op::BinConstInt,
        Op::CompoundLocalConst,
        Op::CmpConstJump,
        Op::BinLocal,
    ];

    /// The opcode of an instruction (payload dropped).
    #[inline]
    pub fn of(instr: &Instr) -> Op {
        match instr {
            Instr::ConstInt(_) => Op::ConstInt,
            Instr::ConstFloat(_) => Op::ConstFloat,
            Instr::LoadLocal(_) => Op::LoadLocal,
            Instr::StoreLocal(_) => Op::StoreLocal,
            Instr::StoreLocalCoerce(..) => Op::StoreLocalCoerce,
            Instr::LoadGlobal(_) => Op::LoadGlobal,
            Instr::StoreGlobal(_) => Op::StoreGlobal,
            Instr::CompoundLocal(..) => Op::CompoundLocal,
            Instr::CompoundGlobal(..) => Op::CompoundGlobal,
            Instr::MacLocal(_) => Op::MacLocal,
            Instr::ZeroLocal(..) => Op::ZeroLocal,
            Instr::AllocLocalArray { .. } => Op::AllocLocalArray,
            Instr::LoadIndex { .. } => Op::LoadIndex,
            Instr::StoreIndex { .. } => Op::StoreIndex,
            Instr::Bin(_) => Op::Bin,
            Instr::Neg => Op::Neg,
            Instr::Not => Op::Not,
            Instr::CastInt => Op::CastInt,
            Instr::CastFloat => Op::CastFloat,
            Instr::BumpCmp => Op::BumpCmp,
            Instr::Jump(_) => Op::Jump,
            Instr::JumpIfFalse(_) => Op::JumpIfFalse,
            Instr::AndCheck(_) => Op::AndCheck,
            Instr::OrCheck(_) => Op::OrCheck,
            Instr::ToBool => Op::ToBool,
            Instr::Pop => Op::Pop,
            Instr::LoopEnter(_) => Op::LoopEnter,
            Instr::LoopTrip(_) => Op::LoopTrip,
            Instr::LoopExit => Op::LoopExit,
            Instr::Call { .. } => Op::Call,
            Instr::Builtin1(_) => Op::Builtin1,
            Instr::Builtin2(_) => Op::Builtin2,
            Instr::Return => Op::Return,
            Instr::Trap(_) => Op::Trap,
            Instr::LoadIndexLocal { .. } => Op::LoadIndexLocal,
            Instr::StoreIndexLocal { .. } => Op::StoreIndexLocal,
            Instr::LoadIndexBin { .. } => Op::LoadIndexBin,
            Instr::BinConstInt(..) => Op::BinConstInt,
            Instr::CompoundLocalConst { .. } => Op::CompoundLocalConst,
            Instr::CmpConstJump { .. } => Op::CmpConstJump,
            Instr::BinLocal { .. } => Op::BinLocal,
        }
    }

    /// Mnemonic, as used in disassembly and reports.
    pub fn name(self) -> &'static str {
        match self {
            Op::ConstInt => "ConstInt",
            Op::ConstFloat => "ConstFloat",
            Op::LoadLocal => "LoadLocal",
            Op::StoreLocal => "StoreLocal",
            Op::StoreLocalCoerce => "StoreLocalCoerce",
            Op::LoadGlobal => "LoadGlobal",
            Op::StoreGlobal => "StoreGlobal",
            Op::CompoundLocal => "CompoundLocal",
            Op::CompoundGlobal => "CompoundGlobal",
            Op::MacLocal => "MacLocal",
            Op::ZeroLocal => "ZeroLocal",
            Op::AllocLocalArray => "AllocLocalArray",
            Op::LoadIndex => "LoadIndex",
            Op::StoreIndex => "StoreIndex",
            Op::Bin => "Bin",
            Op::Neg => "Neg",
            Op::Not => "Not",
            Op::CastInt => "CastInt",
            Op::CastFloat => "CastFloat",
            Op::BumpCmp => "BumpCmp",
            Op::Jump => "Jump",
            Op::JumpIfFalse => "JumpIfFalse",
            Op::AndCheck => "AndCheck",
            Op::OrCheck => "OrCheck",
            Op::ToBool => "ToBool",
            Op::Pop => "Pop",
            Op::LoopEnter => "LoopEnter",
            Op::LoopTrip => "LoopTrip",
            Op::LoopExit => "LoopExit",
            Op::Call => "Call",
            Op::Builtin1 => "Builtin1",
            Op::Builtin2 => "Builtin2",
            Op::Return => "Return",
            Op::Trap => "Trap",
            Op::LoadIndexLocal => "LoadIndexLocal",
            Op::StoreIndexLocal => "StoreIndexLocal",
            Op::LoadIndexBin => "LoadIndexBin",
            Op::BinConstInt => "BinConstInt",
            Op::CompoundLocalConst => "CompoundLocalConst",
            Op::CmpConstJump => "CmpConstJump",
            Op::BinLocal => "BinLocal",
        }
    }

    /// Static cost estimate per dispatch, in abstract cycles.
    ///
    /// Deliberately *not* a measurement (a clock would make reports
    /// schedule-dependent): a coarse model — stack/slot traffic ≈1,
    /// arithmetic ≈3, indexed access ≈6 (bounds check + footprint
    /// attribution), loop bookkeeping ≈4, calls ≈10, libm builtins ≈20 —
    /// that weights the ranking toward where the VM really spends time.
    pub fn weight(self) -> u64 {
        match self {
            Op::ConstInt
            | Op::ConstFloat
            | Op::LoadLocal
            | Op::StoreLocal
            | Op::StoreLocalCoerce
            | Op::LoadGlobal
            | Op::StoreGlobal
            | Op::ZeroLocal
            | Op::Pop
            | Op::Jump
            | Op::JumpIfFalse
            | Op::ToBool
            | Op::BumpCmp
            | Op::Trap => 1,
            Op::Bin
            | Op::BinConstInt
            | Op::BinLocal
            | Op::Neg
            | Op::Not
            | Op::CastInt
            | Op::CastFloat
            | Op::AndCheck
            | Op::OrCheck
            | Op::CmpConstJump
            | Op::CompoundLocal
            | Op::CompoundGlobal
            | Op::CompoundLocalConst => 3,
            Op::MacLocal => 5,
            Op::LoadIndex
            | Op::StoreIndex
            | Op::LoadIndexLocal
            | Op::StoreIndexLocal => 6,
            Op::LoadIndexBin => 7,
            Op::LoopEnter | Op::LoopTrip | Op::LoopExit => 4,
            Op::Call | Op::Return => 10,
            Op::AllocLocalArray => 20,
            Op::Builtin1 | Op::Builtin2 => 20,
        }
    }
}

/// The superinstruction an adjacent `(prev, next)` pair fuses into, if
/// the `resolve.rs` peepholes cover it. This is the discovery table the
/// pair report annotates: a hot *unannotated* pair is a fusion
/// candidate; a hot *annotated* pair measured on the baseline encoding
/// is the justification for the peephole that removes it.
pub fn fused_by(prev: Op, next: Op) -> Option<&'static str> {
    Some(match (prev, next) {
        (Op::LoadLocal, Op::LoadIndex) => "LoadIndexLocal",
        (Op::LoadLocal, Op::StoreIndex) => "StoreIndexLocal",
        (Op::LoadIndex, Op::Bin) => "LoadIndexBin",
        (Op::ConstInt, Op::Bin) => "BinConstInt",
        (Op::ConstInt, Op::CompoundLocal) => "CompoundLocalConst",
        (Op::BinConstInt, Op::JumpIfFalse) => "CmpConstJump",
        (Op::Bin, Op::CompoundLocal) => "MacLocal",
        (Op::LoadLocal, Op::Bin) => "BinLocal (vm-regs)",
        _ => return None,
    })
}

/// Per-opcode and per-adjacent-pair dispatch counters.
///
/// `record` is the only hot-path entry point: one counter bump, one
/// pair-matrix bump, no allocation, no clock. Everything else
/// (ranking, cycle estimates, JSON) happens at report time.
#[derive(Debug, Clone)]
pub struct OpProfiler {
    counts: Vec<u64>,
    /// Row-major `N_OPS × N_OPS` matrix: `pairs[prev * N_OPS + next]`.
    pairs: Vec<u64>,
    /// Previously recorded opcode index; `N_OPS` = none yet.
    prev: usize,
    dispatches: u64,
}

impl Default for OpProfiler {
    fn default() -> Self {
        Self::new()
    }
}

impl OpProfiler {
    pub fn new() -> Self {
        OpProfiler {
            counts: vec![0; N_OPS],
            pairs: vec![0; N_OPS * N_OPS],
            prev: N_OPS,
            dispatches: 0,
        }
    }

    /// Record one dispatched instruction.
    #[inline]
    pub fn record(&mut self, op: Op) {
        let i = op as usize;
        self.counts[i] += 1;
        self.dispatches += 1;
        if self.prev < N_OPS {
            self.pairs[self.prev * N_OPS + i] += 1;
        }
        self.prev = i;
    }

    /// Total instructions recorded (== the VM's dispatch count).
    pub fn dispatches(&self) -> u64 {
        self.dispatches
    }

    /// Dispatches of one opcode.
    pub fn count(&self, op: Op) -> u64 {
        self.counts[op as usize]
    }

    /// Dispatches of `next` immediately after `prev`.
    pub fn pair(&self, prev: Op, next: Op) -> u64 {
        self.pairs[prev as usize * N_OPS + next as usize]
    }

    /// Sum over the pair matrix (== `dispatches - 1` for any non-empty
    /// single profiler, since only the first record has no predecessor).
    pub fn pair_total(&self) -> u64 {
        self.pairs.iter().sum()
    }

    /// Build the ranked report. `top_pairs` bounds the pair list (the
    /// full matrix is mostly zeros); opcode rows with zero count are
    /// dropped. Ordering is count-descending, ties broken by opcode
    /// index, so the report is deterministic.
    pub fn report(&self, top_pairs: usize) -> OpReport {
        let mut ops: Vec<OpStat> = Op::ALL
            .iter()
            .filter(|op| self.count(**op) > 0)
            .map(|op| OpStat {
                op: *op,
                count: self.count(*op),
                est_cycles: self.count(*op) * op.weight(),
            })
            .collect();
        ops.sort_by(|a, b| {
            b.count
                .cmp(&a.count)
                .then((a.op as usize).cmp(&(b.op as usize)))
        });

        let mut pairs: Vec<PairStat> = Vec::new();
        for prev in Op::ALL {
            for next in Op::ALL {
                let count = self.pair(prev, next);
                if count > 0 {
                    pairs.push(PairStat {
                        prev,
                        next,
                        count,
                        fused_as: fused_by(prev, next),
                    });
                }
            }
        }
        pairs.sort_by(|a, b| {
            b.count.cmp(&a.count).then(
                (a.prev as usize, a.next as usize)
                    .cmp(&(b.prev as usize, b.next as usize)),
            )
        });
        pairs.truncate(top_pairs);

        OpReport {
            dispatches: self.dispatches,
            est_cycles: ops.iter().map(|s| s.est_cycles).sum(),
            ops,
            pairs,
        }
    }
}

/// One ranked opcode row.
#[derive(Debug, Clone)]
pub struct OpStat {
    pub op: Op,
    pub count: u64,
    /// `count × weight` under the static cost model.
    pub est_cycles: u64,
}

/// One ranked adjacent-pair row.
#[derive(Debug, Clone)]
pub struct PairStat {
    pub prev: Op,
    pub next: Op,
    pub count: u64,
    /// Superinstruction that fuses this pair, if a peephole exists.
    pub fused_as: Option<&'static str>,
}

/// Deterministic, rendered view of one profiled run.
#[derive(Debug, Clone)]
pub struct OpReport {
    pub dispatches: u64,
    /// Total estimated cycles under the static model.
    pub est_cycles: u64,
    /// Opcodes by descending count (zero rows dropped).
    pub ops: Vec<OpStat>,
    /// Hottest adjacent pairs by descending count.
    pub pairs: Vec<PairStat>,
}

impl OpReport {
    /// JSON form (stable key order via the `Json` object's `BTreeMap`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dispatches", Json::Num(self.dispatches as f64)),
            ("est_cycles", Json::Num(self.est_cycles as f64)),
            (
                "ops",
                Json::Arr(
                    self.ops
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("op", Json::Str(s.op.name().into())),
                                ("count", Json::Num(s.count as f64)),
                                (
                                    "est_cycles",
                                    Json::Num(s.est_cycles as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "pairs",
                Json::Arr(
                    self.pairs
                        .iter()
                        .map(|p| {
                            Json::obj(vec![
                                ("prev", Json::Str(p.prev.name().into())),
                                ("next", Json::Str(p.next.name().into())),
                                ("count", Json::Num(p.count as f64)),
                                (
                                    "fused_as",
                                    match p.fused_as {
                                        Some(n) => Json::Str(n.into()),
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Human-readable table (the `repro vmprofile` text output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "dispatches {}   est cycles {}\n",
            self.dispatches, self.est_cycles
        ));
        out.push_str("  rank  opcode               count      share  est.cycles\n");
        for (i, s) in self.ops.iter().enumerate() {
            let share = if self.dispatches == 0 {
                0.0
            } else {
                100.0 * s.count as f64 / self.dispatches as f64
            };
            out.push_str(&format!(
                "  {:>4}  {:<20} {:>9}  {:>8.2}%  {:>10}\n",
                i + 1,
                s.op.name(),
                s.count,
                share,
                s.est_cycles
            ));
        }
        if !self.pairs.is_empty() {
            out.push_str("  top adjacent pairs:\n");
            for (i, p) in self.pairs.iter().enumerate() {
                out.push_str(&format!(
                    "  {:>4}  {} -> {}  x{}{}\n",
                    i + 1,
                    p.prev.name(),
                    p.next.name(),
                    p.count,
                    match p.fused_as {
                        Some(n) => format!("   [fused as {n}]"),
                        None => String::new(),
                    }
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_covers_every_discriminant_in_order() {
        assert_eq!(Op::ALL.len(), N_OPS);
        for (i, op) in Op::ALL.iter().enumerate() {
            assert_eq!(*op as usize, i, "{op:?} out of order");
        }
    }

    #[test]
    fn record_counts_and_pairs() {
        let mut p = OpProfiler::new();
        p.record(Op::LoadLocal);
        p.record(Op::LoadIndex);
        p.record(Op::Bin);
        p.record(Op::LoadIndex);
        assert_eq!(p.dispatches(), 4);
        assert_eq!(p.count(Op::LoadIndex), 2);
        assert_eq!(p.pair(Op::LoadLocal, Op::LoadIndex), 1);
        assert_eq!(p.pair(Op::LoadIndex, Op::Bin), 1);
        assert_eq!(p.pair(Op::Bin, Op::LoadIndex), 1);
        assert_eq!(p.pair_total(), p.dispatches() - 1);
    }

    #[test]
    fn report_ranks_by_count_and_annotates_fusions() {
        let mut p = OpProfiler::new();
        for _ in 0..3 {
            p.record(Op::LoadLocal);
            p.record(Op::LoadIndex);
        }
        p.record(Op::Bin);
        let r = p.report(8);
        assert_eq!(r.dispatches, 7);
        assert_eq!(r.ops[0].count, 3);
        let hot = &r.pairs[0];
        assert_eq!((hot.prev, hot.next), (Op::LoadLocal, Op::LoadIndex));
        assert_eq!(hot.fused_as, Some("LoadIndexLocal"));
        // 3×LoadLocal(1) + 3×LoadIndex(6) + 1×Bin(3)
        assert_eq!(r.est_cycles, 3 + 18 + 3);
    }

    #[test]
    fn report_is_deterministic_and_serializes() {
        let mut a = OpProfiler::new();
        let mut b = OpProfiler::new();
        for p in [&mut a, &mut b] {
            for _ in 0..5 {
                p.record(Op::ConstInt);
                p.record(Op::Bin);
                p.record(Op::JumpIfFalse);
            }
        }
        let ja = a.report(16).to_json().to_string();
        let jb = b.report(16).to_json().to_string();
        assert_eq!(ja, jb);
        assert!(ja.contains("\"fused_as\":\"BinConstInt\""), "{ja}");
        let parsed = Json::parse(&ja).unwrap();
        assert_eq!(parsed.to_string(), ja);
    }

    #[test]
    fn fusion_table_matches_the_emitted_peepholes() {
        assert_eq!(fused_by(Op::LoadIndex, Op::Bin), Some("LoadIndexBin"));
        assert_eq!(
            fused_by(Op::ConstInt, Op::CompoundLocal),
            Some("CompoundLocalConst")
        );
        assert_eq!(
            fused_by(Op::BinConstInt, Op::JumpIfFalse),
            Some("CmpConstJump")
        );
        assert_eq!(fused_by(Op::Bin, Op::CompoundLocal), Some("MacLocal"));
        assert_eq!(fused_by(Op::Jump, Op::Jump), None);
    }

    #[test]
    fn render_mentions_the_hot_opcode() {
        let mut p = OpProfiler::new();
        p.record(Op::MacLocal);
        let text = p.report(4).render();
        assert!(text.contains("MacLocal"), "{text}");
        assert!(text.contains("dispatches 1"), "{text}");
    }
}
