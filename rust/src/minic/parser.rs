//! Recursive-descent parser for the MiniC subset.
//!
//! Grammar (informal):
//! ```text
//! program   := (define | function | global-decl)*
//! define    := '#define' IDENT (INT | FLOAT)
//! function  := type IDENT '(' params? ')' block
//! decl      := type declarator ('=' expr)? ';'
//! stmt      := decl | assign ';' | if | for | while | return ';'
//!            | call ';' | block
//! for       := 'for' '(' (decl | assign)? ';' expr? ';' assign? ')' body
//! ```
//! Array dimensions must be constant expressions over `#define`s and
//! integer literals. Loop ids are assigned in source order — the stable
//! identity the offload pipeline keys on.

use super::ast::*;
use super::lexer::Lexer;
use super::token::{Token, TokenKind};
use super::MiniCError;

/// Parse a full translation unit.
pub fn parse(src: &str) -> Result<Program, MiniCError> {
    let tokens = Lexer::new(src).tokenize()?;
    Parser {
        tokens,
        pos: 0,
        defines: Vec::new(),
        next_loop: 0,
    }
    .program()
}

struct Parser {
    tokens: Vec<Token>,
    pos: usize,
    defines: Vec<(String, f64)>,
    next_loop: u32,
}

impl Parser {
    fn peek(&self) -> &TokenKind {
        &self.tokens[self.pos].kind
    }

    fn peek2(&self) -> &TokenKind {
        &self.tokens[(self.pos + 1).min(self.tokens.len() - 1)].kind
    }

    fn line(&self) -> u32 {
        self.tokens[self.pos].line
    }

    fn bump(&mut self) -> TokenKind {
        let k = self.tokens[self.pos].kind.clone();
        if self.pos + 1 < self.tokens.len() {
            self.pos += 1;
        }
        k
    }

    fn err(&self, msg: impl Into<String>) -> MiniCError {
        let t = &self.tokens[self.pos];
        MiniCError::Parse {
            line: t.line,
            col: t.col,
            msg: format!("{} (found {})", msg.into(), t.kind),
        }
    }

    fn expect(&mut self, kind: TokenKind) -> Result<(), MiniCError> {
        if *self.peek() == kind {
            self.bump();
            Ok(())
        } else {
            Err(self.err(format!("expected {kind}")))
        }
    }

    fn accept(&mut self, kind: TokenKind) -> bool {
        if *self.peek() == kind {
            self.bump();
            true
        } else {
            false
        }
    }

    // ---- top level ----

    fn program(mut self) -> Result<Program, MiniCError> {
        let mut prog = Program::default();
        loop {
            match self.peek() {
                TokenKind::Eof => break,
                TokenKind::KwDefine => {
                    let (name, val) = self.define()?;
                    self.defines.push((name.clone(), val));
                    prog.defines.push((name, val));
                }
                _ => {
                    let item = self.function_or_global()?;
                    match item {
                        Item::Func(f) => prog.functions.push(f),
                        Item::Global(s) => prog.globals.push(s),
                    }
                }
            }
        }
        prog.loop_count = self.next_loop;
        Ok(prog)
    }

    fn define(&mut self) -> Result<(String, f64), MiniCError> {
        self.expect(TokenKind::KwDefine)?;
        let name = self.ident()?;
        let neg = self.accept(TokenKind::Minus);
        let val = match self.bump() {
            TokenKind::IntLit(v) => v as f64,
            TokenKind::FloatLit(v) => v,
            _ => return Err(self.err("expected numeric #define value")),
        };
        Ok((name, if neg { -val } else { val }))
    }

    fn ident(&mut self) -> Result<String, MiniCError> {
        match self.peek().clone() {
            TokenKind::Ident(s) => {
                self.bump();
                Ok(s)
            }
            _ => Err(self.err("expected identifier")),
        }
    }

    fn scalar_type(&mut self) -> Result<Scalar, MiniCError> {
        self.accept(TokenKind::KwConst);
        let s = match self.peek() {
            TokenKind::KwInt => Scalar::Int,
            TokenKind::KwFloat => Scalar::Float,
            TokenKind::KwDouble => Scalar::Double,
            TokenKind::KwVoid => Scalar::Void,
            _ => return Err(self.err("expected type")),
        };
        self.bump();
        Ok(s)
    }

    fn starts_type(&self) -> bool {
        matches!(
            self.peek(),
            TokenKind::KwInt
                | TokenKind::KwFloat
                | TokenKind::KwDouble
                | TokenKind::KwVoid
                | TokenKind::KwConst
        )
    }

    fn function_or_global(&mut self) -> Result<Item, MiniCError> {
        let line = self.line();
        let scalar = self.scalar_type()?;
        let is_ptr = self.accept(TokenKind::Star);
        let name = self.ident()?;
        if *self.peek() == TokenKind::LParen {
            if is_ptr {
                return Err(self.err("pointer return types unsupported"));
            }
            let f = self.function_rest(scalar, name, line)?;
            Ok(Item::Func(f))
        } else {
            let stmt = self.decl_rest(scalar, is_ptr, name, line)?;
            self.expect(TokenKind::Semi)?;
            Ok(Item::Global(stmt))
        }
    }

    fn function_rest(
        &mut self,
        ret: Scalar,
        name: String,
        line: u32,
    ) -> Result<Function, MiniCError> {
        self.expect(TokenKind::LParen)?;
        let mut params = Vec::new();
        if !self.accept(TokenKind::RParen) {
            loop {
                if *self.peek() == TokenKind::KwVoid
                    && *self.peek2() == TokenKind::RParen
                {
                    self.bump(); // `(void)`
                    break;
                }
                let scalar = self.scalar_type()?;
                let is_ptr = self.accept(TokenKind::Star);
                let pname = self.ident()?;
                let ty = if is_ptr {
                    Type::Ptr(scalar)
                } else if *self.peek() == TokenKind::LBracket {
                    // `float a[N]` parameter — dims must be constant.
                    let dims = self.array_dims()?;
                    Type::Array(scalar, dims)
                } else {
                    Type::Scalar(scalar)
                };
                params.push(Param { name: pname, ty });
                if !self.accept(TokenKind::Comma) {
                    break;
                }
            }
            self.expect(TokenKind::RParen)?;
        }
        let body = self.block()?;
        Ok(Function {
            name,
            ret,
            params,
            body,
            line,
        })
    }

    fn array_dims(&mut self) -> Result<Vec<usize>, MiniCError> {
        let mut dims = Vec::new();
        while self.accept(TokenKind::LBracket) {
            let d = self.const_index_expr()?;
            dims.push(d);
            self.expect(TokenKind::RBracket)?;
        }
        Ok(dims)
    }

    /// Constant expression inside array brackets: INT, `#define` name, or
    /// products/sums of those.
    fn const_index_expr(&mut self) -> Result<usize, MiniCError> {
        let mut acc = self.const_atom()?;
        loop {
            if self.accept(TokenKind::Star) {
                acc *= self.const_atom()?;
            } else if self.accept(TokenKind::Plus) {
                acc += self.const_atom()?;
            } else if self.accept(TokenKind::Minus) {
                let rhs = self.const_atom()?;
                acc = acc.checked_sub(rhs).ok_or_else(|| {
                    self.err("negative array dimension")
                })?;
            } else {
                return Ok(acc);
            }
        }
    }

    fn const_atom(&mut self) -> Result<usize, MiniCError> {
        match self.peek().clone() {
            TokenKind::IntLit(v) if v >= 0 => {
                self.bump();
                Ok(v as usize)
            }
            TokenKind::Ident(name) => {
                let val = self
                    .defines
                    .iter()
                    .rev()
                    .find(|(n, _)| *n == name)
                    .map(|(_, v)| *v)
                    .ok_or_else(|| {
                        self.err(format!(
                            "array dimension `{name}` is not a #define"
                        ))
                    })?;
                self.bump();
                if val < 0.0 || val.fract() != 0.0 {
                    return Err(self.err(format!(
                        "#define {name} = {val} is not a valid dimension"
                    )));
                }
                Ok(val as usize)
            }
            _ => Err(self.err("expected constant array dimension")),
        }
    }

    fn decl_rest(
        &mut self,
        scalar: Scalar,
        is_ptr: bool,
        name: String,
        line: u32,
    ) -> Result<Stmt, MiniCError> {
        let ty = if is_ptr {
            Type::Ptr(scalar)
        } else if *self.peek() == TokenKind::LBracket {
            Type::Array(scalar, self.array_dims()?)
        } else {
            Type::Scalar(scalar)
        };
        let init = if self.accept(TokenKind::Assign) {
            Some(self.expr()?)
        } else {
            None
        };
        Ok(Stmt::Decl {
            name,
            ty,
            init,
            line,
        })
    }

    // ---- statements ----

    fn block(&mut self) -> Result<Vec<Stmt>, MiniCError> {
        self.expect(TokenKind::LBrace)?;
        let mut stmts = Vec::new();
        while !self.accept(TokenKind::RBrace) {
            if *self.peek() == TokenKind::Eof {
                return Err(self.err("unterminated block"));
            }
            stmts.push(self.stmt()?);
        }
        Ok(stmts)
    }

    /// A statement or single-statement body (for `if (c) x = 1;`).
    fn body(&mut self) -> Result<Vec<Stmt>, MiniCError> {
        if *self.peek() == TokenKind::LBrace {
            self.block()
        } else {
            Ok(vec![self.stmt()?])
        }
    }

    fn stmt(&mut self) -> Result<Stmt, MiniCError> {
        let line = self.line();
        match self.peek() {
            TokenKind::KwIf => self.if_stmt(),
            TokenKind::KwFor => self.for_stmt(),
            TokenKind::KwWhile => self.while_stmt(),
            TokenKind::KwReturn => {
                self.bump();
                let value = if *self.peek() == TokenKind::Semi {
                    None
                } else {
                    Some(self.expr()?)
                };
                self.expect(TokenKind::Semi)?;
                Ok(Stmt::Return { value, line })
            }
            _ if self.starts_type() => {
                let scalar = self.scalar_type()?;
                let is_ptr = self.accept(TokenKind::Star);
                let name = self.ident()?;
                let s = self.decl_rest(scalar, is_ptr, name, line)?;
                self.expect(TokenKind::Semi)?;
                Ok(s)
            }
            _ => {
                let s = self.simple_stmt()?;
                self.expect(TokenKind::Semi)?;
                Ok(s)
            }
        }
    }

    /// Assignment, inc/dec, or bare call — no trailing `;` (shared between
    /// statement position and `for` headers).
    fn simple_stmt(&mut self) -> Result<Stmt, MiniCError> {
        let line = self.line();
        let name = self.ident()?;

        // Call statement.
        if *self.peek() == TokenKind::LParen {
            let args = self.call_args()?;
            return Ok(Stmt::ExprStmt {
                expr: Expr::Call { name, args },
                line,
            });
        }

        // Optional index part of the lvalue.
        let target = if *self.peek() == TokenKind::LBracket {
            let mut indices = Vec::new();
            while self.accept(TokenKind::LBracket) {
                indices.push(self.expr()?);
                self.expect(TokenKind::RBracket)?;
            }
            LValue::Index { base: name, indices }
        } else {
            LValue::Var(name)
        };

        use TokenKind::*;
        let (op, value) = match self.peek().clone() {
            Assign => {
                self.bump();
                (AssignOp::Set, self.expr()?)
            }
            PlusAssign => {
                self.bump();
                (AssignOp::AddSet, self.expr()?)
            }
            MinusAssign => {
                self.bump();
                (AssignOp::SubSet, self.expr()?)
            }
            StarAssign => {
                self.bump();
                (AssignOp::MulSet, self.expr()?)
            }
            SlashAssign => {
                self.bump();
                (AssignOp::DivSet, self.expr()?)
            }
            PlusPlus => {
                self.bump();
                (AssignOp::AddSet, Expr::IntLit(1))
            }
            MinusMinus => {
                self.bump();
                (AssignOp::SubSet, Expr::IntLit(1))
            }
            _ => return Err(self.err("expected assignment operator")),
        };
        Ok(Stmt::Assign {
            target,
            op,
            value,
            line,
        })
    }

    fn if_stmt(&mut self) -> Result<Stmt, MiniCError> {
        let line = self.line();
        self.expect(TokenKind::KwIf)?;
        self.expect(TokenKind::LParen)?;
        let cond = self.expr()?;
        self.expect(TokenKind::RParen)?;
        let then_branch = self.body()?;
        let else_branch = if self.accept(TokenKind::KwElse) {
            if *self.peek() == TokenKind::KwIf {
                vec![self.if_stmt()?]
            } else {
                self.body()?
            }
        } else {
            Vec::new()
        };
        Ok(Stmt::If {
            cond,
            then_branch,
            else_branch,
            line,
        })
    }

    fn for_stmt(&mut self) -> Result<Stmt, MiniCError> {
        let line = self.line();
        let id = LoopId(self.next_loop);
        self.next_loop += 1;
        self.expect(TokenKind::KwFor)?;
        self.expect(TokenKind::LParen)?;

        let init = if *self.peek() == TokenKind::Semi {
            None
        } else if self.starts_type() {
            let dline = self.line();
            let scalar = self.scalar_type()?;
            let name = self.ident()?;
            let s = self.decl_rest(scalar, false, name, dline)?;
            Some(Box::new(s))
        } else {
            Some(Box::new(self.simple_stmt()?))
        };
        self.expect(TokenKind::Semi)?;

        let cond = if *self.peek() == TokenKind::Semi {
            None
        } else {
            Some(self.expr()?)
        };
        self.expect(TokenKind::Semi)?;

        let step = if *self.peek() == TokenKind::RParen {
            None
        } else {
            Some(Box::new(self.simple_stmt()?))
        };
        self.expect(TokenKind::RParen)?;

        let body = self.body()?;
        Ok(Stmt::For {
            id,
            init,
            cond,
            step,
            body,
            line,
        })
    }

    fn while_stmt(&mut self) -> Result<Stmt, MiniCError> {
        let line = self.line();
        let id = LoopId(self.next_loop);
        self.next_loop += 1;
        self.expect(TokenKind::KwWhile)?;
        self.expect(TokenKind::LParen)?;
        let cond = self.expr()?;
        self.expect(TokenKind::RParen)?;
        let body = self.body()?;
        Ok(Stmt::While { id, cond, body, line })
    }

    // ---- expressions (precedence climbing) ----

    fn expr(&mut self) -> Result<Expr, MiniCError> {
        self.or_expr()
    }

    fn or_expr(&mut self) -> Result<Expr, MiniCError> {
        let mut lhs = self.and_expr()?;
        while self.accept(TokenKind::OrOr) {
            let rhs = self.and_expr()?;
            lhs = Expr::Bin {
                op: BinOp::Or,
                lhs: Box::new(lhs),
                rhs: Box::new(rhs),
            };
        }
        Ok(lhs)
    }

    fn and_expr(&mut self) -> Result<Expr, MiniCError> {
        let mut lhs = self.equality()?;
        while self.accept(TokenKind::AndAnd) {
            let rhs = self.equality()?;
            lhs = Expr::Bin {
                op: BinOp::And,
                lhs: Box::new(lhs),
                rhs: Box::new(rhs),
            };
        }
        Ok(lhs)
    }

    fn equality(&mut self) -> Result<Expr, MiniCError> {
        let mut lhs = self.relational()?;
        loop {
            let op = match self.peek() {
                TokenKind::Eq => BinOp::Eq,
                TokenKind::Ne => BinOp::Ne,
                _ => return Ok(lhs),
            };
            self.bump();
            let rhs = self.relational()?;
            lhs = Expr::Bin {
                op,
                lhs: Box::new(lhs),
                rhs: Box::new(rhs),
            };
        }
    }

    fn relational(&mut self) -> Result<Expr, MiniCError> {
        let mut lhs = self.additive()?;
        loop {
            let op = match self.peek() {
                TokenKind::Lt => BinOp::Lt,
                TokenKind::Gt => BinOp::Gt,
                TokenKind::Le => BinOp::Le,
                TokenKind::Ge => BinOp::Ge,
                _ => return Ok(lhs),
            };
            self.bump();
            let rhs = self.additive()?;
            lhs = Expr::Bin {
                op,
                lhs: Box::new(lhs),
                rhs: Box::new(rhs),
            };
        }
    }

    fn additive(&mut self) -> Result<Expr, MiniCError> {
        let mut lhs = self.multiplicative()?;
        loop {
            let op = match self.peek() {
                TokenKind::Plus => BinOp::Add,
                TokenKind::Minus => BinOp::Sub,
                _ => return Ok(lhs),
            };
            self.bump();
            let rhs = self.multiplicative()?;
            lhs = Expr::Bin {
                op,
                lhs: Box::new(lhs),
                rhs: Box::new(rhs),
            };
        }
    }

    fn multiplicative(&mut self) -> Result<Expr, MiniCError> {
        let mut lhs = self.unary()?;
        loop {
            let op = match self.peek() {
                TokenKind::Star => BinOp::Mul,
                TokenKind::Slash => BinOp::Div,
                TokenKind::Percent => BinOp::Rem,
                _ => return Ok(lhs),
            };
            self.bump();
            let rhs = self.unary()?;
            lhs = Expr::Bin {
                op,
                lhs: Box::new(lhs),
                rhs: Box::new(rhs),
            };
        }
    }

    fn unary(&mut self) -> Result<Expr, MiniCError> {
        match self.peek() {
            TokenKind::Minus => {
                self.bump();
                Ok(Expr::Un {
                    op: UnOp::Neg,
                    operand: Box::new(self.unary()?),
                })
            }
            TokenKind::Not => {
                self.bump();
                Ok(Expr::Un {
                    op: UnOp::Not,
                    operand: Box::new(self.unary()?),
                })
            }
            // `(float) expr` cast vs parenthesized expression.
            TokenKind::LParen
                if matches!(
                    self.peek2(),
                    TokenKind::KwInt
                        | TokenKind::KwFloat
                        | TokenKind::KwDouble
                ) =>
            {
                self.bump(); // (
                let to = self.scalar_type()?;
                self.expect(TokenKind::RParen)?;
                Ok(Expr::Cast {
                    to,
                    operand: Box::new(self.unary()?),
                })
            }
            _ => self.postfix(),
        }
    }

    fn postfix(&mut self) -> Result<Expr, MiniCError> {
        match self.peek().clone() {
            TokenKind::IntLit(v) => {
                self.bump();
                Ok(Expr::IntLit(v))
            }
            TokenKind::FloatLit(v) => {
                self.bump();
                Ok(Expr::FloatLit(v))
            }
            TokenKind::StrLit(s) => {
                self.bump();
                Ok(Expr::StrLit(s))
            }
            TokenKind::LParen => {
                self.bump();
                let e = self.expr()?;
                self.expect(TokenKind::RParen)?;
                Ok(e)
            }
            TokenKind::Ident(name) => {
                self.bump();
                if *self.peek() == TokenKind::LParen {
                    let args = self.call_args()?;
                    Ok(Expr::Call { name, args })
                } else if *self.peek() == TokenKind::LBracket {
                    let mut indices = Vec::new();
                    while self.accept(TokenKind::LBracket) {
                        indices.push(self.expr()?);
                        self.expect(TokenKind::RBracket)?;
                    }
                    Ok(Expr::Index {
                        base: name,
                        indices,
                    })
                } else {
                    Ok(Expr::Var(name))
                }
            }
            _ => Err(self.err("expected expression")),
        }
    }

    fn call_args(&mut self) -> Result<Vec<Expr>, MiniCError> {
        self.expect(TokenKind::LParen)?;
        let mut args = Vec::new();
        if self.accept(TokenKind::RParen) {
            return Ok(args);
        }
        loop {
            args.push(self.expr()?);
            if !self.accept(TokenKind::Comma) {
                break;
            }
        }
        self.expect(TokenKind::RParen)?;
        Ok(args)
    }
}

enum Item {
    Func(Function),
    Global(Stmt),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_minimal_function() {
        let p = parse("int main() { return 0; }").unwrap();
        assert_eq!(p.functions.len(), 1);
        assert_eq!(p.functions[0].name, "main");
        assert_eq!(p.loop_count, 0);
    }

    #[test]
    fn parse_defines_and_dims() {
        let p = parse(
            "#define N 8\n#define M 4\nfloat a[N][M];\nint main() { return 0; }",
        )
        .unwrap();
        assert_eq!(p.define("N"), Some(8.0));
        match &p.globals[0] {
            Stmt::Decl { ty, .. } => {
                assert_eq!(*ty, Type::Array(Scalar::Float, vec![8, 4]))
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_dim_arithmetic() {
        let p = parse("#define N 8\nfloat a[N*2+1];\nint main(){return 0;}")
            .unwrap();
        match &p.globals[0] {
            Stmt::Decl { ty, .. } => {
                assert_eq!(*ty, Type::Array(Scalar::Float, vec![17]))
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_for_loops_get_ids_in_source_order() {
        let src = "
            void f() {
                for (int i = 0; i < 4; i++) {
                    for (int j = 0; j < 4; j++) { }
                }
                while (1) { }
            }";
        let p = parse(src).unwrap();
        assert_eq!(p.loop_count, 3);
        let mut ids = Vec::new();
        p.walk_stmts(&mut |s| match s {
            Stmt::For { id, .. } | Stmt::While { id, .. } => ids.push(id.0),
            _ => {}
        });
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn parse_precedence() {
        let p = parse("int main() { int x = 1 + 2 * 3; return x; }").unwrap();
        match &p.functions[0].body[0] {
            Stmt::Decl {
                init: Some(Expr::Bin { op: BinOp::Add, rhs, .. }),
                ..
            } => match rhs.as_ref() {
                Expr::Bin { op: BinOp::Mul, .. } => {}
                other => panic!("rhs {other:?}"),
            },
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_compound_assign_and_incdec() {
        let src = "void f() { int i = 0; i += 2; i--; }";
        let p = parse(src).unwrap();
        assert_eq!(p.functions[0].body.len(), 3);
    }

    #[test]
    fn parse_array_indexing_2d() {
        let src = "#define N 4\nfloat a[N][N];\nvoid f() { a[1][2] = a[2][1] + 1.0; }";
        let p = parse(src).unwrap();
        match &p.functions[0].body[0] {
            Stmt::Assign {
                target: LValue::Index { base, indices },
                ..
            } => {
                assert_eq!(base, "a");
                assert_eq!(indices.len(), 2);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_call_and_builtin() {
        let src = "void f(float *x) { x[0] = sin(x[1]) + cos(0.5); }";
        let p = parse(src).unwrap();
        assert_eq!(p.functions[0].params.len(), 1);
        assert!(matches!(
            p.functions[0].params[0].ty,
            Type::Ptr(Scalar::Float)
        ));
    }

    #[test]
    fn parse_if_else_chain() {
        let src = "void f(int x) { if (x > 0) { x = 1; } else if (x < 0) x = 2; else { x = 3; } }";
        let p = parse(src).unwrap();
        match &p.functions[0].body[0] {
            Stmt::If { else_branch, .. } => {
                assert_eq!(else_branch.len(), 1);
                assert!(matches!(else_branch[0], Stmt::If { .. }));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_cast() {
        let src = "void f() { float x = (float) 3 / 2; }";
        let p = parse(src).unwrap();
        match &p.functions[0].body[0] {
            Stmt::Decl { init: Some(Expr::Bin { lhs, .. }), .. } => {
                assert!(matches!(lhs.as_ref(), Expr::Cast { .. }));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_error_reports_position() {
        let err = parse("int main() { int = 3; }").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("1:"), "{msg}");
    }

    #[test]
    fn parse_for_without_decl_init() {
        let src = "void f() { int i; for (i = 0; i < 8; i = i + 1) { } }";
        let p = parse(src).unwrap();
        assert_eq!(p.loop_count, 1);
    }

    #[test]
    fn parse_include_lines_ignored() {
        let src = "#include <math.h>\nvoid f() { }";
        let p = parse(src).unwrap();
        assert_eq!(p.functions.len(), 1);
    }
}
