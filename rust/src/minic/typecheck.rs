//! Static semantic checks over a parsed [`Program`].
//!
//! Run once before analysis/offloading so later stages can assume a
//! well-formed program: every referenced name is declared, array ranks
//! match their declarations, called functions exist with the right arity,
//! and loop ids are unique and dense. (The interpreter re-checks
//! dynamically; this catches errors before any measurement is spent.)

use std::collections::{HashMap, HashSet};

use super::ast::*;
use super::MiniCError;

/// Known 1-argument math builtins.
pub const BUILTINS_1: &[&str] = &[
    "sin", "cos", "tan", "sqrt", "sqrtf", "exp", "log", "fabs", "floor",
    "ceil",
];

/// Known 2-argument builtins.
pub const BUILTINS_2: &[&str] = &["fmin", "fmax", "pow"];

/// Check the program; returns the list of semantic errors (empty = ok).
pub fn check(prog: &Program) -> Vec<MiniCError> {
    let mut errors = Vec::new();
    let mut checker = Checker {
        prog,
        errors: &mut errors,
        scopes: Vec::new(),
    };
    checker.run();
    errors
}

/// Convenience: check and fail on the first error.
pub fn check_ok(prog: &Program) -> Result<(), MiniCError> {
    match check(prog).into_iter().next() {
        None => Ok(()),
        Some(e) => Err(e),
    }
}

struct Checker<'p, 'e> {
    prog: &'p Program,
    errors: &'e mut Vec<MiniCError>,
    scopes: Vec<HashMap<String, Type>>,
}

impl<'p, 'e> Checker<'p, 'e> {
    fn run(&mut self) {
        self.check_loop_ids();

        // Global scope: defines + globals.
        let mut globals = HashMap::new();
        for (name, _) in &self.prog.defines {
            globals.insert(name.clone(), Type::Scalar(Scalar::Int));
        }
        for g in &self.prog.globals {
            if let Stmt::Decl { name, ty, .. } = g {
                if globals.contains_key(name) {
                    self.err(g.line(), format!("duplicate global `{name}`"));
                }
                globals.insert(name.clone(), ty.clone());
            }
        }
        self.scopes.push(globals);

        let mut fn_names = HashSet::new();
        for f in &self.prog.functions {
            if !fn_names.insert(f.name.clone()) {
                self.err(f.line, format!("duplicate function `{}`", f.name));
            }
        }
        for f in &self.prog.functions {
            self.check_function(f);
        }
    }

    fn check_loop_ids(&mut self) {
        let mut seen = HashSet::new();
        let mut max = 0u32;
        let mut count = 0u32;
        self.prog.walk_stmts(&mut |s| {
            if let Stmt::For { id, .. } | Stmt::While { id, .. } = s {
                if !seen.insert(*id) {
                    // Can't borrow self in closure; collected below.
                }
                max = max.max(id.0);
                count += 1;
            }
        });
        if count != self.prog.loop_count
            || (count > 0 && max + 1 != count)
            || seen.len() != count as usize
        {
            self.err(
                0,
                format!(
                    "loop id invariant broken: count={count}, max={max}, \
                     declared={}",
                    self.prog.loop_count
                ),
            );
        }
    }

    fn err(&mut self, line: u32, msg: String) {
        self.errors.push(MiniCError::Semantic { line, msg });
    }

    fn lookup(&self, name: &str) -> Option<&Type> {
        self.scopes.iter().rev().find_map(|s| s.get(name))
    }

    fn declare(&mut self, name: &str, ty: Type) {
        self.scopes
            .last_mut()
            .expect("scope")
            .insert(name.to_string(), ty);
    }

    fn check_function(&mut self, f: &Function) {
        self.scopes.push(HashMap::new());
        for p in &f.params {
            self.declare(&p.name, p.ty.clone());
        }
        self.check_block(&f.body);
        self.scopes.pop();
    }

    fn check_block(&mut self, stmts: &[Stmt]) {
        self.scopes.push(HashMap::new());
        for s in stmts {
            self.check_stmt(s);
        }
        self.scopes.pop();
    }

    fn check_stmt(&mut self, s: &Stmt) {
        match s {
            Stmt::Decl { name, ty, init, line } => {
                if let Some(e) = init {
                    self.check_expr(e, *line);
                }
                self.declare(name, ty.clone());
            }
            Stmt::Assign { target, value, line, .. } => {
                match target {
                    LValue::Var(n) => {
                        if self.lookup(n).is_none() {
                            self.err(
                                *line,
                                format!("assignment to undeclared `{n}`"),
                            );
                        }
                    }
                    LValue::Index { base, indices } => {
                        self.check_index(base, indices, *line);
                        for i in indices {
                            self.check_expr(i, *line);
                        }
                    }
                }
                self.check_expr(value, *line);
            }
            Stmt::If {
                cond,
                then_branch,
                else_branch,
                line,
            } => {
                self.check_expr(cond, *line);
                self.check_block(then_branch);
                self.check_block(else_branch);
            }
            Stmt::For {
                init,
                cond,
                step,
                body,
                line,
                ..
            } => {
                self.scopes.push(HashMap::new());
                if let Some(s) = init {
                    self.check_stmt(s);
                }
                if let Some(c) = cond {
                    self.check_expr(c, *line);
                }
                if let Some(s) = step {
                    self.check_stmt(s);
                }
                self.check_block(body);
                self.scopes.pop();
            }
            Stmt::While { cond, body, line, .. } => {
                self.check_expr(cond, *line);
                self.check_block(body);
            }
            Stmt::Return { value, line } => {
                if let Some(e) = value {
                    self.check_expr(e, *line);
                }
            }
            Stmt::ExprStmt { expr, line } => self.check_expr(expr, *line),
        }
    }

    fn check_index(&mut self, base: &str, indices: &[Expr], line: u32) {
        match self.lookup(base).cloned() {
            None => self.err(line, format!("undeclared array `{base}`")),
            Some(Type::Array(_, dims)) => {
                if dims.len() != indices.len() {
                    self.err(
                        line,
                        format!(
                            "`{base}` has rank {}, indexed with {} subscripts",
                            dims.len(),
                            indices.len()
                        ),
                    );
                }
            }
            Some(Type::Ptr(_)) => {
                if indices.len() != 1 {
                    self.err(
                        line,
                        format!(
                            "pointer `{base}` must be indexed with exactly 1 \
                             subscript"
                        ),
                    );
                }
            }
            Some(Type::Scalar(_)) => {
                self.err(line, format!("scalar `{base}` indexed like an array"))
            }
        }
    }

    fn check_expr(&mut self, e: &Expr, line: u32) {
        match e {
            Expr::Var(n) => {
                if self.lookup(n).is_none() {
                    self.err(line, format!("undeclared variable `{n}`"));
                }
            }
            Expr::Index { base, indices } => {
                self.check_index(base, indices, line);
                for i in indices {
                    self.check_expr(i, line);
                }
            }
            Expr::Bin { lhs, rhs, .. } => {
                self.check_expr(lhs, line);
                self.check_expr(rhs, line);
            }
            Expr::Un { operand, .. } | Expr::Cast { operand, .. } => {
                self.check_expr(operand, line)
            }
            Expr::Call { name, args } => {
                let arity = if BUILTINS_1.contains(&name.as_str()) {
                    Some(1)
                } else if BUILTINS_2.contains(&name.as_str()) {
                    Some(2)
                } else if name == "printf" {
                    None // variadic
                } else if let Some(f) = self.prog.function(name) {
                    Some(f.params.len())
                } else {
                    self.err(line, format!("call to unknown function `{name}`"));
                    None
                };
                if let Some(n) = arity {
                    if args.len() != n {
                        self.err(
                            line,
                            format!(
                                "`{name}` expects {n} args, got {}",
                                args.len()
                            ),
                        );
                    }
                }
                for a in args {
                    self.check_expr(a, line);
                }
            }
            Expr::IntLit(_) | Expr::FloatLit(_) | Expr::StrLit(_) => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::parse;

    fn errs(src: &str) -> Vec<String> {
        check(&parse(src).unwrap())
            .into_iter()
            .map(|e| e.to_string())
            .collect()
    }

    #[test]
    fn clean_program_passes() {
        let es = errs(
            "#define N 4\nfloat a[N];\n
             void f(float *x) { for (int i = 0; i < N; i++) x[i] = 0.0; }\n
             int main() { f(a); return 0; }",
        );
        assert!(es.is_empty(), "{es:?}");
    }

    #[test]
    fn undeclared_variable_caught() {
        let es = errs("int main() { return bogus; }");
        assert!(es.iter().any(|e| e.contains("bogus")), "{es:?}");
    }

    #[test]
    fn unknown_function_caught() {
        let es = errs("int main() { missing(1); return 0; }");
        assert!(es.iter().any(|e| e.contains("missing")), "{es:?}");
    }

    #[test]
    fn wrong_arity_caught() {
        let es = errs("int main() { float x = sin(1.0, 2.0); return 0; }");
        assert!(es.iter().any(|e| e.contains("expects 1")), "{es:?}");
    }

    #[test]
    fn rank_mismatch_caught() {
        let es = errs(
            "#define N 4\nfloat a[N][N];\nint main() { a[1] = 2.0; return 0; }",
        );
        assert!(es.iter().any(|e| e.contains("rank")), "{es:?}");
    }

    #[test]
    fn scalar_indexed_caught() {
        let es = errs("int main() { int x = 0; x[0] = 1; return 0; }");
        assert!(es.iter().any(|e| e.contains("scalar")), "{es:?}");
    }

    #[test]
    fn duplicate_function_caught() {
        let es = errs("void f() { }\nvoid f() { }\nint main() { return 0; }");
        assert!(es.iter().any(|e| e.contains("duplicate")), "{es:?}");
    }

    #[test]
    fn loop_scoped_decl_visible_in_body_only() {
        let es = errs(
            "int main() { for (int i = 0; i < 3; i++) { int j = i; } return 0; }",
        );
        assert!(es.is_empty(), "{es:?}");
        let es2 = errs(
            "int main() { for (int i = 0; i < 3; i++) { } return i; }",
        );
        assert!(es2.iter().any(|e| e.contains('i')), "{es2:?}");
    }
}
