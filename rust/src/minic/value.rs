//! Runtime values and environments for the MiniC interpreter.

use crate::util::fnv::FnvMap;

use super::ast::{Scalar, Type};
use super::MiniCError;

/// A runtime value: scalar or array handle.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    /// Index into the interpreter's array arena.
    Array(ArrayRef),
}

/// Handle to an arena-allocated array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArrayRef(pub usize);

impl Value {
    pub fn as_f64(&self) -> Result<f64, MiniCError> {
        match self {
            Value::Int(v) => Ok(*v as f64),
            Value::Float(v) => Ok(*v),
            Value::Array(_) => Err(MiniCError::Runtime(
                "array used as scalar".into(),
            )),
        }
    }

    pub fn as_i64(&self) -> Result<i64, MiniCError> {
        match self {
            Value::Int(v) => Ok(*v),
            Value::Float(v) => Ok(*v as i64),
            Value::Array(_) => Err(MiniCError::Runtime(
                "array used as integer".into(),
            )),
        }
    }

    pub fn truthy(&self) -> Result<bool, MiniCError> {
        Ok(self.as_f64()? != 0.0)
    }
}

/// An array instance: element type, dims, flat f64 storage.
///
/// Storage is always f64 — int arrays round on store. This keeps the
/// arena monomorphic; precision subtleties of f32 are the kernels'
/// business, the interpreter is a *semantics* oracle.
#[derive(Debug, Clone)]
pub struct ArrayObj {
    pub elem: Scalar,
    pub dims: Vec<usize>,
    pub data: Vec<f64>,
}

impl ArrayObj {
    pub fn new(elem: Scalar, dims: Vec<usize>) -> Self {
        let len = dims.iter().product();
        ArrayObj {
            elem,
            dims,
            data: vec![0.0; len],
        }
    }

    /// Flatten a multi-dim index; bounds-checked.
    pub fn flat_index(&self, idx: &[i64]) -> Result<usize, MiniCError> {
        if idx.len() != self.dims.len() {
            return Err(MiniCError::Runtime(format!(
                "rank mismatch: {} indices into rank-{} array",
                idx.len(),
                self.dims.len()
            )));
        }
        let mut flat = 0usize;
        for (d, (&i, &dim)) in idx.iter().zip(&self.dims).enumerate() {
            if i < 0 || i as usize >= dim {
                return Err(MiniCError::Runtime(format!(
                    "index {i} out of bounds for dim {d} (size {dim})"
                )));
            }
            flat = flat * dim + i as usize;
        }
        Ok(flat)
    }
}

/// Lexically scoped variable environment.
///
/// FNV-hashed maps (§Perf: name resolution is the interpreter's hottest
/// operation; see util::fnv).
#[derive(Debug, Default)]
pub struct Env {
    scopes: Vec<FnvMap<String, Value>>,
}

impl Env {
    pub fn new() -> Self {
        Env {
            scopes: vec![FnvMap::default()],
        }
    }

    pub fn push(&mut self) {
        self.scopes.push(FnvMap::default());
    }

    pub fn pop(&mut self) {
        self.scopes.pop().expect("scope underflow");
    }

    pub fn declare(&mut self, name: &str, v: Value) {
        self.scopes
            .last_mut()
            .expect("no scope")
            .insert(name.to_string(), v);
    }

    pub fn get(&self, name: &str) -> Option<&Value> {
        self.scopes.iter().rev().find_map(|s| s.get(name))
    }

    pub fn set(&mut self, name: &str, v: Value) -> Result<(), MiniCError> {
        for scope in self.scopes.iter_mut().rev() {
            if let Some(slot) = scope.get_mut(name) {
                *slot = v;
                return Ok(());
            }
        }
        Err(MiniCError::Runtime(format!("assignment to undeclared `{name}`")))
    }
}

/// Zero value for a declared type (arrays are allocated by the caller).
pub fn zero_of(ty: &Type) -> Value {
    match ty {
        Type::Scalar(Scalar::Int) => Value::Int(0),
        Type::Scalar(_) => Value::Float(0.0),
        Type::Array(..) | Type::Ptr(..) => {
            unreachable!("arrays allocated via arena")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn env_scoping_shadows_and_restores() {
        let mut env = Env::new();
        env.declare("x", Value::Int(1));
        env.push();
        env.declare("x", Value::Int(2));
        assert_eq!(env.get("x"), Some(&Value::Int(2)));
        env.pop();
        assert_eq!(env.get("x"), Some(&Value::Int(1)));
    }

    #[test]
    fn env_set_walks_outward() {
        let mut env = Env::new();
        env.declare("x", Value::Int(1));
        env.push();
        env.set("x", Value::Int(5)).unwrap();
        env.pop();
        assert_eq!(env.get("x"), Some(&Value::Int(5)));
    }

    #[test]
    fn env_set_undeclared_errors() {
        let mut env = Env::new();
        assert!(env.set("nope", Value::Int(0)).is_err());
    }

    #[test]
    fn array_flat_index_2d() {
        let a = ArrayObj::new(Scalar::Float, vec![3, 4]);
        assert_eq!(a.flat_index(&[0, 0]).unwrap(), 0);
        assert_eq!(a.flat_index(&[1, 2]).unwrap(), 6);
        assert_eq!(a.flat_index(&[2, 3]).unwrap(), 11);
        assert!(a.flat_index(&[3, 0]).is_err());
        assert!(a.flat_index(&[0, 4]).is_err());
        assert!(a.flat_index(&[-1, 0]).is_err());
        assert!(a.flat_index(&[0]).is_err());
    }
}
