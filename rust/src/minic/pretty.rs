//! Pretty-printer: AST → C-like source text.
//!
//! Used by [`crate::codegen`] to emit the host-program and OpenCL-kernel
//! texts (paper §3.3: "the target loop statement is converted into a high
//! level language such as OpenCL"), and in diagnostics.

use super::ast::*;
use std::fmt::Write;

/// Render an expression.
pub fn expr(e: &Expr) -> String {
    let mut s = String::new();
    write_expr(e, &mut s, 0);
    s
}

/// Render a statement at the given indent depth.
pub fn stmt(s: &Stmt, depth: usize) -> String {
    let mut out = String::new();
    write_stmt(s, &mut out, depth);
    out
}

/// Render a whole function.
pub fn function(f: &Function) -> String {
    let mut out = String::new();
    let params = f
        .params
        .iter()
        .map(|p| param(p))
        .collect::<Vec<_>>()
        .join(", ");
    let _ = writeln!(out, "{} {}({}) {{", f.ret, f.name, params);
    for s in &f.body {
        write_stmt(s, &mut out, 1);
    }
    out.push_str("}\n");
    out
}

fn param(p: &Param) -> String {
    match &p.ty {
        Type::Scalar(s) => format!("{s} {}", p.name),
        Type::Ptr(s) => format!("{s} *{}", p.name),
        Type::Array(s, dims) => {
            let d: String = dims.iter().map(|d| format!("[{d}]")).collect();
            format!("{s} {}{d}", p.name)
        }
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("    ");
    }
}

fn write_stmt(s: &Stmt, out: &mut String, depth: usize) {
    indent(out, depth);
    match s {
        Stmt::Decl { name, ty, init, .. } => {
            match ty {
                Type::Scalar(sc) => {
                    let _ = write!(out, "{sc} {name}");
                }
                Type::Ptr(sc) => {
                    let _ = write!(out, "{sc} *{name}");
                }
                Type::Array(sc, dims) => {
                    let _ = write!(out, "{sc} {name}");
                    for d in dims {
                        let _ = write!(out, "[{d}]");
                    }
                }
            }
            if let Some(e) = init {
                let _ = write!(out, " = {}", expr(e));
            }
            out.push_str(";\n");
        }
        Stmt::Assign { target, op, value, .. } => {
            let t = match target {
                LValue::Var(n) => n.clone(),
                LValue::Index { base, indices } => {
                    let idx: String = indices
                        .iter()
                        .map(|i| format!("[{}]", expr(i)))
                        .collect();
                    format!("{base}{idx}")
                }
            };
            let sym = match op {
                AssignOp::Set => "=",
                AssignOp::AddSet => "+=",
                AssignOp::SubSet => "-=",
                AssignOp::MulSet => "*=",
                AssignOp::DivSet => "/=",
            };
            let _ = writeln!(out, "{t} {sym} {};", expr(value));
        }
        Stmt::If {
            cond,
            then_branch,
            else_branch,
            ..
        } => {
            let _ = writeln!(out, "if ({}) {{", expr(cond));
            for s in then_branch {
                write_stmt(s, out, depth + 1);
            }
            indent(out, depth);
            if else_branch.is_empty() {
                out.push_str("}\n");
            } else {
                out.push_str("} else {\n");
                for s in else_branch {
                    write_stmt(s, out, depth + 1);
                }
                indent(out, depth);
                out.push_str("}\n");
            }
        }
        Stmt::For {
            id,
            init,
            cond,
            step,
            body,
            ..
        } => {
            let init_s = init
                .as_ref()
                .map(|s| oneline(s))
                .unwrap_or_default();
            let cond_s = cond.as_ref().map(expr).unwrap_or_default();
            let step_s = step
                .as_ref()
                .map(|s| oneline(s))
                .unwrap_or_default();
            let _ = writeln!(
                out,
                "for ({init_s}; {cond_s}; {step_s}) {{ /* {id} */"
            );
            for s in body {
                write_stmt(s, out, depth + 1);
            }
            indent(out, depth);
            out.push_str("}\n");
        }
        Stmt::While { id, cond, body, .. } => {
            let _ = writeln!(out, "while ({}) {{ /* {id} */", expr(cond));
            for s in body {
                write_stmt(s, out, depth + 1);
            }
            indent(out, depth);
            out.push_str("}\n");
        }
        Stmt::Return { value, .. } => match value {
            Some(e) => {
                let _ = writeln!(out, "return {};", expr(e));
            }
            None => out.push_str("return;\n"),
        },
        Stmt::ExprStmt { expr: e, .. } => {
            let _ = writeln!(out, "{};", expr(e));
        }
    }
}

/// Statement without trailing `;\n` or indent — for `for` headers.
fn oneline(s: &Stmt) -> String {
    let mut text = stmt(s, 0);
    while text.ends_with('\n') || text.ends_with(';') {
        text.pop();
    }
    text
}

fn prec(e: &Expr) -> u8 {
    match e {
        Expr::Bin { op, .. } => match op {
            BinOp::Or => 1,
            BinOp::And => 2,
            BinOp::Eq | BinOp::Ne => 3,
            BinOp::Lt | BinOp::Gt | BinOp::Le | BinOp::Ge => 4,
            BinOp::Add | BinOp::Sub => 5,
            BinOp::Mul | BinOp::Div | BinOp::Rem => 6,
        },
        Expr::Un { .. } | Expr::Cast { .. } => 7,
        _ => 8,
    }
}

fn write_expr(e: &Expr, out: &mut String, parent_prec: u8) {
    let my_prec = prec(e);
    let need_parens = my_prec < parent_prec;
    if need_parens {
        out.push('(');
    }
    match e {
        Expr::IntLit(v) => {
            let _ = write!(out, "{v}");
        }
        Expr::FloatLit(v) => {
            if v.fract() == 0.0 && v.abs() < 1e15 {
                let _ = write!(out, "{:.1}", v);
            } else {
                let _ = write!(out, "{v}");
            }
        }
        Expr::StrLit(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '\n' => out.push_str("\\n"),
                    '\t' => out.push_str("\\t"),
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Expr::Var(n) => out.push_str(n),
        Expr::Index { base, indices } => {
            out.push_str(base);
            for i in indices {
                out.push('[');
                write_expr(i, out, 0);
                out.push(']');
            }
        }
        Expr::Bin { op, lhs, rhs } => {
            write_expr(lhs, out, my_prec);
            let _ = write!(out, " {} ", op.c_symbol());
            // Right operand needs the next precedence up for left-assoc.
            write_expr(rhs, out, my_prec + 1);
        }
        Expr::Un { op, operand } => {
            out.push(match op {
                UnOp::Neg => '-',
                UnOp::Not => '!',
            });
            write_expr(operand, out, my_prec);
        }
        Expr::Call { name, args } => {
            out.push_str(name);
            out.push('(');
            for (i, a) in args.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                write_expr(a, out, 0);
            }
            out.push(')');
        }
        Expr::Cast { to, operand } => {
            let _ = write!(out, "({to}) ");
            write_expr(operand, out, my_prec);
        }
    }
    if need_parens {
        out.push(')');
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::parse;

    #[test]
    fn roundtrip_is_idempotent() {
        // Parse → pretty → parse → pretty: the two renderings must be
        // byte-identical (ASTs carry line numbers, so AST equality across
        // different sources is not expected; print-stability is).
        let src = "
#define N 16
float a[N];
float acc;
void work(float *x, int n) {
    for (int i = 0; i < n; i++) {
        if (x[i] > 0.5) { acc += x[i] * 2.0 - 1.0; }
    }
}
int main() { work(a, N); return (int) acc; }
";
        fn render(p: &crate::minic::Program) -> String {
            let mut out = String::new();
            for (name, val) in &p.defines {
                out.push_str(&format!("#define {name} {val}\n"));
            }
            for g in &p.globals {
                out.push_str(&stmt(g, 0));
            }
            for f in &p.functions {
                out.push_str(&function(f));
            }
            out
        }
        let p1 = parse(src).unwrap();
        let r1 = render(&p1);
        let p2 = parse(&r1).unwrap();
        let r2 = render(&p2);
        assert_eq!(r1, r2);
        // Loop inventory is preserved as well.
        assert_eq!(p1.loop_count, p2.loop_count);
    }

    #[test]
    fn parenthesization_correct() {
        let p = parse("int main() { int x = (1 + 2) * 3; return x; }").unwrap();
        let body = &p.functions[0].body[0];
        let text = stmt(body, 0);
        assert!(text.contains("(1 + 2) * 3"), "{text}");
    }

    #[test]
    fn no_spurious_parens() {
        let p = parse("int main() { int x = 1 + 2 * 3; return x; }").unwrap();
        let text = stmt(&p.functions[0].body[0], 0);
        assert!(text.contains("1 + 2 * 3"), "{text}");
    }

    #[test]
    fn loop_comment_carries_id() {
        let p = parse("void f() { for (int i = 0; i < 4; i++) { } }").unwrap();
        let text = function(&p.functions[0]);
        assert!(text.contains("/* L0 */"), "{text}");
    }
}
