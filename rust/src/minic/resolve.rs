//! Resolution + lowering: AST → slot-resolved bytecode (§Perf).
//!
//! One pass over the program does everything the tree-walker re-does on
//! every execution:
//!
//! * **Name resolution** — lexical scopes are walked once here; every
//!   variable becomes a dense frame slot (locals/params) or a global
//!   slot index. Resolution is *positional*: a use site sees exactly the
//!   bindings a running tree-walker would have declared by that point,
//!   so shadowing, use-before-decl, and re-declaration behave
//!   identically.
//! * **`#define` folding** — a define reference becomes an inline
//!   constant, unless the program somewhere assigns to that name (the
//!   tree-walker models defines as mutable globals; folding would break
//!   such programs, so they keep the slot).
//! * **Interning** — array names used for footprint attribution become
//!   `u32` ids.
//! * **Deferred errors** — anything the tree-walker only rejects at
//!   runtime (undeclared names, unknown functions, bad builtin arity,
//!   rank > 4) lowers to a [`Instr::Trap`] at the equivalent execution
//!   point.

use std::collections::HashSet;

use crate::util::fnv::FnvMap;

use super::ast::*;
use super::bytecode::{
    Builtin1, Builtin2, FuncCode, GlobalDecl, GlobalKind, Instr, Module,
    Storage,
};
use super::MiniCError;

/// Maximum supported array rank (fixed index buffer in the VM).
pub const MAX_RANK: usize = 4;

/// Encoding options for [`compile_with`] — the PGO loop's knobs.
///
/// The peepholes fuse measured-hot adjacent instruction pairs (see
/// `minic::profile` and `repro vmprofile`) into superinstructions.
/// Every fusion is in-place (the pair's first instruction is
/// overwritten when the second is emitted), so code length and jump
/// targets never change and the baseline/fused encodings stay
/// observably identical — the differential fuzzer holds across all
/// option combinations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResolveOpts {
    /// Fuse hot adjacent pairs into superinstructions
    /// (`LoadIndexLocal`, `LoadIndexBin`, `BinConstInt`,
    /// `CompoundLocalConst`, `CmpConstJump`, `StoreIndexLocal`).
    pub fuse_pairs: bool,
    /// Register-style operand encoding experiment (`BinLocal`): binary
    /// operators read their rhs straight from a frame slot. Off by
    /// default; opt in per call or build with `--features vm-regs`.
    pub reg_encoding: bool,
}

impl Default for ResolveOpts {
    fn default() -> Self {
        ResolveOpts {
            fuse_pairs: true,
            reg_encoding: cfg!(feature = "vm-regs"),
        }
    }
}

impl ResolveOpts {
    /// The pre-PGO encoding (only the original `MacLocal` fusion).
    /// This is the `vm-baseline` engine and the bench's control series.
    pub fn baseline() -> Self {
        ResolveOpts { fuse_pairs: false, reg_encoding: false }
    }

    /// All peepholes plus the register-encoding experiment
    /// (the `vm-regs` engine).
    pub fn regs() -> Self {
        ResolveOpts { fuse_pairs: true, reg_encoding: true }
    }
}

/// Lower a parsed program to a [`Module`] with the default encoding.
///
/// Fails only where [`super::Interp::new`] would fail at construction
/// (pointer-typed globals have no binding to allocate).
pub fn compile(prog: &Program) -> Result<Module, MiniCError> {
    compile_with(prog, &ResolveOpts::default())
}

/// Lower with explicit encoding options (see [`ResolveOpts`]).
pub fn compile_with(
    prog: &Program,
    opts: &ResolveOpts,
) -> Result<Module, MiniCError> {
    let mut c = Compiler {
        prog,
        opts: *opts,
        names: Vec::new(),
        name_ids: FnvMap::default(),
        traps: Vec::new(),
        trap_ids: FnvMap::default(),
        array_dims: Vec::new(),
        globals: Vec::new(),
        global_names: FnvMap::default(),
        func_names: FnvMap::default(),
        assigned: assigned_var_names(prog),
    };

    // Defines become (potentially foldable) globals, in source order.
    for (name, val) in &prog.defines {
        let kind = if val.fract() == 0.0 {
            GlobalKind::DefineInt(*val as i64)
        } else {
            GlobalKind::DefineFloat(*val)
        };
        c.push_global(name, kind);
    }

    // Function table before anything compiles (global initializers may
    // call functions; calls resolve by index, first name wins).
    for (i, f) in prog.functions.iter().enumerate() {
        let idx = i as u16;
        c.func_names.entry(f.name.clone()).or_insert(idx);
    }

    // Global declarations: allocate slots in order, compile initializer
    // expressions into the synthetic init chunk. Each initializer only
    // sees defines and the globals declared up to (and including) its
    // own declaration, exactly like the tree-walker's sequential pass.
    let mut init = FnCompiler::new();
    for g in &prog.globals {
        if let Stmt::Decl { name, ty, init: ie, .. } = g {
            let kind = match ty {
                Type::Array(elem, dims) => {
                    GlobalKind::Array(*elem, dims.clone())
                }
                Type::Ptr(_) => {
                    return Err(MiniCError::Runtime(
                        "pointer declarations require an argument binding"
                            .into(),
                    ))
                }
                Type::Scalar(Scalar::Int) => GlobalKind::ScalarInt,
                Type::Scalar(_) => GlobalKind::ScalarFloat,
            };
            let slot = c.push_global(name, kind);
            if let Some(e) = ie {
                init.expr(&mut c, e);
                init.code.push(Instr::StoreGlobal(slot));
            }
        }
    }
    init.code.push(Instr::ConstInt(0));
    init.code.push(Instr::Return);

    let mut funcs = Vec::with_capacity(prog.functions.len() + 1);
    for f in prog.functions.iter() {
        funcs.push(compile_function(&mut c, f));
    }
    let init_func = funcs.len() as u16;
    funcs.push(FuncCode {
        name: "@init".into(),
        params: Vec::new(),
        n_slots: 0,
        code: init.code,
    });

    Ok(Module {
        funcs,
        func_names: c.func_names,
        init_func,
        globals: c.globals,
        global_names: c.global_names,
        names: c.names,
        array_dims: c.array_dims,
        traps: c.traps,
        loop_count: prog.loop_count,
    })
}

/// Names assigned anywhere via `LValue::Var` — a define in this set is
/// mutated at runtime and must keep its global slot (no folding).
fn assigned_var_names(prog: &Program) -> HashSet<String> {
    let mut out = HashSet::new();
    prog.walk_stmts(&mut |s| {
        if let Stmt::Assign { target: LValue::Var(n), .. } = s {
            out.insert(n.clone());
        }
    });
    out
}

struct Compiler<'p> {
    prog: &'p Program,
    opts: ResolveOpts,
    names: Vec<String>,
    name_ids: FnvMap<String, u32>,
    traps: Vec<String>,
    trap_ids: FnvMap<String, u32>,
    array_dims: Vec<(Scalar, Vec<usize>)>,
    globals: Vec<GlobalDecl>,
    global_names: FnvMap<String, u16>,
    func_names: FnvMap<String, u16>,
    assigned: HashSet<String>,
}

impl<'p> Compiler<'p> {
    fn push_global(&mut self, name: &str, kind: GlobalKind) -> u16 {
        let slot = self.globals.len() as u16;
        self.globals.push(GlobalDecl {
            name: name.to_string(),
            kind,
        });
        // Later bindings shadow earlier ones, like map insertion in the
        // tree-walker's global environment.
        self.global_names.insert(name.to_string(), slot);
        slot
    }

    fn intern(&mut self, name: &str) -> u32 {
        if let Some(id) = self.name_ids.get(name) {
            return *id;
        }
        let id = self.names.len() as u32;
        self.names.push(name.to_string());
        self.name_ids.insert(name.to_string(), id);
        id
    }

    fn trap_id(&mut self, msg: String) -> u32 {
        if let Some(id) = self.trap_ids.get(&msg) {
            return *id;
        }
        let id = self.traps.len() as u32;
        self.traps.push(msg.clone());
        self.trap_ids.insert(msg, id);
        id
    }

    /// The define value for a global slot, when folding is allowed.
    fn foldable(&self, name: &str, slot: u16) -> Option<Instr> {
        if self.assigned.contains(name) {
            return None;
        }
        match &self.globals[slot as usize].kind {
            GlobalKind::DefineInt(v) => Some(Instr::ConstInt(*v)),
            GlobalKind::DefineFloat(v) => Some(Instr::ConstFloat(*v)),
            _ => None,
        }
    }
}

fn compile_function(c: &mut Compiler, f: &Function) -> FuncCode {
    let mut fc = FnCompiler::new();
    fc.scopes.push(FnvMap::default());
    for p in &f.params {
        let slot = fc.new_slot();
        fc.bind(&p.name, slot);
    }
    for s in &f.body {
        fc.stmt(c, s);
    }
    // Fall-through return (the tree-walker yields `Int(0)`).
    fc.code.push(Instr::ConstInt(0));
    fc.code.push(Instr::Return);
    FuncCode {
        name: f.name.clone(),
        params: f.params.clone(),
        n_slots: fc.n_slots,
        code: fc.code,
    }
}

struct FnCompiler {
    scopes: Vec<FnvMap<String, u16>>,
    n_slots: u16,
    code: Vec<Instr>,
}

impl FnCompiler {
    fn new() -> Self {
        FnCompiler {
            scopes: Vec::new(),
            n_slots: 0,
            code: Vec::new(),
        }
    }

    fn new_slot(&mut self) -> u16 {
        let slot = self.n_slots;
        // Frames are bounded by source size; u16 overflow would need
        // >65k declarations in one function.
        self.n_slots += 1;
        slot
    }

    fn bind(&mut self, name: &str, slot: u16) {
        self.scopes
            .last_mut()
            .expect("scope")
            .insert(name.to_string(), slot);
    }

    fn resolve_local(&self, name: &str) -> Option<u16> {
        self.scopes.iter().rev().find_map(|s| s.get(name)).copied()
    }

    /// Resolve to local slot / global slot, or `None` (undeclared).
    fn resolve(&self, c: &Compiler, name: &str) -> Option<Storage> {
        if let Some(slot) = self.resolve_local(name) {
            return Some(Storage::Local(slot));
        }
        c.global_names.get(name).copied().map(Storage::Global)
    }

    fn here(&self) -> u32 {
        self.code.len() as u32
    }

    fn patch(&mut self, at: usize) {
        let target = self.here();
        self.code[at] = match self.code[at] {
            Instr::Jump(_) => Instr::Jump(target),
            Instr::JumpIfFalse(_) => Instr::JumpIfFalse(target),
            Instr::AndCheck(_) => Instr::AndCheck(target),
            Instr::OrCheck(_) => Instr::OrCheck(target),
            Instr::CmpConstJump { op, v, .. } => {
                Instr::CmpConstJump { op, v, target }
            }
            other => unreachable!("patching {other:?}"),
        };
    }

    // ---- superinstruction peepholes (§PGO) ----
    //
    // Each helper overwrites the just-emitted first member of a
    // measured-hot pair in place of pushing the second, so fusion never
    // changes code length or invalidates a jump target. Soundness: the
    // overwritten instruction is always the final instruction of the
    // sub-expression emitted immediately before, and no branch target
    // can point *at* it (targets only ever land on statement/condition
    // boundaries — loop tops, post-body joins, `&&`/`||` joins), so no
    // control path can enter between the fused halves.

    /// `Bin(op)`, fusing a trailing `LoadIndex` / `ConstInt` /
    /// (under `reg_encoding`) `LoadLocal` rhs.
    fn emit_bin(&mut self, c: &Compiler, op: BinOp) {
        if c.opts.fuse_pairs {
            match self.code.last().copied() {
                Some(Instr::LoadIndex { base, rank, name }) => {
                    *self.code.last_mut().expect("peephole") =
                        Instr::LoadIndexBin { base, rank, name, op };
                    return;
                }
                Some(Instr::ConstInt(v)) => {
                    *self.code.last_mut().expect("peephole") =
                        Instr::BinConstInt(op, v);
                    return;
                }
                Some(Instr::LoadLocal(slot)) if c.opts.reg_encoding => {
                    *self.code.last_mut().expect("peephole") =
                        Instr::BinLocal { slot, op };
                    return;
                }
                _ => {}
            }
        }
        self.code.push(Instr::Bin(op));
    }

    /// `CompoundLocal(slot, op)`, fusing a trailing small-constant rhs
    /// (`i++`, `i += c`).
    fn emit_compound_local(&mut self, c: &Compiler, slot: u16, op: BinOp) {
        if c.opts.fuse_pairs {
            if let Some(Instr::ConstInt(v)) = self.code.last().copied() {
                if let Ok(v) = i32::try_from(v) {
                    *self.code.last_mut().expect("peephole") =
                        Instr::CompoundLocalConst { slot, op, v };
                    return;
                }
            }
        }
        self.code.push(Instr::CompoundLocal(slot, op));
    }

    /// `LoadIndex`, fusing a trailing `LoadLocal` innermost index.
    fn emit_load_index(
        &mut self,
        c: &Compiler,
        base: Storage,
        rank: u8,
        name: u32,
    ) {
        if c.opts.fuse_pairs {
            if let Some(Instr::LoadLocal(idx)) = self.code.last().copied() {
                *self.code.last_mut().expect("peephole") =
                    Instr::LoadIndexLocal { base, rank, idx, name };
                return;
            }
        }
        self.code.push(Instr::LoadIndex { base, rank, name });
    }

    /// `StoreIndex`, fusing a trailing `LoadLocal` innermost index.
    fn emit_store_index(
        &mut self,
        c: &Compiler,
        base: Storage,
        rank: u8,
        name: u32,
        op: AssignOp,
    ) {
        if c.opts.fuse_pairs {
            if let Some(Instr::LoadLocal(idx)) = self.code.last().copied() {
                *self.code.last_mut().expect("peephole") =
                    Instr::StoreIndexLocal { base, rank, idx, name, op };
                return;
            }
        }
        self.code.push(Instr::StoreIndex { base, rank, name, op });
    }

    /// Conditional branch for an `if`/loop condition, fusing a trailing
    /// small-constant compare (`i < N`) into one dispatch. Returns the
    /// index to [`Self::patch`] once the target is known.
    fn emit_jump_if_false(&mut self, c: &Compiler) -> usize {
        if c.opts.fuse_pairs {
            if let Some(Instr::BinConstInt(op, v)) = self.code.last().copied()
            {
                if let Ok(v) = i32::try_from(v) {
                    let at = self.code.len() - 1;
                    self.code[at] = Instr::CmpConstJump { op, v, target: 0 };
                    return at;
                }
            }
        }
        let at = self.code.len();
        self.code.push(Instr::JumpIfFalse(0));
        at
    }

    fn trap(&mut self, c: &mut Compiler, msg: String) {
        let id = c.trap_id(msg);
        self.code.push(Instr::Trap(id));
    }

    fn block(&mut self, c: &mut Compiler, stmts: &[Stmt]) {
        // Always push a compile-time scope: positional binding makes
        // this equivalent to the tree-walker's conditional scope push.
        self.scopes.push(FnvMap::default());
        for s in stmts {
            self.stmt(c, s);
        }
        self.scopes.pop();
    }

    fn stmt(&mut self, c: &mut Compiler, s: &Stmt) {
        match s {
            Stmt::Decl { name, ty, init, .. } => self.decl(c, name, ty, init),
            Stmt::Assign { target, op, value, .. } => {
                self.assign(c, target, *op, value)
            }
            Stmt::If {
                cond,
                then_branch,
                else_branch,
                ..
            } => {
                self.expr(c, cond);
                self.code.push(Instr::BumpCmp);
                let jf = self.emit_jump_if_false(c);
                self.block(c, then_branch);
                let jend = self.code.len();
                self.code.push(Instr::Jump(0));
                self.patch(jf);
                self.block(c, else_branch);
                self.patch(jend);
            }
            Stmt::For {
                id,
                init,
                cond,
                step,
                body,
                ..
            } => {
                self.scopes.push(FnvMap::default());
                if let Some(s) = init {
                    self.stmt(c, s);
                }
                self.code.push(Instr::LoopEnter(*id));
                let top = self.here();
                let jf = match cond {
                    Some(cexpr) => {
                        self.code.push(Instr::BumpCmp);
                        self.expr(c, cexpr);
                        Some(self.emit_jump_if_false(c))
                    }
                    None => None,
                };
                self.code.push(Instr::LoopTrip(*id));
                self.block(c, body);
                if let Some(s) = step {
                    self.stmt(c, s);
                }
                self.code.push(Instr::Jump(top));
                if let Some(jf) = jf {
                    self.patch(jf);
                }
                self.code.push(Instr::LoopExit);
                self.scopes.pop();
            }
            Stmt::While { id, cond, body, .. } => {
                self.code.push(Instr::LoopEnter(*id));
                let top = self.here();
                self.code.push(Instr::BumpCmp);
                self.expr(c, cond);
                let jf = self.emit_jump_if_false(c);
                self.code.push(Instr::LoopTrip(*id));
                self.block(c, body);
                self.code.push(Instr::Jump(top));
                self.patch(jf);
                self.code.push(Instr::LoopExit);
            }
            Stmt::Return { value, .. } => {
                match value {
                    Some(e) => self.expr(c, e),
                    None => self.code.push(Instr::ConstInt(0)),
                }
                self.code.push(Instr::Return);
            }
            Stmt::ExprStmt { expr, .. } => {
                self.expr(c, expr);
                self.code.push(Instr::Pop);
            }
        }
    }

    fn decl(
        &mut self,
        c: &mut Compiler,
        name: &str,
        ty: &Type,
        init: &Option<Expr>,
    ) {
        match ty {
            Type::Scalar(sc) => {
                let slot = self.new_slot();
                // Zero + bind first: the tree-walker declares the zeroed
                // variable before evaluating the initializer, so an init
                // expression referencing `name` sees the fresh zero.
                self.code.push(Instr::ZeroLocal(slot, *sc));
                self.bind(name, slot);
                if let Some(e) = init {
                    self.expr(c, e);
                    self.code.push(Instr::StoreLocalCoerce(slot, *sc));
                }
            }
            Type::Array(elem, dims) => {
                let slot = self.new_slot();
                let dims_id = c.array_dims.len() as u16;
                c.array_dims.push((*elem, dims.clone()));
                self.code.push(Instr::AllocLocalArray { slot, dims: dims_id });
                self.bind(name, slot);
                if let Some(e) = init {
                    // Degenerate (`float a[N] = expr;`): the tree-walker
                    // overwrites the handle with the scalar, uncoerced.
                    self.expr(c, e);
                    self.code.push(Instr::StoreLocal(slot));
                }
            }
            Type::Ptr(_) => {
                // The tree-walker fails when the declaration executes.
                self.trap(
                    c,
                    "pointer declarations require an argument binding".into(),
                );
                let slot = self.new_slot();
                self.bind(name, slot);
            }
        }
    }

    fn assign(
        &mut self,
        c: &mut Compiler,
        target: &LValue,
        op: AssignOp,
        value: &Expr,
    ) {
        // MAC superinstruction: `local += e1 * e2` fuses the multiply
        // and the compound add into one dispatch — the pattern the
        // workloads' hot tap/voxel loops are made of. Only the final
        // two instructions fuse, so operand evaluation order, counts,
        // and error behavior are untouched.
        if let (
            AssignOp::AddSet,
            LValue::Var(name),
            Expr::Bin {
                op: BinOp::Mul,
                lhs,
                rhs,
            },
        ) = (op, target, value)
        {
            if let Some(slot) = self.resolve_local(name) {
                self.expr(c, lhs);
                self.expr(c, rhs);
                self.code.push(Instr::MacLocal(slot));
                return;
            }
        }
        // Rhs evaluates before the target is resolved or read.
        self.expr(c, value);
        match target {
            LValue::Var(name) => match self.resolve(c, name) {
                Some(Storage::Local(slot)) => match compound_op(op) {
                    None => self.code.push(Instr::StoreLocal(slot)),
                    Some(bin) => self.emit_compound_local(c, slot, bin),
                },
                Some(Storage::Global(slot)) => {
                    self.code.push(match compound_op(op) {
                        None => Instr::StoreGlobal(slot),
                        Some(bin) => Instr::CompoundGlobal(slot, bin),
                    });
                }
                None => {
                    let msg = if op == AssignOp::Set {
                        format!("assignment to undeclared `{name}`")
                    } else {
                        format!("undeclared `{name}`")
                    };
                    self.trap(c, msg);
                }
            },
            LValue::Index { base, indices } => {
                for i in indices {
                    self.expr(c, i);
                }
                if indices.len() > MAX_RANK {
                    let msg = format!(
                        "array rank {} exceeds supported maximum",
                        indices.len()
                    );
                    self.trap(c, msg);
                    return;
                }
                let name = c.intern(base);
                match self.resolve(c, base) {
                    Some(storage) => self.emit_store_index(
                        c,
                        storage,
                        indices.len() as u8,
                        name,
                        op,
                    ),
                    None => {
                        self.trap(c, format!("undeclared `{base}`"));
                    }
                }
            }
        }
    }

    fn expr(&mut self, c: &mut Compiler, e: &Expr) {
        match e {
            Expr::IntLit(v) => self.code.push(Instr::ConstInt(*v)),
            Expr::FloatLit(v) => self.code.push(Instr::ConstFloat(*v)),
            // Format strings evaluate to 0 (only printf consumes them).
            Expr::StrLit(_) => self.code.push(Instr::ConstInt(0)),
            Expr::Var(name) => match self.resolve(c, name) {
                Some(Storage::Local(slot)) => {
                    self.code.push(Instr::LoadLocal(slot))
                }
                Some(Storage::Global(slot)) => {
                    let instr = match c.foldable(name, slot) {
                        Some(folded) => folded,
                        None => Instr::LoadGlobal(slot),
                    };
                    self.code.push(instr);
                }
                None => self.trap(c, format!("undeclared `{name}`")),
            },
            Expr::Index { base, indices } => {
                for i in indices {
                    self.expr(c, i);
                }
                if indices.len() > MAX_RANK {
                    let msg = format!(
                        "array rank {} exceeds supported maximum",
                        indices.len()
                    );
                    self.trap(c, msg);
                    return;
                }
                let name = c.intern(base);
                match self.resolve(c, base) {
                    Some(storage) => self.emit_load_index(
                        c,
                        storage,
                        indices.len() as u8,
                        name,
                    ),
                    None => self.trap(c, format!("undeclared `{base}`")),
                }
            }
            Expr::Bin { op: BinOp::And, lhs, rhs } => {
                self.expr(c, lhs);
                let at = self.code.len();
                self.code.push(Instr::AndCheck(0));
                self.expr(c, rhs);
                self.code.push(Instr::ToBool);
                self.patch(at);
            }
            Expr::Bin { op: BinOp::Or, lhs, rhs } => {
                self.expr(c, lhs);
                let at = self.code.len();
                self.code.push(Instr::OrCheck(0));
                self.expr(c, rhs);
                self.code.push(Instr::ToBool);
                self.patch(at);
            }
            Expr::Bin { op, lhs, rhs } => {
                self.expr(c, lhs);
                self.expr(c, rhs);
                self.emit_bin(c, *op);
            }
            Expr::Un { op, operand } => {
                self.expr(c, operand);
                self.code.push(match op {
                    UnOp::Neg => Instr::Neg,
                    UnOp::Not => Instr::Not,
                });
            }
            Expr::Call { name, args } => self.call(c, name, args),
            Expr::Cast { to, operand } => {
                self.expr(c, operand);
                self.code.push(match to {
                    Scalar::Int => Instr::CastInt,
                    _ => Instr::CastFloat,
                });
            }
        }
    }

    /// Calls follow the tree-walker's dispatch order exactly: 1-arg
    /// builtins, then printf / 2-arg builtins, then user functions.
    fn call(&mut self, c: &mut Compiler, name: &str, args: &[Expr]) {
        if let Some(b) = Builtin1::from_name(name) {
            if args.len() != 1 {
                // Arity is checked before any argument evaluates.
                self.trap(c, format!("`{name}` expects 1 argument"));
                return;
            }
            self.expr(c, &args[0]);
            self.code.push(Instr::Builtin1(b));
            return;
        }
        if name == "printf" {
            // Evaluate args for effect-parity (format string skipped).
            for a in args.iter().skip(1) {
                self.expr(c, a);
                self.code.push(Instr::Pop);
            }
            self.code.push(Instr::ConstInt(0));
            return;
        }
        if let Some(b) = Builtin2::from_name(name) {
            if args.len() != 2 {
                self.trap(c, format!("`{name}` expects 2 arguments"));
                return;
            }
            self.expr(c, &args[0]);
            self.expr(c, &args[1]);
            self.code.push(Instr::Builtin2(b));
            return;
        }
        // User function: arguments evaluate before the lookup/arity
        // failure surfaces, matching the tree-walker.
        for a in args {
            self.expr(c, a);
        }
        match c.func_names.get(name).copied() {
            None => {
                self.trap(c, format!("no function `{name}`"));
            }
            Some(idx) => {
                let expected =
                    c.prog.functions[idx as usize].params.len();
                if expected != args.len() {
                    let msg = format!(
                        "`{name}` expects {expected} args, got {}",
                        args.len()
                    );
                    self.trap(c, msg);
                } else {
                    self.code.push(Instr::Call {
                        func: idx,
                        argc: args.len() as u8,
                    });
                }
            }
        }
    }
}

fn compound_op(op: AssignOp) -> Option<BinOp> {
    match op {
        AssignOp::Set => None,
        AssignOp::AddSet => Some(BinOp::Add),
        AssignOp::SubSet => Some(BinOp::Sub),
        AssignOp::MulSet => Some(BinOp::Mul),
        AssignOp::DivSet => Some(BinOp::Div),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::parse;

    fn main_code(m: &Module) -> &[Instr] {
        &m.funcs[m.func("main").unwrap() as usize].code
    }

    #[test]
    fn compiles_minimal_program() {
        let prog = parse("int main() { return 1 + 2; }").unwrap();
        let m = compile(&prog).unwrap();
        assert_eq!(m.funcs.len(), 2); // main + @init
        assert!(m.func("main").is_some());
        // The constant rhs fuses: `1 + 2` is one dispatch after the
        // lhs push. The baseline encoding keeps the plain pair.
        assert!(main_code(&m).contains(&Instr::BinConstInt(BinOp::Add, 2)));
        let mb = compile_with(&prog, &ResolveOpts::baseline()).unwrap();
        assert!(main_code(&mb).contains(&Instr::Bin(BinOp::Add)));
        assert!(main_code(&mb).contains(&Instr::ConstInt(2)));
    }

    #[test]
    fn defines_fold_to_constants() {
        let prog = parse(
            "#define N 8\nint main() { return N; }",
        )
        .unwrap();
        let m = compile(&prog).unwrap();
        let main = &m.funcs[m.func("main").unwrap() as usize];
        assert!(main.code.contains(&Instr::ConstInt(8)));
        assert!(!main
            .code
            .iter()
            .any(|i| matches!(i, Instr::LoadGlobal(_))));
    }

    #[test]
    fn assigned_define_keeps_global_slot() {
        let prog = parse(
            "#define N 8\nint main() { N = 9; return N; }",
        )
        .unwrap();
        let m = compile(&prog).unwrap();
        let main = &m.funcs[m.func("main").unwrap() as usize];
        assert!(main
            .code
            .iter()
            .any(|i| matches!(i, Instr::LoadGlobal(_))));
    }

    #[test]
    fn loops_carry_profile_markers() {
        let prog = parse(
            "int main() { for (int i = 0; i < 3; i++) { } return 0; }",
        )
        .unwrap();
        let m = compile(&prog).unwrap();
        let main = &m.funcs[m.func("main").unwrap() as usize];
        assert!(main.code.contains(&Instr::LoopEnter(LoopId(0))));
        assert!(main.code.contains(&Instr::LoopTrip(LoopId(0))));
        assert!(main.code.contains(&Instr::LoopExit));
    }

    #[test]
    fn mac_pattern_fuses_to_a_superinstruction() {
        let prog = parse(
            "#define N 8\nfloat a[N]; float b[N];\n\
             int main() {\n\
                 float acc = 0.0;\n\
                 for (int i = 0; i < N; i++) { acc += a[i] * b[i]; }\n\
                 return (int) acc;\n\
             }",
        )
        .unwrap();
        let m = compile(&prog).unwrap();
        let main = &m.funcs[m.func("main").unwrap() as usize];
        assert_eq!(
            main.code
                .iter()
                .filter(|i| matches!(i, Instr::MacLocal(_)))
                .count(),
            1
        );
        // The fused pair is gone: the multiply no longer appears as a
        // standalone Bin instruction (the only Bin left is the loop
        // condition's compare).
        assert!(!main
            .code
            .iter()
            .any(|i| matches!(i, Instr::Bin(BinOp::Mul))));
    }

    #[test]
    fn mac_on_globals_and_non_mul_rhs_stay_unfused() {
        // Global accumulator: CompoundGlobal, not MacLocal.
        let prog = parse(
            "#define N 4\nfloat a[N]; float acc;\n\
             int main() { for (int i = 0; i < N; i++) { acc += a[i] * 2.0; } return 0; }",
        )
        .unwrap();
        let m = compile(&prog).unwrap();
        let main = &m.funcs[m.func("main").unwrap() as usize];
        assert!(!main.code.iter().any(|i| matches!(i, Instr::MacLocal(_))));
        assert!(main
            .code
            .iter()
            .any(|i| matches!(i, Instr::CompoundGlobal(_, BinOp::Add))));
        // Additive (non-multiply) rhs: plain compound add.
        let prog2 = parse(
            "int main() { int s = 0; for (int i = 0; i < 4; i++) { s += i; } return s; }",
        )
        .unwrap();
        let m2 = compile(&prog2).unwrap();
        let main2 = &m2.funcs[m2.func("main").unwrap() as usize];
        assert!(!main2.code.iter().any(|i| matches!(i, Instr::MacLocal(_))));
    }

    #[test]
    fn undeclared_name_becomes_trap() {
        let prog =
            parse("int main() { if (0) { return ghost; } return 0; }")
                .unwrap();
        let m = compile(&prog).unwrap();
        let main = &m.funcs[m.func("main").unwrap() as usize];
        assert!(main.code.iter().any(|i| matches!(i, Instr::Trap(_))));
    }

    #[test]
    fn pointer_global_rejected_at_compile() {
        let prog = parse("float *p;\nint main() { return 0; }").unwrap();
        assert!(compile(&prog).is_err());
    }

    // ---- superinstruction peepholes (§PGO) ----

    #[test]
    fn local_index_fuses_into_index_ops() {
        let prog = parse(
            "#define N 4\nfloat a[N]; float b[N][N];\n\
             int main() {\n\
                 for (int i = 0; i < N; i++) {\n\
                     a[i] = b[i][i] + 1.0;\n\
                 }\n\
                 return 0;\n\
             }",
        )
        .unwrap();
        let m = compile(&prog).unwrap();
        let code = main_code(&m);
        // `b[i][i]`: the innermost `i` folds into the load; the outer
        // index still pops. `a[i] = ...`: the store's index folds too —
        // but its rhs ends in `+ 1.0` (ConstFloat stays unfused), so
        // the store fusion only fires where the last instruction before
        // it is the index load. Check both shapes by opcode presence:
        assert!(code.iter().any(|i| matches!(
            i,
            Instr::LoadIndexLocal { rank: 2, .. }
        )));
        assert!(!code.iter().any(|i| matches!(i, Instr::LoadIndex { .. })));
        // Baseline keeps the plain pair everywhere.
        let mb = compile_with(&prog, &ResolveOpts::baseline()).unwrap();
        let cb = main_code(&mb);
        assert!(cb.iter().any(|i| matches!(i, Instr::LoadIndex { .. })));
        assert!(!cb
            .iter()
            .any(|i| matches!(i, Instr::LoadIndexLocal { .. })));
    }

    #[test]
    fn store_with_local_index_fuses() {
        // Stores emit rhs first, then indices: `a[i] = 2;` lowers to
        // ConstInt, LoadLocal(i), StoreIndex — the trailing index load
        // fuses into the store.
        let prog = parse(
            "#define N 4\nfloat a[N];\n\
             int main() {\n\
                 for (int i = 0; i < N; i++) { a[i] = 2; }\n\
                 return 0;\n\
             }",
        )
        .unwrap();
        let m = compile(&prog).unwrap();
        assert!(main_code(&m).iter().any(|i| matches!(
            i,
            Instr::StoreIndexLocal { rank: 1, .. }
        )));
        assert!(!main_code(&m)
            .iter()
            .any(|i| matches!(i, Instr::StoreIndex { .. })));
    }

    #[test]
    fn index_load_feeding_operator_fuses_to_load_index_bin() {
        // `x[n + k]` leaves a genuine LoadIndex (computed index), and
        // the multiply after it fuses into LoadIndexBin — the
        // index-chain candidate the pair profile surfaces first.
        let prog = parse(
            "#define N 8\nfloat h[N]; float x[N];\n\
             int main() {\n\
                 float acc = 0.0;\n\
                 for (int n = 0; n < 4; n++) {\n\
                     acc = acc + h[n] * x[n + 1];\n\
                 }\n\
                 return (int) acc;\n\
             }",
        )
        .unwrap();
        let m = compile(&prog).unwrap();
        assert!(main_code(&m).iter().any(|i| matches!(
            i,
            Instr::LoadIndexBin { op: BinOp::Mul, .. }
        )));
    }

    #[test]
    fn constant_compare_and_branch_fuse_in_loop_conditions() {
        let prog = parse(
            "#define N 8\n\
             int main() {\n\
                 int s = 0;\n\
                 for (int i = 0; i < N; i++) { s += 2; }\n\
                 return s;\n\
             }",
        )
        .unwrap();
        let m = compile(&prog).unwrap();
        let code = main_code(&m);
        // `i < N` + branch → CmpConstJump; `i++` and `s += 2` →
        // CompoundLocalConst; no unfused remnants of either pair.
        assert!(code.iter().any(|i| matches!(
            i,
            Instr::CmpConstJump { op: BinOp::Lt, v: 8, .. }
        )));
        assert_eq!(
            code.iter()
                .filter(|i| matches!(i, Instr::CompoundLocalConst { .. }))
                .count(),
            2
        );
        assert!(!code.iter().any(|i| matches!(i, Instr::JumpIfFalse(_))));
        assert!(!code
            .iter()
            .any(|i| matches!(i, Instr::CompoundLocal(..))));
        // The if-statement shape keeps a plain JumpIfFalse (BumpCmp
        // sits between the compare and the branch).
        let prog2 = parse(
            "int main() { if (1 < 2) { return 1; } return 0; }",
        )
        .unwrap();
        let m2 = compile(&prog2).unwrap();
        assert!(main_code(&m2)
            .iter()
            .any(|i| matches!(i, Instr::JumpIfFalse(_))));
    }

    #[test]
    fn oversized_constants_stay_unfused() {
        // CompoundLocalConst/CmpConstJump carry i32 payloads; a bound
        // beyond that range keeps the plain encoding.
        let prog = parse(
            "int main() {\n\
                 int s = 0;\n\
                 s += 5000000000;\n\
                 if (s < 6000000000) { return 1; }\n\
                 return 0;\n\
             }",
        )
        .unwrap();
        let m = compile(&prog).unwrap();
        let code = main_code(&m);
        assert!(code.iter().any(|i| matches!(i, Instr::CompoundLocal(..))));
        assert!(code.contains(&Instr::BinConstInt(BinOp::Lt, 6_000_000_000)));
        assert!(!code
            .iter()
            .any(|i| matches!(i, Instr::CompoundLocalConst { .. })));
    }

    #[test]
    fn register_encoding_is_opt_in() {
        let prog = parse(
            "int main() { int a = 3; int b = 4; return a * b; }",
        )
        .unwrap();
        let m = compile_with(&prog, &ResolveOpts::regs()).unwrap();
        assert!(main_code(&m).iter().any(|i| matches!(
            i,
            Instr::BinLocal { op: BinOp::Mul, .. }
        )));
        // Off under the baseline encoding (and the default unless the
        // `vm-regs` feature is enabled).
        let mb = compile_with(&prog, &ResolveOpts::baseline()).unwrap();
        assert!(!main_code(&mb)
            .iter()
            .any(|i| matches!(i, Instr::BinLocal { .. })));
    }

    #[test]
    fn fused_and_baseline_encodings_keep_identical_layout_lengths() {
        // In-place fusion must never change instruction count deltas
        // caused by *jumps*: every function's jump targets must land on
        // valid instruction boundaries in both encodings.
        let prog = parse(
            "#define N 6\nfloat a[N];\n\
             int main() {\n\
                 float s = 0.0;\n\
                 for (int i = 0; i < N; i++) {\n\
                     if (i % 2 == 0) { s += a[i] * 2.0; } else { s -= 1.0; }\n\
                 }\n\
                 while (s > 10.0) { s -= 3.0; }\n\
                 return (int) s;\n\
             }",
        )
        .unwrap();
        for opts in [
            ResolveOpts::default(),
            ResolveOpts::baseline(),
            ResolveOpts::regs(),
        ] {
            let m = compile_with(&prog, &opts).unwrap();
            for f in &m.funcs {
                for (at, i) in f.code.iter().enumerate() {
                    let t = match i {
                        Instr::Jump(t)
                        | Instr::JumpIfFalse(t)
                        | Instr::AndCheck(t)
                        | Instr::OrCheck(t)
                        | Instr::CmpConstJump { target: t, .. } => *t,
                        _ => continue,
                    };
                    assert!(
                        (t as usize) <= f.code.len(),
                        "{opts:?}: jump at {at} to {t} escapes {}",
                        f.name
                    );
                }
            }
        }
    }
}
