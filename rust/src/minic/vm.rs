//! Slot-resolved bytecode VM for MiniC (§Perf).
//!
//! Drop-in replacement for the tree-walking [`super::Interp`] on the
//! pipeline's hot paths (profiling runs, GA fitness, numeric
//! verification). The program is lowered once by [`super::resolve`];
//! execution is a flat dispatch loop over [`Instr`]s with:
//!
//! * dense frame slots instead of `HashMap<String, Value>` scopes,
//! * preallocated operand/locals/frame stacks (no per-iteration
//!   allocation; local arrays are the only runtime allocation, exactly
//!   as in the tree-walker),
//! * the [`OpCounts`] / per-loop profile instrumentation maintained
//!   inline by the same rules as the tree-walker, so `profile()` is
//!   bit-identical (the differential property test enforces this).
//!
//! The tree-walker remains the *semantics oracle*; this VM is the
//! default engine (see [`super::engine`]).
//!
//! The dispatch loop is profile-guided (§PGO): an optional
//! [`OpProfiler`] — a no-op handle like `obs::Tracer`, attached only by
//! the `*_profiled` constructors — counts per-opcode and adjacent-pair
//! frequencies, and the match arms are ordered by the measured ranking
//! from the bundled workloads. The pair report is what justified the
//! fused superinstructions in [`super::resolve`]; every fused handler
//! mirrors the unfused sequence's pops, counter bumps, and error order
//! exactly (the differential fuzz harness enforces this).

use std::collections::HashMap;

use super::ast::{LoopId, Scalar, Type};
use super::bytecode::{Builtin2, Instr, Module, Storage};
use super::interp::{LoopProfile, OpCounts, Profile};
use super::profile::{Op, OpProfiler};
use super::resolve;
use super::resolve::ResolveOpts;
use super::value::{ArrayObj, ArrayRef, Value};
use super::{BinOp, MiniCError, Program};

/// Runaway guard, same budget as the tree-walker.
const MAX_STEPS: u64 = 2_000_000_000;

/// Call-depth guard (the tree-walker recurses on the Rust stack; the VM
/// heap-allocates frames, so it bounds depth explicitly instead).
const MAX_FRAMES: usize = 10_000;

/// Unboxed runtime value (the VM-internal `Value`).
#[derive(Debug, Clone, Copy, PartialEq)]
enum Slot {
    Int(i64),
    Float(f64),
    Arr(u32),
}

fn slot_of_value(v: &Value) -> Slot {
    match v {
        Value::Int(i) => Slot::Int(*i),
        Value::Float(f) => Slot::Float(*f),
        Value::Array(r) => Slot::Arr(r.0 as u32),
    }
}

fn value_of_slot(v: Slot) -> Value {
    match v {
        Slot::Int(i) => Value::Int(i),
        Slot::Float(f) => Value::Float(f),
        Slot::Arr(a) => Value::Array(ArrayRef(a as usize)),
    }
}

fn slot_as_f64(v: Slot) -> Result<f64, MiniCError> {
    match v {
        Slot::Int(i) => Ok(i as f64),
        Slot::Float(f) => Ok(f),
        Slot::Arr(_) => {
            Err(MiniCError::Runtime("array used as scalar".into()))
        }
    }
}

fn slot_as_i64(v: Slot) -> Result<i64, MiniCError> {
    match v {
        Slot::Int(i) => Ok(i),
        Slot::Float(f) => Ok(f as i64),
        Slot::Arr(_) => {
            Err(MiniCError::Runtime("array used as integer".into()))
        }
    }
}

fn truthy(v: Slot) -> Result<bool, MiniCError> {
    Ok(slot_as_f64(v)? != 0.0)
}

#[cold]
#[inline(never)]
fn step_limit_err() -> MiniCError {
    MiniCError::Runtime(format!("step limit exceeded ({MAX_STEPS})"))
}

fn int_cmp(op: BinOp, a: i64, b: i64) -> bool {
    match op {
        BinOp::Eq => a == b,
        BinOp::Ne => a != b,
        BinOp::Lt => a < b,
        BinOp::Gt => a > b,
        BinOp::Le => a <= b,
        BinOp::Ge => a >= b,
        _ => unreachable!(),
    }
}

fn float_cmp(op: BinOp, a: f64, b: f64) -> bool {
    match op {
        BinOp::Eq => a == b,
        BinOp::Ne => a != b,
        BinOp::Lt => a < b,
        BinOp::Gt => a > b,
        BinOp::Le => a <= b,
        BinOp::Ge => a >= b,
        _ => unreachable!(),
    }
}

/// Dense per-loop counters (footprints as interned-id vecs).
#[derive(Debug, Default, Clone)]
struct VmLoopSlot {
    entries: u64,
    trips: u64,
    ops: OpCounts,
    arrays_read: Vec<u32>,
    arrays_written: Vec<u32>,
}

#[derive(Debug, Clone, Copy)]
struct Frame {
    func: u16,
    ret_func: u16,
    ret_pc: u32,
    base: u32,
    loop_base: u32,
}

/// The VM. One instance per program run (like `Interp`); `call` may be
/// invoked repeatedly and counters accumulate.
pub struct Vm {
    module: Module,
    pub arena: Vec<ArrayObj>,
    globals: Vec<Slot>,
    total: OpCounts,
    loop_slots: Vec<VmLoopSlot>,
    /// Active loops (across call frames, like the tree-walker's stack):
    /// id + op-count snapshot at entry.
    loop_stack: Vec<(LoopId, OpCounts)>,
    stack: Vec<Slot>,
    locals: Vec<Slot>,
    frames: Vec<Frame>,
    steps: u64,
    /// Instruction profiler, same no-op-handle pattern as
    /// [`crate::obs::Tracer`]: `None` (the default) costs one
    /// predictable branch per dispatch and nothing else.
    profiler: Option<Box<OpProfiler>>,
}

impl Vm {
    /// Lower `prog` and materialize globals (running global
    /// initializers under instrumentation, like `Interp::new`).
    pub fn new(prog: &Program) -> Result<Self, MiniCError> {
        Self::from_module(resolve::compile(prog)?)
    }

    /// Lower with explicit encoding options (see [`ResolveOpts`]).
    pub fn new_with(
        prog: &Program,
        opts: &ResolveOpts,
    ) -> Result<Self, MiniCError> {
        Self::build(resolve::compile_with(prog, opts)?, false)
    }

    /// Like [`Vm::new`], with the instruction profiler attached.
    pub fn new_profiled(prog: &Program) -> Result<Self, MiniCError> {
        Self::build(resolve::compile(prog)?, true)
    }

    /// Profiled VM under explicit encoding options — the PGO loop's
    /// measurement configuration (`repro vmprofile --baseline` runs
    /// this over `ResolveOpts::baseline()` to surface fusion pairs).
    pub fn new_profiled_with(
        prog: &Program,
        opts: &ResolveOpts,
    ) -> Result<Self, MiniCError> {
        Self::build(resolve::compile_with(prog, opts)?, true)
    }

    /// Build a VM from an already-compiled module.
    pub fn from_module(module: Module) -> Result<Self, MiniCError> {
        Self::build(module, false)
    }

    /// Like [`Vm::from_module`], with the instruction profiler attached.
    pub fn from_module_profiled(module: Module) -> Result<Self, MiniCError> {
        Self::build(module, true)
    }

    fn build(module: Module, profiled: bool) -> Result<Self, MiniCError> {
        let loop_count = module.loop_count as usize;
        let mut vm = Vm {
            arena: Vec::new(),
            globals: Vec::with_capacity(module.globals.len()),
            total: OpCounts::default(),
            loop_slots: vec![VmLoopSlot::default(); loop_count],
            loop_stack: Vec::with_capacity(16),
            stack: Vec::with_capacity(64),
            locals: Vec::with_capacity(256),
            frames: Vec::with_capacity(16),
            steps: 0,
            profiler: profiled.then(|| Box::new(OpProfiler::new())),
            module,
        };
        for g in &vm.module.globals {
            let slot = match &g.kind {
                super::bytecode::GlobalKind::DefineInt(v) => Slot::Int(*v),
                super::bytecode::GlobalKind::DefineFloat(v) => {
                    Slot::Float(*v)
                }
                super::bytecode::GlobalKind::ScalarInt => Slot::Int(0),
                super::bytecode::GlobalKind::ScalarFloat => Slot::Float(0.0),
                super::bytecode::GlobalKind::Array(elem, dims) => {
                    vm.arena.push(ArrayObj::new(*elem, dims.clone()));
                    Slot::Arr((vm.arena.len() - 1) as u32)
                }
            };
            vm.globals.push(slot);
        }
        let init = vm.module.init_func;
        vm.run_entry(init, &[])?;
        Ok(vm)
    }

    /// Call a function by name with the given arguments (drop-in for
    /// `Interp::call`, same error surface).
    pub fn call(
        &mut self,
        name: &str,
        args: &[Value],
    ) -> Result<Value, MiniCError> {
        let func = self.module.func(name).ok_or_else(|| {
            MiniCError::Runtime(format!("no function `{name}`"))
        })?;
        let params = &self.module.funcs[func as usize].params;
        if params.len() != args.len() {
            return Err(MiniCError::Runtime(format!(
                "`{name}` expects {} args, got {}",
                params.len(),
                args.len()
            )));
        }
        for (p, a) in params.iter().zip(args) {
            match (&p.ty, a) {
                (Type::Ptr(_) | Type::Array(..), Value::Array(_)) => {}
                (Type::Scalar(_), Value::Array(_)) => {
                    return Err(MiniCError::Runtime(format!(
                        "array passed to scalar param `{}`",
                        p.name
                    )))
                }
                (Type::Ptr(_) | Type::Array(..), _) => {
                    return Err(MiniCError::Runtime(format!(
                        "scalar passed to array param `{}`",
                        p.name
                    )))
                }
                _ => {}
            }
        }
        let slots: Vec<Slot> = args.iter().map(slot_of_value).collect();
        let v = self.run_entry(func, &slots)?;
        Ok(value_of_slot(v))
    }

    /// Allocate an array in the arena (harness-side input setup).
    pub fn alloc_array(&mut self, elem: Scalar, dims: Vec<usize>) -> ArrayRef {
        self.arena.push(ArrayObj::new(elem, dims));
        ArrayRef(self.arena.len() - 1)
    }

    pub fn array(&self, r: ArrayRef) -> &ArrayObj {
        &self.arena[r.0]
    }

    pub fn array_mut(&mut self, r: ArrayRef) -> &mut ArrayObj {
        &mut self.arena[r.0]
    }

    /// The global named `name`, if it is an array.
    pub fn global_array(&self, name: &str) -> Option<ArrayRef> {
        match self.global_slot(name)? {
            Slot::Arr(a) => Some(ArrayRef(a as usize)),
            _ => None,
        }
    }

    /// The global named `name`, if it is a scalar.
    pub fn global_scalar(&self, name: &str) -> Option<f64> {
        match self.global_slot(name)? {
            Slot::Int(v) => Some(v as f64),
            Slot::Float(v) => Some(v),
            Slot::Arr(_) => None,
        }
    }

    fn global_slot(&self, name: &str) -> Option<Slot> {
        let idx = self.module.global_names.get(name)?;
        Some(self.globals[*idx as usize])
    }

    /// Total instructions dispatched so far (all calls, including the
    /// `@init` chunk). Equals the profiler's counter total — the
    /// property test pins the two together.
    pub fn dispatches(&self) -> u64 {
        self.steps
    }

    /// The attached instruction profiler, when built profiled.
    pub fn instr_profiler(&self) -> Option<&OpProfiler> {
        self.profiler.as_deref()
    }

    /// Assemble the public [`Profile`] (identical shape and contents to
    /// the tree-walker's: never-entered loops omitted).
    pub fn profile(&self) -> Profile {
        let mut loops = HashMap::new();
        for (i, slot) in self.loop_slots.iter().enumerate() {
            if slot.entries == 0 {
                continue;
            }
            loops.insert(
                LoopId(i as u32),
                LoopProfile {
                    entries: slot.entries,
                    trips: slot.trips,
                    ops: slot.ops,
                    arrays_read: slot
                        .arrays_read
                        .iter()
                        .map(|id| self.module.names[*id as usize].clone())
                        .collect(),
                    arrays_written: slot
                        .arrays_written
                        .iter()
                        .map(|id| self.module.names[*id as usize].clone())
                        .collect(),
                },
            );
        }
        Profile {
            total: self.total,
            loops,
        }
    }

    // ---- execution ----

    fn run_entry(
        &mut self,
        func: u16,
        args: &[Slot],
    ) -> Result<Slot, MiniCError> {
        let entry_depth = self.frames.len();
        let stack_mark = self.stack.len();
        let locals_mark = self.locals.len();
        let loops_mark = self.loop_stack.len();

        let n_slots = self.module.funcs[func as usize].n_slots as usize;
        if self.frames.len() >= MAX_FRAMES {
            return Err(MiniCError::Runtime("call depth exceeded".into()));
        }
        let base = self.locals.len();
        self.frames.push(Frame {
            func,
            ret_func: 0,
            ret_pc: 0,
            base: base as u32,
            loop_base: loops_mark as u32,
        });
        self.locals.resize(base + n_slots, Slot::Int(0));
        self.locals[base..base + args.len()].copy_from_slice(args);

        match self.run(entry_depth) {
            Ok(v) => Ok(v),
            Err(e) => {
                // Leave the VM reusable after a runtime error: unwind to
                // the pre-call state (counters keep whatever accrued,
                // like the tree-walker's).
                self.frames.truncate(entry_depth);
                self.stack.truncate(stack_mark);
                self.locals.truncate(locals_mark);
                self.loop_stack.truncate(loops_mark);
                Err(e)
            }
        }
    }

    fn run(&mut self, entry_depth: usize) -> Result<Slot, MiniCError> {
        let mut func = self.frames.last().expect("entry frame").func as usize;
        let mut base =
            self.frames.last().expect("entry frame").base as usize;
        let mut pc: usize = 0;

        loop {
            let instr = self.module.funcs[func].code[pc];
            pc += 1;
            self.steps += 1;
            // Profiler hook sits before the step guard so counter
            // totals equal `steps` even on error paths (the property
            // test relies on this).
            if let Some(p) = self.profiler.as_deref_mut() {
                p.record(Op::of(&instr));
            }
            if self.steps > MAX_STEPS {
                return Err(step_limit_err());
            }
            // Arm order follows the measured opcode ranking from
            // `repro vmprofile` over the bundled workloads (hottest
            // first, allocation/trap in the cold tail) so the common
            // dispatch path stays in front.
            match instr {
                Instr::LoadLocal(s) => {
                    let v = self.locals[base + s as usize];
                    self.stack.push(v);
                }
                Instr::LoadIndexLocal { base: b, rank, idx, name } => {
                    // Fused `LoadLocal(idx)` + `LoadIndex`: the unfused
                    // pair pops the innermost index first (it was
                    // pushed last), so the local slot is converted
                    // first here for identical error order.
                    let rank = rank as usize;
                    let mut buf = [0i64; resolve::MAX_RANK];
                    buf[rank - 1] =
                        slot_as_i64(self.locals[base + idx as usize])?;
                    for i in (0..rank - 1).rev() {
                        let v = self.stack.pop().expect("index");
                        buf[i] = slot_as_i64(v)?;
                    }
                    let out =
                        self.load_index_value(b, base, name, &buf[..rank])?;
                    self.stack.push(out);
                }
                Instr::CmpConstJump { op, v, target } => {
                    // Fused `BinConstInt` + `JumpIfFalse`: one dispatch
                    // for a whole `i < N`-and-branch.
                    let l = self.stack.pop().expect("lhs");
                    let out = self.apply_bin(op, l, Slot::Int(v as i64))?;
                    if !truthy(out)? {
                        pc = target as usize;
                    }
                }
                Instr::CompoundLocalConst { slot, op, v } => {
                    // Fused `ConstInt` + `CompoundLocal` (`i++`,
                    // `s += 2`): rhs comes from the immediate.
                    let old = self.locals[base + slot as usize];
                    let new =
                        self.apply_bin(op, old, Slot::Int(v as i64))?;
                    self.locals[base + slot as usize] = new;
                }
                Instr::LoadIndexBin { base: b, rank, name, op } => {
                    // Fused `LoadIndex` + `Bin`: the loaded element is
                    // the operator's rhs (it was on top of the stack).
                    let rank = rank as usize;
                    let mut buf = [0i64; resolve::MAX_RANK];
                    for i in (0..rank).rev() {
                        let v = self.stack.pop().expect("index");
                        buf[i] = slot_as_i64(v)?;
                    }
                    let r =
                        self.load_index_value(b, base, name, &buf[..rank])?;
                    let l = self.stack.pop().expect("lhs");
                    let out = self.apply_bin(op, l, r)?;
                    self.stack.push(out);
                }
                Instr::BinConstInt(op, v) => {
                    // Fused `ConstInt` + `Bin`: rhs from the immediate.
                    let l = self.stack.pop().expect("lhs");
                    let out = self.apply_bin(op, l, Slot::Int(v))?;
                    self.stack.push(out);
                }
                Instr::MacLocal(s) => {
                    // Fused `Bin(Mul)` + `CompoundLocal(s, Add)`: same
                    // pops, same typing/count rules, same error order.
                    let r = self.stack.pop().expect("mac rhs");
                    let l = self.stack.pop().expect("mac lhs");
                    let prod = self.apply_bin(BinOp::Mul, l, r)?;
                    let old = self.locals[base + s as usize];
                    let new = self.apply_bin(BinOp::Add, old, prod)?;
                    self.locals[base + s as usize] = new;
                }
                Instr::BinLocal { slot, op } => {
                    // Fused `LoadLocal` + `Bin` (register-encoding
                    // experiment): rhs read straight from its slot.
                    let r = self.locals[base + slot as usize];
                    let l = self.stack.pop().expect("lhs");
                    let out = self.apply_bin(op, l, r)?;
                    self.stack.push(out);
                }
                Instr::Bin(op) => {
                    let r = self.stack.pop().expect("rhs");
                    let l = self.stack.pop().expect("lhs");
                    let v = self.apply_bin(op, l, r)?;
                    self.stack.push(v);
                }
                Instr::LoadIndex { base: b, rank, name } => {
                    let rank = rank as usize;
                    let mut buf = [0i64; resolve::MAX_RANK];
                    for i in (0..rank).rev() {
                        let v = self.stack.pop().expect("index");
                        buf[i] = slot_as_i64(v)?;
                    }
                    let out =
                        self.load_index_value(b, base, name, &buf[..rank])?;
                    self.stack.push(out);
                }
                Instr::StoreIndexLocal { base: b, rank, idx, name, op } => {
                    // Fused `LoadLocal(idx)` + `StoreIndex`: innermost
                    // index from the slot first (error-order parity),
                    // then outer indices, then the stored value.
                    let rank = rank as usize;
                    let mut buf = [0i64; resolve::MAX_RANK];
                    buf[rank - 1] =
                        slot_as_i64(self.locals[base + idx as usize])?;
                    for i in (0..rank - 1).rev() {
                        let v = self.stack.pop().expect("index");
                        buf[i] = slot_as_i64(v)?;
                    }
                    let rhs = self.stack.pop().expect("rhs");
                    self.store_index_value(
                        b,
                        base,
                        name,
                        op,
                        &buf[..rank],
                        rhs,
                    )?;
                }
                Instr::StoreIndex { base: b, rank, name, op } => {
                    let rank = rank as usize;
                    let mut buf = [0i64; resolve::MAX_RANK];
                    for i in (0..rank).rev() {
                        let v = self.stack.pop().expect("index");
                        buf[i] = slot_as_i64(v)?;
                    }
                    let rhs = self.stack.pop().expect("rhs");
                    self.store_index_value(
                        b,
                        base,
                        name,
                        op,
                        &buf[..rank],
                        rhs,
                    )?;
                }
                Instr::BumpCmp => self.total.cmp += 1,
                Instr::Jump(t) => pc = t as usize,
                Instr::LoopTrip(id) => {
                    self.loop_slots[id.0 as usize].trips += 1;
                }
                Instr::ConstInt(v) => self.stack.push(Slot::Int(v)),
                Instr::ConstFloat(v) => self.stack.push(Slot::Float(v)),
                Instr::StoreLocal(s) => {
                    let v = self.stack.pop().expect("store value");
                    self.locals[base + s as usize] = v;
                }
                Instr::StoreLocalCoerce(s, sc) => {
                    let v = self.stack.pop().expect("store value");
                    self.locals[base + s as usize] = coerce(sc, v);
                }
                Instr::LoadGlobal(s) => {
                    self.stack.push(self.globals[s as usize])
                }
                Instr::StoreGlobal(s) => {
                    let v = self.stack.pop().expect("store value");
                    self.globals[s as usize] = v;
                }
                Instr::CompoundLocal(s, op) => {
                    let rhs = self.stack.pop().expect("rhs");
                    let old = self.locals[base + s as usize];
                    let new = self.apply_bin(op, old, rhs)?;
                    self.locals[base + s as usize] = new;
                }
                Instr::CompoundGlobal(s, op) => {
                    let rhs = self.stack.pop().expect("rhs");
                    let old = self.globals[s as usize];
                    let new = self.apply_bin(op, old, rhs)?;
                    self.globals[s as usize] = new;
                }
                Instr::ZeroLocal(s, sc) => {
                    self.locals[base + s as usize] = if sc == Scalar::Int {
                        Slot::Int(0)
                    } else {
                        Slot::Float(0.0)
                    };
                }
                Instr::Neg => {
                    let v = self.stack.pop().expect("operand");
                    let out = match v {
                        Slot::Int(i) => {
                            self.total.i_op += 1;
                            Slot::Int(i.wrapping_neg())
                        }
                        Slot::Float(f) => {
                            self.total.f_add += 1;
                            Slot::Float(-f)
                        }
                        Slot::Arr(_) => {
                            return Err(MiniCError::Runtime(
                                "negating an array".into(),
                            ))
                        }
                    };
                    self.stack.push(out);
                }
                Instr::Not => {
                    let v = self.stack.pop().expect("operand");
                    self.total.cmp += 1;
                    let out = Slot::Int(!truthy(v)? as i64);
                    self.stack.push(out);
                }
                Instr::CastInt => {
                    let v = self.stack.pop().expect("operand");
                    let out = Slot::Int(slot_as_i64(v)?);
                    self.stack.push(out);
                }
                Instr::CastFloat => {
                    let v = self.stack.pop().expect("operand");
                    let out = Slot::Float(slot_as_f64(v)?);
                    self.stack.push(out);
                }
                Instr::JumpIfFalse(t) => {
                    let v = self.stack.pop().expect("cond");
                    if !truthy(v)? {
                        pc = t as usize;
                    }
                }
                Instr::AndCheck(t) => {
                    let v = self.stack.pop().expect("lhs");
                    self.total.cmp += 1;
                    if !truthy(v)? {
                        self.stack.push(Slot::Int(0));
                        pc = t as usize;
                    }
                }
                Instr::OrCheck(t) => {
                    let v = self.stack.pop().expect("lhs");
                    self.total.cmp += 1;
                    if truthy(v)? {
                        self.stack.push(Slot::Int(1));
                        pc = t as usize;
                    }
                }
                Instr::ToBool => {
                    let v = self.stack.pop().expect("operand");
                    let out = Slot::Int(truthy(v)? as i64);
                    self.stack.push(out);
                }
                Instr::Pop => {
                    self.stack.pop().expect("pop");
                }
                Instr::LoopEnter(id) => {
                    self.loop_stack.push((id, self.total));
                    self.loop_slots[id.0 as usize].entries += 1;
                }
                Instr::LoopExit => {
                    let (id, snapshot) =
                        self.loop_stack.pop().expect("loop stack");
                    let delta = self.total.delta_from(&snapshot);
                    self.loop_slots[id.0 as usize].ops.accumulate(&delta);
                }
                Instr::Call { func: callee, argc } => {
                    self.enter_call(callee, argc, func as u16, pc as u32)?;
                    func = callee as usize;
                    base = self.frames.last().expect("frame").base as usize;
                    pc = 0;
                }
                Instr::Builtin1(b) => {
                    let v = self.stack.pop().expect("arg");
                    let x = slot_as_f64(v)?;
                    self.total.f_trig += 1;
                    self.stack.push(Slot::Float(b.eval(x)));
                }
                Instr::Builtin2(b) => {
                    let rv = self.stack.pop().expect("arg");
                    let lv = self.stack.pop().expect("arg");
                    let a = slot_as_f64(lv)?;
                    let x = slot_as_f64(rv)?;
                    let out = match b {
                        Builtin2::Fmin => {
                            self.total.cmp += 1;
                            a.min(x)
                        }
                        Builtin2::Fmax => {
                            self.total.cmp += 1;
                            a.max(x)
                        }
                        Builtin2::Pow => {
                            self.total.f_trig += 1;
                            a.powf(x)
                        }
                    };
                    self.stack.push(Slot::Float(out));
                }
                Instr::Return => {
                    let v = self.stack.pop().expect("return value");
                    let frame = self.frames.pop().expect("frame");
                    // Early returns leave loops open: attribute each, as
                    // the tree-walker's unwinding exit_loop calls do.
                    self.unwind_loops(frame.loop_base as usize);
                    self.locals.truncate(frame.base as usize);
                    if self.frames.len() == entry_depth {
                        return Ok(v);
                    }
                    func = frame.ret_func as usize;
                    pc = frame.ret_pc as usize;
                    base = self.frames.last().expect("frame").base as usize;
                    self.stack.push(v);
                }
                // ---- cold tail: setup and failure paths ----
                Instr::AllocLocalArray { slot, dims } => {
                    let (elem, d) =
                        self.module.array_dims[dims as usize].clone();
                    self.arena.push(ArrayObj::new(elem, d));
                    self.locals[base + slot as usize] =
                        Slot::Arr((self.arena.len() - 1) as u32);
                }
                Instr::Trap(id) => return Err(self.trap_err(id)),
            }
        }
    }

    /// Shared tail of `LoadIndex` / `LoadIndexLocal` / `LoadIndexBin`:
    /// count the index ops, locate the array, read one element. The
    /// callers differ only in where the indices come from.
    #[inline]
    fn load_index_value(
        &mut self,
        b: Storage,
        base: usize,
        name: u32,
        idx: &[i64],
    ) -> Result<Slot, MiniCError> {
        self.total.i_op += idx.len() as u64;
        let aidx = self.array_of(b, base, name)?;
        let arr = &self.arena[aidx];
        let flat = arr.flat_index(idx)?;
        let v = arr.data[flat];
        let elem = arr.elem;
        self.count_read(name, elem.size_bytes());
        Ok(if elem == Scalar::Int {
            Slot::Int(v as i64)
        } else {
            Slot::Float(v)
        })
    }

    /// Shared tail of `StoreIndex` / `StoreIndexLocal`: count the index
    /// ops, locate the array, apply the (possibly compound) store.
    #[inline]
    fn store_index_value(
        &mut self,
        b: Storage,
        base: usize,
        name: u32,
        op: super::ast::AssignOp,
        idx: &[i64],
        rhs: Slot,
    ) -> Result<(), MiniCError> {
        self.total.i_op += idx.len() as u64;
        let aidx = self.array_of(b, base, name)?;
        let (elem_size, flat) = {
            let arr = &self.arena[aidx];
            (arr.elem.size_bytes(), arr.flat_index(idx)?)
        };
        let new = match op {
            super::ast::AssignOp::Set => rhs,
            compound => {
                let old = Slot::Float(self.arena[aidx].data[flat]);
                self.count_read(name, elem_size);
                let bin = match compound {
                    super::ast::AssignOp::AddSet => BinOp::Add,
                    super::ast::AssignOp::SubSet => BinOp::Sub,
                    super::ast::AssignOp::MulSet => BinOp::Mul,
                    super::ast::AssignOp::DivSet => BinOp::Div,
                    super::ast::AssignOp::Set => unreachable!(),
                };
                self.apply_bin(bin, old, rhs)?
            }
        };
        self.arena[aidx].data[flat] = slot_as_f64(new)?;
        self.count_write(name, elem_size);
        Ok(())
    }

    #[cold]
    fn trap_err(&self, id: u32) -> MiniCError {
        MiniCError::Runtime(self.module.traps[id as usize].clone())
    }

    fn enter_call(
        &mut self,
        callee: u16,
        argc: u8,
        ret_func: u16,
        ret_pc: u32,
    ) -> Result<(), MiniCError> {
        let argc = argc as usize;
        let args_start = self.stack.len() - argc;
        {
            let f = &self.module.funcs[callee as usize];
            for (p, a) in f.params.iter().zip(&self.stack[args_start..]) {
                match (&p.ty, a) {
                    (Type::Ptr(_) | Type::Array(..), Slot::Arr(_)) => {}
                    (Type::Scalar(_), Slot::Arr(_)) => {
                        return Err(MiniCError::Runtime(format!(
                            "array passed to scalar param `{}`",
                            p.name
                        )))
                    }
                    (Type::Ptr(_) | Type::Array(..), _) => {
                        return Err(MiniCError::Runtime(format!(
                            "scalar passed to array param `{}`",
                            p.name
                        )))
                    }
                    _ => {}
                }
            }
        }
        if self.frames.len() >= MAX_FRAMES {
            return Err(MiniCError::Runtime("call depth exceeded".into()));
        }
        let n_slots = self.module.funcs[callee as usize].n_slots as usize;
        let base = self.locals.len();
        self.frames.push(Frame {
            func: callee,
            ret_func,
            ret_pc,
            base: base as u32,
            loop_base: self.loop_stack.len() as u32,
        });
        self.locals.resize(base + n_slots, Slot::Int(0));
        for i in (0..argc).rev() {
            let v = self.stack.pop().expect("argument");
            self.locals[base + i] = v;
        }
        Ok(())
    }

    fn unwind_loops(&mut self, to: usize) {
        while self.loop_stack.len() > to {
            let (id, snapshot) = self.loop_stack.pop().expect("loop");
            let delta = self.total.delta_from(&snapshot);
            self.loop_slots[id.0 as usize].ops.accumulate(&delta);
        }
    }

    fn array_of(
        &self,
        b: Storage,
        base: usize,
        name: u32,
    ) -> Result<usize, MiniCError> {
        let slot = match b {
            Storage::Local(s) => self.locals[base + s as usize],
            Storage::Global(s) => self.globals[s as usize],
        };
        match slot {
            Slot::Arr(a) => Ok(a as usize),
            _ => Err(MiniCError::Runtime(format!(
                "`{}` is not an array",
                self.module.names[name as usize]
            ))),
        }
    }

    fn count_read(&mut self, name: u32, elem_size: u64) {
        self.total.reads += 1;
        self.total.read_bytes += elem_size;
        let (stack, slots) = (&self.loop_stack, &mut self.loop_slots);
        for (id, _) in stack {
            let set = &mut slots[id.0 as usize].arrays_read;
            if !set.contains(&name) {
                set.push(name);
            }
        }
    }

    fn count_write(&mut self, name: u32, elem_size: u64) {
        self.total.writes += 1;
        self.total.write_bytes += elem_size;
        let (stack, slots) = (&self.loop_stack, &mut self.loop_slots);
        for (id, _) in stack {
            let set = &mut slots[id.0 as usize].arrays_written;
            if !set.contains(&name) {
                set.push(name);
            }
        }
    }

    fn apply_bin(
        &mut self,
        op: BinOp,
        l: Slot,
        r: Slot,
    ) -> Result<Slot, MiniCError> {
        use BinOp::*;
        // Integer fast path (same typing rules as the tree-walker).
        if let (Slot::Int(a), Slot::Int(b)) = (l, r) {
            return Ok(match op {
                Add | Sub | Mul | Div | Rem => {
                    self.total.i_op += 1;
                    match op {
                        Add => Slot::Int(a.wrapping_add(b)),
                        Sub => Slot::Int(a.wrapping_sub(b)),
                        Mul => Slot::Int(a.wrapping_mul(b)),
                        Div => {
                            if b == 0 {
                                return Err(MiniCError::Runtime(
                                    "integer division by zero".into(),
                                ));
                            }
                            Slot::Int(a / b)
                        }
                        Rem => {
                            if b == 0 {
                                return Err(MiniCError::Runtime(
                                    "integer modulo by zero".into(),
                                ));
                            }
                            Slot::Int(a % b)
                        }
                        _ => unreachable!(),
                    }
                }
                Eq | Ne | Lt | Gt | Le | Ge => {
                    self.total.cmp += 1;
                    Slot::Int(int_cmp(op, a, b) as i64)
                }
                And | Or => unreachable!("lowered to AndCheck/OrCheck"),
            });
        }
        // Float path.
        let a = slot_as_f64(l)?;
        let b = slot_as_f64(r)?;
        Ok(match op {
            Add => {
                self.total.f_add += 1;
                Slot::Float(a + b)
            }
            Sub => {
                self.total.f_add += 1;
                Slot::Float(a - b)
            }
            Mul => {
                self.total.f_mul += 1;
                Slot::Float(a * b)
            }
            Div => {
                self.total.f_div += 1;
                Slot::Float(a / b)
            }
            Rem => {
                self.total.f_div += 1;
                Slot::Float(a % b)
            }
            Eq | Ne | Lt | Gt | Le | Ge => {
                self.total.cmp += 1;
                Slot::Int(float_cmp(op, a, b) as i64)
            }
            And | Or => unreachable!("lowered to AndCheck/OrCheck"),
        })
    }
}

fn coerce(sc: Scalar, v: Slot) -> Slot {
    match (sc, v) {
        (Scalar::Int, Slot::Float(f)) => Slot::Int(f as i64),
        (s, Slot::Int(i)) if s.is_floating() => Slot::Float(i as f64),
        (_, v) => v,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::parse;

    fn run_main(src: &str) -> (Value, Profile) {
        let prog = parse(src).unwrap();
        let mut vm = Vm::new(&prog).unwrap();
        let v = vm.call("main", &[]).unwrap();
        (v, vm.profile())
    }

    #[test]
    fn arithmetic_and_return() {
        let (v, _) = run_main("int main() { return 2 + 3 * 4; }");
        assert_eq!(v, Value::Int(14));
    }

    #[test]
    fn float_promotion() {
        let (v, _) = run_main(
            "int main() { float x = 3 / 2.0; return (int)(x * 10.0); }",
        );
        assert_eq!(v, Value::Int(15));
    }

    #[test]
    fn for_loop_profile_matches_interp_shape() {
        let (v, prof) = run_main(
            "int main() { int s = 0; for (int i = 0; i < 10; i++) { s += i; } return s; }",
        );
        assert_eq!(v, Value::Int(45));
        let lp = prof.loop_profile(LoopId(0)).unwrap();
        assert_eq!(lp.trips, 10);
        assert_eq!(lp.entries, 1);
    }

    #[test]
    fn early_return_attributes_open_loops() {
        let (v, prof) = run_main(
            "int main() { for (int i = 0; i < 100; i++) { if (i == 3) return i; } return -1; }",
        );
        assert_eq!(v, Value::Int(3));
        assert_eq!(prof.loop_profile(LoopId(0)).unwrap().trips, 4);
    }

    #[test]
    fn array_footprints_and_bounds() {
        let (_, prof) = run_main(
            "#define N 8\nfloat a[N]; float b[N];\n
             int main() {
               for (int i = 0; i < N; i++) { b[i] = a[i] * 2.0; }
               return 0; }",
        );
        let lp = prof.loop_profile(LoopId(0)).unwrap();
        assert!(lp.arrays_read.contains("a"));
        assert!(lp.arrays_written.contains("b"));
        assert!(!lp.arrays_written.contains("a"));
    }

    #[test]
    fn out_of_bounds_errors_and_vm_survives() {
        let prog = parse(
            "#define N 4\nfloat a[N];\nint main() { a[9] = 1.0; return 0; }\nint ok() { return 7; }",
        )
        .unwrap();
        let mut vm = Vm::new(&prog).unwrap();
        assert!(vm.call("main", &[]).is_err());
        // The VM unwinds to a reusable state after a runtime error.
        assert_eq!(vm.call("ok", &[]).unwrap(), Value::Int(7));
    }

    #[test]
    fn division_by_zero_errors() {
        let prog =
            parse("int main() { int x = 0; return 3 / x; }").unwrap();
        let mut vm = Vm::new(&prog).unwrap();
        assert!(vm.call("main", &[]).is_err());
    }

    #[test]
    fn user_functions_and_globals() {
        let (v, _) = run_main(
            "int counter;\n
             void bump() { counter = counter + 1; }\n
             int main() { bump(); bump(); bump(); return counter; }",
        );
        assert_eq!(v, Value::Int(3));
    }

    #[test]
    fn recursion_works() {
        let (v, _) = run_main(
            "int fib(int n) { if (n < 2) { return n; } return fib(n - 1) + fib(n - 2); }\n
             int main() { return fib(10); }",
        );
        assert_eq!(v, Value::Int(55));
    }

    #[test]
    fn mac_superinstruction_matches_tree_walker_exactly() {
        // The MAC-fused path must keep results, totals and per-loop
        // profiles bit-identical to the oracle — including the int
        // fast path and mixed int/float operands.
        let src = "
#define N 32
float a[N]; float b[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.125 - 1.0; b[i] = i * 0.25; }
    float acc = 0.0;
    int iacc = 0;
    for (int i = 0; i < N; i++) {
        acc += a[i] * b[i];
        iacc += i * 3;
        acc += b[i] * 2;
    }
    return (int) (acc + iacc);
}";
        let prog = parse(src).unwrap();
        let mut interp = crate::minic::Interp::new(&prog).unwrap();
        let vi = interp.call("main", &[]).unwrap();
        let pi = interp.profile();
        let mut vm = Vm::new(&prog).unwrap();
        let vv = vm.call("main", &[]).unwrap();
        let pv = vm.profile();
        assert_eq!(vi, vv);
        assert_eq!(pi.total, pv.total);
        for (id, lp) in &pi.loops {
            let lv = pv.loop_profile(*id).unwrap();
            assert_eq!(lp.ops, lv.ops, "{id}");
        }
    }

    #[test]
    fn mac_error_order_matches_unfused() {
        // `acc += a * b` where the multiply faults (array used as a
        // scalar): the error must surface exactly as without fusion,
        // leaving the VM reusable.
        let src = "
#define N 4
float a[N];
int main() { float acc = 0.0; acc += a * 2.0; return 0; }
int ok() { return 3; }";
        let prog = parse(src).unwrap();
        let mut vm = Vm::new(&prog).unwrap();
        assert!(vm.call("main", &[]).is_err());
        assert_eq!(vm.call("ok", &[]).unwrap(), Value::Int(3));
    }

    #[test]
    fn profile_identical_to_tree_walker_on_mixed_program() {
        let src = "
#define N 24
float a[N]; float b[N];
float acc;
void fill(float *x, int n) {
    for (int i = 0; i < n; i++) { x[i] = i * 0.25 - 1.0; }
}
int main() {
    fill(a, N);
    for (int i = 0; i < N; i++) {
        b[i] = sin(a[i]) * cos(a[i]) + sqrt(a[i] * a[i] + 1.0);
    }
    for (int i = 0; i < N; i++) { acc += b[i]; }
    int odd = 0;
    for (int i = 1; i < N; i += 2) { odd++; }
    while (odd > 0) { odd--; }
    return (int) acc;
}";
        let prog = parse(src).unwrap();
        let mut interp = crate::minic::Interp::new(&prog).unwrap();
        let vi = interp.call("main", &[]).unwrap();
        let pi = interp.profile();
        let mut vm = Vm::new(&prog).unwrap();
        let vv = vm.call("main", &[]).unwrap();
        let pv = vm.profile();
        assert_eq!(vi, vv);
        assert_eq!(pi.total, pv.total);
        assert_eq!(pi.loops.len(), pv.loops.len());
        for (id, lp) in &pi.loops {
            let lv = pv.loop_profile(*id).unwrap();
            assert_eq!(lp.entries, lv.entries, "{id}");
            assert_eq!(lp.trips, lv.trips, "{id}");
            assert_eq!(lp.ops, lv.ops, "{id}");
            assert_eq!(lp.arrays_read, lv.arrays_read, "{id}");
            assert_eq!(lp.arrays_written, lv.arrays_written, "{id}");
        }
        assert_eq!(
            interp.global_scalar("acc"),
            vm.global_scalar("acc")
        );
    }

    /// Run `main` under the oracle and under every VM encoding
    /// (default fused, baseline unfused, register experiment),
    /// asserting identical results and profiles throughout.
    fn diff_all_encodings(src: &str) -> Value {
        let prog = parse(src).unwrap();
        let mut interp = crate::minic::Interp::new(&prog).unwrap();
        let vi = interp.call("main", &[]).unwrap();
        let pi = interp.profile();
        for opts in [
            ResolveOpts::default(),
            ResolveOpts::baseline(),
            ResolveOpts::regs(),
        ] {
            let mut vm = Vm::new_with(&prog, &opts).unwrap();
            let vv = vm.call("main", &[]).unwrap();
            let pv = vm.profile();
            assert_eq!(vi, vv, "{opts:?}");
            assert_eq!(pi.total, pv.total, "{opts:?}");
            assert_eq!(pi.loops.len(), pv.loops.len(), "{opts:?}");
            for (id, lp) in &pi.loops {
                let lv = pv.loop_profile(*id).unwrap();
                assert_eq!(lp.entries, lv.entries, "{opts:?} {id}");
                assert_eq!(lp.trips, lv.trips, "{opts:?} {id}");
                assert_eq!(lp.ops, lv.ops, "{opts:?} {id}");
                assert_eq!(lp.arrays_read, lv.arrays_read, "{opts:?} {id}");
                assert_eq!(
                    lp.arrays_written, lv.arrays_written,
                    "{opts:?} {id}"
                );
            }
        }
        vi
    }

    /// Same as [`diff_all_encodings`] for a program whose `main`
    /// faults: every engine must produce the oracle's error string and
    /// stay reusable.
    fn diff_all_encodings_err(src: &str) -> String {
        let prog = parse(src).unwrap();
        let ei = crate::minic::Interp::new(&prog)
            .unwrap()
            .call("main", &[])
            .unwrap_err()
            .to_string();
        for opts in [
            ResolveOpts::default(),
            ResolveOpts::baseline(),
            ResolveOpts::regs(),
        ] {
            let mut vm = Vm::new_with(&prog, &opts).unwrap();
            let ev = vm.call("main", &[]).unwrap_err().to_string();
            assert_eq!(ei, ev, "{opts:?}");
            assert_eq!(vm.call("ok", &[]).unwrap(), Value::Int(1), "{opts:?}");
        }
        ei
    }

    #[test]
    fn fused_index_ops_match_tree_walker_exactly() {
        // Exercises every §PGO superinstruction the bundled workloads
        // hit: StoreIndexLocal (rank 1 and 2), LoadIndexLocal (rank 1
        // and 2), LoadIndexBin (computed innermost index feeding a
        // multiply), BinConstInt, CompoundLocalConst, CmpConstJump,
        // plus MacLocal alongside them.
        let v = diff_all_encodings(
            "
#define R 3
#define C 4
float t[R][C]; float x[C];
int main() {
    float acc = 0.0;
    int cnt = 0;
    for (int r = 0; r < R; r++) {
        for (int c = 0; c < C; c++) {
            t[r][c] = r * 1.0 + c * 0.5;
            x[c] = c * 0.25 + 1.0;
        }
    }
    for (int r = 0; r < R; r++) {
        for (int c = 1; c < C; c++) {
            acc += t[r][c] * x[c - 1];
            acc = acc + 2.0 * x[c - 1];
            cnt += t[r][c] > 1.0;
            t[r][c] += x[c] / 2.0;
        }
    }
    return (int) acc + cnt;
}",
        );
        assert!(matches!(v, Value::Int(_)));
    }

    #[test]
    fn fused_int_element_loads_match_tree_walker() {
        // Int-element arrays take the `Slot::Int` branch of the shared
        // load tail; local arrays take the `Storage::Local` branch.
        diff_all_encodings(
            "
#define N 8
int g[N];
int main() {
    int m[N];
    for (int i = 0; i < N; i++) { m[i] = i * 3; g[i] = m[i] - 1; }
    int s = 0;
    for (int i = 0; i < N; i++) {
        s += g[i] * m[i];
        s = s + 2 * g[i];
        s += 5;
    }
    return s;
}",
        );
    }

    #[test]
    fn fused_store_out_of_bounds_matches_unfused_error() {
        diff_all_encodings_err(
            "
#define N 4
float s[N];
int main() { int i = 9; s[i] = 1.0; return 0; }
int ok() { return 1; }",
        );
    }

    #[test]
    fn fused_load_out_of_bounds_matches_unfused_error() {
        diff_all_encodings_err(
            "
#define N 4
float s[N];
int main() { int i = 7; float v = s[i]; return (int) v; }
int ok() { return 1; }",
        );
    }

    #[test]
    fn cmp_const_jump_fault_matches_unfused_error() {
        // `a < 4` on an array operand faults inside the fused
        // compare-and-branch; the error string must match the oracle's
        // unfused compare.
        let e = diff_all_encodings_err(
            "
#define N 4
float a[N];
int main() { int n = 0; while (a < 4) { n++; } return n; }
int ok() { return 1; }",
        );
        assert!(e.contains("array used as scalar"), "{e}");
    }

    #[test]
    fn profiled_run_is_invisible_and_counters_total() {
        let src = "
#define N 16
float a[N];
int main() {
    float acc = 0.0;
    for (int i = 0; i < N; i++) { a[i] = i * 0.5; }
    for (int i = 0; i < N; i++) { acc += a[i] * 2.0; }
    return (int) acc;
}";
        let prog = parse(src).unwrap();
        let mut plain = Vm::new(&prog).unwrap();
        let vp = plain.call("main", &[]).unwrap();
        assert!(plain.instr_profiler().is_none());

        let mut prof = Vm::new_profiled(&prog).unwrap();
        let vq = prof.call("main", &[]).unwrap();
        // Profiling is observationally invisible: same value, same
        // counters, same dispatch count.
        assert_eq!(vp, vq);
        assert_eq!(plain.profile().total, prof.profile().total);
        assert_eq!(plain.dispatches(), prof.dispatches());

        let p = prof.instr_profiler().unwrap();
        assert_eq!(p.dispatches(), prof.dispatches());
        let total: u64 = Op::ALL.iter().map(|op| p.count(*op)).sum();
        assert_eq!(total, prof.dispatches());
        assert_eq!(p.pair_total(), p.dispatches() - 1);
        assert!(p.count(Op::CmpConstJump) > 0);
        assert!(p.count(Op::CompoundLocalConst) > 0);
        assert_eq!(p.count(Op::JumpIfFalse), 0);

        // The baseline encoding profiles the unfused pairs instead —
        // this is the measurement that justifies the fusions.
        let mut b =
            Vm::new_profiled_with(&prog, &ResolveOpts::baseline()).unwrap();
        assert_eq!(b.call("main", &[]).unwrap(), vp);
        let pb = b.instr_profiler().unwrap();
        assert_eq!(pb.count(Op::CmpConstJump), 0);
        assert!(pb.count(Op::JumpIfFalse) > 0);
        assert!(pb.pair(Op::ConstInt, Op::CompoundLocal) > 0);
    }

    #[test]
    fn profiler_counts_cover_error_paths() {
        // The hook runs before the fault, so counter totals equal the
        // dispatch count even when `main` errors.
        let src = "
int main() { int x = 0; for (int i = 0; i < 9; i++) { x += 3 / (4 - i); } return x; }";
        let prog = parse(src).unwrap();
        let mut vm = Vm::new_profiled(&prog).unwrap();
        assert!(vm.call("main", &[]).is_err());
        let p = vm.instr_profiler().unwrap();
        let total: u64 = Op::ALL.iter().map(|op| p.count(*op)).sum();
        assert_eq!(total, vm.dispatches());
    }
}
