//! Token model for the MiniC frontend.

use std::fmt;

/// A lexical token with its source position.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub kind: TokenKind,
    /// 1-based line.
    pub line: u32,
    /// 1-based column.
    pub col: u32,
}

/// Token kinds for the C subset.
#[derive(Debug, Clone, PartialEq)]
pub enum TokenKind {
    // Literals and identifiers
    Ident(String),
    IntLit(i64),
    FloatLit(f64),
    StrLit(String),

    // Keywords
    KwInt,
    KwFloat,
    KwDouble,
    KwVoid,
    KwConst,
    KwIf,
    KwElse,
    KwFor,
    KwWhile,
    KwReturn,
    KwDefine, // from `#define` preprocessing

    // Punctuation
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Semi,
    Comma,

    // Operators
    Assign,     // =
    PlusAssign, // +=
    MinusAssign,
    StarAssign,
    SlashAssign,
    Plus,
    Minus,
    Star,
    Slash,
    Percent,
    PlusPlus,
    MinusMinus,
    Eq,  // ==
    Ne,  // !=
    Lt,  // <
    Gt,  // >
    Le,  // <=
    Ge,  // >=
    AndAnd,
    OrOr,
    Not,
    Amp, // & (only in declarator/address contexts we accept)

    Eof,
}

impl TokenKind {
    /// Keyword lookup for an identifier-shaped lexeme.
    pub fn keyword(s: &str) -> Option<TokenKind> {
        Some(match s {
            "int" => TokenKind::KwInt,
            "float" => TokenKind::KwFloat,
            "double" => TokenKind::KwDouble,
            "void" => TokenKind::KwVoid,
            "const" => TokenKind::KwConst,
            "if" => TokenKind::KwIf,
            "else" => TokenKind::KwElse,
            "for" => TokenKind::KwFor,
            "while" => TokenKind::KwWhile,
            "return" => TokenKind::KwReturn,
            _ => return None,
        })
    }
}

impl fmt::Display for TokenKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        use TokenKind::*;
        match self {
            Ident(s) => write!(f, "identifier `{s}`"),
            IntLit(v) => write!(f, "integer literal {v}"),
            FloatLit(v) => write!(f, "float literal {v}"),
            StrLit(s) => write!(f, "string literal {s:?}"),
            KwInt => write!(f, "`int`"),
            KwFloat => write!(f, "`float`"),
            KwDouble => write!(f, "`double`"),
            KwVoid => write!(f, "`void`"),
            KwConst => write!(f, "`const`"),
            KwIf => write!(f, "`if`"),
            KwElse => write!(f, "`else`"),
            KwFor => write!(f, "`for`"),
            KwWhile => write!(f, "`while`"),
            KwReturn => write!(f, "`return`"),
            KwDefine => write!(f, "`#define`"),
            LParen => write!(f, "`(`"),
            RParen => write!(f, "`)`"),
            LBrace => write!(f, "`{{`"),
            RBrace => write!(f, "`}}`"),
            LBracket => write!(f, "`[`"),
            RBracket => write!(f, "`]`"),
            Semi => write!(f, "`;`"),
            Comma => write!(f, "`,`"),
            Assign => write!(f, "`=`"),
            PlusAssign => write!(f, "`+=`"),
            MinusAssign => write!(f, "`-=`"),
            StarAssign => write!(f, "`*=`"),
            SlashAssign => write!(f, "`/=`"),
            Plus => write!(f, "`+`"),
            Minus => write!(f, "`-`"),
            Star => write!(f, "`*`"),
            Slash => write!(f, "`/`"),
            Percent => write!(f, "`%`"),
            PlusPlus => write!(f, "`++`"),
            MinusMinus => write!(f, "`--`"),
            Eq => write!(f, "`==`"),
            Ne => write!(f, "`!=`"),
            Lt => write!(f, "`<`"),
            Gt => write!(f, "`>`"),
            Le => write!(f, "`<=`"),
            Ge => write!(f, "`>=`"),
            AndAnd => write!(f, "`&&`"),
            OrOr => write!(f, "`||`"),
            Not => write!(f, "`!`"),
            Amp => write!(f, "`&`"),
            Eof => write!(f, "end of input"),
        }
    }
}
