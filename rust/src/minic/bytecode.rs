//! Compact bytecode for the slot-resolved MiniC VM (§Perf).
//!
//! The tree-walking interpreter pays for name resolution (hash lookups in
//! scoped maps), AST pointer chasing, and per-call `body.clone()` on every
//! hot-path statement. This module defines the flat program the
//! [`crate::minic::resolve`] pass lowers to instead: identifiers are
//! interned, locals/params live in dense frame slots, globals in a flat
//! slot vector, and loop profiling markers ([`Instr::LoopEnter`] /
//! [`Instr::LoopTrip`] / [`Instr::LoopExit`]) carry their [`LoopId`] so
//! the VM maintains the same per-loop profiles as the tree-walker with no
//! hashing on the trip path.
//!
//! Design rules:
//! * Instructions are `Copy` and fixed-size; dispatch fetches by value.
//! * Control flow is intra-function only (`Jump`/`JumpIfFalse` hold
//!   absolute instruction indices); calls push VM frames.
//! * Anything the tree-walker only rejects *at runtime* (undeclared
//!   names, unknown calls, bad arity) compiles to [`Instr::Trap`] with
//!   the equivalent message, so dead code stays executable-equivalent.

use crate::util::fnv::FnvMap;

use super::ast::{AssignOp, BinOp, LoopId, Param, Scalar};

/// One-argument math builtins (dispatch table kept in the VM).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Builtin1 {
    Sin,
    Cos,
    Tan,
    Sqrt,
    Exp,
    Log,
    Fabs,
    Floor,
    Ceil,
}

impl Builtin1 {
    /// Lookup by source name (mirrors the tree-walker's builtin table).
    pub fn from_name(name: &str) -> Option<Builtin1> {
        Some(match name {
            "sin" => Builtin1::Sin,
            "cos" => Builtin1::Cos,
            "tan" => Builtin1::Tan,
            "sqrt" | "sqrtf" => Builtin1::Sqrt,
            "exp" => Builtin1::Exp,
            "log" => Builtin1::Log,
            "fabs" => Builtin1::Fabs,
            "floor" => Builtin1::Floor,
            "ceil" => Builtin1::Ceil,
            _ => return None,
        })
    }

    pub fn eval(self, v: f64) -> f64 {
        match self {
            Builtin1::Sin => v.sin(),
            Builtin1::Cos => v.cos(),
            Builtin1::Tan => v.tan(),
            Builtin1::Sqrt => v.sqrt(),
            Builtin1::Exp => v.exp(),
            Builtin1::Log => v.ln(),
            Builtin1::Fabs => v.abs(),
            Builtin1::Floor => v.floor(),
            Builtin1::Ceil => v.ceil(),
        }
    }
}

/// Two-argument builtins.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Builtin2 {
    Fmin,
    Fmax,
    Pow,
}

impl Builtin2 {
    pub fn from_name(name: &str) -> Option<Builtin2> {
        Some(match name {
            "fmin" => Builtin2::Fmin,
            "fmax" => Builtin2::Fmax,
            "pow" => Builtin2::Pow,
            _ => return None,
        })
    }
}

/// Where an lvalue/rvalue slot lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Storage {
    /// Frame-relative local slot.
    Local(u16),
    /// Module-global slot.
    Global(u16),
}

/// One VM instruction. All variants are `Copy`; jump targets are
/// absolute indices into the owning function's `code`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Instr {
    ConstInt(i64),
    ConstFloat(f64),
    LoadLocal(u16),
    StoreLocal(u16),
    /// Declaration store: coerce to the declared scalar type first
    /// (`int x = 1.5;` truncates, `float x = 3;` promotes).
    StoreLocalCoerce(u16, Scalar),
    LoadGlobal(u16),
    StoreGlobal(u16),
    /// Pop rhs, apply `old <op> rhs` against the slot, store back.
    /// Mirrors the tree-walker's compound assignment (old value is read
    /// *after* the rhs evaluates).
    CompoundLocal(u16, BinOp),
    CompoundGlobal(u16, BinOp),
    /// Superinstruction for the workloads' MAC pattern
    /// (`acc += a[i] * b[j]`): fuses `Bin(Mul)` + `CompoundLocal(s, Add)`
    /// into one dispatch. Pops the two product operands, multiplies,
    /// and compound-adds into the local — operand typing, op counts and
    /// error order are byte-identical to the unfused pair (the
    /// differential test holds across the fusion).
    MacLocal(u16),
    /// Re-zero a declared scalar slot (a `Decl` re-executes per loop
    /// iteration in the tree-walker, resetting the variable).
    ZeroLocal(u16, Scalar),
    /// Allocate a fresh arena array for a local array declaration
    /// (again per-execution, matching the tree-walker). `dims` indexes
    /// [`Module::array_dims`].
    AllocLocalArray { slot: u16, dims: u16 },
    /// `base[i...]` read: pops `rank` indices (last on top), counts
    /// `rank` address ops + one element read attributed to `name`.
    LoadIndex { base: Storage, rank: u8, name: u32 },
    /// `base[i...] (op)= v`: pops `rank` indices then the rhs value.
    StoreIndex {
        base: Storage,
        rank: u8,
        name: u32,
        op: AssignOp,
    },
    /// Pops rhs then lhs; applies the operator with the tree-walker's
    /// int-fast-path / float-promotion and op-count semantics.
    Bin(BinOp),
    Neg,
    Not,
    CastInt,
    CastFloat,
    /// `total.cmp += 1` — the explicit branch/loop-condition count the
    /// tree-walker performs besides the comparison itself.
    BumpCmp,
    Jump(u32),
    /// Pops; jumps when falsy. Counts nothing (callers emit `BumpCmp`).
    JumpIfFalse(u32),
    /// `&&` lhs check: pops, counts one cmp; when falsy pushes `Int(0)`
    /// and jumps past the rhs.
    AndCheck(u32),
    /// `||` lhs check: pops, counts one cmp; when truthy pushes `Int(1)`
    /// and jumps past the rhs.
    OrCheck(u32),
    /// Pop a value, push `Int(truthy as i64)` (no counts) — normalizes
    /// the rhs of `&&`/`||`.
    ToBool,
    Pop,
    /// Loop header entered: push loop stack entry (snapshot) and count
    /// one entry.
    LoopEnter(LoopId),
    /// One iteration admitted (condition held).
    LoopTrip(LoopId),
    /// Loop exited: pop the stack entry, attribute the op-count delta.
    LoopExit,
    /// Call a user function (index into [`Module::funcs`]); pops `argc`
    /// arguments (first argument deepest).
    Call { func: u16, argc: u8 },
    Builtin1(Builtin1),
    Builtin2(Builtin2),
    /// Pop the return value, unwind the frame (attributing any still-
    /// open loops of this frame), and resume the caller.
    Return,
    /// Deferred runtime error (message in [`Module::traps`]). Emitted
    /// where the tree-walker would fail at execution time, so programs
    /// whose errors live in dead code behave identically.
    Trap(u32),

    // ---- superinstructions (§PGO) ----
    //
    // Each fuses one measured-hot adjacent pair from the baseline
    // encoding's pair-frequency report (`repro vmprofile`) into a
    // single dispatch. All fusions are *in-place*: the peephole in
    // `resolve.rs` overwrites the pair's first instruction when pushing
    // the second, so code length, every jump target, and all
    // observable semantics (pop order, op counts, error order) are
    // unchanged — the differential fuzzer pins each against the
    // tree-walker oracle.
    /// `LoadLocal(idx)` + `LoadIndex`: the last (innermost) index comes
    /// straight from a frame slot; `rank - 1` outer indices still pop.
    LoadIndexLocal {
        base: Storage,
        rank: u8,
        idx: u16,
        name: u32,
    },
    /// `LoadLocal(idx)` + `StoreIndex`: same, for the store side.
    StoreIndexLocal {
        base: Storage,
        rank: u8,
        idx: u16,
        name: u32,
        op: AssignOp,
    },
    /// `LoadIndex` + `Bin(op)`: the indexed load feeds the operator as
    /// its rhs without a push/pop round trip — the index-chain pair the
    /// workloads' tap/stencil loops are made of.
    LoadIndexBin {
        base: Storage,
        rank: u8,
        name: u32,
        op: BinOp,
    },
    /// `ConstInt(v)` + `Bin(op)`: constant rhs (folded `#define` loop
    /// bounds, modulo constants).
    BinConstInt(BinOp, i64),
    /// `ConstInt(v)` + `CompoundLocal(slot, op)`: the `i++` / `i += c`
    /// loop-step shape. Constants beyond `i32` stay unfused.
    CompoundLocalConst { slot: u16, op: BinOp, v: i32 },
    /// `BinConstInt(op, v)` + `JumpIfFalse(target)`: the whole
    /// `i < N`-and-branch loop condition in one dispatch. Constants
    /// beyond `i32` stay unfused.
    CmpConstJump { op: BinOp, v: i32, target: u32 },
    /// `LoadLocal(slot)` + `Bin(op)`: register-style rhs operand read
    /// directly from the frame slot. Only emitted under the gated
    /// `vm-regs` encoding experiment (see `resolve::ResolveOpts`).
    BinLocal { slot: u16, op: BinOp },
}

/// A compiled function.
#[derive(Debug, Clone)]
pub struct FuncCode {
    pub name: String,
    /// Original parameters (used for call-site type checks).
    pub params: Vec<Param>,
    /// Total frame slots (params occupy `0..params.len()`).
    pub n_slots: u16,
    pub code: Vec<Instr>,
}

/// How a global slot is materialized at VM construction.
#[derive(Debug, Clone)]
pub enum GlobalKind {
    /// `#define` constant, integral value.
    DefineInt(i64),
    /// `#define` constant, fractional value.
    DefineFloat(f64),
    /// Scalar global, zero-initialized (`int` ⇒ `Int(0)`).
    ScalarInt,
    /// Scalar global, zero-initialized (`float`/`double`/`void`).
    ScalarFloat,
    /// Array global: arena-allocated at construction.
    Array(Scalar, Vec<usize>),
}

/// One global slot.
#[derive(Debug, Clone)]
pub struct GlobalDecl {
    pub name: String,
    pub kind: GlobalKind,
}

/// A fully lowered program.
#[derive(Debug, Clone)]
pub struct Module {
    pub funcs: Vec<FuncCode>,
    /// First function with each name wins (mirrors `Program::function`).
    pub func_names: FnvMap<String, u16>,
    /// Index into `funcs` of the synthetic global-initializer chunk
    /// (run once at VM construction, instrumented like the tree-walker).
    pub init_func: u16,
    pub globals: Vec<GlobalDecl>,
    /// Final name → slot binding (later declarations shadow earlier).
    pub global_names: FnvMap<String, u16>,
    /// Interned array names for footprint attribution.
    pub names: Vec<String>,
    /// Dim tables for `AllocLocalArray`.
    pub array_dims: Vec<(Scalar, Vec<usize>)>,
    /// Messages for `Trap`.
    pub traps: Vec<String>,
    pub loop_count: u32,
}

impl Module {
    pub fn func(&self, name: &str) -> Option<u16> {
        self.func_names.get(name).copied()
    }

    /// Total compiled instruction count (diagnostics / tests).
    pub fn code_len(&self) -> usize {
        self.funcs.iter().map(|f| f.code.len()).sum()
    }

    /// Deterministic text disassembly of every function, in module
    /// order. Interned ids are resolved back to source names so the
    /// output reads like the program; the golden-file tests
    /// (`tests/bytecode_golden.rs`) pin each bundled workload's
    /// disassembly so encoding changes show up as reviewable diffs.
    pub fn disassemble(&self) -> String {
        let mut out = String::new();
        for f in &self.funcs {
            out.push_str(&format!(
                "fn {}(params={}, slots={})\n",
                f.name,
                f.params.len(),
                f.n_slots
            ));
            for (i, instr) in f.code.iter().enumerate() {
                out.push_str(&format!(
                    "  {:>4}  {}\n",
                    i,
                    self.disasm_instr(instr)
                ));
            }
        }
        out
    }

    fn storage_name(&self, s: Storage) -> String {
        match s {
            Storage::Local(i) => format!("l{i}"),
            Storage::Global(i) => {
                format!("g{i}({})", self.globals[i as usize].name)
            }
        }
    }

    fn disasm_instr(&self, instr: &Instr) -> String {
        let arr = |name: &u32| self.names[*name as usize].clone();
        match instr {
            Instr::ConstInt(v) => format!("ConstInt {v}"),
            Instr::ConstFloat(v) => format!("ConstFloat {v:?}"),
            Instr::LoadLocal(s) => format!("LoadLocal l{s}"),
            Instr::StoreLocal(s) => format!("StoreLocal l{s}"),
            Instr::StoreLocalCoerce(s, sc) => {
                format!("StoreLocalCoerce l{s} {sc:?}")
            }
            Instr::LoadGlobal(s) => {
                format!("LoadGlobal {}", self.storage_name(Storage::Global(*s)))
            }
            Instr::StoreGlobal(s) => {
                format!("StoreGlobal {}", self.storage_name(Storage::Global(*s)))
            }
            Instr::CompoundLocal(s, op) => {
                format!("CompoundLocal l{s} {op:?}")
            }
            Instr::CompoundGlobal(s, op) => format!(
                "CompoundGlobal {} {op:?}",
                self.storage_name(Storage::Global(*s))
            ),
            Instr::MacLocal(s) => format!("MacLocal l{s}"),
            Instr::ZeroLocal(s, sc) => format!("ZeroLocal l{s} {sc:?}"),
            Instr::AllocLocalArray { slot, dims } => {
                let (elem, d) = &self.array_dims[*dims as usize];
                format!("AllocLocalArray l{slot} {elem:?}{d:?}")
            }
            Instr::LoadIndex { base, rank, name } => format!(
                "LoadIndex {} rank={rank} ({})",
                self.storage_name(*base),
                arr(name)
            ),
            Instr::StoreIndex { base, rank, name, op } => format!(
                "StoreIndex {} rank={rank} {op:?} ({})",
                self.storage_name(*base),
                arr(name)
            ),
            Instr::Bin(op) => format!("Bin {op:?}"),
            Instr::Neg => "Neg".into(),
            Instr::Not => "Not".into(),
            Instr::CastInt => "CastInt".into(),
            Instr::CastFloat => "CastFloat".into(),
            Instr::BumpCmp => "BumpCmp".into(),
            Instr::Jump(t) => format!("Jump -> {t}"),
            Instr::JumpIfFalse(t) => format!("JumpIfFalse -> {t}"),
            Instr::AndCheck(t) => format!("AndCheck -> {t}"),
            Instr::OrCheck(t) => format!("OrCheck -> {t}"),
            Instr::ToBool => "ToBool".into(),
            Instr::Pop => "Pop".into(),
            Instr::LoopEnter(id) => format!("LoopEnter L{}", id.0),
            Instr::LoopTrip(id) => format!("LoopTrip L{}", id.0),
            Instr::LoopExit => "LoopExit".into(),
            Instr::Call { func, argc } => format!(
                "Call {}({} args)",
                self.funcs[*func as usize].name, argc
            ),
            Instr::Builtin1(b) => format!("Builtin1 {b:?}"),
            Instr::Builtin2(b) => format!("Builtin2 {b:?}"),
            Instr::Return => "Return".into(),
            Instr::Trap(id) => {
                format!("Trap {:?}", self.traps[*id as usize])
            }
            Instr::LoadIndexLocal { base, rank, idx, name } => format!(
                "LoadIndexLocal {} rank={rank} idx=l{idx} ({})",
                self.storage_name(*base),
                arr(name)
            ),
            Instr::StoreIndexLocal { base, rank, idx, name, op } => format!(
                "StoreIndexLocal {} rank={rank} idx=l{idx} {op:?} ({})",
                self.storage_name(*base),
                arr(name)
            ),
            Instr::LoadIndexBin { base, rank, name, op } => format!(
                "LoadIndexBin {} rank={rank} {op:?} ({})",
                self.storage_name(*base),
                arr(name)
            ),
            Instr::BinConstInt(op, v) => format!("BinConstInt {op:?} {v}"),
            Instr::CompoundLocalConst { slot, op, v } => {
                format!("CompoundLocalConst l{slot} {op:?} {v}")
            }
            Instr::CmpConstJump { op, v, target } => {
                format!("CmpConstJump {op:?} {v} -> {target}")
            }
            Instr::BinLocal { slot, op } => {
                format!("BinLocal l{slot} {op:?}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::{parse, resolve};

    #[test]
    fn instructions_stay_word_pair_sized() {
        // The dispatch loop fetches instructions by value; keeping the
        // enum at 16 bytes is why the superinstruction payloads carry
        // `i32` constants rather than `i64`.
        assert!(std::mem::size_of::<Instr>() <= 16);
    }

    #[test]
    fn disassembly_resolves_names_and_targets() {
        let prog = parse(
            "#define N 4\nfloat a[N];\n\
             int main() {\n\
                 float s = 0.0;\n\
                 for (int i = 0; i < N; i++) { s += a[i] * 2.0; }\n\
                 return (int) s;\n\
             }",
        )
        .unwrap();
        let m = resolve::compile(&prog).unwrap();
        let text = m.disassemble();
        assert!(text.contains("fn main(params=0, slots="), "{text}");
        assert!(text.contains("fn @init"), "{text}");
        assert!(text.contains("(a)"), "{text}");
        assert!(text.contains("LoopEnter L0"), "{text}");
        // Every function disassembles every instruction.
        let lines = text.lines().filter(|l| !l.starts_with("fn ")).count();
        assert_eq!(lines, m.code_len());
    }

    #[test]
    fn disassembly_is_deterministic() {
        let prog = parse(
            "int f(int x) { return x * 3; }\n\
             int main() { return f(2) + f(3); }",
        )
        .unwrap();
        let a = resolve::compile(&prog).unwrap().disassemble();
        let b = resolve::compile(&prog).unwrap().disassemble();
        assert_eq!(a, b);
        assert!(a.contains("Call f(1 args)"), "{a}");
    }
}

