//! Compact bytecode for the slot-resolved MiniC VM (§Perf).
//!
//! The tree-walking interpreter pays for name resolution (hash lookups in
//! scoped maps), AST pointer chasing, and per-call `body.clone()` on every
//! hot-path statement. This module defines the flat program the
//! [`crate::minic::resolve`] pass lowers to instead: identifiers are
//! interned, locals/params live in dense frame slots, globals in a flat
//! slot vector, and loop profiling markers ([`Instr::LoopEnter`] /
//! [`Instr::LoopTrip`] / [`Instr::LoopExit`]) carry their [`LoopId`] so
//! the VM maintains the same per-loop profiles as the tree-walker with no
//! hashing on the trip path.
//!
//! Design rules:
//! * Instructions are `Copy` and fixed-size; dispatch fetches by value.
//! * Control flow is intra-function only (`Jump`/`JumpIfFalse` hold
//!   absolute instruction indices); calls push VM frames.
//! * Anything the tree-walker only rejects *at runtime* (undeclared
//!   names, unknown calls, bad arity) compiles to [`Instr::Trap`] with
//!   the equivalent message, so dead code stays executable-equivalent.

use crate::util::fnv::FnvMap;

use super::ast::{AssignOp, BinOp, LoopId, Param, Scalar};

/// One-argument math builtins (dispatch table kept in the VM).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Builtin1 {
    Sin,
    Cos,
    Tan,
    Sqrt,
    Exp,
    Log,
    Fabs,
    Floor,
    Ceil,
}

impl Builtin1 {
    /// Lookup by source name (mirrors the tree-walker's builtin table).
    pub fn from_name(name: &str) -> Option<Builtin1> {
        Some(match name {
            "sin" => Builtin1::Sin,
            "cos" => Builtin1::Cos,
            "tan" => Builtin1::Tan,
            "sqrt" | "sqrtf" => Builtin1::Sqrt,
            "exp" => Builtin1::Exp,
            "log" => Builtin1::Log,
            "fabs" => Builtin1::Fabs,
            "floor" => Builtin1::Floor,
            "ceil" => Builtin1::Ceil,
            _ => return None,
        })
    }

    pub fn eval(self, v: f64) -> f64 {
        match self {
            Builtin1::Sin => v.sin(),
            Builtin1::Cos => v.cos(),
            Builtin1::Tan => v.tan(),
            Builtin1::Sqrt => v.sqrt(),
            Builtin1::Exp => v.exp(),
            Builtin1::Log => v.ln(),
            Builtin1::Fabs => v.abs(),
            Builtin1::Floor => v.floor(),
            Builtin1::Ceil => v.ceil(),
        }
    }
}

/// Two-argument builtins.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Builtin2 {
    Fmin,
    Fmax,
    Pow,
}

impl Builtin2 {
    pub fn from_name(name: &str) -> Option<Builtin2> {
        Some(match name {
            "fmin" => Builtin2::Fmin,
            "fmax" => Builtin2::Fmax,
            "pow" => Builtin2::Pow,
            _ => return None,
        })
    }
}

/// Where an lvalue/rvalue slot lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Storage {
    /// Frame-relative local slot.
    Local(u16),
    /// Module-global slot.
    Global(u16),
}

/// One VM instruction. All variants are `Copy`; jump targets are
/// absolute indices into the owning function's `code`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Instr {
    ConstInt(i64),
    ConstFloat(f64),
    LoadLocal(u16),
    StoreLocal(u16),
    /// Declaration store: coerce to the declared scalar type first
    /// (`int x = 1.5;` truncates, `float x = 3;` promotes).
    StoreLocalCoerce(u16, Scalar),
    LoadGlobal(u16),
    StoreGlobal(u16),
    /// Pop rhs, apply `old <op> rhs` against the slot, store back.
    /// Mirrors the tree-walker's compound assignment (old value is read
    /// *after* the rhs evaluates).
    CompoundLocal(u16, BinOp),
    CompoundGlobal(u16, BinOp),
    /// Superinstruction for the workloads' MAC pattern
    /// (`acc += a[i] * b[j]`): fuses `Bin(Mul)` + `CompoundLocal(s, Add)`
    /// into one dispatch. Pops the two product operands, multiplies,
    /// and compound-adds into the local — operand typing, op counts and
    /// error order are byte-identical to the unfused pair (the
    /// differential test holds across the fusion).
    MacLocal(u16),
    /// Re-zero a declared scalar slot (a `Decl` re-executes per loop
    /// iteration in the tree-walker, resetting the variable).
    ZeroLocal(u16, Scalar),
    /// Allocate a fresh arena array for a local array declaration
    /// (again per-execution, matching the tree-walker). `dims` indexes
    /// [`Module::array_dims`].
    AllocLocalArray { slot: u16, dims: u16 },
    /// `base[i...]` read: pops `rank` indices (last on top), counts
    /// `rank` address ops + one element read attributed to `name`.
    LoadIndex { base: Storage, rank: u8, name: u32 },
    /// `base[i...] (op)= v`: pops `rank` indices then the rhs value.
    StoreIndex {
        base: Storage,
        rank: u8,
        name: u32,
        op: AssignOp,
    },
    /// Pops rhs then lhs; applies the operator with the tree-walker's
    /// int-fast-path / float-promotion and op-count semantics.
    Bin(BinOp),
    Neg,
    Not,
    CastInt,
    CastFloat,
    /// `total.cmp += 1` — the explicit branch/loop-condition count the
    /// tree-walker performs besides the comparison itself.
    BumpCmp,
    Jump(u32),
    /// Pops; jumps when falsy. Counts nothing (callers emit `BumpCmp`).
    JumpIfFalse(u32),
    /// `&&` lhs check: pops, counts one cmp; when falsy pushes `Int(0)`
    /// and jumps past the rhs.
    AndCheck(u32),
    /// `||` lhs check: pops, counts one cmp; when truthy pushes `Int(1)`
    /// and jumps past the rhs.
    OrCheck(u32),
    /// Pop a value, push `Int(truthy as i64)` (no counts) — normalizes
    /// the rhs of `&&`/`||`.
    ToBool,
    Pop,
    /// Loop header entered: push loop stack entry (snapshot) and count
    /// one entry.
    LoopEnter(LoopId),
    /// One iteration admitted (condition held).
    LoopTrip(LoopId),
    /// Loop exited: pop the stack entry, attribute the op-count delta.
    LoopExit,
    /// Call a user function (index into [`Module::funcs`]); pops `argc`
    /// arguments (first argument deepest).
    Call { func: u16, argc: u8 },
    Builtin1(Builtin1),
    Builtin2(Builtin2),
    /// Pop the return value, unwind the frame (attributing any still-
    /// open loops of this frame), and resume the caller.
    Return,
    /// Deferred runtime error (message in [`Module::traps`]). Emitted
    /// where the tree-walker would fail at execution time, so programs
    /// whose errors live in dead code behave identically.
    Trap(u32),
}

/// A compiled function.
#[derive(Debug, Clone)]
pub struct FuncCode {
    pub name: String,
    /// Original parameters (used for call-site type checks).
    pub params: Vec<Param>,
    /// Total frame slots (params occupy `0..params.len()`).
    pub n_slots: u16,
    pub code: Vec<Instr>,
}

/// How a global slot is materialized at VM construction.
#[derive(Debug, Clone)]
pub enum GlobalKind {
    /// `#define` constant, integral value.
    DefineInt(i64),
    /// `#define` constant, fractional value.
    DefineFloat(f64),
    /// Scalar global, zero-initialized (`int` ⇒ `Int(0)`).
    ScalarInt,
    /// Scalar global, zero-initialized (`float`/`double`/`void`).
    ScalarFloat,
    /// Array global: arena-allocated at construction.
    Array(Scalar, Vec<usize>),
}

/// One global slot.
#[derive(Debug, Clone)]
pub struct GlobalDecl {
    pub name: String,
    pub kind: GlobalKind,
}

/// A fully lowered program.
#[derive(Debug, Clone)]
pub struct Module {
    pub funcs: Vec<FuncCode>,
    /// First function with each name wins (mirrors `Program::function`).
    pub func_names: FnvMap<String, u16>,
    /// Index into `funcs` of the synthetic global-initializer chunk
    /// (run once at VM construction, instrumented like the tree-walker).
    pub init_func: u16,
    pub globals: Vec<GlobalDecl>,
    /// Final name → slot binding (later declarations shadow earlier).
    pub global_names: FnvMap<String, u16>,
    /// Interned array names for footprint attribution.
    pub names: Vec<String>,
    /// Dim tables for `AllocLocalArray`.
    pub array_dims: Vec<(Scalar, Vec<usize>)>,
    /// Messages for `Trap`.
    pub traps: Vec<String>,
    pub loop_count: u32,
}

impl Module {
    pub fn func(&self, name: &str) -> Option<u16> {
        self.func_names.get(name).copied()
    }

    /// Total compiled instruction count (diagnostics / tests).
    pub fn code_len(&self) -> usize {
        self.funcs.iter().map(|f| f.code.len()).sum()
    }
}
