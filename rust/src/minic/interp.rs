//! Tree-walking interpreter for MiniC with profiling instrumentation.
//!
//! This plays two roles in the reproduction:
//!
//! 1. **Semantics oracle** — "all-CPU" execution of the application, the
//!    baseline every offload pattern's numerics are checked against.
//! 2. **Dynamic profiler** — the gcov/gprof analog (paper §4: "to count
//!    loop number, we also can use gcov"): per-loop trip counts, floating
//!    op counts, and memory traffic, attributed to the loop *subtree* so
//!    offloading decisions see the cost of a loop including its children.
//!
//! The cost model in [`crate::cpu`] converts the op counts into modeled
//! CPU time; [`crate::analysis::intensity`] combines them into the
//! arithmetic-intensity indicator.

use std::collections::{HashMap, HashSet};

use super::ast::*;
use super::value::{zero_of, ArrayObj, ArrayRef, Env, Value};
use super::MiniCError;

/// Dynamic operation counters (monotone, global).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct OpCounts {
    /// Floating add/sub.
    pub f_add: u64,
    /// Floating mul.
    pub f_mul: u64,
    /// Floating div.
    pub f_div: u64,
    /// Transcendentals (sin/cos/exp/sqrt/...).
    pub f_trig: u64,
    /// Integer ALU ops (address arithmetic excluded; loop/index math).
    pub i_op: u64,
    /// Comparisons (int or float).
    pub cmp: u64,
    /// Array element reads / writes.
    pub reads: u64,
    pub writes: u64,
    /// Bytes moved by those reads/writes (element-size aware).
    pub read_bytes: u64,
    pub write_bytes: u64,
}

impl OpCounts {
    /// Total floating-point operations.
    pub fn flops(&self) -> u64 {
        self.f_add + self.f_mul + self.f_div + self.f_trig
    }

    /// Total bytes moved.
    pub fn bytes(&self) -> u64 {
        self.read_bytes + self.write_bytes
    }

    /// Saturating element-wise subtraction (for "program minus offloaded
    /// loops" accounting in the FPGA simulator).
    pub fn saturating_sub(&self, o: &OpCounts) -> OpCounts {
        OpCounts {
            f_add: self.f_add.saturating_sub(o.f_add),
            f_mul: self.f_mul.saturating_sub(o.f_mul),
            f_div: self.f_div.saturating_sub(o.f_div),
            f_trig: self.f_trig.saturating_sub(o.f_trig),
            i_op: self.i_op.saturating_sub(o.i_op),
            cmp: self.cmp.saturating_sub(o.cmp),
            reads: self.reads.saturating_sub(o.reads),
            writes: self.writes.saturating_sub(o.writes),
            read_bytes: self.read_bytes.saturating_sub(o.read_bytes),
            write_bytes: self.write_bytes.saturating_sub(o.write_bytes),
        }
    }

    /// Element-wise addition (public counterpart used by the simulator).
    pub fn plus(&self, o: &OpCounts) -> OpCounts {
        let mut out = *self;
        out.accumulate(o);
        out
    }

    pub(crate) fn delta_from(&self, earlier: &OpCounts) -> OpCounts {
        OpCounts {
            f_add: self.f_add - earlier.f_add,
            f_mul: self.f_mul - earlier.f_mul,
            f_div: self.f_div - earlier.f_div,
            f_trig: self.f_trig - earlier.f_trig,
            i_op: self.i_op - earlier.i_op,
            cmp: self.cmp - earlier.cmp,
            reads: self.reads - earlier.reads,
            writes: self.writes - earlier.writes,
            read_bytes: self.read_bytes - earlier.read_bytes,
            write_bytes: self.write_bytes - earlier.write_bytes,
        }
    }

    pub(crate) fn accumulate(&mut self, d: &OpCounts) {
        self.f_add += d.f_add;
        self.f_mul += d.f_mul;
        self.f_div += d.f_div;
        self.f_trig += d.f_trig;
        self.i_op += d.i_op;
        self.cmp += d.cmp;
        self.reads += d.reads;
        self.writes += d.writes;
        self.read_bytes += d.read_bytes;
        self.write_bytes += d.write_bytes;
    }
}

/// Per-loop dynamic profile (subtree-attributed).
#[derive(Debug, Default, Clone)]
pub struct LoopProfile {
    /// Number of times the loop *header* was entered.
    pub entries: u64,
    /// Total iterations executed (all entries summed).
    pub trips: u64,
    /// Ops executed inside the loop subtree.
    pub ops: OpCounts,
    /// Arrays read / written anywhere in the subtree.
    pub arrays_read: HashSet<String>,
    pub arrays_written: HashSet<String>,
}

/// Full profile of one program run.
#[derive(Debug, Default, Clone)]
pub struct Profile {
    pub total: OpCounts,
    pub loops: HashMap<LoopId, LoopProfile>,
}

impl Profile {
    pub fn loop_profile(&self, id: LoopId) -> Option<&LoopProfile> {
        self.loops.get(&id)
    }
}

/// Interpreter execution limits (runaway guard).
const MAX_STEPS: u64 = 2_000_000_000;

/// Dense per-loop counters (§Perf: indexed by `LoopId.0` — no hashing on
/// the per-trip path; array footprints as tiny linear-scan vecs instead
/// of per-access `HashSet` inserts).
#[derive(Debug, Default, Clone)]
struct LoopSlot {
    entries: u64,
    trips: u64,
    ops: OpCounts,
    arrays_read: Vec<String>,
    arrays_written: Vec<String>,
}

/// The interpreter. One instance per program run.
pub struct Interp<'p> {
    prog: &'p Program,
    pub arena: Vec<ArrayObj>,
    globals: Env,
    total: OpCounts,
    loop_slots: Vec<LoopSlot>,
    /// Stack of active loop ids for attribution.
    loop_stack: Vec<LoopId>,
    steps: u64,
}

/// Result of `Stmt` execution: normal flow or early return.
enum Flow {
    Normal,
    Return(Value),
}

impl<'p> Interp<'p> {
    pub fn new(prog: &'p Program) -> Result<Self, MiniCError> {
        let mut interp = Interp {
            prog,
            arena: Vec::new(),
            globals: Env::new(),
            total: OpCounts::default(),
            loop_slots: vec![
                LoopSlot::default();
                prog.loop_count as usize
            ],
            loop_stack: Vec::new(),
            steps: 0,
        };
        // #defines become immutable globals.
        for (name, val) in &prog.defines {
            let v = if val.fract() == 0.0 {
                Value::Int(*val as i64)
            } else {
                Value::Float(*val)
            };
            interp.globals.declare(name, v);
        }
        // Allocate global declarations.
        let globals = prog.globals.clone();
        for g in &globals {
            if let Stmt::Decl { name, ty, init, .. } = g {
                let v = interp.alloc_decl(ty)?;
                interp.globals.declare(name, v);
                if let Some(e) = init {
                    let mut env = Env::new();
                    let val = interp.eval(e, &mut env)?;
                    interp.globals.set(name, val)?;
                }
            }
        }
        Ok(interp)
    }

    fn alloc_decl(&mut self, ty: &Type) -> Result<Value, MiniCError> {
        Ok(match ty {
            Type::Array(elem, dims) => {
                let arr = ArrayObj::new(*elem, dims.clone());
                self.arena.push(arr);
                Value::Array(ArrayRef(self.arena.len() - 1))
            }
            Type::Ptr(_) => {
                return Err(MiniCError::Runtime(
                    "pointer declarations require an argument binding".into(),
                ))
            }
            _ => zero_of(ty),
        })
    }

    /// Allocate an array in the arena (harness-side input setup).
    pub fn alloc_array(&mut self, elem: Scalar, dims: Vec<usize>) -> ArrayRef {
        self.arena.push(ArrayObj::new(elem, dims));
        ArrayRef(self.arena.len() - 1)
    }

    pub fn array(&self, r: ArrayRef) -> &ArrayObj {
        &self.arena[r.0]
    }

    pub fn array_mut(&mut self, r: ArrayRef) -> &mut ArrayObj {
        &mut self.arena[r.0]
    }

    /// The global named `name`, if it is an array.
    pub fn global_array(&self, name: &str) -> Option<ArrayRef> {
        match self.globals.get(name) {
            Some(Value::Array(r)) => Some(*r),
            _ => None,
        }
    }

    /// The global named `name`, if it is a scalar.
    pub fn global_scalar(&self, name: &str) -> Option<f64> {
        match self.globals.get(name) {
            Some(Value::Int(v)) => Some(*v as f64),
            Some(Value::Float(v)) => Some(*v),
            _ => None,
        }
    }

    /// Assemble the public [`Profile`] from the dense internal counters
    /// (loops that never entered are omitted, matching gcov semantics).
    pub fn profile(&self) -> Profile {
        let mut loops = HashMap::new();
        for (i, slot) in self.loop_slots.iter().enumerate() {
            if slot.entries == 0 {
                continue;
            }
            loops.insert(
                LoopId(i as u32),
                LoopProfile {
                    entries: slot.entries,
                    trips: slot.trips,
                    ops: slot.ops,
                    arrays_read: slot.arrays_read.iter().cloned().collect(),
                    arrays_written: slot
                        .arrays_written
                        .iter()
                        .cloned()
                        .collect(),
                },
            );
        }
        Profile {
            total: self.total,
            loops,
        }
    }

    /// Call a function by name with the given arguments.
    pub fn call(
        &mut self,
        name: &str,
        args: &[Value],
    ) -> Result<Value, MiniCError> {
        let func = self
            .prog
            .function(name)
            .ok_or_else(|| {
                MiniCError::Runtime(format!("no function `{name}`"))
            })?;
        if func.params.len() != args.len() {
            return Err(MiniCError::Runtime(format!(
                "`{name}` expects {} args, got {}",
                func.params.len(),
                args.len()
            )));
        }
        let mut env = Env::new();
        for (p, a) in func.params.iter().zip(args) {
            // Array/pointer params must receive array handles.
            match (&p.ty, a) {
                (Type::Ptr(_) | Type::Array(..), Value::Array(_)) => {}
                (Type::Scalar(_), Value::Array(_)) => {
                    return Err(MiniCError::Runtime(format!(
                        "array passed to scalar param `{}`",
                        p.name
                    )))
                }
                (Type::Ptr(_) | Type::Array(..), _) => {
                    return Err(MiniCError::Runtime(format!(
                        "scalar passed to array param `{}`",
                        p.name
                    )))
                }
                _ => {}
            }
            env.declare(&p.name, a.clone());
        }
        let body = func.body.clone();
        match self.exec_block(&body, &mut env)? {
            Flow::Return(v) => Ok(v),
            Flow::Normal => Ok(Value::Int(0)),
        }
    }

    fn tick(&mut self) -> Result<(), MiniCError> {
        self.steps += 1;
        if self.steps > MAX_STEPS {
            return Err(MiniCError::Runtime(format!(
                "step limit exceeded ({MAX_STEPS})"
            )));
        }
        Ok(())
    }

    // ---- statements ----

    fn exec_block(
        &mut self,
        stmts: &[Stmt],
        env: &mut Env,
    ) -> Result<Flow, MiniCError> {
        // §Perf: a scope map allocation per block execution is a per-loop-
        // iteration cost. Blocks without top-level declarations cannot
        // shadow anything, so the scope push is elided for them.
        let needs_scope =
            stmts.iter().any(|s| matches!(s, Stmt::Decl { .. }));
        if needs_scope {
            env.push();
        }
        for s in stmts {
            match self.exec(s, env)? {
                Flow::Normal => {}
                ret => {
                    if needs_scope {
                        env.pop();
                    }
                    return Ok(ret);
                }
            }
        }
        if needs_scope {
            env.pop();
        }
        Ok(Flow::Normal)
    }

    fn exec(&mut self, stmt: &Stmt, env: &mut Env) -> Result<Flow, MiniCError> {
        self.tick()?;
        match stmt {
            Stmt::Decl { name, ty, init, .. } => {
                let v = self.alloc_decl(ty)?;
                env.declare(name, v);
                if let Some(e) = init {
                    let val = self.eval(e, env)?;
                    let val = coerce(ty, val);
                    env.set(name, val)?;
                }
                Ok(Flow::Normal)
            }
            Stmt::Assign { target, op, value, .. } => {
                self.exec_assign(target, *op, value, env)?;
                Ok(Flow::Normal)
            }
            Stmt::If {
                cond,
                then_branch,
                else_branch,
                ..
            } => {
                let c = self.eval(cond, env)?;
                self.total.cmp += 1;
                self.bump_loop_cmp();
                if c.truthy()? {
                    self.exec_block(then_branch, env)
                } else {
                    self.exec_block(else_branch, env)
                }
            }
            Stmt::For {
                id,
                init,
                cond,
                step,
                body,
                ..
            } => {
                env.push();
                if let Some(s) = init {
                    self.exec(s, env)?;
                }
                let snapshot = self.total;
                self.enter_loop(*id);
                let mut flow = Flow::Normal;
                loop {
                    let go = match cond {
                        Some(c) => {
                            self.total.cmp += 1;
                            self.eval(c, env)?.truthy()?
                        }
                        None => true,
                    };
                    if !go {
                        break;
                    }
                    self.record_trip(*id);
                    match self.exec_block(body, env)? {
                        Flow::Normal => {}
                        ret => {
                            flow = ret;
                            break;
                        }
                    }
                    if let Some(s) = step {
                        self.exec(s, env)?;
                    }
                }
                self.exit_loop(*id, snapshot);
                env.pop();
                Ok(flow)
            }
            Stmt::While { id, cond, body, .. } => {
                let snapshot = self.total;
                self.enter_loop(*id);
                let mut flow = Flow::Normal;
                loop {
                    self.total.cmp += 1;
                    if !self.eval(cond, env)?.truthy()? {
                        break;
                    }
                    self.record_trip(*id);
                    match self.exec_block(body, env)? {
                        Flow::Normal => {}
                        ret => {
                            flow = ret;
                            break;
                        }
                    }
                }
                self.exit_loop(*id, snapshot);
                Ok(flow)
            }
            Stmt::Return { value, .. } => {
                let v = match value {
                    Some(e) => self.eval(e, env)?,
                    None => Value::Int(0),
                };
                Ok(Flow::Return(v))
            }
            Stmt::ExprStmt { expr, .. } => {
                self.eval(expr, env)?;
                Ok(Flow::Normal)
            }
        }
    }

    fn exec_assign(
        &mut self,
        target: &LValue,
        op: AssignOp,
        value: &Expr,
        env: &mut Env,
    ) -> Result<(), MiniCError> {
        let rhs = self.eval(value, env)?;
        match target {
            LValue::Var(name) => {
                let new = if op == AssignOp::Set {
                    rhs
                } else {
                    let old = env
                        .get(name)
                        .or_else(|| self.globals.get(name))
                        .cloned()
                        .ok_or_else(|| {
                            MiniCError::Runtime(format!("undeclared `{name}`"))
                        })?;
                    self.apply_compound(op, &old, &rhs)?
                };
                if env.set(name, new.clone()).is_err() {
                    self.globals.set(name, new)?;
                }
            }
            LValue::Index { base, indices } => {
                let mut buf = [0i64; 4];
                let n = self.eval_indices(indices, env, &mut buf)?;
                let idx = &buf[..n];
                // Address arithmetic.
                self.total.i_op += n as u64;
                let arr_ref = self.lookup_array(base, env)?;
                let elem_size =
                    self.arena[arr_ref.0].elem.size_bytes();
                let flat = self.arena[arr_ref.0].flat_index(idx)?;
                let new = if op == AssignOp::Set {
                    rhs
                } else {
                    let old = Value::Float(self.arena[arr_ref.0].data[flat]);
                    self.count_read(base, elem_size);
                    self.apply_compound(op, &old, &rhs)?
                };
                self.arena[arr_ref.0].data[flat] = new.as_f64()?;
                self.count_write(base, elem_size);
            }
        }
        Ok(())
    }

    fn apply_compound(
        &mut self,
        op: AssignOp,
        old: &Value,
        rhs: &Value,
    ) -> Result<Value, MiniCError> {
        let bin = match op {
            AssignOp::AddSet => BinOp::Add,
            AssignOp::SubSet => BinOp::Sub,
            AssignOp::MulSet => BinOp::Mul,
            AssignOp::DivSet => BinOp::Div,
            AssignOp::Set => unreachable!(),
        };
        self.apply_bin(bin, old, rhs)
    }

    /// Evaluate index expressions into a fixed buffer (§Perf: no heap
    /// allocation per array access; MiniC arrays are rank ≤ 2).
    fn eval_indices(
        &mut self,
        indices: &[Expr],
        env: &mut Env,
        buf: &mut [i64; 4],
    ) -> Result<usize, MiniCError> {
        if indices.len() > buf.len() {
            return Err(MiniCError::Runtime(format!(
                "array rank {} exceeds supported maximum",
                indices.len()
            )));
        }
        for (slot, e) in buf.iter_mut().zip(indices) {
            *slot = self.eval(e, env)?.as_i64()?;
        }
        Ok(indices.len())
    }

    fn lookup_array(
        &self,
        name: &str,
        env: &Env,
    ) -> Result<ArrayRef, MiniCError> {
        match env.get(name).or_else(|| self.globals.get(name)) {
            Some(Value::Array(r)) => Ok(*r),
            Some(_) => Err(MiniCError::Runtime(format!(
                "`{name}` is not an array"
            ))),
            None => Err(MiniCError::Runtime(format!("undeclared `{name}`"))),
        }
    }

    // ---- profiling helpers ----

    fn enter_loop(&mut self, id: LoopId) {
        self.loop_stack.push(id);
        self.loop_slots[id.0 as usize].entries += 1;
    }

    fn record_trip(&mut self, id: LoopId) {
        self.loop_slots[id.0 as usize].trips += 1;
    }

    fn exit_loop(&mut self, id: LoopId, snapshot: OpCounts) {
        self.loop_stack.pop();
        let delta = self.total.delta_from(&snapshot);
        self.loop_slots[id.0 as usize].ops.accumulate(&delta);
    }

    fn bump_loop_cmp(&mut self) {
        // cmp already counted in total; loop attribution happens via the
        // snapshot diff at exit, so nothing extra here. Kept as a hook.
    }

    fn count_read(&mut self, array: &str, elem_size: u64) {
        self.total.reads += 1;
        self.total.read_bytes += elem_size;
        let (stack, slots) = (&self.loop_stack, &mut self.loop_slots);
        for id in stack {
            let set = &mut slots[id.0 as usize].arrays_read;
            if !set.iter().any(|a| a == array) {
                set.push(array.to_string());
            }
        }
    }

    fn count_write(&mut self, array: &str, elem_size: u64) {
        self.total.writes += 1;
        self.total.write_bytes += elem_size;
        let (stack, slots) = (&self.loop_stack, &mut self.loop_slots);
        for id in stack {
            let set = &mut slots[id.0 as usize].arrays_written;
            if !set.iter().any(|a| a == array) {
                set.push(array.to_string());
            }
        }
    }

    // ---- expressions ----

    fn eval(&mut self, expr: &Expr, env: &mut Env) -> Result<Value, MiniCError> {
        self.tick()?;
        match expr {
            Expr::IntLit(v) => Ok(Value::Int(*v)),
            Expr::FloatLit(v) => Ok(Value::Float(*v)),
            // Format strings evaluate to 0 (only printf consumes them).
            Expr::StrLit(_) => Ok(Value::Int(0)),
            Expr::Var(name) => env
                .get(name)
                .or_else(|| self.globals.get(name))
                .cloned()
                .ok_or_else(|| {
                    MiniCError::Runtime(format!("undeclared `{name}`"))
                }),
            Expr::Index { base, indices } => {
                let mut buf = [0i64; 4];
                let n = self.eval_indices(indices, env, &mut buf)?;
                self.total.i_op += n as u64;
                let arr_ref = self.lookup_array(base, env)?;
                let arr = &self.arena[arr_ref.0];
                let flat = arr.flat_index(&buf[..n])?;
                let v = arr.data[flat];
                let elem = arr.elem;
                self.count_read(base, elem.size_bytes());
                Ok(if elem == Scalar::Int {
                    Value::Int(v as i64)
                } else {
                    Value::Float(v)
                })
            }
            Expr::Bin { op, lhs, rhs } => {
                // Short-circuit logicals.
                if *op == BinOp::And {
                    let l = self.eval(lhs, env)?;
                    self.total.cmp += 1;
                    if !l.truthy()? {
                        return Ok(Value::Int(0));
                    }
                    let r = self.eval(rhs, env)?;
                    return Ok(Value::Int(r.truthy()? as i64));
                }
                if *op == BinOp::Or {
                    let l = self.eval(lhs, env)?;
                    self.total.cmp += 1;
                    if l.truthy()? {
                        return Ok(Value::Int(1));
                    }
                    let r = self.eval(rhs, env)?;
                    return Ok(Value::Int(r.truthy()? as i64));
                }
                let l = self.eval(lhs, env)?;
                let r = self.eval(rhs, env)?;
                self.apply_bin(*op, &l, &r)
            }
            Expr::Un { op, operand } => {
                let v = self.eval(operand, env)?;
                match op {
                    UnOp::Neg => match v {
                        Value::Int(i) => {
                            self.total.i_op += 1;
                            Ok(Value::Int(-i))
                        }
                        Value::Float(f) => {
                            self.total.f_add += 1;
                            Ok(Value::Float(-f))
                        }
                        Value::Array(_) => Err(MiniCError::Runtime(
                            "negating an array".into(),
                        )),
                    },
                    UnOp::Not => {
                        self.total.cmp += 1;
                        Ok(Value::Int(!v.truthy()? as i64))
                    }
                }
            }
            Expr::Call { name, args } => self.eval_call(name, args, env),
            Expr::Cast { to, operand } => {
                let v = self.eval(operand, env)?;
                Ok(match to {
                    Scalar::Int => Value::Int(v.as_i64()?),
                    _ => Value::Float(v.as_f64()?),
                })
            }
        }
    }

    fn apply_bin(
        &mut self,
        op: BinOp,
        l: &Value,
        r: &Value,
    ) -> Result<Value, MiniCError> {
        use BinOp::*;
        // Integer fast path.
        if let (Value::Int(a), Value::Int(b)) = (l, r) {
            let (a, b) = (*a, *b);
            return Ok(match op {
                Add | Sub | Mul | Div | Rem => {
                    self.total.i_op += 1;
                    match op {
                        Add => Value::Int(a.wrapping_add(b)),
                        Sub => Value::Int(a.wrapping_sub(b)),
                        Mul => Value::Int(a.wrapping_mul(b)),
                        Div => {
                            if b == 0 {
                                return Err(MiniCError::Runtime(
                                    "integer division by zero".into(),
                                ));
                            }
                            Value::Int(a / b)
                        }
                        Rem => {
                            if b == 0 {
                                return Err(MiniCError::Runtime(
                                    "integer modulo by zero".into(),
                                ));
                            }
                            Value::Int(a % b)
                        }
                        _ => unreachable!(),
                    }
                }
                Eq | Ne | Lt | Gt | Le | Ge => {
                    self.total.cmp += 1;
                    Value::Int(int_cmp(op, a, b) as i64)
                }
                And | Or => unreachable!("handled in eval"),
            });
        }
        // Float path.
        let a = l.as_f64()?;
        let b = r.as_f64()?;
        Ok(match op {
            Add => {
                self.total.f_add += 1;
                Value::Float(a + b)
            }
            Sub => {
                self.total.f_add += 1;
                Value::Float(a - b)
            }
            Mul => {
                self.total.f_mul += 1;
                Value::Float(a * b)
            }
            Div => {
                self.total.f_div += 1;
                Value::Float(a / b)
            }
            Rem => {
                self.total.f_div += 1;
                Value::Float(a % b)
            }
            Eq | Ne | Lt | Gt | Le | Ge => {
                self.total.cmp += 1;
                Value::Int(float_cmp(op, a, b) as i64)
            }
            And | Or => unreachable!("handled in eval"),
        })
    }

    fn eval_call(
        &mut self,
        name: &str,
        args: &[Expr],
        env: &mut Env,
    ) -> Result<Value, MiniCError> {
        // Builtins first.
        if let Some(f1) = builtin1(name) {
            if args.len() != 1 {
                return Err(MiniCError::Runtime(format!(
                    "`{name}` expects 1 argument"
                )));
            }
            let v = self.eval(&args[0], env)?.as_f64()?;
            self.total.f_trig += 1;
            return Ok(Value::Float(f1(v)));
        }
        match name {
            "printf" => {
                // Evaluate args for effect-parity, produce no output (the
                // verification environment owns stdout).
                for a in args.iter().skip(1) {
                    self.eval(a, env)?;
                }
                return Ok(Value::Int(0));
            }
            "fmin" | "fmax" | "pow" => {
                if args.len() != 2 {
                    return Err(MiniCError::Runtime(format!(
                        "`{name}` expects 2 arguments"
                    )));
                }
                let a = self.eval(&args[0], env)?.as_f64()?;
                let b = self.eval(&args[1], env)?.as_f64()?;
                let v = match name {
                    "fmin" => {
                        self.total.cmp += 1;
                        a.min(b)
                    }
                    "fmax" => {
                        self.total.cmp += 1;
                        a.max(b)
                    }
                    _ => {
                        self.total.f_trig += 1;
                        a.powf(b)
                    }
                };
                return Ok(Value::Float(v));
            }
            _ => {}
        }
        // User function.
        let vals: Vec<Value> = args
            .iter()
            .map(|a| self.eval(a, env))
            .collect::<Result<_, _>>()?;
        self.call(name, &vals)
    }
}

fn int_cmp(op: BinOp, a: i64, b: i64) -> bool {
    match op {
        BinOp::Eq => a == b,
        BinOp::Ne => a != b,
        BinOp::Lt => a < b,
        BinOp::Gt => a > b,
        BinOp::Le => a <= b,
        BinOp::Ge => a >= b,
        _ => unreachable!(),
    }
}

fn float_cmp(op: BinOp, a: f64, b: f64) -> bool {
    match op {
        BinOp::Eq => a == b,
        BinOp::Ne => a != b,
        BinOp::Lt => a < b,
        BinOp::Gt => a > b,
        BinOp::Le => a <= b,
        BinOp::Ge => a >= b,
        _ => unreachable!(),
    }
}

fn builtin1(name: &str) -> Option<fn(f64) -> f64> {
    Some(match name {
        "sin" => f64::sin,
        "cos" => f64::cos,
        "tan" => f64::tan,
        "sqrt" => f64::sqrt,
        "sqrtf" => f64::sqrt,
        "exp" => f64::exp,
        "log" => f64::ln,
        "fabs" => f64::abs,
        "floor" => f64::floor,
        "ceil" => f64::ceil,
        _ => return None,
    })
}

fn coerce(ty: &Type, v: Value) -> Value {
    match (ty, &v) {
        (Type::Scalar(Scalar::Int), Value::Float(f)) => Value::Int(*f as i64),
        (Type::Scalar(s), Value::Int(i)) if s.is_floating() => {
            Value::Float(*i as f64)
        }
        _ => v,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::parse;

    fn run_main(src: &str) -> (Value, Profile) {
        let prog = parse(src).unwrap();
        let mut interp = Interp::new(&prog).unwrap();
        let v = interp.call("main", &[]).unwrap();
        (v, interp.profile().clone())
    }

    #[test]
    fn arithmetic_and_return() {
        let (v, _) = run_main("int main() { return 2 + 3 * 4; }");
        assert_eq!(v, Value::Int(14));
    }

    #[test]
    fn float_promotion() {
        let (v, _) = run_main("int main() { float x = 3 / 2.0; return (int)(x * 10.0); }");
        assert_eq!(v, Value::Int(15));
    }

    #[test]
    fn for_loop_sums() {
        let (v, prof) = run_main(
            "int main() { int s = 0; for (int i = 0; i < 10; i++) { s += i; } return s; }",
        );
        assert_eq!(v, Value::Int(45));
        let lp = prof.loop_profile(LoopId(0)).unwrap();
        assert_eq!(lp.trips, 10);
        assert_eq!(lp.entries, 1);
    }

    #[test]
    fn nested_loop_trip_attribution() {
        let (_, prof) = run_main(
            "int main() { int s = 0;
               for (int i = 0; i < 3; i++)
                 for (int j = 0; j < 5; j++)
                   s += 1;
               return s; }",
        );
        assert_eq!(prof.loop_profile(LoopId(0)).unwrap().trips, 3);
        let inner = prof.loop_profile(LoopId(1)).unwrap();
        assert_eq!(inner.trips, 15);
        assert_eq!(inner.entries, 3);
    }

    #[test]
    fn outer_loop_ops_include_inner() {
        let (_, prof) = run_main(
            "#define N 4\nfloat a[N];\n
             int main() {
               for (int i = 0; i < N; i++) {
                 for (int j = 0; j < N; j++) {
                   a[i] = a[i] + 1.5;
                 }
               }
               return 0; }",
        );
        let outer = prof.loop_profile(LoopId(0)).unwrap().ops;
        let inner = prof.loop_profile(LoopId(1)).unwrap().ops;
        assert!(outer.f_add >= inner.f_add);
        assert_eq!(inner.f_add, 16);
        assert_eq!(inner.writes, 16);
    }

    #[test]
    fn array_footprint_tracking() {
        let (_, prof) = run_main(
            "#define N 8\nfloat a[N]; float b[N];\n
             int main() {
               for (int i = 0; i < N; i++) { b[i] = a[i] * 2.0; }
               return 0; }",
        );
        let lp = prof.loop_profile(LoopId(0)).unwrap();
        assert!(lp.arrays_read.contains("a"));
        assert!(lp.arrays_written.contains("b"));
        assert!(!lp.arrays_written.contains("a"));
    }

    #[test]
    fn while_loop_and_compound_assign() {
        let (v, prof) = run_main(
            "int main() { int i = 0; int s = 1; while (i < 5) { s *= 2; i++; } return s; }",
        );
        assert_eq!(v, Value::Int(32));
        assert_eq!(prof.loop_profile(LoopId(0)).unwrap().trips, 5);
    }

    #[test]
    fn user_function_call_with_array() {
        let (v, _) = run_main(
            "#define N 4\nfloat a[N];\n
             void fill(float *x, int n) {
               for (int i = 0; i < n; i++) { x[i] = i * 1.0; }
             }
             float total(float *x, int n) {
               float s = 0.0;
               for (int i = 0; i < n; i++) { s += x[i]; }
               return s;
             }
             int main() { fill(a, N); return (int) total(a, N); }",
        );
        assert_eq!(v, Value::Int(6)); // 0+1+2+3
    }

    #[test]
    fn builtins() {
        let (v, prof) = run_main(
            "int main() { float x = sqrt(16.0) + fabs(-2.0) + cos(0.0); return (int) x; }",
        );
        assert_eq!(v, Value::Int(7));
        assert_eq!(prof.total.f_trig, 3);
    }

    #[test]
    fn if_else_branches() {
        let (v, _) = run_main(
            "int main() { int x = 5; if (x > 3 && x < 10) { return 1; } else { return 2; } }",
        );
        assert_eq!(v, Value::Int(1));
    }

    #[test]
    fn early_return_from_loop() {
        let (v, prof) = run_main(
            "int main() { for (int i = 0; i < 100; i++) { if (i == 3) return i; } return -1; }",
        );
        assert_eq!(v, Value::Int(3));
        assert_eq!(prof.loop_profile(LoopId(0)).unwrap().trips, 4);
    }

    #[test]
    fn out_of_bounds_errors() {
        let prog = parse(
            "#define N 4\nfloat a[N];\nint main() { a[9] = 1.0; return 0; }",
        )
        .unwrap();
        let mut interp = Interp::new(&prog).unwrap();
        assert!(interp.call("main", &[]).is_err());
    }

    #[test]
    fn division_by_zero_errors() {
        let prog = parse("int main() { int x = 0; return 3 / x; }").unwrap();
        let mut interp = Interp::new(&prog).unwrap();
        assert!(interp.call("main", &[]).is_err());
    }

    #[test]
    fn two_d_array_roundtrip() {
        let (v, _) = run_main(
            "#define R 3\n#define C 4\nfloat m[R][C];\n
             int main() {
               for (int i = 0; i < R; i++)
                 for (int j = 0; j < C; j++)
                   m[i][j] = i * 10.0 + j;
               return (int) m[2][3];
             }",
        );
        assert_eq!(v, Value::Int(23));
    }

    #[test]
    fn printf_is_silent_noop() {
        let (v, _) = run_main(
            r#"int main() { printf("x=%d\n", 42); return 0; }"#,
        );
        assert_eq!(v, Value::Int(0));
    }

    #[test]
    fn globals_shared_across_calls() {
        let (v, _) = run_main(
            "int counter;\n
             void bump() { counter = counter + 1; }\n
             int main() { bump(); bump(); bump(); return counter; }",
        );
        assert_eq!(v, Value::Int(3));
    }
}
