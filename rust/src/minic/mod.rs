//! MiniC: the C-subset frontend (the paper's Clang/libClang analog).
//!
//! The offloading method needs three things from the source language
//! (paper §3.3: "parses source codes … understands the loop statements and
//! variables information"): the loop-statement structure, the variable
//! reference relations, and an executable semantics for the all-CPU
//! baseline. MiniC provides exactly that for a C subset rich enough to
//! express the paper's evaluation applications (tdfir, MRI-Q): typed
//! scalars/arrays/pointers, `for`/`while`/`if`, functions, math builtins,
//! and `#define` constants.

pub mod ast;
pub mod interp;
pub mod lexer;
pub mod parser;
pub mod pretty;
pub mod token;
pub mod typecheck;
pub mod value;

pub use ast::{
    AssignOp, BinOp, Expr, Function, LValue, LoopId, Param, Program, Scalar,
    Stmt, Type, UnOp,
};
pub use interp::{Interp, LoopProfile, OpCounts, Profile};
pub use parser::parse;
pub use value::{ArrayObj, ArrayRef, Value};

use std::fmt;

/// Errors from any MiniC stage.
#[derive(Debug, Clone, PartialEq)]
pub enum MiniCError {
    Lex { line: u32, col: u32, msg: String },
    Parse { line: u32, col: u32, msg: String },
    Semantic { line: u32, msg: String },
    Runtime(String),
}

impl fmt::Display for MiniCError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MiniCError::Lex { line, col, msg } => {
                write!(f, "lex error at {line}:{col}: {msg}")
            }
            MiniCError::Parse { line, col, msg } => {
                write!(f, "parse error at {line}:{col}: {msg}")
            }
            MiniCError::Semantic { line, msg } => {
                write!(f, "semantic error at line {line}: {msg}")
            }
            MiniCError::Runtime(msg) => write!(f, "runtime error: {msg}"),
        }
    }
}

impl std::error::Error for MiniCError {}
