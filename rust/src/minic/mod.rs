//! MiniC: the C-subset frontend (the paper's Clang/libClang analog).
//!
//! The offloading method needs three things from the source language
//! (paper §3.3: "parses source codes … understands the loop statements and
//! variables information"): the loop-statement structure, the variable
//! reference relations, and an executable semantics for the all-CPU
//! baseline. MiniC provides exactly that for a C subset rich enough to
//! express the paper's evaluation applications (tdfir, MRI-Q): typed
//! scalars/arrays/pointers, `for`/`while`/`if`, functions, math builtins,
//! and `#define` constants.
//!
//! # Two execution engines (oracle vs fast path)
//!
//! Executable semantics comes in two interchangeable engines behind the
//! [`Engine`] trait ([`engine`]):
//!
//! * **[`Interp`]** ([`interp`]) — the tree-walking interpreter, kept as
//!   the *semantics oracle*: simple enough to audit, and the reference
//!   every other executor is measured against. It resolves names through
//!   scoped hash maps on every access, which makes it the slowest part
//!   of the whole pipeline (profiling runs dominate the coordinator's
//!   wall-clock; see `benches/pipeline_hotpath.rs`).
//! * **[`Vm`]** ([`vm`]) — the slot-resolved bytecode VM, the *default
//!   engine* for profiling, GA fitness, and numeric verification. The
//!   [`resolve`] pass lowers the AST once ([`bytecode`]): identifiers
//!   intern to dense frame/global slots, `#define`s fold to constants,
//!   and loop-entry/trip/exit markers carry their [`LoopId`] so the VM
//!   maintains the identical [`OpCounts`]/[`LoopProfile`] instrumentation
//!   inline — no hashing or allocation on the per-iteration path.
//!
//! The two engines are pinned together by a differential property test
//! (`tests/vm_differential.rs`): over randomized programs, final
//! globals, totals, and per-loop profiles must match exactly. Engine
//! selection is wired through [`engine::EngineKind`] (CLI: `--engine
//! interp|vm|vm-baseline|vm-regs`).
//!
//! # The PGO loop (§PGO)
//!
//! The VM's encoding is profile-guided. [`profile`] adds an optional
//! per-opcode / adjacent-pair counter layer ([`OpProfiler`], a no-op
//! handle when absent, like `obs::Tracer`); `repro vmprofile` records
//! it over the bundled workloads. The measured ranking ordered the
//! dispatch arms in [`vm`], and the hottest adjacent pairs became
//! fused superinstructions emitted by [`resolve`]'s peepholes
//! ([`ResolveOpts`] selects the encoding: fused default, unfused
//! `baseline`, or the `regs` register-operand experiment, default-on
//! under the `vm-regs` cargo feature). Every fused handler is pinned
//! to the oracle by the same differential harness.
//!
//! ```
//! use fpga_offload::minic::{parse, typecheck};
//!
//! let prog = parse(
//!     "#define N 8\n\
//!      float a[N];\n\
//!      int main() {\n\
//!          for (int i = 0; i < N; i++) { a[i] = i * 0.5; }\n\
//!          return 0;\n\
//!      }",
//! )
//! .unwrap();
//! typecheck::check_ok(&prog).unwrap();
//! assert!(prog.function("main").is_some());
//! ```

pub mod ast;
pub mod bytecode;
pub mod engine;
pub mod interp;
pub mod lexer;
pub mod parser;
pub mod pretty;
pub mod profile;
pub mod resolve;
pub mod token;
pub mod typecheck;
pub mod value;
pub mod vm;

pub use ast::{
    AssignOp, BinOp, Expr, Function, LValue, LoopId, Param, Program, Scalar,
    Stmt, Type, UnOp,
};
pub use engine::{Engine, EngineKind};
pub use interp::{Interp, LoopProfile, OpCounts, Profile};
pub use parser::parse;
pub use profile::{Op, OpProfiler, OpReport};
pub use resolve::ResolveOpts;
pub use value::{ArrayObj, ArrayRef, Value};
pub use vm::Vm;

use std::fmt;

/// Errors from any MiniC stage.
#[derive(Debug, Clone, PartialEq)]
pub enum MiniCError {
    Lex { line: u32, col: u32, msg: String },
    Parse { line: u32, col: u32, msg: String },
    Semantic { line: u32, msg: String },
    Runtime(String),
}

impl fmt::Display for MiniCError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MiniCError::Lex { line, col, msg } => {
                write!(f, "lex error at {line}:{col}: {msg}")
            }
            MiniCError::Parse { line, col, msg } => {
                write!(f, "parse error at {line}:{col}: {msg}")
            }
            MiniCError::Semantic { line, msg } => {
                write!(f, "semantic error at line {line}: {msg}")
            }
            MiniCError::Runtime(msg) => write!(f, "runtime error: {msg}"),
        }
    }
}

impl std::error::Error for MiniCError {}
