//! AST for the MiniC subset.
//!
//! Every loop statement carries a stable [`LoopId`] assigned in source
//! order by the parser — the identity the whole offloading pipeline keys
//! on (arithmetic intensity tables, resource reports, offload patterns).

use std::fmt;

/// Stable identifier of a loop statement (source order, 0-based).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LoopId(pub u32);

impl fmt::Display for LoopId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "L{}", self.0)
    }
}

/// Scalar element types.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scalar {
    Int,
    Float,  // f32 on the device
    Double, // f64
    Void,
}

impl Scalar {
    pub fn is_floating(self) -> bool {
        matches!(self, Scalar::Float | Scalar::Double)
    }

    /// Size in bytes (for transfer-volume and BRAM estimates).
    pub fn size_bytes(self) -> u64 {
        match self {
            Scalar::Int => 4,
            Scalar::Float => 4,
            Scalar::Double => 8,
            Scalar::Void => 0,
        }
    }
}

impl fmt::Display for Scalar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Scalar::Int => "int",
            Scalar::Float => "float",
            Scalar::Double => "double",
            Scalar::Void => "void",
        };
        f.write_str(s)
    }
}

/// A type: scalar, array with static dims, or pointer-to-scalar (function
/// parameters; extent unknown at parse time).
#[derive(Debug, Clone, PartialEq)]
pub enum Type {
    Scalar(Scalar),
    /// `float a[N][M]` — dims are constant expressions resolved by the
    /// parser against `#define`s.
    Array(Scalar, Vec<usize>),
    /// `float *a` — runtime extent.
    Ptr(Scalar),
}

impl Type {
    pub fn elem(&self) -> Scalar {
        match self {
            Type::Scalar(s) | Type::Array(s, _) | Type::Ptr(s) => *s,
        }
    }

    pub fn is_indexable(&self) -> bool {
        matches!(self, Type::Array(..) | Type::Ptr(..))
    }
}

/// Binary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    Eq,
    Ne,
    Lt,
    Gt,
    Le,
    Ge,
    And,
    Or,
}

impl BinOp {
    pub fn is_comparison(self) -> bool {
        matches!(
            self,
            BinOp::Eq | BinOp::Ne | BinOp::Lt | BinOp::Gt | BinOp::Le | BinOp::Ge
        )
    }

    pub fn is_logical(self) -> bool {
        matches!(self, BinOp::And | BinOp::Or)
    }

    pub fn c_symbol(self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
            BinOp::Rem => "%",
            BinOp::Eq => "==",
            BinOp::Ne => "!=",
            BinOp::Lt => "<",
            BinOp::Gt => ">",
            BinOp::Le => "<=",
            BinOp::Ge => ">=",
            BinOp::And => "&&",
            BinOp::Or => "||",
        }
    }
}

/// Unary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnOp {
    Neg,
    Not,
}

/// Expressions. `line` on the variants that matter for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    IntLit(i64),
    FloatLit(f64),
    /// String literal — only legal as a `printf` format argument.
    StrLit(String),
    /// Variable reference.
    Var(String),
    /// `a[i]` / `a[i][j]` — base is always a named array/pointer in MiniC.
    Index {
        base: String,
        indices: Vec<Expr>,
    },
    Bin {
        op: BinOp,
        lhs: Box<Expr>,
        rhs: Box<Expr>,
    },
    Un {
        op: UnOp,
        operand: Box<Expr>,
    },
    /// Function call — user function or builtin (sin/cos/sqrt/fabs/exp).
    Call {
        name: String,
        args: Vec<Expr>,
    },
    /// `(float) e` — cast, element type only.
    Cast {
        to: Scalar,
        operand: Box<Expr>,
    },
}

impl Expr {
    /// Walk every sub-expression (preorder), including `self`.
    pub fn walk<'a>(&'a self, f: &mut impl FnMut(&'a Expr)) {
        f(self);
        match self {
            Expr::Index { indices, .. } => {
                for i in indices {
                    i.walk(f);
                }
            }
            Expr::Bin { lhs, rhs, .. } => {
                lhs.walk(f);
                rhs.walk(f);
            }
            Expr::Un { operand, .. } | Expr::Cast { operand, .. } => {
                operand.walk(f)
            }
            Expr::Call { args, .. } => {
                for a in args {
                    a.walk(f);
                }
            }
            Expr::IntLit(_)
            | Expr::FloatLit(_)
            | Expr::StrLit(_)
            | Expr::Var(_) => {}
        }
    }
}

/// Assignment targets.
#[derive(Debug, Clone, PartialEq)]
pub enum LValue {
    Var(String),
    Index { base: String, indices: Vec<Expr> },
}

impl LValue {
    pub fn base_name(&self) -> &str {
        match self {
            LValue::Var(n) | LValue::Index { base: n, .. } => n,
        }
    }
}

/// Compound-assignment flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AssignOp {
    Set,      // =
    AddSet,   // +=
    SubSet,   // -=
    MulSet,   // *=
    DivSet,   // /=
}

/// Statements.
#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// Declaration with optional initializer.
    Decl {
        name: String,
        ty: Type,
        init: Option<Expr>,
        line: u32,
    },
    Assign {
        target: LValue,
        op: AssignOp,
        value: Expr,
        line: u32,
    },
    If {
        cond: Expr,
        then_branch: Vec<Stmt>,
        else_branch: Vec<Stmt>,
        line: u32,
    },
    For {
        id: LoopId,
        /// `for (init; cond; step)` — init/step are restricted to
        /// assignments in MiniC; `int i = 0` inits become a Decl.
        init: Option<Box<Stmt>>,
        cond: Option<Expr>,
        step: Option<Box<Stmt>>,
        body: Vec<Stmt>,
        line: u32,
    },
    While {
        id: LoopId,
        cond: Expr,
        body: Vec<Stmt>,
        line: u32,
    },
    Return {
        value: Option<Expr>,
        line: u32,
    },
    /// Bare call, e.g. `init_data(x);`.
    ExprStmt { expr: Expr, line: u32 },
}

impl Stmt {
    /// Walk all statements in this subtree (preorder), including `self`.
    pub fn walk<'a>(&'a self, f: &mut impl FnMut(&'a Stmt)) {
        f(self);
        match self {
            Stmt::If {
                then_branch,
                else_branch,
                ..
            } => {
                for s in then_branch.iter().chain(else_branch) {
                    s.walk(f);
                }
            }
            Stmt::For { init, step, body, .. } => {
                if let Some(s) = init {
                    s.walk(f);
                }
                if let Some(s) = step {
                    s.walk(f);
                }
                for s in body {
                    s.walk(f);
                }
            }
            Stmt::While { body, .. } => {
                for s in body {
                    s.walk(f);
                }
            }
            _ => {}
        }
    }

    pub fn line(&self) -> u32 {
        match self {
            Stmt::Decl { line, .. }
            | Stmt::Assign { line, .. }
            | Stmt::If { line, .. }
            | Stmt::For { line, .. }
            | Stmt::While { line, .. }
            | Stmt::Return { line, .. }
            | Stmt::ExprStmt { line, .. } => *line,
        }
    }
}

/// A function parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct Param {
    pub name: String,
    pub ty: Type,
}

/// A function definition.
#[derive(Debug, Clone, PartialEq)]
pub struct Function {
    pub name: String,
    pub ret: Scalar,
    pub params: Vec<Param>,
    pub body: Vec<Stmt>,
    pub line: u32,
}

/// A parsed translation unit.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Program {
    /// `#define NAME value` constants, in source order.
    pub defines: Vec<(String, f64)>,
    /// Global variable declarations.
    pub globals: Vec<Stmt>,
    pub functions: Vec<Function>,
    /// Total number of loop statements (== next LoopId).
    pub loop_count: u32,
}

impl Program {
    pub fn function(&self, name: &str) -> Option<&Function> {
        self.functions.iter().find(|f| f.name == name)
    }

    /// Visit every statement in every function (globals included).
    pub fn walk_stmts<'a>(&'a self, f: &mut impl FnMut(&'a Stmt)) {
        for g in &self.globals {
            g.walk(f);
        }
        for func in &self.functions {
            for s in &func.body {
                s.walk(f);
            }
        }
    }

    /// The define value for `name`, if any.
    pub fn define(&self, name: &str) -> Option<f64> {
        self.defines
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
    }
}
