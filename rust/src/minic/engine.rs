//! Execution-engine abstraction: tree-walker oracle vs bytecode VM.
//!
//! The pipeline's dynamic stages (profiling runs, numeric verification,
//! GA fitness) only need a small surface: run a function, read the
//! profile, inspect globals/arrays. Both engines implement it:
//!
//! * [`Interp`] — the tree-walking *semantics oracle*. Slow, simple,
//!   and the definition of correct behavior.
//! * [`Vm`] — the slot-resolved bytecode engine (§Perf), the default.
//!   The differential property test (`tests/vm_differential.rs`) pins
//!   it to the oracle: identical results, `OpCounts`, and per-loop
//!   profiles over randomized programs.

use super::ast::Scalar;
use super::interp::{Interp, Profile};
use super::resolve::ResolveOpts;
use super::value::{ArrayObj, ArrayRef, Value};
use super::vm::Vm;
use super::{MiniCError, Program};

/// What the analysis/verification layers need from an executor.
pub trait Engine {
    /// Call a function by name.
    fn call(
        &mut self,
        name: &str,
        args: &[Value],
    ) -> Result<Value, MiniCError>;

    /// Profile accumulated so far.
    fn profile(&self) -> Profile;

    /// The global named `name`, if it is an array.
    fn global_array(&self, name: &str) -> Option<ArrayRef>;

    /// The global named `name`, if it is a scalar.
    fn global_scalar(&self, name: &str) -> Option<f64>;

    fn array(&self, r: ArrayRef) -> &ArrayObj;

    fn array_mut(&mut self, r: ArrayRef) -> &mut ArrayObj;

    /// Allocate an array in the engine's arena (input setup).
    fn alloc_array(&mut self, elem: Scalar, dims: Vec<usize>) -> ArrayRef;
}

impl Engine for Interp<'_> {
    fn call(
        &mut self,
        name: &str,
        args: &[Value],
    ) -> Result<Value, MiniCError> {
        Interp::call(self, name, args)
    }

    fn profile(&self) -> Profile {
        Interp::profile(self)
    }

    fn global_array(&self, name: &str) -> Option<ArrayRef> {
        Interp::global_array(self, name)
    }

    fn global_scalar(&self, name: &str) -> Option<f64> {
        Interp::global_scalar(self, name)
    }

    fn array(&self, r: ArrayRef) -> &ArrayObj {
        Interp::array(self, r)
    }

    fn array_mut(&mut self, r: ArrayRef) -> &mut ArrayObj {
        Interp::array_mut(self, r)
    }

    fn alloc_array(&mut self, elem: Scalar, dims: Vec<usize>) -> ArrayRef {
        Interp::alloc_array(self, elem, dims)
    }
}

impl Engine for Vm {
    fn call(
        &mut self,
        name: &str,
        args: &[Value],
    ) -> Result<Value, MiniCError> {
        Vm::call(self, name, args)
    }

    fn profile(&self) -> Profile {
        Vm::profile(self)
    }

    fn global_array(&self, name: &str) -> Option<ArrayRef> {
        Vm::global_array(self, name)
    }

    fn global_scalar(&self, name: &str) -> Option<f64> {
        Vm::global_scalar(self, name)
    }

    fn array(&self, r: ArrayRef) -> &ArrayObj {
        Vm::array(self, r)
    }

    fn array_mut(&mut self, r: ArrayRef) -> &mut ArrayObj {
        Vm::array_mut(self, r)
    }

    fn alloc_array(&mut self, elem: Scalar, dims: Vec<usize>) -> ArrayRef {
        Vm::alloc_array(self, elem, dims)
    }
}

/// Which engine to execute MiniC with. The VM is the default everywhere;
/// the tree-walker stays selectable (CLI `--engine interp`) as the
/// oracle and fallback.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Tree-walking interpreter (semantics oracle).
    TreeWalk,
    /// Slot-resolved bytecode VM (§Perf fast path), superinstruction
    /// encoding (the §PGO default).
    #[default]
    Bytecode,
    /// The VM on the pre-PGO unfused encoding — the measurement
    /// baseline `repro vmprofile` compares against.
    BytecodeBaseline,
    /// The VM with the register-operand encoding experiment enabled
    /// (`ResolveOpts::regs`; default-on under the `vm-regs` feature).
    BytecodeRegs,
}

impl EngineKind {
    /// Parse a CLI-facing name.
    pub fn parse(s: &str) -> Option<EngineKind> {
        match s {
            "interp" | "treewalk" | "oracle" => Some(EngineKind::TreeWalk),
            "vm" | "bytecode" => Some(EngineKind::Bytecode),
            "vm-baseline" | "baseline" => Some(EngineKind::BytecodeBaseline),
            "vm-regs" | "regs" => Some(EngineKind::BytecodeRegs),
            _ => None,
        }
    }

    /// Construct the engine for `prog`.
    pub fn build<'p>(
        self,
        prog: &'p Program,
    ) -> Result<Box<dyn Engine + 'p>, MiniCError> {
        Ok(match self {
            EngineKind::TreeWalk => Box::new(Interp::new(prog)?),
            EngineKind::Bytecode => Box::new(Vm::new(prog)?),
            EngineKind::BytecodeBaseline => {
                Box::new(Vm::new_with(prog, &ResolveOpts::baseline())?)
            }
            EngineKind::BytecodeRegs => {
                Box::new(Vm::new_with(prog, &ResolveOpts::regs())?)
            }
        })
    }
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            EngineKind::TreeWalk => "interp",
            EngineKind::Bytecode => "vm",
            EngineKind::BytecodeBaseline => "vm-baseline",
            EngineKind::BytecodeRegs => "vm-regs",
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::parse;

    const SRC: &str = "
#define N 6
float a[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 1.5; }
    return 0;
}";

    #[test]
    fn both_engines_run_and_agree() {
        let prog = parse(SRC).unwrap();
        for kind in [
            EngineKind::TreeWalk,
            EngineKind::Bytecode,
            EngineKind::BytecodeBaseline,
            EngineKind::BytecodeRegs,
        ] {
            let mut eng = kind.build(&prog).unwrap();
            eng.call("main", &[]).unwrap();
            let r = eng.global_array("a").unwrap();
            assert_eq!(eng.array(r).data[4], 6.0, "{kind}");
            assert_eq!(eng.profile().total.f_mul, 6, "{kind}");
        }
    }

    #[test]
    fn kind_parses_and_defaults() {
        assert_eq!(EngineKind::default(), EngineKind::Bytecode);
        assert_eq!(EngineKind::parse("interp"), Some(EngineKind::TreeWalk));
        assert_eq!(EngineKind::parse("vm"), Some(EngineKind::Bytecode));
        assert_eq!(
            EngineKind::parse("vm-baseline"),
            Some(EngineKind::BytecodeBaseline)
        );
        assert_eq!(
            EngineKind::parse("vm-regs"),
            Some(EngineKind::BytecodeRegs)
        );
        assert_eq!(EngineKind::parse("gpu"), None);
    }
}
