//! Lexer for the MiniC subset, including a tiny preprocessor layer:
//! `#define NAME <int|float>` becomes a `KwDefine`-led pseudo-statement
//! handled by the parser; `//` and `/* */` comments and `#include` lines
//! are skipped.

use super::token::{Token, TokenKind};
use super::MiniCError;

pub struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: u32,
    col: u32,
}

impl<'a> Lexer<'a> {
    pub fn new(src: &'a str) -> Self {
        Lexer {
            src: src.as_bytes(),
            pos: 0,
            line: 1,
            col: 1,
        }
    }

    /// Tokenize the whole input (appends an `Eof` token).
    pub fn tokenize(mut self) -> Result<Vec<Token>, MiniCError> {
        let mut out = Vec::new();
        loop {
            let tok = self.next_token()?;
            let done = tok.kind == TokenKind::Eof;
            out.push(tok);
            if done {
                return Ok(out);
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn peek2(&self) -> Option<u8> {
        self.src.get(self.pos + 1).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        if b == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(b)
    }

    fn err(&self, msg: impl Into<String>) -> MiniCError {
        MiniCError::Lex {
            line: self.line,
            col: self.col,
            msg: msg.into(),
        }
    }

    fn skip_trivia(&mut self) -> Result<(), MiniCError> {
        loop {
            match (self.peek(), self.peek2()) {
                (Some(b' ' | b'\t' | b'\r' | b'\n'), _) => {
                    self.bump();
                }
                (Some(b'/'), Some(b'/')) => {
                    while let Some(b) = self.peek() {
                        if b == b'\n' {
                            break;
                        }
                        self.bump();
                    }
                }
                (Some(b'/'), Some(b'*')) => {
                    let (l, c) = (self.line, self.col);
                    self.bump();
                    self.bump();
                    loop {
                        match (self.peek(), self.peek2()) {
                            (Some(b'*'), Some(b'/')) => {
                                self.bump();
                                self.bump();
                                break;
                            }
                            (Some(_), _) => {
                                self.bump();
                            }
                            (None, _) => {
                                return Err(MiniCError::Lex {
                                    line: l,
                                    col: c,
                                    msg: "unterminated block comment".into(),
                                })
                            }
                        }
                    }
                }
                _ => return Ok(()),
            }
        }
    }

    fn next_token(&mut self) -> Result<Token, MiniCError> {
        self.skip_trivia()?;
        let (line, col) = (self.line, self.col);
        let mk = |kind| Token { kind, line, col };

        let b = match self.peek() {
            None => return Ok(mk(TokenKind::Eof)),
            Some(b) => b,
        };

        // Preprocessor lines.
        if b == b'#' {
            return self.preprocessor(line, col);
        }

        if b.is_ascii_alphabetic() || b == b'_' {
            let word = self.ident();
            let kind = TokenKind::keyword(&word)
                .unwrap_or(TokenKind::Ident(word));
            return Ok(mk(kind));
        }

        if b.is_ascii_digit()
            || (b == b'.' && self.peek2().is_some_and(|c| c.is_ascii_digit()))
        {
            return self.number(line, col);
        }

        if b == b'"' {
            return self.string(line, col);
        }

        // Operators / punctuation.
        self.bump();
        let two = |lexer: &mut Self, next: u8, yes: TokenKind, no: TokenKind| {
            if lexer.peek() == Some(next) {
                lexer.bump();
                yes
            } else {
                no
            }
        };
        use TokenKind::*;
        let kind = match b {
            b'(' => LParen,
            b')' => RParen,
            b'{' => LBrace,
            b'}' => RBrace,
            b'[' => LBracket,
            b']' => RBracket,
            b';' => Semi,
            b',' => Comma,
            b'%' => Percent,
            b'+' => match self.peek() {
                Some(b'+') => {
                    self.bump();
                    PlusPlus
                }
                Some(b'=') => {
                    self.bump();
                    PlusAssign
                }
                _ => Plus,
            },
            b'-' => match self.peek() {
                Some(b'-') => {
                    self.bump();
                    MinusMinus
                }
                Some(b'=') => {
                    self.bump();
                    MinusAssign
                }
                _ => Minus,
            },
            b'*' => two(self, b'=', StarAssign, Star),
            b'/' => two(self, b'=', SlashAssign, Slash),
            b'=' => two(self, b'=', Eq, Assign),
            b'!' => two(self, b'=', Ne, Not),
            b'<' => two(self, b'=', Le, Lt),
            b'>' => two(self, b'=', Ge, Gt),
            b'&' => two(self, b'&', AndAnd, Amp),
            b'|' => {
                if self.peek() == Some(b'|') {
                    self.bump();
                    OrOr
                } else {
                    return Err(self.err("bitwise `|` unsupported in MiniC"));
                }
            }
            other => {
                return Err(
                    self.err(format!("unexpected byte '{}'", other as char))
                )
            }
        };
        Ok(mk(kind))
    }

    fn ident(&mut self) -> String {
        let start = self.pos;
        while self
            .peek()
            .is_some_and(|b| b.is_ascii_alphanumeric() || b == b'_')
        {
            self.bump();
        }
        String::from_utf8_lossy(&self.src[start..self.pos]).into_owned()
    }

    fn number(&mut self, line: u32, col: u32) -> Result<Token, MiniCError> {
        let start = self.pos;
        let mut is_float = false;
        while self.peek().is_some_and(|b| b.is_ascii_digit()) {
            self.bump();
        }
        if self.peek() == Some(b'.') {
            is_float = true;
            self.bump();
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.bump();
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.bump();
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.bump();
            }
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.bump();
            }
        }
        // Float suffix `f` / `F` (accepted and ignored).
        if matches!(self.peek(), Some(b'f' | b'F')) {
            let _ = is_float; // suffix forces float regardless
            self.bump();
            let text = std::str::from_utf8(&self.src[start..self.pos - 1])
                .expect("ascii digits");
            let v: f64 = text.parse().map_err(|_| MiniCError::Lex {
                line,
                col,
                msg: format!("bad float literal {text:?}"),
            })?;
            return Ok(Token {
                kind: TokenKind::FloatLit(v),
                line,
                col,
            });
        }
        let text = std::str::from_utf8(&self.src[start..self.pos])
            .expect("ascii digits");
        let kind = if is_float {
            TokenKind::FloatLit(text.parse().map_err(|_| MiniCError::Lex {
                line,
                col,
                msg: format!("bad float literal {text:?}"),
            })?)
        } else {
            TokenKind::IntLit(text.parse().map_err(|_| MiniCError::Lex {
                line,
                col,
                msg: format!("bad int literal {text:?}"),
            })?)
        };
        Ok(Token { kind, line, col })
    }

    fn string(&mut self, line: u32, col: u32) -> Result<Token, MiniCError> {
        self.bump(); // opening quote
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => {
                    return Ok(Token {
                        kind: TokenKind::StrLit(out),
                        line,
                        col,
                    })
                }
                Some(b'\\') => match self.bump() {
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    _ => {
                        return Err(MiniCError::Lex {
                            line,
                            col,
                            msg: "bad string escape".into(),
                        })
                    }
                },
                Some(b) => out.push(b as char),
                None => {
                    return Err(MiniCError::Lex {
                        line,
                        col,
                        msg: "unterminated string".into(),
                    })
                }
            }
        }
    }

    /// `#define NAME ...` becomes `KwDefine Ident <value tokens...>`;
    /// `#include ...` and `#pragma ...` lines are skipped entirely.
    fn preprocessor(&mut self, line: u32, col: u32) -> Result<Token, MiniCError> {
        self.bump(); // '#'
        let word = self.ident();
        match word.as_str() {
            "define" => Ok(Token {
                kind: TokenKind::KwDefine,
                line,
                col,
            }),
            "include" | "pragma" | "ifdef" | "ifndef" | "endif" | "else" => {
                // Skip to end of line, then lex the next token.
                while let Some(b) = self.peek() {
                    if b == b'\n' {
                        break;
                    }
                    self.bump();
                }
                self.next_token()
            }
            other => Err(MiniCError::Lex {
                line,
                col,
                msg: format!("unsupported preprocessor directive #{other}"),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use TokenKind::*;

    fn kinds(src: &str) -> Vec<TokenKind> {
        Lexer::new(src)
            .tokenize()
            .unwrap()
            .into_iter()
            .map(|t| t.kind)
            .collect()
    }

    #[test]
    fn lex_basic_tokens() {
        assert_eq!(
            kinds("int x = 42;"),
            vec![KwInt, Ident("x".into()), Assign, IntLit(42), Semi, Eof]
        );
    }

    #[test]
    fn lex_float_forms() {
        assert_eq!(
            kinds("1.5 2e3 0.25f .5"),
            vec![
                FloatLit(1.5),
                FloatLit(2000.0),
                FloatLit(0.25),
                FloatLit(0.5),
                Eof
            ]
        );
    }

    #[test]
    fn lex_operators() {
        assert_eq!(
            kinds("a += b * c <= d && !e || f++"),
            vec![
                Ident("a".into()),
                PlusAssign,
                Ident("b".into()),
                Star,
                Ident("c".into()),
                Le,
                Ident("d".into()),
                AndAnd,
                Not,
                Ident("e".into()),
                OrOr,
                Ident("f".into()),
                PlusPlus,
                Eof
            ]
        );
    }

    #[test]
    fn lex_comments_skipped() {
        assert_eq!(
            kinds("a // line comment\n/* block\ncomment */ b"),
            vec![Ident("a".into()), Ident("b".into()), Eof]
        );
    }

    #[test]
    fn lex_include_skipped_define_kept() {
        assert_eq!(
            kinds("#include <stdio.h>\n#define N 64\nint"),
            vec![KwDefine, Ident("N".into()), IntLit(64), KwInt, Eof]
        );
    }

    #[test]
    fn lex_positions() {
        let toks = Lexer::new("int\n  x;").tokenize().unwrap();
        assert_eq!((toks[0].line, toks[0].col), (1, 1));
        assert_eq!((toks[1].line, toks[1].col), (2, 3));
    }

    #[test]
    fn lex_unterminated_comment_errors() {
        assert!(Lexer::new("/* oops").tokenize().is_err());
    }

    #[test]
    fn lex_string_literal() {
        assert_eq!(
            kinds(r#""hi\n" x"#),
            vec![StrLit("hi\n".into()), Ident("x".into()), Eof]
        );
    }
}
