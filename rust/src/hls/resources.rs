//! HDL-level resource estimation (the Intel-SDK pre-compile analog).
//!
//! Paper §3.3: "it takes only a minute until to extract HDL as the
//! intermediate state. Since resources such as Flip Flop and Look Up Table
//! used in FPGA can be estimated at the HDL level, the amount of resources
//! used can be known in a short time even if compiling is not completed."
//!
//! The estimator prices one *datapath instance* of the kernel body — the
//! structure HLS actually instantiates. Nested loops contribute their body
//! once (they become pipelined sub-schedules, not replicated hardware);
//! the unroll factor replicates the outermost body. Costs are calibrated
//! to Arria-10-class OpenCL reports: hard-FP DSPs absorb mul/add, divides
//! and transcendentals burn soft logic, each array argument owns a
//! load-store unit, and small arrays are cached in M20K local memory (the
//! paper's "local memory cache" speed-up technique).

use crate::codegen::KernelIr;
use crate::minic::ast::*;

use super::device::Device;

/// Estimated resource usage of one kernel.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ResourceEstimate {
    pub luts: u64,
    pub ffs: u64,
    pub dsps: u64,
    pub bram_bits: u64,
}

impl ResourceEstimate {
    pub fn add(&self, o: &ResourceEstimate) -> ResourceEstimate {
        ResourceEstimate {
            luts: self.luts + o.luts,
            ffs: self.ffs + o.ffs,
            dsps: self.dsps + o.dsps,
            bram_bits: self.bram_bits + o.bram_bits,
        }
    }

    /// Utilization fractions of the device's *usable* (post-BSP) pool.
    pub fn utilization(&self, dev: &Device) -> Utilization {
        Utilization {
            luts: self.luts as f64 / dev.usable_luts() as f64,
            ffs: self.ffs as f64 / dev.usable_ffs() as f64,
            dsps: self.dsps as f64 / dev.usable_dsps() as f64,
            bram: self.bram_bits as f64 / dev.usable_bram_bits() as f64,
        }
    }

    pub fn fits(&self, dev: &Device) -> bool {
        let u = self.utilization(dev);
        u.max() <= 1.0
    }
}

/// Per-class utilization fractions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Utilization {
    pub luts: f64,
    pub ffs: f64,
    pub dsps: f64,
    pub bram: f64,
}

impl Utilization {
    /// Bottleneck fraction — the paper's "resource amount" scalar used in
    /// the resource-efficiency ratio.
    pub fn max(&self) -> f64 {
        self.luts.max(self.ffs).max(self.dsps).max(self.bram)
    }
}

/// Static op inventory of one datapath instance.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct OpInventory {
    pub f_add: u64,
    pub f_mul: u64,
    pub f_div: u64,
    pub f_trig: u64,
    pub i_op: u64,
    pub cmp: u64,
    pub loads: u64,
    pub stores: u64,
    /// Nested loop structures (each needs control logic).
    pub inner_loops: u64,
    /// Textual memory access *sites* — the global-memory stream rate per
    /// pipeline slot. Unlike `loads`/`stores` this is NOT multiplied by
    /// spatialization: a spatially unrolled inner loop reads from banked
    /// M20K local memory, not from the global interface.
    pub ports: u64,
}

impl OpInventory {
    fn add_assign(&mut self, o: &OpInventory) {
        self.f_add += o.f_add;
        self.f_mul += o.f_mul;
        self.f_div += o.f_div;
        self.f_trig += o.f_trig;
        self.i_op += o.i_op;
        self.cmp += o.cmp;
        self.loads += o.loads;
        self.stores += o.stores;
        self.inner_loops += o.inner_loops;
        self.ports += o.ports;
    }

    /// Scale the datapath by a spatial replication factor (ports exempt).
    fn scale(&self, f: u64) -> OpInventory {
        OpInventory {
            f_add: self.f_add * f,
            f_mul: self.f_mul * f,
            f_div: self.f_div * f,
            f_trig: self.f_trig * f,
            i_op: self.i_op * f,
            cmp: self.cmp * f,
            loads: self.loads * f,
            stores: self.stores * f,
            inner_loops: self.inner_loops,
            ports: self.ports,
        }
    }
}

/// Inner counted loops with at most this many iterations are *spatialized*
/// — fully unrolled into the datapath, the way Intel's OpenCL compiler
/// treats small fixed-bound inner loops (the K-tap MAC of a FIR becomes K
/// parallel MACs feeding an adder tree).
pub const SPATIAL_MAX_TRIPS: u64 = 64;

// ---- cost table (Arria10-class OpenCL, hard-FP DSP) ----

const KERNEL_BASE_LUT: u64 = 2_400;
const KERNEL_BASE_FF: u64 = 3_600;
const LSU_LUT: u64 = 1_600; // one load-store unit per array argument
const LSU_FF: u64 = 2_600;
const LOOP_CTRL_LUT: u64 = 320;
const LOOP_CTRL_FF: u64 = 420;

const FADD_DSP: u64 = 1;
const FADD_LUT: u64 = 110;
const FADD_FF: u64 = 170;
const FMUL_DSP: u64 = 1;
const FMUL_LUT: u64 = 100;
const FMUL_FF: u64 = 160;
const FDIV_LUT: u64 = 3_000;
const FDIV_FF: u64 = 3_600;
const TRIG_LUT: u64 = 5_800;
const TRIG_FF: u64 = 7_200;
const TRIG_DSP: u64 = 8;
const IOP_LUT: u64 = 64;
const IOP_FF: u64 = 64;
const CMP_LUT: u64 = 36;
const CMP_FF: u64 = 18;
const PORT_LUT: u64 = 210; // per memory access port in the datapath
const PORT_FF: u64 = 260;

/// Arrays up to this size are cached whole in M20K local memory.
const LOCAL_CACHE_MAX_BYTES: u64 = 256 * 1024;
/// Minimum BRAM granule (one M20K block).
const M20K_BITS: u64 = 20_480;

/// Count the datapath op inventory of the kernel's (possibly unrolled)
/// loop body. The outermost loop header counts as control; nested loops
/// contribute their body once plus control — except small fixed-bound
/// innermost loops, which are spatialized (body × trips).
pub fn inventory(kernel: &KernelIr) -> OpInventory {
    let mut inv = OpInventory::default();
    let (Stmt::For { body, .. } | Stmt::While { body, .. }) = &kernel.body
    else {
        return inv;
    };
    // Arrays too big for M20K local caching stream from global memory —
    // only their accesses consume global ports (cached-array traffic is
    // already priced as BRAM in `estimate`).
    let streamed: std::collections::BTreeSet<&str> = kernel
        .array_params()
        .filter(|p| p.bytes() > LOCAL_CACHE_MAX_BYTES)
        .map(|p| p.name.as_str())
        .collect();
    // Outermost header: one compare + one add per iteration.
    inv.cmp += 1;
    inv.i_op += 1;
    for s in body {
        inv.add_assign(&stmt_ops(s, &kernel.defines, &streamed));
    }
    inv
}

/// Spatial replication factor of the kernel's innermost loop (1 when the
/// innermost loop is not spatializable). The performance simulator
/// divides pipeline slots by this.
pub fn spatial_factor(kernel: &KernelIr) -> u64 {
    fn innermost_factor(body: &[Stmt], defines: &[(String, f64)]) -> u64 {
        let mut best = 1;
        for s in body {
            s.walk(&mut |s| {
                if let Stmt::For { body: inner, .. } = s {
                    let has_nested = inner.iter().any(|st| {
                        let mut found = false;
                        st.walk(&mut |x| {
                            if matches!(x, Stmt::For { .. } | Stmt::While { .. })
                            {
                                found = true;
                            }
                        });
                        found
                    });
                    if !has_nested {
                        if let Some(t) = local_static_trips(s, defines) {
                            if t <= SPATIAL_MAX_TRIPS {
                                best = best.max(t);
                            }
                        }
                    }
                }
            });
        }
        best
    }
    match &kernel.body {
        Stmt::For { body, .. } | Stmt::While { body, .. } => {
            innermost_factor(body, &kernel.defines)
        }
        _ => 1,
    }
}

type Streamed<'a> = std::collections::BTreeSet<&'a str>;

fn stmt_ops(s: &Stmt, defines: &[(String, f64)], streamed: &Streamed) -> OpInventory {
    let mut inv = OpInventory::default();
    match s {
        Stmt::Decl { init, .. } => {
            if let Some(e) = init {
                inv.add_assign(&expr_ops(e, streamed));
            }
        }
        Stmt::Assign { target, op, value, .. } => {
            inv.add_assign(&expr_ops(value, streamed));
            match target {
                LValue::Index { base, indices } => {
                    for i in indices {
                        add_expr_ops(i, &mut inv, true, streamed);
                    }
                    inv.i_op += indices.len() as u64;
                    inv.stores += 1;
                    if streamed.contains(base.as_str()) {
                        inv.ports += 1;
                    }
                    if *op != AssignOp::Set {
                        inv.loads += 1;
                        if streamed.contains(base.as_str()) {
                            inv.ports += 1;
                        }
                        inv.f_add += 1; // the compound op itself
                    }
                }
                LValue::Var(_) => {
                    if *op != AssignOp::Set {
                        inv.f_add += 1;
                    }
                }
            }
        }
        Stmt::If {
            cond,
            then_branch,
            else_branch,
            ..
        } => {
            inv.add_assign(&expr_ops(cond, streamed));
            // Both branches exist in hardware (predicated datapath).
            for s in then_branch.iter().chain(else_branch) {
                inv.add_assign(&stmt_ops(s, defines, streamed));
            }
        }
        Stmt::For { cond, body, .. } => {
            let mut body_inv = OpInventory::default();
            let mut has_nested = false;
            for s in body {
                s.walk(&mut |x| {
                    if matches!(x, Stmt::For { .. } | Stmt::While { .. }) {
                        has_nested = true;
                    }
                });
                body_inv.add_assign(&stmt_ops(s, defines, streamed));
            }
            let trips = local_static_trips(s, defines);
            match trips {
                Some(t) if !has_nested && t <= SPATIAL_MAX_TRIPS => {
                    // Spatialized: body replicated t times, loop control
                    // and header vanish into wiring.
                    inv.add_assign(&body_inv.scale(t));
                }
                _ => {
                    inv.inner_loops += 1;
                    inv.cmp += 1;
                    inv.i_op += 1;
                    if let Some(c) = cond {
                        inv.add_assign(&expr_ops(c, streamed));
                    }
                    inv.add_assign(&body_inv);
                }
            }
        }
        Stmt::While { cond, body, .. } => {
            inv.inner_loops += 1;
            inv.add_assign(&expr_ops(cond, streamed));
            for s in body {
                inv.add_assign(&stmt_ops(s, defines, streamed));
            }
        }
        Stmt::Return { value, .. } => {
            if let Some(e) = value {
                inv.add_assign(&expr_ops(e, streamed));
            }
        }
        Stmt::ExprStmt { expr, .. } => inv.add_assign(&expr_ops(expr, streamed)),
    }
    inv
}

/// Static trip count of a canonical `for (v = a; v < b; v += c)` loop
/// using only literals and `#define`s.
fn local_static_trips(s: &Stmt, defines: &[(String, f64)]) -> Option<u64> {
    let Stmt::For { init, cond, step, .. } = s else {
        return None;
    };
    let ev = |e: &Expr| -> Option<f64> { const_eval(e, defines) };
    let var = match init.as_deref()? {
        Stmt::Decl { name, .. } => name.clone(),
        Stmt::Assign {
            target: LValue::Var(n),
            ..
        } => n.clone(),
        _ => return None,
    };
    let start = match init.as_deref()? {
        Stmt::Decl { init: Some(e), .. } => ev(e)?,
        Stmt::Assign { value, .. } => ev(value)?,
        _ => return None,
    };
    let stride = match step.as_deref()? {
        Stmt::Assign {
            op: AssignOp::AddSet,
            value,
            ..
        } => ev(value)?,
        _ => return None,
    };
    if stride <= 0.0 {
        return None;
    }
    let (bound, incl) = match cond.as_ref()? {
        Expr::Bin { op, lhs, rhs } => {
            if !matches!(lhs.as_ref(), Expr::Var(n) if *n == var) {
                return None;
            }
            match op {
                BinOp::Lt => (ev(rhs)?, 0.0),
                BinOp::Le => (ev(rhs)?, 1.0),
                _ => return None,
            }
        }
        _ => return None,
    };
    let span = bound - start + incl;
    if span <= 0.0 {
        return Some(0);
    }
    Some((span / stride).ceil() as u64)
}

fn const_eval(e: &Expr, defines: &[(String, f64)]) -> Option<f64> {
    Some(match e {
        Expr::IntLit(v) => *v as f64,
        Expr::FloatLit(v) => *v,
        Expr::Var(n) => {
            defines.iter().rev().find(|(d, _)| d == n).map(|(_, v)| *v)?
        }
        Expr::Bin { op, lhs, rhs } => {
            let a = const_eval(lhs, defines)?;
            let b = const_eval(rhs, defines)?;
            match op {
                BinOp::Add => a + b,
                BinOp::Sub => a - b,
                BinOp::Mul => a * b,
                BinOp::Div if b != 0.0 => a / b,
                _ => return None,
            }
        }
        Expr::Un {
            op: UnOp::Neg,
            operand,
        } => -const_eval(operand, defines)?,
        _ => return None,
    })
}

fn expr_ops(e: &Expr, streamed: &Streamed) -> OpInventory {
    let mut inv = OpInventory::default();
    add_expr_ops(e, &mut inv, false, streamed);
    inv
}

/// Recursive op pricing. `addr` marks address context: arithmetic inside
/// array subscripts is integer address math (AGU logic), not FP datapath.
fn add_expr_ops(e: &Expr, inv: &mut OpInventory, addr: bool, streamed: &Streamed) {
    match e {
        Expr::Bin { op, lhs, rhs } => {
            match op {
                _ if addr => inv.i_op += 1,
                BinOp::Add | BinOp::Sub => inv.f_add += 1,
                BinOp::Mul => inv.f_mul += 1,
                BinOp::Div | BinOp::Rem => inv.f_div += 1,
                _ => inv.cmp += 1,
            }
            add_expr_ops(lhs, inv, addr, streamed);
            add_expr_ops(rhs, inv, addr, streamed);
        }
        Expr::Un { op, operand } => {
            match op {
                _ if addr => inv.i_op += 1,
                UnOp::Neg => inv.f_add += 1,
                UnOp::Not => inv.cmp += 1,
            }
            add_expr_ops(operand, inv, addr, streamed);
        }
        Expr::Index { base, indices } => {
            inv.loads += 1;
            if streamed.contains(base.as_str()) {
                inv.ports += 1;
            }
            inv.i_op += indices.len() as u64;
            for i in indices {
                add_expr_ops(i, inv, true, streamed);
            }
        }
        Expr::Call { name, args } => {
            // Builtins only (user calls are blocked upstream).
            if name != "printf" {
                inv.f_trig += 1;
            }
            for a in args {
                add_expr_ops(a, inv, addr, streamed);
            }
        }
        Expr::Cast { operand, .. } => add_expr_ops(operand, inv, addr, streamed),
        Expr::IntLit(_) | Expr::FloatLit(_) | Expr::StrLit(_) | Expr::Var(_) => {}
    }
}

/// Estimate resources for a kernel (already unrolled — the body reflects
/// the replication, so the inventory scales naturally).
pub fn estimate(kernel: &KernelIr) -> ResourceEstimate {
    let inv = inventory(kernel);
    let mut est = ResourceEstimate {
        luts: KERNEL_BASE_LUT,
        ffs: KERNEL_BASE_FF,
        ..Default::default()
    };

    // Datapath ops.
    est.luts += inv.f_add * FADD_LUT
        + inv.f_mul * FMUL_LUT
        + inv.f_div * FDIV_LUT
        + inv.f_trig * TRIG_LUT
        + inv.i_op * IOP_LUT
        + inv.cmp * CMP_LUT;
    est.ffs += inv.f_add * FADD_FF
        + inv.f_mul * FMUL_FF
        + inv.f_div * FDIV_FF
        + inv.f_trig * TRIG_FF
        + inv.i_op * IOP_FF
        + inv.cmp * CMP_FF;
    est.dsps += inv.f_add * FADD_DSP
        + inv.f_mul * FMUL_DSP
        + inv.f_trig * TRIG_DSP;

    // Memory system: one LSU per array argument + per-port datapath cost.
    let n_arrays = kernel.array_params().count() as u64;
    est.luts += n_arrays * LSU_LUT;
    est.ffs += n_arrays * LSU_FF;
    est.luts += (inv.loads + inv.stores) * PORT_LUT;
    est.ffs += (inv.loads + inv.stores) * PORT_FF;

    // Loop control (outer + inner).
    est.luts += (1 + inv.inner_loops) * LOOP_CTRL_LUT;
    est.ffs += (1 + inv.inner_loops) * LOOP_CTRL_FF;

    // Local-memory caching of small array arguments.
    for p in kernel.array_params() {
        let bytes = p.bytes();
        if bytes <= LOCAL_CACHE_MAX_BYTES {
            let bits = (bytes * 8).max(M20K_BITS);
            // Round up to whole M20K blocks.
            let blocks = bits.div_ceil(M20K_BITS);
            est.bram_bits += blocks * M20K_BITS;
        }
    }

    est
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::codegen::{split, unroll};
    use crate::hls::device::ARRIA10_GX;
    use crate::minic::ast::LoopId;
    use crate::minic::parse;

    fn kernel(src: &str, id: u32, u: u32) -> KernelIr {
        let prog = parse(src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let r = split(&prog, an.loop_by_id(LoopId(id)).unwrap()).unwrap();
        unroll(&r.kernel, u).unwrap()
    }

    const ELEMWISE: &str = "
#define N 1024
float a[N]; float b[N];
int main() {
    for (int i = 0; i < N; i++) { b[i] = a[i] * 2.0 + 1.0; }
    return 0;
}";

    const TRIG: &str = "
#define N 1024
float a[N]; float b[N];
int main() {
    for (int i = 0; i < N; i++) { b[i] = sin(a[i]) * cos(a[i]); }
    return 0;
}";

    #[test]
    fn inventory_counts_elementwise() {
        let inv = inventory(&kernel(ELEMWISE, 0, 1));
        assert_eq!(inv.f_mul, 1);
        assert_eq!(inv.f_add, 1);
        assert_eq!(inv.loads, 1);
        assert_eq!(inv.stores, 1);
        assert_eq!(inv.f_trig, 0);
    }

    #[test]
    fn trig_kernel_much_bigger() {
        let e1 = estimate(&kernel(ELEMWISE, 0, 1));
        let e2 = estimate(&kernel(TRIG, 0, 1));
        assert!(e2.luts > e1.luts * 2, "{e1:?} vs {e2:?}");
        assert!(e2.dsps > e1.dsps);
    }

    #[test]
    fn unroll_scales_datapath_not_base() {
        let e1 = estimate(&kernel(ELEMWISE, 0, 1));
        let e8 = estimate(&kernel(ELEMWISE, 0, 8));
        // DSPs scale ~8x (datapath), LUTs grow but sublinearly (base+LSU
        // amortized).
        assert_eq!(e8.dsps, e1.dsps * 8);
        assert!(e8.luts > e1.luts);
        assert!(e8.luts < e1.luts * 8);
    }

    #[test]
    fn small_arrays_cached_in_bram() {
        let e = estimate(&kernel(ELEMWISE, 0, 1));
        // Two 4 KiB arrays → at least 2 M20K blocks each rounded up.
        assert!(e.bram_bits >= 2 * 20_480);
        assert_eq!(e.bram_bits % 20_480, 0);
    }

    #[test]
    fn everything_fits_arria10() {
        for u in [1, 4, 16] {
            let e = estimate(&kernel(TRIG, 0, u));
            assert!(e.fits(&ARRIA10_GX), "u={u}: {e:?}");
        }
    }

    #[test]
    fn utilization_bottleneck_is_max() {
        let u = Utilization {
            luts: 0.1,
            ffs: 0.2,
            dsps: 0.7,
            bram: 0.3,
        };
        assert_eq!(u.max(), 0.7);
    }

    #[test]
    fn large_nested_loop_counts_once() {
        let src = "
#define N 512
float a[N][N]; float x[N]; float y[N];
int main() {
    for (int i = 0; i < N; i++) {
        float acc = 0.0;
        for (int j = 0; j < N; j++) { acc += a[i][j] * x[j]; }
        y[i] = acc;
    }
    return 0;
}";
        // N=512 > SPATIAL_MAX_TRIPS: the inner loop pipelines, the
        // datapath holds ONE instance of its body.
        let inv = inventory(&kernel(src, 0, 1));
        assert_eq!(inv.f_mul, 1);
        assert_eq!(inv.inner_loops, 1);
    }

    #[test]
    fn small_inner_loop_spatializes() {
        let src = "
#define N 512
#define K 16
float a[N]; float h[K]; float y[N];
int main() {
    for (int i = 0; i < N; i++) {
        float acc = 0.0;
        for (int k = 0; k < K; k++) { acc += h[k] * a[i]; }
        y[i] = acc;
    }
    return 0;
}";
        let k = kernel(src, 0, 1);
        let inv = inventory(&k);
        // K=16 ≤ SPATIAL_MAX_TRIPS: 16 parallel MACs in the datapath.
        assert_eq!(inv.f_mul, 16);
        assert_eq!(inv.inner_loops, 0);
        assert_eq!(spatial_factor(&k), 16);
        // Ports stay at the textual site count (local-memory banking).
        assert!(inv.ports < inv.loads + inv.stores);
    }

    #[test]
    fn spatial_factor_one_for_flat_loops() {
        assert_eq!(spatial_factor(&kernel(ELEMWISE, 0, 1)), 1);
    }
}
