//! FPGA device models (paper Fig. 3: Intel PAC with Intel Arria10 GX).
//!
//! Public resource figures for the Arria 10 GX 1150 on the Intel
//! Programmable Acceleration Card, the paper's verification device:
//! 427,200 ALMs (~2 LUT + 2 FF each), 1,518 hard DSP blocks, 2,713 M20K
//! (20 kb) memory blocks. The OpenCL BSP (board support package:
//! PCIe/DDR controllers, DMA) permanently occupies a sizable slice —
//! that's the `bsp_overhead` fraction, and it is why even trivial kernels
//! report double-digit utilization in real Quartus reports.

/// Static description of an FPGA device + BSP.
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    pub name: &'static str,
    /// Adaptive logic modules; we track LUTs and FFs through ALM-derived
    /// totals (2 per ALM each).
    pub luts: u64,
    pub ffs: u64,
    /// Hard floating-point capable DSP blocks.
    pub dsps: u64,
    /// Block RAM bits (M20K × 20 kb).
    pub bram_bits: u64,
    /// Fraction of each resource pre-consumed by the board support
    /// package (PCIe, DDR4 controllers, DMA engines).
    pub bsp_overhead: f64,
    /// Peak kernel clock of the OpenCL fabric in Hz (derated by
    /// utilization in [`crate::hls::schedule`]).
    pub base_clock_hz: f64,
    /// Effective host↔device bandwidth (PCIe Gen3 x8), bytes/s.
    pub pcie_bytes_per_sec: f64,
    /// Fixed per-DMA-transfer latency, seconds.
    pub dma_latency_s: f64,
    /// Fixed kernel-launch overhead, seconds.
    pub launch_latency_s: f64,
}

/// Intel PAC with Arria 10 GX 1150 + Acceleration Stack 1.2 (paper Fig. 3).
pub const ARRIA10_GX: Device = Device {
    name: "Intel PAC Arria10 GX 1150",
    luts: 854_400,        // 427,200 ALMs × 2
    ffs: 1_708_800,       // 427,200 ALMs × 4 registers / 2 (usable pairs)
    dsps: 1_518,
    bram_bits: 55_562_240, // 2,713 × 20,480 bits
    bsp_overhead: 0.18,
    base_clock_hz: 240.0e6, // typical Arria10 OpenCL kernel clock
    pcie_bytes_per_sec: 6.0e9, // PCIe Gen3 x8 effective (~75% of 8 GB/s)
    dma_latency_s: 12.0e-6,
    launch_latency_s: 6.0e-6,
};

impl Device {
    /// Resource amount available to kernels (after the BSP).
    pub fn usable_luts(&self) -> u64 {
        (self.luts as f64 * (1.0 - self.bsp_overhead)) as u64
    }

    pub fn usable_ffs(&self) -> u64 {
        (self.ffs as f64 * (1.0 - self.bsp_overhead)) as u64
    }

    pub fn usable_dsps(&self) -> u64 {
        (self.dsps as f64 * (1.0 - self.bsp_overhead)) as u64
    }

    pub fn usable_bram_bits(&self) -> u64 {
        (self.bram_bits as f64 * (1.0 - self.bsp_overhead)) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arria10_figures_sane() {
        let d = &ARRIA10_GX;
        assert!(d.luts > 800_000);
        assert!(d.dsps > 1_000);
        assert!(d.bram_bits > 50_000_000);
        assert!(d.bsp_overhead > 0.0 && d.bsp_overhead < 0.5);
    }

    #[test]
    fn usable_less_than_total() {
        let d = &ARRIA10_GX;
        assert!(d.usable_luts() < d.luts);
        assert!(d.usable_dsps() < d.dsps);
        assert!(d.usable_bram_bits() < d.bram_bits);
        // But the majority remains usable.
        assert!(d.usable_luts() > d.luts / 2);
    }
}
