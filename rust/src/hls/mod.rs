//! HLS model: the Intel FPGA SDK for OpenCL pre-compile analog.
//!
//! Given a kernel IR, produce in "about a minute" what the real toolchain
//! produces from the HDL intermediate: resource usage (FF/LUT/DSP/M20K as
//! % of the Arria10), a pipeline schedule (II/depth/fmax), and the
//! resource-efficiency ratio the paper narrows candidates by — all
//! without the ~3 h full place-and-route, which is exactly the asymmetry
//! the paper's method is built around.
//!
//! ```
//! use fpga_offload::hls::{full_compile_seconds, ResourceEstimate, ARRIA10_GX};
//!
//! // Even an empty design pays the base place-and-route hours — the
//! // wall-clock asymmetry the pre-compile narrowing exists to avoid.
//! let full = full_compile_seconds(&ResourceEstimate::default(), &ARRIA10_GX);
//! assert!(full > 3600.0);
//! ```

pub mod device;
pub mod report;
pub mod resources;
pub mod schedule;

pub use device::{Device, ARRIA10_GX};
pub use report::{
    full_compile_seconds, precompile, render, PrecompileReport,
    PRECOMPILE_SECONDS,
};
pub use resources::{
    estimate, inventory, spatial_factor, OpInventory, ResourceEstimate,
    Utilization, SPATIAL_MAX_TRIPS,
};
pub use schedule::{body_latency, schedule, Schedule};
