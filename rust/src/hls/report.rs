//! Pre-compile report: the Quartus-style summary the narrowing step reads.
//!
//! One per candidate kernel. Carries the resource estimate (as % of the
//! device, like the SDK's report), the pipeline schedule, the *resource
//! efficiency* (paper §3.3: "(arithmetic intensity / resource amount)"),
//! and the modeled compile times — minutes for HDL extraction, hours for
//! full place-and-route (the asymmetry the whole method exists to
//! exploit).

use crate::analysis::LoopIntensity;
use crate::codegen::KernelIr;
use crate::minic::ast::LoopId;

use super::device::Device;
use super::resources::{estimate, ResourceEstimate, Utilization};
use super::schedule::{schedule, Schedule};

/// Modeled time for the HDL-extraction pre-compile (paper: "about a
/// minute").
pub const PRECOMPILE_SECONDS: f64 = 60.0;

/// The pre-compile report for one kernel variant.
#[derive(Debug, Clone)]
pub struct PrecompileReport {
    pub loop_id: LoopId,
    pub kernel_name: String,
    pub unroll: u32,
    pub estimate: ResourceEstimate,
    pub utilization: Utilization,
    /// Bottleneck fraction (the paper's scalar "resource amount").
    pub resource_amount: f64,
    pub fits: bool,
    pub schedule: Schedule,
    /// intensity / resource_amount (paper's resource efficiency).
    pub resource_efficiency: f64,
    /// Modeled full-compile wall-clock, seconds (~3 h in the paper).
    pub full_compile_s: f64,
}

/// Modeled full place-and-route time: base hours plus growth with design
/// size (bigger designs route longer). Paper §5.2: "about 3 hours to
/// compile one offload pattern".
pub fn full_compile_seconds(est: &ResourceEstimate, dev: &Device) -> f64 {
    let util = est.utilization(dev).max();
    let base_h = 2.4;
    let growth_h = 1.2 * util.min(1.2);
    (base_h + growth_h) * 3600.0
}

/// Produce the report for a kernel + its measured intensity.
pub fn precompile(
    kernel: &KernelIr,
    intensity: &LoopIntensity,
    dev: &Device,
) -> PrecompileReport {
    let est = estimate(kernel);
    let utilization = est.utilization(dev);
    let resource_amount = utilization.max();
    let sched = schedule(kernel, &est, dev);
    let resource_efficiency = if resource_amount > 0.0 {
        intensity.intensity / resource_amount
    } else {
        0.0
    };
    PrecompileReport {
        loop_id: kernel.loop_id,
        kernel_name: kernel.name.clone(),
        unroll: kernel.unroll,
        estimate: est,
        utilization,
        resource_amount,
        fits: est.fits(dev),
        schedule: sched,
        resource_efficiency,
        full_compile_s: full_compile_seconds(&est, dev),
    }
}

/// Human-readable rendering (the `--explain` output).
pub fn render(r: &PrecompileReport) -> String {
    format!(
        "{name} (loop {id}, unroll {u}):\n\
         \x20 LUT {lut:>8}  ({lutp:5.2}%)   FF {ff:>8} ({ffp:5.2}%)\n\
         \x20 DSP {dsp:>8}  ({dspp:5.2}%)   M20K bits {bram:>9} ({bramp:5.2}%)\n\
         \x20 II {ii}  depth {depth}  fmax {fmax:.0} MHz  fits: {fits}\n\
         \x20 resource amount {ra:.4}  efficiency {re:.1}  full compile {fc:.1} h",
        name = r.kernel_name,
        id = r.loop_id,
        u = r.unroll,
        lut = r.estimate.luts,
        lutp = r.utilization.luts * 100.0,
        ff = r.estimate.ffs,
        ffp = r.utilization.ffs * 100.0,
        dsp = r.estimate.dsps,
        dspp = r.utilization.dsps * 100.0,
        bram = r.estimate.bram_bits,
        bramp = r.utilization.bram * 100.0,
        ii = r.schedule.ii,
        depth = r.schedule.depth,
        fmax = r.schedule.fmax_hz / 1e6,
        fits = r.fits,
        ra = r.resource_amount,
        re = r.resource_efficiency,
        fc = r.full_compile_s / 3600.0,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::codegen::split;
    use crate::hls::device::ARRIA10_GX;
    use crate::minic::parse;

    fn report_for(src: &str, id: u32) -> PrecompileReport {
        let prog = parse(src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let al = an.loop_by_id(LoopId(id)).unwrap();
        let r = split(&prog, al).unwrap();
        precompile(
            &r.kernel,
            al.intensity.as_ref().unwrap(),
            &ARRIA10_GX,
        )
    }

    const SRC: &str = "
#define N 1024
float a[N]; float b[N]; float c[N];
int main() {
    for (int i = 0; i < N; i++) { b[i] = a[i] + 1.0; }                   // L0 cheap
    for (int i = 0; i < N; i++) { c[i] = sin(a[i]) * cos(b[i]) + sqrt(a[i] + 2.0); } // L1 dense
    return 0;
}";

    #[test]
    fn efficiency_is_intensity_over_amount() {
        let r = report_for(SRC, 1);
        let expected = {
            let prog = parse(SRC).unwrap();
            let an = analyze(&prog, "main").unwrap();
            let i = an
                .loop_by_id(LoopId(1))
                .unwrap()
                .intensity
                .as_ref()
                .unwrap()
                .intensity;
            i / r.resource_amount
        };
        assert!((r.resource_efficiency - expected).abs() < 1e-9);
    }

    #[test]
    fn compile_time_in_paper_ballpark() {
        let r = report_for(SRC, 1);
        let hours = r.full_compile_s / 3600.0;
        assert!((2.0..4.0).contains(&hours), "{hours} h");
    }

    #[test]
    fn render_mentions_key_fields() {
        let r = report_for(SRC, 0);
        let text = render(&r);
        assert!(text.contains("kernel_L0"));
        assert!(text.contains("fmax"));
        assert!(text.contains("efficiency"));
    }

    #[test]
    fn dense_kernel_lower_efficiency_iff_resources_dominate() {
        // The trig loop has higher intensity but also much bigger
        // datapath; the report must reflect both sides of the ratio.
        let cheap = report_for(SRC, 0);
        let dense = report_for(SRC, 1);
        assert!(dense.resource_amount > cheap.resource_amount);
        assert!(
            dense.estimate.luts > cheap.estimate.luts * 3,
            "{} vs {}",
            dense.estimate.luts,
            cheap.estimate.luts
        );
    }
}
