//! Pipeline scheduling model: initiation interval (II), pipeline depth,
//! and the achievable kernel clock.
//!
//! HLS for FPGAs pipelines the loop: after `depth` cycles of fill, one
//! iteration completes every `II` cycles. The classification from
//! [`crate::analysis::depend`] sets the II:
//!
//! * `Independent` — II = 1 (or the memory-port bound if the body makes
//!   more concurrent accesses than ports exist).
//! * `Reduction`   — II = accumulator latency ÷ (tree width); modeled as
//!   a fixed small constant since HLS tree-balances unrolled reductions.
//! * `Carried`     — the dependence chain serializes: II = body latency.
//!
//! The clock is derated from the device base as utilization grows —
//! routing congestion on a crowded Arria10 costs real MHz, which is why
//! "use all the resources" is not free speed (and why the combination
//! patterns in the paper can lose).

use crate::analysis::Dependence;
use crate::codegen::KernelIr;

use super::device::Device;
use super::resources::{inventory, OpInventory, ResourceEstimate};

// Op latencies in kernel-clock cycles (Arria10-class, hard-FP).
const LAT_FADD: u64 = 4;
const LAT_FMUL: u64 = 4;
const LAT_FDIV: u64 = 28;
const LAT_TRIG: u64 = 36;
const LAT_MEM: u64 = 5;
const LAT_INT: u64 = 1;

/// Reduction II after HLS tree-balancing.
const REDUCTION_II: u64 = 4;

/// Concurrent memory ports the BSP exposes to a kernel.
const MEM_PORTS: u64 = 4;

/// The schedule of one kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Schedule {
    /// Cycles between successive iteration starts.
    pub ii: u64,
    /// Pipeline fill depth in cycles.
    pub depth: u64,
    /// Achievable clock after utilization derating, Hz.
    pub fmax_hz: f64,
}

impl Schedule {
    /// Cycles to run `trips` iterations once the kernel is launched.
    pub fn cycles(&self, trips: u64, unroll: u32) -> u64 {
        // The unrolled body consumes `unroll` iterations per II slot.
        let slots = trips.div_ceil(unroll.max(1) as u64);
        self.depth + slots.saturating_mul(self.ii)
    }

    /// Seconds for `trips` iterations.
    pub fn time(&self, trips: u64, unroll: u32) -> f64 {
        self.cycles(trips, unroll) as f64 / self.fmax_hz
    }
}

/// Body latency along a conservative critical path: the sum of op
/// latencies (an upper bound on the chain; real HLS overlaps independent
/// ops, so this intentionally over-approximates carried-loop cost).
pub fn body_latency(inv: &OpInventory) -> u64 {
    inv.f_add * LAT_FADD
        + inv.f_mul * LAT_FMUL
        + inv.f_div * LAT_FDIV
        + inv.f_trig * LAT_TRIG
        + (inv.loads + inv.stores) * LAT_MEM
        + (inv.i_op + inv.cmp) * LAT_INT
}

/// Compute the schedule for a kernel with a given resource estimate.
pub fn schedule(
    kernel: &KernelIr,
    est: &ResourceEstimate,
    dev: &Device,
) -> Schedule {
    let inv = inventory(kernel);
    let latency = body_latency(&inv).max(1);

    // Port pressure counts global-memory access *sites* (spatialized
    // inner-loop accesses hit banked local memory instead).
    let mem_bound = inv.ports.div_ceil(MEM_PORTS).max(1);
    let ii = match &kernel.dependence {
        Dependence::Independent => mem_bound,
        Dependence::Reduction(_) => REDUCTION_II.max(mem_bound),
        Dependence::Carried(_) => latency.max(mem_bound),
    };

    // Inner loops serialize the outer pipeline: an inner counted loop of
    // T iterations makes the effective II at the outer level ≈ T × inner
    // II. We fold that into `cycles()` via the caller passing *total*
    // (product) trips instead; the schedule stays per-innermost-iteration.
    let util = est.utilization(dev).max();
    let derate = 1.0 - 0.28 * util.powf(1.5);
    let fmax_hz = dev.base_clock_hz * derate.clamp(0.4, 1.0);

    Schedule {
        ii,
        depth: latency,
        fmax_hz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::codegen::{split, unroll};
    use crate::hls::device::ARRIA10_GX;
    use crate::hls::resources::estimate;
    use crate::minic::ast::LoopId;
    use crate::minic::parse;

    fn kernel_of(src: &str, u: u32) -> KernelIr {
        let prog = parse(src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let r = split(&prog, an.loop_by_id(LoopId(0)).unwrap()).unwrap();
        unroll(&r.kernel, u).unwrap()
    }

    const INDEP: &str = "
#define N 4096
float a[N]; float b[N];
int main() { for (int i = 0; i < N; i++) { b[i] = a[i] * 2.0; } return 0; }";

    const REDUCE: &str = "
#define N 4096
float a[N]; float s;
int main() { for (int i = 0; i < N; i++) { s += a[i]; } return 0; }";

    const CARRIED: &str = "
#define N 4096
float a[N];
int main() { for (int i = 1; i < N; i++) { a[i] = a[i-1] * 0.5 + 1.0; } return 0; }";

    #[test]
    fn independent_ii_is_one() {
        let k = kernel_of(INDEP, 1);
        let s = schedule(&k, &estimate(&k), &ARRIA10_GX);
        assert_eq!(s.ii, 1);
    }

    #[test]
    fn reduction_ii_is_small_constant() {
        let k = kernel_of(REDUCE, 1);
        let s = schedule(&k, &estimate(&k), &ARRIA10_GX);
        assert_eq!(s.ii, REDUCTION_II);
    }

    #[test]
    fn carried_ii_is_body_latency() {
        let k = kernel_of(CARRIED, 1);
        let s = schedule(&k, &estimate(&k), &ARRIA10_GX);
        assert!(s.ii > REDUCTION_II, "carried must serialize: {s:?}");
    }

    #[test]
    fn unroll_speeds_up_independent_loop() {
        let k1 = kernel_of(INDEP, 1);
        let k8 = kernel_of(INDEP, 8);
        let s1 = schedule(&k1, &estimate(&k1), &ARRIA10_GX);
        let s8 = schedule(&k8, &estimate(&k8), &ARRIA10_GX);
        let t1 = s1.time(4096, 1);
        let t8 = s8.time(4096, 8);
        // Unroll 8 with more memory ports in use won't be a clean 8x, but
        // must be clearly faster.
        assert!(t8 < t1 * 0.6, "t1={t1} t8={t8}");
    }

    #[test]
    fn fmax_derates_with_utilization() {
        let k = kernel_of(INDEP, 1);
        let small = estimate(&k);
        let big = ResourceEstimate {
            luts: ARRIA10_GX.usable_luts() * 9 / 10,
            ..small
        };
        let s_small = schedule(&k, &small, &ARRIA10_GX);
        let s_big = schedule(&k, &big, &ARRIA10_GX);
        assert!(s_big.fmax_hz < s_small.fmax_hz);
        assert!(s_big.fmax_hz >= ARRIA10_GX.base_clock_hz * 0.4);
    }

    #[test]
    fn cycles_accounts_depth_plus_throughput() {
        let s = Schedule {
            ii: 2,
            depth: 100,
            fmax_hz: 1e8,
        };
        assert_eq!(s.cycles(1000, 1), 100 + 2000);
        assert_eq!(s.cycles(1000, 4), 100 + 500);
        assert_eq!(s.cycles(0, 1), 100);
    }
}
