//! # fpga-offload
//!
//! Reproduction of **Yamato, "Proposal of Automatic FPGA Offloading for
//! Applications Loop Statements" (CS.DC 2020)** as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The paper's pipeline, end to end:
//!
//! 1. [`minic`] parses the application's C source (the Clang analog).
//! 2. [`analysis`] extracts the loop tree, measures arithmetic intensity
//!    (the PGI-compiler analog) and dynamic trip counts (the gcov analog).
//! 3. [`codegen`] splits each candidate loop into an OpenCL-style
//!    FPGA-kernel / CPU-host pair and applies unrolling.
//! 4. [`hls`] "pre-compiles" the kernel to an HDL-level resource estimate
//!    (FF/LUT/DSP/BRAM as % of an Arria10 GX) without full place-and-route.
//! 5. [`search`] runs the paper's narrowing funnel — top-A arithmetic
//!    intensity, top-C resource efficiency, ≤D measured patterns (singles
//!    then combinations) — measuring each pattern through a pluggable
//!    [`search::Backend`] inside the verification environment: the
//!    [`fpga`] simulator (the paper's destination), the [`gpu`] model
//!    (the mixed-environment board), the [`cpu::omp`] many-core model
//!    (OpenMP parallel regions over shared memory), or the CPU control.
//! 6. [`envadapt`] wires the above into the Fig.-1 environment-adaptive
//!    software flow as the staged [`envadapt::Pipeline`] (one typed stage
//!    per Fig.-1 step), with [`envadapt::Batch`] orchestration for
//!    many-application automation cycles and the test-case /
//!    code-pattern / facility DBs.
//!
//! Beyond the paper's loop funnel, [`funcblock`] adds the follow-on
//! papers' function-block path (arXiv:2004.09883): whole algorithmic
//! blocks (matmul, FIR bank, 2D stencil, sqrt-magnitude) are detected,
//! behaviorally confirmed by VM sample tests, and replaced with
//! catalogued FPGA IP cores / GPU libraries; the loop search then runs
//! only over the loops no block claimed.
//!
//! Numeric ground truth comes from the real stack: [`runtime`] loads the
//! AOT-compiled HLO artifacts (JAX models wrapping Pallas kernels, lowered
//! once at build time by `python/compile/aot.py`) and executes them via
//! PJRT — Python is never on the request path.
//!
//! # Module map
//!
//! The crate is eight subsystems plus shared support code:
//!
//! | subsystem    | role                                                   |
//! |--------------|--------------------------------------------------------|
//! | [`minic`]    | C-subset frontend + two execution engines (tree-walker oracle, slot-resolved bytecode VM) |
//! | [`analysis`] | static loop table, dynamic profiling, arithmetic intensity, dependence classes |
//! | [`codegen`]  | kernel/host splitting, OpenCL emission, unrolling      |
//! | [`hls`]      | pre-compile resource/schedule model of the FPGA toolchain (`fpga` and `cpu` hold the device/CPU cost models it prices against) |
//! | [`gpu`]      | the mixed-environment GPU destination model            |
//! | [`search`]   | the narrowing funnel, measurement backends, GA baseline |
//! | [`funcblock`]| function-block catalog, detection, sample-test confirmation, replacement planning |
//! | [`envadapt`] | the staged Fig.-1 pipeline, batch orchestration, test-case / code-pattern / facility DBs |
//!
//! Support: [`cpu`] (CPU cost model + the [`cpu::omp`] many-core OpenMP
//! destination), [`fpga`] (FPGA simulator + transfer model), [`runtime`]
//! (PJRT artifacts), [`workloads`] (bundled applications), [`store`]
//! (the sharded, log-structured pattern store every DB facade sits on),
//! [`service`] (the resident plan-serving daemon behind `repro serve`),
//! [`obs`] (end-to-end tracing, lock-free latency histograms, and the
//! Prometheus exposition behind `repro trace` and the `metrics` op),
//! [`cli`], and [`util`]. See `ARCHITECTURE.md` at the repository root
//! for the full data-flow map and the recipe for adding another
//! destination.
//!
//! # Quickstart
//!
//! Solve one application end to end (the all-CPU control backend keeps
//! this instant — swap in [`FpgaBackend`], [`GpuBackend`] or
//! [`OmpBackend`] for a real destination):
//!
//! ```
//! use fpga_offload::cpu::XEON_BRONZE_3104;
//! use fpga_offload::hls::ARRIA10_GX;
//! use fpga_offload::{CpuBaseline, OffloadRequest, Pipeline, SearchConfig};
//!
//! let backend = CpuBaseline { cpu: &XEON_BRONZE_3104, device: &ARRIA10_GX };
//! let pipeline = Pipeline::new(SearchConfig::default(), &backend).unwrap();
//! let request = OffloadRequest::builder("demo")
//!     .source(
//!         "#define N 256\n\
//!          float a[N]; float out[N];\n\
//!          int main() {\n\
//!              for (int i = 0; i < N; i++) { a[i] = i * 0.01 - 1.0; }\n\
//!              for (int i = 0; i < N; i++) { out[i] = sin(a[i]) * 2.0; }\n\
//!              return 0;\n\
//!          }",
//!     )
//!     .build()
//!     .unwrap();
//! let planned = pipeline.solve(request).unwrap();
//! // The control never claims acceleration — exactly 1.0x.
//! assert_eq!(planned.plan.speedup(), 1.0);
//! ```

pub mod analysis;
pub mod cli;
pub mod codegen;
pub mod cpu;
pub mod envadapt;
pub mod fpga;
pub mod funcblock;
pub mod gpu;
pub mod hls;
pub mod minic;
pub mod obs;
pub mod runtime;
pub mod search;
pub mod service;
pub mod store;
pub mod util;
pub mod workloads;

pub use envadapt::{Batch, BatchReport, OffloadRequest, Pipeline};
pub use search::backend::{
    Backend, CpuBaseline, FpgaBackend, GpuBackend, OmpBackend,
};
pub use search::config::SearchConfig;
pub use search::result::{OffloadSolution, PatternMeasurement};
