//! Artifact discovery: locate `artifacts/` and read `meta.json`.
//!
//! `make artifacts` (the one-time Python AOT step) produces
//! `artifacts/{tdfir,mriq}.hlo.txt` and `artifacts/meta.json`. Everything
//! the Rust side needs at run time — paths and sample-test shapes — comes
//! from here; Python itself is never invoked.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Sample-test shapes for the TDFIR artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TdfirShape {
    /// Number of filters in the bank.
    pub m: usize,
    /// Stream length.
    pub n: usize,
    /// Taps per filter.
    pub k: usize,
}

/// Sample-test shapes for the MRI-Q artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MriqShape {
    /// K-space samples.
    pub k: usize,
    /// Voxels.
    pub x: usize,
}

/// Resolved artifact set.
#[derive(Debug, Clone)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub tdfir_hlo: PathBuf,
    pub mriq_hlo: PathBuf,
    pub tdfir_shape: TdfirShape,
    pub mriq_shape: MriqShape,
}

impl Artifacts {
    /// Locate artifacts under `dir` and parse `meta.json`.
    pub fn load(dir: &Path) -> Result<Artifacts> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path).with_context(|| {
            format!(
                "reading {meta_path:?} — run `make artifacts` first"
            )
        })?;
        let meta = Json::parse(&text)
            .with_context(|| format!("parsing {meta_path:?}"))?;

        let need = |path: &[&str]| -> Result<usize> {
            meta.get(path)
                .and_then(Json::as_usize)
                .with_context(|| format!("meta.json missing {path:?}"))
        };
        let tdfir_shape = TdfirShape {
            m: need(&["shapes", "tdfir", "m"])?,
            n: need(&["shapes", "tdfir", "n"])?,
            k: need(&["shapes", "tdfir", "k"])?,
        };
        let mriq_shape = MriqShape {
            k: need(&["shapes", "mriq", "k"])?,
            x: need(&["shapes", "mriq", "x"])?,
        };

        let tdfir_hlo = dir.join("tdfir.hlo.txt");
        let mriq_hlo = dir.join("mriq.hlo.txt");
        for p in [&tdfir_hlo, &mriq_hlo] {
            if !p.exists() {
                bail!("missing artifact {p:?} — run `make artifacts`");
            }
        }
        Ok(Artifacts {
            dir: dir.to_path_buf(),
            tdfir_hlo,
            mriq_hlo,
            tdfir_shape,
            mriq_shape,
        })
    }

    /// Search upward from `start` (usually the cwd) for an `artifacts/`
    /// directory containing `meta.json`.
    pub fn discover(start: &Path) -> Result<Artifacts> {
        let mut cur = Some(start);
        while let Some(dir) = cur {
            let candidate = dir.join("artifacts");
            if candidate.join("meta.json").exists() {
                return Self::load(&candidate);
            }
            cur = dir.parent();
        }
        bail!(
            "no artifacts/ directory found above {start:?} — run `make artifacts`"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_meta(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"shapes":{"tdfir":{"m":8,"n":1024,"k":32},
                 "mriq":{"k":512,"x":1024,"block_x":128,"block_k":128}}}"#,
        )
        .unwrap();
        std::fs::write(dir.join("tdfir.hlo.txt"), "HloModule x").unwrap();
        std::fs::write(dir.join("mriq.hlo.txt"), "HloModule y").unwrap();
    }

    #[test]
    fn load_parses_shapes() {
        let base =
            crate::util::tempdir::TempDir::new("fpga-offload-art").unwrap();
        let dir = base.join("artifacts");
        write_meta(&dir);
        let art = Artifacts::load(&dir).unwrap();
        assert_eq!(
            art.tdfir_shape,
            TdfirShape { m: 8, n: 1024, k: 32 }
        );
        assert_eq!(art.mriq_shape, MriqShape { k: 512, x: 1024 });
    }

    #[test]
    fn discover_walks_up() {
        let base =
            crate::util::tempdir::TempDir::new("fpga-offload-art").unwrap();
        let nested = base.join("a").join("b");
        std::fs::create_dir_all(&nested).unwrap();
        write_meta(&base.join("artifacts"));
        let art = Artifacts::discover(&nested).unwrap();
        assert!(art.dir.ends_with("artifacts"));
    }

    #[test]
    fn missing_artifacts_is_helpful_error() {
        let base =
            crate::util::tempdir::TempDir::new("fpga-offload-art").unwrap();
        let err = Artifacts::discover(base.path()).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
