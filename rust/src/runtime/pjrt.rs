//! PJRT wrapper: load HLO-text artifacts and execute them on the CPU
//! client.
//!
//! This is the only place the `xla` crate is touched. The interchange
//! format is HLO *text* (see python/compile/aot.py — xla_extension 0.5.1
//! rejects jax≥0.5 serialized protos). Executables are compiled once and
//! cached; execution is synchronous on the caller thread.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

/// A compiled XLA executable plus its source path (for diagnostics).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Path of the HLO text this was compiled from.
    pub source: PathBuf,
}

impl Executable {
    /// Execute with f32 tensor inputs; returns the flattened output
    /// tensors (the lowering wraps outputs in a 1-level tuple, which is
    /// unwrapped here).
    pub fn run_f32(&self, inputs: &[TensorF32]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(TensorF32::to_literal)
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {:?}", self.source))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let parts = out.to_tuple().context("untupling result")?;
        parts
            .iter()
            .map(|lit| lit.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

/// A host-side f32 tensor: flat data + dims.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorF32 {
    pub data: Vec<f32>,
    pub dims: Vec<i64>,
}

impl TensorF32 {
    pub fn new(data: Vec<f32>, dims: Vec<i64>) -> Self {
        debug_assert_eq!(
            data.len() as i64,
            dims.iter().product::<i64>(),
            "data length must match dims product"
        );
        TensorF32 { data, dims }
    }

    pub fn vec1(data: Vec<f32>) -> Self {
        let n = data.len() as i64;
        TensorF32::new(data, vec![n])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(&self.data)
            .reshape(&self.dims)
            .context("reshaping input literal")?)
    }
}

/// Owns the PJRT client and a cache of compiled executables.
///
/// `Mutex` (not `RwLock`) around the cache: compilation writes are rare,
/// lookups are cheap clones of `Arc`-like handles — but the xla crate's
/// executable is not `Clone`, so we key by path and return `&Executable`
/// through a stable `Box`. Thread-safe so the verification environment's
/// worker threads can share one runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, &'static Executable>>,
}

impl Runtime {
    /// Create a CPU PJRT runtime.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it (cached per path).
    ///
    /// The returned reference is `'static` because compiled executables are
    /// intentionally leaked: they live for the process lifetime (there are
    /// at most a handful of model variants) and PJRT teardown order with
    /// the client is finicky otherwise.
    pub fn load(&self, path: &Path) -> Result<&'static Executable> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(exe) = cache.get(path) {
            return Ok(exe);
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .with_context(|| format!("non-utf8 path {path:?}"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        let boxed: &'static Executable = Box::leak(Box::new(Executable {
            exe,
            source: path.to_path_buf(),
        }));
        cache.insert(path.to_path_buf(), boxed);
        Ok(boxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_check() {
        let t = TensorF32::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.dims, vec![2, 2]);
        assert_eq!(t.data.len(), 4);
    }

    #[test]
    fn vec1_dims() {
        let t = TensorF32::vec1(vec![1.0; 7]);
        assert_eq!(t.dims, vec![7]);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "data length")]
    fn tensor_shape_mismatch_panics() {
        let _ = TensorF32::new(vec![1.0; 3], vec![2, 2]);
    }
}
