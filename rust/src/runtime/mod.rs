//! L3 runtime: load AOT HLO artifacts and execute them via PJRT.
//!
//! The `xla` crate wiring follows /opt/xla-example/load_hlo:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. One compiled executable per model
//! variant, cached for the process lifetime. Python is build-time only.

pub mod artifacts;
pub mod pjrt;
pub mod sampletest;

pub use artifacts::{Artifacts, MriqShape, TdfirShape};
pub use pjrt::{Executable, Runtime, TensorF32};
pub use sampletest::{run_app, run_mriq, run_tdfir, SampleRun};
