//! Sample tests: the paper's per-application performance/correctness
//! probes (§4: "the sample processing specified by the application to be
//! accelerated is performed").
//!
//! Each sample test generates deterministic input data, executes the
//! AOT-compiled HLO artifact (JAX model wrapping the Pallas kernel) on the
//! PJRT runtime, and validates the numerics against the in-crate Rust
//! reference implementation. A passing sample test is the proof that the
//! L1→L2→L3 stack composes: the bytes the coordinator measures are the
//! bytes the paper's offloaded kernel would produce.

use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use super::artifacts::Artifacts;
use super::pjrt::{Runtime, TensorF32};
use crate::workloads::{data, reference};

/// Result of one sample-test execution.
#[derive(Debug, Clone)]
pub struct SampleRun {
    /// Which application.
    pub app: &'static str,
    /// Wall-clock of the PJRT execution only (excludes data generation).
    pub exec_time: Duration,
    /// Max |kernel - reference| over all outputs.
    pub max_abs_err: f64,
    /// Number of output scalars checked.
    pub checked: usize,
}

/// Tolerance for kernel-vs-reference agreement. f32 accumulation order
/// differs between XLA and the Rust reference, so exact equality is not
/// expected; the bound is scaled generously above observed error.
pub const TOLERANCE: f64 = 5e-3;

/// Run the TDFIR sample test once.
pub fn run_tdfir(rt: &Runtime, art: &Artifacts, seed: u64) -> Result<SampleRun> {
    let s = art.tdfir_shape;
    let inp = data::tdfir_inputs(s, seed);
    let exe = rt.load(&art.tdfir_hlo)?;

    let tensors = [
        TensorF32::new(inp.xr.clone(), vec![s.m as i64, s.n as i64]),
        TensorF32::new(inp.xi.clone(), vec![s.m as i64, s.n as i64]),
        TensorF32::new(inp.hr.clone(), vec![s.m as i64, s.k as i64]),
        TensorF32::new(inp.hi.clone(), vec![s.m as i64, s.k as i64]),
    ];
    let start = Instant::now();
    let outs = exe.run_f32(&tensors)?;
    let exec_time = start.elapsed();
    ensure!(outs.len() == 2, "tdfir artifact returned {} outputs", outs.len());

    let (er, ei) = reference::tdfir(&inp.xr, &inp.xi, &inp.hr, &inp.hi, s.m, s.n, s.k);
    let err_r = max_abs_diff(&outs[0], &er);
    let err_i = max_abs_diff(&outs[1], &ei);
    let max_abs_err = err_r.max(err_i);
    ensure!(
        max_abs_err < TOLERANCE,
        "tdfir sample test numerics diverged: max err {max_abs_err}"
    );
    Ok(SampleRun {
        app: "tdfir",
        exec_time,
        max_abs_err,
        checked: er.len() + ei.len(),
    })
}

/// Run the MRI-Q sample test once.
pub fn run_mriq(rt: &Runtime, art: &Artifacts, seed: u64) -> Result<SampleRun> {
    let s = art.mriq_shape;
    let inp = data::mriq_inputs(s, seed);
    let exe = rt.load(&art.mriq_hlo)?;

    let kd = s.k as i64;
    let xd = s.x as i64;
    let tensors = [
        TensorF32::new(inp.kx.clone(), vec![kd]),
        TensorF32::new(inp.ky.clone(), vec![kd]),
        TensorF32::new(inp.kz.clone(), vec![kd]),
        TensorF32::new(inp.x.clone(), vec![xd]),
        TensorF32::new(inp.y.clone(), vec![xd]),
        TensorF32::new(inp.z.clone(), vec![xd]),
        TensorF32::new(inp.phir.clone(), vec![kd]),
        TensorF32::new(inp.phii.clone(), vec![kd]),
    ];
    let start = Instant::now();
    let outs = exe.run_f32(&tensors)?;
    let exec_time = start.elapsed();
    ensure!(outs.len() == 2, "mriq artifact returned {} outputs", outs.len());

    let (eqr, eqi) = reference::mriq(
        &inp.kx, &inp.ky, &inp.kz, &inp.x, &inp.y, &inp.z, &inp.phir,
        &inp.phii,
    );
    let err_r = max_abs_diff(&outs[0], &eqr);
    let err_i = max_abs_diff(&outs[1], &eqi);
    let max_abs_err = err_r.max(err_i);
    ensure!(
        max_abs_err < TOLERANCE * 10.0, // K=512-term trig sums accumulate more
        "mriq sample test numerics diverged: max err {max_abs_err}"
    );
    Ok(SampleRun {
        app: "mriq",
        exec_time,
        max_abs_err,
        checked: eqr.len() + eqi.len(),
    })
}

/// Dispatch by application name (as used by the CLI and the verification
/// environment).
pub fn run_app(
    rt: &Runtime,
    art: &Artifacts,
    app: &str,
    seed: u64,
) -> Result<SampleRun> {
    match app {
        "tdfir" => run_tdfir(rt, art, seed),
        "mriq" => run_mriq(rt, art, seed),
        other => anyhow::bail!("unknown sample-test app {other:?}"),
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "output length mismatch");
    a.iter()
        .zip(b)
        .map(|(x, y)| (*x as f64 - *y as f64).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_abs_diff_basics() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert_eq!(max_abs_diff(&[], &[]), 0.0);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn max_abs_diff_len_mismatch() {
        max_abs_diff(&[1.0], &[1.0, 2.0]);
    }
}
