//! Many-core (OpenMP) performance model: the fourth destination of the
//! mixed environment (arXiv:2011.12431 names many-core CPU next to GPU
//! and FPGA), mirroring [`crate::gpu::sim`] in shape — the same
//! [`PatternTiming`] output, the same per-loop
//! `entries × [overhead + compute]` decomposition — but with shared-memory
//! physics:
//!
//! * **No PCIe.** The worker threads see the host's arrays directly, so a
//!   pattern pays *no* DMA at all — only a fixed fork/join cost per
//!   parallel-region entry ([`OmpDevice::fork_join_s`], the libgomp
//!   static-schedule barrier pair). This is the structural edge over both
//!   accelerator destinations: a memory-heavy loop whose per-element work
//!   is too light to amortize a PCIe crossing still parallelizes cleanly
//!   over shared memory (the bundled Sobel stencil routes here for
//!   exactly this reason).
//! * **Modest parallelism.** An automatically inserted `#pragma omp
//!   parallel for` on an unrestructured loop sustains
//!   [`OmpDevice::parallel_lanes`] ≈ cores × SMT yield × efficiency —
//!   tens of lanes, not the GPU's hundreds. Carried loops cannot be
//!   annotated at all and run serially; reductions parallelize but pay a
//!   log-tree combine per region ([`OmpDevice::combine_latency_s`] per
//!   level).
//! * **A shared bandwidth ceiling.** All cores drain one memory system:
//!   per parallel region the model floors compute time at subtree bytes
//!   over [`OmpDevice::mem_bytes_per_sec`], so streaming loops stop
//!   scaling well before the lane count.
//! * **Near-zero build.** The destination build is seconds of
//!   `gcc -fopenmp` ([`OmpDevice::build_seconds`]) — against the GPU's
//!   ~1 min nvcc and the FPGA's ~3 h place-and-route, a many-core
//!   automation cycle is essentially free.
//!
//! ```
//! use fpga_offload::cpu::XEON_GOLD_6130;
//!
//! // Tens of lanes, seconds of build — the many-core destination trades
//! // peak parallelism for zero transfer cost and instant turnaround.
//! let omp = &XEON_GOLD_6130;
//! assert!(omp.parallel_lanes() > 8.0);
//! assert!(omp.parallel_lanes() < omp.cores as f64 * 2.0);
//! assert!(omp.build_seconds < 60.0);
//! ```

use crate::analysis::{Analysis, Dependence};
use crate::codegen::KernelIr;
use crate::fpga::{subtree_ids, LoopTiming, PatternTiming, SimError};
use crate::hls::ResourceEstimate;
use crate::minic::ast::LoopId;
use crate::minic::OpCounts;

use super::CpuModel;

/// Static description of a many-core OpenMP destination. Per-thread
/// scalar throughput is modeled with the *baseline* [`CpuModel`] (base
/// clocks converge under all-core load; keeping one scalar model also
/// keeps the all-CPU denominator exact) — this struct describes only
/// what parallel execution adds and costs.
#[derive(Debug, Clone, PartialEq)]
pub struct OmpDevice {
    pub name: &'static str,
    /// Physical worker cores.
    pub cores: u64,
    /// Throughput yield of 2-way SMT over the physical cores (> 1.0).
    pub smt_yield: f64,
    /// Fraction of linear scaling an *automatically* annotated
    /// `parallel for` sustains (scheduling skew, NUMA, false sharing).
    pub par_efficiency: f64,
    /// Fork/join cost per parallel-region entry, seconds (libgomp
    /// static schedule: team wake + end barrier).
    pub fork_join_s: f64,
    /// Effective memory bandwidth shared across all cores, bytes/s.
    pub mem_bytes_per_sec: f64,
    /// Per-level cost of the log-tree reduction combine, seconds.
    pub combine_latency_s: f64,
    /// Modeled destination build per pattern, seconds — a `gcc
    /// -fopenmp` compile, not a place-and-route.
    pub build_seconds: f64,
}

/// Intel Xeon Gold 6130 (Skylake-SP, 16C/32T): the many-core board the
/// mixed-destination follow-on puts beside the Arria10 and the T4 in
/// the verification environment.
pub const XEON_GOLD_6130: OmpDevice = OmpDevice {
    name: "Intel Xeon Gold 6130 (16C/32T, OpenMP)",
    cores: 16,
    smt_yield: 1.15,
    par_efficiency: 0.75,
    fork_join_s: 4.0e-6,
    mem_bytes_per_sec: 8.0e10, // 6-ch DDR4-2666, STREAM-class effective
    combine_latency_s: 5.0e-7,
    build_seconds: 5.0,
};

impl OmpDevice {
    /// Lanes an automatically parallelized loop effectively keeps busy:
    /// cores × SMT yield × parallel efficiency (never below one).
    pub fn parallel_lanes(&self) -> f64 {
        (self.cores as f64 * self.smt_yield * self.par_efficiency).max(1.0)
    }

    /// Levels of the log-tree combine a reduction pays when `threads`
    /// lanes fold their partial values.
    pub fn combine_levels(&self, lanes: f64) -> f64 {
        lanes.max(2.0).log2().ceil()
    }
}

/// Simulate a pattern of offloaded loops on a many-core OpenMP
/// destination.
///
/// Returns the same [`PatternTiming`] the FPGA and GPU simulators
/// produce so the funnel and the mixed-destination selector compare all
/// destinations directly; `combined` stays at the zero
/// [`ResourceEstimate`] — an OpenMP pattern consumes no FPGA fabric.
pub fn simulate(
    analysis: &Analysis,
    kernels: &[KernelIr],
    cpu: &CpuModel,
    omp: &OmpDevice,
) -> Result<PatternTiming, SimError> {
    // Disjointness: no offloaded loop may contain another offloaded loop
    // (same rule as every destination — one parallel region per nest).
    let offloaded: Vec<LoopId> = kernels.iter().map(|k| k.loop_id).collect();
    for k in kernels {
        let subtree = subtree_ids(analysis, k.loop_id);
        for other in &offloaded {
            if *other != k.loop_id && subtree.contains(other) {
                return Err(SimError::OverlappingLoops(k.loop_id, *other));
            }
        }
    }

    let cpu_baseline_s = cpu.time(&analysis.profile.total);

    let mut offloaded_ops = OpCounts::default();
    let mut loops = Vec::new();
    for k in kernels {
        let lp = analysis
            .profile
            .loop_profile(k.loop_id)
            .ok_or(SimError::ColdLoop(k.loop_id))?;
        offloaded_ops = offloaded_ops.plus(&lp.ops);

        let entries = lp.entries.max(1);
        // Work distribution: iterations of the annotated loop itself
        // across the team (static schedule, no restructuring).
        let threads = (lp.trips / entries).max(1);
        // One region's whole subtree, serially, on the baseline core.
        let serial_s = cpu.time(&lp.ops) / entries as f64;
        let lanes = omp.parallel_lanes().min(threads as f64);

        let compute_per_entry = match &k.dependence {
            // A carried loop cannot be annotated: the region runs on
            // one thread at exactly the serial time, so the fork/join
            // below makes the pattern a strict loss — which is the
            // right verified answer for a carried loop.
            Dependence::Carried(_) => serial_s,
            dep => {
                let mut t = serial_s / lanes;
                if matches!(dep, Dependence::Reduction(_)) {
                    t += omp.combine_levels(lanes) * omp.combine_latency_s;
                }
                // Shared bandwidth ceiling: all lanes drain one memory
                // system.
                let mem_s = (lp.ops.bytes() as f64 / entries as f64)
                    / omp.mem_bytes_per_sec;
                t.max(mem_s)
            }
        };

        let compute_s = compute_per_entry * entries as f64;
        // No PCIe: the only per-entry overhead is the fork/join pair.
        let transfer_s = entries as f64 * omp.fork_join_s;

        loops.push(LoopTiming {
            loop_id: k.loop_id,
            entries,
            slots: threads,
            compute_s,
            transfer_s,
            total_s: compute_s + transfer_s,
        });
    }

    let rest_ops = analysis.profile.total.saturating_sub(&offloaded_ops);
    let cpu_rest_s = cpu.time(&rest_ops);
    let omp_s: f64 = loops.iter().map(|l| l.total_s).sum();
    let pattern_s = cpu_rest_s + omp_s;
    let speedup = if pattern_s > 0.0 {
        cpu_baseline_s / pattern_s
    } else {
        f64::INFINITY
    };

    Ok(PatternTiming {
        cpu_baseline_s,
        cpu_rest_s,
        loops,
        pattern_s,
        speedup,
        combined: ResourceEstimate::default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::codegen::split;
    use crate::cpu::XEON_BRONZE_3104;
    use crate::minic::parse;

    /// A trig-dense wide loop (parallel-friendly), a streaming
    /// double-precision copy (bandwidth-ceiling probe), a carried
    /// recurrence (serializes), and a wide scalar reduction.
    const SRC: &str = "
#define N 4096
#define M 65536
float a[N]; float b[N]; float acc[N];
double src[M]; double dst[M];
float total;
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.0004 - 0.8; }       // L0 init
    for (int i = 0; i < N; i++) {                                  // L1 trig
        b[i] = sin(a[i]) * cos(a[i]) + sqrt(a[i] * a[i] + 1.0);
    }
    for (int i = 0; i < M; i++) { dst[i] = src[i]; }               // L2 copy
    for (int i = 1; i < N; i++) { acc[i] = acc[i - 1] + b[i]; }    // L3 carried
    for (int i = 0; i < N; i++) { total += b[i] * b[i]; }          // L4 reduce
    return 0;
}";

    fn setup() -> (crate::minic::Program, Analysis) {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        (prog, an)
    }

    fn kernel(
        prog: &crate::minic::Program,
        an: &Analysis,
        id: u32,
    ) -> KernelIr {
        split(prog, an.loop_by_id(LoopId(id)).unwrap())
            .unwrap()
            .kernel
    }

    #[test]
    fn device_figures_sane() {
        let d = &XEON_GOLD_6130;
        assert!(d.parallel_lanes() > 8.0);
        assert!(d.parallel_lanes() < d.cores as f64 * d.smt_yield);
        assert!(d.build_seconds < 60.0, "an OpenMP build is gcc, not HLS");
        assert_eq!(d.combine_levels(16.0), 4.0);
        assert_eq!(d.combine_levels(1.0), 1.0);
    }

    #[test]
    fn wide_trig_loop_scales_to_the_lanes() {
        let (prog, an) = setup();
        let k = kernel(&prog, &an, 1);
        let t = simulate(&an, &[k], &XEON_BRONZE_3104, &XEON_GOLD_6130)
            .unwrap();
        assert!(
            t.speedup > 1.2,
            "wide trig loop should win on the many-core: {:.2}x",
            t.speedup
        );
        assert_eq!(t.loops[0].entries, 1);
        assert_eq!(t.loops[0].slots, 4096);
        // Compute-dense: the lane split, not the bandwidth ceiling,
        // decides this loop.
        let lp = an.profile.loop_profile(LoopId(1)).unwrap();
        let expected =
            XEON_BRONZE_3104.time(&lp.ops) / XEON_GOLD_6130.parallel_lanes();
        assert!((t.loops[0].compute_s - expected).abs() < expected * 1e-9);
    }

    #[test]
    fn no_pcie_only_fork_join() {
        let (prog, an) = setup();
        let k = kernel(&prog, &an, 1);
        // The kernel does move real array footprints on accelerator
        // destinations...
        assert!(k.bytes_in() + k.bytes_out() > 0);
        let t = simulate(&an, &[k], &XEON_BRONZE_3104, &XEON_GOLD_6130)
            .unwrap();
        // ...but shared memory pays only the fork/join pair per entry.
        let expected =
            t.loops[0].entries as f64 * XEON_GOLD_6130.fork_join_s;
        assert!((t.loops[0].transfer_s - expected).abs() < 1e-15);
    }

    #[test]
    fn streaming_copy_hits_the_bandwidth_ceiling() {
        let (prog, an) = setup();
        let k = kernel(&prog, &an, 2);
        let t = simulate(&an, &[k], &XEON_BRONZE_3104, &XEON_GOLD_6130)
            .unwrap();
        let lp = an.profile.loop_profile(LoopId(2)).unwrap();
        let mem_floor =
            lp.ops.bytes() as f64 / XEON_GOLD_6130.mem_bytes_per_sec;
        let lane_split =
            XEON_BRONZE_3104.time(&lp.ops) / XEON_GOLD_6130.parallel_lanes();
        // The 16-byte-per-element double stream saturates memory before
        // it runs out of lanes...
        assert!(
            mem_floor > lane_split,
            "mem {mem_floor:e} vs lanes {lane_split:e}"
        );
        // ...and the model charges the ceiling, not the lane split.
        assert!((t.loops[0].compute_s - mem_floor).abs() < mem_floor * 1e-9);
        // Effective scaling is therefore well below the lane count.
        let serial = XEON_BRONZE_3104.time(&lp.ops);
        let local_speedup = serial / t.loops[0].total_s;
        assert!(local_speedup < XEON_GOLD_6130.parallel_lanes() * 0.9);
        assert!(local_speedup > 1.0);
    }

    #[test]
    fn carried_loop_serializes_and_loses() {
        let (prog, an) = setup();
        let k = kernel(&prog, &an, 3);
        assert!(matches!(k.dependence, Dependence::Carried(_)));
        let t = simulate(&an, &[k], &XEON_BRONZE_3104, &XEON_GOLD_6130)
            .unwrap();
        // Serial region + fork/join: strictly slower than not offloading.
        assert!(t.speedup < 1.0, "got {:.3}x", t.speedup);
        let lp = an.profile.loop_profile(LoopId(3)).unwrap();
        let serial = XEON_BRONZE_3104.time(&lp.ops);
        assert!((t.loops[0].compute_s - serial).abs() < serial * 1e-9);
    }

    #[test]
    fn reduction_pays_the_log_tree_combine() {
        let (prog, an) = setup();
        let k = kernel(&prog, &an, 4);
        assert!(matches!(k.dependence, Dependence::Reduction(_)));
        let t = simulate(&an, &[k], &XEON_BRONZE_3104, &XEON_GOLD_6130)
            .unwrap();
        let lp = an.profile.loop_profile(LoopId(4)).unwrap();
        let lanes = XEON_GOLD_6130.parallel_lanes();
        let lane_split = XEON_BRONZE_3104.time(&lp.ops) / lanes;
        let combine = XEON_GOLD_6130.combine_levels(lanes)
            * XEON_GOLD_6130.combine_latency_s;
        // Strictly more than an independent loop of equal work...
        assert!(t.loops[0].compute_s > lane_split);
        // ...by exactly the combine tree (this loop is compute-bound).
        assert!(
            (t.loops[0].compute_s - (lane_split + combine)).abs()
                < (lane_split + combine) * 1e-9
        );
    }

    #[test]
    fn overlapping_pattern_rejected() {
        // A parallel region inside another parallel region of the same
        // pattern is malformed on every destination.
        const NESTED: &str = "
#define R 16
#define N 256
float x[N]; float y[N];
int main() {
    for (int r = 0; r < R; r++) {             // L0 outer
        for (int i = 0; i < N; i++) {         // L1 inner
            y[i] = y[i] + x[i] * 0.5;
        }
    }
    return 0;
}";
        let nprog = parse(NESTED).unwrap();
        let nan = analyze(&nprog, "main").unwrap();
        let k0 = kernel(&nprog, &nan, 0);
        let k1 = kernel(&nprog, &nan, 1);
        let err =
            simulate(&nan, &[k0, k1], &XEON_BRONZE_3104, &XEON_GOLD_6130)
                .unwrap_err();
        assert!(matches!(err, SimError::OverlappingLoops(..)));
    }

    #[test]
    fn empty_pattern_is_baseline() {
        let (_prog, an) = setup();
        let t = simulate(&an, &[], &XEON_BRONZE_3104, &XEON_GOLD_6130)
            .unwrap();
        assert!((t.speedup - 1.0).abs() < 1e-9);
        assert_eq!(t.loops.len(), 0);
        assert_eq!(t.combined, ResourceEstimate::default());
    }

    #[test]
    fn omp_pattern_consumes_no_fpga_fabric() {
        let (prog, an) = setup();
        let k = kernel(&prog, &an, 1);
        let t = simulate(&an, &[k], &XEON_BRONZE_3104, &XEON_GOLD_6130)
            .unwrap();
        assert_eq!(t.combined, ResourceEstimate::default());
    }
}
