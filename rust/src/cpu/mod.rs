//! CPU cost models: the "all CPU processing" baseline (paper Fig. 3:
//! Intel Xeon Bronze 3104, 1.7 GHz, no turbo) and, in [`omp`], the
//! many-core OpenMP destination built on top of it.
//!
//! [`CpuModel`] converts the interpreter's dynamic op counts into modeled
//! single-thread wall-clock. Per-op costs are in cycles and folded
//! through an effective superscalar factor; memory traffic is priced
//! separately so access-heavy loops are slower than flop-heavy loops of
//! equal op count (which is what makes offloading
//! access-light/compute-dense loops pay off — the paper's selection
//! signal).
//!
//! Every destination's speedup figure is a ratio against this model, so
//! it must be deterministic and strictly monotone in work:
//!
//! ```
//! use fpga_offload::cpu::XEON_BRONZE_3104;
//! use fpga_offload::minic::OpCounts;
//!
//! let light = OpCounts { f_add: 1_000, reads: 1_000, ..Default::default() };
//! let heavy = OpCounts { f_add: 2_000, reads: 2_000, ..Default::default() };
//! assert!(XEON_BRONZE_3104.time(&light) > 0.0);
//! assert!(XEON_BRONZE_3104.time(&heavy) > XEON_BRONZE_3104.time(&light));
//! ```

pub mod omp;

pub use omp::{OmpDevice, XEON_GOLD_6130};

use crate::minic::OpCounts;

/// A CPU performance model.
#[derive(Debug, Clone, PartialEq)]
pub struct CpuModel {
    pub name: &'static str,
    pub clock_hz: f64,
    /// Sustained instructions-per-cycle for scalar FP code.
    pub ipc: f64,
    /// Cycles per op class (before IPC folding).
    pub cyc_fadd: f64,
    pub cyc_fmul: f64,
    pub cyc_fdiv: f64,
    pub cyc_trig: f64,
    pub cyc_iop: f64,
    pub cyc_cmp: f64,
    /// Cycles per array access (streaming, cache-resident mix).
    pub cyc_read: f64,
    pub cyc_write: f64,
}

/// Intel Xeon Bronze 3104 (paper Fig. 3): 6C/6T, 1.7 GHz base, no turbo,
/// modeled single-threaded (the paper's applications are single-thread C).
pub const XEON_BRONZE_3104: CpuModel = CpuModel {
    name: "Intel Xeon Bronze 3104 @ 1.7 GHz",
    clock_hz: 1.7e9,
    ipc: 1.6,
    cyc_fadd: 1.0,
    cyc_fmul: 1.0,
    cyc_fdiv: 14.0,
    cyc_trig: 42.0, // libm sin/cos on Skylake-SP class cores
    cyc_iop: 0.5,
    cyc_cmp: 0.5,
    cyc_read: 1.1,
    cyc_write: 1.4,
};

impl CpuModel {
    /// Modeled cycles for an op-count record.
    pub fn cycles(&self, ops: &OpCounts) -> f64 {
        let raw = ops.f_add as f64 * self.cyc_fadd
            + ops.f_mul as f64 * self.cyc_fmul
            + ops.f_div as f64 * self.cyc_fdiv
            + ops.f_trig as f64 * self.cyc_trig
            + ops.i_op as f64 * self.cyc_iop
            + ops.cmp as f64 * self.cyc_cmp
            + ops.reads as f64 * self.cyc_read
            + ops.writes as f64 * self.cyc_write;
        raw / self.ipc
    }

    /// Modeled seconds for an op-count record.
    pub fn time(&self, ops: &OpCounts) -> f64 {
        self.cycles(ops) / self.clock_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ops(f_add: u64, f_trig: u64, reads: u64) -> OpCounts {
        OpCounts {
            f_add,
            f_trig,
            reads,
            ..Default::default()
        }
    }

    #[test]
    fn time_positive_and_monotone() {
        let m = &XEON_BRONZE_3104;
        let t1 = m.time(&ops(1000, 0, 1000));
        let t2 = m.time(&ops(2000, 0, 2000));
        assert!(t1 > 0.0);
        assert!(t2 > t1 * 1.9 && t2 < t1 * 2.1);
    }

    #[test]
    fn trig_dominates_adds() {
        let m = &XEON_BRONZE_3104;
        assert!(m.time(&ops(0, 100, 0)) > m.time(&ops(100, 0, 0)) * 10.0);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(XEON_BRONZE_3104.time(&OpCounts::default()), 0.0);
    }

    #[test]
    fn gigaflop_scale_sane() {
        // 1e9 adds ≈ 0.37 s at 1.7 GHz / IPC 1.6 — single-digit-GFLOPS
        // scalar, the right ballpark for this CPU.
        let t = XEON_BRONZE_3104.time(&ops(1_000_000_000, 0, 0));
        assert!((0.1..1.0).contains(&t), "{t}");
    }
}
