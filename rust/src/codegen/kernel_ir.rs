//! Kernel IR: the OpenCL-analog representation of one offloaded loop.
//!
//! Paper §3.3: "two processes are required to make a loop statement into a
//! high level language such as OpenCL. One is to divide a CPU processing
//! program into a kernel (FPGA) program and a host (CPU) program … The
//! other is to include techniques for speeding up for loop statements."
//! [`crate::codegen::split`] performs the division; this module is the
//! resulting kernel-side artifact, consumed by [`crate::hls`] (resource
//! estimation), [`crate::fpga`] (simulation + functional execution) and
//! [`crate::codegen::opencl`] (text emission).

use std::fmt;

use crate::analysis::Dependence;
use crate::minic::ast::{LoopId, Scalar, Stmt};

/// Transfer direction of a kernel parameter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Host → device before launch.
    In,
    /// Device → host after completion.
    Out,
    /// Both ways.
    InOut,
}

impl Direction {
    pub fn reads_host(self) -> bool {
        matches!(self, Direction::In | Direction::InOut)
    }

    pub fn writes_host(self) -> bool {
        matches!(self, Direction::Out | Direction::InOut)
    }
}

impl fmt::Display for Direction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Direction::In => "in",
            Direction::Out => "out",
            Direction::InOut => "inout",
        })
    }
}

/// One kernel parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelParam {
    pub name: String,
    pub elem: Scalar,
    /// `Some(dims)` for statically sized arrays; `None` for scalars.
    pub dims: Option<Vec<usize>>,
    pub direction: Direction,
}

impl KernelParam {
    pub fn is_array(&self) -> bool {
        self.dims.is_some()
    }

    /// Bytes transferred for this parameter (one direction).
    pub fn bytes(&self) -> u64 {
        match &self.dims {
            Some(dims) => {
                dims.iter().product::<usize>() as u64
                    * self.elem.size_bytes()
            }
            None => self.elem.size_bytes(),
        }
    }
}

/// The kernel: one loop statement hoisted into an OpenCL-style kernel.
#[derive(Debug, Clone)]
pub struct KernelIr {
    pub loop_id: LoopId,
    /// `kernel_L<n>`.
    pub name: String,
    pub params: Vec<KernelParam>,
    /// The loop statement itself (a `Stmt::For`), possibly unrolled.
    pub body: Stmt,
    /// Unroll factor applied (1 = none) — paper's expansion number B.
    pub unroll: u32,
    /// Static trip count of the outermost loop, if known.
    pub static_trips: Option<u64>,
    pub dependence: Dependence,
    /// `#define` constants visible to the loop (needed by the HLS model
    /// to evaluate inner-loop bounds for spatialization).
    pub defines: Vec<(String, f64)>,
}

impl KernelIr {
    /// Total host→device bytes.
    pub fn bytes_in(&self) -> u64 {
        self.params
            .iter()
            .filter(|p| p.direction.reads_host())
            .map(KernelParam::bytes)
            .sum()
    }

    /// Total device→host bytes.
    pub fn bytes_out(&self) -> u64 {
        self.params
            .iter()
            .filter(|p| p.direction.writes_host())
            .map(KernelParam::bytes)
            .sum()
    }

    pub fn array_params(&self) -> impl Iterator<Item = &KernelParam> {
        self.params.iter().filter(|p| p.is_array())
    }

    pub fn scalar_params(&self) -> impl Iterator<Item = &KernelParam> {
        self.params.iter().filter(|p| !p.is_array())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn param(name: &str, dims: Option<Vec<usize>>, dir: Direction) -> KernelParam {
        KernelParam {
            name: name.into(),
            elem: Scalar::Float,
            dims,
            direction: dir,
        }
    }

    #[test]
    fn param_bytes() {
        assert_eq!(param("x", Some(vec![8, 4]), Direction::In).bytes(), 128);
        assert_eq!(param("s", None, Direction::In).bytes(), 4);
    }

    #[test]
    fn transfer_totals_respect_direction() {
        let k = KernelIr {
            loop_id: LoopId(0),
            name: "kernel_L0".into(),
            params: vec![
                param("a", Some(vec![16]), Direction::In),
                param("b", Some(vec![16]), Direction::Out),
                param("c", Some(vec![16]), Direction::InOut),
                param("n", None, Direction::In),
            ],
            body: Stmt::Return { value: None, line: 0 },
            unroll: 1,
            static_trips: Some(16),
            dependence: Dependence::Independent,
            defines: Vec::new(),
        };
        assert_eq!(k.bytes_in(), 64 + 64 + 4);
        assert_eq!(k.bytes_out(), 64 + 64);
        assert_eq!(k.array_params().count(), 3);
        assert_eq!(k.scalar_params().count(), 1);
    }
}
