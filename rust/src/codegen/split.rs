//! Host/kernel division (paper §3.3: "divide a CPU processing program into
//! a kernel (FPGA) program and a host (CPU) program").
//!
//! For a candidate loop the splitter derives the kernel signature from the
//! analysis' reference sets — arrays become `__global` buffers with a
//! transfer [`Direction`], free scalars become value arguments — and
//! produces:
//!
//! * the [`KernelIr`] (resource estimation / simulation / OpenCL text),
//! * an *outlined MiniC function* whose body is the loop, and
//! * the host-side launch call.
//!
//! The outlined function is the functional-verification path: running the
//! host program with loops replaced by calls through the ordinary
//! interpreter proves the split captured every input the kernel needs — a
//! missed parameter surfaces as an undeclared-variable error, exactly the
//! bug class real OpenCL splits suffer.

use std::collections::BTreeMap;

use crate::analysis::profile::AnalyzedLoop;
use crate::minic::ast::*;
use crate::minic::Program;

use super::kernel_ir::{Direction, KernelIr, KernelParam};

/// Splitting failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SplitError {
    NotOffloadable(LoopId),
    LoopNotFound(LoopId),
    /// Could not determine the extent of array `name` (pointer parameter
    /// with no resolvable call site).
    UnsizedArray(String),
    UnknownScalar(String),
    /// The loop writes a function-local scalar that outlives it (e.g. the
    /// accumulator of an enclosing loop). OpenCL kernels cannot write
    /// back by-value scalars; offloading this loop alone is unsound, so
    /// the generator refuses (offload an enclosing loop instead).
    ScalarWriteback(String),
}

impl std::fmt::Display for SplitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SplitError::NotOffloadable(id) => {
                write!(f, "loop {id} is not offloadable")
            }
            SplitError::LoopNotFound(id) => {
                write!(f, "loop {id} not found in program")
            }
            SplitError::UnsizedArray(n) => {
                write!(f, "cannot determine extent of array `{n}`")
            }
            SplitError::UnknownScalar(n) => {
                write!(f, "cannot determine type of scalar `{n}`")
            }
            SplitError::ScalarWriteback(n) => {
                write!(
                    f,
                    "loop writes non-global scalar `{n}` — no write-back \
                     path for a by-value kernel argument"
                )
            }
        }
    }
}

impl std::error::Error for SplitError {}

/// Result of splitting one loop.
#[derive(Debug, Clone)]
pub struct SplitResult {
    pub kernel: KernelIr,
    /// The kernel as an ordinary MiniC function (for verification runs).
    pub kernel_fn: Function,
    /// The host-side call replacing the loop.
    pub launch_call: Stmt,
}

/// Split one analyzed loop out of the program.
pub fn split(prog: &Program, al: &AnalyzedLoop) -> Result<SplitResult, SplitError> {
    let id = al.info.id;
    if !al.info.offloadable() {
        return Err(SplitError::NotOffloadable(id));
    }
    let loop_stmt = find_loop(prog, id).ok_or(SplitError::LoopNotFound(id))?;

    let mut params: Vec<KernelParam> = Vec::new();
    let mut args: Vec<Expr> = Vec::new();
    let mut fn_params: Vec<Param> = Vec::new();

    // Arrays, in deterministic (BTreeSet) order: read∪written.
    let mut all_arrays: BTreeMap<&str, Direction> = BTreeMap::new();
    for a in &al.info.arrays_read {
        all_arrays.insert(a, Direction::In);
    }
    for a in &al.info.arrays_written {
        all_arrays
            .entry(a)
            .and_modify(|d| *d = Direction::InOut)
            .or_insert(Direction::Out);
    }
    for (name, dir) in &all_arrays {
        let (elem, dims) = array_shape(prog, &al.info.function, name)
            .ok_or_else(|| SplitError::UnsizedArray(name.to_string()))?;
        params.push(KernelParam {
            name: name.to_string(),
            elem,
            dims: Some(dims.clone()),
            direction: *dir,
        });
        args.push(Expr::Var(name.to_string()));
        fn_params.push(Param {
            name: name.to_string(),
            ty: Type::Array(elem, dims),
        });
    }

    // Free scalars become value arguments.
    for name in &al.info.free_scalars {
        let elem = scalar_type(prog, &al.info.function, name)
            .ok_or_else(|| SplitError::UnknownScalar(name.clone()))?;
        let direction = scalar_direction(&loop_stmt, name);
        if direction.writes_host() && !is_global(prog, name) {
            return Err(SplitError::ScalarWriteback(name.clone()));
        }
        params.push(KernelParam {
            name: name.clone(),
            elem,
            dims: None,
            direction,
        });
        args.push(Expr::Var(name.clone()));
        fn_params.push(Param {
            name: name.clone(),
            ty: Type::Scalar(elem),
        });
    }

    let kname = format!("kernel_{id}");
    let (static_trips, line) = match &loop_stmt {
        Stmt::For { line, .. } | Stmt::While { line, .. } => {
            (al.info.static_trips, *line)
        }
        _ => unreachable!(),
    };

    let kernel = KernelIr {
        loop_id: id,
        name: kname.clone(),
        params,
        body: loop_stmt.clone(),
        unroll: 1,
        static_trips,
        dependence: al.dependence.clone(),
        defines: prog.defines.clone(),
    };

    // NOTE on scalar outputs: a `Reduction` accumulator is a scalar the
    // kernel must return. MiniC functions pass scalars by value, so the
    // outlined function writes reductions back through a 1-element global
    // staging array would complicate things — instead the outliner keeps
    // reduction scalars *global* (they already are, or they wouldn't be
    // free), and the outlined function updates the global directly. The
    // kernel-parameter list still records them for transfer accounting.
    let kernel_fn_params: Vec<Param> = fn_params
        .iter()
        .filter(|p| {
            // Globals stay global in the outlined fn so writes persist.
            !is_global(prog, &p.name)
        })
        .cloned()
        .collect();
    let kernel_fn_args: Vec<Expr> = all_arrays
        .keys()
        .map(|n| n.to_string())
        .chain(al.info.free_scalars.iter().cloned())
        .filter(|n| !is_global(prog, n))
        .map(Expr::Var)
        .collect();

    let kernel_fn = Function {
        name: kname.clone(),
        ret: Scalar::Void,
        params: kernel_fn_params,
        body: vec![loop_stmt.clone()],
        line,
    };
    let launch_call = Stmt::ExprStmt {
        expr: Expr::Call {
            name: kname,
            args: kernel_fn_args,
        },
        line,
    };

    Ok(SplitResult {
        kernel,
        kernel_fn,
        launch_call,
    })
}

/// Build the host program: loops in `splits` replaced by launch calls,
/// outlined kernel functions appended.
pub fn offload_program(prog: &Program, splits: &[SplitResult]) -> Program {
    let mut out = prog.clone();
    for f in &mut out.functions {
        f.body = replace_loops(std::mem::take(&mut f.body), splits);
    }
    for s in splits {
        out.functions.push(s.kernel_fn.clone());
    }
    out
}

fn replace_loops(stmts: Vec<Stmt>, splits: &[SplitResult]) -> Vec<Stmt> {
    stmts
        .into_iter()
        .map(|s| replace_in_stmt(s, splits))
        .collect()
}

fn replace_in_stmt(s: Stmt, splits: &[SplitResult]) -> Stmt {
    match s {
        Stmt::For {
            id,
            init,
            cond,
            step,
            body,
            line,
        } => {
            if let Some(sp) = splits.iter().find(|sp| sp.kernel.loop_id == id)
            {
                sp.launch_call.clone()
            } else {
                Stmt::For {
                    id,
                    init,
                    cond,
                    step,
                    body: replace_loops(body, splits),
                    line,
                }
            }
        }
        Stmt::While { id, cond, body, line } => {
            if let Some(sp) = splits.iter().find(|sp| sp.kernel.loop_id == id)
            {
                sp.launch_call.clone()
            } else {
                Stmt::While {
                    id,
                    cond,
                    body: replace_loops(body, splits),
                    line,
                }
            }
        }
        Stmt::If {
            cond,
            then_branch,
            else_branch,
            line,
        } => Stmt::If {
            cond,
            then_branch: replace_loops(then_branch, splits),
            else_branch: replace_loops(else_branch, splits),
            line,
        },
        other => other,
    }
}

fn find_loop(prog: &Program, id: LoopId) -> Option<Stmt> {
    let mut found = None;
    prog.walk_stmts(&mut |s| {
        if found.is_some() {
            return;
        }
        if let Stmt::For { id: lid, .. } | Stmt::While { id: lid, .. } = s {
            if *lid == id {
                found = Some(s.clone());
            }
        }
    });
    found
}

fn is_global(prog: &Program, name: &str) -> bool {
    prog.globals.iter().any(
        |g| matches!(g, Stmt::Decl { name: n, .. } if n == name),
    ) || prog.define(name).is_some()
}

/// Element type + dims for array `name` visible in `func`.
fn array_shape(
    prog: &Program,
    func: &str,
    name: &str,
) -> Option<(Scalar, Vec<usize>)> {
    // Global array?
    for g in &prog.globals {
        if let Stmt::Decl {
            name: n,
            ty: Type::Array(elem, dims),
            ..
        } = g
        {
            if n == name {
                return Some((*elem, dims.clone()));
            }
        }
    }
    // Function parameter?
    let f = prog.function(func)?;
    let param = f.params.iter().find(|p| p.name == name)?;
    match &param.ty {
        Type::Array(elem, dims) => Some((*elem, dims.clone())),
        Type::Ptr(elem) => {
            // Resolve the extent through call sites: find a call to `func`
            // passing a sizable array for this parameter.
            let pos = f.params.iter().position(|p| p.name == name)?;
            resolve_ptr_extent(prog, func, pos).map(|dims| (*elem, dims))
        }
        Type::Scalar(_) => None,
    }
}

fn resolve_ptr_extent(
    prog: &Program,
    func: &str,
    arg_pos: usize,
) -> Option<Vec<usize>> {
    let mut resolved: Option<Vec<usize>> = None;
    prog.walk_stmts(&mut |s| {
        let exprs: Vec<&Expr> = match s {
            Stmt::ExprStmt { expr, .. } => vec![expr],
            Stmt::Assign { value, .. } => vec![value],
            Stmt::Decl { init: Some(e), .. } => vec![e],
            _ => vec![],
        };
        for e in exprs {
            e.walk(&mut |e| {
                if let Expr::Call { name, args } = e {
                    if name == func && arg_pos < args.len() {
                        if let Expr::Var(arg_name) = &args[arg_pos] {
                            for g in &prog.globals {
                                if let Stmt::Decl {
                                    name: n,
                                    ty: Type::Array(_, dims),
                                    ..
                                } = g
                                {
                                    if n == arg_name && resolved.is_none() {
                                        resolved = Some(dims.clone());
                                    }
                                }
                            }
                        }
                    }
                }
            });
        }
    });
    resolved
}

/// Scalar type for `name` visible in `func`.
fn scalar_type(prog: &Program, func: &str, name: &str) -> Option<Scalar> {
    let f = prog.function(func)?;
    // Parameter?
    if let Some(p) = f.params.iter().find(|p| p.name == name) {
        if let Type::Scalar(s) = p.ty {
            return Some(s);
        }
    }
    // Local declaration before the loop?
    let mut found = None;
    for s in &f.body {
        s.walk(&mut |s| {
            if let Stmt::Decl {
                name: n,
                ty: Type::Scalar(sc),
                ..
            } = s
            {
                if n == name && found.is_none() {
                    found = Some(*sc);
                }
            }
        });
    }
    if found.is_some() {
        return found;
    }
    // Global?
    for g in &prog.globals {
        if let Stmt::Decl {
            name: n,
            ty: Type::Scalar(sc),
            ..
        } = g
        {
            if n == name {
                return Some(*sc);
            }
        }
    }
    None
}

/// A scalar written inside the loop (reduction) must flow back.
fn scalar_direction(loop_stmt: &Stmt, name: &str) -> Direction {
    let mut written = false;
    loop_stmt.walk(&mut |s| {
        if let Stmt::Assign {
            target: LValue::Var(n),
            ..
        } = s
        {
            if n == name {
                written = true;
            }
        }
    });
    if written {
        Direction::InOut
    } else {
        Direction::In
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::minic::parse;

    const SRC: &str = "
#define N 32
float a[N]; float b[N];
float scale;
float total;
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.25; }           // L0
    for (int i = 0; i < N; i++) { b[i] = a[i] * scale + 1.0; } // L1
    for (int i = 0; i < N; i++) { total += b[i]; }             // L2
    return 0;
}";

    fn split_loop(src: &str, id: u32) -> SplitResult {
        let prog = parse(src).unwrap();
        let a = analyze(&prog, "main").unwrap();
        let al = a.loop_by_id(LoopId(id)).unwrap();
        split(&prog, al).unwrap()
    }

    #[test]
    fn elementwise_split_directions() {
        let r = split_loop(SRC, 1);
        let k = &r.kernel;
        let dir = |n: &str| {
            k.params.iter().find(|p| p.name == n).unwrap().direction
        };
        assert_eq!(dir("a"), Direction::In);
        assert_eq!(dir("b"), Direction::Out);
        assert_eq!(dir("scale"), Direction::In);
        assert_eq!(k.bytes_in(), 32 * 4 + 4);
        assert_eq!(k.bytes_out(), 32 * 4);
    }

    #[test]
    fn reduction_scalar_is_inout() {
        let r = split_loop(SRC, 2);
        let total = r
            .kernel
            .params
            .iter()
            .find(|p| p.name == "total")
            .unwrap();
        assert_eq!(total.direction, Direction::InOut);
        assert!(total.dims.is_none());
    }

    #[test]
    fn offloaded_program_matches_original_numerics() {
        use crate::minic::{Interp, Value};
        let prog = parse(SRC).unwrap();
        let a = analyze(&prog, "main").unwrap();
        let r1 = split(&prog, a.loop_by_id(LoopId(1)).unwrap()).unwrap();
        let r2 = split(&prog, a.loop_by_id(LoopId(2)).unwrap()).unwrap();
        let host = offload_program(&prog, &[r1, r2]);

        // Typecheck the host program — the outlined kernels must be
        // complete (no undeclared variables).
        let errs = crate::minic::typecheck::check(&host);
        assert!(errs.is_empty(), "{errs:?}");

        // Run both and compare array `b` and `total`.
        let mut base = Interp::new(&prog).unwrap();
        base.call("main", &[]).unwrap();
        let mut off = Interp::new(&host).unwrap();
        off.call("main", &[]).unwrap();

        let b_base = base.array(base.global_array("b").unwrap()).data.clone();
        let b_off = off.array(off.global_array("b").unwrap()).data.clone();
        assert_eq!(b_base, b_off);
    }

    #[test]
    fn pointer_param_extent_resolved_via_call_site() {
        let src = "
#define N 16
float data[N];
void work(float *x, int n) {
    for (int i = 0; i < n; i++) { x[i] = x[i] * 2.0; }   // L0
}
int main() { work(data, N); return 0; }";
        let prog = parse(src).unwrap();
        let a = analyze(&prog, "main").unwrap();
        let r = split(&prog, a.loop_by_id(LoopId(0)).unwrap()).unwrap();
        let x = r.kernel.params.iter().find(|p| p.name == "x").unwrap();
        assert_eq!(x.dims, Some(vec![16]));
        assert_eq!(x.direction, Direction::InOut);
        // `n` comes along as a scalar.
        assert!(r.kernel.params.iter().any(|p| p.name == "n"));
    }

    #[test]
    fn split_rejects_blocked_loop() {
        let src = r#"
void helper() { }
int main() {
    for (int i = 0; i < 4; i++) { helper(); }
    return 0;
}"#;
        let prog = parse(src).unwrap();
        let a = analyze(&prog, "main").unwrap();
        let al = a.loop_by_id(LoopId(0)).unwrap();
        assert_eq!(
            split(&prog, al).unwrap_err(),
            SplitError::NotOffloadable(LoopId(0))
        );
    }
}
