//! Loop expansion — the paper's speed-up technique B (§4: "the loop
//! sentence is expanded by number B … The loop statement expansion process
//! increases the amount of resources, but is effective for speeding up").
//!
//! For a canonical counted loop `for (i = a; i < b; i += s)` with unroll
//! factor `u`, the body is replicated `u` times with the induction
//! variable substituted `i, i+s, …, i+(u-1)s` and the step becomes
//! `i += u*s`. Replicas after the first are guarded (`if (i + k*s < b)`)
//! unless the static trip count is known to divide evenly.

use crate::minic::ast::*;

use super::kernel_ir::KernelIr;

/// Error: the loop shape does not admit unrolling.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct UnrollError(pub String);

impl std::fmt::Display for UnrollError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cannot unroll: {}", self.0)
    }
}

impl std::error::Error for UnrollError {}

/// Apply unroll factor `u` to the kernel's outermost loop.
///
/// `u == 1` is the identity. Returns a new kernel with `unroll = u` and a
/// rewritten body.
pub fn unroll(kernel: &KernelIr, u: u32) -> Result<KernelIr, UnrollError> {
    if u == 0 {
        return Err(UnrollError("factor must be >= 1".into()));
    }
    if u == 1 {
        let mut k = kernel.clone();
        k.unroll = 1;
        return Ok(k);
    }
    let Stmt::For {
        id,
        init,
        cond,
        step,
        body,
        line,
    } = &kernel.body
    else {
        return Err(UnrollError("only for-loops can be expanded".into()));
    };

    let var = induction_of(init.as_deref(), step.as_deref())
        .ok_or_else(|| UnrollError("non-canonical loop header".into()))?;
    let stride = stride_of(step.as_deref())
        .ok_or_else(|| UnrollError("non-constant stride".into()))?;
    let bound = bound_of(cond.as_ref())
        .ok_or_else(|| UnrollError("unsupported loop condition".into()))?;

    let even = kernel
        .static_trips
        .map(|t| t % u as u64 == 0)
        .unwrap_or(false);

    let mut new_body: Vec<Stmt> = Vec::new();
    for k in 0..u {
        let offset = (k as i64) * stride;
        let replica: Vec<Stmt> = body
            .iter()
            .map(|s| substitute_stmt(s, &var, offset))
            .collect();
        if k == 0 || even {
            new_body.extend(replica);
        } else {
            // Guard: if (var + offset < bound) { replica }
            let guard = Expr::Bin {
                op: bound.op,
                lhs: Box::new(Expr::Bin {
                    op: BinOp::Add,
                    lhs: Box::new(Expr::Var(var.clone())),
                    rhs: Box::new(Expr::IntLit(offset)),
                }),
                rhs: Box::new(bound.expr.clone()),
            };
            new_body.push(Stmt::If {
                cond: guard,
                then_branch: replica,
                else_branch: Vec::new(),
                line: *line,
            });
        }
    }

    let new_step = Stmt::Assign {
        target: LValue::Var(var.clone()),
        op: AssignOp::AddSet,
        value: Expr::IntLit(stride * u as i64),
        line: *line,
    };

    let mut out = kernel.clone();
    out.unroll = u;
    out.body = Stmt::For {
        id: *id,
        init: init.clone(),
        cond: cond.clone(),
        step: Some(Box::new(new_step)),
        body: new_body,
        line: *line,
    };
    Ok(out)
}

struct Bound {
    op: BinOp,
    expr: Expr,
}

fn induction_of(init: Option<&Stmt>, step: Option<&Stmt>) -> Option<String> {
    let iv = match init? {
        Stmt::Decl { name, .. } => name.clone(),
        Stmt::Assign {
            target: LValue::Var(n),
            ..
        } => n.clone(),
        _ => return None,
    };
    let sv = match step? {
        Stmt::Assign {
            target: LValue::Var(n),
            ..
        } => n.clone(),
        _ => return None,
    };
    (iv == sv).then_some(iv)
}

fn stride_of(step: Option<&Stmt>) -> Option<i64> {
    match step? {
        Stmt::Assign {
            op: AssignOp::AddSet,
            value: Expr::IntLit(c),
            ..
        } => Some(*c),
        Stmt::Assign {
            op: AssignOp::Set,
            value:
                Expr::Bin {
                    op: BinOp::Add,
                    lhs: _,
                    rhs,
                },
            ..
        } => match rhs.as_ref() {
            Expr::IntLit(c) => Some(*c),
            _ => None,
        },
        _ => None,
    }
}

fn bound_of(cond: Option<&Expr>) -> Option<Bound> {
    match cond? {
        Expr::Bin { op, rhs, .. } if matches!(op, BinOp::Lt | BinOp::Le) => {
            Some(Bound {
                op: *op,
                expr: rhs.as_ref().clone(),
            })
        }
        _ => None,
    }
}

/// Substitute `var := var + offset` in a statement subtree.
fn substitute_stmt(s: &Stmt, var: &str, offset: i64) -> Stmt {
    if offset == 0 {
        return s.clone();
    }
    match s {
        Stmt::Decl { name, ty, init, line } => Stmt::Decl {
            name: name.clone(),
            ty: ty.clone(),
            init: init.as_ref().map(|e| substitute_expr(e, var, offset)),
            line: *line,
        },
        Stmt::Assign {
            target,
            op,
            value,
            line,
        } => Stmt::Assign {
            target: match target {
                LValue::Var(n) => LValue::Var(n.clone()),
                LValue::Index { base, indices } => LValue::Index {
                    base: base.clone(),
                    indices: indices
                        .iter()
                        .map(|e| substitute_expr(e, var, offset))
                        .collect(),
                },
            },
            op: *op,
            value: substitute_expr(value, var, offset),
            line: *line,
        },
        Stmt::If {
            cond,
            then_branch,
            else_branch,
            line,
        } => Stmt::If {
            cond: substitute_expr(cond, var, offset),
            then_branch: then_branch
                .iter()
                .map(|s| substitute_stmt(s, var, offset))
                .collect(),
            else_branch: else_branch
                .iter()
                .map(|s| substitute_stmt(s, var, offset))
                .collect(),
            line: *line,
        },
        Stmt::For {
            id,
            init,
            cond,
            step,
            body,
            line,
        } => Stmt::For {
            id: *id,
            init: init
                .as_ref()
                .map(|s| Box::new(substitute_stmt(s, var, offset))),
            cond: cond.as_ref().map(|e| substitute_expr(e, var, offset)),
            step: step
                .as_ref()
                .map(|s| Box::new(substitute_stmt(s, var, offset))),
            body: body
                .iter()
                .map(|s| substitute_stmt(s, var, offset))
                .collect(),
            line: *line,
        },
        Stmt::While { id, cond, body, line } => Stmt::While {
            id: *id,
            cond: substitute_expr(cond, var, offset),
            body: body
                .iter()
                .map(|s| substitute_stmt(s, var, offset))
                .collect(),
            line: *line,
        },
        Stmt::Return { value, line } => Stmt::Return {
            value: value.as_ref().map(|e| substitute_expr(e, var, offset)),
            line: *line,
        },
        Stmt::ExprStmt { expr, line } => Stmt::ExprStmt {
            expr: substitute_expr(expr, var, offset),
            line: *line,
        },
    }
}

fn substitute_expr(e: &Expr, var: &str, offset: i64) -> Expr {
    match e {
        Expr::Var(n) if n == var => Expr::Bin {
            op: BinOp::Add,
            lhs: Box::new(Expr::Var(n.clone())),
            rhs: Box::new(Expr::IntLit(offset)),
        },
        Expr::Var(_)
        | Expr::IntLit(_)
        | Expr::FloatLit(_)
        | Expr::StrLit(_) => e.clone(),
        Expr::Index { base, indices } => Expr::Index {
            base: base.clone(),
            indices: indices
                .iter()
                .map(|i| substitute_expr(i, var, offset))
                .collect(),
        },
        Expr::Bin { op, lhs, rhs } => Expr::Bin {
            op: *op,
            lhs: Box::new(substitute_expr(lhs, var, offset)),
            rhs: Box::new(substitute_expr(rhs, var, offset)),
        },
        Expr::Un { op, operand } => Expr::Un {
            op: *op,
            operand: Box::new(substitute_expr(operand, var, offset)),
        },
        Expr::Call { name, args } => Expr::Call {
            name: name.clone(),
            args: args
                .iter()
                .map(|a| substitute_expr(a, var, offset))
                .collect(),
        },
        Expr::Cast { to, operand } => Expr::Cast {
            to: *to,
            operand: Box::new(substitute_expr(operand, var, offset)),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::codegen::split::split;
    use crate::minic::ast::LoopId;
    use crate::minic::{parse, Interp};

    const SRC: &str = "
#define N 32
float a[N]; float b[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.5; }
    for (int i = 0; i < N; i++) { b[i] = a[i] * 2.0 + 1.0; }
    return 0;
}";

    fn kernel_l1(u: u32) -> KernelIr {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let r = split(&prog, an.loop_by_id(LoopId(1)).unwrap()).unwrap();
        unroll(&r.kernel, u).unwrap()
    }

    #[test]
    fn unroll_1_is_identity() {
        let k = kernel_l1(1);
        assert_eq!(k.unroll, 1);
    }

    #[test]
    fn unroll_even_has_no_guards() {
        let k = kernel_l1(4); // 32 % 4 == 0
        let Stmt::For { body, .. } = &k.body else { panic!() };
        assert_eq!(body.len(), 4);
        assert!(body.iter().all(|s| matches!(s, Stmt::Assign { .. })));
    }

    #[test]
    fn unroll_uneven_guards_replicas() {
        let k = kernel_l1(5); // 32 % 5 != 0
        let Stmt::For { body, .. } = &k.body else { panic!() };
        assert_eq!(body.len(), 5);
        assert!(matches!(body[0], Stmt::Assign { .. }));
        assert!(body[1..].iter().all(|s| matches!(s, Stmt::If { .. })));
    }

    /// The decisive test: unrolled kernels must compute the same values.
    #[test]
    fn unrolled_kernel_preserves_semantics() {
        for u in [1u32, 2, 4, 5, 8] {
            let prog = parse(SRC).unwrap();
            let an = analyze(&prog, "main").unwrap();
            let mut r =
                split(&prog, an.loop_by_id(LoopId(1)).unwrap()).unwrap();
            let unrolled = unroll(&r.kernel, u).unwrap();
            // Patch the outlined function body with the unrolled loop.
            r.kernel_fn.body = vec![unrolled.body.clone()];
            r.kernel = unrolled;
            let host =
                crate::codegen::split::offload_program(&prog, &[r]);

            let mut base = Interp::new(&prog).unwrap();
            base.call("main", &[]).unwrap();
            let mut off = Interp::new(&host).unwrap();
            off.call("main", &[]).unwrap();

            let b0 = base.array(base.global_array("b").unwrap()).data.clone();
            let b1 = off.array(off.global_array("b").unwrap()).data.clone();
            assert_eq!(b0, b1, "unroll factor {u} changed results");
        }
    }

    #[test]
    fn unroll_step_multiplied() {
        let k = kernel_l1(4);
        let Stmt::For { step: Some(step), .. } = &k.body else { panic!() };
        match step.as_ref() {
            Stmt::Assign {
                op: AssignOp::AddSet,
                value: Expr::IntLit(4),
                ..
            } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unroll_0_rejected() {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let r = split(&prog, an.loop_by_id(LoopId(1)).unwrap()).unwrap();
        assert!(unroll(&r.kernel, 0).is_err());
    }
}
