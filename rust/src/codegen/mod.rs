//! Codegen: turn candidate loops into OpenCL-style kernel/host pairs
//! (paper §3.3, Step 3 of the flow).
//!
//! * [`kernel_ir`] — the kernel-side IR (signature + body + unroll).
//! * [`split`] — host/kernel division from the analysis' reference sets,
//!   plus AST outlining for functional verification.
//! * [`unroll`] — loop expansion by factor B (the paper's speed-up
//!   technique).
//! * [`opencl`] — OpenCL-C text emission (kernel + the ten host steps).
//!
//! ```
//! use fpga_offload::analysis::analyze;
//! use fpga_offload::codegen::split;
//! use fpga_offload::minic::ast::LoopId;
//! use fpga_offload::minic::parse;
//!
//! let prog = parse(
//!     "#define N 32\n\
//!      float a[N]; float out[N];\n\
//!      int main() {\n\
//!          for (int i = 0; i < N; i++) { a[i] = i * 0.1; }\n\
//!          for (int i = 0; i < N; i++) { out[i] = a[i] * 2.0; }\n\
//!          return 0;\n\
//!      }",
//! )
//! .unwrap();
//! let an = analyze(&prog, "main").unwrap();
//! let sp = split(&prog, an.loop_by_id(LoopId(1)).unwrap()).unwrap();
//! // The kernel reads `a`, writes `out` — both cross the device boundary.
//! assert_eq!(sp.kernel.loop_id, LoopId(1));
//! assert!(sp.kernel.bytes_in() > 0);
//! assert!(sp.kernel.bytes_out() > 0);
//! ```

pub mod kernel_ir;
pub mod opencl;
pub mod split;
pub mod unroll;

pub use kernel_ir::{Direction, KernelIr, KernelParam};
pub use split::{offload_program, split, SplitError, SplitResult};
pub use unroll::{unroll, UnrollError};
