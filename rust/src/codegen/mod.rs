//! Codegen: turn candidate loops into OpenCL-style kernel/host pairs
//! (paper §3.3, Step 3 of the flow).
//!
//! * [`kernel_ir`] — the kernel-side IR (signature + body + unroll).
//! * [`split`] — host/kernel division from the analysis' reference sets,
//!   plus AST outlining for functional verification.
//! * [`unroll`] — loop expansion by factor B (the paper's speed-up
//!   technique).
//! * [`opencl`] — OpenCL-C text emission (kernel + the ten host steps).

pub mod kernel_ir;
pub mod opencl;
pub mod split;
pub mod unroll;

pub use kernel_ir::{Direction, KernelIr, KernelParam};
pub use split::{offload_program, split, SplitError, SplitResult};
pub use unroll::{unroll, UnrollError};
