//! The verification environment: measure offload patterns and select the
//! solution (paper Fig. 1 steps 4–5 and §4's two measurement rounds).
//!
//! Measurement and functional verification are destination-specific, so
//! both route through a [`Backend`] (FPGA simulation by default, the CPU
//! baseline as the control; see [`super::backend`]). Measurements run on
//! a worker pool sized like the environment's build-machine pool
//! (`cfg.build_machines`) — std threads + channels (no tokio in the
//! offline crate set; the work is CPU-bound simulation anyway).
//! Wall-clock accounting (the ~3 h compiles) is *modeled* via
//! [`crate::fpga::compile_model`] so the half-day automation figure is
//! reproducible in milliseconds.
//!
//! The stages are exposed separately — [`measure_patterns`] (step 4) and
//! [`select`] (step 5) — so the staged [`crate::envadapt::Pipeline`] can
//! own the intermediate artifacts; [`search`] and [`search_with_backend`]
//! run funnel → measurement → selection end to end.

use std::sync::mpsc;

use crate::analysis::Analysis;
use crate::cpu::CpuModel;
use crate::fpga::{self, CompileJob};
use crate::hls::Device;
use crate::minic::Program;

use super::backend::{Backend, FpgaBackend};
use super::config::SearchConfig;
use super::funnel::{self, Candidate, FunnelError};
use super::patterns::{self, Pattern};
use super::resilience::{FaultClass, OffloadError, Stage};
use super::result::{FunnelTrace, OffloadSolution, PatternMeasurement};

/// Search failure.
#[derive(Debug)]
pub enum SearchError {
    Funnel(FunnelError),
    Sim(fpga::SimError),
    Interp(crate::minic::MiniCError),
    NoMeasurements,
    /// A typed resilience-layer fault (injected, retried-and-exhausted,
    /// timed out, or panicked) — see [`super::resilience`].
    Fault(OffloadError),
}

impl SearchError {
    /// Map this error onto the resilience taxonomy: which stage it
    /// belongs to and whether a retry could help. The intrinsic search
    /// errors are all permanent — re-running the funnel or the
    /// simulator on the same inputs reproduces them.
    pub fn classify(&self) -> (Stage, FaultClass) {
        match self {
            SearchError::Funnel(_) => (Stage::Extract, FaultClass::Permanent),
            SearchError::Sim(_) => (Stage::Measure, FaultClass::Permanent),
            SearchError::Interp(_) => (Stage::Verify, FaultClass::Permanent),
            SearchError::NoMeasurements => {
                (Stage::Select, FaultClass::Permanent)
            }
            SearchError::Fault(e) => (e.stage, e.class),
        }
    }
}

impl std::fmt::Display for SearchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SearchError::Funnel(e) => write!(f, "funnel: {e}"),
            SearchError::Sim(e) => write!(f, "simulation: {e}"),
            SearchError::Interp(e) => write!(f, "verification: {e}"),
            SearchError::NoMeasurements => {
                write!(f, "no patterns could be measured")
            }
            SearchError::Fault(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for SearchError {}

impl From<FunnelError> for SearchError {
    fn from(e: FunnelError) -> Self {
        SearchError::Funnel(e)
    }
}

impl From<OffloadError> for SearchError {
    fn from(e: OffloadError) -> Self {
        SearchError::Fault(e)
    }
}

/// Step-4 output: the measured patterns plus the per-round compile jobs
/// that feed automation-time accounting in [`select`].
#[derive(Debug, Clone)]
pub struct MeasuredSet {
    /// All successfully measured patterns, in measurement order.
    pub measurements: Vec<PatternMeasurement>,
    /// Compile jobs per measurement round (for the makespan model).
    pub rounds: Vec<Vec<CompileJob>>,
}

/// Measure one pattern through the backend (performance + optional
/// functional verification).
fn measure_one(
    prog: &Program,
    analysis: &Analysis,
    cands: &[Candidate],
    pattern: &Pattern,
    round: u32,
    cfg: &SearchConfig,
    backend: &dyn Backend,
) -> Result<PatternMeasurement, SearchError> {
    let bm = backend.measure(prog, analysis, cands, pattern, cfg)?;

    let verified = if cfg.verify_numerics {
        // Verify under the entry the profiling run executed — requests
        // with a non-`main` entry must be checked against *their own*
        // entry function.
        Some(backend.verify(prog, cands, pattern, &analysis.entry, cfg)?)
    } else {
        None
    };

    let mut loops: Vec<_> =
        pattern.iter().map(|&i| cands[i].loop_id()).collect();
    loops.sort();
    Ok(PatternMeasurement {
        loops,
        round,
        timing: bm.timing,
        compile_s: bm.compile_s,
        verified,
    })
}

/// Measure a round of patterns on the worker pool. Results come back in
/// pattern order.
fn measure_round(
    prog: &Program,
    analysis: &Analysis,
    cands: &[Candidate],
    round_patterns: &[Pattern],
    round: u32,
    cfg: &SearchConfig,
    backend: &dyn Backend,
) -> Vec<Result<PatternMeasurement, SearchError>> {
    let workers = cfg.build_machines.min(round_patterns.len()).max(1);
    if workers <= 1 || round_patterns.len() <= 1 {
        return round_patterns
            .iter()
            .map(|p| {
                measure_one(prog, analysis, cands, p, round, cfg, backend)
            })
            .collect();
    }

    let (job_tx, job_rx) = mpsc::channel::<(usize, Pattern)>();
    let job_rx = std::sync::Mutex::new(job_rx);
    let (res_tx, res_rx) =
        mpsc::channel::<(usize, Result<PatternMeasurement, SearchError>)>();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let res_tx = res_tx.clone();
            let job_rx = &job_rx;
            scope.spawn(move || loop {
                let job = { job_rx.lock().unwrap().recv() };
                match job {
                    Ok((idx, pattern)) => {
                        let m = measure_one(
                            prog, analysis, cands, &pattern, round, cfg,
                            backend,
                        );
                        if res_tx.send((idx, m)).is_err() {
                            return;
                        }
                    }
                    Err(_) => return,
                }
            });
        }
        for (i, p) in round_patterns.iter().enumerate() {
            job_tx.send((i, p.clone())).unwrap();
        }
        drop(job_tx);
        drop(res_tx);

        let mut results: Vec<Option<Result<PatternMeasurement, SearchError>>> =
            (0..round_patterns.len()).map(|_| None).collect();
        for (idx, m) in res_rx {
            results[idx] = Some(m);
        }
        results
            .into_iter()
            .map(|r| r.expect("worker delivered"))
            .collect()
    })
}

/// Step 4: round-1 singles, then round-2 combinations within the
/// remaining measurement budget, all through the backend.
pub fn measure_patterns(
    prog: &Program,
    analysis: &Analysis,
    cands: &[Candidate],
    cfg: &SearchConfig,
    backend: &dyn Backend,
) -> Result<MeasuredSet, SearchError> {
    // Round 1: singles.
    let round1 = patterns::singles(cands, cfg);
    let r1 =
        measure_round(prog, analysis, cands, &round1, 1, cfg, backend);

    let mut measurements: Vec<PatternMeasurement> = Vec::new();
    let mut accelerated: Vec<(usize, f64)> = Vec::new();
    let mut rounds: Vec<Vec<CompileJob>> = vec![Vec::new()];
    for (pat, res) in round1.iter().zip(r1) {
        match res {
            Ok(m) => {
                rounds[0].push(CompileJob {
                    duration_s: m.compile_s,
                });
                if m.speedup() > 1.0 {
                    accelerated.push((pat[0], m.speedup()));
                }
                measurements.push(m);
            }
            Err(SearchError::Sim(_)) => {
                // A pattern that cannot be simulated (e.g. resources) is
                // simply not measured — mirrors the paper skipping
                // non-generable patterns.
            }
            Err(e) => return Err(e),
        }
    }

    // Round 2: combinations within the remaining budget.
    let budget = cfg.max_patterns.saturating_sub(measurements.len());
    let round2 = patterns::combinations(
        cands,
        &accelerated,
        analysis,
        cfg,
        backend.device(),
        budget,
    );
    if !round2.is_empty() {
        let r2 =
            measure_round(prog, analysis, cands, &round2, 2, cfg, backend);
        rounds.push(Vec::new());
        for res in r2 {
            match res {
                Ok(m) => {
                    rounds[1].push(CompileJob {
                        duration_s: m.compile_s,
                    });
                    measurements.push(m);
                }
                Err(SearchError::Sim(_)) => {}
                Err(e) => return Err(e),
            }
        }
    }

    Ok(MeasuredSet {
        measurements,
        rounds,
    })
}

/// Step 5: pick the best measured pattern and account automation time.
pub fn select(
    app: &str,
    trace: FunnelTrace,
    set: MeasuredSet,
    cfg: &SearchConfig,
) -> Result<OffloadSolution, SearchError> {
    if set.measurements.is_empty() {
        return Err(SearchError::NoMeasurements);
    }

    let best = set
        .measurements
        .iter()
        .enumerate()
        .max_by(|a, b| {
            a.1.speedup()
                .partial_cmp(&b.1.speedup())
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .map(|(i, _)| i)
        .expect("nonempty");

    let automation_s = fpga::automation_time(
        &set.rounds,
        cfg.build_machines,
        cfg.measure_seconds,
    );

    Ok(OffloadSolution {
        app: app.to_string(),
        funnel: trace,
        measurements: set.measurements,
        best,
        // Block replacements are a pipeline-level concern: the staged
        // pipeline folds its confirmed blocks in after selection.
        blocks: Vec::new(),
        automation_s,
    })
}

/// The full search against an explicit backend: funnel → round-1 singles
/// → round-2 combinations → best pattern (paper Fig. 2 end to end).
pub fn search_with_backend(
    app: &str,
    prog: &Program,
    analysis: &Analysis,
    cfg: &SearchConfig,
    backend: &dyn Backend,
) -> Result<OffloadSolution, SearchError> {
    let (cands, trace) = funnel::run(prog, analysis, cfg, backend.device())?;
    let set = measure_patterns(prog, analysis, &cands, cfg, backend)?;
    select(app, trace, set, cfg)
}

/// The full search on the paper's FPGA destination.
pub fn search(
    app: &str,
    prog: &Program,
    analysis: &Analysis,
    cfg: &SearchConfig,
    cpu: &CpuModel,
    dev: &Device,
) -> Result<OffloadSolution, SearchError> {
    let backend = FpgaBackend { cpu, device: dev };
    search_with_backend(app, prog, analysis, cfg, &backend)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::cpu::XEON_BRONZE_3104;
    use crate::hls::ARRIA10_GX;
    use crate::minic::parse;
    use crate::search::backend::CpuBaseline;

    const SRC: &str = "
#define N 4096
#define REP 32
float sig[N]; float out1[N]; float out2[N]; float tmp[N];
int main() {
    for (int i = 0; i < N; i++) { sig[i] = i * 0.0005 - 1.0; }       // L0 init
    for (int r = 0; r < REP; r++) {                                  // L1 hot nest
        for (int i = 0; i < N; i++) {                                // L2
            out1[i] = sin(sig[i]) * cos(sig[i]) + sqrt(sig[i] * sig[i] + 1.0);
        }
    }
    for (int i = 0; i < N; i++) { tmp[i] = out1[i] * 0.5; }          // L3 light
    for (int i = 0; i < N; i++) { out2[i] = sqrt(tmp[i] + 2.0); }    // L4 medium
    return 0;
}";

    fn run_search(cfg: &SearchConfig) -> OffloadSolution {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        search("test", &prog, &an, cfg, &XEON_BRONZE_3104, &ARRIA10_GX)
            .unwrap()
    }

    #[test]
    fn search_finds_a_speedup() {
        let sol = run_search(&SearchConfig::default());
        assert!(
            sol.speedup() > 1.5,
            "expected a clear win: {:.2}x",
            sol.speedup()
        );
        // The hot nest should be in the winning pattern.
        let best = sol.best_measurement();
        assert!(
            best.loops.iter().any(|l| l.0 == 1 || l.0 == 2),
            "{best:?}"
        );
    }

    #[test]
    fn measurement_budget_respected() {
        let cfg = SearchConfig::default();
        let sol = run_search(&cfg);
        assert!(sol.measurements.len() <= cfg.max_patterns);
        assert!(!sol.measurements.is_empty());
    }

    #[test]
    fn all_measured_patterns_verified() {
        let sol = run_search(&SearchConfig::default());
        for m in &sol.measurements {
            assert_eq!(m.verified, Some(true), "{}", m.label());
        }
    }

    #[test]
    fn rounds_are_labeled() {
        let sol = run_search(&SearchConfig::default());
        assert!(sol.measurements.iter().any(|m| m.round == 1));
        // Round 2 only exists if ≥2 singles accelerated — with this
        // workload at least the hot nest and the sqrt loop should.
        if sol.measurements.iter().filter(|m| m.round == 1).count() >= 2 {
            let r1_wins = sol
                .measurements
                .iter()
                .filter(|m| m.round == 1 && m.speedup() > 1.0)
                .count();
            if r1_wins >= 2 {
                assert!(
                    sol.measurements.iter().any(|m| m.round == 2),
                    "expected a combination round"
                );
            }
        }
    }

    #[test]
    fn automation_time_reflects_compiles() {
        let sol = run_search(&SearchConfig::default());
        let hours = sol.automation_s / 3600.0;
        // n patterns at ~3 h on one machine.
        let n = sol.measurements.len() as f64;
        assert!(
            hours > 2.0 * n && hours < 5.0 * n,
            "hours={hours:.1} n={n}"
        );
    }

    #[test]
    fn parallel_build_machines_agree_with_serial() {
        let serial = run_search(&SearchConfig::default());
        let parallel = run_search(&SearchConfig {
            build_machines: 4,
            ..Default::default()
        });
        // Same measurements (order-stable), different automation time.
        assert_eq!(serial.measurements.len(), parallel.measurements.len());
        for (a, b) in serial
            .measurements
            .iter()
            .zip(&parallel.measurements)
        {
            assert_eq!(a.loops, b.loops);
            assert!((a.speedup() - b.speedup()).abs() < 1e-12);
        }
        assert!(parallel.automation_s < serial.automation_s);
    }

    #[test]
    fn best_is_argmax() {
        let sol = run_search(&SearchConfig::default());
        let max = sol
            .measurements
            .iter()
            .map(|m| m.speedup())
            .fold(f64::MIN, f64::max);
        assert!((sol.speedup() - max).abs() < 1e-12);
    }

    #[test]
    fn cpu_baseline_backend_never_accelerates() {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let backend = CpuBaseline {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let sol = search_with_backend(
            "test",
            &prog,
            &an,
            &SearchConfig::default(),
            &backend,
        )
        .unwrap();
        assert_eq!(sol.speedup(), 1.0);
        // No compiles → automation time is measurement time only.
        let cfg = SearchConfig::default();
        let expected: f64 =
            sol.measurements.len() as f64 * cfg.measure_seconds;
        assert!((sol.automation_s - expected).abs() < 1e-9);
    }

    #[test]
    fn backend_search_matches_plain_search() {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let cfg = SearchConfig::default();
        let via_fn =
            search("t", &prog, &an, &cfg, &XEON_BRONZE_3104, &ARRIA10_GX)
                .unwrap();
        let backend = FpgaBackend {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let via_backend =
            search_with_backend("t", &prog, &an, &cfg, &backend).unwrap();
        assert_eq!(via_fn.best, via_backend.best);
        assert_eq!(
            via_fn.best_measurement().loops,
            via_backend.best_measurement().loops
        );
        assert!((via_fn.speedup() - via_backend.speedup()).abs() < 1e-12);
    }
}
