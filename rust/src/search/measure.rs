//! The verification environment: measure offload patterns and select the
//! solution (paper Fig. 1 steps 4–6 and §4's two measurement rounds).
//!
//! Measurements run on a worker pool sized like the environment's build-
//! machine pool (`cfg.build_machines`) — std threads + channels (no tokio
//! in the offline crate set; the work is CPU-bound simulation anyway).
//! Wall-clock accounting (the ~3 h compiles) is *modeled* via
//! [`crate::fpga::compile_model`] so the half-day automation figure is
//! reproducible in milliseconds.

use std::sync::mpsc;

use crate::analysis::Analysis;
use crate::cpu::CpuModel;
use crate::fpga::{self, verify_pattern_with, CompileJob};
use crate::hls::{full_compile_seconds, Device, ResourceEstimate};
use crate::minic::Program;

use super::config::SearchConfig;
use super::funnel::{self, Candidate, FunnelError};
use super::patterns::{self, Pattern};
use super::result::{OffloadSolution, PatternMeasurement};

/// Search failure.
#[derive(Debug)]
pub enum SearchError {
    Funnel(FunnelError),
    Sim(fpga::SimError),
    Interp(crate::minic::MiniCError),
    NoMeasurements,
}

impl std::fmt::Display for SearchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SearchError::Funnel(e) => write!(f, "funnel: {e}"),
            SearchError::Sim(e) => write!(f, "simulation: {e}"),
            SearchError::Interp(e) => write!(f, "verification: {e}"),
            SearchError::NoMeasurements => {
                write!(f, "no patterns could be measured")
            }
        }
    }
}

impl std::error::Error for SearchError {}

impl From<FunnelError> for SearchError {
    fn from(e: FunnelError) -> Self {
        SearchError::Funnel(e)
    }
}

/// Measure one pattern (simulate + optional functional verification).
fn measure_one(
    prog: &Program,
    analysis: &Analysis,
    cands: &[Candidate],
    pattern: &Pattern,
    round: u32,
    cfg: &SearchConfig,
    cpu: &CpuModel,
    dev: &Device,
) -> Result<PatternMeasurement, SearchError> {
    let kernels: Vec<_> = pattern
        .iter()
        .map(|&i| cands[i].split.kernel.clone())
        .collect();
    let timing = fpga::simulate(analysis, &kernels, cpu, dev)
        .map_err(SearchError::Sim)?;

    let combined = pattern
        .iter()
        .map(|&i| cands[i].report.estimate)
        .fold(ResourceEstimate::default(), |acc, e| acc.add(&e));
    let compile_s = full_compile_seconds(&combined, dev);

    let verified = if cfg.verify_numerics {
        let splits: Vec<_> = pattern
            .iter()
            .map(|&i| cands[i].split.clone())
            .collect();
        let v = verify_pattern_with(prog, &splits, "main", cfg.engine)
            .map_err(SearchError::Interp)?;
        Some(v.passed)
    } else {
        None
    };

    let mut loops: Vec<_> =
        pattern.iter().map(|&i| cands[i].loop_id()).collect();
    loops.sort();
    Ok(PatternMeasurement {
        loops,
        round,
        timing,
        compile_s,
        verified,
    })
}

/// Measure a round of patterns on the worker pool. Results come back in
/// pattern order.
fn measure_round(
    prog: &Program,
    analysis: &Analysis,
    cands: &[Candidate],
    round_patterns: &[Pattern],
    round: u32,
    cfg: &SearchConfig,
    cpu: &CpuModel,
    dev: &Device,
) -> Vec<Result<PatternMeasurement, SearchError>> {
    let workers = cfg.build_machines.min(round_patterns.len()).max(1);
    if workers <= 1 || round_patterns.len() <= 1 {
        return round_patterns
            .iter()
            .map(|p| {
                measure_one(prog, analysis, cands, p, round, cfg, cpu, dev)
            })
            .collect();
    }

    let (job_tx, job_rx) = mpsc::channel::<(usize, Pattern)>();
    let job_rx = std::sync::Mutex::new(job_rx);
    let (res_tx, res_rx) =
        mpsc::channel::<(usize, Result<PatternMeasurement, SearchError>)>();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let res_tx = res_tx.clone();
            let job_rx = &job_rx;
            scope.spawn(move || loop {
                let job = { job_rx.lock().unwrap().recv() };
                match job {
                    Ok((idx, pattern)) => {
                        let m = measure_one(
                            prog, analysis, cands, &pattern, round, cfg,
                            cpu, dev,
                        );
                        if res_tx.send((idx, m)).is_err() {
                            return;
                        }
                    }
                    Err(_) => return,
                }
            });
        }
        for (i, p) in round_patterns.iter().enumerate() {
            job_tx.send((i, p.clone())).unwrap();
        }
        drop(job_tx);
        drop(res_tx);

        let mut results: Vec<Option<Result<PatternMeasurement, SearchError>>> =
            (0..round_patterns.len()).map(|_| None).collect();
        for (idx, m) in res_rx {
            results[idx] = Some(m);
        }
        results
            .into_iter()
            .map(|r| r.expect("worker delivered"))
            .collect()
    })
}

/// The full search: funnel → round-1 singles → round-2 combinations →
/// best pattern (paper Fig. 2 end to end).
pub fn search(
    app: &str,
    prog: &Program,
    analysis: &Analysis,
    cfg: &SearchConfig,
    cpu: &CpuModel,
    dev: &Device,
) -> Result<OffloadSolution, SearchError> {
    let (cands, trace) = funnel::run(prog, analysis, cfg, dev)?;

    // Round 1: singles.
    let round1 = patterns::singles(&cands, cfg);
    let r1 = measure_round(prog, analysis, &cands, &round1, 1, cfg, cpu, dev);

    let mut measurements: Vec<PatternMeasurement> = Vec::new();
    let mut accelerated: Vec<(usize, f64)> = Vec::new();
    let mut rounds_jobs: Vec<Vec<CompileJob>> = vec![Vec::new()];
    for (pat, res) in round1.iter().zip(r1) {
        match res {
            Ok(m) => {
                rounds_jobs[0].push(CompileJob {
                    duration_s: m.compile_s,
                });
                if m.speedup() > 1.0 {
                    accelerated.push((pat[0], m.speedup()));
                }
                measurements.push(m);
            }
            Err(SearchError::Sim(_)) => {
                // A pattern that cannot be simulated (e.g. resources) is
                // simply not measured — mirrors the paper skipping
                // non-generable patterns.
            }
            Err(e) => return Err(e),
        }
    }

    // Round 2: combinations within the remaining budget.
    let budget = cfg.max_patterns.saturating_sub(measurements.len());
    let round2 = patterns::combinations(
        &cands,
        &accelerated,
        analysis,
        cfg,
        dev,
        budget,
    );
    if !round2.is_empty() {
        let r2 =
            measure_round(prog, analysis, &cands, &round2, 2, cfg, cpu, dev);
        rounds_jobs.push(Vec::new());
        for res in r2 {
            match res {
                Ok(m) => {
                    rounds_jobs[1].push(CompileJob {
                        duration_s: m.compile_s,
                    });
                    measurements.push(m);
                }
                Err(SearchError::Sim(_)) => {}
                Err(e) => return Err(e),
            }
        }
    }

    if measurements.is_empty() {
        return Err(SearchError::NoMeasurements);
    }

    let best = measurements
        .iter()
        .enumerate()
        .max_by(|a, b| {
            a.1.speedup()
                .partial_cmp(&b.1.speedup())
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .map(|(i, _)| i)
        .expect("nonempty");

    let automation_s = fpga::automation_time(
        &rounds_jobs,
        cfg.build_machines,
        cfg.measure_seconds,
    );

    Ok(OffloadSolution {
        app: app.to_string(),
        funnel: trace,
        measurements,
        best,
        automation_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::cpu::XEON_BRONZE_3104;
    use crate::hls::ARRIA10_GX;
    use crate::minic::parse;

    const SRC: &str = "
#define N 4096
#define REP 32
float sig[N]; float out1[N]; float out2[N]; float tmp[N];
int main() {
    for (int i = 0; i < N; i++) { sig[i] = i * 0.0005 - 1.0; }       // L0 init
    for (int r = 0; r < REP; r++) {                                  // L1 hot nest
        for (int i = 0; i < N; i++) {                                // L2
            out1[i] = sin(sig[i]) * cos(sig[i]) + sqrt(sig[i] * sig[i] + 1.0);
        }
    }
    for (int i = 0; i < N; i++) { tmp[i] = out1[i] * 0.5; }          // L3 light
    for (int i = 0; i < N; i++) { out2[i] = sqrt(tmp[i] + 2.0); }    // L4 medium
    return 0;
}";

    fn run_search(cfg: &SearchConfig) -> OffloadSolution {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        search("test", &prog, &an, cfg, &XEON_BRONZE_3104, &ARRIA10_GX)
            .unwrap()
    }

    #[test]
    fn search_finds_a_speedup() {
        let sol = run_search(&SearchConfig::default());
        assert!(
            sol.speedup() > 1.5,
            "expected a clear win: {:.2}x",
            sol.speedup()
        );
        // The hot nest should be in the winning pattern.
        let best = sol.best_measurement();
        assert!(
            best.loops.iter().any(|l| l.0 == 1 || l.0 == 2),
            "{best:?}"
        );
    }

    #[test]
    fn measurement_budget_respected() {
        let cfg = SearchConfig::default();
        let sol = run_search(&cfg);
        assert!(sol.measurements.len() <= cfg.max_patterns);
        assert!(!sol.measurements.is_empty());
    }

    #[test]
    fn all_measured_patterns_verified() {
        let sol = run_search(&SearchConfig::default());
        for m in &sol.measurements {
            assert_eq!(m.verified, Some(true), "{}", m.label());
        }
    }

    #[test]
    fn rounds_are_labeled() {
        let sol = run_search(&SearchConfig::default());
        assert!(sol.measurements.iter().any(|m| m.round == 1));
        // Round 2 only exists if ≥2 singles accelerated — with this
        // workload at least the hot nest and the sqrt loop should.
        if sol.measurements.iter().filter(|m| m.round == 1).count() >= 2 {
            let r1_wins = sol
                .measurements
                .iter()
                .filter(|m| m.round == 1 && m.speedup() > 1.0)
                .count();
            if r1_wins >= 2 {
                assert!(
                    sol.measurements.iter().any(|m| m.round == 2),
                    "expected a combination round"
                );
            }
        }
    }

    #[test]
    fn automation_time_reflects_compiles() {
        let sol = run_search(&SearchConfig::default());
        let hours = sol.automation_s / 3600.0;
        // n patterns at ~3 h on one machine.
        let n = sol.measurements.len() as f64;
        assert!(
            hours > 2.0 * n && hours < 5.0 * n,
            "hours={hours:.1} n={n}"
        );
    }

    #[test]
    fn parallel_build_machines_agree_with_serial() {
        let serial = run_search(&SearchConfig::default());
        let parallel = run_search(&SearchConfig {
            build_machines: 4,
            ..Default::default()
        });
        // Same measurements (order-stable), different automation time.
        assert_eq!(serial.measurements.len(), parallel.measurements.len());
        for (a, b) in serial
            .measurements
            .iter()
            .zip(&parallel.measurements)
        {
            assert_eq!(a.loops, b.loops);
            assert!((a.speedup() - b.speedup()).abs() < 1e-12);
        }
        assert!(parallel.automation_s < serial.automation_s);
    }

    #[test]
    fn best_is_argmax() {
        let sol = run_search(&SearchConfig::default());
        let max = sol
            .measurements
            .iter()
            .map(|m| m.speedup())
            .fold(f64::MIN, f64::max);
        assert!((sol.speedup() - max).abs() < 1e-12);
    }
}
