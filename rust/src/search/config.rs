//! Search configuration: the paper's experiment knobs (§5.1.2).

use crate::minic::EngineKind;

/// Tunable parameters of the offload search.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchConfig {
    /// Narrow to the top **A** loops by arithmetic intensity (paper: 5).
    pub top_a: usize,
    /// Loop expansion factor **B** applied to every kernel (paper: 1 —
    /// "I confirm the effect of FPGA offloading with OpenCL without
    /// expansions").
    pub unroll: u32,
    /// Narrow to the top **C** loops by resource efficiency (paper: 3).
    pub top_c: usize,
    /// Singles measured in the first round (paper: 3 — the top-C loops).
    pub first_round: usize,
    /// Total measured offload patterns **D** across rounds (paper: 4).
    pub max_patterns: usize,
    /// Combined-utilization cap for combination patterns (paper: "if it
    /// does not fit within the upper limit, the combination pattern is
    /// not generated").
    pub resource_cap: f64,
    /// Build machines in the verification environment (paper Fig. 3: one
    /// verification machine).
    pub build_machines: usize,
    /// Modeled sample-test measurement time per pattern, seconds.
    pub measure_seconds: f64,
    /// Functionally verify each measured pattern (numeric equivalence
    /// of the offloaded program).
    pub verify_numerics: bool,
    /// Execution engine for verification runs (default: bytecode VM;
    /// the tree-walking oracle stays selectable via `--engine interp`).
    pub engine: EngineKind,
}

impl Default for SearchConfig {
    /// The paper's §5.1.2 conditions.
    fn default() -> Self {
        SearchConfig {
            top_a: 5,
            unroll: 1,
            top_c: 3,
            first_round: 3,
            max_patterns: 4,
            resource_cap: 1.0,
            build_machines: 1,
            measure_seconds: 120.0,
            verify_numerics: true,
            engine: EngineKind::default(),
        }
    }
}

impl SearchConfig {
    /// Stable FNV-1a fingerprint over every search-relevant knob. Stored
    /// with each pattern-DB record: a plan searched under one
    /// configuration (budget, narrowing widths, engine, ...) must not be
    /// silently reused after the configuration changes.
    pub fn fingerprint(&self) -> u64 {
        use std::hash::Hasher;
        let canonical = format!(
            "a={};b={};c={};r={};d={};cap={:016x};m={};t={:016x};v={};e={:?}",
            self.top_a,
            self.unroll,
            self.top_c,
            self.first_round,
            self.max_patterns,
            self.resource_cap.to_bits(),
            self.build_machines,
            self.measure_seconds.to_bits(),
            self.verify_numerics,
            self.engine,
        );
        let mut h = crate::util::fnv::FnvHasher::default();
        h.write(canonical.as_bytes());
        h.finish()
    }

    /// Validate the invariants the funnel relies on.
    pub fn validate(&self) -> Result<(), String> {
        if self.top_a == 0 {
            return Err("top_a must be >= 1".into());
        }
        if self.top_c == 0 {
            return Err("top_c must be >= 1".into());
        }
        if self.unroll == 0 {
            return Err("unroll must be >= 1".into());
        }
        if self.first_round == 0 || self.first_round > self.max_patterns {
            return Err(
                "first_round must be in 1..=max_patterns".into()
            );
        }
        if self.top_c < self.first_round {
            return Err("first_round cannot exceed top_c".into());
        }
        if !(0.0..=1.0).contains(&self.resource_cap) {
            return Err("resource_cap must be in [0, 1]".into());
        }
        if self.build_machines == 0 {
            return Err("need at least one build machine".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper() {
        let c = SearchConfig::default();
        assert_eq!(c.top_a, 5);
        assert_eq!(c.unroll, 1);
        assert_eq!(c.top_c, 3);
        assert_eq!(c.first_round, 3);
        assert_eq!(c.max_patterns, 4);
        c.validate().unwrap();
    }

    #[test]
    fn fingerprint_is_stable_and_knob_sensitive() {
        let base = SearchConfig::default();
        assert_eq!(base.fingerprint(), SearchConfig::default().fingerprint());
        for changed in [
            SearchConfig { top_a: 4, ..base.clone() },
            SearchConfig { unroll: 2, ..base.clone() },
            SearchConfig { top_c: 2, ..base.clone() },
            SearchConfig { first_round: 2, ..base.clone() },
            SearchConfig { max_patterns: 5, ..base.clone() },
            SearchConfig { resource_cap: 0.9, ..base.clone() },
            SearchConfig { build_machines: 2, ..base.clone() },
            SearchConfig { measure_seconds: 60.0, ..base.clone() },
            SearchConfig { verify_numerics: false, ..base.clone() },
            SearchConfig {
                engine: EngineKind::TreeWalk,
                ..base.clone()
            },
        ] {
            assert_ne!(
                changed.fingerprint(),
                base.fingerprint(),
                "{changed:?}"
            );
        }
    }

    #[test]
    fn invalid_configs_rejected() {
        let base = SearchConfig::default();
        for bad in [
            SearchConfig { top_a: 0, ..base.clone() },
            SearchConfig { top_c: 0, ..base.clone() },
            SearchConfig { unroll: 0, ..base.clone() },
            SearchConfig { first_round: 0, ..base.clone() },
            SearchConfig {
                first_round: 9,
                max_patterns: 4,
                ..base.clone()
            },
            SearchConfig { resource_cap: 1.5, ..base.clone() },
            SearchConfig { build_machines: 0, ..base.clone() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?}");
        }
    }
}
