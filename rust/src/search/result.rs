//! Result types for the offload search.

use crate::fpga::PatternTiming;
use crate::funcblock::BlockReplacement;
use crate::hls::PrecompileReport;
use crate::minic::ast::LoopId;
use crate::util::json::Json;

/// One measured offload pattern.
#[derive(Debug, Clone)]
pub struct PatternMeasurement {
    /// Offloaded loop ids (sorted).
    pub loops: Vec<LoopId>,
    /// Round in which it was measured (1 = singles, 2 = combinations).
    pub round: u32,
    pub timing: PatternTiming,
    /// Modeled full-compile wall clock for this pattern, seconds.
    pub compile_s: f64,
    /// Functional verification outcome (None = not requested).
    pub verified: Option<bool>,
}

impl PatternMeasurement {
    pub fn speedup(&self) -> f64 {
        self.timing.speedup
    }

    pub fn label(&self) -> String {
        if self.loops.is_empty() {
            "all-CPU".to_string()
        } else {
            self.loops
                .iter()
                .map(|l| l.to_string())
                .collect::<Vec<_>>()
                .join("+")
        }
    }
}

/// Trace of the narrowing funnel (Fig. 2 of the paper).
#[derive(Debug, Clone)]
pub struct FunnelTrace {
    /// Total loop statements found (paper: 36 for tdfir, 16 for MRI-Q).
    pub total_loops: usize,
    /// Offloadable after structural filtering.
    pub offloadable: Vec<LoopId>,
    /// After arithmetic-intensity narrowing (top A).
    pub top_a: Vec<LoopId>,
    /// Pre-compile reports for the top-A loops.
    pub reports: Vec<PrecompileReport>,
    /// After resource-efficiency narrowing (top C).
    pub top_c: Vec<LoopId>,
}

/// The search's final product.
#[derive(Debug, Clone)]
pub struct OffloadSolution {
    pub app: String,
    pub funnel: FunnelTrace,
    /// All measured patterns in measurement order.
    pub measurements: Vec<PatternMeasurement>,
    /// Index into `measurements` of the selected pattern.
    pub best: usize,
    /// Confirmed-and-profitable function-block replacements (empty when
    /// the request ran loop-only). Their loops were pre-claimed away
    /// from the funnel, so the measured patterns never overlap them.
    pub blocks: Vec<BlockReplacement>,
    /// Modeled end-to-end automation wall clock, seconds (compiles +
    /// measurements per round, plus block core builds).
    pub automation_s: f64,
}

impl OffloadSolution {
    pub fn best_measurement(&self) -> &PatternMeasurement {
        &self.measurements[self.best]
    }

    /// Speedup of the chosen loop pattern alone (block replacements
    /// excluded) — the PR-3 headline number.
    pub fn loop_speedup(&self) -> f64 {
        self.best_measurement().speedup()
    }

    /// Headline number: combined speedup vs all-CPU. The measured
    /// pattern time still carries the claimed block nests at CPU speed
    /// (the funnel never offloaded them), so the combination swaps that
    /// CPU time for the cores' accelerated time.
    pub fn speedup(&self) -> f64 {
        if self.blocks.is_empty() {
            return self.loop_speedup();
        }
        let t = &self.best_measurement().timing;
        let block_cpu: f64 = self.blocks.iter().map(|b| b.cpu_s).sum();
        let block_accel: f64 =
            self.blocks.iter().map(|b| b.accel_s).sum();
        let combined_s =
            (t.pattern_s - block_cpu + block_accel).max(f64::MIN_POSITIVE);
        t.cpu_baseline_s / combined_s
    }

    /// Serialize for the code-pattern DB.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("app", Json::Str(self.app.clone())),
            (
                "best_pattern",
                Json::Arr(
                    self.best_measurement()
                        .loops
                        .iter()
                        .map(|l| Json::Num(l.0 as f64))
                        .collect(),
                ),
            ),
            ("speedup", Json::Num(self.speedup())),
            ("loop_speedup", Json::Num(self.loop_speedup())),
            (
                "blocks",
                Json::Arr(
                    self.blocks
                        .iter()
                        .map(|b| {
                            Json::obj(vec![
                                (
                                    "kind",
                                    Json::Str(b.kind.name().to_string()),
                                ),
                                (
                                    "function",
                                    Json::Str(b.func.clone()),
                                ),
                                (
                                    "ip",
                                    Json::Str(b.ip_name.to_string()),
                                ),
                                (
                                    "loops",
                                    Json::Arr(
                                        b.loops
                                            .iter()
                                            .map(|l| Json::Num(l.0 as f64))
                                            .collect(),
                                    ),
                                ),
                                ("cpu_s", Json::Num(b.cpu_s)),
                                ("accel_s", Json::Num(b.accel_s)),
                                (
                                    "block_speedup",
                                    Json::Num(b.speedup()),
                                ),
                                (
                                    "confirmed",
                                    Json::Bool(b.confirmed),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("automation_hours", Json::Num(self.automation_s / 3600.0)),
            (
                "measurements",
                Json::Arr(
                    self.measurements
                        .iter()
                        .map(|m| {
                            Json::obj(vec![
                                ("pattern", Json::Str(m.label())),
                                ("round", Json::Num(m.round as f64)),
                                ("speedup", Json::Num(m.speedup())),
                                (
                                    "verified",
                                    match m.verified {
                                        Some(v) => Json::Bool(v),
                                        None => Json::Null,
                                    },
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "funnel",
                Json::obj(vec![
                    (
                        "total_loops",
                        Json::Num(self.funnel.total_loops as f64),
                    ),
                    (
                        "offloadable",
                        Json::Num(self.funnel.offloadable.len() as f64),
                    ),
                    ("top_a", Json::Num(self.funnel.top_a.len() as f64)),
                    ("top_c", Json::Num(self.funnel.top_c.len() as f64)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_formats() {
        let m = PatternMeasurement {
            loops: vec![LoopId(1), LoopId(3)],
            round: 2,
            timing: crate::fpga::PatternTiming {
                cpu_baseline_s: 1.0,
                cpu_rest_s: 0.2,
                loops: vec![],
                pattern_s: 0.5,
                speedup: 2.0,
                combined: Default::default(),
            },
            compile_s: 3.0 * 3600.0,
            verified: Some(true),
        };
        assert_eq!(m.label(), "L1+L3");
        assert_eq!(m.speedup(), 2.0);
    }
}
