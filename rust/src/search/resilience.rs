//! Resilience layer for the automation cycle: typed faults, retry
//! budgets, and deterministic fault injection around the backend seam.
//!
//! The paper's cycle leans on long, flaky real-world steps — ~3-hour HLS
//! builds, sample-test verification, deployment checks — yet a naive
//! implementation treats every stage as infallible-or-fatal. This module
//! supplies the three pieces the staged pipeline and the batch
//! orchestrator need to survive a flaky verification environment:
//!
//! * [`OffloadError`] — a stage-tagged, classed fault
//!   ([`FaultClass::Transient`] / [`Permanent`](FaultClass::Permanent) /
//!   [`Timeout`](FaultClass::Timeout) / [`Panic`](FaultClass::Panic)) so
//!   callers can tell "retry this" from "give up now".
//! * [`RetryPolicy`] + [`RetryingBackend`] — bounded attempts with
//!   deterministic exponential backoff (seeded jitter) and per-stage
//!   deadline budgets, driven by a virtual [`SimClock`] so a "3-hour
//!   hung build" costs microseconds in tests. Transient and timeout
//!   faults are retried; permanent faults and panics fail fast.
//! * [`FaultPlan`] + [`FaultyBackend`] — a deterministic, seeded fault
//!   injector that wraps any inner [`Backend`] with transient error
//!   bursts, hung builds, verify mismatches, one-shot panics, and
//!   permanently dead sites. Fault decisions are keyed on the *call
//!   site* (backend + stage + pattern/sample), not on call order, so
//!   injection is reproducible regardless of worker-pool scheduling.
//!
//! Telemetry accumulates in [`FaultStats`] (shared, atomic) and is
//! snapshotted into a [`FaultReport`] for `BatchReport` / CLI output.
//!
//! Classification note: [`Backend::deploy_check`] returns the vendored
//! `anyhow::Result`, which carries no type information to downcast. The
//! retry wrapper therefore classifies deploy errors by message
//! convention — errors whose chain mentions `transient` are retried,
//! everything else fails fast as permanent. [`FaultyBackend`] emits
//! injected deploy faults under that convention.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::analysis::Analysis;
use crate::funcblock::{BlockCost, Catalog, ConfirmedBlock};
use crate::hls::Device;
use crate::minic::Program;
use crate::obs;
use crate::runtime::{Artifacts, Runtime, SampleRun};
use crate::util::json::Json;
use crate::util::rng::Pcg32;

use super::backend::{Backend, BackendMeasurement};
use super::config::SearchConfig;
use super::funnel::Candidate;
use super::measure::SearchError;
use super::patterns::Pattern;

/// How a fault should be treated by the retry machinery.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultClass {
    /// Worth retrying: the next attempt may succeed (flaky build host,
    /// transient toolchain error).
    Transient,
    /// Retrying cannot help (bad program, resource overflow, numeric
    /// mismatch).
    Permanent,
    /// A stage deadline budget was exceeded (hung build).
    Timeout,
    /// The backend panicked; the attempt was abandoned.
    Panic,
}

impl FaultClass {
    /// Whether the retry loop should try again on this class.
    pub fn retryable(self) -> bool {
        matches!(self, FaultClass::Transient | FaultClass::Timeout)
    }

    pub fn as_str(self) -> &'static str {
        match self {
            FaultClass::Transient => "transient",
            FaultClass::Permanent => "permanent",
            FaultClass::Timeout => "timeout",
            FaultClass::Panic => "panic",
        }
    }
}

impl std::fmt::Display for FaultClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Which stage of the automation cycle a fault occurred in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Parse,
    Analysis,
    Extract,
    Measure,
    Verify,
    Select,
    Db,
    Deploy,
    /// Service-tier admission/scheduling: the request never reached a
    /// pipeline stage (queue full, deadline expired while queued, or
    /// the service was draining).
    Queue,
}

impl Stage {
    pub fn as_str(self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::Analysis => "analysis",
            Stage::Extract => "extract",
            Stage::Measure => "measure",
            Stage::Verify => "verify",
            Stage::Select => "select",
            Stage::Db => "db",
            Stage::Deploy => "deploy",
            Stage::Queue => "queue",
        }
    }
}

impl std::fmt::Display for Stage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A typed automation-cycle fault: where it happened, how to treat it,
/// and how many attempts were spent before giving up.
#[derive(Debug, Clone, PartialEq)]
pub struct OffloadError {
    pub stage: Stage,
    pub class: FaultClass,
    pub message: String,
    /// Attempts made by the time the error was surfaced (1 = no retry).
    pub attempts: u32,
}

impl OffloadError {
    pub fn new(
        stage: Stage,
        class: FaultClass,
        message: impl Into<String>,
    ) -> Self {
        OffloadError {
            stage,
            class,
            message: message.into(),
            attempts: 1,
        }
    }
}

impl std::fmt::Display for OffloadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} fault at {} after {} attempt(s): {}",
            self.class, self.stage, self.attempts, self.message
        )
    }
}

impl std::error::Error for OffloadError {}

/// FNV-1a over string parts with a separator — the deterministic site
/// key for fault injection and backoff jitter.
fn fnv1a(parts: &[&str]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for part in parts {
        for b in part.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h ^= 0xff;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// A shared virtual clock (microsecond ticks). Backoff waits and
/// injected hangs advance it instead of sleeping, so retry/deadline
/// semantics are exact and tests finish instantly. All clones share the
/// same underlying time.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    micros: Arc<AtomicU64>,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time, seconds since clock creation.
    pub fn now_s(&self) -> f64 {
        self.micros.load(Ordering::Relaxed) as f64 * 1e-6
    }

    /// Advance the clock by `s` virtual seconds.
    pub fn advance_s(&self, s: f64) {
        if s > 0.0 {
            self.micros
                .fetch_add((s * 1e6).round() as u64, Ordering::Relaxed);
        }
    }
}

/// Retry and deadline budgets for the backend-facing stages
/// (measure / verify / deploy_check).
///
/// Backoff is exponential with seeded jitter and fully deterministic:
/// the jitter RNG is keyed on `(seed, stage, attempt)`, never on wall
/// clock or thread identity.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts per call, including the first (≥ 1).
    pub max_attempts: u32,
    /// First backoff wait, virtual seconds.
    pub backoff_base_s: f64,
    /// Multiplier per subsequent wait (≥ 1).
    pub backoff_factor: f64,
    /// Jitter as a fraction of the wait (0 = none, 0.25 = ±25%).
    pub jitter_frac: f64,
    /// Seed for the jitter RNG.
    pub seed: u64,
    /// Per-stage deadline budget, virtual seconds: once a single call's
    /// attempts (including injected hangs and backoff waits) have
    /// consumed this much clock, the call fails with
    /// [`FaultClass::Timeout`]. `None` = no deadline.
    pub stage_deadline_s: Option<f64>,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 3,
            // The environment's builds are hours long; half a virtual
            // minute between attempts is noise against that scale.
            backoff_base_s: 30.0,
            backoff_factor: 2.0,
            jitter_frac: 0.25,
            seed: 42,
            stage_deadline_s: None,
        }
    }
}

impl RetryPolicy {
    /// Validate the knobs, mirroring [`SearchConfig::validate`].
    pub fn validate(&self) -> Result<(), String> {
        if self.max_attempts == 0 {
            return Err("max_attempts must be >= 1".into());
        }
        if self.backoff_base_s < 0.0 || self.backoff_base_s.is_nan() {
            return Err("backoff_base_s must be >= 0".into());
        }
        if self.backoff_factor < 1.0 || self.backoff_factor.is_nan() {
            return Err("backoff_factor must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.jitter_frac) {
            return Err("jitter_frac must be in [0, 1]".into());
        }
        if let Some(d) = self.stage_deadline_s {
            if d <= 0.0 || d.is_nan() {
                return Err("stage_deadline_s must be > 0".into());
            }
        }
        Ok(())
    }

    /// Deterministic backoff wait before retry number `attempt`
    /// (1-based: the wait after the first failed attempt is `attempt =
    /// 1`).
    pub fn backoff_s(&self, stage: Stage, attempt: u32) -> f64 {
        let exp = attempt.saturating_sub(1).min(30);
        let base = self.backoff_base_s * self.backoff_factor.powi(exp as i32);
        let mut rng = Pcg32::new(
            self.seed ^ fnv1a(&[stage.as_str()]),
            attempt as u64,
        );
        let jitter = 1.0 + self.jitter_frac * (2.0 * rng.f64() - 1.0);
        base * jitter
    }
}

#[derive(Debug, Default)]
struct StageCounters {
    calls: AtomicU64,
    retries: AtomicU64,
    exhausted: AtomicU64,
    timeouts: AtomicU64,
    panics: AtomicU64,
    backoff_micros: AtomicU64,
}

impl StageCounters {
    fn snapshot(&self) -> StageReport {
        StageReport {
            calls: self.calls.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            exhausted: self.exhausted.load(Ordering::Relaxed),
            timeouts: self.timeouts.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            backoff_s: self.backoff_micros.load(Ordering::Relaxed) as f64
                * 1e-6,
        }
    }
}

#[derive(Debug, Default)]
struct StatsInner {
    measure: StageCounters,
    verify: StageCounters,
    deploy: StageCounters,
}

/// Shared, thread-safe fault telemetry. Clones share the same counters,
/// so one `FaultStats` can be handed to every wrapped backend in a
/// batch and snapshotted once at the end.
#[derive(Debug, Clone, Default)]
pub struct FaultStats {
    inner: Arc<StatsInner>,
}

impl FaultStats {
    pub fn new() -> Self {
        Self::default()
    }

    fn counters(&self, stage: Stage) -> &StageCounters {
        match stage {
            Stage::Verify => &self.inner.verify,
            Stage::Deploy => &self.inner.deploy,
            _ => &self.inner.measure,
        }
    }

    /// Snapshot the counters into a plain report.
    pub fn snapshot(&self) -> FaultReport {
        FaultReport {
            measure: self.inner.measure.snapshot(),
            verify: self.inner.verify.snapshot(),
            deploy: self.inner.deploy.snapshot(),
        }
    }
}

/// Per-stage retry telemetry (a snapshot of [`FaultStats`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageReport {
    /// Logical calls (each may span several attempts).
    pub calls: u64,
    /// Retries performed (attempts beyond the first).
    pub retries: u64,
    /// Calls that spent their whole retry budget and failed.
    pub exhausted: u64,
    /// Calls that hit the stage deadline.
    pub timeouts: u64,
    /// Calls whose backend panicked.
    pub panics: u64,
    /// Total virtual backoff time waited, seconds.
    pub backoff_s: f64,
}

impl StageReport {
    fn merge(&mut self, other: &StageReport) {
        self.calls += other.calls;
        self.retries += other.retries;
        self.exhausted += other.exhausted;
        self.timeouts += other.timeouts;
        self.panics += other.panics;
        self.backoff_s += other.backoff_s;
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("calls", Json::Num(self.calls as f64)),
            ("retries", Json::Num(self.retries as f64)),
            ("exhausted", Json::Num(self.exhausted as f64)),
            ("timeouts", Json::Num(self.timeouts as f64)),
            ("panics", Json::Num(self.panics as f64)),
            ("backoff_s", Json::Num(self.backoff_s)),
        ])
    }
}

/// Fault telemetry across the retry-wrapped stages.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultReport {
    pub measure: StageReport,
    pub verify: StageReport,
    pub deploy: StageReport,
}

impl FaultReport {
    pub fn total_retries(&self) -> u64 {
        self.measure.retries + self.verify.retries + self.deploy.retries
    }

    pub fn total_exhausted(&self) -> u64 {
        self.measure.exhausted + self.verify.exhausted + self.deploy.exhausted
    }

    pub fn total_panics(&self) -> u64 {
        self.measure.panics + self.verify.panics + self.deploy.panics
    }

    /// Fold another report into this one (batch-level aggregation).
    pub fn merge(&mut self, other: &FaultReport) {
        self.measure.merge(&other.measure);
        self.verify.merge(&other.verify);
        self.deploy.merge(&other.deploy);
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("measure", self.measure.to_json()),
            ("verify", self.verify.to_json()),
            ("deploy", self.deploy.to_json()),
            ("total_retries", Json::Num(self.total_retries() as f64)),
            (
                "total_exhausted",
                Json::Num(self.total_exhausted() as f64),
            ),
            ("total_panics", Json::Num(self.total_panics() as f64)),
        ])
    }
}

/// Span name for a retry-wrapped backend call (the same taxonomy
/// [`crate::search::backend::TracedBackend`] uses on the unretried
/// path).
fn backend_span_name(stage: Stage) -> &'static str {
    match stage {
        Stage::Verify => "backend.verify",
        Stage::Deploy => "backend.deploy",
        _ => "backend.measure",
    }
}

fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// A [`Backend`] decorator that applies a [`RetryPolicy`] to the
/// measure / verify / deploy_check stages: retryable faults are retried
/// with deterministic backoff on the shared [`SimClock`], permanent
/// faults fail fast, panics are caught and surfaced as
/// [`FaultClass::Panic`], and per-stage deadline budgets turn hung
/// calls into [`FaultClass::Timeout`].
pub struct RetryingBackend<'a> {
    pub inner: &'a dyn Backend,
    pub policy: RetryPolicy,
    pub clock: SimClock,
    pub stats: FaultStats,
}

impl<'a> RetryingBackend<'a> {
    pub fn new(inner: &'a dyn Backend, policy: RetryPolicy) -> Self {
        RetryingBackend {
            inner,
            policy,
            clock: SimClock::new(),
            stats: FaultStats::new(),
        }
    }

    /// Retry loop for the `SearchError`-returning stages.
    fn run_stage<T>(
        &self,
        stage: Stage,
        mut call: impl FnMut() -> Result<T, SearchError>,
    ) -> Result<T, SearchError> {
        let _span = obs::span(backend_span_name(stage));
        let counters = self.stats.counters(stage);
        counters.calls.fetch_add(1, Ordering::Relaxed);
        let start = self.clock.now_s();
        let mut attempt: u32 = 1;
        loop {
            let outcome = {
                let mut att = obs::span("retry.attempt");
                att.note(|| format!("attempt {attempt}"));
                catch_unwind(AssertUnwindSafe(&mut call))
            };
            let err = match outcome {
                Err(payload) => {
                    counters.panics.fetch_add(1, Ordering::Relaxed);
                    let mut e = OffloadError::new(
                        stage,
                        FaultClass::Panic,
                        format!(
                            "backend panicked: {}",
                            panic_text(payload.as_ref())
                        ),
                    );
                    e.attempts = attempt;
                    return Err(SearchError::Fault(e));
                }
                Ok(Ok(v)) => return Ok(v),
                Ok(Err(e)) => e,
            };

            let (err_stage, class) = err.classify();
            if !class.retryable() {
                // Permanent faults (and anything the taxonomy cannot
                // call transient) fail fast, preserving the original
                // error so callers like `measure_patterns` keep their
                // skip semantics.
                return Err(err);
            }
            if let Some(deadline) = self.policy.stage_deadline_s {
                let elapsed = self.clock.now_s() - start;
                if elapsed >= deadline {
                    counters.timeouts.fetch_add(1, Ordering::Relaxed);
                    let mut e = OffloadError::new(
                        err_stage,
                        FaultClass::Timeout,
                        format!(
                            "stage deadline {deadline:.0}s exceeded \
                             ({elapsed:.0}s elapsed): {err}"
                        ),
                    );
                    e.attempts = attempt;
                    return Err(SearchError::Fault(e));
                }
            }
            if attempt >= self.policy.max_attempts {
                counters.exhausted.fetch_add(1, Ordering::Relaxed);
                let mut e = OffloadError::new(
                    err_stage,
                    class,
                    format!("retry budget exhausted: {err}"),
                );
                e.attempts = attempt;
                return Err(SearchError::Fault(e));
            }
            let wait = self.policy.backoff_s(err_stage, attempt);
            {
                let mut backoff = obs::span("retry.backoff");
                backoff.note(|| format!("{wait:.1}s"));
                self.clock.advance_s(wait);
            }
            counters
                .backoff_micros
                .fetch_add((wait * 1e6).round() as u64, Ordering::Relaxed);
            counters.retries.fetch_add(1, Ordering::Relaxed);
            attempt += 1;
        }
    }
}

impl Backend for RetryingBackend<'_> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn device(&self) -> &Device {
        self.inner.device()
    }

    fn destination(&self) -> &'static str {
        self.inner.destination()
    }

    fn measure(
        &self,
        prog: &Program,
        analysis: &Analysis,
        cands: &[Candidate],
        pattern: &Pattern,
        cfg: &SearchConfig,
    ) -> Result<BackendMeasurement, SearchError> {
        self.run_stage(Stage::Measure, || {
            self.inner.measure(prog, analysis, cands, pattern, cfg)
        })
    }

    fn verify(
        &self,
        prog: &Program,
        cands: &[Candidate],
        pattern: &Pattern,
        entry: &str,
        cfg: &SearchConfig,
    ) -> Result<bool, SearchError> {
        self.run_stage(Stage::Verify, || {
            self.inner.verify(prog, cands, pattern, entry, cfg)
        })
    }

    fn deploy_check(
        &self,
        sample: &str,
        env: (&Runtime, &Artifacts),
        seed: u64,
    ) -> anyhow::Result<SampleRun> {
        let _span = obs::span(backend_span_name(Stage::Deploy));
        let counters = self.stats.counters(Stage::Deploy);
        counters.calls.fetch_add(1, Ordering::Relaxed);
        let start = self.clock.now_s();
        let mut attempt: u32 = 1;
        loop {
            let outcome = {
                let mut att = obs::span("retry.attempt");
                att.note(|| format!("attempt {attempt}"));
                catch_unwind(AssertUnwindSafe(|| {
                    self.inner.deploy_check(sample, env, seed)
                }))
            };
            let err = match outcome {
                Err(payload) => {
                    counters.panics.fetch_add(1, Ordering::Relaxed);
                    return Err(anyhow::Error::msg(format!(
                        "panic fault at deploy after {attempt} \
                         attempt(s): backend panicked: {}",
                        panic_text(payload.as_ref())
                    )));
                }
                Ok(Ok(run)) => return Ok(run),
                Ok(Err(e)) => e,
            };

            // No downcast through the vendored anyhow: classify by the
            // documented message convention (see module docs).
            let chain = format!("{err:#}");
            if !chain.contains("transient") {
                return Err(err);
            }
            if let Some(deadline) = self.policy.stage_deadline_s {
                let elapsed = self.clock.now_s() - start;
                if elapsed >= deadline {
                    counters.timeouts.fetch_add(1, Ordering::Relaxed);
                    return Err(anyhow::Error::msg(format!(
                        "timeout fault at deploy after {attempt} \
                         attempt(s): stage deadline {deadline:.0}s \
                         exceeded: {chain}"
                    )));
                }
            }
            if attempt >= self.policy.max_attempts {
                counters.exhausted.fetch_add(1, Ordering::Relaxed);
                return Err(anyhow::Error::msg(format!(
                    "transient fault at deploy after {attempt} \
                     attempt(s): retry budget exhausted: {chain}"
                )));
            }
            let wait = self.policy.backoff_s(Stage::Deploy, attempt);
            {
                let mut backoff = obs::span("retry.backoff");
                backoff.note(|| format!("{wait:.1}s"));
                self.clock.advance_s(wait);
            }
            counters
                .backoff_micros
                .fetch_add((wait * 1e6).round() as u64, Ordering::Relaxed);
            counters.retries.fetch_add(1, Ordering::Relaxed);
            attempt += 1;
        }
    }

    fn price_block(
        &self,
        block: &ConfirmedBlock,
        catalog: &Catalog,
    ) -> Option<BlockCost> {
        self.inner.price_block(block, catalog)
    }
}

/// Which faults a [`FaultyBackend`] injects and how often. All rates are
/// per-*site* probabilities (a site = backend + stage + pattern/sample),
/// drawn once per site from a PCG stream keyed on `(seed, site)` — the
/// same seed always produces the same fault plan, independent of call
/// order or thread scheduling.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Probability a site gets a burst of transient failures.
    pub transient_rate: f64,
    /// Maximum consecutive transient failures in a burst (burst size is
    /// uniform in `1..=max_burst`).
    pub max_burst: u32,
    /// Probability a site's first call hangs (advances the virtual
    /// clock by `hang_s`) before failing with a timeout-class fault.
    pub hang_rate: f64,
    /// Virtual seconds consumed by one injected hang.
    pub hang_s: f64,
    /// Probability a verify site's first successful call reports a
    /// numeric mismatch (`Ok(false)`).
    pub verify_flip_rate: f64,
    /// Probability a site's first call panics.
    pub panic_rate: f64,
    /// Probability a site fails permanently on every call.
    pub permanent_rate: f64,
}

impl FaultPlan {
    /// No injection at all (the wrapper becomes a transparent proxy).
    pub fn none() -> Self {
        FaultPlan {
            seed: 0,
            transient_rate: 0.0,
            max_burst: 0,
            hang_rate: 0.0,
            hang_s: 0.0,
            verify_flip_rate: 0.0,
            panic_rate: 0.0,
            permanent_rate: 0.0,
        }
    }

    /// Only recoverable faults: transient bursts short enough that the
    /// default [`RetryPolicy`] always recovers.
    pub fn transient_only(seed: u64) -> Self {
        FaultPlan {
            seed,
            transient_rate: 0.5,
            max_burst: 2,
            ..FaultPlan::none()
        }
    }

    /// The full chaos menu at moderate rates — the CLI's
    /// `--inject-faults <seed>` plan.
    pub fn from_seed(seed: u64) -> Self {
        FaultPlan {
            seed,
            transient_rate: 0.3,
            max_burst: 2,
            hang_rate: 0.05,
            hang_s: 3.0 * 3600.0,
            verify_flip_rate: 0.05,
            panic_rate: 0.02,
            permanent_rate: 0.05,
        }
    }
}

/// What the plan injects for one call, in site-queue order.
enum Injected {
    Panic,
    Hang,
    Transient,
    Permanent,
    VerifyFlip,
    None,
}

/// A deterministic fault injector around any inner [`Backend`] — the
/// test/bench harness for the resilience layer. See [`FaultPlan`] for
/// the fault menu and the determinism contract.
pub struct FaultyBackend<'a> {
    pub inner: &'a dyn Backend,
    pub plan: FaultPlan,
    pub clock: SimClock,
    /// Per-site call counters (site key → calls made so far).
    sites: Mutex<HashMap<u64, u32>>,
}

impl<'a> FaultyBackend<'a> {
    pub fn new(inner: &'a dyn Backend, plan: FaultPlan, clock: SimClock) -> Self {
        FaultyBackend {
            inner,
            plan,
            clock,
            sites: Mutex::new(HashMap::new()),
        }
    }

    /// Decide what (if anything) to inject for this call. The site
    /// profile (permanent? panic? hang? burst size? flip?) is a pure
    /// function of `(plan.seed, site)`; the per-site call counter turns
    /// the profile into a failure queue: panic first, then the hang,
    /// then the transient burst, then success.
    fn injected(&self, stage: Stage, detail: &str) -> Injected {
        let key = fnv1a(&[self.inner.name(), stage.as_str(), detail]);
        let mut rng = Pcg32::new(self.plan.seed, key);
        let permanent = rng.chance(self.plan.permanent_rate);
        let panic_once = rng.chance(self.plan.panic_rate);
        let hang = rng.chance(self.plan.hang_rate);
        let burst = if rng.chance(self.plan.transient_rate) {
            1 + rng.below(self.plan.max_burst.max(1))
        } else {
            0
        };
        let flip = rng.chance(self.plan.verify_flip_rate);

        let call = {
            let mut sites = self.sites.lock().unwrap();
            let n = sites.entry(key).or_insert(0);
            let call = *n;
            *n += 1;
            call
        };

        if permanent {
            return Injected::Permanent;
        }
        let mut queue: Vec<Injected> = Vec::new();
        if panic_once {
            queue.push(Injected::Panic);
        }
        if hang {
            queue.push(Injected::Hang);
        }
        for _ in 0..burst {
            queue.push(Injected::Transient);
        }
        if (call as usize) < queue.len() {
            return queue.swap_remove(call as usize);
        }
        if flip && stage == Stage::Verify && call as usize == queue.len() {
            return Injected::VerifyFlip;
        }
        Injected::None
    }

    fn fault(&self, stage: Stage, detail: &str) -> Option<SearchError> {
        match self.injected(stage, detail) {
            Injected::None | Injected::VerifyFlip => None,
            Injected::Panic => {
                panic!("injected backend panic at {stage} ({detail})")
            }
            Injected::Hang => {
                self.clock.advance_s(self.plan.hang_s);
                Some(SearchError::Fault(OffloadError::new(
                    stage,
                    FaultClass::Timeout,
                    format!(
                        "injected hung build ({:.0}s) at {stage} ({detail})",
                        self.plan.hang_s
                    ),
                )))
            }
            Injected::Transient => {
                Some(SearchError::Fault(OffloadError::new(
                    stage,
                    FaultClass::Transient,
                    format!("injected transient fault at {stage} ({detail})"),
                )))
            }
            Injected::Permanent => {
                Some(SearchError::Fault(OffloadError::new(
                    stage,
                    FaultClass::Permanent,
                    format!("injected permanent fault at {stage} ({detail})"),
                )))
            }
        }
    }
}

impl Backend for FaultyBackend<'_> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn device(&self) -> &Device {
        self.inner.device()
    }

    fn destination(&self) -> &'static str {
        self.inner.destination()
    }

    fn measure(
        &self,
        prog: &Program,
        analysis: &Analysis,
        cands: &[Candidate],
        pattern: &Pattern,
        cfg: &SearchConfig,
    ) -> Result<BackendMeasurement, SearchError> {
        let detail = format!("{}:{:?}", analysis.entry, pattern);
        if let Some(e) = self.fault(Stage::Measure, &detail) {
            return Err(e);
        }
        self.inner.measure(prog, analysis, cands, pattern, cfg)
    }

    fn verify(
        &self,
        prog: &Program,
        cands: &[Candidate],
        pattern: &Pattern,
        entry: &str,
        cfg: &SearchConfig,
    ) -> Result<bool, SearchError> {
        let detail = format!("{entry}:{pattern:?}");
        match self.injected(Stage::Verify, &detail) {
            Injected::None => {}
            Injected::VerifyFlip => return Ok(false),
            Injected::Panic => {
                panic!("injected backend panic at verify ({detail})")
            }
            Injected::Hang => {
                self.clock.advance_s(self.plan.hang_s);
                return Err(SearchError::Fault(OffloadError::new(
                    Stage::Verify,
                    FaultClass::Timeout,
                    format!(
                        "injected hung build ({:.0}s) at verify ({detail})",
                        self.plan.hang_s
                    ),
                )));
            }
            Injected::Transient => {
                return Err(SearchError::Fault(OffloadError::new(
                    Stage::Verify,
                    FaultClass::Transient,
                    format!("injected transient fault at verify ({detail})"),
                )));
            }
            Injected::Permanent => {
                return Err(SearchError::Fault(OffloadError::new(
                    Stage::Verify,
                    FaultClass::Permanent,
                    format!("injected permanent fault at verify ({detail})"),
                )));
            }
        }
        self.inner.verify(prog, cands, pattern, entry, cfg)
    }

    fn deploy_check(
        &self,
        sample: &str,
        env: (&Runtime, &Artifacts),
        seed: u64,
    ) -> anyhow::Result<SampleRun> {
        match self.injected(Stage::Deploy, sample) {
            Injected::None | Injected::VerifyFlip => {}
            Injected::Panic => {
                panic!("injected backend panic at deploy ({sample})")
            }
            Injected::Hang => {
                self.clock.advance_s(self.plan.hang_s);
                // "transient" keeps the retry wrapper's message-
                // convention classifier treating hangs as retryable.
                anyhow::bail!(
                    "transient injected hung deploy ({:.0}s) for {sample}",
                    self.plan.hang_s
                );
            }
            Injected::Transient => {
                anyhow::bail!(
                    "transient injected deploy fault for {sample}"
                );
            }
            Injected::Permanent => {
                anyhow::bail!(
                    "injected permanent deploy fault for {sample}"
                );
            }
        }
        self.inner.deploy_check(sample, env, seed)
    }

    fn price_block(
        &self,
        block: &ConfirmedBlock,
        catalog: &Catalog,
    ) -> Option<BlockCost> {
        self.inner.price_block(block, catalog)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::cpu::XEON_BRONZE_3104;
    use crate::hls::ARRIA10_GX;
    use crate::minic::parse;
    use crate::search::backend::FpgaBackend;
    use crate::search::measure::search_with_backend;

    const SRC: &str = "
#define N 2048
#define REP 16
float sig[N]; float out1[N]; float out2[N];
int main() {
    for (int i = 0; i < N; i++) { sig[i] = i * 0.001 - 1.0; }
    for (int r = 0; r < REP; r++) {
        for (int i = 0; i < N; i++) {
            out1[i] = sin(sig[i]) * cos(sig[i]) + sqrt(sig[i] * sig[i] + 1.0);
        }
    }
    for (int i = 0; i < N; i++) { out2[i] = sqrt(out1[i] + 2.0); }
    return 0;
}";

    #[test]
    fn backoff_is_deterministic_and_bounded() {
        let p = RetryPolicy::default();
        let a = p.backoff_s(Stage::Measure, 1);
        let b = p.backoff_s(Stage::Measure, 1);
        assert_eq!(a, b);
        // Jitter stays within ±jitter_frac of the nominal wait.
        for attempt in 1..=4u32 {
            let nominal = p.backoff_base_s
                * p.backoff_factor.powi(attempt as i32 - 1);
            let w = p.backoff_s(Stage::Measure, attempt);
            assert!(
                w >= nominal * (1.0 - p.jitter_frac)
                    && w <= nominal * (1.0 + p.jitter_frac),
                "attempt {attempt}: {w} vs nominal {nominal}"
            );
        }
        // Different stages jitter differently but share the envelope.
        assert_ne!(
            p.backoff_s(Stage::Measure, 1),
            p.backoff_s(Stage::Verify, 1)
        );
    }

    #[test]
    fn policy_validation_rejects_bad_knobs() {
        assert!(RetryPolicy::default().validate().is_ok());
        let bad = RetryPolicy {
            max_attempts: 0,
            ..RetryPolicy::default()
        };
        assert!(bad.validate().is_err());
        let bad = RetryPolicy {
            backoff_factor: 0.5,
            ..RetryPolicy::default()
        };
        assert!(bad.validate().is_err());
        let bad = RetryPolicy {
            stage_deadline_s: Some(0.0),
            ..RetryPolicy::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn sim_clock_is_shared_across_clones() {
        let clock = SimClock::new();
        let other = clock.clone();
        clock.advance_s(12.5);
        assert!((other.now_s() - 12.5).abs() < 1e-9);
    }

    fn fault_free_solution() -> crate::search::OffloadSolution {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let backend = FpgaBackend {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        search_with_backend(
            "t",
            &prog,
            &an,
            &SearchConfig::default(),
            &backend,
        )
        .unwrap()
    }

    #[test]
    fn transient_faults_retry_to_the_fault_free_solution() {
        let clean = fault_free_solution();

        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let backend = FpgaBackend {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        // Every site faults once or twice, then recovers — the default
        // 3-attempt budget always wins.
        let plan = FaultPlan {
            transient_rate: 1.0,
            ..FaultPlan::transient_only(7)
        };
        let clock = SimClock::new();
        let faulty = FaultyBackend::new(&backend, plan, clock.clone());
        let retrying = RetryingBackend {
            inner: &faulty,
            policy: RetryPolicy::default(),
            clock: clock.clone(),
            stats: FaultStats::new(),
        };
        let sol = search_with_backend(
            "t",
            &prog,
            &an,
            &SearchConfig::default(),
            &retrying,
        )
        .unwrap();

        assert_eq!(
            clean.best_measurement().loops,
            sol.best_measurement().loops
        );
        assert!((clean.speedup() - sol.speedup()).abs() < 1e-12);
        let report = retrying.stats.snapshot();
        assert!(report.total_retries() > 0, "{report:?}");
        assert_eq!(report.total_exhausted(), 0, "{report:?}");
        // Backoff waits landed on the virtual clock, not wall clock.
        assert!(clock.now_s() > 0.0);
        assert!(report.measure.backoff_s > 0.0);
    }

    #[test]
    fn permanent_faults_fail_fast() {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let backend = FpgaBackend {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let plan = FaultPlan {
            seed: 3,
            permanent_rate: 1.0,
            ..FaultPlan::none()
        };
        let clock = SimClock::new();
        let faulty = FaultyBackend::new(&backend, plan, clock.clone());
        let retrying = RetryingBackend {
            inner: &faulty,
            policy: RetryPolicy::default(),
            clock,
            stats: FaultStats::new(),
        };
        let err = search_with_backend(
            "t",
            &prog,
            &an,
            &SearchConfig::default(),
            &retrying,
        )
        .unwrap_err();
        match err {
            SearchError::Fault(e) => {
                assert_eq!(e.class, FaultClass::Permanent);
                assert_eq!(e.attempts, 1, "no retries on permanent faults");
            }
            other => panic!("expected a fault, got {other}"),
        }
        assert_eq!(retrying.stats.snapshot().total_retries(), 0);
    }

    #[test]
    fn hung_builds_hit_the_stage_deadline() {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let backend = FpgaBackend {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let plan = FaultPlan {
            seed: 11,
            hang_rate: 1.0,
            hang_s: 3.0 * 3600.0,
            ..FaultPlan::none()
        };
        let clock = SimClock::new();
        let faulty = FaultyBackend::new(&backend, plan, clock.clone());
        let retrying = RetryingBackend {
            inner: &faulty,
            policy: RetryPolicy {
                stage_deadline_s: Some(3600.0),
                ..RetryPolicy::default()
            },
            clock,
            stats: FaultStats::new(),
        };
        let err = search_with_backend(
            "t",
            &prog,
            &an,
            &SearchConfig::default(),
            &retrying,
        )
        .unwrap_err();
        match err {
            SearchError::Fault(e) => {
                assert_eq!(e.class, FaultClass::Timeout);
            }
            other => panic!("expected a timeout, got {other}"),
        }
        let report = retrying.stats.snapshot();
        assert!(report.measure.timeouts > 0, "{report:?}");
    }

    #[test]
    fn panics_are_caught_and_classified() {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let backend = FpgaBackend {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let plan = FaultPlan {
            seed: 5,
            panic_rate: 1.0,
            ..FaultPlan::none()
        };
        let clock = SimClock::new();
        let faulty = FaultyBackend::new(&backend, plan, clock.clone());
        let retrying = RetryingBackend {
            inner: &faulty,
            policy: RetryPolicy::default(),
            clock,
            stats: FaultStats::new(),
        };
        let err = search_with_backend(
            "t",
            &prog,
            &an,
            &SearchConfig::default(),
            &retrying,
        )
        .unwrap_err();
        match err {
            SearchError::Fault(e) => {
                assert_eq!(e.class, FaultClass::Panic);
                assert!(e.message.contains("injected backend panic"));
            }
            other => panic!("expected a panic fault, got {other}"),
        }
        assert!(retrying.stats.snapshot().total_panics() > 0);
    }

    #[test]
    fn injection_is_deterministic_per_seed() {
        let run = |seed: u64| {
            let prog = parse(SRC).unwrap();
            let an = analyze(&prog, "main").unwrap();
            let backend = FpgaBackend {
                cpu: &XEON_BRONZE_3104,
                device: &ARRIA10_GX,
            };
            let clock = SimClock::new();
            let faulty = FaultyBackend::new(
                &backend,
                FaultPlan::transient_only(seed),
                clock.clone(),
            );
            let retrying = RetryingBackend {
                inner: &faulty,
                policy: RetryPolicy::default(),
                clock,
                stats: FaultStats::new(),
            };
            let sol = search_with_backend(
                "t",
                &prog,
                &an,
                &SearchConfig::default(),
                &retrying,
            )
            .unwrap();
            (sol.speedup(), retrying.stats.snapshot())
        };
        let (s1, r1) = run(99);
        let (s2, r2) = run(99);
        assert_eq!(s1, s2);
        assert_eq!(r1, r2, "same seed, same fault telemetry");
    }

    #[test]
    fn fault_report_json_shape() {
        let stats = FaultStats::new();
        stats
            .counters(Stage::Measure)
            .retries
            .fetch_add(3, Ordering::Relaxed);
        let report = stats.snapshot();
        let j = report.to_json();
        assert_eq!(
            j.get(&["measure", "retries"]).and_then(Json::as_f64),
            Some(3.0)
        );
        assert_eq!(
            j.get(&["total_retries"]).and_then(Json::as_f64),
            Some(3.0)
        );
        let mut merged = FaultReport::default();
        merged.merge(&report);
        merged.merge(&report);
        assert_eq!(merged.measure.retries, 6);
    }

    #[test]
    fn site_keys_separate_stages_and_details() {
        let a = fnv1a(&["fpga", "measure", "main:[0]"]);
        let b = fnv1a(&["fpga", "verify", "main:[0]"]);
        let c = fnv1a(&["fpga", "measure", "main:[1]"]);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, fnv1a(&["fpga", "measure", "main:[0]"]));
    }
}
