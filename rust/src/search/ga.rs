//! GA search baseline — the previous work's strategy ([32], automatic GPU
//! offloading), implemented for comparison benches.
//!
//! [32] evolves offload bitmasks over *all* processable loops with many
//! performance measurements. That is affordable when a pattern compiles in
//! minutes (GPU) and ruinous at ~3 h per FPGA compile — the gap the
//! paper's funnel exists to close. `ga_vs_funnel` benchmarks exactly this:
//! measurements-to-solution and modeled wall-clock for both strategies.

use crate::analysis::Analysis;
use crate::codegen::{split, SplitResult};
use crate::cpu::CpuModel;
use crate::fpga::{self, simulate};
use crate::hls::{estimate, full_compile_seconds, Device, ResourceEstimate};
use crate::minic::ast::LoopId;
use crate::minic::Program;
use crate::util::rng::Pcg32;

/// Genome width: offload masks are `u64` bitmaps, so the gene space is
/// capped at the 64 top-ranked candidate loops.
pub const MAX_GENES: usize = 64;

/// GA hyper-parameters (matched to [32]'s modest settings).
#[derive(Debug, Clone)]
pub struct GaConfig {
    pub population: usize,
    pub generations: usize,
    pub crossover_rate: f64,
    pub mutation_rate: f64,
    pub seed: u64,
}

impl Default for GaConfig {
    fn default() -> Self {
        GaConfig {
            population: 8,
            generations: 5,
            crossover_rate: 0.9,
            mutation_rate: 0.05,
            seed: 0xf96a,
        }
    }
}

/// GA outcome.
#[derive(Debug, Clone)]
pub struct GaResult {
    pub best_loops: Vec<LoopId>,
    pub best_speedup: f64,
    /// Distinct patterns whose fitness was measured (each would be a ~3 h
    /// FPGA compile).
    pub measurements: usize,
    /// Modeled wall-clock to run those compiles sequentially, seconds.
    pub modeled_wall_clock_s: f64,
    /// Best speedup after each generation (convergence curve).
    pub history: Vec<f64>,
}

/// Run the GA baseline over all offloadable candidate loops.
pub fn run(
    prog: &Program,
    analysis: &Analysis,
    cfg: &GaConfig,
    cpu: &CpuModel,
    dev: &Device,
) -> GaResult {
    // Gene space: every offloadable candidate (no funnel narrowing).
    let mut cands: Vec<(LoopId, SplitResult)> = analysis
        .ranked_candidates()
        .into_iter()
        .filter_map(|al| split(prog, al).ok().map(|s| (al.id(), s)))
        .collect();
    // The genome is a u64 bitmask: with more than 64 candidates,
    // `1u64 << b` shifts out of range (panic in debug, silent wraparound
    // corrupting genes in release). Cap the gene space at the 64
    // top-ranked candidates (`ranked_candidates` is score-descending),
    // logging the truncation.
    if cands.len() > MAX_GENES {
        eprintln!(
            "ga: truncating gene space from {} to {MAX_GENES} top-ranked \
             candidates (u64 genome)",
            cands.len()
        );
        cands.truncate(MAX_GENES);
    }
    let n = cands.len();
    if n == 0 {
        return GaResult {
            best_loops: Vec::new(),
            best_speedup: 1.0,
            measurements: 0,
            modeled_wall_clock_s: 0.0,
            history: Vec::new(),
        };
    }

    let mut rng = Pcg32::seeded(cfg.seed);
    let mut evaluated: std::collections::HashMap<u64, f64> =
        std::collections::HashMap::new();
    let mut compile_s_total = 0.0;

    let fitness = |mask: u64,
                       evaluated: &mut std::collections::HashMap<u64, f64>,
                       compile_s_total: &mut f64|
     -> f64 {
        if let Some(f) = evaluated.get(&mask) {
            return *f;
        }
        let kernels: Vec<_> = (0..n)
            .filter(|b| mask & (1 << b) != 0)
            .map(|b| cands[b].1.kernel.clone())
            .collect();
        let f = if kernels.is_empty() {
            1.0 // all-CPU
        } else {
            match simulate(analysis, &kernels, cpu, dev) {
                Ok(t) => t.speedup,
                Err(fpga::SimError::OverlappingLoops(..))
                | Err(fpga::SimError::DoesNotFit) => 0.0,
                Err(fpga::SimError::ColdLoop(_)) => 0.0,
            }
        };
        // Every *new* measured pattern costs a full compile.
        if !kernels.is_empty() && f > 0.0 {
            let combined = kernels
                .iter()
                .map(estimate)
                .fold(ResourceEstimate::default(), |a, e| a.add(&e));
            *compile_s_total += full_compile_seconds(&combined, dev);
        }
        evaluated.insert(mask, f);
        f
    };

    // Init population: random masks with 1–2 bits set.
    let mut pop: Vec<u64> = (0..cfg.population)
        .map(|_| {
            let mut m = 1u64 << rng.index(n);
            if rng.chance(0.5) {
                m |= 1 << rng.index(n);
            }
            m
        })
        .collect();

    let mut best_mask = 0u64;
    let mut best_fit = 1.0f64;
    let mut history = Vec::new();

    for _gen in 0..cfg.generations {
        let fits: Vec<f64> = pop
            .iter()
            .map(|&m| fitness(m, &mut evaluated, &mut compile_s_total))
            .collect();
        for (m, f) in pop.iter().zip(&fits) {
            if *f > best_fit {
                best_fit = *f;
                best_mask = *m;
            }
        }
        history.push(best_fit);

        // Tournament selection + single-point crossover + mutation.
        let mut next = Vec::with_capacity(pop.len());
        while next.len() < pop.len() {
            let pick = |rng: &mut Pcg32| {
                let a = rng.index(pop.len());
                let b = rng.index(pop.len());
                if fits[a] >= fits[b] {
                    pop[a]
                } else {
                    pop[b]
                }
            };
            let p1 = pick(&mut rng);
            let p2 = pick(&mut rng);
            let mut child = if rng.chance(cfg.crossover_rate) && n > 1 {
                let point = 1 + rng.index(n - 1);
                let low = (1u64 << point) - 1;
                (p1 & low) | (p2 & !low)
            } else {
                p1
            };
            for b in 0..n {
                if rng.chance(cfg.mutation_rate) {
                    child ^= 1 << b;
                }
            }
            next.push(child);
        }
        pop = next;
    }
    // Final evaluation pass.
    for &m in &pop {
        let f = fitness(m, &mut evaluated, &mut compile_s_total);
        if f > best_fit {
            best_fit = f;
            best_mask = m;
        }
    }
    history.push(best_fit);

    let best_loops: Vec<LoopId> = (0..n)
        .filter(|b| best_mask & (1 << b) != 0)
        .map(|b| cands[b].0)
        .collect();
    GaResult {
        best_loops,
        best_speedup: best_fit,
        measurements: evaluated.len(),
        modeled_wall_clock_s: compile_s_total,
        history,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::cpu::XEON_BRONZE_3104;
    use crate::hls::ARRIA10_GX;
    use crate::minic::parse;
    use crate::search::{measure, SearchConfig};

    const SRC: &str = "
#define N 2048
#define REP 16
float sig[N]; float o1[N]; float o2[N];
int main() {
    for (int i = 0; i < N; i++) { sig[i] = i * 0.001 - 1.0; }
    for (int r = 0; r < REP; r++) {
        for (int i = 0; i < N; i++) {
            o1[i] = sin(sig[i]) * cos(sig[i]) + sqrt(sig[i] * sig[i] + 1.0);
        }
    }
    for (int i = 0; i < N; i++) { o2[i] = sqrt(o1[i] + 2.0); }
    return 0;
}";

    #[test]
    fn ga_finds_a_win_but_pays_many_measurements() {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let ga = run(
            &prog,
            &an,
            &GaConfig::default(),
            &XEON_BRONZE_3104,
            &ARRIA10_GX,
        );
        assert!(ga.best_speedup > 1.0, "{ga:?}");

        let funnel_sol = measure::search(
            "t",
            &prog,
            &an,
            &SearchConfig::default(),
            &XEON_BRONZE_3104,
            &ARRIA10_GX,
        )
        .unwrap();
        // The funnel reaches comparable quality with far fewer
        // measurements — the paper's core claim.
        assert!(ga.measurements > funnel_sol.measurements.len());
        assert!(
            funnel_sol.speedup() >= ga.best_speedup * 0.8,
            "funnel {:.2} vs ga {:.2}",
            funnel_sol.speedup(),
            ga.best_speedup
        );
    }

    #[test]
    fn ga_deterministic_per_seed() {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let a = run(&prog, &an, &GaConfig::default(), &XEON_BRONZE_3104, &ARRIA10_GX);
        let b = run(&prog, &an, &GaConfig::default(), &XEON_BRONZE_3104, &ARRIA10_GX);
        assert_eq!(a.best_loops, b.best_loops);
        assert_eq!(a.measurements, b.measurements);
    }

    #[test]
    fn gene_space_capped_at_64_candidates() {
        // Regression: with > 64 offloadable loops the old code computed
        // `1u64 << b` with b >= 64 (debug panic / release wraparound).
        let mut src = String::from("#define N 8\n");
        for i in 0..68 {
            src.push_str(&format!("float a{i}[N];\n"));
        }
        src.push_str("int main() {\n");
        for i in 0..68 {
            src.push_str(&format!(
                "    for (int i = 0; i < N; i++) {{ a{i}[i] = a{i}[i] * 1.01 + {i}.0; }}\n"
            ));
        }
        src.push_str("    return 0;\n}\n");
        let prog = parse(&src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        assert!(an.ranked_candidates().len() > MAX_GENES);
        let ga = run(
            &prog,
            &an,
            &GaConfig::default(),
            &XEON_BRONZE_3104,
            &ARRIA10_GX,
        );
        assert!(ga.measurements > 0);
        // Any selected loop must come from the (capped) candidate space.
        assert!(ga.best_loops.len() <= MAX_GENES);
        for l in &ga.best_loops {
            assert!(l.0 < 68);
        }
    }

    #[test]
    fn ga_history_monotone() {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let ga = run(&prog, &an, &GaConfig::default(), &XEON_BRONZE_3104, &ARRIA10_GX);
        for w in ga.history.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }
}
