//! The offload search — the paper's contribution (§3.3, Fig. 2).
//!
//! * [`config`] — the A/B/C/D knobs from §5.1.2.
//! * [`funnel`] — intensity → pre-compile → resource-efficiency narrowing.
//! * [`patterns`] — single + combination pattern generation with the
//!   resource-cap rule.
//! * [`backend`] — the destination seam: measurement, verification and
//!   deploy-check per target ([`FpgaBackend`], [`GpuBackend`],
//!   [`OmpBackend`], [`CpuBaseline`]).
//! * [`measure`] — the verification environment: worker-pool measurement,
//!   two rounds, best-pattern selection, automation-time accounting.
//! * [`resilience`] — typed faults, retry/deadline budgets, and the
//!   seeded fault-injection harness around the backend seam.
//! * [`ga`] — the previous work's GA strategy \[32\], as the comparison
//!   baseline.
//!
//! The funnel's A/B/C/D knobs are a validated [`SearchConfig`]; its
//! fingerprint is part of the pattern-DB reuse key, so two configs that
//! differ in any knob never share stored plans:
//!
//! ```
//! use fpga_offload::search::SearchConfig;
//!
//! let base = SearchConfig::default();
//! assert!(base.validate().is_ok());
//! let tighter = SearchConfig { max_patterns: 3, ..SearchConfig::default() };
//! assert_ne!(base.fingerprint(), tighter.fingerprint());
//! ```

pub mod backend;
pub mod config;
pub mod funnel;
pub mod ga;
pub mod measure;
pub mod patterns;
pub mod resilience;
pub mod result;

pub use backend::{
    Backend, BackendMeasurement, CpuBaseline, FpgaBackend, GpuBackend,
    OmpBackend,
};
pub use config::SearchConfig;
pub use funnel::{Candidate, FunnelError};
pub use ga::{GaConfig, GaResult};
pub use measure::{
    measure_patterns, search, search_with_backend, select, MeasuredSet,
    SearchError,
};
pub use resilience::{
    FaultClass, FaultPlan, FaultReport, FaultStats, FaultyBackend,
    OffloadError, RetryPolicy, RetryingBackend, SimClock, Stage, StageReport,
};
pub use result::{FunnelTrace, OffloadSolution, PatternMeasurement};
