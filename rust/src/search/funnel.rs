//! The narrowing funnel (paper Fig. 2): loops → offloadable → top-A by
//! arithmetic intensity → OpenCL generation + pre-compile → top-C by
//! resource efficiency.
//!
//! The funnel's entire purpose is measurement economy: a full FPGA compile
//! is ~3 h, so the set that reaches actual measurement must be tiny, and
//! everything before that line must come from cheap analysis (profiling,
//! one-minute pre-compiles).

use crate::analysis::Analysis;
use crate::codegen::{split, unroll, SplitResult};
use crate::hls::{precompile, Device, PrecompileReport};
use crate::minic::ast::LoopId;
use crate::minic::Program;

use super::config::SearchConfig;
use super::result::FunnelTrace;

/// A candidate that survived the funnel: its split (with unrolled kernel)
/// and pre-compile report.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub split: SplitResult,
    pub report: PrecompileReport,
}

impl Candidate {
    pub fn loop_id(&self) -> LoopId {
        self.split.kernel.loop_id
    }
}

/// Funnel failure.
#[derive(Debug, Clone)]
pub enum FunnelError {
    Config(String),
    NoCandidates,
}

impl std::fmt::Display for FunnelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FunnelError::Config(msg) => write!(f, "bad config: {msg}"),
            FunnelError::NoCandidates => {
                write!(f, "no offloadable loops survived the funnel")
            }
        }
    }
}

impl std::error::Error for FunnelError {}

/// Run the funnel. Returns the surviving candidates (top-C, ordered by
/// resource efficiency, descending) and the trace for reporting.
pub fn run(
    prog: &Program,
    analysis: &Analysis,
    cfg: &SearchConfig,
    dev: &Device,
) -> Result<(Vec<Candidate>, FunnelTrace), FunnelError> {
    run_excluding(prog, analysis, cfg, dev, &std::collections::BTreeSet::new())
}

/// [`run`], with a pre-claimed region: loops in `claimed` (typically
/// swallowed by a [`crate::funcblock`] replacement) never enter the
/// funnel — not as offloadable, not as top-A, not as candidates — so
/// the loop search runs only over what no block replacement claimed.
pub fn run_excluding(
    prog: &Program,
    analysis: &Analysis,
    cfg: &SearchConfig,
    dev: &Device,
    claimed: &std::collections::BTreeSet<LoopId>,
) -> Result<(Vec<Candidate>, FunnelTrace), FunnelError> {
    cfg.validate().map_err(FunnelError::Config)?;

    let total_loops = analysis.loops.len();
    let offloadable: Vec<LoopId> = analysis
        .loops
        .iter()
        .filter(|l| l.candidate() && !claimed.contains(&l.id()))
        .map(|l| l.id())
        .collect();

    // Stage 1: arithmetic-intensity narrowing (top A).
    let mut ranked = analysis.ranked_candidates();
    ranked.retain(|l| !claimed.contains(&l.id()));
    let top_a_loops: Vec<LoopId> = ranked
        .iter()
        .take(cfg.top_a)
        .map(|l| l.id())
        .collect();

    // Stage 2: OpenCL generation + pre-compile for each top-A loop.
    let mut survivors: Vec<Candidate> = Vec::new();
    let mut reports: Vec<PrecompileReport> = Vec::new();
    for al in ranked.iter().take(cfg.top_a) {
        let Ok(mut sp) = split(prog, al) else {
            continue; // split failure = drop from funnel (kept in trace)
        };
        // Apply the expansion factor B.
        match unroll(&sp.kernel, cfg.unroll) {
            Ok(k) => {
                sp.kernel_fn.body = vec![k.body.clone()];
                sp.kernel = k;
            }
            Err(_) => {
                // Unrollable shape with B > 1: keep the un-expanded kernel
                // (the paper's expansion is best-effort).
            }
        }
        let intensity = al.intensity.as_ref().expect("candidate");
        let report = precompile(&sp.kernel, intensity, dev);
        reports.push(report.clone());
        if report.fits {
            survivors.push(Candidate { split: sp, report });
        }
    }

    // Stage 3: resource-efficiency narrowing (top C).
    survivors.sort_by(|a, b| {
        b.report
            .resource_efficiency
            .partial_cmp(&a.report.resource_efficiency)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.loop_id().cmp(&b.loop_id()))
    });
    survivors.truncate(cfg.top_c);

    if survivors.is_empty() {
        return Err(FunnelError::NoCandidates);
    }

    let trace = FunnelTrace {
        total_loops,
        offloadable,
        top_a: top_a_loops,
        reports,
        top_c: survivors.iter().map(Candidate::loop_id).collect(),
    };
    Ok((survivors, trace))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::hls::ARRIA10_GX;
    use crate::minic::parse;

    /// Six loops with clearly graded intensity so the funnel's ordering is
    /// deterministic; one blocked loop.
    const SRC: &str = r#"
#define N 1024
float a[N]; float b[N]; float c[N]; float d[N];
float acc;
void audit() { }
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.001; }                // L0 init
    for (int i = 0; i < N; i++) { b[i] = a[i] + 1.0; }               // L1 cheap
    for (int i = 0; i < N; i++) { c[i] = sin(a[i]) * cos(a[i]); }    // L2 trig
    for (int i = 0; i < N; i++) {                                    // L3 dense
        d[i] = sin(a[i]) * cos(b[i]) + sqrt(a[i] * a[i] + b[i] * b[i] + 1.0);
    }
    for (int i = 0; i < N; i++) { acc += d[i]; }                     // L4 reduce
    for (int i = 0; i < N; i++) { audit(); }                         // L5 blocked
    return 0;
}"#;

    fn run_funnel(cfg: &SearchConfig) -> (Vec<Candidate>, FunnelTrace) {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        run(&prog, &an, cfg, &ARRIA10_GX).unwrap()
    }

    #[test]
    fn funnel_stage_sizes_match_config() {
        let cfg = SearchConfig {
            top_a: 4,
            top_c: 2,
            first_round: 2,
            max_patterns: 3,
            ..Default::default()
        };
        let (cands, trace) = run_funnel(&cfg);
        assert_eq!(trace.total_loops, 6);
        assert_eq!(trace.offloadable.len(), 5); // L5 blocked
        assert_eq!(trace.top_a.len(), 4);
        assert_eq!(cands.len(), 2);
        assert_eq!(trace.top_c.len(), 2);
    }

    #[test]
    fn blocked_loop_never_survives() {
        let (cands, trace) = run_funnel(&SearchConfig::default());
        assert!(!trace.offloadable.contains(&LoopId(5)));
        assert!(cands.iter().all(|c| c.loop_id() != LoopId(5)));
    }

    #[test]
    fn survivors_sorted_by_efficiency() {
        let (cands, _) = run_funnel(&SearchConfig::default());
        for w in cands.windows(2) {
            assert!(
                w[0].report.resource_efficiency
                    >= w[1].report.resource_efficiency
            );
        }
    }

    #[test]
    fn trig_loops_reach_top() {
        let (cands, _) = run_funnel(&SearchConfig::default());
        let ids: Vec<LoopId> = cands.iter().map(Candidate::loop_id).collect();
        assert!(
            ids.contains(&LoopId(2)) || ids.contains(&LoopId(3)),
            "{ids:?}"
        );
    }

    #[test]
    fn unroll_factor_applied() {
        let cfg = SearchConfig {
            unroll: 4,
            ..Default::default()
        };
        let (cands, _) = run_funnel(&cfg);
        assert!(cands.iter().all(|c| c.split.kernel.unroll == 4));
    }

    #[test]
    fn claimed_loops_never_enter_the_funnel() {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let claimed: std::collections::BTreeSet<LoopId> =
            [LoopId(2), LoopId(3)].into_iter().collect();
        let (cands, trace) = run_excluding(
            &prog,
            &an,
            &SearchConfig::default(),
            &ARRIA10_GX,
            &claimed,
        )
        .unwrap();
        assert!(trace.offloadable.iter().all(|l| !claimed.contains(l)));
        assert!(trace.top_a.iter().all(|l| !claimed.contains(l)));
        assert!(cands.iter().all(|c| !claimed.contains(&c.loop_id())));
        // The unclaimed loops still funnel normally.
        assert!(!cands.is_empty());
    }

    #[test]
    fn no_candidates_is_error() {
        let src = r#"void log_x() { }
int main() { for (int i = 0; i < 4; i++) { log_x(); } return 0; }"#;
        let prog = parse(src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let err = run(&prog, &an, &SearchConfig::default(), &ARRIA10_GX);
        assert!(matches!(err, Err(FunnelError::NoCandidates)));
    }
}
