//! Offload-pattern generation (paper §4).
//!
//! Round 1: single-loop patterns for the top-C candidates ("in the first
//! measurement, the implementation generates patterns within D").
//! Round 2: combinations of the singles that actually accelerated ("if #1
//! and #3 offloading can be accelerated, the implementation generates a
//! pattern with both #1 and #3 offloaded"), skipping combinations whose
//! summed resources exceed the device ("if it does not fit within the
//! upper limit, the combination pattern is not generated") and pairs of
//! loops that nest one another.

use crate::analysis::Analysis;
use crate::fpga::subtree_ids;
use crate::hls::{Device, ResourceEstimate};
use crate::minic::ast::LoopId;

use super::config::SearchConfig;
use super::funnel::Candidate;

/// A pattern: indices into the candidate list.
pub type Pattern = Vec<usize>;

/// Round-1 single-loop patterns (at most `first_round`).
pub fn singles(cands: &[Candidate], cfg: &SearchConfig) -> Vec<Pattern> {
    (0..cands.len().min(cfg.first_round)).map(|i| vec![i]).collect()
}

/// Round-2 combination patterns.
///
/// `accelerated` holds (candidate index, measured speedup) for the singles
/// that beat the CPU. Combinations are ranked by the sum of their parts'
/// speedups (the greedy prior: combine the best) and truncated to the
/// remaining measurement budget.
pub fn combinations(
    cands: &[Candidate],
    accelerated: &[(usize, f64)],
    analysis: &Analysis,
    cfg: &SearchConfig,
    dev: &Device,
    budget: usize,
) -> Vec<Pattern> {
    if accelerated.len() < 2 || budget == 0 {
        return Vec::new();
    }
    let idxs: Vec<usize> = accelerated.iter().map(|(i, _)| *i).collect();
    let mut combos: Vec<(f64, Pattern)> = Vec::new();

    // All subsets of size >= 2 (accelerated set is tiny: <= top_c).
    let n = idxs.len();
    for mask in 1u32..(1 << n) {
        if mask.count_ones() < 2 {
            continue;
        }
        let subset: Pattern = (0..n)
            .filter(|b| mask & (1 << b) != 0)
            .map(|b| idxs[b])
            .collect();
        if !disjoint(&subset, cands, analysis) {
            continue;
        }
        if !fits(&subset, cands, dev, cfg.resource_cap) {
            continue;
        }
        let score: f64 = subset
            .iter()
            .map(|i| {
                accelerated
                    .iter()
                    .find(|(j, _)| j == i)
                    .map(|(_, s)| *s)
                    .unwrap_or(0.0)
            })
            .sum();
        combos.push((score, subset));
    }

    combos.sort_by(|a, b| {
        b.0.partial_cmp(&a.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.1.len().cmp(&b.1.len()))
    });
    combos.into_iter().take(budget).map(|(_, p)| p).collect()
}

/// No loop in the pattern may be nested inside another.
pub fn disjoint(
    pattern: &[usize],
    cands: &[Candidate],
    analysis: &Analysis,
) -> bool {
    let ids: Vec<LoopId> = pattern.iter().map(|&i| cands[i].loop_id()).collect();
    for &i in pattern {
        let sub = subtree_ids(analysis, cands[i].loop_id());
        for id in &ids {
            if *id != cands[i].loop_id() && sub.contains(id) {
                return false;
            }
        }
    }
    true
}

/// Combined estimate fits under the resource cap.
pub fn fits(
    pattern: &[usize],
    cands: &[Candidate],
    dev: &Device,
    cap: f64,
) -> bool {
    let combined = pattern
        .iter()
        .map(|&i| cands[i].report.estimate)
        .fold(ResourceEstimate::default(), |acc, e| acc.add(&e));
    combined.utilization(dev).max() <= cap
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::hls::ARRIA10_GX;
    use crate::minic::parse;
    use crate::search::funnel;

    const SRC: &str = "
#define N 512
float a[N]; float b[N]; float c[N]; float d[N];
int main() {
    for (int i = 0; i < N; i++) { b[i] = sin(a[i]) + 1.0; }   // L0
    for (int i = 0; i < N; i++) { c[i] = cos(a[i]) * 2.0; }   // L1
    for (int i = 0; i < N; i++) { d[i] = sqrt(a[i] + 4.0); }  // L2
    return 0;
}";

    fn setup() -> (Vec<Candidate>, Analysis) {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let (cands, _) =
            funnel::run(&prog, &an, &SearchConfig::default(), &ARRIA10_GX)
                .unwrap();
        (cands, an)
    }

    #[test]
    fn singles_respect_first_round() {
        let (cands, _) = setup();
        let cfg = SearchConfig {
            first_round: 2,
            max_patterns: 3,
            top_c: 3,
            ..Default::default()
        };
        let s = singles(&cands, &cfg);
        assert_eq!(s, vec![vec![0], vec![1]]);
    }

    #[test]
    fn combos_require_two_accelerated() {
        let (cands, an) = setup();
        let cfg = SearchConfig::default();
        let combos = combinations(
            &cands,
            &[(0, 2.0)],
            &an,
            &cfg,
            &ARRIA10_GX,
            4,
        );
        assert!(combos.is_empty());
    }

    #[test]
    fn combos_ranked_and_budgeted() {
        let (cands, an) = setup();
        let cfg = SearchConfig::default();
        let acc = [(0usize, 3.0), (1usize, 2.0), (2usize, 1.5)];
        let combos =
            combinations(&cands, &acc, &an, &cfg, &ARRIA10_GX, 1);
        assert_eq!(combos.len(), 1);
        // Best combo should include the two highest-speedup singles, or
        // all three if it scores higher (sum 6.5 > 5.0) and fits.
        assert!(combos[0].contains(&0));
        assert!(combos[0].len() >= 2);
    }

    #[test]
    fn zero_budget_no_combos() {
        let (cands, an) = setup();
        let cfg = SearchConfig::default();
        let acc = [(0usize, 3.0), (1usize, 2.0)];
        assert!(
            combinations(&cands, &acc, &an, &cfg, &ARRIA10_GX, 0)
                .is_empty()
        );
    }

    #[test]
    fn nested_loops_not_combined() {
        let src = "
#define N 256
float a[N]; float b[N];
int main() {
    for (int r = 0; r < 8; r++) {                       // L0
        for (int i = 0; i < N; i++) {                   // L1 nested in L0
            b[i] = sin(a[i]) * cos(a[i]);
        }
    }
    return 0;
}";
        let prog = parse(src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let (cands, _) =
            funnel::run(&prog, &an, &SearchConfig::default(), &ARRIA10_GX)
                .unwrap();
        // If both L0 and L1 survive the funnel, they must not combine.
        if cands.len() >= 2 {
            let acc: Vec<(usize, f64)> =
                (0..cands.len()).map(|i| (i, 2.0)).collect();
            let combos = combinations(
                &cands,
                &acc,
                &an,
                &SearchConfig::default(),
                &ARRIA10_GX,
                4,
            );
            assert!(combos.is_empty(), "{combos:?}");
        }
    }

    #[test]
    fn resource_cap_prunes() {
        let (cands, an) = setup();
        let cfg = SearchConfig {
            resource_cap: 0.000_001, // nothing fits together
            ..Default::default()
        };
        let acc = [(0usize, 2.0), (1usize, 2.0)];
        assert!(
            combinations(&cands, &acc, &an, &cfg, &ARRIA10_GX, 4)
                .is_empty()
        );
    }
}
