//! Measurement backends: the seam between the search funnel and the
//! destination hardware.
//!
//! The paper's verification environment measures every offload pattern on
//! one hard-wired destination (a server with an Arria10 FPGA). The
//! follow-on evaluations the ROADMAP names need more: many applications
//! per automation cycle (arXiv:2002.09541) and mixed destinations —
//! FPGA, GPU, many-core — per environment (arXiv:2011.12431). The
//! [`Backend`] trait carries exactly the destination-specific
//! operations of the Fig.-1 flow:
//!
//! * [`Backend::measure`] — step 4: performance-measure one offload
//!   pattern (simulation + compile-time model here; a real toolchain
//!   invocation in production).
//! * [`Backend::verify`] — step 4: functionally verify the offloaded
//!   program against the unmodified baseline.
//! * [`Backend::deploy_check`] — step 6: the production deployment
//!   check (the PJRT sample test for destinations that have real
//!   artifacts).
//! * [`Backend::price_block`] — the function-block path's per-
//!   destination pricing hook (arXiv:2004.09883).
//!
//! Implementations: [`FpgaBackend`] (the paper's path), [`GpuBackend`]
//! (the mixed-environment board, measured by [`crate::gpu::sim`]),
//! [`OmpBackend`] (the many-core fourth destination, measured by
//! [`crate::cpu::omp`]) and [`CpuBaseline`] (a control destination that
//! offloads nothing — the all-CPU denominator as a first-class backend).
//!
//! Backends are `Sync`: the verification environment's worker pool and
//! the batch orchestrator share one backend across threads.

use crate::analysis::Analysis;
use crate::cpu::{omp, CpuModel, OmpDevice};
use crate::fpga::{self, verify_pattern_with, PatternTiming};
use crate::funcblock::{BlockCost, Catalog, ConfirmedBlock};
use crate::gpu::{self, GpuDevice};
use crate::hls::{full_compile_seconds, Device, ResourceEstimate};
use crate::minic::Program;
use crate::runtime::{self, Artifacts, Runtime, SampleRun};

use super::config::SearchConfig;
use super::funnel::Candidate;
use super::measure::SearchError;
use super::patterns::Pattern;

/// What a backend reports for one measured pattern.
#[derive(Debug, Clone)]
pub struct BackendMeasurement {
    pub timing: PatternTiming,
    /// Modeled full-compile wall clock, seconds (0 when the destination
    /// needs no compile).
    pub compile_s: f64,
}

/// A measurement/verification/deployment destination (see module docs).
pub trait Backend: Sync {
    /// Short identifier used in reports and CLI flags ("fpga", "gpu",
    /// "omp", "cpu").
    fn name(&self) -> &'static str;

    /// The device whose resource model narrows the funnel (pre-compile
    /// estimates are destination-specific even when execution is not).
    fn device(&self) -> &Device;

    /// Name of the physical destination a plan is measured for — part of
    /// the pattern-DB reuse key, so a plan searched for one board is
    /// never replayed on another. Defaults to the funnel device's name;
    /// backends whose funnel device is only a stand-in (the GPU narrows
    /// with the FPGA resource model to keep candidate sets comparable)
    /// must override it.
    fn destination(&self) -> &'static str {
        self.device().name
    }

    /// Step 4: performance-measure one offload pattern.
    fn measure(
        &self,
        prog: &Program,
        analysis: &Analysis,
        cands: &[Candidate],
        pattern: &Pattern,
        cfg: &SearchConfig,
    ) -> Result<BackendMeasurement, SearchError>;

    /// Step 4: functionally verify the offloaded program against the
    /// unmodified baseline, both running `entry` — the same entry the
    /// profiling run used, never a hard-coded `main`.
    fn verify(
        &self,
        prog: &Program,
        cands: &[Candidate],
        pattern: &Pattern,
        entry: &str,
        cfg: &SearchConfig,
    ) -> Result<bool, SearchError>;

    /// Step 6: production deployment check — run the application's
    /// sample test on the real stack.
    fn deploy_check(
        &self,
        sample: &str,
        env: (&Runtime, &Artifacts),
        seed: u64,
    ) -> anyhow::Result<SampleRun>;

    /// Price one confirmed function block for this destination: naive
    /// CPU time of the claimed nest vs the destination's catalogued IP
    /// core / library (compute + transfers + build). `None` means the
    /// destination has no block support — the planner then leaves the
    /// block's loops to the ordinary loop funnel.
    fn price_block(
        &self,
        _block: &ConfirmedBlock,
        _catalog: &Catalog,
    ) -> Option<BlockCost> {
        None
    }
}

/// A [`Backend`] decorator that wraps each backend-facing call in an
/// observability span (`backend.measure` / `backend.verify` /
/// `backend.deploy`), picking the trace context up from the thread.
/// Used on the *unretried* pipeline path; the retry wrapper
/// ([`RetryingBackend`](super::RetryingBackend)) emits the same spans
/// itself, with per-attempt children, so the two are never stacked.
pub struct TracedBackend<'a> {
    inner: &'a dyn Backend,
}

impl<'a> TracedBackend<'a> {
    pub fn new(inner: &'a dyn Backend) -> Self {
        TracedBackend { inner }
    }
}

impl Backend for TracedBackend<'_> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn device(&self) -> &Device {
        self.inner.device()
    }

    fn destination(&self) -> &'static str {
        self.inner.destination()
    }

    fn measure(
        &self,
        prog: &Program,
        analysis: &Analysis,
        cands: &[Candidate],
        pattern: &Pattern,
        cfg: &SearchConfig,
    ) -> Result<BackendMeasurement, SearchError> {
        let _span = crate::obs::span("backend.measure");
        self.inner.measure(prog, analysis, cands, pattern, cfg)
    }

    fn verify(
        &self,
        prog: &Program,
        cands: &[Candidate],
        pattern: &Pattern,
        entry: &str,
        cfg: &SearchConfig,
    ) -> Result<bool, SearchError> {
        let _span = crate::obs::span("backend.verify");
        self.inner.verify(prog, cands, pattern, entry, cfg)
    }

    fn deploy_check(
        &self,
        sample: &str,
        env: (&Runtime, &Artifacts),
        seed: u64,
    ) -> anyhow::Result<SampleRun> {
        let _span = crate::obs::span("backend.deploy");
        self.inner.deploy_check(sample, env, seed)
    }

    fn price_block(
        &self,
        block: &ConfirmedBlock,
        catalog: &Catalog,
    ) -> Option<BlockCost> {
        self.inner.price_block(block, catalog)
    }
}

/// The paper's destination: Arria10-class FPGA measured by the cycle /
/// transfer simulator, verified by outlined-kernel interpretation, and
/// deploy-checked by the PJRT sample test.
#[derive(Debug, Clone, Copy)]
pub struct FpgaBackend<'a> {
    pub cpu: &'a CpuModel,
    pub device: &'a Device,
}

impl Backend for FpgaBackend<'_> {
    fn name(&self) -> &'static str {
        "fpga"
    }

    fn device(&self) -> &Device {
        self.device
    }

    fn measure(
        &self,
        _prog: &Program,
        analysis: &Analysis,
        cands: &[Candidate],
        pattern: &Pattern,
        _cfg: &SearchConfig,
    ) -> Result<BackendMeasurement, SearchError> {
        let kernels: Vec<_> = pattern
            .iter()
            .map(|&i| cands[i].split.kernel.clone())
            .collect();
        let timing = fpga::simulate(analysis, &kernels, self.cpu, self.device)
            .map_err(SearchError::Sim)?;
        let combined = pattern
            .iter()
            .map(|&i| cands[i].report.estimate)
            .fold(ResourceEstimate::default(), |acc, e| acc.add(&e));
        let compile_s = full_compile_seconds(&combined, self.device);
        Ok(BackendMeasurement { timing, compile_s })
    }

    fn verify(
        &self,
        prog: &Program,
        cands: &[Candidate],
        pattern: &Pattern,
        entry: &str,
        cfg: &SearchConfig,
    ) -> Result<bool, SearchError> {
        let splits: Vec<_> = pattern
            .iter()
            .map(|&i| cands[i].split.clone())
            .collect();
        let v = verify_pattern_with(prog, &splits, entry, cfg.engine)
            .map_err(SearchError::Interp)?;
        Ok(v.passed)
    }

    fn deploy_check(
        &self,
        sample: &str,
        env: (&Runtime, &Artifacts),
        seed: u64,
    ) -> anyhow::Result<SampleRun> {
        let (rt, art) = env;
        runtime::run_app(rt, art, sample, seed)
    }

    /// FPGA IP-core pricing: the catalogued core is a hand-optimized
    /// spatial engine (`lanes` parallel ops at a closed `fmax`), not the
    /// auto-generated OpenCL the funnel measures — that asymmetry is the
    /// whole point of the function-block path. Transfers still cross
    /// PCIe once per block invocation.
    fn price_block(
        &self,
        block: &ConfirmedBlock,
        catalog: &Catalog,
    ) -> Option<BlockCost> {
        let core = &catalog.spec(block.kind).fpga;
        let fill_s = (block.entries * core.depth) as f64 / core.fmax_hz;
        let throughput_s = block.inner_units.div_ceil(core.lanes) as f64
            / core.fmax_hz;
        let xfer_s = block.entries as f64
            * fpga::launch_overhead(
                self.device,
                block.bytes_in,
                block.bytes_out,
            );
        Some(BlockCost {
            cpu_s: self.cpu.time(&block.ops),
            accel_s: fill_s + throughput_s + xfer_s,
            build_s: core.build_seconds,
        })
    }
}

/// The mixed-environment GPU destination (ROADMAP / arXiv:2011.12431):
/// measured by the [`crate::gpu::sim`] occupancy/roofline model, verified
/// by the same outlined-kernel interpretation as every destination, and
/// deploy-checked by the PJRT sample test. The funnel narrows with the
/// FPGA resource model (`device`) so all destinations rank the *same*
/// candidate set and the mixed-destination selector compares like with
/// like.
#[derive(Debug, Clone, Copy)]
pub struct GpuBackend<'a> {
    pub cpu: &'a CpuModel,
    pub gpu: &'a GpuDevice,
    /// Funnel-narrowing device model only; the destination is `gpu`.
    pub device: &'a Device,
}

impl Backend for GpuBackend<'_> {
    fn name(&self) -> &'static str {
        "gpu"
    }

    fn device(&self) -> &Device {
        self.device
    }

    fn destination(&self) -> &'static str {
        self.gpu.name
    }

    fn measure(
        &self,
        _prog: &Program,
        analysis: &Analysis,
        cands: &[Candidate],
        pattern: &Pattern,
        _cfg: &SearchConfig,
    ) -> Result<BackendMeasurement, SearchError> {
        let kernels: Vec<_> = pattern
            .iter()
            .map(|&i| cands[i].split.kernel.clone())
            .collect();
        let timing = gpu::simulate(analysis, &kernels, self.cpu, self.gpu)
            .map_err(SearchError::Sim)?;
        // No place-and-route on this destination: the build is an
        // nvcc/OpenACC compile, minutes not hours.
        Ok(BackendMeasurement {
            timing,
            compile_s: self.gpu.build_seconds,
        })
    }

    fn verify(
        &self,
        prog: &Program,
        cands: &[Candidate],
        pattern: &Pattern,
        entry: &str,
        cfg: &SearchConfig,
    ) -> Result<bool, SearchError> {
        let splits: Vec<_> = pattern
            .iter()
            .map(|&i| cands[i].split.clone())
            .collect();
        let v = verify_pattern_with(prog, &splits, entry, cfg.engine)
            .map_err(SearchError::Interp)?;
        Ok(v.passed)
    }

    fn deploy_check(
        &self,
        sample: &str,
        env: (&Runtime, &Artifacts),
        seed: u64,
    ) -> anyhow::Result<SampleRun> {
        let (rt, art) = env;
        runtime::run_app(rt, art, sample, seed)
    }

    /// GPU library pricing: the vendor library sustains the catalog's
    /// `efficiency` fraction of peak ALU throughput (vs the much lower
    /// `auto_efficiency` the auto-generated kernels reach), bounded by
    /// device-memory bandwidth, plus per-invocation PCIe transfers.
    fn price_block(
        &self,
        block: &ConfirmedBlock,
        catalog: &Catalog,
    ) -> Option<BlockCost> {
        let lib = &catalog.spec(block.kind).gpu;
        let issue = self.gpu.issue_cycles(&block.ops);
        let throughput_s = issue
            / (self.gpu.cores() as f64 * lib.efficiency * self.gpu.clock_hz);
        let mem_s = block.ops.bytes() as f64 / self.gpu.mem_bytes_per_sec;
        let xfer_s = block.entries as f64
            * self.gpu.launch_overhead(block.bytes_in, block.bytes_out);
        Some(BlockCost {
            cpu_s: self.cpu.time(&block.ops),
            accel_s: throughput_s.max(mem_s) + xfer_s,
            build_s: lib.build_seconds,
        })
    }
}

/// The many-core fourth destination (ROADMAP / arXiv:2011.12431):
/// OpenMP parallel regions on a shared-memory Xeon, measured by the
/// [`crate::cpu::omp`] fork-join/bandwidth model, verified by the same
/// outlined-kernel interpretation as every destination. Like the GPU,
/// the funnel narrows with the FPGA resource model (`device`) so all
/// destinations rank the *same* candidate set; unlike the GPU, a
/// pattern pays no PCIe at all and the destination build is seconds of
/// `gcc -fopenmp`.
#[derive(Debug, Clone, Copy)]
pub struct OmpBackend<'a> {
    pub cpu: &'a CpuModel,
    pub omp: &'a OmpDevice,
    /// Funnel-narrowing device model only; the destination is `omp`.
    pub device: &'a Device,
}

impl Backend for OmpBackend<'_> {
    fn name(&self) -> &'static str {
        "omp"
    }

    fn device(&self) -> &Device {
        self.device
    }

    fn destination(&self) -> &'static str {
        self.omp.name
    }

    fn measure(
        &self,
        _prog: &Program,
        analysis: &Analysis,
        cands: &[Candidate],
        pattern: &Pattern,
        _cfg: &SearchConfig,
    ) -> Result<BackendMeasurement, SearchError> {
        let kernels: Vec<_> = pattern
            .iter()
            .map(|&i| cands[i].split.kernel.clone())
            .collect();
        let timing = omp::simulate(analysis, &kernels, self.cpu, self.omp)
            .map_err(SearchError::Sim)?;
        // The destination build is a gcc -fopenmp compile: seconds, so
        // a many-core automation cycle is essentially free.
        Ok(BackendMeasurement {
            timing,
            compile_s: self.omp.build_seconds,
        })
    }

    fn verify(
        &self,
        prog: &Program,
        cands: &[Candidate],
        pattern: &Pattern,
        entry: &str,
        cfg: &SearchConfig,
    ) -> Result<bool, SearchError> {
        let splits: Vec<_> = pattern
            .iter()
            .map(|&i| cands[i].split.clone())
            .collect();
        let v = verify_pattern_with(prog, &splits, entry, cfg.engine)
            .map_err(SearchError::Interp)?;
        Ok(v.passed)
    }

    fn deploy_check(
        &self,
        sample: &str,
        env: (&Runtime, &Artifacts),
        seed: u64,
    ) -> anyhow::Result<SampleRun> {
        let (rt, art) = env;
        runtime::run_app(rt, art, sample, seed)
    }

    /// Many-core block pricing, [`crate::funcblock::CpuLibModel`]-based
    /// so replacements compete fairly with the FPGA core and the GPU
    /// library: the catalog's tuned-CPU factor over the naive nest,
    /// spread across the parallel lanes, floored by the shared memory
    /// bandwidth, plus one fork/join per block invocation.
    fn price_block(
        &self,
        block: &ConfirmedBlock,
        catalog: &Catalog,
    ) -> Option<BlockCost> {
        let lib = &catalog.spec(block.kind).cpu;
        let cpu_s = self.cpu.time(&block.ops);
        let tuned_s = cpu_s / lib.speedup.max(f64::MIN_POSITIVE);
        let compute_s = tuned_s / self.omp.parallel_lanes();
        let mem_s = block.ops.bytes() as f64 / self.omp.mem_bytes_per_sec;
        let fork_s = block.entries as f64 * self.omp.fork_join_s;
        Some(BlockCost {
            cpu_s,
            accel_s: compute_s.max(mem_s) + fork_s,
            build_s: self.omp.build_seconds,
        })
    }
}

/// Control destination: nothing is offloaded, every pattern runs at the
/// all-CPU baseline (speedup exactly 1.0, no compile time). Useful as the
/// denominator in mixed-destination comparisons and as a cheap smoke
/// backend for batch runs. Verification still exercises the real
/// codegen: the outlined host program must match the baseline
/// program numerically even when its kernels run on the CPU.
#[derive(Debug, Clone, Copy)]
pub struct CpuBaseline<'a> {
    pub cpu: &'a CpuModel,
    /// Device model used only to narrow the funnel, so candidate sets
    /// stay comparable with destination backends.
    pub device: &'a Device,
}

impl Backend for CpuBaseline<'_> {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn device(&self) -> &Device {
        self.device
    }

    fn destination(&self) -> &'static str {
        // The funnel device is only a stand-in; nothing leaves the CPU.
        self.cpu.name
    }

    fn measure(
        &self,
        _prog: &Program,
        analysis: &Analysis,
        _cands: &[Candidate],
        _pattern: &Pattern,
        _cfg: &SearchConfig,
    ) -> Result<BackendMeasurement, SearchError> {
        let cpu_baseline_s = self.cpu.time(&analysis.profile.total);
        Ok(BackendMeasurement {
            timing: PatternTiming {
                cpu_baseline_s,
                cpu_rest_s: cpu_baseline_s,
                loops: Vec::new(),
                pattern_s: cpu_baseline_s,
                speedup: 1.0,
                combined: ResourceEstimate::default(),
            },
            compile_s: 0.0,
        })
    }

    fn verify(
        &self,
        prog: &Program,
        cands: &[Candidate],
        pattern: &Pattern,
        entry: &str,
        cfg: &SearchConfig,
    ) -> Result<bool, SearchError> {
        let splits: Vec<_> = pattern
            .iter()
            .map(|&i| cands[i].split.clone())
            .collect();
        let v = verify_pattern_with(prog, &splits, entry, cfg.engine)
            .map_err(SearchError::Interp)?;
        Ok(v.passed)
    }

    fn deploy_check(
        &self,
        sample: &str,
        _env: (&Runtime, &Artifacts),
        _seed: u64,
    ) -> anyhow::Result<SampleRun> {
        anyhow::bail!(
            "cpu baseline backend has no production deployment for {sample:?}"
        )
    }

    /// CPU-library pricing: the catalog's tuned-library factor over the
    /// naive nest. The bundled catalog keeps that factor at 1.0 so this
    /// destination stays the paper's exact all-CPU denominator — the
    /// planner then finds no strict profit and leaves the block alone.
    fn price_block(
        &self,
        block: &ConfirmedBlock,
        catalog: &Catalog,
    ) -> Option<BlockCost> {
        let lib = &catalog.spec(block.kind).cpu;
        let cpu_s = self.cpu.time(&block.ops);
        Some(BlockCost {
            cpu_s,
            accel_s: cpu_s / lib.speedup.max(f64::MIN_POSITIVE),
            build_s: 0.0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::cpu::XEON_BRONZE_3104;
    use crate::hls::ARRIA10_GX;
    use crate::minic::parse;
    use crate::search::funnel;

    const SRC: &str = "
#define N 2048
float a[N]; float out[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.001 - 1.0; }
    for (int i = 0; i < N; i++) { out[i] = sin(a[i]) * cos(a[i]); }
    return 0;
}";

    fn setup() -> (crate::minic::Program, Analysis, Vec<Candidate>) {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let (cands, _trace) =
            funnel::run(&prog, &an, &SearchConfig::default(), &ARRIA10_GX)
                .unwrap();
        (prog, an, cands)
    }

    #[test]
    fn fpga_backend_measures_and_verifies() {
        let (prog, an, cands) = setup();
        let b = FpgaBackend {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let cfg = SearchConfig::default();
        let m = b.measure(&prog, &an, &cands, &vec![0], &cfg).unwrap();
        assert!(m.timing.speedup > 0.0);
        assert!(m.compile_s > 0.0);
        assert!(b.verify(&prog, &cands, &vec![0], "main", &cfg).unwrap());
    }

    #[test]
    fn gpu_backend_measures_and_verifies() {
        let (prog, an, cands) = setup();
        let b = GpuBackend {
            cpu: &XEON_BRONZE_3104,
            gpu: &crate::gpu::TESLA_T4,
            device: &ARRIA10_GX,
        };
        let cfg = SearchConfig::default();
        let m = b.measure(&prog, &an, &cands, &vec![0], &cfg).unwrap();
        assert!(m.timing.speedup > 0.0);
        // GPU builds are minutes (nvcc), not the FPGA's hours.
        assert!(m.compile_s > 0.0);
        assert!(m.compile_s < 3600.0);
        assert!(b.verify(&prog, &cands, &vec![0], "main", &cfg).unwrap());
        assert_eq!(b.name(), "gpu");
        assert_eq!(b.destination(), crate::gpu::TESLA_T4.name);
    }

    #[test]
    fn omp_backend_measures_and_verifies() {
        let (prog, an, cands) = setup();
        let b = OmpBackend {
            cpu: &XEON_BRONZE_3104,
            omp: &crate::cpu::XEON_GOLD_6130,
            device: &ARRIA10_GX,
        };
        let cfg = SearchConfig::default();
        let m = b.measure(&prog, &an, &cands, &vec![0], &cfg).unwrap();
        assert!(m.timing.speedup > 0.0);
        // OpenMP builds are gcc seconds — below even the GPU's nvcc
        // minutes, and nowhere near the FPGA's hours.
        assert!(m.compile_s > 0.0);
        assert!(m.compile_s < 60.0);
        assert!(b.verify(&prog, &cands, &vec![0], "main", &cfg).unwrap());
        assert_eq!(b.name(), "omp");
        assert_eq!(b.destination(), crate::cpu::XEON_GOLD_6130.name);
    }

    #[test]
    fn cpu_baseline_is_exactly_one_x() {
        let (prog, an, cands) = setup();
        let b = CpuBaseline {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let cfg = SearchConfig::default();
        let m = b.measure(&prog, &an, &cands, &vec![0], &cfg).unwrap();
        assert_eq!(m.timing.speedup, 1.0);
        assert_eq!(m.compile_s, 0.0);
        assert_eq!(m.timing.cpu_baseline_s, m.timing.pattern_s);
        assert!(b.verify(&prog, &cands, &vec![0], "main", &cfg).unwrap());
    }

    #[test]
    fn verify_runs_the_requested_entry() {
        // A program whose loops live under a non-`main` entry: with the
        // old hard-coded "main" this verified the wrong function (or
        // failed outright when no `main` existed).
        const ENTRY_SRC: &str = "
#define N 256
float a[N]; float out[N];
int compute() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.01 - 1.0; }
    for (int i = 0; i < N; i++) { out[i] = sin(a[i]) * 2.0; }
    return 0;
}";
        let prog = parse(ENTRY_SRC).unwrap();
        let an = analyze(&prog, "compute").unwrap();
        let (cands, _trace) =
            funnel::run(&prog, &an, &SearchConfig::default(), &ARRIA10_GX)
                .unwrap();
        let b = FpgaBackend {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let cfg = SearchConfig::default();
        assert!(b
            .verify(&prog, &cands, &vec![0], "compute", &cfg)
            .unwrap());
        // The old behavior is now an explicit error, not a silent wrong
        // answer: "main" does not exist in this program.
        assert!(b.verify(&prog, &cands, &vec![0], "main", &cfg).is_err());
    }

    #[test]
    fn block_pricing_per_destination() {
        use crate::funcblock::{find_blocks, BlockKind};
        use crate::minic::EngineKind;

        let prog = parse(crate::workloads::TDFIR_C).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let catalog = Catalog::builtin();
        let blocks =
            find_blocks(&prog, &an, &catalog, EngineKind::default(), 42);
        let fir = blocks
            .iter()
            .find(|b| b.kind == BlockKind::Fir)
            .expect("tdfir fir bank");

        let f = FpgaBackend {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let g = GpuBackend {
            cpu: &XEON_BRONZE_3104,
            gpu: &crate::gpu::TESLA_T4,
            device: &ARRIA10_GX,
        };
        let c = CpuBaseline {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };

        // The hand-optimized FPGA core demolishes the naive nest.
        let pf = f.price_block(fir, &catalog).unwrap();
        assert!(pf.profitable(), "{pf:?}");
        assert!(pf.accel_s < pf.cpu_s / 10.0, "{pf:?}");
        assert!(pf.build_s > 0.0);

        // The GPU library wins too (different arithmetic, same block).
        let pg = g.price_block(fir, &catalog).unwrap();
        assert!(pg.profitable(), "{pg:?}");
        assert_eq!(pg.cpu_s, pf.cpu_s);

        // The many-core destination profits as well — the catalog's CPU
        // library factor spread across the OpenMP lanes — but never by
        // more than the lane count allows.
        let o = OmpBackend {
            cpu: &XEON_BRONZE_3104,
            omp: &crate::cpu::XEON_GOLD_6130,
            device: &ARRIA10_GX,
        };
        let po = o.price_block(fir, &catalog).unwrap();
        assert!(po.profitable(), "{po:?}");
        assert_eq!(po.cpu_s, pf.cpu_s);
        assert!(
            po.cpu_s / po.accel_s
                <= crate::cpu::XEON_GOLD_6130.parallel_lanes() + 1e-9,
            "{po:?}"
        );
        assert!(po.build_s < pg.build_s);

        // The control destination never strictly profits (library
        // factor 1.0): blocks stay un-replaced and the backend stays
        // the exact all-CPU denominator.
        let pc = c.price_block(fir, &catalog).unwrap();
        assert!(!pc.profitable(), "{pc:?}");
        assert_eq!(pc.accel_s, pc.cpu_s);
    }

    #[test]
    fn backend_names_and_destinations_are_distinct() {
        let f = FpgaBackend {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let g = GpuBackend {
            cpu: &XEON_BRONZE_3104,
            gpu: &crate::gpu::TESLA_T4,
            device: &ARRIA10_GX,
        };
        let o = OmpBackend {
            cpu: &XEON_BRONZE_3104,
            omp: &crate::cpu::XEON_GOLD_6130,
            device: &ARRIA10_GX,
        };
        let c = CpuBaseline {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let names = [f.name(), g.name(), o.name(), c.name()];
        for i in 0..names.len() {
            for j in (i + 1)..names.len() {
                assert_ne!(names[i], names[j]);
            }
        }
        // All four narrow the funnel with the same device model, but
        // their *destinations* (the pattern-DB key) differ.
        assert_eq!(f.device().name, c.device().name);
        assert_eq!(f.device().name, g.device().name);
        assert_eq!(f.device().name, o.device().name);
        assert_eq!(f.destination(), ARRIA10_GX.name);
        assert_eq!(g.destination(), crate::gpu::TESLA_T4.name);
        assert_eq!(o.destination(), crate::cpu::XEON_GOLD_6130.name);
        assert_eq!(c.destination(), XEON_BRONZE_3104.name);
        // The many-core board is not the baseline core: plans for one
        // must never be replayed on the other.
        assert_ne!(o.destination(), c.destination());
    }
}
