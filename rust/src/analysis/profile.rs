//! Profiled analysis runs: execute the application under an instrumented
//! engine (the gcov analog) and join dynamic stats with the static
//! loop table into the [`AnalyzedLoop`] records the funnel consumes.
//!
//! The profiling run is the pipeline's dominant wall-clock cost, so it
//! executes on the bytecode VM by default; pass
//! [`EngineKind::TreeWalk`] to [`analyze_with`] to profile under the
//! tree-walking oracle instead (the two are differentially tested to
//! produce identical profiles).

use std::collections::BTreeSet;

use crate::minic::ast::{LoopId, Stmt};
use crate::minic::{
    EngineKind, MiniCError, OpReport, Profile, Program, ResolveOpts, Vm,
};

use super::depend::{classify, Dependence};
use super::intensity::{rank, LoopIntensity};
use super::loopinfo::{extract, LoopInfo};

/// Everything the offload pipeline knows about one loop.
#[derive(Debug, Clone)]
pub struct AnalyzedLoop {
    pub info: LoopInfo,
    pub dependence: Dependence,
    /// None when the loop never executed in the profiling run.
    pub intensity: Option<LoopIntensity>,
}

impl AnalyzedLoop {
    pub fn id(&self) -> LoopId {
        self.info.id
    }

    /// Candidate for offload: statically offloadable AND observed hot.
    pub fn candidate(&self) -> bool {
        self.info.offloadable() && self.intensity.is_some()
    }
}

/// Result of a full analysis pass.
#[derive(Debug, Clone)]
pub struct Analysis {
    pub loops: Vec<AnalyzedLoop>,
    pub profile: Profile,
    /// Entry function the profiling run executed. Verification must run
    /// the *same* entry — a pattern profiled under `compute()` proves
    /// nothing when verified against `main()`.
    pub entry: String,
}

impl Analysis {
    pub fn loop_by_id(&self, id: LoopId) -> Option<&AnalyzedLoop> {
        self.loops.iter().find(|l| l.id() == id)
    }

    /// Loops ranked by intensity, filtered to offloadable candidates.
    pub fn ranked_candidates(&self) -> Vec<&AnalyzedLoop> {
        let mut cands: Vec<&AnalyzedLoop> =
            self.loops.iter().filter(|l| l.candidate()).collect();
        cands.sort_by(|a, b| {
            let ia = a.intensity.as_ref().expect("candidate").score;
            let ib = b.intensity.as_ref().expect("candidate").score;
            ib.partial_cmp(&ia)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.id().cmp(&b.id()))
        });
        cands
    }

    /// Names of loops that never ran (dead under the sample input).
    pub fn cold_loops(&self) -> BTreeSet<LoopId> {
        self.loops
            .iter()
            .filter(|l| l.intensity.is_none())
            .map(|l| l.id())
            .collect()
    }
}

/// Parse-independent analysis entry: profile `entry()` and join tables.
///
/// This is paper Step 1 + Step 2's analysis half: code analysis (static)
/// plus the profiling run that the arithmetic-intensity tool needs.
/// Profiles on the default engine (the bytecode VM).
pub fn analyze(prog: &Program, entry: &str) -> Result<Analysis, MiniCError> {
    analyze_with(prog, entry, EngineKind::default())
}

/// [`analyze`] with an explicit execution engine.
pub fn analyze_with(
    prog: &Program,
    entry: &str,
    engine: EngineKind,
) -> Result<Analysis, MiniCError> {
    let static_info = extract(prog);

    let mut eng = engine.build(prog)?;
    eng.call(entry, &[])?;
    let profile = eng.profile();

    let ranked = rank(&profile);

    let loops = static_info
        .into_iter()
        .map(|info| {
            let dependence = loop_dependence(prog, &info);
            let intensity =
                ranked.iter().find(|r| r.id == info.id).cloned();
            AnalyzedLoop {
                info,
                dependence,
                intensity,
            }
        })
        .collect();

    Ok(Analysis {
        loops,
        profile,
        entry: entry.to_string(),
    })
}

/// Profile `entry()` on an instruction-profiled VM under the given
/// encoding: the §PGO measurement run behind `repro vmprofile`.
///
/// Returns the ordinary loop [`Profile`] (identical to [`analyze`]'s —
/// the profiler is observationally invisible) plus the [`OpReport`]
/// of per-opcode and adjacent-pair dispatch counts, truncated to
/// `top_pairs` pair rows.
pub fn opcode_profile(
    prog: &Program,
    entry: &str,
    opts: &ResolveOpts,
    top_pairs: usize,
) -> Result<(Profile, OpReport), MiniCError> {
    let mut vm = Vm::new_profiled_with(prog, opts)?;
    vm.call(entry, &[])?;
    let report = vm
        .instr_profiler()
        .expect("profiled VM has a profiler")
        .report(top_pairs);
    Ok((vm.profile(), report))
}

/// Find the loop body in the program and classify its dependence.
fn loop_dependence(prog: &Program, info: &LoopInfo) -> Dependence {
    let mut dep = Dependence::Independent;
    let mut found = false;
    prog.walk_stmts(&mut |s| {
        if found {
            return;
        }
        if let Stmt::For { id, body, .. } | Stmt::While { id, body, .. } = s {
            if *id == info.id {
                dep = classify(body, info.induction.as_deref());
                found = true;
            }
        }
    });
    dep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::parse;

    const SRC: &str = "
#define N 64
float a[N]; float b[N];
float total;
void setup() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.5; }      // L0
}
int main() {
    setup();
    for (int i = 0; i < N; i++) {                        // L1 hot
        b[i] = sin(a[i]) * cos(a[i]) + sqrt(a[i] + 1.0);
    }
    for (int i = 0; i < N; i++) { total += b[i]; }       // L2 reduction
    if (total < 0.0) {
        for (int i = 0; i < N; i++) { b[i] = 0.0; }      // L3 cold
    }
    return 0;
}";

    #[test]
    fn analysis_joins_static_and_dynamic() {
        let prog = parse(SRC).unwrap();
        let a = analyze(&prog, "main").unwrap();
        assert_eq!(a.loops.len(), 4);
        // L1 is the hottest candidate.
        let ranked = a.ranked_candidates();
        assert_eq!(ranked[0].id(), LoopId(1));
        // L2 classified as reduction.
        assert!(matches!(
            a.loop_by_id(LoopId(2)).unwrap().dependence,
            Dependence::Reduction(_)
        ));
        // L3 never ran.
        assert!(a.cold_loops().contains(&LoopId(3)));
        assert!(!a.loop_by_id(LoopId(3)).unwrap().candidate());
    }

    #[test]
    fn engines_produce_identical_analysis() {
        let prog = parse(SRC).unwrap();
        let a_vm =
            analyze_with(&prog, "main", EngineKind::Bytecode).unwrap();
        let a_tw =
            analyze_with(&prog, "main", EngineKind::TreeWalk).unwrap();
        assert_eq!(a_vm.profile.total, a_tw.profile.total);
        assert_eq!(a_vm.profile.loops.len(), a_tw.profile.loops.len());
        for (id, lp) in &a_tw.profile.loops {
            let lv = a_vm.profile.loop_profile(*id).unwrap();
            assert_eq!(lp.ops, lv.ops, "{id}");
            assert_eq!(lp.trips, lv.trips, "{id}");
        }
    }

    #[test]
    fn opcode_profile_matches_plain_analysis() {
        let prog = parse(SRC).unwrap();
        let a = analyze(&prog, "main").unwrap();
        let (p, report) =
            opcode_profile(&prog, "main", &ResolveOpts::default(), 8)
                .unwrap();
        // The instruction profiler is invisible to the loop profile.
        assert_eq!(a.profile.total, p.total);
        assert!(report.dispatches > 0);
        assert!(report.pairs.len() <= 8);
        // Baseline encoding dispatches strictly more instructions —
        // that gap is the fusion win vmprofile reports.
        let (_, base) =
            opcode_profile(&prog, "main", &ResolveOpts::baseline(), 8)
                .unwrap();
        assert!(base.dispatches > report.dispatches);
    }

    #[test]
    fn candidates_exclude_blocked_loops() {
        let src = r#"
#define N 8
float a[N];
void log_it() { }
int main() {
    for (int i = 0; i < N; i++) { a[i] = a[i] + 1.0; log_it(); }  // L0 blocked
    for (int i = 0; i < N; i++) { a[i] = a[i] * 2.0; }            // L1 ok
    return 0;
}"#;
        let prog = parse(src).unwrap();
        let a = analyze(&prog, "main").unwrap();
        let ids: Vec<LoopId> =
            a.ranked_candidates().iter().map(|l| l.id()).collect();
        assert_eq!(ids, vec![LoopId(1)]);
    }
}
