//! Static loop-structure extraction (paper Step 1, code analysis).
//!
//! Builds the loop tree with, per loop: nesting, induction variable,
//! statically-known trip count (when the bounds are `#define`s/literals),
//! array reference sets, and *offloadability* — whether the loop body is
//! something our OpenCL-style codegen can turn into a standalone kernel
//! (no user-function calls, no I/O, no `return`, arrays with known element
//! types).

use std::collections::BTreeSet;

use crate::minic::ast::*;
use crate::minic::typecheck::{BUILTINS_1, BUILTINS_2};
use crate::minic::Program;

/// Why a loop cannot be offloaded.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Blocker {
    /// Calls a user-defined function (kernel can't contain it).
    UserCall(String),
    /// Performs I/O (printf).
    Io,
    /// Contains a `return` (control leaves the loop body).
    Return,
    /// `while` loop without a `for`-shaped header — trip count unknowable
    /// for the HLS pipeline model.
    WhileLoop,
    /// Contains a nested while-blocker (propagated from children).
    Nested(Box<Blocker>),
}

impl std::fmt::Display for Blocker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Blocker::UserCall(n) => write!(f, "calls user function `{n}`"),
            Blocker::Io => write!(f, "performs I/O"),
            Blocker::Return => write!(f, "contains return"),
            Blocker::WhileLoop => write!(f, "non-counted while loop"),
            Blocker::Nested(b) => write!(f, "nested loop {b}"),
        }
    }
}

/// Static description of one loop statement.
#[derive(Debug, Clone)]
pub struct LoopInfo {
    pub id: LoopId,
    /// Function containing the loop.
    pub function: String,
    pub line: u32,
    /// 0 = outermost in its function.
    pub depth: usize,
    pub parent: Option<LoopId>,
    pub children: Vec<LoopId>,
    /// Induction variable, when the `for` header is canonical
    /// (`for (i = a; i < b; i += c)`).
    pub induction: Option<String>,
    /// Static trip count when derivable from literals/#defines.
    pub static_trips: Option<u64>,
    /// Array names read / written in the loop subtree.
    pub arrays_read: BTreeSet<String>,
    pub arrays_written: BTreeSet<String>,
    /// Scalar variables referenced but defined outside the loop (kernel
    /// arguments beyond the arrays).
    pub free_scalars: BTreeSet<String>,
    /// None = offloadable; Some(blocker) = not.
    pub blocker: Option<Blocker>,
}

impl LoopInfo {
    pub fn offloadable(&self) -> bool {
        self.blocker.is_none()
    }
}

/// Extract the loop table for a whole program, in loop-id order.
pub fn extract(prog: &Program) -> Vec<LoopInfo> {
    let mut out = Vec::new();
    for f in &prog.functions {
        let mut stack: Vec<LoopId> = Vec::new();
        walk_stmts(&f.body, prog, f, &mut stack, &mut out);
    }
    out.sort_by_key(|l| l.id);
    out
}

fn walk_stmts(
    stmts: &[Stmt],
    prog: &Program,
    func: &Function,
    stack: &mut Vec<LoopId>,
    out: &mut Vec<LoopInfo>,
) {
    for s in stmts {
        match s {
            Stmt::For {
                id,
                init,
                cond,
                step,
                body,
                line,
            } => {
                let induction = induction_var(init.as_deref(), step.as_deref());
                let static_trips = static_trip_count(
                    prog,
                    init.as_deref(),
                    cond.as_ref(),
                    step.as_deref(),
                );
                push_loop(
                    LoopInfo {
                        id: *id,
                        function: func.name.clone(),
                        line: *line,
                        depth: stack.len(),
                        parent: stack.last().copied(),
                        children: Vec::new(),
                        induction,
                        static_trips,
                        arrays_read: BTreeSet::new(),
                        arrays_written: BTreeSet::new(),
                        free_scalars: BTreeSet::new(),
                        blocker: None,
                    },
                    s,
                    prog,
                    func,
                    stack,
                    out,
                    body,
                );
            }
            Stmt::While { id, body, line, .. } => {
                push_loop(
                    LoopInfo {
                        id: *id,
                        function: func.name.clone(),
                        line: *line,
                        depth: stack.len(),
                        parent: stack.last().copied(),
                        children: Vec::new(),
                        induction: None,
                        static_trips: None,
                        arrays_read: BTreeSet::new(),
                        arrays_written: BTreeSet::new(),
                        free_scalars: BTreeSet::new(),
                        blocker: Some(Blocker::WhileLoop),
                    },
                    s,
                    prog,
                    func,
                    stack,
                    out,
                    body,
                );
            }
            Stmt::If {
                then_branch,
                else_branch,
                ..
            } => {
                walk_stmts(then_branch, prog, func, stack, out);
                walk_stmts(else_branch, prog, func, stack, out);
            }
            _ => {}
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn push_loop(
    mut info: LoopInfo,
    stmt: &Stmt,
    prog: &Program,
    func: &Function,
    stack: &mut Vec<LoopId>,
    out: &mut Vec<LoopInfo>,
    body: &[Stmt],
) {
    analyze_subtree(stmt, prog, func, &mut info);
    let id = info.id;
    if let Some(parent) = stack.last() {
        // Parent is already in `out` (preorder).
        if let Some(p) = out.iter_mut().find(|l| l.id == *parent) {
            p.children.push(id);
        }
    }
    out.push(info);
    stack.push(id);
    walk_stmts(body, prog, func, stack, out);
    stack.pop();
    // Propagate child blockers upward: a loop containing a non-offloadable
    // while child is still offloadable only if the child itself is; we are
    // conservative and inherit while-blockers.
    let child_blockers: Vec<Blocker> = out
        .iter()
        .filter(|l| l.parent == Some(id))
        .filter_map(|l| l.blocker.clone())
        .collect();
    if let Some(b) = child_blockers.into_iter().next() {
        let me = out.iter_mut().find(|l| l.id == id).expect("self");
        if me.blocker.is_none() {
            me.blocker = Some(Blocker::Nested(Box::new(b)));
        }
    }
}

/// Scan the loop subtree for refs, blockers, and free scalars.
fn analyze_subtree(
    loop_stmt: &Stmt,
    prog: &Program,
    func: &Function,
    info: &mut LoopInfo,
) {
    let mut declared: BTreeSet<String> = BTreeSet::new();
    // For-header decls count as loop-local.
    if let Stmt::For { init: Some(init), .. } = loop_stmt {
        if let Stmt::Decl { name, .. } = init.as_ref() {
            declared.insert(name.clone());
        }
    }

    let body: &[Stmt] = match loop_stmt {
        Stmt::For { body, .. } | Stmt::While { body, .. } => body,
        _ => unreachable!("analyze_subtree on non-loop"),
    };

    // Collect declarations first (any depth) — they are kernel-local.
    for s in body {
        s.walk(&mut |s| {
            if let Stmt::Decl { name, .. } = s {
                declared.insert(name.clone());
            }
            if let Stmt::For { init: Some(init), .. } = s {
                if let Stmt::Decl { name, .. } = init.as_ref() {
                    declared.insert(name.clone());
                }
            }
        });
    }

    let is_array = |name: &str| -> bool {
        // Arrays are globals with array type or params with ptr/array type.
        prog.globals.iter().any(|g| {
            matches!(g, Stmt::Decl { name: n, ty, .. }
                if n == name && ty.is_indexable())
        }) || func
            .params
            .iter()
            .any(|p| p.name == name && p.ty.is_indexable())
    };

    let note_expr = |e: &Expr, info: &mut LoopInfo, declared: &BTreeSet<String>| {
        e.walk(&mut |e| match e {
            Expr::Index { base, .. } => {
                info.arrays_read.insert(base.clone());
            }
            Expr::Var(n) => {
                if !declared.contains(n)
                    && !is_array(n)
                    && prog.define(n).is_none()
                {
                    info.free_scalars.insert(n.clone());
                }
            }
            Expr::Call { name, args: _ } => {
                let known = BUILTINS_1.contains(&name.as_str())
                    || BUILTINS_2.contains(&name.as_str());
                if name == "printf" {
                    info.blocker.get_or_insert(Blocker::Io);
                } else if !known && prog.function(name).is_some() {
                    info.blocker
                        .get_or_insert(Blocker::UserCall(name.clone()));
                }
            }
            _ => {}
        });
    };

    // Walk statements including the loop's own cond/step.
    if let Stmt::For { cond, step, .. } = loop_stmt {
        if let Some(c) = cond {
            note_expr(c, info, &declared);
        }
        if let Some(s) = step {
            if let Stmt::Assign { value, .. } = s.as_ref() {
                note_expr(value, info, &declared);
            }
        }
    }
    if let Stmt::While { cond, .. } = loop_stmt {
        note_expr(cond, info, &declared);
    }

    for s in body {
        s.walk(&mut |s| match s {
            Stmt::Assign { target, value, .. } => {
                match target {
                    LValue::Index { base, indices } => {
                        info.arrays_written.insert(base.clone());
                        for i in indices {
                            note_expr(i, info, &declared);
                        }
                    }
                    LValue::Var(n) => {
                        if !declared.contains(n) {
                            info.free_scalars.insert(n.clone());
                        }
                    }
                }
                note_expr(value, info, &declared);
            }
            Stmt::Decl { init, .. } => {
                if let Some(e) = init {
                    note_expr(e, info, &declared);
                }
            }
            Stmt::If { cond, .. } => note_expr(cond, info, &declared),
            Stmt::For { cond, step, .. } => {
                if let Some(c) = cond {
                    note_expr(c, info, &declared);
                }
                if let Some(st) = step {
                    if let Stmt::Assign { value, .. } = st.as_ref() {
                        note_expr(value, info, &declared);
                    }
                }
            }
            Stmt::While { cond, .. } => note_expr(cond, info, &declared),
            Stmt::Return { .. } => {
                info.blocker.get_or_insert(Blocker::Return);
            }
            Stmt::ExprStmt { expr, .. } => note_expr(expr, info, &declared),
        });
    }

    // Reads that are also written: keep in both sets (that's information —
    // in/out arrays). But indices seen only as write targets shouldn't be
    // in arrays_read; the walker above already only adds Index *reads* via
    // expressions, and writes via Assign targets.
}

/// `for (i = a; ...; i++/i+=c)` → `Some(i)` if init and step agree.
fn induction_var(init: Option<&Stmt>, step: Option<&Stmt>) -> Option<String> {
    let init_var = match init? {
        Stmt::Decl { name, .. } => name.clone(),
        Stmt::Assign {
            target: LValue::Var(n),
            ..
        } => n.clone(),
        _ => return None,
    };
    let step_var = match step? {
        Stmt::Assign {
            target: LValue::Var(n),
            ..
        } => n.clone(),
        _ => return None,
    };
    (init_var == step_var).then_some(init_var)
}

/// Evaluate a constant expression over int literals and `#define`s.
fn const_eval(prog: &Program, e: &Expr) -> Option<f64> {
    Some(match e {
        Expr::IntLit(v) => *v as f64,
        Expr::FloatLit(v) => *v,
        Expr::Var(n) => prog.define(n)?,
        Expr::Bin { op, lhs, rhs } => {
            let a = const_eval(prog, lhs)?;
            let b = const_eval(prog, rhs)?;
            match op {
                BinOp::Add => a + b,
                BinOp::Sub => a - b,
                BinOp::Mul => a * b,
                BinOp::Div => {
                    if b == 0.0 {
                        return None;
                    }
                    a / b
                }
                _ => return None,
            }
        }
        Expr::Un {
            op: UnOp::Neg,
            operand,
        } => -const_eval(prog, operand)?,
        Expr::Cast { operand, .. } => const_eval(prog, operand)?,
        _ => return None,
    })
}

/// Static trip count for a canonical counted loop.
fn static_trip_count(
    prog: &Program,
    init: Option<&Stmt>,
    cond: Option<&Expr>,
    step: Option<&Stmt>,
) -> Option<u64> {
    let var = induction_var(init, step)?;
    let start = match init? {
        Stmt::Decl { init: Some(e), .. } => const_eval(prog, e)?,
        Stmt::Assign { value, .. } => const_eval(prog, value)?,
        _ => return None,
    };
    // Step must be i++ / i += c with constant c > 0.
    let stride = match step? {
        Stmt::Assign {
            op: AssignOp::AddSet,
            value,
            ..
        } => const_eval(prog, value)?,
        Stmt::Assign {
            op: AssignOp::Set,
            value:
                Expr::Bin {
                    op: BinOp::Add,
                    lhs,
                    rhs,
                },
            ..
        } => {
            // i = i + c
            if matches!(lhs.as_ref(), Expr::Var(n) if *n == var) {
                const_eval(prog, rhs)?
            } else {
                return None;
            }
        }
        _ => return None,
    };
    if stride <= 0.0 {
        return None;
    }
    // Cond must be `var < bound` or `var <= bound`.
    let (bound, inclusive) = match cond? {
        Expr::Bin { op, lhs, rhs } => {
            if !matches!(lhs.as_ref(), Expr::Var(n) if *n == var) {
                return None;
            }
            match op {
                BinOp::Lt => (const_eval(prog, rhs)?, false),
                BinOp::Le => (const_eval(prog, rhs)?, true),
                _ => return None,
            }
        }
        _ => return None,
    };
    let span = bound - start + if inclusive { 1.0 } else { 0.0 };
    if span <= 0.0 {
        return Some(0);
    }
    Some((span / stride).ceil() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::parse;

    fn table(src: &str) -> Vec<LoopInfo> {
        extract(&parse(src).unwrap())
    }

    #[test]
    fn loop_tree_structure() {
        let t = table(
            "#define N 8\nfloat a[N];\n
             void f() {
               for (int i = 0; i < N; i++) {        // L0
                 for (int j = 0; j < N; j++) {      // L1
                   a[i] = a[i] + 1.0;
                 }
               }
               for (int k = 0; k < N; k++) { }      // L2
             }",
        );
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].depth, 0);
        assert_eq!(t[1].depth, 1);
        assert_eq!(t[1].parent, Some(LoopId(0)));
        assert_eq!(t[0].children, vec![LoopId(1)]);
        assert_eq!(t[2].parent, None);
    }

    #[test]
    fn static_trips_from_defines() {
        let t = table(
            "#define N 100\nvoid f() { for (int i = 0; i < N; i++) { } }",
        );
        assert_eq!(t[0].static_trips, Some(100));
        assert_eq!(t[0].induction.as_deref(), Some("i"));
    }

    #[test]
    fn static_trips_with_stride_and_le() {
        let t = table("void f() { for (int i = 2; i <= 10; i += 3) { } }");
        assert_eq!(t[0].static_trips, Some(3)); // 2, 5, 8 → wait: 2,5,8 then 11>10 → 3
    }

    #[test]
    fn array_read_write_sets() {
        let t = table(
            "#define N 4\nfloat a[N]; float b[N]; float c[N];\n
             void f() { for (int i = 0; i < N; i++) { c[i] = a[i] * b[i]; } }",
        );
        assert!(t[0].arrays_read.contains("a"));
        assert!(t[0].arrays_read.contains("b"));
        assert!(t[0].arrays_written.contains("c"));
        assert!(!t[0].arrays_written.contains("a"));
        assert!(t[0].offloadable());
    }

    #[test]
    fn free_scalars_detected() {
        let t = table(
            "#define N 4\nfloat a[N];\nfloat scale;\n
             void f(float bias) {
               for (int i = 0; i < N; i++) { a[i] = a[i] * scale + bias; }
             }",
        );
        assert!(t[0].free_scalars.contains("scale"));
        assert!(t[0].free_scalars.contains("bias"));
        assert!(!t[0].free_scalars.contains("i"));
    }

    #[test]
    fn user_call_blocks_offload() {
        let t = table(
            "void helper() { }\n
             void f() { for (int i = 0; i < 4; i++) { helper(); } }",
        );
        assert_eq!(
            t[0].blocker,
            Some(Blocker::UserCall("helper".into()))
        );
    }

    #[test]
    fn builtin_call_does_not_block() {
        let t = table(
            "#define N 4\nfloat a[N];\n
             void f() { for (int i = 0; i < N; i++) { a[i] = sin(a[i]); } }",
        );
        assert!(t[0].offloadable());
    }

    #[test]
    fn printf_blocks_offload() {
        let t = table(
            r#"void f() { for (int i = 0; i < 4; i++) { printf("%d", i); } }"#,
        );
        assert_eq!(t[0].blocker, Some(Blocker::Io));
    }

    #[test]
    fn return_blocks_offload() {
        let t = table(
            "int f() { for (int i = 0; i < 4; i++) { if (i == 2) return i; } return 0; }",
        );
        assert_eq!(t[0].blocker, Some(Blocker::Return));
    }

    #[test]
    fn while_blocks_and_propagates() {
        let t = table(
            "void f() {
               for (int i = 0; i < 4; i++) {   // L0
                 while (i < 2) { }             // L1
               }
             }",
        );
        assert_eq!(t[1].blocker, Some(Blocker::WhileLoop));
        assert!(matches!(t[0].blocker, Some(Blocker::Nested(_))));
    }

    #[test]
    fn nested_offloadable_for_is_fine() {
        let t = table(
            "#define N 4\nfloat a[N][N];\n
             void f() {
               for (int i = 0; i < N; i++)
                 for (int j = 0; j < N; j++)
                   a[i][j] = 1.0;
             }",
        );
        assert!(t[0].offloadable());
        assert!(t[1].offloadable());
    }
}
