//! Arithmetic-intensity analysis (the PGI-compiler analog, paper §3.3/§4).
//!
//! The paper's indicator: "an index that increases when the number of
//! loops and the amount of data are large, and decreases when the number
//! of accesses is large". We compute it from the dynamic profile (the
//! gcov-analog run of [`crate::minic::Interp`]):
//!
//! ```text
//! intensity(L)  = flops(L) / accesses(L)        (ops per array access)
//! flop_byte(L)  = flops(L) / bytes(L)           (classic roofline AI)
//! work(L)       = flops(L)                      (absolute weight)
//! score(L)      = intensity(L) × work(L)        (the narrowing key)
//! ```
//!
//! `score` is the narrowing key (top-A). Both factors matter: the paper's
//! indicator "increases when the number of loops and the amount of data
//! are large" (that's `work` — total flops already scale with trip count)
//! "and decreases when the number of accesses is large" (that's the
//! `intensity` ratio). Ranking by the ratio alone would let a
//! 10-iteration loop with a lucky flop/access ratio displace the real hot
//! loop; ranking by work alone would pick memory-bound giants.
//! Transcendentals are weighted: one sin/cos on the Xeon costs ~20-40
//! scalar flops, and on the FPGA consumes a big CORDIC pipeline; counting
//! them as `TRIG_FLOP_WEIGHT` flops keeps both models honest.

use crate::minic::ast::LoopId;
use crate::minic::{OpCounts, Profile};

/// Effective flops charged per transcendental call (sin/cos/exp/...).
pub const TRIG_FLOP_WEIGHT: u64 = 24;

/// Per-loop intensity record.
#[derive(Debug, Clone)]
pub struct LoopIntensity {
    pub id: LoopId,
    /// Weighted flops in the loop subtree (trig-weighted).
    pub work: u64,
    /// Array accesses (reads + writes).
    pub accesses: u64,
    /// Bytes moved by those accesses.
    pub bytes: u64,
    /// Total iterations observed.
    pub trips: u64,
    /// Ops per array access.
    pub intensity: f64,
    /// Classic flop/byte (for the roofline view).
    pub flop_byte: f64,
    /// The narrowing key: `intensity × work`.
    pub score: f64,
}

/// Weighted flop count for an op-count record.
pub fn weighted_flops(ops: &OpCounts) -> u64 {
    ops.f_add + ops.f_mul + ops.f_div + ops.f_trig * TRIG_FLOP_WEIGHT
}

/// Compute intensity for every profiled loop, sorted descending by
/// `score` — the order the funnel consumes.
pub fn rank(profile: &Profile) -> Vec<LoopIntensity> {
    let mut out: Vec<LoopIntensity> = profile
        .loops
        .iter()
        .map(|(id, lp)| {
            let work = weighted_flops(&lp.ops);
            let accesses = lp.ops.reads + lp.ops.writes;
            let bytes = lp.ops.bytes();
            let intensity = work as f64 / accesses.max(1) as f64;
            LoopIntensity {
                id: *id,
                work,
                accesses,
                bytes,
                trips: lp.trips,
                intensity,
                flop_byte: work as f64 / bytes.max(1) as f64,
                score: intensity * work as f64,
            }
        })
        .collect();
    out.sort_by(|a, b| {
        b.score
            .partial_cmp(&a.score)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(b.work.cmp(&a.work))
            .then(a.id.cmp(&b.id))
    });
    out
}

/// Keep the top `a` records (the paper's "top A loop statements with the
/// highest arithmetic intensity", §4).
pub fn top_a(ranked: &[LoopIntensity], a: usize) -> Vec<LoopIntensity> {
    ranked.iter().take(a).cloned().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::{parse, Interp};

    fn profile_of(src: &str) -> Profile {
        let prog = parse(src).unwrap();
        let mut interp = Interp::new(&prog).unwrap();
        interp.call("main", &[]).unwrap();
        interp.profile().clone()
    }

    #[test]
    fn trig_heavy_loop_outranks_copy_loop() {
        let profile = profile_of(
            "#define N 64\nfloat a[N]; float b[N];\n
             int main() {
               for (int i = 0; i < N; i++) { b[i] = a[i]; }          // L0 copy
               for (int i = 0; i < N; i++) { b[i] = sin(a[i]) * cos(a[i]); } // L1
               return 0;
             }",
        );
        let ranked = rank(&profile);
        assert_eq!(ranked[0].id, LoopId(1));
        assert!(ranked[0].intensity > ranked[1].intensity);
    }

    #[test]
    fn work_counts_subtree() {
        let profile = profile_of(
            "#define N 16\nfloat a[N];\n
             int main() {
               for (int i = 0; i < N; i++)       // L0
                 for (int j = 0; j < N; j++)     // L1
                   a[i] = a[i] + 1.5;
               return 0;
             }",
        );
        let ranked = rank(&profile);
        let l0 = ranked.iter().find(|l| l.id == LoopId(0)).unwrap();
        let l1 = ranked.iter().find(|l| l.id == LoopId(1)).unwrap();
        assert!(l0.work >= l1.work);
        assert_eq!(l1.trips, 256);
    }

    #[test]
    fn top_a_truncates_in_order() {
        let profile = profile_of(
            "#define N 8\nfloat a[N];\n
             int main() {
               for (int i = 0; i < N; i++) { a[i] = a[i] * 2.0; }
               for (int i = 0; i < N; i++) { a[i] = a[i] + 1.0; }
               for (int i = 0; i < N; i++) { a[i] = sin(a[i]); }
               return 0;
             }",
        );
        let ranked = rank(&profile);
        let top2 = top_a(&ranked, 2);
        assert_eq!(top2.len(), 2);
        assert_eq!(top2[0].id, ranked[0].id);
        let top99 = top_a(&ranked, 99);
        assert_eq!(top99.len(), 3);
    }

    #[test]
    fn intensity_decreases_with_accesses() {
        // Same flops, more accesses → lower intensity (paper's wording).
        let profile = profile_of(
            "#define N 32\nfloat a[N]; float b[N]; float c[N]; float d[N];\n
             int main() {
               for (int i = 0; i < N; i++) { d[i] = a[i] + 1.0; }            // L0: 1 add, 2 acc
               for (int i = 0; i < N; i++) { d[i] = a[i] + b[i] + c[i] - 1.0; } // L1: 3 add, 4 acc
               return 0;
             }",
        );
        let ranked = rank(&profile);
        let l0 = ranked.iter().find(|l| l.id == LoopId(0)).unwrap();
        let l1 = ranked.iter().find(|l| l.id == LoopId(1)).unwrap();
        // L1: 3/4 ops/access beats L0: 1/2 — intensity follows flops per
        // access, so check the arithmetic exactly.
        assert!((l0.intensity - 0.5).abs() < 1e-9, "{}", l0.intensity);
        assert!((l1.intensity - 0.75).abs() < 1e-9, "{}", l1.intensity);
    }

    #[test]
    fn weighted_flops_counts_trig() {
        let ops = OpCounts {
            f_add: 10,
            f_trig: 2,
            ..Default::default()
        };
        assert_eq!(weighted_flops(&ops), 10 + 2 * TRIG_FLOP_WEIGHT);
    }
}
