//! Analysis layer: paper Step 1 (code analysis) and the front half of
//! Step 2 (appropriate-place extraction).
//!
//! * [`loopinfo`] — static loop tree, reference sets, offloadability
//!   (Clang-analog structural analysis).
//! * [`profile`] — dynamic profiling via the instrumented interpreter
//!   (gcov/gprof analog) joined with the static table.
//! * [`intensity`] — the arithmetic-intensity indicator (PGI analog).
//! * [`depend`] — loop-carried dependence classification feeding the HLS
//!   pipeline model.

pub mod depend;
pub mod intensity;
pub mod loopinfo;
pub mod profile;

pub use depend::Dependence;
pub use intensity::{LoopIntensity, TRIG_FLOP_WEIGHT};
pub use loopinfo::{Blocker, LoopInfo};
pub use profile::{analyze, analyze_with, Analysis, AnalyzedLoop};
