//! Analysis layer: paper Step 1 (code analysis) and the front half of
//! Step 2 (appropriate-place extraction).
//!
//! * [`loopinfo`] — static loop tree, reference sets, offloadability
//!   (Clang-analog structural analysis).
//! * [`profile`] — dynamic profiling via the instrumented interpreter
//!   (gcov/gprof analog) joined with the static table.
//! * [`intensity`] — the arithmetic-intensity indicator (PGI analog).
//! * [`depend`] — loop-carried dependence classification feeding the HLS
//!   pipeline model.
//!
//! One call profiles an entry function and joins every view:
//!
//! ```
//! use fpga_offload::analysis::analyze;
//! use fpga_offload::minic::parse;
//!
//! let prog = parse(
//!     "#define N 64\n\
//!      float a[N]; float out[N];\n\
//!      int main() {\n\
//!          for (int i = 0; i < N; i++) { a[i] = i * 0.1; }\n\
//!          for (int i = 0; i < N; i++) { out[i] = sin(a[i]); }\n\
//!          return 0;\n\
//!      }",
//! )
//! .unwrap();
//! let an = analyze(&prog, "main").unwrap();
//! assert_eq!(an.loops.len(), 2);
//! assert_eq!(an.entry, "main");
//! // The profiling run counted real work for the baseline model.
//! assert!(an.profile.total.f_trig >= 64);
//! ```

pub mod depend;
pub mod intensity;
pub mod loopinfo;
pub mod profile;

pub use depend::Dependence;
pub use intensity::{LoopIntensity, TRIG_FLOP_WEIGHT};
pub use loopinfo::{Blocker, LoopInfo};
pub use profile::{
    analyze, analyze_with, opcode_profile, Analysis, AnalyzedLoop,
};
