//! Loop-carried dependence classification.
//!
//! Drives two decisions downstream:
//! * [`crate::hls::schedule`] — the pipeline initiation interval (II): an
//!   independent loop streams one iteration per cycle, a reduction pays
//!   the accumulator latency, a true carried dependence serializes.
//! * parallel replication (multiple kernel instances) is only valid for
//!   independent loops.
//!
//! Method: the body is linearized into an *event sequence* (scalar/array
//! reads and writes in evaluation order — RHS before LHS). A non-local
//! scalar read before its first write carries a value across iterations;
//! recognized reduction updates (`s += e`, `s = s ± e`) are exempted. An
//! array written at index `I` and read anywhere at a textually different
//! index is conservatively carried (the paper's analysis likewise defers
//! borderline cases to measurement).

use std::collections::{BTreeMap, BTreeSet};

use crate::minic::ast::*;

/// Dependence classification for one loop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Dependence {
    /// Iterations are independent — fully pipelineable/replicable.
    Independent,
    /// Scalar reduction(s): pipelineable with accumulator latency.
    Reduction(BTreeSet<String>),
    /// A loop-carried dependence through the named variable/array.
    Carried(String),
}

impl Dependence {
    pub fn parallelizable(&self) -> bool {
        matches!(self, Dependence::Independent)
    }

    pub fn pipelineable(&self) -> bool {
        !matches!(self, Dependence::Carried(_))
    }
}

#[derive(Debug, Clone, PartialEq)]
enum Event {
    ReadScalar(String),
    /// `reduction=true` for `s += e` / `s = s ⊕ e` shapes (the self-read
    /// is folded into the update and not emitted separately).
    WriteScalar { name: String, reduction: bool },
    ReadArray { base: String, idx: Vec<Expr> },
    WriteArray { base: String, idx: Vec<Expr> },
}

/// Classify the carried dependences of a loop body w.r.t. the given
/// induction variable.
pub fn classify(body: &[Stmt], induction: Option<&str>) -> Dependence {
    // Locals declared anywhere in the body are iteration-private.
    let mut local: BTreeSet<String> = BTreeSet::new();
    for s in body {
        s.walk(&mut |s| {
            if let Stmt::Decl { name, .. } = s {
                local.insert(name.clone());
            }
            if let Stmt::For { init: Some(i), .. } = s {
                if let Stmt::Decl { name, .. } = i.as_ref() {
                    local.insert(name.clone());
                }
            }
        });
    }
    // Inner-loop induction variables are private too.
    for s in body {
        s.walk(&mut |s| {
            if let Stmt::For { init: Some(i), .. } = s {
                if let Stmt::Assign {
                    target: LValue::Var(n),
                    ..
                } = i.as_ref()
                {
                    local.insert(n.clone());
                }
            }
        });
    }

    let mut events = Vec::new();
    for s in body {
        emit_stmt(s, &mut events);
    }

    // ---- array dependences ----
    let mut array_writes: BTreeMap<&str, Vec<&Vec<Expr>>> = BTreeMap::new();
    for e in &events {
        if let Event::WriteArray { base, idx } = e {
            array_writes.entry(base).or_default().push(idx);
        }
    }
    for e in &events {
        if let Event::ReadArray { base, idx } = e {
            if let Some(writes) = array_writes.get(base.as_str()) {
                if writes.iter().any(|w| w.as_slice() != idx.as_slice()) {
                    return Dependence::Carried(base.clone());
                }
            }
        }
    }

    // ---- scalar dependences (event order) ----
    let is_tracked = |n: &str| {
        !local.contains(n) && Some(n) != induction
    };
    #[derive(Default, Clone)]
    struct ScalarState {
        read_first: bool,
        written: bool,
        plain_write: bool,     // non-reduction write
        reduction_write: bool, // reduction-shaped write
        read_after_write: bool,
    }
    let mut state: BTreeMap<String, ScalarState> = BTreeMap::new();
    for e in &events {
        match e {
            Event::ReadScalar(n) if is_tracked(n) => {
                let st = state.entry(n.clone()).or_default();
                if st.written {
                    st.read_after_write = true;
                } else {
                    st.read_first = true;
                }
            }
            Event::WriteScalar { name, reduction } if is_tracked(name) => {
                let st = state.entry(name.clone()).or_default();
                st.written = true;
                if *reduction {
                    st.reduction_write = true;
                } else {
                    st.plain_write = true;
                }
            }
            _ => {}
        }
    }

    let mut reductions = BTreeSet::new();
    for (name, st) in &state {
        if !st.written {
            continue; // read-only outer scalar: a kernel argument, fine.
        }
        if st.reduction_write && !st.plain_write && !st.read_first
            && !st.read_after_write
        {
            // Pure accumulator: only reduction updates, never read.
            reductions.insert(name.clone());
            continue;
        }
        if st.reduction_write {
            // Reduction value observed inside the iteration (prefix sum)
            // or mixed with plain writes: order-dependent → carried.
            return Dependence::Carried(name.clone());
        }
        if st.read_first {
            // Value flows in from the previous iteration.
            return Dependence::Carried(name.clone());
        }
        // Write-first then (maybe) read: privatizable.
    }

    if reductions.is_empty() {
        Dependence::Independent
    } else {
        Dependence::Reduction(reductions)
    }
}

/// Emit events for a statement, RHS before LHS (evaluation order).
fn emit_stmt(s: &Stmt, out: &mut Vec<Event>) {
    match s {
        Stmt::Decl { init, .. } => {
            if let Some(e) = init {
                emit_expr(e, out);
            }
        }
        Stmt::Assign { target, op, value, .. } => {
            match target {
                LValue::Var(name) => {
                    let reduction = match op {
                        AssignOp::AddSet
                        | AssignOp::SubSet
                        | AssignOp::MulSet
                        | AssignOp::DivSet => {
                            emit_expr(value, out);
                            true
                        }
                        AssignOp::Set => {
                            if let Some(rest) = self_update_rest(name, value) {
                                emit_expr(rest, out);
                                true
                            } else {
                                emit_expr(value, out);
                                false
                            }
                        }
                    };
                    out.push(Event::WriteScalar {
                        name: name.clone(),
                        reduction,
                    });
                }
                LValue::Index { base, indices } => {
                    emit_expr(value, out);
                    for i in indices {
                        emit_expr(i, out);
                    }
                    if *op != AssignOp::Set {
                        // Compound array update reads the element first.
                        out.push(Event::ReadArray {
                            base: base.clone(),
                            idx: indices.clone(),
                        });
                    }
                    out.push(Event::WriteArray {
                        base: base.clone(),
                        idx: indices.clone(),
                    });
                }
            }
        }
        Stmt::If {
            cond,
            then_branch,
            else_branch,
            ..
        } => {
            emit_expr(cond, out);
            for s in then_branch.iter().chain(else_branch) {
                emit_stmt(s, out);
            }
        }
        Stmt::For {
            init, cond, step, body, ..
        } => {
            if let Some(s) = init {
                emit_stmt(s, out);
            }
            if let Some(c) = cond {
                emit_expr(c, out);
            }
            for s in body {
                emit_stmt(s, out);
            }
            if let Some(s) = step {
                emit_stmt(s, out);
            }
        }
        Stmt::While { cond, body, .. } => {
            emit_expr(cond, out);
            for s in body {
                emit_stmt(s, out);
            }
        }
        Stmt::Return { value, .. } => {
            if let Some(e) = value {
                emit_expr(e, out);
            }
        }
        Stmt::ExprStmt { expr, .. } => emit_expr(expr, out),
    }
}

fn emit_expr(e: &Expr, out: &mut Vec<Event>) {
    match e {
        Expr::Var(n) => out.push(Event::ReadScalar(n.clone())),
        Expr::Index { base, indices } => {
            for i in indices {
                emit_expr(i, out);
            }
            out.push(Event::ReadArray {
                base: base.clone(),
                idx: indices.clone(),
            });
        }
        Expr::Bin { lhs, rhs, .. } => {
            emit_expr(lhs, out);
            emit_expr(rhs, out);
        }
        Expr::Un { operand, .. } | Expr::Cast { operand, .. } => {
            emit_expr(operand, out)
        }
        Expr::Call { args, .. } => {
            for a in args {
                emit_expr(a, out);
            }
        }
        Expr::IntLit(_) | Expr::FloatLit(_) | Expr::StrLit(_) => {}
    }
}

/// If `value` is `name ⊕ rest` or `rest ⊕ name` (⊕ ∈ {+, -, *}) with a
/// single occurrence of `name`, return the non-self operand.
fn self_update_rest<'a>(name: &str, value: &'a Expr) -> Option<&'a Expr> {
    if let Expr::Bin { op, lhs, rhs } = value {
        if !matches!(op, BinOp::Add | BinOp::Sub | BinOp::Mul) {
            return None;
        }
        let l_is = matches!(lhs.as_ref(), Expr::Var(n) if n == name);
        let r_is = matches!(rhs.as_ref(), Expr::Var(n) if n == name);
        if l_is && !expr_reads_var(rhs, name) {
            return Some(rhs);
        }
        if r_is && !expr_reads_var(lhs, name) && *op != BinOp::Sub {
            return Some(lhs);
        }
    }
    None
}

fn expr_reads_var(e: &Expr, name: &str) -> bool {
    let mut found = false;
    e.walk(&mut |e| {
        if let Expr::Var(n) = e {
            if n == name {
                found = true;
            }
        }
    });
    found
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::parse;

    fn classify_loop0(src: &str) -> Dependence {
        let prog = parse(src).unwrap();
        let info = crate::analysis::loopinfo::extract(&prog);
        let ind = info[0].induction.clone();
        let mut result = None;
        prog.walk_stmts(&mut |s| {
            if result.is_none() {
                if let Stmt::For { id, body, .. } = s {
                    if id.0 == 0 {
                        result = Some(classify(body, ind.as_deref()));
                    }
                }
            }
        });
        result.expect("no loop")
    }

    #[test]
    fn elementwise_is_independent() {
        let d = classify_loop0(
            "#define N 4\nfloat a[N]; float b[N];\n
             void f() { for (int i = 0; i < N; i++) { b[i] = a[i] * 2.0; } }",
        );
        assert_eq!(d, Dependence::Independent);
    }

    #[test]
    fn same_index_update_is_independent() {
        let d = classify_loop0(
            "#define N 4\nfloat a[N];\n
             void f() { for (int i = 0; i < N; i++) { a[i] = a[i] * 2.0; } }",
        );
        assert_eq!(d, Dependence::Independent);
    }

    #[test]
    fn accumulator_is_reduction() {
        let d = classify_loop0(
            "#define N 4\nfloat a[N];\nfloat s;\n
             void f() { for (int i = 0; i < N; i++) { s += a[i]; } }",
        );
        match d {
            Dependence::Reduction(vars) => assert!(vars.contains("s")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn explicit_self_add_is_reduction() {
        let d = classify_loop0(
            "#define N 4\nfloat a[N];\nfloat s;\n
             void f() { for (int i = 0; i < N; i++) { s = s + a[i]; } }",
        );
        assert!(matches!(d, Dependence::Reduction(_)));
    }

    #[test]
    fn stencil_is_carried() {
        let d = classify_loop0(
            "#define N 8\nfloat a[N];\n
             void f() { for (int i = 1; i < N; i++) { a[i] = a[i - 1] + 1.0; } }",
        );
        assert_eq!(d, Dependence::Carried("a".to_string()));
    }

    #[test]
    fn gather_read_other_array_ok() {
        let d = classify_loop0(
            "#define N 8\nfloat a[N]; float b[N];\n
             void f() { for (int i = 1; i < N; i++) { b[i] = a[i - 1] + a[i]; } }",
        );
        assert_eq!(d, Dependence::Independent);
    }

    #[test]
    fn prefix_sum_is_carried() {
        let d = classify_loop0(
            "#define N 8\nfloat a[N]; float b[N];\nfloat s;\n
             void f() { for (int i = 0; i < N; i++) { s += a[i]; b[i] = s; } }",
        );
        assert!(matches!(d, Dependence::Carried(v) if v == "s"));
    }

    #[test]
    fn private_temp_is_fine() {
        let d = classify_loop0(
            "#define N 8\nfloat a[N]; float b[N];\n
             void f() {
               for (int i = 0; i < N; i++) {
                 float t = a[i] * 2.0;
                 b[i] = t + 1.0;
               }
             }",
        );
        assert_eq!(d, Dependence::Independent);
    }

    #[test]
    fn overwritten_outer_scalar_is_privatized() {
        let d = classify_loop0(
            "#define N 8\nfloat a[N]; float b[N];\nfloat t;\n
             void f() {
               for (int i = 0; i < N; i++) { t = a[i]; b[i] = t * t; }
             }",
        );
        assert_eq!(d, Dependence::Independent);
    }

    #[test]
    fn read_before_write_scalar_is_carried() {
        // `a[i] = t` reads last iteration's t before `t = a[i] + 1`.
        let d = classify_loop0(
            "#define N 8\nfloat a[N];\nfloat t;\n
             void f() {
               for (int i = 0; i < N; i++) { a[i] = t; t = a[i] + 1.0; }
             }",
        );
        assert_eq!(d, Dependence::Carried("t".to_string()));
    }

    #[test]
    fn read_only_outer_scalar_is_fine() {
        let d = classify_loop0(
            "#define N 8\nfloat a[N];\nfloat scale;\n
             void f() { for (int i = 0; i < N; i++) { a[i] = a[i] * scale; } }",
        );
        assert_eq!(d, Dependence::Independent);
    }

    #[test]
    fn inner_loop_reduction_into_array_is_independent_outer() {
        // Classic matmul-ish shape: inner accumulates into a local.
        let d = classify_loop0(
            "#define N 4\nfloat a[N][N]; float x[N]; float y[N];\n
             void f() {
               for (int i = 0; i < N; i++) {
                 float acc = 0.0;
                 for (int j = 0; j < N; j++) { acc += a[i][j] * x[j]; }
                 y[i] = acc;
               }
             }",
        );
        assert_eq!(d, Dependence::Independent);
    }

    #[test]
    fn compound_array_update_same_index_ok() {
        let d = classify_loop0(
            "#define N 8\nfloat a[N];\n
             void f() { for (int i = 0; i < N; i++) { a[i] += 1.0; } }",
        );
        assert_eq!(d, Dependence::Independent);
    }

    #[test]
    fn global_accumulator_array_different_index_carried() {
        let d = classify_loop0(
            "#define N 8\nfloat a[N];\n
             void f() { for (int i = 0; i < N; i++) { a[0] = a[0] + a[i]; } }",
        );
        assert_eq!(d, Dependence::Carried("a".to_string()));
    }
}
