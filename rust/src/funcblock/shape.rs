//! Resolve-level canonicalization of MiniC function bodies.
//!
//! Function-block detection (arXiv:2004.09883 §III: "detection of offload
//! target function blocks") must not depend on identifier spelling or
//! statement noise, so every function is first normalized into a
//! [`FnShape`]:
//!
//! * **interned names** — array identifiers become dense `u32` ids in a
//!   per-function intern table (the same trick [`crate::minic::resolve`]
//!   plays for the VM), so two FIR banks with differently named taps
//!   normalize identically;
//! * **loop-structure skeleton** — the nest shape as a paren string
//!   (`"(((())))"` for a four-deep nest), which is what separates a
//!   matmul from an elementwise map long before any semantics run;
//! * **operation multiset** — static counts of multiplies, adds,
//!   divides, `sqrt`, transcendentals, min/max and comparisons over the
//!   whole body.
//!
//! The shape is deliberately lossy: it exists to *propose* catalog
//! matches cheaply. Every proposal is then behaviorally confirmed by
//! [`super::confirm`] — the paper's "verify by sample test" discipline —
//! so a shape that over-matches costs a confirmation run, never a wrong
//! replacement.

use crate::minic::ast::{Expr, Function, LValue, LoopId, Stmt};

/// Static operation multiset of a function body (syntactic counts — the
/// dynamic profile is the planner's job, not the detector's).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpMultiset {
    pub mul: u32,
    pub add_sub: u32,
    pub div: u32,
    pub sqrt: u32,
    /// sin/cos/tan/exp/log/pow.
    pub trig: u32,
    /// fmin/fmax/fabs/floor/ceil.
    pub minmax: u32,
    pub cmp: u32,
    /// Calls to user-defined (non-builtin) functions.
    pub user_calls: u32,
}

/// Canonical form of one function: what block detection matches against.
#[derive(Debug, Clone)]
pub struct FnShape {
    pub func: String,
    pub params: usize,
    /// Loop-nest skeleton: one `(` ... `)` pair per loop statement,
    /// nesting mirrored, siblings adjacent.
    pub skeleton: String,
    /// Deepest loop nesting level (1 = a single non-nested loop).
    pub max_depth: usize,
    pub ops: OpMultiset,
    /// Intern table: array names referenced anywhere in the body.
    pub arrays: Vec<String>,
    /// Interned ids of arrays read (indexed loads).
    pub reads: Vec<u32>,
    /// Interned ids of arrays written (indexed stores).
    pub writes: Vec<u32>,
    /// Every loop statement in the body, in source order.
    pub loops: Vec<LoopId>,
    /// Whether the body assigns to a bare (non-indexed) name that is not
    /// declared locally — i.e. mutates a global scalar. Such side
    /// effects are invisible to array-output comparison, so the detector
    /// refuses to propose these functions.
    pub writes_outer_scalar: bool,
}

impl FnShape {
    pub fn intern_id(&self, name: &str) -> Option<u32> {
        self.arrays
            .iter()
            .position(|a| a == name)
            .map(|i| i as u32)
    }

    pub fn reads_array(&self, name: &str) -> bool {
        self.intern_id(name)
            .is_some_and(|id| self.reads.contains(&id))
    }

    pub fn writes_array(&self, name: &str) -> bool {
        self.intern_id(name)
            .is_some_and(|id| self.writes.contains(&id))
    }
}

/// Normalize one function.
pub fn shape_of(f: &Function) -> FnShape {
    let mut sh = Shaper {
        shape: FnShape {
            func: f.name.clone(),
            params: f.params.len(),
            skeleton: String::new(),
            max_depth: 0,
            ops: OpMultiset::default(),
            arrays: Vec::new(),
            reads: Vec::new(),
            writes: Vec::new(),
            loops: Vec::new(),
            writes_outer_scalar: false,
        },
        depth: 0,
        locals: Vec::new(),
    };
    sh.locals
        .extend(f.params.iter().map(|p| p.name.clone()));
    for s in &f.body {
        sh.stmt(s);
    }
    sh.shape
}

struct Shaper {
    shape: FnShape,
    depth: usize,
    /// Names declared in the body so far (flat — canonicalization does
    /// not need scope-exact resolution, only local-vs-outer).
    locals: Vec<String>,
}

impl Shaper {
    fn intern(&mut self, name: &str) -> u32 {
        if let Some(id) = self.shape.intern_id(name) {
            return id;
        }
        self.shape.arrays.push(name.to_string());
        (self.shape.arrays.len() - 1) as u32
    }

    fn note_read(&mut self, name: &str) {
        let id = self.intern(name);
        if !self.shape.reads.contains(&id) {
            self.shape.reads.push(id);
        }
    }

    fn note_write(&mut self, name: &str) {
        let id = self.intern(name);
        if !self.shape.writes.contains(&id) {
            self.shape.writes.push(id);
        }
    }

    fn stmt(&mut self, s: &Stmt) {
        match s {
            Stmt::Decl { name, init, .. } => {
                self.locals.push(name.clone());
                if let Some(e) = init {
                    self.expr(e);
                }
            }
            Stmt::Assign { target, op, value, .. } => {
                self.expr(value);
                if *op != crate::minic::ast::AssignOp::Set {
                    self.shape.ops.add_sub += 1;
                }
                match target {
                    LValue::Var(n) => {
                        if !self.locals.iter().any(|l| l == n) {
                            self.shape.writes_outer_scalar = true;
                        }
                    }
                    LValue::Index { base, indices } => {
                        for i in indices {
                            self.expr(i);
                        }
                        self.note_write(base);
                    }
                }
            }
            Stmt::If { cond, then_branch, else_branch, .. } => {
                self.expr(cond);
                self.shape.ops.cmp += 1;
                for s in then_branch.iter().chain(else_branch) {
                    self.stmt(s);
                }
            }
            Stmt::For { id, init, cond, step, body, .. } => {
                self.shape.loops.push(*id);
                if let Some(s) = init {
                    self.stmt(s);
                }
                if let Some(e) = cond {
                    self.expr(e);
                }
                self.open_loop(body, step.as_deref());
            }
            Stmt::While { id, cond, body, .. } => {
                self.shape.loops.push(*id);
                self.expr(cond);
                self.open_loop(body, None);
            }
            Stmt::Return { value, .. } => {
                if let Some(e) = value {
                    self.expr(e);
                }
            }
            Stmt::ExprStmt { expr, .. } => self.expr(expr),
        }
    }

    fn open_loop(&mut self, body: &[Stmt], step: Option<&Stmt>) {
        self.depth += 1;
        self.shape.max_depth = self.shape.max_depth.max(self.depth);
        self.shape.skeleton.push('(');
        for s in body {
            self.stmt(s);
        }
        if let Some(s) = step {
            self.stmt(s);
        }
        self.shape.skeleton.push(')');
        self.depth -= 1;
    }

    fn expr(&mut self, e: &Expr) {
        use crate::minic::ast::BinOp::*;
        match e {
            Expr::Index { base, indices } => {
                for i in indices {
                    self.expr(i);
                }
                self.note_read(base);
            }
            Expr::Bin { op, lhs, rhs } => {
                self.expr(lhs);
                self.expr(rhs);
                match op {
                    Mul => self.shape.ops.mul += 1,
                    Add | Sub => self.shape.ops.add_sub += 1,
                    Div | Rem => self.shape.ops.div += 1,
                    Eq | Ne | Lt | Gt | Le | Ge => self.shape.ops.cmp += 1,
                    And | Or => self.shape.ops.cmp += 1,
                }
            }
            Expr::Un { operand, .. } | Expr::Cast { operand, .. } => {
                self.expr(operand)
            }
            Expr::Call { name, args } => {
                for a in args {
                    self.expr(a);
                }
                match name.as_str() {
                    "sqrt" | "sqrtf" => self.shape.ops.sqrt += 1,
                    "sin" | "cos" | "tan" | "exp" | "log" | "pow" => {
                        self.shape.ops.trig += 1
                    }
                    "fmin" | "fmax" | "fabs" | "floor" | "ceil" => {
                        self.shape.ops.minmax += 1
                    }
                    "printf" => {}
                    _ => self.shape.ops.user_calls += 1,
                }
            }
            Expr::IntLit(_)
            | Expr::FloatLit(_)
            | Expr::StrLit(_)
            | Expr::Var(_) => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::parse;
    use crate::workloads;

    fn shape(src: &str, func: &str) -> FnShape {
        let prog = parse(src).unwrap();
        shape_of(prog.function(func).unwrap())
    }

    #[test]
    fn fir_all_skeleton_is_a_four_deep_nest() {
        let s = shape(workloads::TDFIR_C, "fir_all");
        assert_eq!(s.skeleton, "(((())))");
        assert_eq!(s.max_depth, 4);
        assert!(s.ops.mul >= 4);
        assert!(s.ops.add_sub >= 4);
        assert!(!s.writes_outer_scalar);
        assert_eq!(s.loops.len(), 4);
    }

    #[test]
    fn magnitude_is_a_single_sqrt_loop() {
        let s = shape(workloads::MRIQ_C, "magnitude");
        assert_eq!(s.skeleton, "()");
        assert_eq!(s.max_depth, 1);
        assert_eq!(s.ops.sqrt, 1);
        assert!(s.reads_array("qr") && s.reads_array("qi"));
        assert!(s.writes_array("qmag"));
    }

    #[test]
    fn interning_is_spelling_independent() {
        let a = shape(
            "#define N 8\nfloat x[N]; float y[N];\n\
             void f() { for (int i = 0; i < N; i++) { y[i] = x[i] * 2.0; } }",
            "f",
        );
        let b = shape(
            "#define N 8\nfloat alpha[N]; float beta[N];\n\
             void f() { for (int i = 0; i < N; i++) { beta[i] = alpha[i] * 2.0; } }",
            "f",
        );
        assert_eq!(a.skeleton, b.skeleton);
        assert_eq!(a.ops, b.ops);
        assert_eq!(a.reads, b.reads);
        assert_eq!(a.writes, b.writes);
    }

    #[test]
    fn global_scalar_writes_are_flagged() {
        let s = shape(workloads::TDFIR_C, "energy");
        assert!(s.writes_outer_scalar);
        let ok = shape(workloads::TDFIR_C, "clear_out");
        assert!(!ok.writes_outer_scalar);
    }

    #[test]
    fn siblings_sit_adjacent_in_the_skeleton() {
        let s = shape(workloads::SOBEL_C, "stats");
        assert_eq!(s.skeleton, "()()");
        assert_eq!(s.max_depth, 1);
    }
}
