//! Behavioral confirmation of proposed block replacements — the paper's
//! "verify by sample test" discipline applied to function blocks.
//!
//! A structural match proves nothing: a function can be FIR-*shaped* and
//! still compute something else (a saturating accumulator, a scaled
//! variant, a transposed access). Before any replacement is planned, the
//! candidate function and the catalog's reference semantics are both
//! executed through the slot-resolved VM ([`crate::minic::Vm`] via
//! [`EngineKind`]) on deterministically sampled inputs:
//!
//! 1. fill the candidate's input arrays with seeded PCG32 samples,
//! 2. call the candidate function (zero-argument, operating on globals),
//! 3. instantiate the catalog reference program for the extracted
//!    binding, fill its inputs with the *same* samples, run `block()`,
//! 4. compare every output array elementwise.
//!
//! Multiple sample rounds with distinct fills guard against coincidental
//! agreement (e.g. clamps that only engage on large values). Any
//! disagreement, any runtime error, and any parse failure of the
//! reference all reject the proposal — replacements are conservative by
//! construction.

use crate::minic::{parse, EngineKind, MiniCError};
use crate::util::rng::Pcg32;

use super::catalog::Catalog;
use super::detect::BlockMatch;

/// Outcome of one confirmation run.
#[derive(Debug, Clone, PartialEq)]
pub enum Confirmation {
    /// All sample rounds agreed (max |err| over all rounds attached).
    Confirmed { max_abs_err: f64 },
    /// Outputs disagreed on some sample (worst element difference).
    Mismatch { max_abs_err: f64 },
    /// The candidate or the reference failed to run.
    Error(String),
}

impl Confirmation {
    pub fn is_confirmed(&self) -> bool {
        matches!(self, Confirmation::Confirmed { .. })
    }
}

/// Tolerance for output agreement. Candidate and reference run in the
/// same VM arithmetic; a true match accumulates in the same order, so
/// this is a guard band, not a fudge factor.
pub const TOLERANCE: f64 = 1e-9;

/// Sample rounds per confirmation (distinct fills each).
pub const SAMPLE_ROUNDS: u64 = 3;

/// Confirm one proposed match against the catalog's reference semantics.
pub fn confirm(
    prog: &crate::minic::Program,
    m: &BlockMatch,
    catalog: &Catalog,
    engine: EngineKind,
    seed: u64,
) -> Confirmation {
    let ref_src = catalog.reference_source(&m.binding);
    let ref_prog = match parse(&ref_src) {
        Ok(p) => p,
        Err(e) => {
            return Confirmation::Error(format!(
                "catalog reference failed to parse: {e}"
            ))
        }
    };

    let mut worst = 0.0f64;
    for round in 0..SAMPLE_ROUNDS {
        match confirm_round(prog, &ref_prog, m, engine, seed ^ round) {
            Ok(err) if err <= TOLERANCE => worst = worst.max(err),
            Ok(err) => return Confirmation::Mismatch { max_abs_err: err },
            Err(e) => return Confirmation::Error(format!("{e}")),
        }
    }
    Confirmation::Confirmed { max_abs_err: worst }
}

fn confirm_round(
    prog: &crate::minic::Program,
    ref_prog: &crate::minic::Program,
    m: &BlockMatch,
    engine: EngineKind,
    seed: u64,
) -> Result<f64, MiniCError> {
    // Fresh engines per round: globals re-zeroed, no state bleed.
    let mut cand = engine.build(prog)?;
    let mut refr = engine.build(ref_prog)?;

    // One sample vector per *unique* candidate input array (an array
    // playing two roles — e.g. sqrt-mag of a single array — must feed
    // both reference inputs with the same values).
    let mut rng = Pcg32::new(seed, 0x666e_6263); // "fnbc"
    let inputs = m.binding.inputs();
    let mut fills: Vec<(String, Vec<f64>)> = Vec::new();
    for name in &inputs {
        if fills.iter().any(|(n, _)| n == name) {
            continue;
        }
        let r = cand.global_array(name).ok_or_else(|| {
            MiniCError::Runtime(format!(
                "block input `{name}` is not a global array"
            ))
        })?;
        let len = cand.array(r).data.len();
        let vals: Vec<f64> = (0..len)
            .map(|_| rng.next_u32() as f64 / u32::MAX as f64 * 2.0 - 1.0)
            .collect();
        cand.array_mut(r).data.copy_from_slice(&vals);
        fills.push((name.to_string(), vals));
    }
    for (name, ref_name) in
        inputs.iter().zip(m.binding.reference_inputs())
    {
        let vals = &fills
            .iter()
            .find(|(n, _)| n == name)
            .expect("filled above")
            .1;
        let r = refr.global_array(ref_name).ok_or_else(|| {
            MiniCError::Runtime(format!(
                "reference input `{ref_name}` missing"
            ))
        })?;
        let data = &mut refr.array_mut(r).data;
        if data.len() != vals.len() {
            return Err(MiniCError::Runtime(format!(
                "reference `{ref_name}` extent {} != candidate `{name}` {}",
                data.len(),
                vals.len()
            )));
        }
        data.copy_from_slice(vals);
    }

    cand.call(&m.func, &[])?;
    refr.call("block", &[])?;

    let mut max_err = 0.0f64;
    for (out, ref_out) in m
        .binding
        .outputs()
        .iter()
        .zip(m.binding.reference_outputs())
    {
        let co = cand.global_array(out).ok_or_else(|| {
            MiniCError::Runtime(format!(
                "block output `{out}` is not a global array"
            ))
        })?;
        let ro = refr.global_array(ref_out).ok_or_else(|| {
            MiniCError::Runtime(format!(
                "reference output `{ref_out}` missing"
            ))
        })?;
        let cd = &cand.array(co).data;
        let rd = &refr.array(ro).data;
        if cd.len() != rd.len() {
            return Err(MiniCError::Runtime(format!(
                "output `{out}` extent {} != reference {}",
                cd.len(),
                rd.len()
            )));
        }
        for (c, r) in cd.iter().zip(rd) {
            max_err = max_err.max((c - r).abs());
        }
    }
    Ok(max_err)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::funcblock::catalog::BlockKind;
    use crate::funcblock::detect::detect;
    use crate::minic::parse;
    use crate::workloads;

    fn confirm_kind(src: &str, kind: BlockKind) -> Confirmation {
        let prog = parse(src).unwrap();
        let catalog = Catalog::builtin();
        let m = detect(&prog, &catalog)
            .into_iter()
            .find(|m| m.kind == kind)
            .expect("proposed");
        confirm(&prog, &m, &catalog, EngineKind::default(), 42)
    }

    #[test]
    fn tdfir_fir_bank_confirms() {
        let c = confirm_kind(workloads::TDFIR_C, BlockKind::Fir);
        assert!(c.is_confirmed(), "{c:?}");
    }

    #[test]
    fn mriq_sqrt_magnitude_confirms() {
        let c = confirm_kind(workloads::MRIQ_C, BlockKind::SqrtMag);
        assert!(c.is_confirmed(), "{c:?}");
    }

    #[test]
    fn sobel_gradient_confirms() {
        let c = confirm_kind(workloads::SOBEL_C, BlockKind::Stencil2d);
        assert!(c.is_confirmed(), "{c:?}");
    }

    #[test]
    fn synthetic_gemm_confirms() {
        let src = "
#define NI 5
#define NJ 7
#define NK 3
float a[NI][NK]; float b[NK][NJ]; float c[NI][NJ];
void gemm() {
    for (int i = 0; i < NI; i++) {
        for (int j = 0; j < NJ; j++) {
            for (int k = 0; k < NK; k++) {
                c[i][j] += a[i][k] * b[k][j];
            }
        }
    }
}
int main() { gemm(); return 0; }";
        let c = confirm_kind(src, BlockKind::MatMul);
        assert!(c.is_confirmed(), "{c:?}");
    }

    #[test]
    fn saturating_fir_is_rejected_by_the_sample_test() {
        // The headline false-positive case: structurally FIR-shaped,
        // behaviorally different (saturating accumulate). The detector
        // proposes it; the sample test must kill it.
        let c = confirm_kind(crate::funcblock::SAT_FIR_SRC, BlockKind::Fir);
        assert!(
            matches!(c, Confirmation::Mismatch { .. }),
            "saturating FIR must be a mismatch, got {c:?}"
        );
    }

    #[test]
    fn scaled_sqrt_magnitude_is_rejected() {
        // sqrt(a^2 + b^2) * 0.5 written as sqrt((a*0.5)^2 + ...) would
        // not bind; a plain scaled copy binds structurally via an inner
        // sqrt but disagrees numerically.
        let src = "
#define N 32
float a[N]; float b[N]; float o[N];
void mag_biased() {
    for (int i = 0; i < N; i++) {
        o[i] = sqrt(a[i] * a[i] + b[i] * b[i]);
        o[i] = o[i] + 0.001;
    }
}
int main() { mag_biased(); return 0; }";
        let c = confirm_kind(src, BlockKind::SqrtMag);
        assert!(
            matches!(c, Confirmation::Mismatch { .. }),
            "biased magnitude must mismatch, got {c:?}"
        );
    }

    #[test]
    fn confirmation_is_deterministic_under_a_seed() {
        let prog = parse(workloads::MRIQ_C).unwrap();
        let catalog = Catalog::builtin();
        let m = detect(&prog, &catalog)
            .into_iter()
            .find(|m| m.kind == BlockKind::SqrtMag)
            .unwrap();
        let a = confirm(&prog, &m, &catalog, EngineKind::default(), 7);
        let b = confirm(&prog, &m, &catalog, EngineKind::default(), 7);
        assert_eq!(a, b);
    }
}
