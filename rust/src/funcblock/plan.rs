//! The replacement planner: turn confirmed block matches into priced,
//! claim-carrying replacements the offload pipeline can act on.
//!
//! Detection proposes, confirmation verifies, and this module decides:
//! for each behaviorally-confirmed block it gathers the *dynamic*
//! figures a pricing model needs (profiled op counts, innermost
//! iteration totals, invocation counts, transfer footprints) into a
//! [`ConfirmedBlock`], and exposes the profitability arithmetic shared
//! by every destination's [`crate::search::Backend`] pricing hook.
//!
//! A replacement **claims** every loop of its function: the narrowing
//! funnel must not offer those loops to the GA/funnel loop search again
//! (they are pre-claimed regions), and the combined plan accounts the
//! block's time instead of their CPU time.

use crate::analysis::Analysis;
use crate::minic::ast::LoopId;
use crate::minic::{EngineKind, OpCounts, Program};

use super::catalog::{BlockKind, Catalog};
use super::confirm::{confirm, Confirmation};
use super::detect::{detect, BlockBinding, BlockMatch};

/// A behaviorally-confirmed block with the dynamic figures pricing
/// needs. Destination-independent — one of these is priced once per
/// backend.
#[derive(Debug, Clone)]
pub struct ConfirmedBlock {
    pub kind: BlockKind,
    pub func: String,
    pub binding: BlockBinding,
    /// Every loop of the replaced function — the pre-claimed region the
    /// loop funnel must skip.
    pub loops: Vec<LoopId>,
    /// Profiled op counts of the function's top-level loops (nested
    /// loops included via the profiler's subtree attribution).
    pub ops: OpCounts,
    /// Total innermost iterations across the profiling run (the work
    /// units a spatial core consumes).
    pub inner_units: u64,
    /// Outer-loop entries — how many times the block's buffers cross
    /// the PCIe boundary.
    pub entries: u64,
    /// Input / output transfer footprints, bytes.
    pub bytes_in: u64,
    pub bytes_out: u64,
    /// Worst sample-test error observed during confirmation.
    pub max_abs_err: f64,
}

/// What one destination charges for one confirmed block.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockCost {
    /// Naive loop-nest time on the all-CPU baseline, seconds.
    pub cpu_s: f64,
    /// IP-core / library time on the destination (compute + transfers),
    /// seconds.
    pub accel_s: f64,
    /// Destination build (core integration / library link), seconds.
    pub build_s: f64,
}

impl BlockCost {
    /// A replacement is planned only when the destination strictly
    /// beats the naive nest.
    pub fn profitable(&self) -> bool {
        self.accel_s < self.cpu_s
    }
}

/// A priced replacement bound for one destination — what the pipeline
/// carries into the solution and the pattern DB.
#[derive(Debug, Clone)]
pub struct BlockReplacement {
    pub kind: BlockKind,
    pub func: String,
    pub ip_name: &'static str,
    /// Claimed loops (the whole function's).
    pub loops: Vec<LoopId>,
    pub cpu_s: f64,
    pub accel_s: f64,
    pub build_s: f64,
    /// Sample-test outcome. Always `true` for planned replacements —
    /// unconfirmed matches never reach this type — recorded so reports
    /// and the pattern DB state it explicitly.
    pub confirmed: bool,
}

impl BlockReplacement {
    /// Block-local speedup (naive nest vs core).
    pub fn speedup(&self) -> f64 {
        if self.accel_s > 0.0 {
            self.cpu_s / self.accel_s
        } else {
            f64::INFINITY
        }
    }
}

/// Detect, confirm, and measure every function block in a program.
/// Returns destination-independent confirmed blocks; pricing is the
/// backend's job. Conservative by construction:
///
/// * the profiled entry function itself is never replaced;
/// * every loop of the function must have executed under the profiling
///   run (a cold block has no figures to price);
/// * the function's observable arrays must be fully covered by the
///   binding (no hidden inputs or outputs);
/// * the sample test must confirm behavior.
pub fn find_blocks(
    prog: &Program,
    analysis: &Analysis,
    catalog: &Catalog,
    engine: EngineKind,
    seed: u64,
) -> Vec<ConfirmedBlock> {
    let mut out: Vec<ConfirmedBlock> = Vec::new();
    for m in detect(prog, catalog) {
        if m.func == analysis.entry {
            continue;
        }
        let Some(cb) = measure_block(prog, analysis, &m) else {
            continue;
        };
        // One claim per loop: a function already claimed (two catalog
        // kinds binding the same body) keeps its first match.
        if cb
            .loops
            .iter()
            .any(|l| out.iter().any(|o| o.loops.contains(l)))
        {
            continue;
        }
        match confirm(prog, &m, catalog, engine, seed) {
            Confirmation::Confirmed { max_abs_err } => {
                out.push(ConfirmedBlock {
                    max_abs_err,
                    ..cb
                });
            }
            Confirmation::Mismatch { .. } | Confirmation::Error(_) => {}
        }
    }
    out
}

/// Dynamic figures for one match, or `None` when the block is not
/// soundly replaceable (cold loops, uncovered arrays).
fn measure_block(
    prog: &Program,
    analysis: &Analysis,
    m: &BlockMatch,
) -> Option<ConfirmedBlock> {
    let loops: Vec<LoopId> = analysis
        .loops
        .iter()
        .filter(|l| l.info.function == m.func)
        .map(|l| l.id())
        .collect();
    if loops.is_empty() {
        return None;
    }

    // Top-level loops of the function: their profiles subsume nested
    // work via the profiler's delta attribution.
    let tops: Vec<LoopId> = analysis
        .loops
        .iter()
        .filter(|l| l.info.function == m.func && l.info.parent.is_none())
        .map(|l| l.id())
        .collect();

    let mut ops = OpCounts::default();
    let mut entries = 0u64;
    for id in &tops {
        let lp = analysis.profile.loop_profile(*id)?;
        ops = ops.plus(&lp.ops);
        entries = entries.max(lp.entries);
    }
    // Every claimed loop must have run (cold loops make the block's
    // behavior unobserved along some path — do not replace).
    let mut inner_units = 0u64;
    for id in &loops {
        let lp = analysis.profile.loop_profile(*id)?;
        inner_units = inner_units.max(lp.trips);
    }

    // Full coverage of the observable state: everything the function's
    // loops touch must be a bound input or output, and the nest must
    // not depend on *free* global scalars — the sample test zero-fills
    // everything except the bound input arrays, so a caller-set scalar
    // (a shift, a scale) would be confirmed against its zero value and
    // silently mis-replaced in production.
    let inputs = m.binding.inputs();
    let outputs = m.binding.outputs();
    for id in &tops {
        let info = &analysis.loop_by_id(*id)?.info;
        if !info.free_scalars.is_empty() {
            return None;
        }
        for r in &info.arrays_read {
            if !inputs.contains(&r.as_str())
                && !outputs.contains(&r.as_str())
            {
                return None;
            }
        }
        for w in &info.arrays_written {
            if !outputs.contains(&w.as_str()) {
                return None;
            }
        }
    }

    let array_bytes = |name: &str| -> u64 {
        global_array_bytes(prog, name).unwrap_or(0)
    };
    let mut seen: Vec<&str> = Vec::new();
    let mut bytes_in = 0u64;
    for &name in &inputs {
        if !seen.contains(&name) {
            seen.push(name);
            bytes_in += array_bytes(name);
        }
    }
    let bytes_out: u64 = outputs.iter().map(|n| array_bytes(n)).sum();

    Some(ConfirmedBlock {
        kind: m.kind,
        func: m.func.clone(),
        binding: m.binding.clone(),
        loops,
        ops,
        inner_units,
        entries: entries.max(1),
        bytes_in,
        bytes_out,
        max_abs_err: 0.0,
    })
}

/// Byte size of a global array declaration.
fn global_array_bytes(prog: &Program, name: &str) -> Option<u64> {
    prog.globals.iter().find_map(|g| match g {
        crate::minic::ast::Stmt::Decl {
            name: n,
            ty: crate::minic::ast::Type::Array(elem, dims),
            ..
        } if n == name => Some(
            dims.iter().product::<usize>() as u64 * elem.size_bytes(),
        ),
        _ => None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::minic::parse;
    use crate::workloads;

    fn blocks_for(src: &str) -> (Program, Analysis, Vec<ConfirmedBlock>) {
        let prog = parse(src).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let blocks = find_blocks(
            &prog,
            &an,
            &Catalog::builtin(),
            EngineKind::default(),
            42,
        );
        (prog, an, blocks)
    }

    #[test]
    fn tdfir_plans_the_fir_bank_with_profiled_figures() {
        let (_p, an, blocks) = blocks_for(workloads::TDFIR_C);
        let fir = blocks
            .iter()
            .find(|b| b.kind == BlockKind::Fir)
            .expect("fir bank planned");
        assert_eq!(fir.func, "fir_all");
        // fir_all is L12..L15.
        assert_eq!(
            fir.loops,
            vec![LoopId(12), LoopId(13), LoopId(14), LoopId(15)]
        );
        // REP * M * N * K innermost iterations.
        assert_eq!(fir.inner_units, 2 * 8 * 1024 * 16);
        assert_eq!(fir.entries, 1);
        // Coef (2 × 8×16) + input (2 × 1040) floats in, 2 × 8×1024 out.
        assert_eq!(fir.bytes_in, (2 * 8 * 16 + 2 * 1040) * 4);
        assert_eq!(fir.bytes_out, 2 * 8 * 1024 * 4);
        assert!(fir.ops.f_mul > 0);
        // The claimed ops are a strict subset of the whole program's.
        assert!(fir.ops.f_mul < an.profile.total.f_mul);
    }

    #[test]
    fn every_bundled_app_plans_at_least_one_block() {
        for app in workloads::APPS {
            let (_p, _an, blocks) =
                blocks_for(workloads::source(app).unwrap());
            assert!(
                !blocks.is_empty(),
                "{app}: no confirmed block — catalog no longer covers it"
            );
        }
    }

    #[test]
    fn saturating_fir_never_reaches_the_plan() {
        let (_p, _an, blocks) = blocks_for(crate::funcblock::SAT_FIR_SRC);
        assert!(
            blocks.is_empty(),
            "behaviorally-different FIR must not be planned: {blocks:?}"
        );
    }

    #[test]
    fn entry_function_is_never_replaced() {
        // A program whose entry itself is a perfect sqrt-mag block: the
        // entry is the thing being offloaded, not a callee to replace.
        let src = "
#define N 16
float a[N]; float b[N]; float o[N];
int main() {
    for (int i = 0; i < N; i++) { o[i] = sqrt(a[i] * a[i] + b[i] * b[i]); }
    return 0;
}";
        let (_p, _an, blocks) = blocks_for(src);
        assert!(blocks.is_empty());
    }

    #[test]
    fn free_scalar_dependence_is_never_replaced() {
        // Behavior depends on a caller-set global scalar: the sample
        // test would only ever see its zero value (candidate and
        // reference agree under shift == 0), so without the free-scalar
        // gate this would be confirmed — and then mis-replaced for the
        // production run where main() sets shift = 1.
        let src = "
#define N 16
int shift;
float a[N]; float b[N]; float o[N];
void mag() {
    for (int i = 0; i < N; i++) {
        o[i] = sqrt(a[(i + shift) % N] * a[(i + shift) % N] + b[i] * b[i]);
    }
}
int main() { shift = 1; mag(); return 0; }";
        let (_p, _an, blocks) = blocks_for(src);
        assert!(
            blocks.is_empty(),
            "free-scalar-dependent block must not be replaced: {blocks:?}"
        );
    }

    #[test]
    fn cold_blocks_are_not_planned() {
        // The block function never runs under the profiling entry: no
        // figures, no replacement.
        let src = "
#define N 16
float a[N]; float b[N]; float o[N];
void mag() {
    for (int i = 0; i < N; i++) { o[i] = sqrt(a[i] * a[i] + b[i] * b[i]); }
}
int main() { return 0; }";
        let (_p, _an, blocks) = blocks_for(src);
        assert!(blocks.is_empty());
    }

    #[test]
    fn block_cost_profitability() {
        let c = BlockCost {
            cpu_s: 1.0,
            accel_s: 0.2,
            build_s: 60.0,
        };
        assert!(c.profitable());
        let flat = BlockCost {
            cpu_s: 1.0,
            accel_s: 1.0,
            build_s: 0.0,
        };
        assert!(!flat.profitable());
    }
}
