//! The function-block catalog: known algorithmic blocks with reference
//! semantics and per-device IP-core / library performance models.
//!
//! The follow-on papers (arXiv:2004.09883, arXiv:2005.04174) get their
//! largest speedups not from GA-searching loop subsets but from
//! recognizing *whole algorithmic blocks* — FFT, matrix multiply, 2D
//! convolution — and swapping in hand-optimized implementations: an FPGA
//! IP core, a GPU vendor library, or a tuned CPU library. This module is
//! that catalog, sized to the bundled workloads: each of tdfir / mriq /
//! sobel contains at least one entry.
//!
//! Every [`BlockSpec`] carries three things:
//!
//! 1. **structural requirements** the detector checks against a
//!    normalized [`FnShape`] (cheap, lossy — proposals only);
//! 2. **reference semantics** — a canonical MiniC program generated for
//!    a concrete [`BlockBinding`], executed through the slot-resolved VM
//!    next to the candidate function for behavioral confirmation
//!    ([`super::confirm`]);
//! 3. **performance models** per destination: the FPGA core's
//!    lanes/depth/fmax (hand-closed timing, unlike the auto-generated
//!    `hls::` kernels), the GPU library's sustained-efficiency factor
//!    (vendor library vs the `gpu::device` auto-offload factor), and a
//!    CPU-library baseline factor.
//!
//! The catalog's [`fingerprint`](Catalog::fingerprint) is part of the
//! pattern-DB reuse key: a plan produced under one catalog must not be
//! silently replayed after the catalog (or its models) changes.

use super::detect::BlockBinding;
use super::shape::FnShape;

/// The block kinds the catalog knows.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BlockKind {
    /// Dense matrix multiply `C[i][j] += A[i][k] * B[k][j]`.
    MatMul,
    /// Complex FIR filter bank (the tdfir hot nest).
    Fir,
    /// 3x3 Sobel gradient-magnitude stencil (2D convolution family).
    Stencil2d,
    /// Elementwise complex magnitude `out[i] = sqrt(a[i]^2 + b[i]^2)`.
    SqrtMag,
}

impl BlockKind {
    pub fn name(self) -> &'static str {
        match self {
            BlockKind::MatMul => "matmul",
            BlockKind::Fir => "fir",
            BlockKind::Stencil2d => "stencil2d",
            BlockKind::SqrtMag => "sqrt-mag",
        }
    }
}

impl std::fmt::Display for BlockKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Hand-optimized FPGA IP core timing (`hls::`-style resources, but with
/// the numbers a vendor core ships with, not what auto-generated OpenCL
/// reaches: wider spatial replication, deeper pipeline, closed timing).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FpgaCoreModel {
    /// Parallel processing lanes (spatial replication of the inner op).
    pub lanes: u64,
    /// Pipeline fill depth, cycles.
    pub depth: u64,
    /// Closed clock, Hz.
    pub fmax_hz: f64,
    /// Fraction of device resources the core occupies.
    pub utilization: f64,
    /// Integration build (the core itself is pre-verified; this is the
    /// partial-reconfiguration / linking compile), seconds.
    pub build_seconds: f64,
}

/// GPU vendor-library timing knobs, applied on top of the
/// [`crate::gpu::GpuDevice`] model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuLibModel {
    /// Fraction of peak ALU throughput the library sustains (vs the
    /// device's `auto_efficiency` for auto-generated kernels).
    pub efficiency: f64,
    /// Link/build step, seconds.
    pub build_seconds: f64,
}

/// Tuned CPU library baseline (kept at 1.0 for the bundled control
/// backend so the all-CPU destination stays the paper's exact
/// denominator; the knob exists for calibration experiments).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CpuLibModel {
    /// Speedup factor over the naive loop nest.
    pub speedup: f64,
}

/// One catalog entry.
#[derive(Debug, Clone)]
pub struct BlockSpec {
    pub kind: BlockKind,
    /// Human-readable core name for reports.
    pub ip_name: &'static str,
    /// Structural gate: minimum loop-nest depth.
    pub min_depth: usize,
    /// Structural gate: maximum loop-nest depth (0 = unbounded).
    pub max_depth: usize,
    /// Structural gate: minimum static multiply count.
    pub min_mul: u32,
    /// Structural gate: requires a `sqrt` in the body.
    pub needs_sqrt: bool,
    pub fpga: FpgaCoreModel,
    pub gpu: GpuLibModel,
    pub cpu: CpuLibModel,
}

impl BlockSpec {
    /// Cheap structural proposal check against a normalized shape. The
    /// detector refines this with per-kind binding extraction; the
    /// sample test makes the final call.
    pub fn structural_match(&self, shape: &FnShape) -> bool {
        shape.params == 0
            && !shape.writes_outer_scalar
            && shape.ops.user_calls == 0
            && shape.max_depth >= self.min_depth
            && (self.max_depth == 0 || shape.max_depth <= self.max_depth)
            && shape.ops.mul >= self.min_mul
            && (!self.needs_sqrt || shape.ops.sqrt >= 1)
            && !shape.writes.is_empty()
    }
}

/// The block catalog.
#[derive(Debug, Clone)]
pub struct Catalog {
    specs: Vec<BlockSpec>,
}

impl Catalog {
    /// The built-in catalog: matmul, complex FIR bank, Sobel 3x3
    /// stencil, sqrt-magnitude — chosen so every bundled workload
    /// contains at least one.
    pub fn builtin() -> Catalog {
        Catalog {
            specs: vec![
                BlockSpec {
                    kind: BlockKind::MatMul,
                    ip_name: "systolic GEMM core / cuBLAS sgemm",
                    min_depth: 3,
                    max_depth: 3,
                    min_mul: 1,
                    needs_sqrt: false,
                    fpga: FpgaCoreModel {
                        lanes: 128,
                        depth: 64,
                        fmax_hz: 300.0e6,
                        utilization: 0.30,
                        build_seconds: 1800.0,
                    },
                    gpu: GpuLibModel {
                        efficiency: 0.85,
                        build_seconds: 10.0,
                    },
                    cpu: CpuLibModel { speedup: 1.0 },
                },
                BlockSpec {
                    kind: BlockKind::Fir,
                    ip_name: "systolic complex FIR bank core / cuFFT-conv",
                    min_depth: 3,
                    max_depth: 4,
                    min_mul: 4,
                    needs_sqrt: false,
                    fpga: FpgaCoreModel {
                        lanes: 64,
                        depth: 96,
                        fmax_hz: 350.0e6,
                        utilization: 0.22,
                        build_seconds: 1800.0,
                    },
                    gpu: GpuLibModel {
                        efficiency: 0.60,
                        build_seconds: 10.0,
                    },
                    cpu: CpuLibModel { speedup: 1.0 },
                },
                BlockSpec {
                    kind: BlockKind::Stencil2d,
                    ip_name: "line-buffered Sobel 3x3 core / NPP filter",
                    min_depth: 2,
                    max_depth: 2,
                    min_mul: 4,
                    needs_sqrt: true,
                    fpga: FpgaCoreModel {
                        lanes: 32,
                        depth: 48,
                        fmax_hz: 330.0e6,
                        utilization: 0.15,
                        build_seconds: 1800.0,
                    },
                    gpu: GpuLibModel {
                        efficiency: 0.70,
                        build_seconds: 10.0,
                    },
                    cpu: CpuLibModel { speedup: 1.0 },
                },
                BlockSpec {
                    kind: BlockKind::SqrtMag,
                    ip_name: "streaming complex-magnitude core / thrust",
                    min_depth: 1,
                    max_depth: 1,
                    min_mul: 2,
                    needs_sqrt: true,
                    fpga: FpgaCoreModel {
                        lanes: 16,
                        depth: 40,
                        fmax_hz: 330.0e6,
                        utilization: 0.08,
                        build_seconds: 1800.0,
                    },
                    gpu: GpuLibModel {
                        efficiency: 0.50,
                        build_seconds: 10.0,
                    },
                    cpu: CpuLibModel { speedup: 1.0 },
                },
            ],
        }
    }

    /// Shared instance of the built-in catalog. It is a compile-time
    /// constant in spirit; rebuilding (and re-fingerprinting) it on
    /// every pipeline stage would be wasted work on hot paths.
    pub fn shared() -> &'static Catalog {
        static SHARED: std::sync::OnceLock<Catalog> =
            std::sync::OnceLock::new();
        SHARED.get_or_init(Catalog::builtin)
    }

    pub fn specs(&self) -> &[BlockSpec] {
        &self.specs
    }

    pub fn spec(&self, kind: BlockKind) -> &BlockSpec {
        self.specs
            .iter()
            .find(|s| s.kind == kind)
            .expect("catalog covers every BlockKind")
    }

    /// [`fingerprint`](Self::fingerprint) of the shared built-in
    /// catalog, computed once (the reuse key needs it on every
    /// pattern-DB lookup and store).
    pub fn shared_fingerprint() -> u64 {
        static FP: std::sync::OnceLock<u64> = std::sync::OnceLock::new();
        *FP.get_or_init(|| Catalog::shared().fingerprint())
    }

    /// Stable FNV-1a fingerprint over every spec (kinds, structural
    /// gates, and all performance-model knobs). Part of the pattern-DB
    /// reuse key: a stored plan is only replayed under the exact catalog
    /// that produced it.
    pub fn fingerprint(&self) -> u64 {
        use std::hash::Hasher;
        let mut canonical = String::new();
        for s in &self.specs {
            canonical.push_str(&format!(
                "{};d={}..{};mul={};sqrt={};fpga={}/{}/{:016x}/{:016x}/{:016x};gpu={:016x}/{:016x};cpu={:016x};",
                s.kind,
                s.min_depth,
                s.max_depth,
                s.min_mul,
                s.needs_sqrt,
                s.fpga.lanes,
                s.fpga.depth,
                s.fpga.fmax_hz.to_bits(),
                s.fpga.utilization.to_bits(),
                s.fpga.build_seconds.to_bits(),
                s.gpu.efficiency.to_bits(),
                s.gpu.build_seconds.to_bits(),
                s.cpu.speedup.to_bits(),
            ));
        }
        let mut h = crate::util::fnv::FnvHasher::default();
        h.write(canonical.as_bytes());
        h.finish()
    }

    /// The catalog's canonical reference program for a concrete binding
    /// — MiniC source whose `block()` entry computes the block's defined
    /// semantics over arrays with the candidate's exact dimensions. Run
    /// through the slot-resolved VM next to the candidate function by
    /// [`super::confirm`].
    pub fn reference_source(&self, binding: &BlockBinding) -> String {
        match binding {
            BlockBinding::MatMul { n_i, n_j, n_k, .. } => format!(
                "#define NI {n_i}\n#define NJ {n_j}\n#define NK {n_k}\n\
                 float fb_a[NI][NK]; float fb_b[NK][NJ]; float fb_c[NI][NJ];\n\
                 void block() {{\n\
                 \x20   for (int i = 0; i < NI; i++) {{\n\
                 \x20       for (int j = 0; j < NJ; j++) {{\n\
                 \x20           for (int k = 0; k < NK; k++) {{\n\
                 \x20               fb_c[i][j] += fb_a[i][k] * fb_b[k][j];\n\
                 \x20           }}\n\
                 \x20       }}\n\
                 \x20   }}\n\
                 }}\n"
            ),
            BlockBinding::Fir {
                banks,
                taps,
                n_out,
                n_in,
                ..
            } => format!(
                "#define BANKS {banks}\n#define TAPS {taps}\n\
                 #define NOUT {n_out}\n#define NIN {n_in}\n\
                 float fb_cr[BANKS][TAPS]; float fb_ci[BANKS][TAPS];\n\
                 float fb_xr[NIN]; float fb_xi[NIN];\n\
                 float fb_or[BANKS][NOUT]; float fb_oi[BANKS][NOUT];\n\
                 void block() {{\n\
                 \x20   for (int m = 0; m < BANKS; m++) {{\n\
                 \x20       for (int n = 0; n < NOUT; n++) {{\n\
                 \x20           float ar = 0.0;\n\
                 \x20           float ai = 0.0;\n\
                 \x20           for (int k = 0; k < TAPS; k++) {{\n\
                 \x20               ar += fb_cr[m][k] * fb_xr[n + k] - fb_ci[m][k] * fb_xi[n + k];\n\
                 \x20               ai += fb_cr[m][k] * fb_xi[n + k] + fb_ci[m][k] * fb_xr[n + k];\n\
                 \x20           }}\n\
                 \x20           fb_or[m][n] = ar;\n\
                 \x20           fb_oi[m][n] = ai;\n\
                 \x20       }}\n\
                 \x20   }}\n\
                 }}\n"
            ),
            BlockBinding::Stencil2d { h, w, .. } => {
                let h1 = h - 1;
                let w1 = w - 1;
                format!(
                    "#define H {h}\n#define W {w}\n#define H1 {h1}\n#define W1 {w1}\n\
                     float fb_in[H][W]; float fb_out[H][W];\n\
                     void block() {{\n\
                     \x20   for (int y = 1; y < H1; y++) {{\n\
                     \x20       for (int x = 1; x < W1; x++) {{\n\
                     \x20           float gx = (fb_in[y - 1][x + 1] + fb_in[y][x + 1] * 2.0 + fb_in[y + 1][x + 1])\n\
                     \x20               - (fb_in[y - 1][x - 1] + fb_in[y][x - 1] * 2.0 + fb_in[y + 1][x - 1]);\n\
                     \x20           float gy = (fb_in[y + 1][x - 1] + fb_in[y + 1][x] * 2.0 + fb_in[y + 1][x + 1])\n\
                     \x20               - (fb_in[y - 1][x - 1] + fb_in[y - 1][x] * 2.0 + fb_in[y - 1][x + 1]);\n\
                     \x20           fb_out[y][x] = sqrt(gx * gx + gy * gy);\n\
                     \x20       }}\n\
                     \x20   }}\n\
                     }}\n"
                )
            }
            BlockBinding::SqrtMag { n, .. } => format!(
                "#define N {n}\n\
                 float fb_a[N]; float fb_b[N]; float fb_o[N];\n\
                 void block() {{\n\
                 \x20   for (int i = 0; i < N; i++) {{\n\
                 \x20       fb_o[i] = sqrt(fb_a[i] * fb_a[i] + fb_b[i] * fb_b[i]);\n\
                 \x20   }}\n\
                 }}\n"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::parse;

    #[test]
    fn builtin_covers_every_kind() {
        let c = Catalog::builtin();
        for kind in [
            BlockKind::MatMul,
            BlockKind::Fir,
            BlockKind::Stencil2d,
            BlockKind::SqrtMag,
        ] {
            assert_eq!(c.spec(kind).kind, kind);
        }
        assert_eq!(c.specs().len(), 4);
    }

    #[test]
    fn fingerprint_is_stable_and_model_sensitive() {
        let a = Catalog::builtin();
        let b = Catalog::builtin();
        assert_eq!(a.fingerprint(), b.fingerprint());
        let mut c = Catalog::builtin();
        c.specs[0].fpga.lanes += 1;
        assert_ne!(a.fingerprint(), c.fingerprint());
        let mut d = Catalog::builtin();
        d.specs[1].gpu.efficiency = 0.61;
        assert_ne!(a.fingerprint(), d.fingerprint());
    }

    #[test]
    fn reference_sources_parse_and_typecheck() {
        let c = Catalog::builtin();
        for binding in [
            BlockBinding::MatMul {
                a: "x".into(),
                b: "y".into(),
                out: "z".into(),
                n_i: 4,
                n_j: 5,
                n_k: 6,
            },
            BlockBinding::Fir {
                coef_r: "hr".into(),
                coef_i: "hi".into(),
                in_r: "xr".into(),
                in_i: "xi".into(),
                out_r: "or_".into(),
                out_i: "oi".into(),
                banks: 2,
                taps: 4,
                n_out: 8,
                n_in: 11,
            },
            BlockBinding::Stencil2d {
                input: "img".into(),
                out: "g".into(),
                h: 8,
                w: 9,
            },
            BlockBinding::SqrtMag {
                in_a: "a".into(),
                in_b: "b".into(),
                out: "o".into(),
                n: 16,
            },
        ] {
            let src = c.reference_source(&binding);
            let prog = parse(&src).unwrap_or_else(|e| {
                panic!("reference failed to parse: {e}\n{src}")
            });
            assert!(
                crate::minic::typecheck::check(&prog).is_empty(),
                "{src}"
            );
            assert!(prog.function("block").is_some());
        }
    }
}
