//! Function-block detection: propose catalog matches for whole MiniC
//! functions.
//!
//! Two phases, both cheap and both allowed to over-propose:
//!
//! 1. **Structural** — the normalized [`FnShape`] is checked against
//!    each [`super::catalog::BlockSpec`]'s gates (nest depth, operation
//!    multiset).
//! 2. **Binding extraction** — the function's loop nest is pattern-
//!    matched to recover the block's *roles*: which arrays are
//!    coefficients, inputs, outputs, and what the dimensions are. The
//!    extraction is deliberately tolerant of extra statements (a
//!    structurally-FIR-shaped function with, say, a saturating clamp in
//!    the tap loop still *binds*) because the authority on semantics is
//!    the sample-test confirmation in [`super::confirm`], never the
//!    matcher.
//!
//! A [`BlockMatch`] is therefore only a *candidate replacement*; nothing
//! is swapped until the candidate function and the catalog's reference
//! semantics agree through the VM on sampled inputs.

use crate::minic::ast::{
    AssignOp, BinOp, Expr, Function, LValue, Stmt, Type,
};
use crate::minic::Program;

use super::catalog::{BlockKind, Catalog};
use super::shape::{shape_of, FnShape};

/// Role assignment of a matched block: candidate array names plus the
/// dimensions the reference program is instantiated with.
#[derive(Debug, Clone, PartialEq)]
pub enum BlockBinding {
    MatMul {
        a: String,
        b: String,
        out: String,
        n_i: usize,
        n_j: usize,
        n_k: usize,
    },
    Fir {
        coef_r: String,
        coef_i: String,
        in_r: String,
        in_i: String,
        out_r: String,
        out_i: String,
        banks: usize,
        taps: usize,
        n_out: usize,
        n_in: usize,
    },
    Stencil2d {
        input: String,
        out: String,
        h: usize,
        w: usize,
    },
    SqrtMag {
        in_a: String,
        in_b: String,
        out: String,
        n: usize,
    },
}

impl BlockBinding {
    /// Candidate input arrays, in the reference program's fill order
    /// (may contain duplicates when one array plays two roles).
    pub fn inputs(&self) -> Vec<&str> {
        match self {
            BlockBinding::MatMul { a, b, .. } => vec![a, b],
            BlockBinding::Fir {
                coef_r,
                coef_i,
                in_r,
                in_i,
                ..
            } => vec![coef_r, coef_i, in_r, in_i],
            BlockBinding::Stencil2d { input, .. } => vec![input],
            BlockBinding::SqrtMag { in_a, in_b, .. } => vec![in_a, in_b],
        }
    }

    /// Candidate output arrays, in the reference program's compare order.
    pub fn outputs(&self) -> Vec<&str> {
        match self {
            BlockBinding::MatMul { out, .. } => vec![out],
            BlockBinding::Fir { out_r, out_i, .. } => vec![out_r, out_i],
            BlockBinding::Stencil2d { out, .. } => vec![out],
            BlockBinding::SqrtMag { out, .. } => vec![out],
        }
    }

    /// The reference program's input array names, aligned with
    /// [`inputs`](Self::inputs).
    pub fn reference_inputs(&self) -> Vec<&'static str> {
        match self {
            BlockBinding::MatMul { .. } => vec!["fb_a", "fb_b"],
            BlockBinding::Fir { .. } => {
                vec!["fb_cr", "fb_ci", "fb_xr", "fb_xi"]
            }
            BlockBinding::Stencil2d { .. } => vec!["fb_in"],
            BlockBinding::SqrtMag { .. } => vec!["fb_a", "fb_b"],
        }
    }

    /// The reference program's output array names, aligned with
    /// [`outputs`](Self::outputs).
    pub fn reference_outputs(&self) -> Vec<&'static str> {
        match self {
            BlockBinding::MatMul { .. } => vec!["fb_c"],
            BlockBinding::Fir { .. } => vec!["fb_or", "fb_oi"],
            BlockBinding::Stencil2d { .. } => vec!["fb_out"],
            BlockBinding::SqrtMag { .. } => vec!["fb_o"],
        }
    }
}

/// A proposed (not yet confirmed) replacement of one function by one
/// catalog block.
#[derive(Debug, Clone)]
pub struct BlockMatch {
    pub kind: BlockKind,
    pub func: String,
    pub binding: BlockBinding,
    pub shape: FnShape,
}

/// Detect catalog matches across a whole program. At most one match per
/// function (first catalog entry that binds wins, in catalog order).
pub fn detect(prog: &Program, catalog: &Catalog) -> Vec<BlockMatch> {
    let mut out = Vec::new();
    for f in &prog.functions {
        let shape = shape_of(f);
        for spec in catalog.specs() {
            if !spec.structural_match(&shape) {
                continue;
            }
            let binding = match spec.kind {
                BlockKind::MatMul => bind_matmul(prog, f),
                BlockKind::Fir => bind_fir(prog, f),
                BlockKind::Stencil2d => bind_stencil2d(prog, f),
                BlockKind::SqrtMag => bind_sqrtmag(prog, f),
            };
            if let Some(binding) = binding {
                out.push(BlockMatch {
                    kind: spec.kind,
                    func: f.name.clone(),
                    binding,
                    shape: shape.clone(),
                });
                break;
            }
        }
    }
    out
}

/// Dimensions of a global array declaration.
fn global_dims(prog: &Program, name: &str) -> Option<Vec<usize>> {
    prog.globals.iter().find_map(|g| match g {
        Stmt::Decl {
            name: n,
            ty: Type::Array(_, dims),
            ..
        } if n == name => Some(dims.clone()),
        _ => None,
    })
}

/// `base[...]` with the given rank.
fn index_of(e: &Expr, rank: usize) -> Option<&str> {
    match e {
        Expr::Index { base, indices } if indices.len() == rank => {
            Some(base)
        }
        _ => None,
    }
}

fn as_mul(e: &Expr) -> Option<(&Expr, &Expr)> {
    match e {
        Expr::Bin {
            op: BinOp::Mul,
            lhs,
            rhs,
        } => Some((lhs, rhs)),
        _ => None,
    }
}

/// The chain of singly-nested `for` loops starting at `body` (each
/// level's *first* `for` statement). Returns each level's body.
fn loop_chain(body: &[Stmt]) -> Vec<&[Stmt]> {
    let mut chain: Vec<&[Stmt]> = Vec::new();
    let mut cur = body;
    loop {
        let next = cur.iter().find_map(|s| match s {
            Stmt::For { body, .. } => Some(body.as_slice()),
            _ => None,
        });
        match next {
            Some(b) => {
                chain.push(b);
                cur = b;
            }
            None => return chain,
        }
    }
}

/// `acc += c[·][·] * x[·] (±) c[·][·] * x[·]` — the complex-MAC shape.
/// Returns (coef, input, coef2, input2) base names.
fn fir_products(e: &Expr) -> Option<(&str, &str, &str, &str)> {
    let Expr::Bin {
        op: BinOp::Add | BinOp::Sub,
        lhs,
        rhs,
    } = e
    else {
        return None;
    };
    let (c1e, x1e) = as_mul(lhs)?;
    let (c2e, x2e) = as_mul(rhs)?;
    Some((
        index_of(c1e, 2)?,
        index_of(x1e, 1)?,
        index_of(c2e, 2)?,
        index_of(x2e, 1)?,
    ))
}

fn bind_fir(prog: &Program, f: &Function) -> Option<BlockBinding> {
    let chain = loop_chain(&f.body);
    if chain.len() < 3 {
        return None;
    }
    let inner = chain[chain.len() - 1];
    let sample = chain[chain.len() - 2];

    // The two complex accumulators in the tap loop. Extra statements
    // (clamps, debugging) are tolerated — the sample test judges them.
    let mut accs = inner.iter().filter_map(|s| match s {
        Stmt::Assign {
            target: LValue::Var(v),
            op: AssignOp::AddSet,
            value,
            ..
        } => Some((v.as_str(), value)),
        _ => None,
    });
    let (v_r, e_r) = accs.next()?;
    let (v_i, e_i) = accs.next()?;
    let (coef_r, in_r, coef_i, in_i) = fir_products(e_r)?;
    fir_products(e_i)?;

    // Output write-back in the sample loop: out[·][·] = acc.
    let out_r = writeback_target(sample, v_r)?;
    let out_i = writeback_target(sample, v_i)?;

    let cd = global_dims(prog, coef_r)?;
    let xd = global_dims(prog, in_r)?;
    let od = global_dims(prog, out_r)?;
    if cd.len() != 2 || xd.len() != 1 || od.len() != 2 {
        return None;
    }
    if global_dims(prog, coef_i)? != cd
        || global_dims(prog, in_i)? != xd
        || global_dims(prog, out_i)? != od
        || od[0] != cd[0]
        || xd[0] < od[1] + cd[1] - 1
    {
        return None;
    }
    Some(BlockBinding::Fir {
        coef_r: coef_r.into(),
        coef_i: coef_i.into(),
        in_r: in_r.into(),
        in_i: in_i.into(),
        out_r: out_r.into(),
        out_i: out_i.into(),
        banks: cd[0],
        taps: cd[1],
        n_out: od[1],
        n_in: xd[0],
    })
}

/// `out[·][·] = acc` in a statement list: the accumulator's write-back
/// array.
fn writeback_target<'a>(stmts: &'a [Stmt], acc: &str) -> Option<&'a str> {
    stmts.iter().find_map(|s| match s {
        Stmt::Assign {
            target: LValue::Index { base, indices },
            op: AssignOp::Set,
            value: Expr::Var(v),
            ..
        } if indices.len() == 2 && v == acc => Some(base.as_str()),
        _ => None,
    })
}

fn bind_matmul(prog: &Program, f: &Function) -> Option<BlockBinding> {
    let chain = loop_chain(&f.body);
    if chain.len() < 3 {
        return None;
    }
    let inner = chain[chain.len() - 1];
    let (out, a, b) = inner.iter().find_map(|s| match s {
        Stmt::Assign {
            target: LValue::Index { base, indices },
            op: AssignOp::AddSet,
            value,
            ..
        } if indices.len() == 2 => {
            let (ae, be) = as_mul(value)?;
            Some((base.as_str(), index_of(ae, 2)?, index_of(be, 2)?))
        }
        _ => None,
    })?;
    let ad = global_dims(prog, a)?;
    let bd = global_dims(prog, b)?;
    let od = global_dims(prog, out)?;
    if ad.len() != 2 || bd.len() != 2 || od.len() != 2 {
        return None;
    }
    // C[i][j] += A[i][k] * B[k][j]: dims must chain.
    if ad[0] != od[0] || bd[1] != od[1] || ad[1] != bd[0] {
        return None;
    }
    Some(BlockBinding::MatMul {
        a: a.into(),
        b: b.into(),
        out: out.into(),
        n_i: od[0],
        n_j: od[1],
        n_k: ad[1],
    })
}

fn bind_stencil2d(prog: &Program, f: &Function) -> Option<BlockBinding> {
    let chain = loop_chain(&f.body);
    if chain.len() != 2 {
        return None;
    }
    let inner = chain[1];

    // The gradient accumulator declarations read the input array.
    let input = inner.iter().find_map(|s| match s {
        Stmt::Decl {
            init: Some(e), ..
        } => {
            let mut found = None;
            e.walk(&mut |sub| {
                if found.is_none() {
                    if let Some(base) = index_of(sub, 2) {
                        found = Some(base.to_string());
                    }
                }
            });
            found
        }
        _ => None,
    })?;

    // The magnitude write: out[y][x] = sqrt(g1*g1 + g2*g2).
    let out = inner.iter().find_map(|s| match s {
        Stmt::Assign {
            target: LValue::Index { base, indices },
            op: AssignOp::Set,
            value:
                Expr::Call {
                    name,
                    args,
                },
            ..
        } if indices.len() == 2 && name == "sqrt" && args.len() == 1 => {
            let Expr::Bin {
                op: BinOp::Add,
                lhs,
                rhs,
            } = &args[0]
            else {
                return None;
            };
            as_mul(lhs)?;
            as_mul(rhs)?;
            Some(base.as_str())
        }
        _ => None,
    })?;

    let id = global_dims(prog, &input)?;
    let od = global_dims(prog, out)?;
    if id.len() != 2 || od != id || id[0] < 3 || id[1] < 3 {
        return None;
    }
    Some(BlockBinding::Stencil2d {
        input,
        out: out.into(),
        h: id[0],
        w: id[1],
    })
}

fn bind_sqrtmag(prog: &Program, f: &Function) -> Option<BlockBinding> {
    let chain = loop_chain(&f.body);
    if chain.len() != 1 {
        return None;
    }
    let (out, a, b) = chain[0].iter().find_map(|s| match s {
        Stmt::Assign {
            target: LValue::Index { base, indices },
            op: AssignOp::Set,
            value: Expr::Call { name, args },
            ..
        } if indices.len() == 1 && name == "sqrt" && args.len() == 1 => {
            let Expr::Bin {
                op: BinOp::Add,
                lhs,
                rhs,
            } = &args[0]
            else {
                return None;
            };
            let (a1, a2) = as_mul(lhs)?;
            let (b1, b2) = as_mul(rhs)?;
            let a = index_of(a1, 1)?;
            let b = index_of(b1, 1)?;
            if index_of(a2, 1)? != a || index_of(b2, 1)? != b {
                return None;
            }
            Some((base.as_str(), a, b))
        }
        _ => None,
    })?;
    let od = global_dims(prog, out)?;
    let ad = global_dims(prog, a)?;
    let bd = global_dims(prog, b)?;
    if od.len() != 1 || ad.len() != 1 || bd.len() != 1 {
        return None;
    }
    let n = od[0];
    if ad[0] < n || bd[0] < n {
        return None;
    }
    Some(BlockBinding::SqrtMag {
        in_a: a.into(),
        in_b: b.into(),
        out: out.into(),
        n,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::minic::parse;
    use crate::workloads;

    fn detect_in(src: &str) -> Vec<BlockMatch> {
        detect(&parse(src).unwrap(), &Catalog::builtin())
    }

    #[test]
    fn tdfir_proposes_the_fir_bank() {
        let ms = detect_in(workloads::TDFIR_C);
        let fir = ms
            .iter()
            .find(|m| m.kind == BlockKind::Fir)
            .expect("fir_all proposed");
        assert_eq!(fir.func, "fir_all");
        let BlockBinding::Fir {
            coef_r,
            in_r,
            out_r,
            banks,
            taps,
            n_out,
            n_in,
            ..
        } = &fir.binding
        else {
            panic!("fir binding");
        };
        assert_eq!(coef_r, "hrevr");
        assert_eq!(in_r, "xr");
        assert_eq!(out_r, "outr");
        assert_eq!((*banks, *taps, *n_out, *n_in), (8, 16, 1024, 1040));
    }

    #[test]
    fn mriq_proposes_sqrt_magnitude() {
        let ms = detect_in(workloads::MRIQ_C);
        let m = ms
            .iter()
            .find(|m| m.kind == BlockKind::SqrtMag)
            .expect("magnitude proposed");
        assert_eq!(m.func, "magnitude");
        assert_eq!(
            m.binding,
            BlockBinding::SqrtMag {
                in_a: "qr".into(),
                in_b: "qi".into(),
                out: "qmag".into(),
                n: 1536,
            }
        );
    }

    #[test]
    fn sobel_proposes_the_gradient_stencil() {
        let ms = detect_in(workloads::SOBEL_C);
        let m = ms
            .iter()
            .find(|m| m.kind == BlockKind::Stencil2d)
            .expect("gradient proposed");
        assert_eq!(m.func, "gradient");
        assert_eq!(
            m.binding,
            BlockBinding::Stencil2d {
                input: "tmp".into(),
                out: "gmag".into(),
                h: 96,
                w: 96,
            }
        );
        // blur has no sqrt: it must not be proposed as a stencil core.
        assert!(ms.iter().all(|m| m.func != "blur"));
    }

    #[test]
    fn matmul_binds_on_a_synthetic_gemm() {
        let src = "
#define NI 8
#define NJ 12
#define NK 6
float a[NI][NK]; float b[NK][NJ]; float c[NI][NJ];
void gemm() {
    for (int i = 0; i < NI; i++) {
        for (int j = 0; j < NJ; j++) {
            for (int k = 0; k < NK; k++) {
                c[i][j] += a[i][k] * b[k][j];
            }
        }
    }
}
int main() { gemm(); return 0; }";
        let ms = detect_in(src);
        let m = ms
            .iter()
            .find(|m| m.kind == BlockKind::MatMul)
            .expect("gemm proposed");
        assert_eq!(
            m.binding,
            BlockBinding::MatMul {
                a: "a".into(),
                b: "b".into(),
                out: "c".into(),
                n_i: 8,
                n_j: 12,
                n_k: 6,
            }
        );
    }

    #[test]
    fn mismatched_gemm_dims_do_not_bind() {
        let src = "
#define NI 8
#define NJ 12
#define NK 6
float a[NI][NK]; float b[NJ][NK]; float c[NI][NJ];
void gemm() {
    for (int i = 0; i < NI; i++) {
        for (int j = 0; j < NJ; j++) {
            for (int k = 0; k < NK; k++) {
                c[i][j] += a[i][k] * b[j][k];
            }
        }
    }
}
int main() { gemm(); return 0; }";
        assert!(detect_in(src)
            .iter()
            .all(|m| m.kind != BlockKind::MatMul));
    }

    #[test]
    fn scalar_side_effects_disqualify_a_function() {
        // energy() is loop-shaped but folds into a global scalar — its
        // effect is invisible to array comparison, so it is never
        // proposed.
        let ms = detect_in(workloads::TDFIR_C);
        assert!(ms.iter().all(|m| m.func != "energy"));
    }

    #[test]
    fn saturating_fir_is_still_proposed() {
        // Structurally FIR-shaped with an extra clamp: the detector must
        // propose it (rejection is the sample test's job — see
        // funcblock::confirm tests).
        let ms = detect_in(crate::funcblock::SAT_FIR_SRC);
        let m = ms
            .iter()
            .find(|m| m.kind == BlockKind::Fir)
            .expect("saturating fir proposed");
        assert_eq!(m.func, "fir_sat");
    }
}
