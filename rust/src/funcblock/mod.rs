//! Function-block offloading (arXiv:2004.09883 / arXiv:2005.04174): the
//! follow-on to loop-statement offloading that recognizes *whole
//! algorithmic blocks* and swaps in catalogued FPGA IP cores / GPU
//! libraries instead of GA-searching loop subsets.
//!
//! The subsystem composes with — never replaces — the loop funnel:
//!
//! 1. [`shape`] normalizes every function (interned names, loop
//!    skeleton, operation multiset);
//! 2. [`detect`] proposes [`catalog`] matches and extracts role
//!    bindings (which arrays are coefficients / inputs / outputs);
//! 3. [`confirm`] behaviorally verifies each proposal by running the
//!    candidate function and the catalog's reference semantics through
//!    the slot-resolved VM on sampled inputs — the paper's "verify by
//!    sample test" discipline, so structurally-similar-but-semantically-
//!    different functions are never replaced;
//! 4. [`plan`] gathers profiled figures per confirmed block; each
//!    [`crate::search::Backend`] prices it for its destination, and the
//!    staged [`crate::envadapt::Pipeline`] claims the block's loops away
//!    from the loop funnel and folds the core's time into the combined
//!    plan.
//!
//! Enable per request via
//! [`crate::envadapt::OffloadRequestBuilder::func_blocks`] (CLI:
//! `repro offload --func-blocks`, `repro batch --func-blocks`).
//!
//! ```
//! use fpga_offload::funcblock::{BlockKind, Catalog};
//!
//! let catalog = Catalog::builtin();
//! // Sized to the bundled workloads: each of tdfir / mriq / sobel
//! // contains at least one of these four blocks.
//! assert_eq!(catalog.specs().len(), 4);
//! assert_eq!(catalog.spec(BlockKind::Fir).kind, BlockKind::Fir);
//! // The fingerprint is part of the pattern-DB reuse key: stable for
//! // one catalog, different the moment any model knob moves.
//! assert_eq!(catalog.fingerprint(), Catalog::builtin().fingerprint());
//! ```

pub mod catalog;
pub mod confirm;
pub mod detect;
pub mod plan;
pub mod shape;

pub use catalog::{
    BlockKind, BlockSpec, Catalog, CpuLibModel, FpgaCoreModel, GpuLibModel,
};
pub use confirm::{confirm, Confirmation};
pub use detect::{detect, BlockBinding, BlockMatch};
pub use plan::{find_blocks, BlockCost, BlockReplacement, ConfirmedBlock};
pub use shape::{shape_of, FnShape, OpMultiset};

/// Structurally FIR-shaped, behaviorally different (saturating
/// accumulate) — the canonical false-positive fixture shared by the
/// detect / confirm / plan test suites.
#[cfg(test)]
pub(crate) const SAT_FIR_SRC: &str = "
#define M 4
#define K 8
#define N 64
#define NIN 71
float cr[M][K]; float ci[M][K];
float xr[NIN]; float xi[NIN];
float outr[M][N]; float outi[M][N];
void fir_sat() {
    for (int m = 0; m < M; m++) {
        for (int n = 0; n < N; n++) {
            float ar = 0.0;
            float ai = 0.0;
            for (int k = 0; k < K; k++) {
                ar += cr[m][k] * xr[n + k] - ci[m][k] * xi[n + k];
                ai += cr[m][k] * xi[n + k] + ci[m][k] * xr[n + k];
                ar = fmin(ar, 0.5);
            }
            outr[m][n] = ar;
            outi[m][n] = ai;
        }
    }
}
int main() { fir_sat(); return 0; }";
