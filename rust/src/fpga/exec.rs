//! Functional verification of offload patterns.
//!
//! The paper verifies candidate patterns by running the application's
//! sample test on the real FPGA. Here the "FPGA execution" of a pattern is
//! the *offloaded host program* — the original source with each offloaded
//! loop outlined into a kernel function ([`crate::codegen::split`]) — run
//! through the MiniC interpreter, compared array-by-array against the
//! unmodified program. A split that forgot a kernel argument, mis-directed
//! a transfer, or broke unrolling shows up as a numeric mismatch or an
//! interpreter error, the same bug classes a real OpenCL port has.

use std::collections::BTreeMap;

use crate::codegen::{offload_program, SplitResult};
use crate::minic::{EngineKind, MiniCError, Program};

/// Result of a functional verification run.
#[derive(Debug, Clone)]
pub struct VerifyResult {
    /// Max |offloaded − baseline| across all global arrays.
    pub max_abs_err: f64,
    /// Arrays compared (name → element count).
    pub compared: BTreeMap<String, usize>,
    pub passed: bool,
}

/// Numerical tolerance: the interpreter is deterministic f64, and the
/// outlined kernels execute the *same arithmetic in the same order*, so
/// agreement is exact. Any nonzero diff is a split bug.
pub const TOLERANCE: f64 = 0.0;

/// Run baseline and offloaded programs; compare every global array.
/// Executes on the default engine (the bytecode VM); two rounds of
/// pattern verification are a hot path of the automation loop.
pub fn verify_pattern(
    prog: &Program,
    splits: &[SplitResult],
    entry: &str,
) -> Result<VerifyResult, MiniCError> {
    verify_pattern_with(prog, splits, entry, EngineKind::default())
}

/// [`verify_pattern`] with an explicit execution engine.
pub fn verify_pattern_with(
    prog: &Program,
    splits: &[SplitResult],
    entry: &str,
    engine: EngineKind,
) -> Result<VerifyResult, MiniCError> {
    let host = offload_program(prog, splits);

    let mut base = engine.build(prog)?;
    base.call(entry, &[])?;
    let mut off = engine.build(&host)?;
    off.call(entry, &[])?;

    let mut max_abs_err = 0.0f64;
    let mut compared = BTreeMap::new();
    for g in &prog.globals {
        if let crate::minic::ast::Stmt::Decl { name, ty, .. } = g {
            if !ty.is_indexable() {
                continue;
            }
            let (Some(rb), Some(ro)) =
                (base.global_array(name), off.global_array(name))
            else {
                continue;
            };
            let a = &base.array(rb).data;
            let b = &off.array(ro).data;
            debug_assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b) {
                max_abs_err = max_abs_err.max((x - y).abs());
            }
            compared.insert(name.clone(), a.len());
        }
    }
    Ok(VerifyResult {
        max_abs_err,
        passed: max_abs_err <= TOLERANCE,
        compared,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::codegen::{split, unroll};
    use crate::minic::ast::LoopId;
    use crate::minic::parse;

    const SRC: &str = "
#define N 128
float a[N]; float b[N]; float c[N];
float total;
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.01 - 0.5; }        // L0
    for (int i = 0; i < N; i++) { b[i] = sin(a[i]) + a[i] * 2.0; } // L1
    for (int i = 0; i < N; i++) { c[i] = b[i] * b[i]; }            // L2
    for (int i = 0; i < N; i++) { total += c[i]; }                 // L3
    return 0;
}";

    #[test]
    fn single_loop_pattern_verifies() {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let s = split(&prog, an.loop_by_id(LoopId(1)).unwrap()).unwrap();
        let v = verify_pattern(&prog, &[s], "main").unwrap();
        assert!(v.passed, "err = {}", v.max_abs_err);
        assert!(v.compared.contains_key("b"));
    }

    #[test]
    fn multi_loop_pattern_verifies() {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let s1 = split(&prog, an.loop_by_id(LoopId(1)).unwrap()).unwrap();
        let s2 = split(&prog, an.loop_by_id(LoopId(2)).unwrap()).unwrap();
        let s3 = split(&prog, an.loop_by_id(LoopId(3)).unwrap()).unwrap();
        let v = verify_pattern(&prog, &[s1, s2, s3], "main").unwrap();
        assert!(v.passed, "err = {}", v.max_abs_err);
        assert_eq!(v.compared.len(), 3); // a, b, c
    }

    #[test]
    fn unrolled_pattern_verifies() {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        for u in [2u32, 4, 7] {
            let mut s =
                split(&prog, an.loop_by_id(LoopId(2)).unwrap()).unwrap();
            let unrolled = unroll(&s.kernel, u).unwrap();
            s.kernel_fn.body = vec![unrolled.body.clone()];
            s.kernel = unrolled;
            let v = verify_pattern(&prog, &[s], "main").unwrap();
            assert!(v.passed, "unroll {u}: err = {}", v.max_abs_err);
        }
    }

    #[test]
    fn oracle_and_vm_verification_agree() {
        use crate::minic::EngineKind;
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let s = split(&prog, an.loop_by_id(LoopId(2)).unwrap()).unwrap();
        let v_vm = verify_pattern_with(
            &prog,
            std::slice::from_ref(&s),
            "main",
            EngineKind::Bytecode,
        )
        .unwrap();
        let v_tw = verify_pattern_with(
            &prog,
            std::slice::from_ref(&s),
            "main",
            EngineKind::TreeWalk,
        )
        .unwrap();
        assert_eq!(v_vm.passed, v_tw.passed);
        assert_eq!(v_vm.max_abs_err, v_tw.max_abs_err);
        assert_eq!(v_vm.compared, v_tw.compared);
    }

    #[test]
    fn corrupted_split_detected() {
        // Sabotage: drop the kernel body entirely — verification must
        // catch the wrong numerics.
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        let mut s = split(&prog, an.loop_by_id(LoopId(2)).unwrap()).unwrap();
        s.kernel_fn.body.clear();
        let v = verify_pattern(&prog, &[s], "main").unwrap();
        assert!(!v.passed);
        assert!(v.max_abs_err > 0.0);
    }
}
