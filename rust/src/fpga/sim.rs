//! FPGA performance simulator: models the verification-environment
//! measurement of one offload pattern (paper §4: "conducts performance
//! measurements on a server with FPGA in the verification environment").
//!
//! For each offloaded loop the time is
//!
//! ```text
//! entries × [launch + DMA(in) + pipeline(depth + slots·II/unroll)/fmax + DMA(out)]
//! ```
//!
//! where `slots` is the innermost iteration count of the loop's subtree
//! (HLS pipelines the innermost loop; outer levels wrap it), `fmax` is
//! derated by the *combined* utilization of all kernels in the pattern —
//! concentrating resources on one kernel versus spreading them across
//! several is exactly the trade-off the paper's two "types of speed up"
//! describe — and the remaining program stays on the CPU model.

use std::collections::BTreeSet;

use crate::analysis::Analysis;
use crate::codegen::KernelIr;
use crate::cpu::CpuModel;
use crate::hls::{estimate, schedule, Device, ResourceEstimate};
use crate::minic::ast::LoopId;
use crate::minic::OpCounts;

use super::xfer;

/// Simulation failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimError {
    /// Two offloaded loops overlap (one nests the other).
    OverlappingLoops(LoopId, LoopId),
    /// The combined pattern exceeds device resources.
    DoesNotFit,
    /// A kernel's loop has no profile data (never executed).
    ColdLoop(LoopId),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::OverlappingLoops(a, b) => {
                write!(f, "offloaded loops {a} and {b} overlap")
            }
            SimError::DoesNotFit => {
                write!(f, "combined pattern exceeds device resources")
            }
            SimError::ColdLoop(id) => {
                write!(f, "loop {id} never executed in the profiling run")
            }
        }
    }
}

impl std::error::Error for SimError {}

/// Timing breakdown for one offloaded loop.
#[derive(Debug, Clone)]
pub struct LoopTiming {
    pub loop_id: LoopId,
    pub entries: u64,
    /// Innermost pipeline slots across all entries.
    pub slots: u64,
    pub compute_s: f64,
    pub transfer_s: f64,
    pub total_s: f64,
}

/// Timing of a full pattern.
#[derive(Debug, Clone)]
pub struct PatternTiming {
    /// All-CPU baseline (the paper's comparison denominator).
    pub cpu_baseline_s: f64,
    /// CPU time of the non-offloaded remainder.
    pub cpu_rest_s: f64,
    pub loops: Vec<LoopTiming>,
    /// Total modeled pattern time.
    pub pattern_s: f64,
    /// `cpu_baseline_s / pattern_s`.
    pub speedup: f64,
    /// Combined resource estimate of the pattern.
    pub combined: ResourceEstimate,
}

/// Simulate a pattern of offloaded kernels against an analysis profile.
pub fn simulate(
    analysis: &Analysis,
    kernels: &[KernelIr],
    cpu: &CpuModel,
    dev: &Device,
) -> Result<PatternTiming, SimError> {
    // Disjointness: no offloaded loop may contain another offloaded loop.
    let offloaded: Vec<LoopId> = kernels.iter().map(|k| k.loop_id).collect();
    for k in kernels {
        let subtree = subtree_ids(analysis, k.loop_id);
        for other in &offloaded {
            if *other != k.loop_id && subtree.contains(other) {
                return Err(SimError::OverlappingLoops(k.loop_id, *other));
            }
        }
    }

    // Combined resources decide fit and clock derating.
    let combined = kernels
        .iter()
        .map(estimate)
        .fold(ResourceEstimate::default(), |acc, e| acc.add(&e));
    if !combined.fits(dev) {
        return Err(SimError::DoesNotFit);
    }

    let cpu_baseline_s = cpu.time(&analysis.profile.total);

    let mut offloaded_ops = OpCounts::default();
    let mut loops = Vec::new();
    for k in kernels {
        let lp = analysis
            .profile
            .loop_profile(k.loop_id)
            .ok_or(SimError::ColdLoop(k.loop_id))?;
        offloaded_ops = offloaded_ops.plus(&lp.ops);

        let sched = schedule(k, &combined, dev);
        let entries = lp.entries.max(1);
        // Innermost iteration count of the subtree, divided by the
        // spatial replication of the innermost loop (a spatialized K-tap
        // MAC consumes K iterations per clock).
        let inner_trips = subtree_ids(analysis, k.loop_id)
            .iter()
            .filter_map(|id| analysis.profile.loop_profile(*id))
            .map(|p| p.trips)
            .max()
            .unwrap_or(lp.trips);
        let slots = inner_trips.div_ceil(crate::hls::spatial_factor(k)).max(1);

        let fill_s = (entries * sched.depth) as f64 / sched.fmax_hz;
        let throughput_s = (slots.div_ceil(k.unroll.max(1) as u64)
            * sched.ii) as f64
            / sched.fmax_hz;
        let compute_s = fill_s + throughput_s;
        let transfer_s = entries as f64
            * xfer::launch_overhead(dev, k.bytes_in(), k.bytes_out());
        loops.push(LoopTiming {
            loop_id: k.loop_id,
            entries,
            slots,
            compute_s,
            transfer_s,
            total_s: compute_s + transfer_s,
        });
    }

    let rest_ops = analysis.profile.total.saturating_sub(&offloaded_ops);
    let cpu_rest_s = cpu.time(&rest_ops);
    let fpga_s: f64 = loops.iter().map(|l| l.total_s).sum();
    let pattern_s = cpu_rest_s + fpga_s;
    let speedup = if pattern_s > 0.0 {
        cpu_baseline_s / pattern_s
    } else {
        f64::INFINITY
    };

    Ok(PatternTiming {
        cpu_baseline_s,
        cpu_rest_s,
        loops,
        pattern_s,
        speedup,
        combined,
    })
}

/// Ids of the loop and every loop nested inside it.
pub fn subtree_ids(analysis: &Analysis, id: LoopId) -> BTreeSet<LoopId> {
    let mut out = BTreeSet::new();
    let mut stack = vec![id];
    while let Some(cur) = stack.pop() {
        if !out.insert(cur) {
            continue;
        }
        if let Some(al) = analysis.loop_by_id(cur) {
            stack.extend(al.info.children.iter().copied());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::codegen::split;
    use crate::cpu::XEON_BRONZE_3104;
    use crate::hls::ARRIA10_GX;
    use crate::minic::parse;

    /// A program with one hot trig loop and one cold copy loop that is
    /// entered many times (transfer-dominated if offloaded).
    const SRC: &str = "
#define N 2048
#define REP 64
float a[N]; float b[N]; float c[N];
int main() {
    for (int r = 0; r < REP; r++) {                   // L0 (outer, calls nothing)
        for (int i = 0; i < N; i++) {                 // L1 hot inner
            b[i] = sin(a[i]) * cos(a[i]) + sqrt(a[i] + 2.0);
        }
    }
    for (int i = 0; i < N; i++) { c[i] = b[i]; }      // L2 copy
    return 0;
}";

    fn setup() -> (crate::minic::Program, Analysis) {
        let prog = parse(SRC).unwrap();
        let an = analyze(&prog, "main").unwrap();
        (prog, an)
    }

    fn kernel(prog: &crate::minic::Program, an: &Analysis, id: u32) -> KernelIr {
        split(prog, an.loop_by_id(LoopId(id)).unwrap())
            .unwrap()
            .kernel
    }

    #[test]
    fn hot_outer_loop_speeds_up() {
        let (prog, an) = setup();
        let k = kernel(&prog, &an, 0); // offload the whole repetition loop
        let t = simulate(&an, &[k], &XEON_BRONZE_3104, &ARRIA10_GX).unwrap();
        assert!(
            t.speedup > 1.5,
            "trig-dense loop should win on FPGA: {:.2}x",
            t.speedup
        );
    }

    #[test]
    fn copy_loop_loses() {
        let (prog, an) = setup();
        let k = kernel(&prog, &an, 2);
        let t = simulate(&an, &[k], &XEON_BRONZE_3104, &ARRIA10_GX).unwrap();
        assert!(
            t.speedup < 1.05,
            "pure copy loop must not win: {:.3}x",
            t.speedup
        );
    }

    #[test]
    fn inner_loop_per_entry_transfer_tax() {
        let (prog, an) = setup();
        // Offloading L1 directly means REP kernel launches with transfers.
        let k_inner = kernel(&prog, &an, 1);
        let k_outer = kernel(&prog, &an, 0);
        let t_inner =
            simulate(&an, &[k_inner], &XEON_BRONZE_3104, &ARRIA10_GX)
                .unwrap();
        let t_outer =
            simulate(&an, &[k_outer], &XEON_BRONZE_3104, &ARRIA10_GX)
                .unwrap();
        let inner_l = &t_inner.loops[0];
        let outer_l = &t_outer.loops[0];
        assert_eq!(inner_l.entries, 64);
        assert_eq!(outer_l.entries, 1);
        assert!(inner_l.transfer_s > outer_l.transfer_s * 10.0);
        assert!(t_outer.speedup > t_inner.speedup);
    }

    #[test]
    fn overlapping_pattern_rejected() {
        let (prog, an) = setup();
        let k0 = kernel(&prog, &an, 0);
        let k1 = kernel(&prog, &an, 1);
        let err = simulate(&an, &[k0, k1], &XEON_BRONZE_3104, &ARRIA10_GX)
            .unwrap_err();
        assert!(matches!(err, SimError::OverlappingLoops(..)));
    }

    #[test]
    fn disjoint_combination_allowed() {
        let (prog, an) = setup();
        let k0 = kernel(&prog, &an, 0);
        let k2 = kernel(&prog, &an, 2);
        let t = simulate(&an, &[k0, k2], &XEON_BRONZE_3104, &ARRIA10_GX)
            .unwrap();
        assert_eq!(t.loops.len(), 2);
        // Combined estimate is the sum of parts.
        let e0 = estimate(&kernel(&prog, &an, 0));
        let e2 = estimate(&kernel(&prog, &an, 2));
        assert_eq!(t.combined, e0.add(&e2));
    }

    #[test]
    fn empty_pattern_is_baseline() {
        let (_prog, an) = setup();
        let t = simulate(&an, &[], &XEON_BRONZE_3104, &ARRIA10_GX).unwrap();
        assert!((t.speedup - 1.0).abs() < 1e-9);
        assert_eq!(t.loops.len(), 0);
    }

    #[test]
    fn subtree_ids_cover_nesting() {
        let (_prog, an) = setup();
        let s = subtree_ids(&an, LoopId(0));
        assert!(s.contains(&LoopId(0)));
        assert!(s.contains(&LoopId(1)));
        assert!(!s.contains(&LoopId(2)));
    }
}
