//! Host↔device transfer model (PCIe DMA).
//!
//! OpenCL/CUDA offloading's tax: every kernel launch moves its buffers
//! over PCIe (paper §2: "naive parallel processing performances with
//! FPGAs or GPUs are not high because of overheads of CPU and FPGA/GPU
//! devices memory data transfer"). The model is latency + size/bandwidth
//! per DMA, which is what makes *frequently-entered small loops* lose
//! when offloaded — the decision landscape the funnel must navigate.

use crate::hls::Device;

/// One direction of a transfer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    pub bytes: u64,
    pub seconds: f64,
}

/// Time to move `bytes` one way (one DMA).
pub fn dma_time(dev: &Device, bytes: u64) -> f64 {
    if bytes == 0 {
        return 0.0;
    }
    dev.dma_latency_s + bytes as f64 / dev.pcie_bytes_per_sec
}

/// Full launch overhead for a kernel invocation that moves `bytes_in`
/// then `bytes_out`.
pub fn launch_overhead(dev: &Device, bytes_in: u64, bytes_out: u64) -> f64 {
    dev.launch_latency_s + dma_time(dev, bytes_in) + dma_time(dev, bytes_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::ARRIA10_GX;

    #[test]
    fn zero_bytes_zero_time() {
        assert_eq!(dma_time(&ARRIA10_GX, 0), 0.0);
    }

    #[test]
    fn latency_floor_for_small_transfers() {
        let t = dma_time(&ARRIA10_GX, 64);
        assert!(t >= ARRIA10_GX.dma_latency_s);
        assert!(t < ARRIA10_GX.dma_latency_s * 1.01);
    }

    #[test]
    fn bandwidth_dominates_large_transfers() {
        let gb = 1u64 << 30;
        let t = dma_time(&ARRIA10_GX, gb);
        let ideal = gb as f64 / ARRIA10_GX.pcie_bytes_per_sec;
        assert!((t - ideal).abs() / ideal < 0.01);
    }

    #[test]
    fn launch_overhead_sums_parts() {
        let t = launch_overhead(&ARRIA10_GX, 1000, 2000);
        let expect = ARRIA10_GX.launch_latency_s
            + dma_time(&ARRIA10_GX, 1000)
            + dma_time(&ARRIA10_GX, 2000);
        assert!((t - expect).abs() < 1e-12);
    }
}
