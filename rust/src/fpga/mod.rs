//! FPGA substrate: what the paper's physical Intel PAC provides, built as
//! simulation (repro band 0/5 — no hardware; see DESIGN.md §2).
//!
//! * [`sim`] — cycle/transfer performance model for measuring patterns.
//! * [`xfer`] — PCIe DMA transfer model.
//! * [`exec`] — *functional* execution of offloaded programs for numeric
//!   verification (outlined-kernel interpretation).
//! * [`compile_model`] — the hours-long place-and-route wall-clock model
//!   behind the paper's "half day" automation figure.

pub mod compile_model;
pub mod exec;
pub mod sim;
pub mod xfer;

pub use compile_model::{automation_time, makespan, CompileJob};
pub use exec::{verify_pattern, verify_pattern_with, VerifyResult};
pub use sim::{simulate, subtree_ids, LoopTiming, PatternTiming, SimError};
pub use xfer::{dma_time, launch_overhead};
