//! FPGA substrate: what the paper's physical Intel PAC provides, built as
//! simulation (repro band 0/5 — no hardware; see DESIGN.md §2).
//!
//! * [`sim`] — cycle/transfer performance model for measuring patterns.
//! * [`xfer`] — PCIe DMA transfer model.
//! * [`exec`] — *functional* execution of offloaded programs for numeric
//!   verification (outlined-kernel interpretation).
//! * [`compile_model`] — the hours-long place-and-route wall-clock model
//!   behind the paper's "half day" automation figure.
//!
//! The transfer model alone explains most routing decisions — a PCIe
//! crossing has a fixed latency floor no small transfer can amortize:
//!
//! ```
//! use fpga_offload::fpga::dma_time;
//! use fpga_offload::hls::ARRIA10_GX;
//!
//! let tiny = dma_time(&ARRIA10_GX, 64);
//! let big = dma_time(&ARRIA10_GX, 4 << 20);
//! assert!(big > tiny);
//! // The 64-byte transfer is pure latency: doubling its bytes moves
//! // the cost by well under a percent.
//! assert!(dma_time(&ARRIA10_GX, 128) < tiny * 1.01);
//! assert_eq!(dma_time(&ARRIA10_GX, 0), 0.0);
//! ```

pub mod compile_model;
pub mod exec;
pub mod sim;
pub mod xfer;

pub use compile_model::{automation_time, makespan, CompileJob};
pub use exec::{verify_pattern, verify_pattern_with, VerifyResult};
pub use sim::{simulate, subtree_ids, LoopTiming, PatternTiming, SimError};
pub use xfer::{dma_time, launch_overhead};
