//! Compile-time model for the verification environment.
//!
//! Full FPGA place-and-route takes hours (paper §5.2: "about 3 hours to
//! compile one offload pattern" → "about half day" for 4 patterns). The
//! verification environment schedules pattern compiles on a pool of build
//! machines; this module computes the makespan so the automation-time
//! experiment (EXPERIMENTS.md, §5.2 text) is reproducible without
//! actually burning 12 hours.

/// A compile job (one offload pattern).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompileJob {
    /// Modeled compile duration, seconds.
    pub duration_s: f64,
}

/// Makespan of `jobs` on `machines` identical build machines using LPT
/// (longest processing time first) list scheduling — what a Jenkins-style
/// verification environment with a worker pool does.
pub fn makespan(jobs: &[CompileJob], machines: usize) -> f64 {
    assert!(machines > 0, "need at least one build machine");
    let mut sorted: Vec<f64> = jobs.iter().map(|j| j.duration_s).collect();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut loads = vec![0.0f64; machines];
    for d in sorted {
        // Assign to least-loaded machine.
        let (idx, _) = loads
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        loads[idx] += d;
    }
    loads.into_iter().fold(0.0, f64::max)
}

/// Total automation time: sequential measurement rounds, each round's
/// compiles in parallel on the machine pool, plus per-pattern measurement
/// time (sample-test execution, minutes at most).
pub fn automation_time(
    rounds: &[Vec<CompileJob>],
    machines: usize,
    measure_s_per_pattern: f64,
) -> f64 {
    rounds
        .iter()
        .map(|round| {
            makespan(round, machines)
                + round.len() as f64 * measure_s_per_pattern
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(h: f64) -> CompileJob {
        CompileJob {
            duration_s: h * 3600.0,
        }
    }

    #[test]
    fn single_machine_sums() {
        let jobs = vec![job(3.0), job(3.0), job(3.0)];
        assert!((makespan(&jobs, 1) - 9.0 * 3600.0).abs() < 1.0);
    }

    #[test]
    fn enough_machines_parallelize() {
        let jobs = vec![job(3.0), job(2.0), job(1.0)];
        assert!((makespan(&jobs, 3) - 3.0 * 3600.0).abs() < 1.0);
    }

    #[test]
    fn lpt_balances() {
        let jobs = vec![job(3.0), job(2.0), job(2.0), job(1.0)];
        // 2 machines: LPT → {3,1}, {2,2} → makespan 4 h.
        assert!((makespan(&jobs, 2) - 4.0 * 3600.0).abs() < 1.0);
    }

    #[test]
    fn paper_half_day_scenario() {
        // §5.1.2/§5.2: 4 patterns (3 singles + 1 combo), ~3 h each, one
        // verification machine, two rounds (3 then 1) → ~12 h ≈ half day.
        let rounds = vec![
            vec![job(3.0), job(3.0), job(3.0)],
            vec![job(3.0)],
        ];
        let t = automation_time(&rounds, 1, 120.0);
        let hours = t / 3600.0;
        assert!(
            (11.0..14.0).contains(&hours),
            "automation should be about half a day: {hours:.1} h"
        );
    }

    #[test]
    #[should_panic(expected = "at least one")]
    fn zero_machines_panics() {
        makespan(&[job(1.0)], 0);
    }
}
