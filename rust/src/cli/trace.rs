//! `repro trace` — inspect the observability layer's span ring: recent
//! traces as a summary table, one trace as an indented tree, slow-root
//! outlier capture, and NDJSON / Chrome-trace dumps.
//!
//! The data source is either a live daemon (the `trace` op over the
//! line protocol) or an NDJSON dump written earlier with `--out`
//! (re-read with `--in` — same filters, no daemon needed).

use crate::obs::export::{
    from_ndjson, render_summary, render_tree, sort_spans, to_chrome,
    to_ndjson,
};
use crate::obs::SpanRow;
use crate::service::{Client, DEFAULT_ADDR};
use crate::util::json::Json;

use super::Flags;

/// The `--in FILE` equivalent of the daemon-side span selection: one
/// trace by id, slow-root traces, or the last N traces.
fn filter_rows(
    rows: Vec<SpanRow>,
    trace_id: Option<u64>,
    slow_ms: Option<f64>,
    last: usize,
) -> Vec<SpanRow> {
    use std::collections::BTreeSet;
    if let Some(id) = trace_id {
        return rows.into_iter().filter(|s| s.trace_id == id).collect();
    }
    let keep: BTreeSet<u64> = match slow_ms {
        Some(ms) => {
            let cut_us = (ms * 1000.0).max(0.0) as u64;
            rows.iter()
                .filter(|s| s.parent_id == 0 && s.duration_us() >= cut_us)
                .map(|s| s.trace_id)
                .collect()
        }
        None => {
            let mut ids: Vec<u64> =
                rows.iter().map(|s| s.trace_id).collect();
            ids.sort_unstable();
            ids.dedup();
            ids.into_iter().rev().take(last).collect()
        }
    };
    rows.into_iter()
        .filter(|s| keep.contains(&s.trace_id))
        .collect()
}

pub(super) fn cmd_trace(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let trace_id: Option<u64> = match f.value("--id") {
        None => None,
        Some(v) => Some(v.parse().map_err(|_| {
            anyhow::anyhow!("bad value for --id: {v:?}")
        })?),
    };
    let slow_ms: Option<f64> = match f.value("--slow-ms") {
        None => None,
        Some(v) => Some(v.parse().map_err(|_| {
            anyhow::anyhow!("bad value for --slow-ms: {v:?}")
        })?),
    };
    let last: usize = f.num("--last", 8usize)?;

    let mut spans: Vec<SpanRow> = match f.value("--in") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            let rows = from_ndjson(&text)
                .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
            filter_rows(rows, trace_id, slow_ms, last)
        }
        None => {
            let addr = f.value("--addr").unwrap_or(DEFAULT_ADDR);
            let mut client = Client::connect(addr)?;
            let resp = client.trace(1, trace_id, slow_ms, Some(last))?;
            let Some(arr) = resp.get(&["spans"]).and_then(Json::as_arr)
            else {
                anyhow::bail!("trace response carries no spans: {resp}");
            };
            arr.iter().filter_map(SpanRow::from_json).collect()
        }
    };
    sort_spans(&mut spans);

    if let Some(path) = f.value("--out") {
        std::fs::write(path, to_ndjson(&spans))
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("{} span(s) written to {path}", spans.len());
        return Ok(());
    }
    if let Some(path) = f.value("--chrome") {
        std::fs::write(path, to_chrome(&spans).pretty())
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!(
            "chrome trace ({} spans) written to {path} — load it in \
             chrome://tracing or Perfetto",
            spans.len()
        );
        return Ok(());
    }
    if f.has("--json") {
        print!("{}", to_ndjson(&spans));
        return Ok(());
    }
    if spans.is_empty() {
        println!(
            "no spans matched (is tracing on? `repro serve` traces \
             unless started with --no-trace)"
        );
        return Ok(());
    }
    if trace_id.is_some() {
        print!("{}", render_tree(&spans));
    } else {
        print!("{}", render_summary(&spans));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(trace: u64, parent: u64, start: u64, end: u64) -> SpanRow {
        SpanRow {
            trace_id: trace,
            span_id: if parent == 0 { 1 } else { 2 },
            parent_id: parent,
            name: "request".to_string(),
            detail: String::new(),
            start_us: start,
            end_us: end,
        }
    }

    #[test]
    fn dump_filters_match_the_daemon_semantics() {
        let rows = vec![
            row(1, 0, 0, 500_000),
            row(1, 1, 10, 20),
            row(2, 0, 0, 100),
            row(3, 0, 0, 80_000),
        ];
        let one = filter_rows(rows.clone(), Some(1), None, 8);
        assert_eq!(one.len(), 2);
        let slow = filter_rows(rows.clone(), None, Some(60.0), 8);
        let ids: Vec<u64> = slow.iter().map(|s| s.trace_id).collect();
        assert!(ids.contains(&1) && ids.contains(&3) && !ids.contains(&2));
        let newest = filter_rows(rows, None, None, 1);
        assert!(newest.iter().all(|s| s.trace_id == 3));
    }

    #[test]
    fn trace_cli_reads_back_a_dump() {
        use crate::util::tempdir::TempDir;
        let dir = TempDir::new("cli-trace-dump").unwrap();
        let dump = dir.join("spans.ndjson");
        let rows = vec![row(1, 0, 0, 900), row(1, 1, 10, 20)];
        std::fs::write(&dump, to_ndjson(&rows)).unwrap();
        let dump_s = dump.to_string_lossy().into_owned();
        let chrome = dir.join("trace.json");
        let chrome_s = chrome.to_string_lossy().into_owned();
        let s = |v: &[&str]| -> Vec<String> {
            v.iter().map(|x| x.to_string()).collect()
        };
        // Summary, tree, and chrome re-export all succeed offline.
        assert_eq!(crate::cli::run(&s(&["trace", "--in", &dump_s])), 0);
        assert_eq!(
            crate::cli::run(&s(&[
                "trace", "--in", &dump_s, "--id", "1"
            ])),
            0
        );
        assert_eq!(
            crate::cli::run(&s(&[
                "trace", "--in", &dump_s, "--chrome", &chrome_s
            ])),
            0
        );
        let text = std::fs::read_to_string(&chrome).unwrap();
        let j = Json::parse(&text).unwrap();
        let events = j.get(&["traceEvents"]).unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
    }
}
