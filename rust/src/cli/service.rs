//! `repro serve`, `repro client`, and `repro patterndb` — the CLI face
//! of the [`crate::service`] tier.
//!
//! `serve` keeps a [`Service`] resident behind the newline-delimited
//! JSON TCP protocol; `client` is the matching line-protocol client
//! (one response line per request, `--json` for the raw lines);
//! `patterndb` inspects a pattern-DB directory offline (record stats,
//! quarantined files) without starting a daemon.

use std::io::Write as _;
use std::path::PathBuf;
use std::time::Duration;

use crate::envadapt::patterndb::unix_now;
use crate::envadapt::PatternDb;
use crate::obs::TraceConfig;
use crate::search::RetryPolicy;
use crate::service::{
    BackendKind, Client, Service, ServiceConfig, TcpServer,
    DEFAULT_ADDR,
};
use crate::util::json::Json;
use crate::workloads;

use super::{config_from_flags, Flags};

fn service_config(f: &Flags) -> anyhow::Result<ServiceConfig> {
    let backend = match f.value("--backend") {
        None => BackendKind::Fpga,
        Some(v) => BackendKind::parse(v).ok_or_else(|| {
            anyhow::anyhow!(
                "bad value for --backend: {v:?} (use fpga|gpu|omp|cpu)"
            )
        })?,
    };
    let max_age = match f.value("--max-age") {
        None => None,
        Some(v) => Some(Duration::from_secs(v.parse().map_err(|_| {
            anyhow::anyhow!("bad value for --max-age: {v:?} (seconds)")
        })?)),
    };
    let stage_deadline: Option<f64> = match f.value("--stage-deadline") {
        None => None,
        Some(v) => Some(v.parse().map_err(|_| {
            anyhow::anyhow!("bad value for --stage-deadline: {v:?}")
        })?),
    };
    let retry = if f.value("--retries").is_some() || stage_deadline.is_some()
    {
        Some(RetryPolicy {
            max_attempts: f.num("--retries", 3u32)?,
            stage_deadline_s: stage_deadline,
            ..RetryPolicy::default()
        })
    } else {
        None
    };
    let db_capacity = match f.value("--db-capacity") {
        None => None,
        Some(v) => Some(v.parse().map_err(|_| {
            anyhow::anyhow!("bad value for --db-capacity: {v:?} (records)")
        })?),
    };
    let trace_default = TraceConfig::default();
    let trace = TraceConfig {
        enabled: !f.has("--no-trace"),
        capacity: f.num("--trace-capacity", trace_default.capacity)?,
        sample: f.num("--trace-sample", trace_default.sample)?,
    };
    let cfg = ServiceConfig {
        search: config_from_flags(f)?,
        backend,
        pattern_db: f.value("--pattern-db").map(PathBuf::from),
        workers: f.num("--workers", 2usize)?,
        queue_cap: f.num("--queue-cap", 64usize)?,
        max_age,
        refresh_ahead: f.num("--refresh-ahead", 0.8f64)?,
        retry,
        db_capacity,
        trace,
    };
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(cfg)
}

pub(super) fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let cfg = service_config(&f)?;
    let addr = f.value("--addr").unwrap_or(DEFAULT_ADDR).to_string();
    let workers = cfg.workers;
    let queue_cap = cfg.queue_cap;
    let service = Service::start(cfg)?;
    let server = TcpServer::bind(service, &addr)?;
    let local = server.local_addr();
    if let Some(path) = f.value("--port-file") {
        // Written atomically-enough for the smoke test: the file appears
        // with the full address in one create+write.
        let mut tmp = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        writeln!(tmp, "{local}")
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    }
    println!(
        "serving on {local} — {workers} workers, queue {queue_cap} \
         (send {{\"op\":\"shutdown\"}} or Ctrl-C to stop)"
    );
    server.wait();
    println!("drained; bye");
    Ok(())
}

/// The aligned human view of a stats snapshot (`client --stats`). The
/// raw JSON — schema pinned by the golden test in
/// [`crate::service::stats`] — stays available behind `--json`.
fn render_stats(stats: &Json) -> String {
    let n = |k: &str| stats.get(&[k]).and_then(Json::as_f64).unwrap_or(0.0);
    let mut out = String::from("service\n");
    for (label, key) in [
        ("requests", "requests"),
        ("hits", "hits"),
        ("misses", "misses"),
        ("coalesced", "coalesced"),
        ("rejected", "rejected"),
        ("timeouts", "timeouts"),
        ("degraded", "degraded"),
        ("solves", "solves"),
        ("solve errors", "solve_errors"),
        ("avg solve ms", "avg_solve_ms"),
        ("queue depth", "queue_depth"),
        ("inflight", "inflight"),
        ("refreshes scheduled", "refreshes_scheduled"),
        ("refreshes done", "refreshes_done"),
        ("refreshes dropped", "refreshes_dropped"),
    ] {
        out.push_str(&format!("  {label:<22} {:>12}\n", n(key)));
    }
    out.push_str("latency (us)\n");
    for (label, p50, p99, max) in [
        ("hit", "hit_p50_us", "hit_p99_us", "hit_max_us"),
        ("miss", "miss_p50_us", "miss_p99_us", "miss_max_us"),
    ] {
        out.push_str(&format!(
            "  {label:<8} p50 {:>10}  p99 {:>10}  max {:>10}\n",
            n(p50),
            n(p99),
            n(max)
        ));
    }
    out.push_str("store\n");
    for (label, key) in [
        ("index records", "index_records"),
        ("index hits", "index_hits"),
        ("index misses", "index_misses"),
        ("stale hits", "stale_hits"),
        ("appends", "appends"),
        ("stale writes dropped", "stale_writes_dropped"),
        ("evictions", "evictions"),
        ("compactions", "compactions"),
        ("quarantined bytes", "quarantined_bytes"),
        ("torn truncations", "torn_truncations"),
    ] {
        out.push_str(&format!("  {label:<22} {:>12}\n", n(key)));
    }
    out.push_str("retries (per stage)\n");
    let stage = |s: &str, k: &str| {
        stats
            .get(&["faults", s, k])
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
    };
    out.push_str(&format!(
        "  {:<10} {:>10} {:>10} {:>10} {:>10} {:>8} {:>11}\n",
        "stage", "calls", "retries", "exhausted", "timeouts", "panics",
        "backoff s"
    ));
    for s in ["measure", "verify", "deploy"] {
        out.push_str(&format!(
            "  {s:<10} {:>10} {:>10} {:>10} {:>10} {:>8} {:>11.1}\n",
            stage(s, "calls"),
            stage(s, "retries"),
            stage(s, "exhausted"),
            stage(s, "timeouts"),
            stage(s, "panics"),
            stage(s, "backoff_s"),
        ));
    }
    out
}

pub(super) fn cmd_client(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let addr = f.value("--addr").unwrap_or(DEFAULT_ADDR);
    let raw_json = f.has("--json");
    let mut client = Client::connect(addr)?;
    let mut id = 0u64;

    if f.has("--shutdown") {
        let resp = client.shutdown(id)?;
        if raw_json {
            println!("{resp}");
        } else {
            println!(
                "shutdown: {}",
                resp.get(&["status"]).and_then(Json::as_str).unwrap_or("?")
            );
        }
        return Ok(());
    }

    let stats_only = (f.has("--stats") || f.has("--metrics"))
        && f.positionals().is_empty();
    let mut failed = 0usize;
    if !stats_only {
        let apps: Vec<String> = {
            let given = f.positionals();
            if given.is_empty() {
                workloads::APPS.iter().map(|s| s.to_string()).collect()
            } else {
                given.iter().map(|s| s.to_string()).collect()
            }
        };
        let deadline_ms: Option<u64> = match f.value("--deadline-ms") {
            None => None,
            Some(v) => Some(v.parse().map_err(|_| {
                anyhow::anyhow!("bad value for --deadline-ms: {v:?}")
            })?),
        };
        for app in &apps {
            id += 1;
            let resp = client.plan(id, app, None, deadline_ms)?;
            let status = resp
                .get(&["status"])
                .and_then(Json::as_str)
                .unwrap_or("?")
                .to_string();
            if status != "ok" {
                failed += 1;
            }
            if raw_json {
                println!("{resp}");
                continue;
            }
            if status == "ok" {
                println!(
                    "{app}: {} {:.2}x [{}] {}us{}",
                    resp.get(&["label"])
                        .and_then(Json::as_str)
                        .unwrap_or("?"),
                    resp.get(&["speedup"])
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0),
                    resp.get(&["class"])
                        .and_then(Json::as_str)
                        .unwrap_or("?"),
                    resp.get(&["latency_us"])
                        .and_then(Json::as_f64)
                        .unwrap_or(0.0),
                    if resp.get(&["refresh_ahead"]).and_then(Json::as_bool)
                        == Some(true)
                    {
                        " (refresh scheduled)"
                    } else {
                        ""
                    },
                );
            } else {
                println!(
                    "{app}: {status} — {}",
                    resp.get(&["message"])
                        .and_then(Json::as_str)
                        .unwrap_or("?"),
                );
            }
        }
    }

    if f.has("--stats") {
        id += 1;
        let resp = client.stats(id)?;
        if raw_json {
            println!("{resp}");
        } else if let Some(stats) = resp.get(&["stats"]) {
            print!("{}", render_stats(stats));
        }
    }

    if f.has("--metrics") {
        id += 1;
        // Prometheus exposition is already a text format; print it
        // verbatim (it is what a scraper would ingest).
        print!("{}", client.metrics(id)?);
    }

    if failed > 0 {
        anyhow::bail!("{failed} request(s) not served");
    }
    Ok(())
}

pub(super) fn cmd_patterndb(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let sub = f.positional(0).ok_or_else(|| {
        anyhow::anyhow!(
            "usage: repro patterndb \
             <stats|quarantined|migrate|compact|export> --pattern-db DIR"
        )
    })?;
    let dir = f.value("--pattern-db").ok_or_else(|| {
        anyhow::anyhow!("patterndb {sub} needs --pattern-db DIR")
    })?;
    let db = PatternDb::open(std::path::Path::new(dir))?;
    match sub {
        "stats" => {
            let apps = db.list()?;
            let mut by_backend: Vec<(String, usize)> = Vec::new();
            let mut verified = 0usize;
            let mut unstamped = 0usize;
            // Age histogram: <1h, <1d, <7d, older.
            let mut ages = [0usize; 4];
            let now = unix_now();
            let mut loaded = 0usize;
            for app in &apps {
                let Some(rec) = db.load_record(app)? else {
                    continue;
                };
                loaded += 1;
                let backend = rec
                    .backend
                    .clone()
                    .unwrap_or_else(|| "unkeyed".into());
                match by_backend.iter_mut().find(|(b, _)| *b == backend) {
                    Some((_, n)) => *n += 1,
                    None => by_backend.push((backend, 1)),
                }
                if rec.verified == Some(true) {
                    verified += 1;
                }
                match rec.age_secs(now) {
                    None => unstamped += 1,
                    Some(age) if age < 3600 => ages[0] += 1,
                    Some(age) if age < 86_400 => ages[1] += 1,
                    Some(age) if age < 604_800 => ages[2] += 1,
                    Some(_) => ages[3] += 1,
                }
            }
            by_backend.sort();
            println!("pattern DB {dir}: {loaded} records");
            for (backend, n) in &by_backend {
                println!("  backend {backend}: {n}");
            }
            println!(
                "  age: {} <1h, {} <1d, {} <7d, {} older, {} unstamped",
                ages[0], ages[1], ages[2], ages[3], unstamped
            );
            println!("  verified at store time: {verified}/{loaded}");
            let store = db.store_handle();
            let snap = store.stats().snapshot();
            println!(
                "  store: {} shards, {} dead record(s), \
                 {} eviction(s), {} compaction(s)",
                crate::store::SHARD_COUNT,
                store.dead_records(),
                snap.evictions,
                snap.compactions,
            );
            match store.capacity() {
                Some(cap) => println!("  capacity: {cap} records"),
                None => println!("  capacity: unbounded"),
            }
            let legacy = store.legacy_count();
            if legacy > 0 {
                println!(
                    "  {legacy} legacy flat file(s) present — run \
                     `repro patterndb migrate --pattern-db {dir}`"
                );
            }
            // A running daemon owns the live hit/miss counters.
            if let Some(addr) = f.value("--addr") {
                let mut client = Client::connect(addr)?;
                let resp = client.stats(1)?;
                if let Some(stats) = resp.get(&["stats"]) {
                    let count = |k: &str| {
                        stats
                            .get(&[k])
                            .and_then(Json::as_f64)
                            .unwrap_or(0.0)
                    };
                    println!(
                        "  live service: {} hits / {} misses \
                         (index: {} hits / {} misses, {} stale, \
                         {} evictions, {} compactions)",
                        count("hits"),
                        count("misses"),
                        count("index_hits"),
                        count("index_misses"),
                        count("stale_hits"),
                        count("evictions"),
                        count("compactions"),
                    );
                }
            }
        }
        "quarantined" => {
            let bad = db.quarantined()?;
            if bad.is_empty() {
                println!("pattern DB {dir}: no quarantined records");
            } else {
                println!(
                    "pattern DB {dir}: {} quarantined record(s)",
                    bad.len()
                );
                for name in &bad {
                    // Shard-log sidecars quarantine whole log suffixes;
                    // anything else is a legacy flat record.
                    if name.starts_with("shard-") {
                        println!("  {name}  ({name}.corrupt)");
                    } else {
                        println!(
                            "  {name}  ({name}.pattern.json.corrupt)"
                        );
                    }
                }
            }
        }
        "migrate" => {
            let report = db.store_handle().migrate_legacy()?;
            println!(
                "pattern DB {dir}: migrated {} record(s), \
                 {} skipped (stale), {} quarantined",
                report.migrated, report.skipped_stale, report.quarantined
            );
        }
        "compact" => {
            let reclaimed = db.store_handle().compact_all()?;
            println!(
                "pattern DB {dir}: compacted, \
                 {reclaimed} dead record(s) reclaimed"
            );
        }
        "export" => {
            let out = f.value("--out").ok_or_else(|| {
                anyhow::anyhow!("patterndb export needs --out DIR")
            })?;
            let written = db
                .store_handle()
                .export_legacy(std::path::Path::new(out))?;
            println!(
                "pattern DB {dir}: exported {written} flat record(s) \
                 to {out}"
            );
        }
        other => anyhow::bail!(
            "unknown patterndb subcommand {other:?} \
             (use stats|quarantined|migrate|compact|export)"
        ),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use crate::cli::run;
    use crate::util::tempdir::TempDir;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn patterndb_stats_on_fresh_dir() {
        let dir = TempDir::new("cli-pdb-stats").unwrap();
        let d = dir.path().to_string_lossy().into_owned();
        assert_eq!(
            run(&s(&["patterndb", "stats", "--pattern-db", &d])),
            0
        );
        assert_eq!(
            run(&s(&["patterndb", "quarantined", "--pattern-db", &d])),
            0
        );
    }

    #[test]
    fn patterndb_needs_a_dir() {
        assert_eq!(run(&s(&["patterndb", "stats"])), 1);
        assert_eq!(run(&s(&["patterndb"])), 1);
    }

    #[test]
    fn patterndb_counts_stored_records_and_quarantine() {
        let dir = TempDir::new("cli-pdb-counts").unwrap();
        let d = dir.path().to_string_lossy().into_owned();
        // A real record via a batch solve, plus a corrupt file.
        assert_eq!(
            run(&s(&[
                "batch",
                "sobel",
                "--pattern-db",
                &d,
                "--out",
                &dir.join("r.json").to_string_lossy().into_owned(),
            ])),
            0
        );
        std::fs::write(
            dir.join("broken.pattern.json.corrupt"),
            "not json",
        )
        .unwrap();
        assert_eq!(
            run(&s(&["patterndb", "stats", "--pattern-db", &d])),
            0
        );
        assert_eq!(
            run(&s(&["patterndb", "quarantined", "--pattern-db", &d])),
            0
        );
    }

    #[test]
    fn stats_table_renders_all_sections() {
        use crate::util::json::Json;
        let stats = Json::parse(
            r#"{"requests": 10, "hits": 7, "hit_p50_us": 120,
                "faults": {"measure": {"retries": 3,
                                       "backoff_s": 90.0}}}"#,
        )
        .unwrap();
        let table = super::render_stats(&stats);
        for section in
            ["service", "latency (us)", "store", "retries (per stage)"]
        {
            assert!(table.contains(section), "{table}");
        }
        assert!(table
            .lines()
            .any(|l| l.contains("requests") && l.contains("10")));
        assert!(table
            .lines()
            .any(|l| l.contains("p50") && l.contains("120")));
        assert!(table
            .lines()
            .any(|l| l.starts_with("  measure")
                && l.contains('3')
                && l.contains("90.0")));
    }

    #[test]
    fn serve_rejects_bad_flags() {
        assert_eq!(
            run(&s(&["serve", "--backend", "tpu"])),
            1
        );
        assert_eq!(
            run(&s(&["serve", "--refresh-ahead", "2.0"])),
            1
        );
        assert_eq!(run(&s(&["serve", "--db-capacity", "0"])), 1);
        assert_eq!(run(&s(&["serve", "--trace-capacity", "0"])), 1);
        assert_eq!(run(&s(&["serve", "--trace-sample", "0"])), 1);
        assert_eq!(run(&s(&["client", "--addr", "127.0.0.1:1"])), 1);
    }

    #[test]
    fn patterndb_export_then_migrate_roundtrip() {
        let dir = TempDir::new("cli-pdb-migrate").unwrap();
        let d = dir.path().to_string_lossy().into_owned();
        assert_eq!(
            run(&s(&[
                "batch",
                "sobel",
                "--pattern-db",
                &d,
                "--out",
                &dir.join("r.json").to_string_lossy().into_owned(),
            ])),
            0
        );
        // Export the record as a legacy flat file into a fresh dir,
        // then migrate it into that dir's sharded store.
        let legacy = dir.join("legacy");
        let l = legacy.to_string_lossy().into_owned();
        assert_eq!(
            run(&s(&[
                "patterndb", "export", "--pattern-db", &d, "--out", &l,
            ])),
            0
        );
        assert!(legacy.join("sobel.pattern.json").exists());
        assert_eq!(
            run(&s(&["patterndb", "migrate", "--pattern-db", &l])),
            0
        );
        assert!(legacy.join("sobel.pattern.json.migrated").exists());
        assert_eq!(
            run(&s(&["patterndb", "compact", "--pattern-db", &l])),
            0
        );
        assert_eq!(
            run(&s(&["patterndb", "stats", "--pattern-db", &l])),
            0
        );
    }

    #[test]
    fn patterndb_export_needs_out() {
        let dir = TempDir::new("cli-pdb-export").unwrap();
        let d = dir.path().to_string_lossy().into_owned();
        assert_eq!(
            run(&s(&["patterndb", "export", "--pattern-db", &d])),
            1
        );
    }
}
