//! Command-line interface (hand-rolled; no clap in the offline crate set).
//!
//! ```text
//! repro offload <app|file.c> [--explain] [--top-a N] [--unroll B]
//!               [--top-c N] [--max-patterns D] [--machines N]
//!               [--pattern-db DIR] [--pjrt] [--no-verify]
//!               [--engine interp|vm]
//! repro analyze <app|file.c>       loop table + intensity ranking
//! repro estimate <app|file.c> [--unroll B]   pre-compile reports (top-A)
//! repro opencl <app|file.c> --loop N [--unroll B]   emit kernel + host
//! repro ga <app|file.c> [--seed S]           GA baseline from [32]
//! repro run-sample <tdfir|mriq>    PJRT sample test only
//! repro apps                       list bundled applications
//! ```

use crate::analysis::{analyze_with, Analysis};
use crate::cpu::XEON_BRONZE_3104;
use crate::envadapt::{FlowOptions, TestDb};
use crate::hls::{render, ARRIA10_GX};
use crate::minic::{parse, typecheck, EngineKind, Program};
use crate::runtime::{Artifacts, Runtime};
use crate::search::{GaConfig, SearchConfig};
use crate::workloads;

/// Entry point. Returns the process exit code.
pub fn run(args: &[String]) -> i32 {
    let result = match args.first().map(String::as_str) {
        Some("offload") => cmd_offload(&args[1..]),
        Some("analyze") => cmd_analyze(&args[1..]),
        Some("estimate") => cmd_estimate(&args[1..]),
        Some("opencl") => cmd_opencl(&args[1..]),
        Some("ga") => cmd_ga(&args[1..]),
        Some("run-sample") => cmd_run_sample(&args[1..]),
        Some("apps") => {
            for app in workloads::APPS {
                println!("{app}");
            }
            Ok(())
        }
        Some("--help") | Some("-h") | None => {
            print_usage();
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n");
            print_usage();
            return 2;
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn print_usage() {
    println!(
        "repro — automatic FPGA offloading of application loop statements\n\
         (Yamato 2020 reproduction; FPGA toolchain simulated, numerics via\n\
         Pallas→HLO→PJRT artifacts)\n\
         \n\
         USAGE: repro <subcommand> [options]\n\
         \n\
         SUBCOMMANDS\n\
           offload <app|file.c>   full flow: analyze → funnel → measure → pick\n\
             --explain            print the funnel trace and reports\n\
             --engine E           execution engine: vm (default) | interp\n\
             --top-a N            intensity narrowing (default 5)\n\
             --unroll B           loop expansion factor (default 1)\n\
             --top-c N            resource-efficiency narrowing (default 3)\n\
             --max-patterns D     measurement budget (default 4)\n\
             --machines N         verification build machines (default 1)\n\
             --pattern-db DIR     persist the solution\n\
             --pjrt               run the PJRT sample test (step 6)\n\
             --no-verify          skip functional verification\n\
           analyze <app|file.c>   loop table with intensity ranking\n\
           estimate <app|file.c>  pre-compile resource reports (top-A)\n\
           opencl <app|file.c> --loop N   emit OpenCL kernel + host text\n\
           ga <app|file.c>        GA baseline search ([32])\n\
           run-sample <tdfir|mriq>  PJRT sample test\n\
           apps                   list bundled applications\n\
         \n\
         <app> is one of the bundled apps (repro apps) or a path to a .c file."
    );
}

/// Resolve an app name or .c path to (name, source).
fn resolve_source(spec: &str) -> anyhow::Result<(String, String)> {
    if let Some(src) = workloads::source(spec) {
        return Ok((spec.to_string(), src.to_string()));
    }
    if spec.ends_with(".c") {
        let text = std::fs::read_to_string(spec)
            .map_err(|e| anyhow::anyhow!("reading {spec}: {e}"))?;
        let name = std::path::Path::new(spec)
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "custom".into());
        return Ok((name, text));
    }
    anyhow::bail!(
        "unknown app {spec:?} — use `repro apps` or pass a .c file path"
    )
}

fn parse_and_analyze(
    src: &str,
    engine: EngineKind,
) -> anyhow::Result<(Program, Analysis)> {
    let prog = parse(src).map_err(|e| anyhow::anyhow!("{e}"))?;
    typecheck::check_ok(&prog).map_err(|e| anyhow::anyhow!("{e}"))?;
    let an = analyze_with(&prog, "main", engine)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok((prog, an))
}

fn engine_from_flags(f: &Flags) -> anyhow::Result<EngineKind> {
    match f.value("--engine") {
        None => Ok(EngineKind::default()),
        Some(v) => EngineKind::parse(v).ok_or_else(|| {
            anyhow::anyhow!("bad value for --engine: {v:?} (use interp|vm)")
        }),
    }
}

/// Tiny flag parser: positional args + `--key value` + `--switch`.
struct Flags<'a> {
    args: &'a [String],
}

impl<'a> Flags<'a> {
    fn positional(&self, n: usize) -> Option<&'a str> {
        self.args
            .iter()
            .filter(|a| !a.starts_with("--"))
            .nth(n)
            .map(String::as_str)
    }

    fn has(&self, name: &str) -> bool {
        self.args.iter().any(|a| a == name)
    }

    fn value(&self, name: &str) -> Option<&'a str> {
        let idx = self.args.iter().position(|a| a == name)?;
        self.args.get(idx + 1).map(String::as_str)
    }

    fn num<T: std::str::FromStr>(&self, name: &str, default: T) -> anyhow::Result<T> {
        match self.value(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value for {name}: {v:?}")),
        }
    }
}

fn config_from_flags(f: &Flags) -> anyhow::Result<SearchConfig> {
    let d = SearchConfig::default();
    let top_c = f.num("--top-c", d.top_c)?;
    let cfg = SearchConfig {
        top_a: f.num("--top-a", d.top_a)?,
        unroll: f.num("--unroll", d.unroll)?,
        top_c,
        first_round: f.num("--first-round", d.first_round.min(top_c))?,
        max_patterns: f.num("--max-patterns", d.max_patterns)?,
        build_machines: f.num("--machines", d.build_machines)?,
        verify_numerics: !f.has("--no-verify"),
        engine: engine_from_flags(f)?,
        ..d
    };
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(cfg)
}

fn cmd_offload(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let spec = f
        .positional(0)
        .ok_or_else(|| anyhow::anyhow!("usage: repro offload <app|file.c>"))?;
    let (app, src) = resolve_source(spec)?;
    let cfg = config_from_flags(&f)?;

    let mut testdb = TestDb::builtin();
    if testdb.get(&app).is_none() {
        testdb.register(crate::envadapt::TestCase {
            app: app.clone(),
            entry: "main".into(),
            observed_arrays: vec![],
            pjrt_sample: None,
            description: format!("user-supplied application {app}"),
        });
    }

    let (rt, art);
    let runtime_pair = if f.has("--pjrt") {
        let cwd = std::env::current_dir()?;
        art = Artifacts::discover(&cwd)?;
        rt = Runtime::cpu()?;
        Some((&rt, &art))
    } else {
        None
    };

    let pattern_db = f.value("--pattern-db").map(std::path::PathBuf::from);
    let opts = FlowOptions {
        config: cfg,
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
        pattern_db: pattern_db.as_deref(),
        runtime: runtime_pair,
        seed: f.num("--seed", 42u64)?,
    };
    let report = crate::envadapt::run_flow(&app, &src, &testdb, &opts)?;
    let sol = &report.solution;

    if f.has("--explain") {
        println!("== funnel (Fig. 2) ==");
        println!(
            "loops {} → offloadable {} → top-A {} → top-C {}",
            sol.funnel.total_loops,
            sol.funnel.offloadable.len(),
            sol.funnel.top_a.len(),
            sol.funnel.top_c.len()
        );
        for r in &sol.funnel.reports {
            println!("{}", render(r));
        }
    }

    println!("== measurements ==");
    for m in &sol.measurements {
        println!(
            "round {} pattern {:<12} speedup {:>6.2}x  compile {:>4.1} h  verified {}",
            m.round,
            m.label(),
            m.speedup(),
            m.compile_s / 3600.0,
            m.verified.map(|v| v.to_string()).unwrap_or("-".into()),
        );
    }
    println!("== solution ==");
    println!(
        "{}: best pattern {} — {:.2}x vs all-CPU (automation {:.1} h)",
        app,
        sol.best_measurement().label(),
        sol.speedup(),
        sol.automation_s / 3600.0
    );
    if let Some(path) = &report.stored_at {
        println!("pattern stored at {}", path.display());
    }
    if let Some(sr) = &report.sample_run {
        println!(
            "PJRT sample test [{}]: exec {:?}, max|err| {:.2e} over {} outputs — OK",
            sr.app, sr.exec_time, sr.max_abs_err, sr.checked
        );
    }
    Ok(())
}

fn cmd_analyze(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let spec = f
        .positional(0)
        .ok_or_else(|| anyhow::anyhow!("usage: repro analyze <app|file.c>"))?;
    let (app, src) = resolve_source(spec)?;
    let (_prog, an) = parse_and_analyze(&src, engine_from_flags(&f)?)?;

    println!("{app}: {} loop statements", an.loops.len());
    println!(
        "{:<5} {:<14} {:>5} {:>10} {:>12} {:>10} {:>12}  {}",
        "loop", "function", "line", "trips", "work(flops)", "ops/acc",
        "score", "status"
    );
    let mut rows: Vec<_> = an.loops.iter().collect();
    rows.sort_by(|a, b| {
        let sa = a.intensity.as_ref().map(|i| i.score).unwrap_or(-1.0);
        let sb = b.intensity.as_ref().map(|i| i.score).unwrap_or(-1.0);
        sb.partial_cmp(&sa).unwrap()
    });
    for al in rows {
        let (trips, work, inten, score) = match &al.intensity {
            Some(i) => (
                i.trips.to_string(),
                i.work.to_string(),
                format!("{:.2}", i.intensity),
                format!("{:.3e}", i.score),
            ),
            None => ("-".into(), "-".into(), "-".into(), "-".into()),
        };
        let status = match &al.info.blocker {
            Some(b) => format!("blocked: {b}"),
            None => format!("{:?}", al.dependence),
        };
        println!(
            "{:<5} {:<14} {:>5} {:>10} {:>12} {:>10} {:>12}  {}",
            al.id().to_string(),
            al.info.function,
            al.info.line,
            trips,
            work,
            inten,
            score,
            status
        );
    }
    Ok(())
}

fn cmd_estimate(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let spec = f
        .positional(0)
        .ok_or_else(|| anyhow::anyhow!("usage: repro estimate <app|file.c>"))?;
    let (_app, src) = resolve_source(spec)?;
    let (prog, an) = parse_and_analyze(&src, engine_from_flags(&f)?)?;
    let cfg = config_from_flags(&f)?;
    let (cands, trace) =
        crate::search::funnel::run(&prog, &an, &cfg, &ARRIA10_GX)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "funnel: {} loops → {} offloadable → top-A {:?} → top-C {:?}",
        trace.total_loops,
        trace.offloadable.len(),
        trace.top_a,
        trace.top_c
    );
    for r in &trace.reports {
        println!("{}", render(r));
    }
    let _ = cands;
    Ok(())
}

fn cmd_opencl(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let spec = f
        .positional(0)
        .ok_or_else(|| anyhow::anyhow!("usage: repro opencl <app|file.c> --loop N"))?;
    let (_app, src) = resolve_source(spec)?;
    let (prog, an) = parse_and_analyze(&src, engine_from_flags(&f)?)?;
    let loop_n: u32 = f.num("--loop", 0)?;
    let unroll_b: u32 = f.num("--unroll", 1)?;
    let al = an
        .loop_by_id(crate::minic::ast::LoopId(loop_n))
        .ok_or_else(|| anyhow::anyhow!("no loop L{loop_n}"))?;
    let sp = crate::codegen::split(&prog, al)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let k = crate::codegen::unroll(&sp.kernel, unroll_b)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("{}", crate::codegen::opencl::kernel_text(&k));
    println!("{}", crate::codegen::opencl::host_text(&k));
    Ok(())
}

fn cmd_ga(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let spec = f
        .positional(0)
        .ok_or_else(|| anyhow::anyhow!("usage: repro ga <app|file.c>"))?;
    let (app, src) = resolve_source(spec)?;
    let (prog, an) = parse_and_analyze(&src, engine_from_flags(&f)?)?;
    let cfg = GaConfig {
        seed: f.num("--seed", GaConfig::default().seed)?,
        ..Default::default()
    };
    let res =
        crate::search::ga::run(&prog, &an, &cfg, &XEON_BRONZE_3104, &ARRIA10_GX);
    println!(
        "{app}: GA best {:?} — {:.2}x after {} measured patterns \
         (modeled compile wall-clock {:.1} h)",
        res.best_loops,
        res.best_speedup,
        res.measurements,
        res.modeled_wall_clock_s / 3600.0
    );
    println!("convergence: {:?}", res.history);
    Ok(())
}

fn cmd_run_sample(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let app = f
        .positional(0)
        .ok_or_else(|| anyhow::anyhow!("usage: repro run-sample <tdfir|mriq>"))?;
    let cwd = std::env::current_dir()?;
    let art = Artifacts::discover(&cwd)?;
    let rt = Runtime::cpu()?;
    let run = crate::runtime::run_app(&rt, &art, app, 42)?;
    println!(
        "{}: exec {:?}, max|err| {:.3e} over {} outputs — OK",
        run.app, run.exec_time, run.max_abs_err, run.checked
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn unknown_subcommand_fails() {
        assert_eq!(run(&s(&["bogus"])), 2);
    }

    #[test]
    fn help_succeeds() {
        assert_eq!(run(&s(&["--help"])), 0);
        assert_eq!(run(&[]), 0);
    }

    #[test]
    fn apps_lists_bundled() {
        assert_eq!(run(&s(&["apps"])), 0);
    }

    #[test]
    fn analyze_bundled_app() {
        assert_eq!(run(&s(&["analyze", "sobel"])), 0);
    }

    #[test]
    fn analyze_unknown_app_fails() {
        assert_eq!(run(&s(&["analyze", "ghost"])), 1);
    }

    #[test]
    fn flags_parse() {
        let args = s(&["sobel", "--top-a", "3", "--explain"]);
        let f = Flags { args: &args };
        assert_eq!(f.positional(0), Some("sobel"));
        assert!(f.has("--explain"));
        assert_eq!(f.num("--top-a", 5usize).unwrap(), 3);
        assert_eq!(f.num("--top-c", 7usize).unwrap(), 7);
    }

    #[test]
    fn opencl_emission_for_sobel() {
        assert_eq!(run(&s(&["opencl", "sobel", "--loop", "4"])), 0);
    }
}
