//! Command-line interface (hand-rolled; no clap in the offline crate set).
//!
//! ```text
//! repro offload <app|file.c> [--explain] [--top-a N] [--unroll B]
//!               [--top-c N] [--max-patterns D] [--machines N]
//!               [--pattern-db DIR] [--reuse] [--pjrt] [--no-verify]
//!               [--engine interp|vm] [--backend fpga|gpu|omp|cpu]
//!               [--entry FN] [--func-blocks]
//! repro batch [apps...] [--out FILE] [--pattern-db DIR] [--reuse]
//!             [--backend fpga|gpu|omp|cpu] [--mixed] [--func-blocks]
//!             [--retries N] [--stage-deadline S] [--inject-faults SEED]
//!             [--trace-out FILE] [--trace-chrome FILE]
//!             + the offload search flags
//! repro analyze <app|file.c>       loop table + intensity ranking
//! repro estimate <app|file.c> [--unroll B]   pre-compile reports (top-A)
//! repro opencl <app|file.c> --loop N [--unroll B]   emit kernel + host
//! repro ga <app|file.c> [--seed S]           GA baseline from [32]
//! repro vmprofile [apps...] [--pairs N] [--baseline] [--regs]
//!                 [--disasm] [--json] [--out FILE] [--entry FN]
//! repro run-sample <tdfir|mriq>    PJRT sample test only
//! repro apps                       list bundled applications
//! repro serve [--addr A] [--port-file F] [--workers N] [--queue-cap N]
//!             [--pattern-db DIR] [--max-age S] [--refresh-ahead F]
//!             [--backend B] [--retries N] [--stage-deadline S]
//!             [--no-trace] [--trace-capacity N] [--trace-sample N]
//!             + the offload search flags
//! repro client [apps...] [--addr A] [--deadline-ms N] [--json]
//!              [--stats] [--metrics] [--shutdown]
//! repro trace [--addr A] [--last N] [--id N] [--slow-ms MS]
//!             [--out FILE] [--chrome FILE] [--in FILE] [--json]
//! repro patterndb <stats|quarantined|migrate|compact|export>
//!                 --pattern-db DIR [--addr A] [--out DIR]
//! ```
//!
//! `offload` and `batch` are thin drivers over the staged
//! [`crate::envadapt::Pipeline`]; `batch` runs every requested app
//! through one shared automation cycle and writes a
//! [`crate::envadapt::BatchReport`] JSON. `batch --mixed` measures every
//! app against all four destinations (FPGA, GPU, many-core OpenMP, CPU
//! control) in one cycle and routes each app to the best verified
//! speedup — the mixed-destination environment of arXiv:2011.12431.
//! `serve`/`client`/`patterndb` front the resident [`crate::service`]
//! tier: a daemon that answers pattern-DB hits from memory in
//! microseconds and funnels misses through a bounded queue and worker
//! pool with typed admission control.

mod service;
mod trace;

use crate::analysis::{analyze_with, Analysis};
use crate::cpu::{XEON_BRONZE_3104, XEON_GOLD_6130};
use crate::envadapt::{Batch, OffloadRequest, Pipeline, TestDb};
use crate::gpu::TESLA_T4;
use crate::hls::{render, ARRIA10_GX};
use crate::minic::{parse, typecheck, EngineKind, Program, ResolveOpts};
use crate::obs::export::{sort_spans, to_chrome, to_ndjson};
use crate::obs::{SpanRow, TraceConfig, Tracer};
use crate::runtime::{Artifacts, Runtime};
use crate::search::{
    Backend, CpuBaseline, FaultPlan, FaultyBackend, FpgaBackend, GaConfig,
    GpuBackend, OmpBackend, RetryPolicy, SearchConfig, SimClock,
};
use crate::workloads;

/// Entry point. Returns the process exit code.
pub fn run(args: &[String]) -> i32 {
    let result = match args.first().map(String::as_str) {
        Some("offload") => cmd_offload(&args[1..]),
        Some("batch") => cmd_batch(&args[1..]),
        Some("analyze") => cmd_analyze(&args[1..]),
        Some("estimate") => cmd_estimate(&args[1..]),
        Some("opencl") => cmd_opencl(&args[1..]),
        Some("ga") => cmd_ga(&args[1..]),
        Some("vmprofile") => cmd_vmprofile(&args[1..]),
        Some("run-sample") => cmd_run_sample(&args[1..]),
        Some("serve") => service::cmd_serve(&args[1..]),
        Some("client") => service::cmd_client(&args[1..]),
        Some("patterndb") => service::cmd_patterndb(&args[1..]),
        Some("trace") => trace::cmd_trace(&args[1..]),
        Some("apps") => {
            for app in workloads::APPS {
                println!("{app}");
            }
            Ok(())
        }
        Some("--help") | Some("-h") | None => {
            print_usage();
            Ok(())
        }
        Some(other) => {
            eprintln!("unknown subcommand {other:?}\n");
            print_usage();
            return 2;
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn print_usage() {
    println!(
        "repro — automatic FPGA offloading of application loop statements\n\
         (Yamato 2020 reproduction; FPGA toolchain simulated, numerics via\n\
         Pallas→HLO→PJRT artifacts)\n\
         \n\
         USAGE: repro <subcommand> [options]\n\
         \n\
         SUBCOMMANDS\n\
           offload <app|file.c>   full staged pipeline: parse → analyze →\n\
                                  extract → measure → select → deploy\n\
             --explain            print the funnel trace and reports\n\
             --engine E           execution engine: vm (default) |\n\
                                  interp | vm-baseline (unfused\n\
                                  encoding) | vm-regs (register\n\
                                  experiment)\n\
             --backend B          destination: fpga (default) | gpu |\n\
                                  omp (many-core OpenMP) | cpu (control)\n\
             --entry FN           entry function for profiling and\n\
                                  verification (default: test-case DB\n\
                                  entry, else main)\n\
             --func-blocks        detect whole algorithmic blocks (FIR,\n\
                                  matmul, stencil, sqrt-magnitude), confirm\n\
                                  them by VM sample test, and replace them\n\
                                  with catalogued IP cores before the loop\n\
                                  funnel runs\n\
             --top-a N            intensity narrowing (default 5)\n\
             --unroll B           loop expansion factor (default 1)\n\
             --top-c N            resource-efficiency narrowing (default 3)\n\
             --max-patterns D     measurement budget (default 4)\n\
             --machines N         verification build machines (default 1)\n\
             --pattern-db DIR     persist the solution\n\
             --reuse              reuse a stored pattern when source,\n\
                                  backend, entry, device and config are\n\
                                  all unchanged (needs --pattern-db)\n\
             --pjrt               run the PJRT sample test (step 6)\n\
             --no-verify          skip functional verification\n\
           batch [apps...]        one automation cycle over many apps\n\
                                  (default: all bundled apps) — shares one\n\
                                  config, runs funnels concurrently\n\
             --backend B          destination: fpga (default) | gpu |\n\
                                  omp (many-core OpenMP) | cpu (control)\n\
             --mixed              measure every app on fpga+gpu+omp+cpu\n\
                                  and route each to its best verified\n\
                                  speedup (per-app `destination` in the\n\
                                  report)\n\
             --func-blocks        enable the function-block path for\n\
                                  every app in the cycle\n\
             --out FILE           batch-report JSON path\n\
                                  (default batch_report.json)\n\
             --retries N          retry budget per measure/verify/deploy\n\
                                  call (bounded exponential backoff on the\n\
                                  simulated clock; default 3 once any\n\
                                  resilience flag is given)\n\
             --stage-deadline S   per-stage deadline budget in simulated\n\
                                  seconds — a call that burns past it is\n\
                                  a timeout fault\n\
             --inject-faults SEED deterministic fault injection around\n\
                                  every destination backend (transient\n\
                                  bursts, hung builds, verify mismatches,\n\
                                  panics — all drawn from SEED); implies\n\
                                  the default retry policy\n\
             --trace-out FILE     record spans for the whole cycle and\n\
                                  dump them as NDJSON (deterministic\n\
                                  timestamps under --inject-faults: the\n\
                                  spans ride the simulated clock)\n\
             --trace-chrome FILE  same spans as Chrome trace-event JSON\n\
                                  (chrome://tracing, Perfetto)\n\
             + the offload flags above (except --explain/--pjrt)\n\
           analyze <app|file.c>   loop table with intensity ranking\n\
           estimate <app|file.c>  pre-compile resource reports (top-A)\n\
           opencl <app|file.c> --loop N   emit OpenCL kernel + host text\n\
           ga <app|file.c>        GA baseline search ([32])\n\
           vmprofile [apps...]    per-opcode / adjacent-pair dispatch\n\
                                  profile of the MiniC VM over the\n\
                                  bundled workloads (default: all) —\n\
                                  the measurement behind the fused\n\
                                  superinstruction encoding (§PGO)\n\
             --pairs N            pair rows per report (default 12)\n\
             --baseline           profile the unfused pre-PGO encoding\n\
             --regs               profile the register-operand\n\
                                  encoding experiment\n\
             --disasm             print the bytecode disassembly first\n\
             --json               machine-readable report on stdout\n\
             --out FILE           write the JSON report to FILE\n\
             --entry FN           entry function (default main)\n\
           run-sample <tdfir|mriq>  PJRT sample test\n\
           apps                   list bundled applications\n\
           serve                  resident plan-serving daemon (newline-\n\
                                  delimited JSON over TCP): pattern-DB\n\
                                  hits answered from memory, misses\n\
                                  queued to a worker pool with typed\n\
                                  admission control\n\
             --addr A             listen address (default 127.0.0.1:7411;\n\
                                  port 0 for an OS-assigned port)\n\
             --port-file F        write the bound address to F (for\n\
                                  scripts using port 0)\n\
             --workers N          miss-solving worker threads (default 2)\n\
             --queue-cap N        admission queue slots (default 64);\n\
                                  overflow is rejected immediately with\n\
                                  a retry_after_ms hint\n\
             --pattern-db DIR     hit index + write-through store\n\
             --db-capacity N      cap the store at N live records;\n\
                                  over capacity the cheapest-to-\n\
                                  recompute (least solve time, most\n\
                                  stale) records are evicted\n\
             --max-age S          serve hits younger than S seconds;\n\
                                  older records are re-searched\n\
             --refresh-ahead F    fraction of --max-age (default 0.8)\n\
                                  past which a hit is served AND a\n\
                                  background re-search is enqueued\n\
             --backend B          destination for misses (default fpga)\n\
             --retries/--stage-deadline   worker retry policy (see batch)\n\
             --no-trace           turn end-to-end tracing off (it is on\n\
                                  by default; every span site becomes a\n\
                                  no-op)\n\
             --trace-capacity N   span ring size (default 4096); the\n\
                                  oldest spans are overwritten first\n\
             --trace-sample N     keep 1 trace in N (default 1 = all)\n\
           client [apps...]       drive a running daemon (default: all\n\
                                  bundled apps)\n\
             --addr A             daemon address\n\
             --deadline-ms N      per-request deadline\n\
             --json               print raw response lines\n\
             --stats              fetch the stats endpoint (aligned\n\
                                  table; --json for the raw snapshot)\n\
             --metrics            fetch the Prometheus text exposition\n\
             --shutdown           drain and stop the daemon\n\
           trace                  inspect the daemon's span ring\n\
             --addr A             daemon address\n\
             --last N             newest N traces (default 8)\n\
             --id N               one trace, rendered as a span tree\n\
             --slow-ms MS         only traces whose root took ≥ MS\n\
                                  (outlier capture)\n\
             --out FILE           dump matching spans as NDJSON\n\
             --chrome FILE        dump as Chrome trace-event JSON\n\
             --in FILE            read a prior --out dump instead of\n\
                                  connecting (same filters)\n\
             --json               print spans as NDJSON to stdout\n\
           patterndb <sub> --pattern-db DIR   offline DB tooling\n\
             stats                record counts, per-backend split, age\n\
                                  histogram, shard/eviction/compaction\n\
                                  counters; --addr adds live daemon\n\
                                  hit/miss counters\n\
             quarantined          list quarantined *.corrupt debris\n\
             migrate              one-shot migration of legacy flat\n\
                                  <app>.pattern.json files into the\n\
                                  sharded log store (idempotent)\n\
             compact              rewrite shard logs dropping dead\n\
                                  (superseded/tombstoned) records\n\
             export --out DIR     write live records back out as flat\n\
                                  legacy files (migration smokes,\n\
                                  bench baseline)\n\
         \n\
         <app> is one of the bundled apps (repro apps) or a path to a .c file."
    );
}

/// Resolve an app name or .c path to (name, source).
fn resolve_source(spec: &str) -> anyhow::Result<(String, String)> {
    if let Some(src) = workloads::source(spec) {
        return Ok((spec.to_string(), src.to_string()));
    }
    if spec.ends_with(".c") {
        let text = std::fs::read_to_string(spec)
            .map_err(|e| anyhow::anyhow!("reading {spec}: {e}"))?;
        let name = std::path::Path::new(spec)
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "custom".into());
        return Ok((name, text));
    }
    anyhow::bail!(
        "unknown app {spec:?} — use `repro apps` or pass a .c file path"
    )
}

fn parse_and_analyze(
    src: &str,
    engine: EngineKind,
) -> anyhow::Result<(Program, Analysis)> {
    let prog = parse(src).map_err(|e| anyhow::anyhow!("{e}"))?;
    typecheck::check_ok(&prog).map_err(|e| anyhow::anyhow!("{e}"))?;
    let an = analyze_with(&prog, "main", engine)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok((prog, an))
}

fn engine_from_flags(f: &Flags) -> anyhow::Result<EngineKind> {
    match f.value("--engine") {
        None => Ok(EngineKind::default()),
        Some(v) => EngineKind::parse(v).ok_or_else(|| {
            anyhow::anyhow!(
                "bad value for --engine: {v:?} \
                 (use interp|vm|vm-baseline|vm-regs)"
            )
        }),
    }
}

/// The bundled destination backends, selected by `--backend`.
enum BackendChoice {
    Fpga(FpgaBackend<'static>),
    Gpu(GpuBackend<'static>),
    Omp(OmpBackend<'static>),
    Cpu(CpuBaseline<'static>),
}

fn fpga_backend() -> FpgaBackend<'static> {
    FpgaBackend {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    }
}

fn gpu_backend() -> GpuBackend<'static> {
    GpuBackend {
        cpu: &XEON_BRONZE_3104,
        gpu: &TESLA_T4,
        device: &ARRIA10_GX,
    }
}

fn omp_backend() -> OmpBackend<'static> {
    OmpBackend {
        cpu: &XEON_BRONZE_3104,
        omp: &XEON_GOLD_6130,
        device: &ARRIA10_GX,
    }
}

fn cpu_backend() -> CpuBaseline<'static> {
    CpuBaseline {
        cpu: &XEON_BRONZE_3104,
        device: &ARRIA10_GX,
    }
}

impl BackendChoice {
    fn from_flags(f: &Flags) -> anyhow::Result<BackendChoice> {
        match f.value("--backend") {
            None | Some("fpga") => Ok(BackendChoice::Fpga(fpga_backend())),
            Some("gpu") => Ok(BackendChoice::Gpu(gpu_backend())),
            Some("omp") => Ok(BackendChoice::Omp(omp_backend())),
            Some("cpu") => Ok(BackendChoice::Cpu(cpu_backend())),
            Some(v) => Err(anyhow::anyhow!(
                "bad value for --backend: {v:?} (use fpga|gpu|omp|cpu)"
            )),
        }
    }

    fn as_dyn(&self) -> &dyn Backend {
        match self {
            BackendChoice::Fpga(b) => b,
            BackendChoice::Gpu(b) => b,
            BackendChoice::Omp(b) => b,
            BackendChoice::Cpu(b) => b,
        }
    }
}

/// Tiny flag parser: positional args + `--key value` + `--switch`.
struct Flags<'a> {
    args: &'a [String],
}

/// Value-taking flags, so positional scanning can skip their values.
const VALUE_FLAGS: &[&str] = &[
    "--engine",
    "--backend",
    "--entry",
    "--pairs",
    "--top-a",
    "--unroll",
    "--top-c",
    "--first-round",
    "--max-patterns",
    "--machines",
    "--pattern-db",
    "--seed",
    "--loop",
    "--out",
    "--retries",
    "--stage-deadline",
    "--inject-faults",
    "--addr",
    "--port-file",
    "--workers",
    "--queue-cap",
    "--max-age",
    "--refresh-ahead",
    "--deadline-ms",
    "--db-capacity",
    "--trace-capacity",
    "--trace-sample",
    "--trace-out",
    "--trace-chrome",
    "--id",
    "--slow-ms",
    "--last",
    "--chrome",
    "--in",
];

impl<'a> Flags<'a> {
    fn positional(&self, n: usize) -> Option<&'a str> {
        self.positionals().get(n).copied()
    }

    /// All positional args, skipping `--flag value` pairs.
    fn positionals(&self) -> Vec<&'a str> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.args.len() {
            let a = self.args[i].as_str();
            if a.starts_with("--") {
                if VALUE_FLAGS.contains(&a) {
                    i += 1; // skip the flag's value too
                }
            } else {
                out.push(a);
            }
            i += 1;
        }
        out
    }

    fn has(&self, name: &str) -> bool {
        self.args.iter().any(|a| a == name)
    }

    fn value(&self, name: &str) -> Option<&'a str> {
        let idx = self.args.iter().position(|a| a == name)?;
        self.args.get(idx + 1).map(String::as_str)
    }

    fn num<T: std::str::FromStr>(&self, name: &str, default: T) -> anyhow::Result<T> {
        match self.value(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad value for {name}: {v:?}")),
        }
    }
}

fn config_from_flags(f: &Flags) -> anyhow::Result<SearchConfig> {
    let d = SearchConfig::default();
    let top_c = f.num("--top-c", d.top_c)?;
    let cfg = SearchConfig {
        top_a: f.num("--top-a", d.top_a)?,
        unroll: f.num("--unroll", d.unroll)?,
        top_c,
        first_round: f.num("--first-round", d.first_round.min(top_c))?,
        max_patterns: f.num("--max-patterns", d.max_patterns)?,
        build_machines: f.num("--machines", d.build_machines)?,
        verify_numerics: !f.has("--no-verify"),
        engine: engine_from_flags(f)?,
        ..d
    };
    cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(cfg)
}

/// A pipeline request for an app spec: entry/sample from the test-case
/// DB when the app is registered there, with `--entry` overriding both
/// the DB's entry and the `main` default.
fn request_for(
    testdb: &TestDb,
    app: &str,
    src: &str,
    seed: u64,
    pjrt: bool,
    entry_override: Option<&str>,
    func_blocks: bool,
) -> OffloadRequest {
    let mut req = match testdb.get(app) {
        Some(case) => OffloadRequest::from_case(case, src),
        None => OffloadRequest {
            app: app.to_string(),
            source: src.to_string(),
            entry: "main".into(),
            pjrt_sample: None,
            seed,
            func_blocks: false,
        },
    };
    req.seed = seed;
    req.func_blocks = func_blocks;
    if let Some(entry) = entry_override {
        req.entry = entry.to_string();
    }
    if !pjrt {
        req.pjrt_sample = None;
    }
    req
}

fn cmd_offload(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let spec = f
        .positional(0)
        .ok_or_else(|| anyhow::anyhow!("usage: repro offload <app|file.c>"))?;
    let (app, src) = resolve_source(spec)?;
    let cfg = config_from_flags(&f)?;
    let choice = BackendChoice::from_flags(&f)?;

    let seed = f.num("--seed", 42u64)?;
    let testdb = TestDb::builtin();
    let req = request_for(
        &testdb,
        &app,
        &src,
        seed,
        f.has("--pjrt"),
        f.value("--entry"),
        f.has("--func-blocks"),
    );

    let (rt, art);
    let runtime_pair = if f.has("--pjrt") {
        let cwd = std::env::current_dir()?;
        art = Artifacts::discover(&cwd)?;
        rt = Runtime::cpu()?;
        Some((&rt, &art))
    } else {
        None
    };

    let mut pipeline = Pipeline::new(cfg, choice.as_dyn())
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(dir) = f.value("--pattern-db") {
        pipeline = pipeline
            .with_pattern_db(dir)
            .with_cache_reuse(f.has("--reuse"));
    }

    let deployed = pipeline
        .run(req, runtime_pair)
        .map_err(|e| anyhow::anyhow!("{e}"))?;

    if let Some(sol) = deployed.plan.solution() {
        if !sol.blocks.is_empty() {
            println!("== function blocks ==");
            for b in &sol.blocks {
                println!(
                    "{}: {} ({}) — loops {}, {:.2}x over the naive nest \
                     (cpu {:.3} ms → core {:.3} ms), sample-test confirmed",
                    b.func,
                    b.kind,
                    b.ip_name,
                    b.loops
                        .iter()
                        .map(|l| format!("L{}", l.0))
                        .collect::<Vec<_>>()
                        .join("+"),
                    b.speedup(),
                    b.cpu_s * 1e3,
                    b.accel_s * 1e3,
                );
            }
        }
        if f.has("--explain") {
            println!("== funnel (Fig. 2) ==");
            println!(
                "loops {} → offloadable {} → top-A {} → top-C {}",
                sol.funnel.total_loops,
                sol.funnel.offloadable.len(),
                sol.funnel.top_a.len(),
                sol.funnel.top_c.len()
            );
            for r in &sol.funnel.reports {
                println!("{}", render(r));
            }
        }
        println!("== measurements ==");
        for m in &sol.measurements {
            println!(
                "round {} pattern {:<12} speedup {:>6.2}x  compile {:>4.1} h  verified {}",
                m.round,
                m.label(),
                m.speedup(),
                m.compile_s / 3600.0,
                m.verified.map(|v| v.to_string()).unwrap_or("-".into()),
            );
        }
    } else {
        println!("== pattern reused from DB (source unchanged) ==");
    }
    println!("== solution ==");
    println!(
        "{}: best pattern {} — {:.2}x vs all-CPU (backend {}, automation {:.1} h)",
        deployed.app,
        deployed.plan.label(),
        deployed.plan.speedup(),
        deployed.backend,
        deployed.plan.automation_s() / 3600.0
    );
    if let Some(path) = &deployed.stored_at {
        println!("pattern stored at {}", path.display());
    }
    if let Some(sr) = &deployed.sample_run {
        println!(
            "PJRT sample test [{}]: exec {:?}, max|err| {:.2e} over {} outputs — OK",
            sr.app, sr.exec_time, sr.max_abs_err, sr.checked
        );
    }
    Ok(())
}

/// A pipeline with the batch's retry policy and shared simulated clock
/// applied (when any resilience flag selected a policy).
fn pipeline_with_resilience<'a>(
    cfg: SearchConfig,
    backend: &'a dyn Backend,
    policy: &Option<RetryPolicy>,
    clock: &SimClock,
) -> anyhow::Result<Pipeline<'a>> {
    let mut p = Pipeline::new(cfg, backend)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(pol) = policy {
        p = p
            .with_retry(pol.clone())
            .map_err(|e| anyhow::anyhow!("{e}"))?
            .with_clock(clock.clone());
    }
    Ok(p)
}

fn cmd_batch(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let cfg = config_from_flags(&f)?;
    let mixed = f.has("--mixed");
    let seed = f.num("--seed", 42u64)?;

    // Resilience knobs. Any of them implies a retry policy; the
    // simulated clock is shared across every destination pipeline so
    // backoff and injected hangs advance one coherent timeline.
    let fault_seed: Option<u64> = match f.value("--inject-faults") {
        None => None,
        Some(v) => Some(v.parse().map_err(|_| {
            anyhow::anyhow!("bad value for --inject-faults: {v:?}")
        })?),
    };
    let stage_deadline: Option<f64> = match f.value("--stage-deadline") {
        None => None,
        Some(v) => Some(v.parse().map_err(|_| {
            anyhow::anyhow!("bad value for --stage-deadline: {v:?}")
        })?),
    };
    let policy: Option<RetryPolicy> = if f.value("--retries").is_some()
        || stage_deadline.is_some()
        || fault_seed.is_some()
    {
        Some(RetryPolicy {
            max_attempts: f.num("--retries", 3u32)?,
            stage_deadline_s: stage_deadline,
            seed,
            ..RetryPolicy::default()
        })
    } else {
        None
    };
    let clock = SimClock::new();

    let specs: Vec<String> = {
        let given = f.positionals();
        if given.is_empty() {
            workloads::APPS.iter().map(|s| s.to_string()).collect()
        } else {
            given.iter().map(|s| s.to_string()).collect()
        }
    };
    let testdb = TestDb::builtin();

    // Backends and pipelines live here so both branches can borrow them.
    let fpga = fpga_backend();
    let gpu = gpu_backend();
    let omp = omp_backend();
    let cpu = cpu_backend();
    let choice;
    let faulty: Vec<FaultyBackend>;
    let (pipelines, label): (Vec<Pipeline>, String) = if mixed {
        if f.value("--pattern-db").is_some() || f.has("--reuse") {
            anyhow::bail!(
                "--mixed re-measures every destination and does not \
                 combine with --pattern-db/--reuse"
            );
        }
        if f.value("--backend").is_some() {
            anyhow::bail!(
                "--mixed always measures fpga+gpu+omp+cpu; drop --backend \
                 (or drop --mixed for a single-destination batch)"
            );
        }
        // One pipeline per destination; registration order breaks ties
        // (prefer the paper's FPGA, then the GPU, then the many-core,
        // then the control).
        let inner: [&dyn Backend; 4] = [&fpga, &gpu, &omp, &cpu];
        let pipes = if let Some(fseed) = fault_seed {
            faulty = inner
                .iter()
                .map(|&b| {
                    FaultyBackend::new(
                        b,
                        FaultPlan::from_seed(fseed),
                        clock.clone(),
                    )
                })
                .collect();
            faulty
                .iter()
                .map(|b| {
                    pipeline_with_resilience(
                        cfg.clone(),
                        b,
                        &policy,
                        &clock,
                    )
                })
                .collect::<anyhow::Result<Vec<_>>>()?
        } else {
            inner
                .iter()
                .map(|&b| {
                    pipeline_with_resilience(
                        cfg.clone(),
                        b,
                        &policy,
                        &clock,
                    )
                })
                .collect::<anyhow::Result<Vec<_>>>()?
        };
        (pipes, "mixed fpga+gpu+omp+cpu".to_string())
    } else {
        choice = BackendChoice::from_flags(&f)?;
        let backend: &dyn Backend = if let Some(fseed) = fault_seed {
            faulty = vec![FaultyBackend::new(
                choice.as_dyn(),
                FaultPlan::from_seed(fseed),
                clock.clone(),
            )];
            &faulty[0]
        } else {
            choice.as_dyn()
        };
        let mut pipeline =
            pipeline_with_resilience(cfg, backend, &policy, &clock)?;
        if let Some(dir) = f.value("--pattern-db") {
            pipeline = pipeline
                .with_pattern_db(dir)
                .with_cache_reuse(f.has("--reuse"));
        }
        let label = pipeline.backend().name().to_string();
        (vec![pipeline], label)
    };

    // Span recording for the cycle. Under a resilience policy the spans
    // ride the shared simulated clock (deterministic timestamps for a
    // given --inject-faults seed); otherwise they stamp wall time.
    let trace_out = f.value("--trace-out");
    let trace_chrome = f.value("--trace-chrome");
    let tracer = if trace_out.is_some() || trace_chrome.is_some() {
        if policy.is_some() {
            Tracer::with_sim_clock(&TraceConfig::default(), clock.clone())
        } else {
            Tracer::new(&TraceConfig::default())
        }
    } else {
        Tracer::disabled()
    };

    let mut batch = Batch::mixed(pipelines.iter().collect())
        .with_tracer(tracer.clone());
    for spec in &specs {
        let (app, src) = resolve_source(spec)?;
        batch.push(request_for(
            &testdb,
            &app,
            &src,
            seed,
            false,
            f.value("--entry"),
            f.has("--func-blocks"),
        ));
    }

    println!(
        "batch: {} applications through one automation cycle (backend {label})",
        batch.len(),
    );
    let report = batch.run();

    for e in &report.entries {
        match (&e.plan, &e.error) {
            (Some(plan), _) => {
                let alternatives = if report.is_mixed() {
                    let others: Vec<String> = e
                        .outcomes
                        .iter()
                        .filter(|o| Some(o.backend) != e.destination)
                        .map(|o| match &o.plan {
                            Some(p) => {
                                format!("{} {:.2}x", o.backend, p.speedup())
                            }
                            None => format!("{} failed", o.backend),
                        })
                        .collect();
                    format!("  ({})", others.join(", "))
                } else {
                    String::new()
                };
                let blocks = match plan.block_count() {
                    0 => String::new(),
                    n => format!("  ({n} block{})", if n == 1 { "" } else { "s" }),
                };
                println!(
                    "  {:<10} → {:<5} best {:<12} {:>6.2}x  automation {:>5.1} h{}{}{}",
                    e.app,
                    e.destination.unwrap_or("?"),
                    plan.label(),
                    plan.speedup(),
                    plan.automation_s() / 3600.0,
                    if plan.is_cached() { "  (cached)" } else { "" },
                    blocks,
                    alternatives,
                );
            }
            (None, Some(err)) => println!("  {:<10} FAILED: {err}", e.app),
            (None, None) => println!("  {:<10} FAILED", e.app),
        }
        if let Some(why) = &e.degradation {
            println!("  {:<10}   [{}] {}", "", e.service, why);
        }
    }
    if report.is_mixed() {
        let split: Vec<String> = report
            .destination_counts()
            .iter()
            .map(|(b, n)| format!("{b} {n}"))
            .collect();
        println!("destination split: {}", split.join(" / "));
    }
    println!(
        "cycle: {}/{} solved ({} served, {} degraded), {} cache hits — \
         automation {:.1} h serial / {:.1} h concurrent",
        report.solved(),
        report.entries.len(),
        report.served(),
        report.degraded(),
        report.cache_hits(),
        report.serial_automation_s / 3600.0,
        report.concurrent_automation_s / 3600.0
    );
    let t = &report.fault_telemetry;
    if policy.is_some() {
        let timeouts =
            t.measure.timeouts + t.verify.timeouts + t.deploy.timeouts;
        println!(
            "faults: {} retries, {} exhausted budgets, {} timeouts, \
             {} panics (measure/verify/deploy)",
            t.total_retries(),
            t.total_exhausted(),
            timeouts,
            t.total_panics(),
        );
    }

    if tracer.enabled() {
        let mut rows: Vec<SpanRow> =
            tracer.spans().iter().map(SpanRow::from).collect();
        sort_spans(&mut rows);
        if let Some(path) = trace_out {
            std::fs::write(path, to_ndjson(&rows))
                .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
            println!("{} span(s) written to {path}", rows.len());
        }
        if let Some(path) = trace_chrome {
            std::fs::write(path, to_chrome(&rows).pretty())
                .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
            println!(
                "chrome trace ({} spans) written to {path}",
                rows.len()
            );
        }
    }

    let out = f.value("--out").unwrap_or("batch_report.json");
    report.write_json(std::path::Path::new(out))?;
    println!("batch report written to {out}");
    Ok(())
}

fn cmd_analyze(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let spec = f
        .positional(0)
        .ok_or_else(|| anyhow::anyhow!("usage: repro analyze <app|file.c>"))?;
    let (app, src) = resolve_source(spec)?;
    let (_prog, an) = parse_and_analyze(&src, engine_from_flags(&f)?)?;

    println!("{app}: {} loop statements", an.loops.len());
    println!(
        "{:<5} {:<14} {:>5} {:>10} {:>12} {:>10} {:>12}  {}",
        "loop", "function", "line", "trips", "work(flops)", "ops/acc",
        "score", "status"
    );
    let mut rows: Vec<_> = an.loops.iter().collect();
    rows.sort_by(|a, b| {
        let sa = a.intensity.as_ref().map(|i| i.score).unwrap_or(-1.0);
        let sb = b.intensity.as_ref().map(|i| i.score).unwrap_or(-1.0);
        sb.partial_cmp(&sa).unwrap()
    });
    for al in rows {
        let (trips, work, inten, score) = match &al.intensity {
            Some(i) => (
                i.trips.to_string(),
                i.work.to_string(),
                format!("{:.2}", i.intensity),
                format!("{:.3e}", i.score),
            ),
            None => ("-".into(), "-".into(), "-".into(), "-".into()),
        };
        let status = match &al.info.blocker {
            Some(b) => format!("blocked: {b}"),
            None => format!("{:?}", al.dependence),
        };
        println!(
            "{:<5} {:<14} {:>5} {:>10} {:>12} {:>10} {:>12}  {}",
            al.id().to_string(),
            al.info.function,
            al.info.line,
            trips,
            work,
            inten,
            score,
            status
        );
    }
    Ok(())
}

fn cmd_estimate(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let spec = f
        .positional(0)
        .ok_or_else(|| anyhow::anyhow!("usage: repro estimate <app|file.c>"))?;
    let (_app, src) = resolve_source(spec)?;
    let (prog, an) = parse_and_analyze(&src, engine_from_flags(&f)?)?;
    let cfg = config_from_flags(&f)?;
    let (cands, trace) =
        crate::search::funnel::run(&prog, &an, &cfg, &ARRIA10_GX)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!(
        "funnel: {} loops → {} offloadable → top-A {:?} → top-C {:?}",
        trace.total_loops,
        trace.offloadable.len(),
        trace.top_a,
        trace.top_c
    );
    for r in &trace.reports {
        println!("{}", render(r));
    }
    let _ = cands;
    Ok(())
}

fn cmd_opencl(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let spec = f
        .positional(0)
        .ok_or_else(|| anyhow::anyhow!("usage: repro opencl <app|file.c> --loop N"))?;
    let (_app, src) = resolve_source(spec)?;
    let (prog, an) = parse_and_analyze(&src, engine_from_flags(&f)?)?;
    let loop_n: u32 = f.num("--loop", 0)?;
    let unroll_b: u32 = f.num("--unroll", 1)?;
    let al = an
        .loop_by_id(crate::minic::ast::LoopId(loop_n))
        .ok_or_else(|| anyhow::anyhow!("no loop L{loop_n}"))?;
    let sp = crate::codegen::split(&prog, al)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let k = crate::codegen::unroll(&sp.kernel, unroll_b)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    println!("{}", crate::codegen::opencl::kernel_text(&k));
    println!("{}", crate::codegen::opencl::host_text(&k));
    Ok(())
}

fn cmd_ga(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let spec = f
        .positional(0)
        .ok_or_else(|| anyhow::anyhow!("usage: repro ga <app|file.c>"))?;
    let (app, src) = resolve_source(spec)?;
    let (prog, an) = parse_and_analyze(&src, engine_from_flags(&f)?)?;
    let cfg = GaConfig {
        seed: f.num("--seed", GaConfig::default().seed)?,
        ..Default::default()
    };
    let res =
        crate::search::ga::run(&prog, &an, &cfg, &XEON_BRONZE_3104, &ARRIA10_GX);
    println!(
        "{app}: GA best {:?} — {:.2}x after {} measured patterns \
         (modeled compile wall-clock {:.1} h)",
        res.best_loops,
        res.best_speedup,
        res.measurements,
        res.modeled_wall_clock_s / 3600.0
    );
    println!("convergence: {:?}", res.history);
    Ok(())
}

/// `repro vmprofile` — the §PGO measurement tool: run each workload on
/// an instruction-profiled VM and report per-opcode dispatch ranking
/// plus the hottest adjacent pairs (annotated with the superinstruction
/// that fuses them, when one exists). Always profiles the unfused
/// baseline too, so the dispatch/cycle reduction of the current
/// encoding is printed alongside.
fn cmd_vmprofile(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let pairs: usize = f.num("--pairs", 12usize)?;
    let (opts, label) = if f.has("--baseline") {
        (ResolveOpts::baseline(), "baseline")
    } else if f.has("--regs") {
        (ResolveOpts::regs(), "regs")
    } else {
        (ResolveOpts::default(), "fused")
    };
    let entry = f.value("--entry").unwrap_or("main");
    let specs: Vec<String> = {
        let p = f.positionals();
        if p.is_empty() {
            workloads::APPS.iter().map(|s| s.to_string()).collect()
        } else {
            p.iter().map(|s| s.to_string()).collect()
        }
    };

    use crate::util::json::Json;
    let want_json = f.has("--json") || f.value("--out").is_some();
    let mut doc = std::collections::BTreeMap::new();

    for spec in &specs {
        let (app, src) = resolve_source(spec)?;
        let prog = parse(&src).map_err(|e| anyhow::anyhow!("{e}"))?;
        typecheck::check_ok(&prog).map_err(|e| anyhow::anyhow!("{e}"))?;

        if f.has("--disasm") {
            let module =
                crate::minic::resolve::compile_with(&prog, &opts)
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
            println!("== {app}: {label} encoding disassembly ==");
            println!("{}", module.disassemble());
        }

        let (_, report) =
            crate::analysis::opcode_profile(&prog, entry, &opts, pairs)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
        let (_, base) = crate::analysis::opcode_profile(
            &prog,
            entry,
            &ResolveOpts::baseline(),
            pairs,
        )
        .map_err(|e| anyhow::anyhow!("{e}"))?;
        let dispatch_x = base.dispatches as f64 / report.dispatches as f64;
        let cycles_x = base.est_cycles as f64 / report.est_cycles as f64;

        if want_json {
            doc.insert(
                app.clone(),
                Json::obj(vec![
                    ("encoding", Json::Str(label.into())),
                    ("entry", Json::Str(entry.into())),
                    ("report", report.to_json()),
                    ("baseline", base.to_json()),
                    ("dispatch_reduction", Json::Num(dispatch_x)),
                    ("est_cycle_reduction", Json::Num(cycles_x)),
                ]),
            );
        }
        if !f.has("--json") {
            println!("== {app} ({label} encoding, entry {entry}) ==");
            print!("{}", report.render());
            if label != "baseline" {
                println!(
                    "  vs baseline: dispatches {} -> {} ({dispatch_x:.2}x), \
                     est cycles {} -> {} ({cycles_x:.2}x)",
                    base.dispatches,
                    report.dispatches,
                    base.est_cycles,
                    report.est_cycles
                );
                println!("  baseline pairs (fusion candidates):");
                for p in &base.pairs {
                    println!(
                        "    {} -> {}  x{}{}",
                        p.prev.name(),
                        p.next.name(),
                        p.count,
                        p.fused_as
                            .map(|n| format!("   [fused as {n}]"))
                            .unwrap_or_default()
                    );
                }
            }
            println!();
        }
    }

    if want_json {
        let doc = Json::Obj(doc);
        if f.has("--json") {
            println!("{}", doc.pretty());
        }
        if let Some(out) = f.value("--out") {
            std::fs::write(out, doc.pretty() + "\n")?;
            println!("vmprofile report written to {out}");
        }
    }
    Ok(())
}

fn cmd_run_sample(args: &[String]) -> anyhow::Result<()> {
    let f = Flags { args };
    let app = f
        .positional(0)
        .ok_or_else(|| anyhow::anyhow!("usage: repro run-sample <tdfir|mriq>"))?;
    let cwd = std::env::current_dir()?;
    let art = Artifacts::discover(&cwd)?;
    let rt = Runtime::cpu()?;
    let run = crate::runtime::run_app(&rt, &art, app, 42)?;
    println!(
        "{}: exec {:?}, max|err| {:.3e} over {} outputs — OK",
        run.app, run.exec_time, run.max_abs_err, run.checked
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn unknown_subcommand_fails() {
        assert_eq!(run(&s(&["bogus"])), 2);
    }

    #[test]
    fn help_succeeds() {
        assert_eq!(run(&s(&["--help"])), 0);
        assert_eq!(run(&[]), 0);
    }

    #[test]
    fn apps_lists_bundled() {
        assert_eq!(run(&s(&["apps"])), 0);
    }

    #[test]
    fn analyze_bundled_app() {
        assert_eq!(run(&s(&["analyze", "sobel"])), 0);
    }

    #[test]
    fn analyze_unknown_app_fails() {
        assert_eq!(run(&s(&["analyze", "ghost"])), 1);
    }

    #[test]
    fn flags_parse() {
        let args = s(&["sobel", "--top-a", "3", "--explain"]);
        let f = Flags { args: &args };
        assert_eq!(f.positional(0), Some("sobel"));
        assert!(f.has("--explain"));
        assert_eq!(f.num("--top-a", 5usize).unwrap(), 3);
        assert_eq!(f.num("--top-c", 7usize).unwrap(), 7);
    }

    #[test]
    fn positionals_skip_flag_values() {
        let args = s(&["sobel", "--top-a", "3", "mriq", "--explain", "tdfir"]);
        let f = Flags { args: &args };
        assert_eq!(f.positionals(), vec!["sobel", "mriq", "tdfir"]);
    }

    #[test]
    fn opencl_emission_for_sobel() {
        assert_eq!(run(&s(&["opencl", "sobel", "--loop", "4"])), 0);
    }

    #[test]
    fn offload_sobel_on_cpu_backend() {
        assert_eq!(
            run(&s(&["offload", "sobel", "--backend", "cpu"])),
            0
        );
    }

    #[test]
    fn offload_sobel_on_gpu_backend() {
        assert_eq!(
            run(&s(&["offload", "sobel", "--backend", "gpu"])),
            0
        );
    }

    #[test]
    fn offload_sobel_on_omp_backend() {
        assert_eq!(
            run(&s(&["offload", "sobel", "--backend", "omp"])),
            0
        );
    }

    #[test]
    fn offload_sobel_with_func_blocks() {
        assert_eq!(run(&s(&["offload", "sobel", "--func-blocks"])), 0);
    }

    #[test]
    fn batch_func_blocks_reports_block_counts() {
        let dir = TempDir::new("fpga-offload-cli-funcblock").unwrap();
        let out = dir.join("fb.json");
        let out_s = out.to_string_lossy().into_owned();
        assert_eq!(
            run(&s(&["batch", "sobel", "--func-blocks", "--out", &out_s])),
            0
        );
        let text = std::fs::read_to_string(&out).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.get(&["solved"]).unwrap().as_f64(), Some(1.0));
        let results = j.get(&["results"]).unwrap().as_arr().unwrap();
        // The sobel gradient stencil is replaced on the FPGA backend.
        assert_eq!(
            results[0].get(&["blocks"]).unwrap().as_f64(),
            Some(1.0)
        );
    }

    #[test]
    fn mixed_batch_rejects_pattern_db() {
        assert_eq!(
            run(&s(&["batch", "sobel", "--mixed", "--pattern-db", "/tmp/x"])),
            1
        );
    }

    #[test]
    fn mixed_batch_rejects_backend_flag() {
        assert_eq!(
            run(&s(&["batch", "sobel", "--mixed", "--backend", "cpu"])),
            1
        );
    }

    #[test]
    fn mixed_batch_writes_destination_report() {
        let dir = TempDir::new("fpga-offload-cli-mixed").unwrap();
        let out = dir.join("mixed.json");
        let out_s = out.to_string_lossy().into_owned();
        assert_eq!(
            run(&s(&["batch", "sobel", "mriq", "--mixed", "--out", &out_s])),
            0
        );
        let text = std::fs::read_to_string(&out).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.get(&["mixed"]).unwrap().as_bool(), Some(true));
        assert_eq!(j.get(&["apps"]).unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get(&["solved"]).unwrap().as_f64(), Some(2.0));
        // Four destinations measured: fpga + gpu + omp + cpu.
        let backends = j.get(&["backends"]).unwrap().as_arr().unwrap();
        let names: Vec<_> =
            backends.iter().filter_map(|b| b.as_str()).collect();
        assert_eq!(names, vec!["fpga", "gpu", "omp", "cpu"]);
        assert!(j.get(&["destinations", "omp"]).unwrap().as_f64().is_some());
        let results = j.get(&["results"]).unwrap().as_arr().unwrap();
        for r in results {
            assert!(r.get(&["destination"]).unwrap().as_str().is_some());
            assert!(r.get(&["backends", "omp"]).unwrap().as_f64().is_some());
        }
    }

    #[test]
    fn offload_rejects_bad_backend() {
        assert_eq!(
            run(&s(&["offload", "sobel", "--backend", "tpu"])),
            1
        );
    }

    #[test]
    fn batch_trace_dump_is_deterministic_under_faults() {
        let dir = TempDir::new("cli-batch-trace").unwrap();
        let mut dumps = Vec::new();
        for name in ["t1.ndjson", "t2.ndjson"] {
            let t = dir.join(name).to_string_lossy().into_owned();
            let r = dir
                .join(format!("{name}.report.json"))
                .to_string_lossy()
                .into_owned();
            assert_eq!(
                run(&s(&[
                    "batch",
                    "sobel",
                    "--inject-faults",
                    "7",
                    "--trace-out",
                    &t,
                    "--out",
                    &r,
                ])),
                0
            );
            dumps.push(std::fs::read_to_string(dir.join(name)).unwrap());
        }
        // Same seed, same simulated clock → byte-identical span dumps.
        assert_eq!(dumps[0], dumps[1]);
        assert!(dumps[0].contains("request"), "{}", dumps[0]);
        assert!(dumps[0].contains("destination"), "{}", dumps[0]);
        assert!(dumps[0].contains("stage.measure"), "{}", dumps[0]);
    }

    #[test]
    fn vmprofile_runs_on_a_bundled_app() {
        assert_eq!(run(&s(&["vmprofile", "tdfir", "--pairs", "6"])), 0);
    }

    #[test]
    fn vmprofile_baseline_regs_and_disasm_run() {
        assert_eq!(run(&s(&["vmprofile", "sobel", "--baseline"])), 0);
        assert_eq!(
            run(&s(&["vmprofile", "sobel", "--regs", "--disasm", "--json"])),
            0
        );
    }

    #[test]
    fn vmprofile_writes_json_report() {
        let dir = TempDir::new("fpga-offload-cli-vmprofile").unwrap();
        let out = dir.join("vmprof.json");
        let out_s = out.to_string_lossy().into_owned();
        assert_eq!(
            run(&s(&["vmprofile", "mriq", "--out", &out_s])),
            0
        );
        let text = std::fs::read_to_string(&out).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert!(j.get(&["mriq", "report", "dispatches"]).is_some());
        assert!(j.get(&["mriq", "baseline", "pairs"]).is_some());
        // Fused encoding must dispatch strictly fewer instructions.
        let x = j
            .get(&["mriq", "dispatch_reduction"])
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(x > 1.0, "dispatch reduction {x}");
    }

    #[test]
    fn bad_engine_value_mentions_new_kinds() {
        assert_eq!(run(&s(&["analyze", "sobel", "--engine", "jit"])), 1);
        assert_eq!(
            run(&s(&["analyze", "sobel", "--engine", "vm-baseline"])),
            0
        );
    }

    #[test]
    fn batch_runs_bundled_apps_and_writes_report() {
        let dir = TempDir::new("fpga-offload-cli-batch").unwrap();
        let out = dir.join("report.json");
        let out_s = out.to_string_lossy().into_owned();
        assert_eq!(
            run(&s(&["batch", "sobel", "mriq", "--out", &out_s])),
            0
        );
        let text = std::fs::read_to_string(&out).unwrap();
        let j = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(j.get(&["apps"]).unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get(&["solved"]).unwrap().as_f64(), Some(2.0));
    }
}
