//! The staged offload pipeline: the paper's Fig.-1 flow as a typed API.
//!
//! [`super::flow::run_flow`] ran all six steps behind one opaque call.
//! This module exposes each step as a stage that consumes the previous
//! stage's artifact, so callers can stop anywhere, inspect everything,
//! and batch many applications through one automation cycle:
//!
//! | stage method         | artifact      | paper Fig. 1 step            |
//! |----------------------|---------------|------------------------------|
//! | [`Pipeline::parse`]  | [`Parsed`]    | 1 (code analysis, front)     |
//! | [`Pipeline::analyze`]| [`Analyzed`]  | 1 (profiling, back)          |
//! | [`Pipeline::detect_blocks`] | [`FuncBlocked`] | function-block path (arXiv:2004.09883; no-op unless requested) |
//! | [`Pipeline::extract`] / [`Pipeline::extract_blocked`] | [`Candidates`] | 2–3 (extraction + conversion) |
//! | [`Pipeline::measure`]| [`Measured`]  | 4 (verification measurement) |
//! | [`Pipeline::select`] | [`Planned`]   | 5 (solution + DB store)      |
//! | [`Pipeline::deploy`] | [`Deployed`]  | 6 (production deploy check)  |
//!
//! Steps 4 and 6 route through a [`Backend`]
//! ([`crate::search::FpgaBackend`] is the paper's destination,
//! [`crate::search::GpuBackend`] the mixed-environment board,
//! [`crate::search::OmpBackend`] the many-core OpenMP machine, and
//! [`crate::search::CpuBaseline`] the control), so the same staged flow
//! serves a mixed-destination environment.
//!
//! The artifact types make stage order a *compile-time* property — you
//! cannot measure what was never analyzed:
//!
//! ```compile_fail,E0308
//! use fpga_offload::cpu::XEON_BRONZE_3104;
//! use fpga_offload::envadapt::{OffloadRequest, Pipeline};
//! use fpga_offload::hls::ARRIA10_GX;
//! use fpga_offload::search::{FpgaBackend, SearchConfig};
//!
//! let backend = FpgaBackend { cpu: &XEON_BRONZE_3104, device: &ARRIA10_GX };
//! let pipe = Pipeline::new(SearchConfig::default(), &backend).unwrap();
//! let req = OffloadRequest::builder("app")
//!     .source("int main() { return 0; }")
//!     .build()
//!     .unwrap();
//! let parsed = pipe.parse(req).unwrap();
//! let analyzed = pipe.analyze(parsed).unwrap();
//! // `measure` wants `Candidates`, not `Analyzed`: does not compile.
//! let _ = pipe.measure(analyzed);
//! ```

use std::path::PathBuf;
use std::time::Duration;

use crate::analysis::{analyze_with, Analysis};
use crate::funcblock::{self, BlockReplacement, Catalog};
use crate::minic::ast::LoopId;
use crate::minic::{parse as parse_minic, typecheck, Program};
use crate::obs;
use crate::runtime::{Artifacts, Runtime, SampleRun};
use crate::search::backend::{Backend, TracedBackend};
use crate::search::resilience::{
    FaultClass, FaultReport, FaultStats, OffloadError, RetryPolicy,
    RetryingBackend, SimClock, Stage,
};
use crate::search::{
    funnel, measure, Candidate, FunnelTrace, MeasuredSet, OffloadSolution,
    PatternMeasurement, SearchConfig, SearchError,
};

use super::patterndb::{unix_now, PatternDb, ReuseKey, StoredPattern};
use super::testdb::TestCase;

/// FNV-1a fingerprint of an application's source text. Stored with each
/// pattern-DB record so [`Pipeline::solve`] can prove the source is
/// unchanged before reusing a stored solution.
pub fn source_fingerprint(source: &str) -> u64 {
    use std::hash::Hasher;
    let mut h = crate::util::fnv::FnvHasher::default();
    h.write(source.as_bytes());
    h.finish()
}

/// Pipeline failure, tagged by the stage that produced it.
#[derive(Debug)]
pub enum PipelineError {
    /// The request builder was given missing or invalid fields.
    InvalidRequest(String),
    /// The search configuration violates a funnel invariant.
    InvalidConfig(String),
    /// Parse or semantic failure in the application source.
    Parse(String),
    /// Profiling analysis failure.
    Analysis(String),
    /// Funnel, measurement or selection failure.
    Search(SearchError),
    /// Code-pattern DB I/O failure.
    Db(String),
    /// Step-6 deployment-check failure.
    Deploy(String),
}

impl std::fmt::Display for PipelineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PipelineError::InvalidRequest(m) => {
                write!(f, "invalid offload request: {m}")
            }
            PipelineError::InvalidConfig(m) => {
                write!(f, "invalid search config: {m}")
            }
            PipelineError::Parse(m) => write!(f, "{m}"),
            PipelineError::Analysis(m) => write!(f, "analysis: {m}"),
            PipelineError::Search(e) => write!(f, "{e}"),
            PipelineError::Db(m) => write!(f, "pattern db: {m}"),
            PipelineError::Deploy(m) => write!(f, "deploy check: {m}"),
        }
    }
}

impl std::error::Error for PipelineError {}

impl From<SearchError> for PipelineError {
    fn from(e: SearchError) -> Self {
        PipelineError::Search(e)
    }
}

impl PipelineError {
    /// Map this error onto the resilience taxonomy
    /// ([`crate::search::resilience`]) so the batch orchestrator can
    /// report every per-destination failure as a typed, stage-tagged
    /// fault. Search faults pass through verbatim; the intrinsic
    /// pipeline errors are permanent except DB I/O (a busy filesystem
    /// is worth another look) and deploy errors that follow the
    /// transient message convention.
    pub fn to_offload_error(&self) -> OffloadError {
        match self {
            PipelineError::InvalidRequest(m)
            | PipelineError::InvalidConfig(m)
            | PipelineError::Parse(m) => OffloadError::new(
                Stage::Parse,
                FaultClass::Permanent,
                m.clone(),
            ),
            PipelineError::Analysis(m) => OffloadError::new(
                Stage::Analysis,
                FaultClass::Permanent,
                m.clone(),
            ),
            PipelineError::Search(SearchError::Fault(e)) => e.clone(),
            PipelineError::Search(other) => {
                let (stage, class) = other.classify();
                OffloadError::new(stage, class, format!("{other}"))
            }
            PipelineError::Db(m) => OffloadError::new(
                Stage::Db,
                FaultClass::Transient,
                m.clone(),
            ),
            PipelineError::Deploy(m) => {
                let class = if m.contains("transient") {
                    FaultClass::Transient
                } else {
                    FaultClass::Permanent
                };
                OffloadError::new(Stage::Deploy, class, m.clone())
            }
        }
    }
}

/// One application's offload request: what to offload and how to test it.
#[derive(Debug, Clone)]
pub struct OffloadRequest {
    pub app: String,
    /// MiniC (C-subset) source text.
    pub source: String,
    /// Entry function for profiling and verification runs.
    pub entry: String,
    /// PJRT sample-test id for the step-6 deploy check (None = CPU-only
    /// verification, step 6 is skipped).
    pub pjrt_sample: Option<String>,
    pub seed: u64,
    /// Run the function-block path (detect → confirm → replace with
    /// catalogued IP cores) before the loop funnel. Off by default.
    pub func_blocks: bool,
}

impl OffloadRequest {
    /// Start a validated builder.
    pub fn builder(app: impl Into<String>) -> OffloadRequestBuilder {
        OffloadRequestBuilder {
            app: app.into(),
            source: None,
            entry: "main".to_string(),
            pjrt_sample: None,
            seed: 42,
            func_blocks: false,
        }
    }

    /// A request for a registered test case (the test-case DB knows the
    /// entry point and the sample test; the caller supplies the source).
    pub fn from_case(case: &TestCase, source: impl Into<String>) -> Self {
        OffloadRequest {
            app: case.app.clone(),
            source: source.into(),
            entry: case.entry.clone(),
            pjrt_sample: case.pjrt_sample.clone(),
            seed: 42,
            func_blocks: false,
        }
    }

    /// Enable (or disable) the function-block path on an existing
    /// request.
    pub fn with_func_blocks(mut self, on: bool) -> Self {
        self.func_blocks = on;
        self
    }
}

/// Builder for [`OffloadRequest`]; [`build`](Self::build) validates.
#[derive(Debug, Clone)]
pub struct OffloadRequestBuilder {
    app: String,
    source: Option<String>,
    entry: String,
    pjrt_sample: Option<String>,
    seed: u64,
    func_blocks: bool,
}

impl OffloadRequestBuilder {
    pub fn source(mut self, source: impl Into<String>) -> Self {
        self.source = Some(source.into());
        self
    }

    /// Enable the function-block path (see
    /// [`OffloadRequest::with_func_blocks`]).
    pub fn func_blocks(mut self, on: bool) -> Self {
        self.func_blocks = on;
        self
    }

    pub fn entry(mut self, entry: impl Into<String>) -> Self {
        self.entry = entry.into();
        self
    }

    pub fn pjrt_sample(mut self, sample: impl Into<String>) -> Self {
        self.pjrt_sample = Some(sample.into());
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn build(self) -> Result<OffloadRequest, PipelineError> {
        if self.app.trim().is_empty() {
            return Err(PipelineError::InvalidRequest(
                "application name must not be empty".into(),
            ));
        }
        let source = match self.source {
            Some(s) if !s.trim().is_empty() => s,
            Some(_) => {
                return Err(PipelineError::InvalidRequest(
                    "source must not be empty".into(),
                ))
            }
            None => {
                return Err(PipelineError::InvalidRequest(
                    "source is required (OffloadRequestBuilder::source)"
                        .into(),
                ))
            }
        };
        if self.entry.trim().is_empty() {
            return Err(PipelineError::InvalidRequest(
                "entry function must not be empty".into(),
            ));
        }
        Ok(OffloadRequest {
            app: self.app,
            source,
            entry: self.entry,
            pjrt_sample: self.pjrt_sample,
            seed: self.seed,
            func_blocks: self.func_blocks,
        })
    }
}

/// Step-1 (front) artifact: parsed + semantically-checked program.
#[derive(Clone)]
pub struct Parsed {
    pub req: OffloadRequest,
    pub prog: Program,
    /// [`source_fingerprint`] of the request source.
    pub source_hash: u64,
}

/// Step-1 (back) artifact: the profiled loop analysis.
#[derive(Clone)]
pub struct Analyzed {
    pub req: OffloadRequest,
    pub prog: Program,
    pub source_hash: u64,
    pub analysis: Analysis,
}

/// Function-block stage artifact (between [`Analyzed`] and
/// [`Candidates`]): confirmed, priced, strictly-profitable block
/// replacements whose loops are pre-claimed away from the loop funnel.
/// Empty when the request runs loop-only.
#[derive(Clone)]
pub struct FuncBlocked {
    pub req: OffloadRequest,
    pub prog: Program,
    pub source_hash: u64,
    pub analysis: Analysis,
    pub blocks: Vec<BlockReplacement>,
}

/// Step-2/3 artifact: funnel survivors with generated kernels and
/// pre-compile reports (plus any function-block replacements riding
/// along from the [`FuncBlocked`] stage).
#[derive(Clone)]
pub struct Candidates {
    pub req: OffloadRequest,
    pub prog: Program,
    pub source_hash: u64,
    pub analysis: Analysis,
    pub cands: Vec<Candidate>,
    pub trace: FunnelTrace,
    pub blocks: Vec<BlockReplacement>,
}

/// Step-4 artifact: measured patterns plus compile-job accounting.
pub struct Measured {
    pub req: OffloadRequest,
    pub source_hash: u64,
    pub trace: FunnelTrace,
    pub set: MeasuredSet,
    pub blocks: Vec<BlockReplacement>,
}

/// Step-5 output: the selected offload plan — freshly searched, or
/// reused from the code-pattern DB when the source hash is unchanged.
#[derive(Debug, Clone)]
pub enum Plan {
    Fresh(OffloadSolution),
    Cached(StoredPattern),
    /// The degradation ladder's last rung: no destination could produce
    /// a verified plan (or a stale cached one), so the application keeps
    /// running all-CPU, unmodified. Speedup 1.0, trivially verified,
    /// zero automation time — an app is never left unserved.
    Baseline,
}

impl Plan {
    pub fn is_cached(&self) -> bool {
        matches!(self, Plan::Cached(_))
    }

    /// Whether this is the degraded all-CPU fallback rather than a
    /// searched or cached offload plan.
    pub fn is_baseline(&self) -> bool {
        matches!(self, Plan::Baseline)
    }

    /// The full solution, when this plan came from a fresh search.
    pub fn solution(&self) -> Option<&OffloadSolution> {
        match self {
            Plan::Fresh(sol) => Some(sol),
            Plan::Cached(_) | Plan::Baseline => None,
        }
    }

    /// Offloaded loop ids of the selected pattern.
    pub fn best_loops(&self) -> Vec<u32> {
        match self {
            Plan::Fresh(sol) => sol
                .best_measurement()
                .loops
                .iter()
                .map(|l| l.0)
                .collect(),
            Plan::Cached(rec) => rec.best_pattern.clone(),
            Plan::Baseline => Vec::new(),
        }
    }

    /// Selected pattern as a label ("L12+L13", or "all-CPU").
    pub fn label(&self) -> String {
        match self {
            Plan::Fresh(sol) => sol.best_measurement().label(),
            Plan::Cached(rec) => {
                if rec.best_pattern.is_empty() {
                    "all-CPU".to_string()
                } else {
                    rec.best_pattern
                        .iter()
                        .map(|l| format!("L{l}"))
                        .collect::<Vec<_>>()
                        .join("+")
                }
            }
            Plan::Baseline => "all-CPU".to_string(),
        }
    }

    pub fn speedup(&self) -> f64 {
        match self {
            Plan::Fresh(sol) => sol.speedup(),
            Plan::Cached(rec) => rec.speedup,
            Plan::Baseline => 1.0,
        }
    }

    /// Whether the selected pattern passed functional verification. A
    /// plan whose best measurement failed verification is not
    /// trustworthy — cached plans carry the outcome recorded at store
    /// time, so reuse cannot launder a failed check. Plans measured with
    /// verification disabled (`None`) count as ok. The mixed-destination
    /// selector only routes apps to destinations whose plan holds up.
    pub fn verified_ok(&self) -> bool {
        match self {
            Plan::Fresh(sol) => {
                sol.best_measurement().verified != Some(false)
            }
            Plan::Cached(rec) => rec.verified != Some(false),
            // Running the unmodified program is trivially correct.
            Plan::Baseline => true,
        }
    }

    /// Modeled automation wall clock spent producing this plan, seconds.
    /// Zero for a cache hit — that is the entire point of the DB.
    pub fn automation_s(&self) -> f64 {
        match self {
            Plan::Fresh(sol) => sol.automation_s,
            Plan::Cached(_) | Plan::Baseline => 0.0,
        }
    }

    /// Function-block replacements in this plan (cached plans carry only
    /// the stored count; the full list lives in the record JSON).
    pub fn block_count(&self) -> usize {
        match self {
            Plan::Fresh(sol) => sol.blocks.len(),
            Plan::Cached(rec) => rec.blocks as usize,
            Plan::Baseline => 0,
        }
    }

    /// The full replacement list, when this plan came from a fresh
    /// search.
    pub fn block_replacements(&self) -> &[BlockReplacement] {
        match self {
            Plan::Fresh(sol) => &sol.blocks,
            Plan::Cached(_) | Plan::Baseline => &[],
        }
    }
}

/// Step-5 artifact: a plan, possibly persisted.
#[derive(Debug, Clone)]
pub struct Planned {
    pub req: OffloadRequest,
    pub plan: Plan,
    /// Where the pattern record lives, when a DB is configured.
    pub stored_at: Option<PathBuf>,
}

/// Step-6 artifact: the final report for one application.
#[derive(Debug)]
pub struct Deployed {
    pub app: String,
    /// Backend that measured and deploy-checked the plan.
    pub backend: &'static str,
    pub plan: Plan,
    pub stored_at: Option<PathBuf>,
    /// PJRT sample-test result, when the request names a sample and a
    /// runtime was supplied.
    pub sample_run: Option<SampleRun>,
}

/// The staged flow for one destination backend. See the module docs for
/// the stage table; [`solve`](Self::solve) and [`run`](Self::run) chain
/// the stages for callers that want the old one-call ergonomics.
pub struct Pipeline<'a> {
    config: SearchConfig,
    backend: &'a dyn Backend,
    pattern_db: Option<PathBuf>,
    reuse_cached: bool,
    max_age: Option<Duration>,
    retry: Option<RetryPolicy>,
    clock: SimClock,
    stats: FaultStats,
}

impl<'a> Pipeline<'a> {
    /// A pipeline over a validated configuration.
    pub fn new(
        config: SearchConfig,
        backend: &'a dyn Backend,
    ) -> Result<Self, PipelineError> {
        config.validate().map_err(PipelineError::InvalidConfig)?;
        Ok(Pipeline {
            config,
            backend,
            pattern_db: None,
            reuse_cached: false,
            max_age: None,
            retry: None,
            clock: SimClock::new(),
            stats: FaultStats::new(),
        })
    }

    /// Persist selected plans to (and reuse them from) this pattern-DB
    /// directory.
    pub fn with_pattern_db(mut self, dir: impl Into<PathBuf>) -> Self {
        self.pattern_db = Some(dir.into());
        self
    }

    /// Reuse a stored plan when the app's source hash is unchanged
    /// (skips the whole funnel; requires a pattern DB). Off by default.
    pub fn with_cache_reuse(mut self, on: bool) -> Self {
        self.reuse_cached = on;
        self
    }

    /// Age-based re-search policy (ROADMAP): a stored plan older than
    /// `max_age` is treated as a cache miss — the funnel re-measures and
    /// the record is refreshed — instead of being reused blindly
    /// forever. Records without an age stamp (pre-policy schema) count
    /// as infinitely old. `None` (the default) keeps the old behavior:
    /// matching records never expire.
    pub fn with_max_age(mut self, max_age: Duration) -> Self {
        self.max_age = Some(max_age);
        self
    }

    /// Apply a validated [`RetryPolicy`] to the backend-facing stages
    /// (measure / verify / deploy_check): transient faults are retried
    /// with deterministic backoff on this pipeline's [`SimClock`],
    /// permanent faults fail fast, and per-stage deadlines turn hung
    /// builds into timeouts. Without a policy the pipeline behaves
    /// exactly as before — every backend error is final and panics
    /// propagate.
    pub fn with_retry(
        mut self,
        policy: RetryPolicy,
    ) -> Result<Self, PipelineError> {
        policy.validate().map_err(PipelineError::InvalidConfig)?;
        self.retry = Some(policy);
        Ok(self)
    }

    /// Share a virtual clock (backoff waits, injected hangs, deadline
    /// accounting) with other pipelines or a fault injector. Clones of
    /// one `SimClock` share the same underlying time.
    pub fn with_clock(mut self, clock: SimClock) -> Self {
        self.clock = clock;
        self
    }

    /// Accumulate retry/fault telemetry into a caller-owned
    /// [`FaultStats`] instead of this pipeline's private one. The
    /// service tier hands every worker pipeline the same sink, so
    /// per-job counters survive the pipeline being dropped and surface
    /// through [`StatsSnapshot`](crate::service::StatsSnapshot).
    pub fn with_fault_stats(mut self, stats: FaultStats) -> Self {
        self.stats = stats;
        self
    }

    pub fn config(&self) -> &SearchConfig {
        &self.config
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend
    }

    pub fn retry_policy(&self) -> Option<&RetryPolicy> {
        self.retry.as_ref()
    }

    pub fn clock(&self) -> &SimClock {
        &self.clock
    }

    /// Snapshot of the retry/fault telemetry accumulated by this
    /// pipeline's wrapped stages (all zeros when no [`RetryPolicy`] is
    /// configured).
    pub fn fault_report(&self) -> FaultReport {
        self.stats.snapshot()
    }

    /// A stack-local retry wrapper around this pipeline's backend,
    /// when a [`RetryPolicy`] is configured. The wrapper shares the
    /// pipeline's clock and telemetry, so repeated wrapping accumulates
    /// into one [`FaultReport`].
    fn retrying_backend(&self) -> Option<RetryingBackend<'_>> {
        self.retry.as_ref().map(|policy| RetryingBackend {
            inner: self.backend,
            policy: policy.clone(),
            clock: self.clock.clone(),
            stats: self.stats.clone(),
        })
    }

    /// Step 1 (front): parse + semantic check.
    pub fn parse(&self, req: OffloadRequest) -> Result<Parsed, PipelineError> {
        let _span = obs::span("stage.parse");
        let prog = parse_minic(&req.source)
            .map_err(|e| PipelineError::Parse(format!("{e}")))?;
        typecheck::check_ok(&prog)
            .map_err(|e| PipelineError::Parse(format!("{e}")))?;
        let source_hash = source_fingerprint(&req.source);
        Ok(Parsed {
            req,
            prog,
            source_hash,
        })
    }

    /// Step 1 (back): profiling analysis on the configured engine.
    pub fn analyze(&self, p: Parsed) -> Result<Analyzed, PipelineError> {
        let _span = obs::span("stage.analyze");
        let analysis =
            analyze_with(&p.prog, &p.req.entry, self.config.engine)
                .map_err(|e| PipelineError::Analysis(format!("{e}")))?;
        Ok(Analyzed {
            req: p.req,
            prog: p.prog,
            source_hash: p.source_hash,
            analysis,
        })
    }

    /// Function-block stage (between [`Analyzed`] and [`Candidates`]):
    /// detect catalog matches, behaviorally confirm each through the VM
    /// sample test ([`confirm_blocks`](Self::confirm_blocks)), price the
    /// confirmed blocks on this pipeline's destination
    /// ([`price_blocks`](Self::price_blocks)), and keep the strictly
    /// profitable ones. A no-op (empty block list) when the request runs
    /// loop-only.
    pub fn detect_blocks(
        &self,
        a: Analyzed,
    ) -> Result<FuncBlocked, PipelineError> {
        let _span = obs::span("stage.funcblock");
        let confirmed = self.confirm_blocks(&a);
        Ok(self.price_blocks(a, &confirmed))
    }

    /// Destination-*independent* half of the function-block stage:
    /// detection + VM sample-test confirmation. The result can be shared
    /// across every destination pipeline of a mixed cycle (the batch
    /// orchestrator does exactly that); only pricing is per-backend.
    /// Empty when the request runs loop-only.
    pub fn confirm_blocks(
        &self,
        a: &Analyzed,
    ) -> Vec<funcblock::ConfirmedBlock> {
        if !a.req.func_blocks {
            return Vec::new();
        }
        funcblock::find_blocks(
            &a.prog,
            &a.analysis,
            Catalog::shared(),
            self.config.engine,
            a.req.seed,
        )
    }

    /// Destination-*specific* half of the function-block stage: price
    /// each confirmed block on this backend and keep the strictly
    /// profitable replacements.
    pub fn price_blocks(
        &self,
        a: Analyzed,
        confirmed: &[funcblock::ConfirmedBlock],
    ) -> FuncBlocked {
        let catalog = Catalog::shared();
        let blocks = confirmed
            .iter()
            .filter_map(|cb| {
                let cost = self.backend.price_block(cb, catalog)?;
                if !cost.profitable() {
                    return None;
                }
                Some(BlockReplacement {
                    kind: cb.kind,
                    func: cb.func.clone(),
                    ip_name: catalog.spec(cb.kind).ip_name,
                    loops: cb.loops.clone(),
                    cpu_s: cost.cpu_s,
                    accel_s: cost.accel_s,
                    build_s: cost.build_s,
                    confirmed: true,
                })
            })
            .collect();
        FuncBlocked {
            req: a.req,
            prog: a.prog,
            source_hash: a.source_hash,
            analysis: a.analysis,
            blocks,
        }
    }

    /// Steps 2–3: extraction of offloadable areas + conversion (the
    /// narrowing funnel with OpenCL-style kernel generation inside).
    pub fn extract(&self, a: Analyzed) -> Result<Candidates, PipelineError> {
        self.extract_blocked(FuncBlocked {
            req: a.req,
            prog: a.prog,
            source_hash: a.source_hash,
            analysis: a.analysis,
            blocks: Vec::new(),
        })
    }

    /// Steps 2–3 over a [`FuncBlocked`] stage: the funnel runs only over
    /// the loops no block replacement claimed. When the blocks swallow
    /// every candidate loop, the stage degrades to an empty candidate
    /// set (the plan is then blocks + all-CPU remainder) instead of the
    /// loop-only "no candidates" failure.
    pub fn extract_blocked(
        &self,
        f: FuncBlocked,
    ) -> Result<Candidates, PipelineError> {
        let _span = obs::span("stage.extract");
        let claimed: std::collections::BTreeSet<LoopId> = f
            .blocks
            .iter()
            .flat_map(|b| b.loops.iter().copied())
            .collect();
        let run = funnel::run_excluding(
            &f.prog,
            &f.analysis,
            &self.config,
            self.backend.device(),
            &claimed,
        );
        let (cands, trace) = match run {
            Ok(pair) => pair,
            Err(funnel::FunnelError::NoCandidates)
                if !f.blocks.is_empty() =>
            {
                (
                    Vec::new(),
                    FunnelTrace {
                        total_loops: f.analysis.loops.len(),
                        offloadable: Vec::new(),
                        top_a: Vec::new(),
                        reports: Vec::new(),
                        top_c: Vec::new(),
                    },
                )
            }
            Err(e) => return Err(PipelineError::Search(e.into())),
        };
        Ok(Candidates {
            req: f.req,
            prog: f.prog,
            source_hash: f.source_hash,
            analysis: f.analysis,
            cands,
            trace,
            blocks: f.blocks,
        })
    }

    /// Step 4: verification-environment measurement through the backend
    /// (two rounds: singles, then combinations). When block replacements
    /// are present, the **empty** loop pattern is measured too: the
    /// blocks stand on their own, so "replace the blocks and offload no
    /// further loop" must be a selectable plan — without it, a cycle
    /// whose only winning region was swallowed by a block would be
    /// forced onto the least-bad *losing* loop pattern.
    pub fn measure(&self, c: Candidates) -> Result<Measured, PipelineError> {
        match self.retrying_backend() {
            // The retry wrapper emits its own backend.measure /
            // backend.verify spans (with per-attempt children); the
            // bare backend gets the span decorator instead.
            Some(wrapped) => self.measure_with(c, &wrapped),
            None => self
                .measure_with(c, &TracedBackend::new(self.backend)),
        }
    }

    fn measure_with(
        &self,
        c: Candidates,
        backend: &dyn Backend,
    ) -> Result<Measured, PipelineError> {
        let _span = obs::span("stage.measure");
        let mut set = if c.cands.is_empty() {
            // Every candidate loop was claimed by a block (extract only
            // degrades to an empty set when blocks exist).
            MeasuredSet {
                measurements: Vec::new(),
                rounds: vec![Vec::new()],
            }
        } else {
            measure::measure_patterns(
                &c.prog,
                &c.analysis,
                &c.cands,
                &self.config,
                backend,
            )?
        };
        if !c.blocks.is_empty() {
            let empty: crate::search::patterns::Pattern = Vec::new();
            let bm = backend
                .measure(&c.prog, &c.analysis, &[], &empty, &self.config)
                .map_err(PipelineError::Search)?;
            let verified = if self.config.verify_numerics {
                Some(
                    backend
                        .verify(
                            &c.prog,
                            &[],
                            &empty,
                            &c.analysis.entry,
                            &self.config,
                        )
                        .map_err(PipelineError::Search)?,
                )
            } else {
                None
            };
            set.measurements.push(PatternMeasurement {
                loops: Vec::new(),
                round: 1,
                timing: bm.timing,
                // The empty pattern builds nothing — the blocks' own
                // core-integration builds are accounted at selection.
                compile_s: 0.0,
                verified,
            });
            // ...but its verification-environment *measurement* slot is
            // real wall clock like any other pattern's: account it in
            // the round's job list (a zero-duration compile job adds
            // one measure_seconds slot to automation time).
            if let Some(round) = set.rounds.first_mut() {
                round.push(crate::fpga::CompileJob { duration_s: 0.0 });
            }
        }
        Ok(Measured {
            req: c.req,
            source_hash: c.source_hash,
            trace: c.trace,
            set,
            blocks: c.blocks,
        })
    }

    /// The reuse key this pipeline stores records under and demands back
    /// before replaying one: source hash + backend + entry + destination
    /// device + search-config fingerprint + function-block catalog
    /// fingerprint (0 for loop-only requests).
    /// The [`ReuseKey`] a given request resolves to under this
    /// pipeline's backend and configuration — what [`select`] stores
    /// records under and [`cached_plan`] demands back. Public so the
    /// service tier's shared in-memory index
    /// ([`crate::envadapt::PatternIndex`]) can probe for a hit with
    /// exactly the key a worker-pool solve would store, without any
    /// possibility of the two drifting apart.
    ///
    /// [`select`]: Self::select
    /// [`cached_plan`]: Self::cached_plan
    pub fn reuse_key_for(&self, req: &OffloadRequest) -> ReuseKey {
        self.reuse_key(
            source_fingerprint(&req.source),
            &req.entry,
            req.func_blocks,
        )
    }

    fn reuse_key(
        &self,
        source_hash: u64,
        entry: &str,
        func_blocks: bool,
    ) -> ReuseKey {
        ReuseKey {
            source_hash,
            backend: self.backend.name().to_string(),
            entry: entry.to_string(),
            device: self.backend.destination().to_string(),
            config_fp: self.config.fingerprint(),
            catalog_fp: if func_blocks {
                Catalog::shared_fingerprint()
            } else {
                0
            },
        }
    }

    /// Step 5: solution selection (loop pattern + block replacements),
    /// then persistence when a pattern DB is configured.
    pub fn select(&self, m: Measured) -> Result<Planned, PipelineError> {
        let _span = obs::span("stage.select");
        let mut sol =
            measure::select(&m.req.app, m.trace, m.set, &self.config)?;
        // Fold the block replacements into the solution: combined
        // speedup, and the cores' integration builds on the automation
        // clock.
        sol.automation_s +=
            m.blocks.iter().map(|b| b.build_s).sum::<f64>();
        sol.blocks = m.blocks;
        let stored_at = match &self.pattern_db {
            Some(dir) => {
                let db = PatternDb::open(dir)
                    .map_err(|e| PipelineError::Db(format!("{e:#}")))?;
                let key = self.reuse_key(
                    m.source_hash,
                    &m.req.entry,
                    m.req.func_blocks,
                );
                Some(
                    db.store_hashed(&sol, &key)
                        .map_err(|e| PipelineError::Db(format!("{e:#}")))?,
                )
            }
            None => None,
        };
        Ok(Planned {
            req: m.req,
            plan: Plan::Fresh(sol),
            stored_at,
        })
    }

    /// Step 6: production deployment check. Runs the request's PJRT
    /// sample test when a runtime + artifacts pair is supplied.
    pub fn deploy(
        &self,
        p: Planned,
        env: Option<(&Runtime, &Artifacts)>,
    ) -> Result<Deployed, PipelineError> {
        let _span = obs::span("stage.deploy");
        let sample_run = match (&p.req.pjrt_sample, env) {
            (Some(sample), Some((rt, art))) => {
                let run = match self.retrying_backend() {
                    Some(wrapped) => {
                        wrapped.deploy_check(sample, (rt, art), p.req.seed)
                    }
                    None => TracedBackend::new(self.backend).deploy_check(
                        sample,
                        (rt, art),
                        p.req.seed,
                    ),
                };
                Some(run.map_err(|e| {
                    PipelineError::Deploy(format!("{e:#}"))
                })?)
            }
            _ => None,
        };
        Ok(Deployed {
            app: p.req.app,
            backend: self.backend.name(),
            plan: p.plan,
            stored_at: p.stored_at,
            sample_run,
        })
    }

    /// Pattern-DB lookup for a parsed request: a stored plan whose full
    /// reuse key (source hash + backend + entry + destination device +
    /// config fingerprint) matches, if cache reuse is enabled. A plan
    /// measured on another backend, entry point, board, or under another
    /// search configuration is never reused — a 4x FPGA plan says
    /// nothing about the CPU baseline, an Arria10 plan nothing about a
    /// T4, and records from before the key carried device/config fields
    /// never match at all.
    pub fn cached_plan(
        &self,
        parsed: &Parsed,
    ) -> Result<Option<Planned>, PipelineError> {
        if !self.reuse_cached {
            return Ok(None);
        }
        let Some(dir) = &self.pattern_db else {
            return Ok(None);
        };
        let db = PatternDb::open(dir)
            .map_err(|e| PipelineError::Db(format!("{e:#}")))?;
        let Some(rec) = db
            .load_record(&parsed.req.app)
            .map_err(|e| PipelineError::Db(format!("{e:#}")))?
        else {
            return Ok(None);
        };
        let key = self.reuse_key(
            parsed.source_hash,
            &parsed.req.entry,
            parsed.req.func_blocks,
        );
        if !rec.matches(&key) {
            return Ok(None);
        }
        // Age policy: a matching-but-stale record triggers re-search
        // (re-verification through the full funnel) instead of blind
        // reuse; unstamped records count as infinitely old.
        if let Some(max_age) = self.max_age {
            match rec.age_secs(unix_now()) {
                Some(age) if age <= max_age.as_secs() => {}
                _ => return Ok(None),
            }
        }
        let stored_at = Some(db.path_of(&parsed.req.app));
        Ok(Some(Planned {
            req: parsed.req.clone(),
            plan: Plan::Cached(rec),
            stored_at,
        }))
    }

    /// Degradation-ladder lookup (stale-but-valid rung): a stored plan
    /// whose full reuse key matches this pipeline and request,
    /// *ignoring* the `with_cache_reuse` switch and the age policy. The
    /// batch orchestrator falls back to this only after every
    /// destination has exhausted its retry budget — a stale verified
    /// plan beats leaving the app unserved. Plans that failed
    /// verification at store time are still never served, and DB I/O
    /// errors degrade to "no fallback" rather than aborting the ladder.
    pub fn fallback_plan(&self, req: &OffloadRequest) -> Option<Planned> {
        let dir = self.pattern_db.as_ref()?;
        let db = PatternDb::open(dir).ok()?;
        let rec = db.load_record(&req.app).ok()??;
        let key = self.reuse_key(
            source_fingerprint(&req.source),
            &req.entry,
            req.func_blocks,
        );
        if !rec.matches(&key) || rec.verified == Some(false) {
            return None;
        }
        let stored_at = Some(db.path_of(&req.app));
        Some(Planned {
            req: req.clone(),
            plan: Plan::Cached(rec),
            stored_at,
        })
    }

    /// Stages 1–5 (parse → select), with the pattern-DB cache shortcut
    /// when the stored hash matches, and the function-block stage when
    /// the request asks for it.
    pub fn solve(
        &self,
        req: OffloadRequest,
    ) -> Result<Planned, PipelineError> {
        let parsed = self.parse(req)?;
        if let Some(planned) = self.cached_plan(&parsed)? {
            return Ok(planned);
        }
        let analyzed = self.analyze(parsed)?;
        self.solve_from_analyzed(analyzed)
    }

    /// Stages 2–5 from an existing analysis artifact. Exposed so the
    /// batch orchestrator can run parse/analysis once per application
    /// and fan the shared artifact out across destination pipelines.
    pub fn solve_from_analyzed(
        &self,
        analyzed: Analyzed,
    ) -> Result<Planned, PipelineError> {
        let blocked = self.detect_blocks(analyzed)?;
        self.solve_from_blocked(blocked)
    }

    /// Stages 3–5 from a priced function-block stage. Exposed for the
    /// mixed-cycle batch path: block detection + confirmation are
    /// destination-independent and run once per app; each destination
    /// then prices, extracts, measures and selects on its own.
    pub fn solve_from_blocked(
        &self,
        blocked: FuncBlocked,
    ) -> Result<Planned, PipelineError> {
        let candidates = self.extract_blocked(blocked)?;
        let measured = self.measure(candidates)?;
        self.select(measured)
    }

    /// Stages 4–5 from an existing candidate set. Exposed for the
    /// mixed-cycle batch path: when every destination shares one funnel
    /// configuration and narrowing device, candidate extraction runs
    /// once and each backend only re-measures.
    pub fn solve_from_candidates(
        &self,
        candidates: Candidates,
    ) -> Result<Planned, PipelineError> {
        let measured = self.measure(candidates)?;
        self.select(measured)
    }

    /// All six stages.
    pub fn run(
        &self,
        req: OffloadRequest,
        env: Option<(&Runtime, &Artifacts)>,
    ) -> Result<Deployed, PipelineError> {
        let planned = self.solve(req)?;
        self.deploy(planned, env)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::XEON_BRONZE_3104;
    use crate::hls::ARRIA10_GX;
    use crate::search::FpgaBackend;
    use crate::util::tempdir::TempDir;

    const SRC: &str = "
#define N 1024
float a[N]; float outr[N]; float outi[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.002 - 1.0; }
    for (int i = 0; i < N; i++) { outr[i] = sin(a[i]) * cos(a[i]); }
    for (int i = 0; i < N; i++) { outi[i] = sqrt(a[i] * a[i] + 1.0); }
    return 0;
}";

    fn backend() -> FpgaBackend<'static> {
        FpgaBackend {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        }
    }

    fn request(app: &str) -> OffloadRequest {
        OffloadRequest::builder(app).source(SRC).seed(1).build().unwrap()
    }

    #[test]
    fn builder_rejects_missing_source() {
        let err = OffloadRequest::builder("x").build().unwrap_err();
        assert!(matches!(err, PipelineError::InvalidRequest(_)), "{err}");
    }

    #[test]
    fn builder_rejects_empty_app_and_entry() {
        assert!(matches!(
            OffloadRequest::builder("").source(SRC).build(),
            Err(PipelineError::InvalidRequest(_))
        ));
        assert!(matches!(
            OffloadRequest::builder("x").source(SRC).entry("").build(),
            Err(PipelineError::InvalidRequest(_))
        ));
        assert!(matches!(
            OffloadRequest::builder("x").source("   \n").build(),
            Err(PipelineError::InvalidRequest(_))
        ));
    }

    #[test]
    fn pipeline_rejects_invalid_config() {
        let b = backend();
        let bad = SearchConfig {
            top_a: 0,
            ..Default::default()
        };
        assert!(matches!(
            Pipeline::new(bad, &b),
            Err(PipelineError::InvalidConfig(_))
        ));
    }

    #[test]
    fn staged_run_produces_a_plan() {
        let b = backend();
        let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let parsed = pipe.parse(request("mini")).unwrap();
        let analyzed = pipe.analyze(parsed).unwrap();
        let candidates = pipe.extract(analyzed).unwrap();
        assert!(!candidates.cands.is_empty());
        let measured = pipe.measure(candidates).unwrap();
        assert!(!measured.set.measurements.is_empty());
        let planned = pipe.select(measured).unwrap();
        assert!(planned.plan.speedup() > 0.5);
        assert!(!planned.plan.is_cached());
        let deployed = pipe.deploy(planned, None).unwrap();
        assert_eq!(deployed.backend, "fpga");
        assert!(deployed.sample_run.is_none());
    }

    #[test]
    fn cache_reuse_skips_the_funnel_on_unchanged_source() {
        let b = backend();
        let dir = TempDir::new("fpga-offload-pipe-cache").unwrap();
        let pipe = Pipeline::new(SearchConfig::default(), &b)
            .unwrap()
            .with_pattern_db(dir.path())
            .with_cache_reuse(true);

        let first = pipe.solve(request("mini")).unwrap();
        assert!(!first.plan.is_cached());
        let second = pipe.solve(request("mini")).unwrap();
        assert!(second.plan.is_cached());
        assert_eq!(first.plan.best_loops(), second.plan.best_loops());
        assert!((first.plan.speedup() - second.plan.speedup()).abs() < 1e-9);

        // A changed source must invalidate the cache.
        let changed = OffloadRequest::builder("mini")
            .source(SRC.replace("0.002", "0.004"))
            .seed(1)
            .build()
            .unwrap();
        let third = pipe.solve(changed).unwrap();
        assert!(!third.plan.is_cached());
    }

    #[test]
    fn cache_reuse_never_crosses_backends() {
        let fpga = backend();
        let dir = TempDir::new("fpga-offload-pipe-xbackend").unwrap();
        let pipe = Pipeline::new(SearchConfig::default(), &fpga)
            .unwrap()
            .with_pattern_db(dir.path())
            .with_cache_reuse(true);
        pipe.solve(request("mini")).unwrap();

        // Same source, same DB, different destination: must re-search.
        let cpu = crate::search::CpuBaseline {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let cpu_pipe = Pipeline::new(SearchConfig::default(), &cpu)
            .unwrap()
            .with_pattern_db(dir.path())
            .with_cache_reuse(true);
        let plan = cpu_pipe.solve(request("mini")).unwrap();
        assert!(!plan.plan.is_cached());
        assert_eq!(plan.plan.speedup(), 1.0);
    }

    #[test]
    fn fingerprint_is_stable_and_source_sensitive() {
        let a = source_fingerprint(SRC);
        assert_eq!(a, source_fingerprint(SRC));
        assert_ne!(a, source_fingerprint("int main() { return 0; }"));
    }

    #[test]
    fn stale_record_triggers_re_search() {
        let b = backend();
        let dir = TempDir::new("fpga-offload-pipe-age").unwrap();
        let pipe = Pipeline::new(SearchConfig::default(), &b)
            .unwrap()
            .with_pattern_db(dir.path())
            .with_cache_reuse(true)
            .with_max_age(Duration::from_secs(3600));

        let first = pipe.solve(request("mini")).unwrap();
        assert!(!first.plan.is_cached());
        // Fresh record: well inside the age budget, so it is reused.
        let second = pipe.solve(request("mini")).unwrap();
        assert!(second.plan.is_cached());

        // Age the record past max_age: the hit must degrade to a fresh
        // re-measurement, not blind reuse. (restamp is the store's seam
        // for exactly this — the record itself stays byte-identical.)
        let db = PatternDb::open(dir.path()).unwrap();
        db.restamp("mini", unix_now() - 7200).unwrap();

        let third = pipe.solve(request("mini")).unwrap();
        assert!(!third.plan.is_cached(), "aged record must re-measure");
        // The re-search refreshed the stamp: reuse works again.
        let fourth = pipe.solve(request("mini")).unwrap();
        assert!(fourth.plan.is_cached());

        // A pipeline without an age policy reuses even an aged record.
        db.restamp("mini", unix_now() - 720_000).unwrap();
        let lax = Pipeline::new(SearchConfig::default(), &b)
            .unwrap()
            .with_pattern_db(dir.path())
            .with_cache_reuse(true);
        assert!(lax.solve(request("mini")).unwrap().plan.is_cached());
    }

    #[test]
    fn func_blocks_flag_is_part_of_the_reuse_key() {
        // A plan searched loop-only must not be replayed for a
        // func-blocks request (and vice versa): the catalog fingerprint
        // component differs.
        let b = backend();
        let dir = TempDir::new("fpga-offload-pipe-fbkey").unwrap();
        let pipe = Pipeline::new(SearchConfig::default(), &b)
            .unwrap()
            .with_pattern_db(dir.path())
            .with_cache_reuse(true);
        let loop_only = pipe.solve(request("mini")).unwrap();
        assert!(!loop_only.plan.is_cached());
        let blocked = pipe
            .solve(request("mini").with_func_blocks(true))
            .unwrap();
        assert!(
            !blocked.plan.is_cached(),
            "blocks-on request must not reuse the loop-only record"
        );
        // Same flavor again: now it reuses.
        let again = pipe
            .solve(request("mini").with_func_blocks(true))
            .unwrap();
        assert!(again.plan.is_cached());
    }

    #[test]
    fn with_retry_rejects_bad_policy() {
        let b = backend();
        let bad = RetryPolicy {
            max_attempts: 0,
            ..RetryPolicy::default()
        };
        let err = Pipeline::new(SearchConfig::default(), &b)
            .unwrap()
            .with_retry(bad)
            .unwrap_err();
        assert!(matches!(err, PipelineError::InvalidConfig(_)), "{err}");
    }

    #[test]
    fn retry_wrapped_solve_matches_plain_solve() {
        // Fault-free regression guard: a retry policy must not change
        // any plan — same loops, same speedup, zero telemetry.
        let b = backend();
        let plain = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let wrapped = Pipeline::new(SearchConfig::default(), &b)
            .unwrap()
            .with_retry(RetryPolicy::default())
            .unwrap();
        let p1 = plain.solve(request("mini")).unwrap();
        let p2 = wrapped.solve(request("mini")).unwrap();
        assert_eq!(p1.plan.best_loops(), p2.plan.best_loops());
        assert!((p1.plan.speedup() - p2.plan.speedup()).abs() < 1e-12);
        let report = wrapped.fault_report();
        assert_eq!(report.total_retries(), 0, "{report:?}");
        assert!(report.measure.calls > 0, "wrapper actually ran");
    }

    #[test]
    fn fallback_plan_ignores_reuse_switch_and_age() {
        let b = backend();
        let dir = TempDir::new("fpga-offload-pipe-fallback").unwrap();
        // Reuse disabled: cached_plan would refuse, fallback must not.
        let pipe = Pipeline::new(SearchConfig::default(), &b)
            .unwrap()
            .with_pattern_db(dir.path())
            .with_max_age(Duration::from_secs(3600));
        let req = request("mini");
        assert!(pipe.fallback_plan(&req).is_none(), "empty DB");
        let first = pipe.solve(req.clone()).unwrap();
        assert!(!first.plan.is_cached());

        let fb = pipe.fallback_plan(&req).expect("stored plan serves");
        assert!(fb.plan.is_cached());
        assert_eq!(first.plan.best_loops(), fb.plan.best_loops());

        // Age the record far past max_age: still served as fallback.
        let db = PatternDb::open(dir.path()).unwrap();
        db.restamp("mini", unix_now() - 720_000).unwrap();
        assert!(pipe.fallback_plan(&req).is_some(), "stale still serves");

        // A changed source must never be served a fallback.
        let changed = OffloadRequest::builder("mini")
            .source(SRC.replace("0.002", "0.004"))
            .seed(1)
            .build()
            .unwrap();
        assert!(pipe.fallback_plan(&changed).is_none());
    }

    #[test]
    fn baseline_plan_shape() {
        let plan = Plan::Baseline;
        assert!(plan.is_baseline());
        assert!(!plan.is_cached());
        assert_eq!(plan.speedup(), 1.0);
        assert_eq!(plan.label(), "all-CPU");
        assert!(plan.verified_ok());
        assert!(plan.best_loops().is_empty());
        assert_eq!(plan.block_count(), 0);
        assert_eq!(plan.automation_s(), 0.0);
    }

    #[test]
    fn detect_blocks_is_a_no_op_when_disabled() {
        let b = backend();
        let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let parsed = pipe.parse(request("mini")).unwrap();
        let analyzed = pipe.analyze(parsed).unwrap();
        let blocked = pipe.detect_blocks(analyzed).unwrap();
        assert!(blocked.blocks.is_empty());
        let candidates = pipe.extract_blocked(blocked).unwrap();
        assert!(!candidates.cands.is_empty());
        assert!(candidates.blocks.is_empty());
    }
}
