//! Multi-application batch orchestration: one automation cycle, many
//! applications — and, in mixed mode, many destinations.
//!
//! The ROADMAP's arXiv:2002.09541 evaluation runs *many* applications
//! through the environment-adaptive cycle at once — cheap now that the
//! slot-resolved VM made per-app profiling fast. A [`Batch`] shares one
//! [`Pipeline`] (one `SearchConfig`, one backend, one measurement budget
//! of `max_patterns` per app) across N requests, runs their funnels
//! concurrently on scoped threads, and aggregates the outcomes into a
//! [`BatchReport`] with per-app and cycle-level accounting.
//!
//! **Mixed destinations** (arXiv:2011.12431): [`Batch::mixed`] registers
//! one pipeline per destination backend. One cycle then measures every
//! app against every destination — reusing each backend's own funnel
//! candidates — and picks the best destination per app by *verified*
//! speedup: the [`BatchEntry`] carries the winning `destination`, the
//! winning plan, and the per-destination [`DestinationOutcome`]s, and the
//! report aggregates the environment's destination split.
//!
//! Concurrency does not change results: each app's search is
//! deterministic under its seed, so a batch entry is identical to
//! running that app through [`Pipeline::solve`] alone on the same
//! backend. A panicking or failing app degrades to an error entry (or a
//! lost destination in mixed mode) — it never aborts the cycle.
//!
//! **Graceful degradation** (the resilience layer,
//! [`crate::search::resilience`]): a destination that fails — or
//! exhausts its retry budget under a [`RetryPolicy`] — drops out, and
//! the app walks the ladder in [`ServiceLevel`] order: next-best
//! verified destination, then a stale-but-valid cached plan from the
//! pattern DB (flagged `served_stale`), then the all-CPU
//! [`Plan::Baseline`]. An app never ends the cycle unserved. Failures
//! are typed [`OffloadError`]s, and the report aggregates per-stage
//! retry telemetry from every destination pipeline.
//!
//! [`RetryPolicy`]: crate::search::resilience::RetryPolicy

use std::path::{Path, PathBuf};

use crate::funcblock::ConfirmedBlock;
use crate::obs::{self, Tracer};
use crate::search::resilience::{
    FaultClass, FaultReport, OffloadError, Stage,
};
use crate::util::json::Json;

use super::pipeline::{
    Analyzed, Candidates, OffloadRequest, Pipeline, Plan, Planned,
};

/// One destination's result for one application in a mixed cycle.
#[derive(Debug)]
pub struct DestinationOutcome {
    /// Backend name ("fpga", "gpu", "omp", "cpu").
    pub backend: &'static str,
    /// The plan this destination produced, when it solved.
    pub plan: Option<Plan>,
    pub stored_at: Option<PathBuf>,
    /// The stage-tagged, classed fault (or caught panic) when this
    /// destination failed.
    pub error: Option<OffloadError>,
}

/// How well an application was served by the cycle — the rungs of the
/// degradation ladder, best first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServiceLevel {
    /// A destination won with every destination healthy.
    Full,
    /// At least one destination dropped out (failed or exhausted its
    /// retry budget); the app routed to its best surviving destination.
    Rerouted,
    /// Every destination failed; a stale-but-valid cached plan from the
    /// pattern DB is served instead.
    ServedStale,
    /// Nothing worked and no cached plan exists; the app keeps running
    /// all-CPU ([`Plan::Baseline`]).
    Baseline,
}

impl ServiceLevel {
    pub fn as_str(self) -> &'static str {
        match self {
            ServiceLevel::Full => "full",
            ServiceLevel::Rerouted => "rerouted",
            ServiceLevel::ServedStale => "served_stale",
            ServiceLevel::Baseline => "baseline",
        }
    }
}

impl std::fmt::Display for ServiceLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Outcome of one application in a batch.
#[derive(Debug)]
pub struct BatchEntry {
    pub app: String,
    /// Winning destination backend, when any destination solved (also
    /// set for a stale-served plan: the destination it was stored for).
    pub destination: Option<&'static str>,
    /// The plan the app is served with. Always present after the
    /// degradation ladder — [`Plan::Baseline`] at worst.
    pub plan: Option<Plan>,
    pub stored_at: Option<PathBuf>,
    /// Combined error text, when every destination failed.
    pub error: Option<String>,
    /// Which ladder rung served this app.
    pub service: ServiceLevel,
    /// Why the app was degraded below [`ServiceLevel::Full`], when it
    /// was (dropped destinations and their fault classes).
    pub degradation: Option<String>,
    /// Every measured destination, in backend registration order
    /// (exactly one for a single-backend batch).
    pub outcomes: Vec<DestinationOutcome>,
}

impl BatchEntry {
    /// Whether the app solved on a real destination (fresh or cached
    /// plan). The all-CPU baseline rung keeps the app *served* but does
    /// not count as solved.
    pub fn ok(&self) -> bool {
        self.plan.as_ref().is_some_and(|p| !p.is_baseline())
    }

    /// Whether the app left the cycle with *some* plan (the ladder
    /// guarantees this for every entry).
    pub fn served(&self) -> bool {
        self.plan.is_some()
    }

    fn cached(&self) -> bool {
        self.plan.as_ref().is_some_and(Plan::is_cached)
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("app", Json::Str(self.app.clone())),
            ("ok", Json::Bool(self.ok())),
            ("cached", Json::Bool(self.cached())),
            (
                "destination",
                match self.destination {
                    Some(d) => Json::Str(d.to_string()),
                    None => Json::Null,
                },
            ),
        ];
        match &self.plan {
            Some(plan) => {
                fields.push((
                    "best_pattern",
                    Json::Arr(
                        plan.best_loops()
                            .iter()
                            .map(|&l| Json::Num(l as f64))
                            .collect(),
                    ),
                ));
                fields.push(("speedup", Json::Num(plan.speedup())));
                fields.push((
                    "blocks",
                    Json::Num(plan.block_count() as f64),
                ));
                fields.push((
                    "automation_hours",
                    Json::Num(plan.automation_s() / 3600.0),
                ));
            }
            None => {
                fields.push(("best_pattern", Json::Null));
                fields.push(("speedup", Json::Null));
                fields.push(("blocks", Json::Null));
                fields.push(("automation_hours", Json::Null));
            }
        }
        fields.push((
            "stored_at",
            match &self.stored_at {
                Some(p) => Json::Str(p.display().to_string()),
                None => Json::Null,
            },
        ));
        fields.push((
            "error",
            match &self.error {
                Some(e) => Json::Str(e.clone()),
                None => Json::Null,
            },
        ));
        fields.push((
            "service",
            Json::Str(self.service.as_str().to_string()),
        ));
        fields.push((
            "served_stale",
            Json::Bool(self.service == ServiceLevel::ServedStale),
        ));
        fields.push((
            "degradation",
            match &self.degradation {
                Some(d) => Json::Str(d.clone()),
                None => Json::Null,
            },
        ));
        // Per-destination speedups (null where that destination failed).
        let mut backends = std::collections::BTreeMap::new();
        for o in &self.outcomes {
            backends.insert(
                o.backend.to_string(),
                match &o.plan {
                    Some(p) => Json::Num(p.speedup()),
                    None => Json::Null,
                },
            );
        }
        fields.push(("backends", Json::Obj(backends)));
        // Typed per-destination faults, in a separate object so the
        // `backends` speedup map stays purely numeric for tooling.
        let mut errors = std::collections::BTreeMap::new();
        for o in &self.outcomes {
            if let Some(e) = &o.error {
                errors.insert(
                    o.backend.to_string(),
                    Json::obj(vec![
                        ("stage", Json::Str(e.stage.as_str().to_string())),
                        ("class", Json::Str(e.class.as_str().to_string())),
                        ("attempts", Json::Num(e.attempts as f64)),
                        ("message", Json::Str(e.message.clone())),
                    ]),
                );
            }
        }
        fields.push(("errors", Json::Obj(errors)));
        Json::obj(fields)
    }
}

/// Aggregate report of one batch automation cycle.
#[derive(Debug)]
pub struct BatchReport {
    pub entries: Vec<BatchEntry>,
    /// Backend that ran the cycle ("fpga", "cpu", ... — "mixed" for a
    /// multi-destination cycle).
    pub backend: &'static str,
    /// All destination backends measured, in registration order.
    pub backends: Vec<&'static str>,
    /// Measurement budget per app (`SearchConfig::max_patterns`).
    pub budget_per_app: usize,
    /// Modeled automation wall clock if all (app × destination)
    /// measurements ran one after another on the shared verification
    /// environment, seconds.
    pub serial_automation_s: f64,
    /// Modeled automation wall clock with all funnels running
    /// concurrently (the batch's threads): the slowest measurement
    /// bounds the cycle, seconds.
    pub concurrent_automation_s: f64,
    /// Aggregated per-stage retry/fault telemetry from every
    /// destination pipeline (all zeros when no pipeline carries a
    /// retry policy).
    pub fault_telemetry: FaultReport,
}

impl BatchReport {
    fn new(
        backend: &'static str,
        backends: Vec<&'static str>,
        budget_per_app: usize,
        entries: Vec<BatchEntry>,
        fault_telemetry: FaultReport,
    ) -> Self {
        let times: Vec<f64> = entries
            .iter()
            .flat_map(|e| e.outcomes.iter())
            .filter_map(|o| o.plan.as_ref().map(Plan::automation_s))
            .collect();
        BatchReport {
            backend,
            backends,
            budget_per_app,
            serial_automation_s: times.iter().sum(),
            concurrent_automation_s: times.iter().fold(0.0, |a, &b| a.max(b)),
            entries,
            fault_telemetry,
        }
    }

    pub fn is_mixed(&self) -> bool {
        self.backends.len() > 1
    }

    pub fn solved(&self) -> usize {
        self.entries.iter().filter(|e| e.ok()).count()
    }

    pub fn failed(&self) -> usize {
        self.entries.len() - self.solved()
    }

    /// Apps that left the cycle with a plan of any kind — the ladder
    /// makes this every app.
    pub fn served(&self) -> usize {
        self.entries.iter().filter(|e| e.served()).count()
    }

    /// Apps served below [`ServiceLevel::Full`].
    pub fn degraded(&self) -> usize {
        self.entries
            .iter()
            .filter(|e| e.service != ServiceLevel::Full)
            .count()
    }

    pub fn cache_hits(&self) -> usize {
        self.entries.iter().filter(|e| e.cached()).count()
    }

    /// How many apps each destination won, in backend registration
    /// order (destinations that won nothing included with 0).
    pub fn destination_counts(&self) -> Vec<(&'static str, usize)> {
        self.backends
            .iter()
            .map(|&b| {
                let n = self
                    .entries
                    .iter()
                    .filter(|e| e.destination == Some(b))
                    .count();
                (b, n)
            })
            .collect()
    }

    /// Serialize for `repro batch --out` and downstream tooling.
    pub fn to_json(&self) -> Json {
        let mut destinations = std::collections::BTreeMap::new();
        for (b, n) in self.destination_counts() {
            destinations.insert(b.to_string(), Json::Num(n as f64));
        }
        Json::obj(vec![
            ("backend", Json::Str(self.backend.to_string())),
            ("mixed", Json::Bool(self.is_mixed())),
            (
                "backends",
                Json::Arr(
                    self.backends
                        .iter()
                        .map(|b| Json::Str(b.to_string()))
                        .collect(),
                ),
            ),
            ("destinations", Json::Obj(destinations)),
            ("apps", Json::Num(self.entries.len() as f64)),
            ("solved", Json::Num(self.solved() as f64)),
            ("failed", Json::Num(self.failed() as f64)),
            ("served", Json::Num(self.served() as f64)),
            ("degraded", Json::Num(self.degraded() as f64)),
            ("cache_hits", Json::Num(self.cache_hits() as f64)),
            ("fault_telemetry", self.fault_telemetry.to_json()),
            (
                "budget_per_app",
                Json::Num(self.budget_per_app as f64),
            ),
            (
                "serial_automation_hours",
                Json::Num(self.serial_automation_s / 3600.0),
            ),
            (
                "concurrent_automation_hours",
                Json::Num(self.concurrent_automation_s / 3600.0),
            ),
            (
                "results",
                Json::Arr(
                    self.entries.iter().map(BatchEntry::to_json).collect(),
                ),
            ),
        ])
    }

    /// Write the JSON report to a file.
    pub fn write_json(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty()).map_err(|e| {
            anyhow::anyhow!("writing batch report {path:?}: {e}")
        })
    }
}

/// N applications through one shared pipeline — or through one pipeline
/// per destination in mixed mode (see module docs).
pub struct Batch<'a> {
    pipelines: Vec<&'a Pipeline<'a>>,
    requests: Vec<OffloadRequest>,
    tracer: Tracer,
}

impl<'a> Batch<'a> {
    /// A single-destination batch (the PR-2 shape): every app measured
    /// on one backend.
    pub fn new(pipeline: &'a Pipeline<'a>) -> Self {
        Batch {
            pipelines: vec![pipeline],
            requests: Vec::new(),
            tracer: Tracer::disabled(),
        }
    }

    /// A mixed-destination batch: one pipeline per destination backend.
    /// Every app is measured against every destination, and the best
    /// verified speedup picks its destination. Registration order breaks
    /// ties (put the preferred destination first).
    ///
    /// Routing and the report are keyed by [`crate::search::Backend::name`]
    /// ("fpga", "gpu", "omp", "cpu") — register at most one pipeline per
    /// backend *kind*; two same-kind backends on different boards would
    /// collide in the per-app `backends` map and the destination split.
    pub fn mixed(pipelines: Vec<&'a Pipeline<'a>>) -> Self {
        Batch {
            pipelines,
            requests: Vec::new(),
            tracer: Tracer::disabled(),
        }
    }

    /// Record spans for this cycle on `tracer`: each app mints its own
    /// root `request` trace, and the destination fan-out, pipeline
    /// stages, retries, and store writes nest under it. Without this
    /// the batch runs untraced (every span site is a no-op).
    pub fn with_tracer(mut self, tracer: Tracer) -> Self {
        self.tracer = tracer;
        self
    }

    pub fn push(&mut self, req: OffloadRequest) {
        self.requests.push(req);
    }

    /// Builder-style [`push`](Self::push).
    pub fn with(mut self, req: OffloadRequest) -> Self {
        self.push(req);
        self
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Destination backends this batch measures, in registration order.
    pub fn backend_names(&self) -> Vec<&'static str> {
        self.pipelines.iter().map(|p| p.backend().name()).collect()
    }

    /// Whether the destination pipelines can share one funnel run per
    /// app: identical search configuration (fingerprint covers every
    /// knob, the execution engine included) and identical narrowing
    /// device. The bundled mixed cycle (fpga+gpu+omp+cpu over one
    /// config, all narrowing on the FPGA resource model) always
    /// qualifies.
    fn sharable(&self) -> bool {
        self.pipelines.len() > 1
            && self.pipelines.windows(2).all(|w| {
                w[0].config().fingerprint() == w[1].config().fingerprint()
                    && w[0].backend().device().name
                        == w[1].backend().device().name
            })
    }

    /// Run every (request × destination) through stages 1–5,
    /// concurrently, then serve each app through the degradation
    /// ladder. In a sharable mixed cycle, parse / profiling analysis /
    /// candidate extraction run **once per app** and fan out to every
    /// destination (only measurement and selection are per-backend);
    /// otherwise each destination runs its own full funnel. One failing
    /// or *panicking* app does not abort the cycle — its entry carries
    /// the typed fault, walks the ladder, and the remaining apps still
    /// solve.
    pub fn run(&self) -> BatchReport {
        // Thread-local trace context does not cross the scoped-thread
        // boundary by itself; capture a handoff here so worker threads
        // can ride an enclosing trace when the caller has one.
        let inherited = obs::handoff();
        let results: Vec<Vec<Result<Planned, OffloadError>>> =
            std::thread::scope(|scope| {
                let inherited = &inherited;
                let handles: Vec<_> = self
                    .requests
                    .iter()
                    .map(|req| {
                        scope.spawn(move || {
                            let _enter = obs::enter(inherited);
                            let mut _child =
                                _enter.is_some().then(|| obs::span("request"));
                            if let Some(s) = _child.as_mut() {
                                s.note(|| req.app.clone());
                            }
                            let _root = _enter.is_none().then(|| {
                                self.tracer.trace("request", &req.app)
                            });
                            self.solve_app(req)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| match h.join() {
                        Ok(per_dest) => per_dest,
                        Err(payload) => {
                            // The shared prefix (parse / analysis)
                            // panicked: every destination loses this app.
                            let fault = panic_fault(payload.as_ref());
                            self.pipelines
                                .iter()
                                .map(|_| Err(fault.clone()))
                                .collect()
                        }
                    })
                    .collect()
            });

        let entries = self
            .requests
            .iter()
            .zip(results)
            .map(|(req, per_app)| {
                let outcomes: Vec<DestinationOutcome> = self
                    .pipelines
                    .iter()
                    .zip(per_app)
                    .map(|(pipe, res)| match res {
                        Ok(Planned {
                            plan, stored_at, ..
                        }) => DestinationOutcome {
                            backend: pipe.backend().name(),
                            plan: Some(plan),
                            stored_at,
                            error: None,
                        },
                        Err(e) => DestinationOutcome {
                            backend: pipe.backend().name(),
                            plan: None,
                            stored_at: None,
                            error: Some(e),
                        },
                    })
                    .collect();
                self.serve_app(req, outcomes)
            })
            .collect();

        let backends = self.backend_names();
        let label = if backends.len() > 1 {
            "mixed"
        } else {
            backends.first().copied().unwrap_or("none")
        };
        let budget = self
            .pipelines
            .first()
            .map(|p| p.config().max_patterns)
            .unwrap_or(0);
        let mut telemetry = FaultReport::default();
        for p in &self.pipelines {
            telemetry.merge(&p.fault_report());
        }
        BatchReport::new(label, backends, budget, entries, telemetry)
    }

    /// The degradation ladder for one application (see the module
    /// docs): best verified surviving destination → stale-but-valid
    /// cached plan → all-CPU baseline. Every rung produces an entry
    /// with a plan; no invariant break can panic the batch.
    fn serve_app(
        &self,
        req: &OffloadRequest,
        outcomes: Vec<DestinationOutcome>,
    ) -> BatchEntry {
        // Rung 1: best surviving destination — verified plans beat
        // unverified ones, then higher speedup wins; earlier
        // registration breaks exact ties.
        let mut best: Option<(usize, bool, f64)> = None;
        for (i, o) in outcomes.iter().enumerate() {
            let Some(plan) = &o.plan else { continue };
            let verified = plan.verified_ok();
            let speedup = plan.speedup();
            let better = match best {
                None => true,
                Some((_, bv, bs)) => {
                    (verified && !bv) || (verified == bv && speedup > bs)
                }
            };
            if better {
                best = Some((i, verified, speedup));
            }
        }
        let dropped: Vec<String> = outcomes
            .iter()
            .filter_map(|o| {
                o.error.as_ref().map(|e| {
                    format!("{} ({} at {})", o.backend, e.class, e.stage)
                })
            })
            .collect();
        if let Some((i, ..)) = best {
            let degradation = if dropped.is_empty() {
                None
            } else {
                Some(format!(
                    "destination(s) dropped out: {}",
                    dropped.join(", ")
                ))
            };
            let service = if dropped.is_empty() {
                ServiceLevel::Full
            } else {
                ServiceLevel::Rerouted
            };
            return BatchEntry {
                app: req.app.clone(),
                destination: Some(outcomes[i].backend),
                plan: outcomes[i].plan.clone(),
                stored_at: outcomes[i].stored_at.clone(),
                error: None,
                service,
                degradation,
                outcomes,
            };
        }

        // Every destination failed.
        let combined = outcomes
            .iter()
            .map(|o| {
                format!(
                    "{}: {}",
                    o.backend,
                    o.error
                        .as_ref()
                        .map(|e| e.to_string())
                        .unwrap_or_else(|| "no plan".to_string())
                )
            })
            .collect::<Vec<_>>()
            .join("; ");

        // Rung 2: a stale-but-valid cached plan, preferring
        // registration order (the caller's destination preference).
        for pipe in &self.pipelines {
            if let Some(planned) = pipe.fallback_plan(req) {
                return BatchEntry {
                    app: req.app.clone(),
                    destination: Some(pipe.backend().name()),
                    plan: Some(planned.plan),
                    stored_at: planned.stored_at,
                    error: Some(combined.clone()),
                    service: ServiceLevel::ServedStale,
                    degradation: Some(format!(
                        "all destinations failed; serving stored plan: \
                         {combined}"
                    )),
                    outcomes,
                };
            }
        }

        // Rung 3: the all-CPU baseline — served, not solved.
        BatchEntry {
            app: req.app.clone(),
            destination: None,
            plan: Some(Plan::Baseline),
            stored_at: None,
            error: Some(combined.clone()),
            service: ServiceLevel::Baseline,
            degradation: Some(format!(
                "all destinations failed; app stays all-CPU: {combined}"
            )),
            outcomes,
        }
    }

    /// One application across every destination, funnel shared where
    /// the pipelines allow it (see `sharable`).
    fn solve_app(
        &self,
        req: &OffloadRequest,
    ) -> Vec<Result<Planned, OffloadError>> {
        if !self.sharable() {
            // Independent full solves, each isolated on its own thread
            // so a panicking backend only loses its own destination.
            let trace = obs::handoff();
            return std::thread::scope(|scope| {
                let trace = &trace;
                let handles: Vec<_> = self
                    .pipelines
                    .iter()
                    .map(|&pipe| {
                        let req = req.clone();
                        scope.spawn(move || {
                            let _enter = obs::enter(trace);
                            let mut span = obs::span("destination");
                            span.note(|| {
                                pipe.backend().name().to_string()
                            });
                            pipe.solve(req)
                        })
                    })
                    .collect();
                handles.into_iter().map(join_solve).collect()
            });
        }

        // Shared prefix: parse + profiling analysis once per app.
        let first = self.pipelines[0];
        let parsed = match first.parse(req.clone()) {
            Ok(p) => p,
            Err(e) => {
                return self.every_destination_fails(e.to_offload_error())
            }
        };
        // Per-destination cache lookups against the shared parse.
        let cached: Vec<Result<Option<Planned>, OffloadError>> = self
            .pipelines
            .iter()
            .map(|p| {
                p.cached_plan(&parsed).map_err(|e| e.to_offload_error())
            })
            .collect();
        let all_cached = cached
            .iter()
            .all(|c| matches!(c, Ok(Some(_)) | Err(_)));
        let analyzed = if all_cached {
            None
        } else {
            match first.analyze(parsed) {
                Ok(a) => Some(a),
                Err(e) => {
                    return self
                        .every_destination_fails(e.to_offload_error())
                }
            }
        };
        // Candidate extraction is destination-independent here (shared
        // narrowing device), *unless* the function-block stage is on:
        // block pricing — and therefore the claimed-loop set the funnel
        // must skip — is per-destination. Block detection + sample-test
        // confirmation, however, are destination-independent and run
        // once here even then.
        let shared_cands = match &analyzed {
            Some(a) if !req.func_blocks => {
                match first.extract(a.clone()) {
                    Ok(c) => Some(c),
                    Err(e) => {
                        return self
                            .every_destination_fails(e.to_offload_error())
                    }
                }
            }
            _ => None,
        };
        let shared_blocks = match &analyzed {
            Some(a) if req.func_blocks => {
                Some(first.confirm_blocks(a))
            }
            _ => None,
        };

        let trace = obs::handoff();
        std::thread::scope(|scope| {
            let analyzed = &analyzed;
            let shared_cands = &shared_cands;
            let shared_blocks = &shared_blocks;
            let trace = &trace;
            let handles: Vec<_> = self
                .pipelines
                .iter()
                .zip(cached)
                .map(|(&pipe, cache_hit)| {
                    scope.spawn(move || {
                        let _enter = obs::enter(trace);
                        let mut span = obs::span("destination");
                        span.note(|| pipe.backend().name().to_string());
                        match cache_hit {
                            Ok(Some(planned)) => Ok(planned),
                            Err(e) => Err(DestFault(e)),
                            Ok(None) => {
                                solve_uncached(
                                    pipe,
                                    analyzed,
                                    shared_cands,
                                    shared_blocks,
                                )
                            }
                        }
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(Ok(planned)) => Ok(planned),
                    Ok(Err(DestFault(e))) => Err(e),
                    Err(payload) => Err(panic_fault(payload.as_ref())),
                })
                .collect()
        })
    }

    fn every_destination_fails(
        &self,
        fault: OffloadError,
    ) -> Vec<Result<Planned, OffloadError>> {
        self.pipelines
            .iter()
            .map(|_| Err(fault.clone()))
            .collect()
    }
}

/// Typed fault carried across the per-destination worker boundary.
struct DestFault(OffloadError);

/// Stages 4–5 for one destination that missed the cache, fed from the
/// shared per-app funnel prefix (see [`Batch::run`]). Hoisted out of
/// the worker closure so the trace guards wrap exactly one call.
fn solve_uncached(
    pipe: &Pipeline<'_>,
    analyzed: &Option<Analyzed>,
    shared_cands: &Option<Candidates>,
    shared_blocks: &Option<Vec<ConfirmedBlock>>,
) -> Result<Planned, DestFault> {
    let r = match (shared_cands, shared_blocks) {
        (Some(c), _) => pipe.solve_from_candidates(c.clone()),
        (None, Some(blocks)) => match analyzed {
            Some(a) => pipe
                .solve_from_blocked(pipe.price_blocks(a.clone(), blocks)),
            None => return Err(DestFault(invariant_fault())),
        },
        (None, None) => match analyzed {
            Some(a) => pipe.solve_from_analyzed(a.clone()),
            None => return Err(DestFault(invariant_fault())),
        },
    };
    r.map_err(|e| DestFault(e.to_offload_error()))
}

fn join_solve(
    h: std::thread::ScopedJoinHandle<
        '_,
        Result<Planned, super::pipeline::PipelineError>,
    >,
) -> Result<Planned, OffloadError> {
    match h.join() {
        Ok(Ok(planned)) => Ok(planned),
        Ok(Err(e)) => Err(e.to_offload_error()),
        Err(payload) => Err(panic_fault(payload.as_ref())),
    }
}

/// A caught worker panic as a typed, non-retryable fault.
fn panic_fault(payload: &(dyn std::any::Any + Send)) -> OffloadError {
    OffloadError::new(
        Stage::Measure,
        FaultClass::Panic,
        format!("worker panicked: {}", panic_message(payload)),
    )
}

/// The shared-funnel invariant ("analysis exists whenever any
/// destination missed the cache") broke. Degrading beats panicking the
/// whole cycle: the destination drops out and the ladder takes over.
fn invariant_fault() -> OffloadError {
    OffloadError::new(
        Stage::Select,
        FaultClass::Permanent,
        "internal invariant broken: shared analysis missing for an \
         uncached destination",
    )
}

/// Best-effort text of a worker panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpu::{XEON_BRONZE_3104, XEON_GOLD_6130};
    use crate::gpu::TESLA_T4;
    use crate::hls::ARRIA10_GX;
    use crate::search::{
        Backend, CpuBaseline, FpgaBackend, GpuBackend, OmpBackend,
        SearchConfig,
    };

    const GOOD: &str = "
#define N 1024
float a[N]; float out[N];
int main() {
    for (int i = 0; i < N; i++) { a[i] = i * 0.001 - 0.5; }
    for (int i = 0; i < N; i++) { out[i] = sin(a[i]) * cos(a[i]); }
    return 0;
}";

    fn backend() -> FpgaBackend<'static> {
        FpgaBackend {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        }
    }

    fn req(app: &str, source: &str) -> OffloadRequest {
        OffloadRequest::builder(app)
            .source(source)
            .seed(7)
            .build()
            .unwrap()
    }

    #[test]
    fn batch_isolates_per_app_failures() {
        let b = backend();
        let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let batch = Batch::new(&pipe)
            .with(req("good", GOOD))
            .with(req("noloop", "int main() { return 42; }"));
        assert_eq!(batch.len(), 2);
        assert!(!batch.is_empty());
        let report = batch.run();
        assert_eq!(report.solved(), 1);
        assert_eq!(report.failed(), 1);
        let bad = &report.entries[1];
        assert_eq!(bad.app, "noloop");
        assert!(bad.error.as_ref().unwrap().contains("funnel"));
        assert!(bad.destination.is_none());
        let good = &report.entries[0];
        assert_eq!(good.destination, Some("fpga"));
    }

    #[test]
    fn batch_matches_individual_runs() {
        let b = backend();
        let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let solo = pipe.solve(req("good", GOOD)).unwrap();
        let report = Batch::new(&pipe).with(req("good", GOOD)).run();
        let entry = &report.entries[0];
        let plan = entry.plan.as_ref().unwrap();
        assert_eq!(plan.best_loops(), solo.plan.best_loops());
        assert!((plan.speedup() - solo.plan.speedup()).abs() < 1e-12);
    }

    #[test]
    fn report_json_shape() {
        let b = backend();
        let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let report = Batch::new(&pipe).with(req("good", GOOD)).run();
        let j = report.to_json();
        assert_eq!(j.get(&["apps"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get(&["solved"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get(&["backend"]).unwrap().as_str(), Some("fpga"));
        assert_eq!(j.get(&["mixed"]).unwrap().as_bool(), Some(false));
        assert_eq!(
            j.get(&["destinations", "fpga"]).unwrap().as_f64(),
            Some(1.0)
        );
        let results = j.get(&["results"]).unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(
            results[0].get(&["app"]).unwrap().as_str(),
            Some("good")
        );
        assert_eq!(
            results[0].get(&["destination"]).unwrap().as_str(),
            Some("fpga")
        );
        assert!(results[0]
            .get(&["backends", "fpga"])
            .unwrap()
            .as_f64()
            .is_some());
        // Round-trips through the parser.
        let text = j.pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    /// A backend that panics while measuring any program with a global
    /// named `boom` — the failure-injection seam for the isolation test.
    struct PanickyBackend<'a>(CpuBaseline<'a>);

    impl Backend for PanickyBackend<'_> {
        fn name(&self) -> &'static str {
            "cpu"
        }

        fn device(&self) -> &crate::hls::Device {
            self.0.device
        }

        fn measure(
            &self,
            prog: &crate::minic::Program,
            analysis: &crate::analysis::Analysis,
            cands: &[crate::search::Candidate],
            pattern: &crate::search::patterns::Pattern,
            cfg: &SearchConfig,
        ) -> Result<
            crate::search::BackendMeasurement,
            crate::search::SearchError,
        > {
            let has_boom = prog.globals.iter().any(|g| {
                matches!(
                    g,
                    crate::minic::ast::Stmt::Decl { name, .. }
                        if name == "boom"
                )
            });
            if has_boom {
                panic!("injected measurement panic");
            }
            self.0.measure(prog, analysis, cands, pattern, cfg)
        }

        fn verify(
            &self,
            prog: &crate::minic::Program,
            cands: &[crate::search::Candidate],
            pattern: &crate::search::patterns::Pattern,
            entry: &str,
            cfg: &SearchConfig,
        ) -> Result<bool, crate::search::SearchError> {
            self.0.verify(prog, cands, pattern, entry, cfg)
        }

        fn deploy_check(
            &self,
            sample: &str,
            env: (&crate::runtime::Runtime, &crate::runtime::Artifacts),
            seed: u64,
        ) -> anyhow::Result<crate::runtime::SampleRun> {
            self.0.deploy_check(sample, env, seed)
        }
    }

    #[test]
    fn panicking_app_degrades_to_an_error_entry() {
        const BOOM: &str = "
#define N 512
float boom[N]; float o[N];
int main() {
    for (int i = 0; i < N; i++) { boom[i] = i * 0.01; }
    for (int i = 0; i < N; i++) { o[i] = sin(boom[i]); }
    return 0;
}";
        let b = PanickyBackend(CpuBaseline {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        });
        let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let report = Batch::new(&pipe)
            .with(req("good", GOOD))
            .with(req("boom", BOOM))
            .run();
        // The panicking app becomes an error entry; the rest still solve.
        assert_eq!(report.solved(), 1);
        assert_eq!(report.failed(), 1);
        let bad = &report.entries[1];
        assert_eq!(bad.app, "boom");
        let err = bad.error.as_ref().unwrap();
        assert!(err.contains("panicked"), "{err}");
        assert!(err.contains("injected measurement panic"), "{err}");
        assert!(report.entries[0].ok());
    }

    /// A second app with a different winner profile, to exercise the
    /// shared-funnel path across more than one request.
    const GOOD2: &str = "
#define N 512
#define REP 8
float x[N]; float y[N];
int main() {
    for (int i = 0; i < N; i++) { x[i] = i * 0.002 - 0.5; }
    for (int r = 0; r < REP; r++) {
        for (int i = 0; i < N; i++) {
            y[i] = sqrt(x[i] * x[i] + 1.0) + sin(x[i]);
        }
    }
    return 0;
}";

    #[test]
    fn shared_funnel_routing_matches_independent_solves() {
        // The mixed cycle shares parse/analysis/extraction per app
        // across the four destination pipelines. Routing and every
        // per-destination figure must be identical to running each
        // (app × backend) solve independently — the PR-3 behavior.
        let fpga = backend();
        let gpu = GpuBackend {
            cpu: &XEON_BRONZE_3104,
            gpu: &TESLA_T4,
            device: &ARRIA10_GX,
        };
        let omp = OmpBackend {
            cpu: &XEON_BRONZE_3104,
            omp: &XEON_GOLD_6130,
            device: &ARRIA10_GX,
        };
        let cpu = CpuBaseline {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let pf = Pipeline::new(SearchConfig::default(), &fpga).unwrap();
        let pg = Pipeline::new(SearchConfig::default(), &gpu).unwrap();
        let po = Pipeline::new(SearchConfig::default(), &omp).unwrap();
        let pc = Pipeline::new(SearchConfig::default(), &cpu).unwrap();
        let batch = Batch::mixed(vec![&pf, &pg, &po, &pc])
            .with(req("good", GOOD))
            .with(req("good2", GOOD2));
        assert!(batch.sharable());
        let report = batch.run();
        assert_eq!(report.solved(), 2);

        for (entry, source) in
            report.entries.iter().zip([GOOD, GOOD2])
        {
            for (outcome, pipe) in
                entry.outcomes.iter().zip([&pf, &pg, &po, &pc])
            {
                let solo = pipe.solve(req(&entry.app, source)).unwrap();
                let shared = outcome.plan.as_ref().unwrap();
                assert_eq!(
                    shared.best_loops(),
                    solo.plan.best_loops(),
                    "{}@{}",
                    entry.app,
                    outcome.backend
                );
                assert!(
                    (shared.speedup() - solo.plan.speedup()).abs()
                        < 1e-12,
                    "{}@{}",
                    entry.app,
                    outcome.backend
                );
            }
            // The winner is whatever an independent comparison picks.
            let best = entry
                .outcomes
                .iter()
                .max_by(|a, b| {
                    a.plan
                        .as_ref()
                        .unwrap()
                        .speedup()
                        .partial_cmp(&b.plan.as_ref().unwrap().speedup())
                        .unwrap()
                })
                .unwrap();
            assert!(
                entry.plan.as_ref().unwrap().speedup() + 1e-12
                    >= best.plan.as_ref().unwrap().speedup()
            );
        }
    }

    #[test]
    fn traced_batch_mints_one_root_per_app() {
        let b = backend();
        let pipe = Pipeline::new(SearchConfig::default(), &b).unwrap();
        let tracer = Tracer::new(&crate::obs::TraceConfig::default());
        let report = Batch::new(&pipe)
            .with(req("good", GOOD))
            .with(req("good2", GOOD2))
            .with_tracer(tracer.clone())
            .run();
        assert_eq!(report.solved(), 2);
        let spans = tracer.spans();
        let roots: Vec<_> = spans
            .iter()
            .filter(|s| s.name == "request")
            .collect();
        assert_eq!(roots.len(), 2, "one root trace per app");
        let apps: std::collections::BTreeSet<&str> =
            roots.iter().map(|s| s.detail.as_str()).collect();
        assert!(apps.contains("good") && apps.contains("good2"));
        assert_ne!(roots[0].trace_id, roots[1].trace_id);
        // The destination fan-out and the pipeline stages nest inside
        // the same traces the roots minted.
        let ids: std::collections::BTreeSet<u64> =
            roots.iter().map(|s| s.trace_id).collect();
        for name in ["destination", "stage.measure", "stage.select"] {
            assert!(
                spans
                    .iter()
                    .any(|s| s.name == name && ids.contains(&s.trace_id)),
                "missing {name} span inside the app traces"
            );
        }
        // An untraced batch records nothing and still solves.
        let silent = Batch::new(&pipe).with(req("good", GOOD)).run();
        assert_eq!(silent.solved(), 1);
    }

    #[test]
    fn different_configs_fall_back_to_independent_funnels() {
        let fpga = backend();
        let cpu = CpuBaseline {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let pf = Pipeline::new(SearchConfig::default(), &fpga).unwrap();
        let pc = Pipeline::new(
            SearchConfig {
                max_patterns: 5,
                ..Default::default()
            },
            &cpu,
        )
        .unwrap();
        let batch = Batch::mixed(vec![&pf, &pc]).with(req("good", GOOD));
        assert!(!batch.sharable());
        let report = batch.run();
        assert_eq!(report.solved(), 1);
        assert!(report.entries[0]
            .outcomes
            .iter()
            .all(|o| o.plan.is_some()));
    }

    #[test]
    fn mixed_batch_picks_a_destination_per_app() {
        let fpga = backend();
        let gpu = GpuBackend {
            cpu: &XEON_BRONZE_3104,
            gpu: &TESLA_T4,
            device: &ARRIA10_GX,
        };
        let omp = OmpBackend {
            cpu: &XEON_BRONZE_3104,
            omp: &XEON_GOLD_6130,
            device: &ARRIA10_GX,
        };
        let cpu = CpuBaseline {
            cpu: &XEON_BRONZE_3104,
            device: &ARRIA10_GX,
        };
        let pf = Pipeline::new(SearchConfig::default(), &fpga).unwrap();
        let pg = Pipeline::new(SearchConfig::default(), &gpu).unwrap();
        let po = Pipeline::new(SearchConfig::default(), &omp).unwrap();
        let pc = Pipeline::new(SearchConfig::default(), &cpu).unwrap();
        let report = Batch::mixed(vec![&pf, &pg, &po, &pc])
            .with(req("good", GOOD))
            .run();
        assert!(report.is_mixed());
        assert_eq!(report.backend, "mixed");
        assert_eq!(report.backends, vec!["fpga", "gpu", "omp", "cpu"]);
        let entry = &report.entries[0];
        assert_eq!(entry.outcomes.len(), 4);
        // Every destination solved this trivially offloadable app...
        assert!(entry.outcomes.iter().all(|o| o.plan.is_some()));
        // ...and the winner beats (or equals) the all-CPU control. (This
        // tiny trig loop has no PCIe budget at all, so the shared-memory
        // many-core actually takes it.)
        let dest = entry.destination.unwrap();
        assert!(
            dest == "fpga" || dest == "gpu" || dest == "omp",
            "picked {dest}"
        );
        let win = entry.plan.as_ref().unwrap();
        assert!(win.verified_ok());
        for o in &entry.outcomes {
            assert!(
                win.speedup() >= o.plan.as_ref().unwrap().speedup() - 1e-12
            );
        }
        // The winning destination's result is identical to a solo run on
        // that backend alone.
        let solo_pipe = match dest {
            "fpga" => &pf,
            "gpu" => &pg,
            "omp" => &po,
            _ => &pc,
        };
        let solo = solo_pipe.solve(req("good", GOOD)).unwrap();
        assert_eq!(win.best_loops(), solo.plan.best_loops());
        assert!((win.speedup() - solo.plan.speedup()).abs() < 1e-12);
    }
}
